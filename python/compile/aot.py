"""AOT exporter: lower every (segment, width, batch) variant to HLO text.

This is the single build-time entry point (``make artifacts``). It:

  1. initializes the SlimResNet parameters deterministically (seed 42),
  2. writes them to ``artifacts/weights.bin`` (flat f32 little-endian, in
     ``model.param_specs`` order),
  3. lowers ``segment_apply`` for every (seg, width, batch) in the grid to
     HLO **text** (``seg{s}_w{WW}_b{B}.hlo.txt``),
  4. lowers a tiny probe computation (runtime smoke test), and
  5. writes ``manifest.json`` describing everything — the rust side's only
     source of truth (artifact table, parameter order/offsets, cost model).

HLO text — NOT ``.serialize()`` — is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

Python never runs at serve time: after this script, the rust binary is
self-contained.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M

DEFAULT_BATCHES = (1, 4, 16)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def artifact_name(seg: int, width: float, batch: int) -> str:
    return f"seg{seg}_w{int(round(width * 100)):03d}_b{batch}.hlo.txt"


def export_segment(params, seg, width, batch, cfg, out_dir):
    """Lower one segment variant; returns its manifest entry."""
    in_shape, out_shape = M.segment_io_shapes(seg, batch, cfg)
    names = M.segment_param_names(seg, cfg)
    specs = dict(M.param_specs(cfg))
    flat_specs = [
        jax.ShapeDtypeStruct(specs[n], jnp.float32) for n in names
    ]

    def fn(x, *flat):
        p = dict(zip(names, flat))
        return M.segment_apply(p, x, seg, width, cfg, impl="pallas")

    x_spec = jax.ShapeDtypeStruct(in_shape, jnp.float32)
    lowered = jax.jit(fn).lower(x_spec, *flat_specs)
    text = to_hlo_text(lowered)
    fname = artifact_name(seg, width, batch)
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    return {
        "file": fname,
        "segment": seg,
        "width": width,
        "batch": batch,
        "input_shape": list(in_shape),
        "output_shape": list(out_shape),
        "params": names,
        "flops_wprev_full": M.segment_flops(seg, width, 1.0, batch, cfg),
    }


def export_goldens(params, cfg, out_dir: str, batches=(1, 4)) -> list:
    """Golden (input, output) pairs for cross-language numeric validation.

    The rust integration test (`rust/tests/runtime_golden.rs`) loads the
    HLO artifact, executes it via PJRT, and compares with these outputs —
    the end-to-end proof that the python-authored network and the
    rust-served one compute the same function.
    """
    goldens = []
    key = jax.random.PRNGKey(9)
    for batch in batches:
        for seg, width in ((0, 0.5), (1, 0.25), (2, 0.75), (3, 1.0)):
            in_shape, out_shape = M.segment_io_shapes(seg, batch, cfg)
            key, sub = jax.random.split(key)
            x = jax.random.normal(sub, in_shape, jnp.float32)
            if seg > 0:
                # make the input a realistic full-interface tensor: zeros
                # above a previous width's active slice
                c_prev = cfg["base_channels"][seg - 1]
                x = x.at[..., M.c_active(c_prev, 0.5):].set(0.0)
            y = M.segment_apply(params, x, seg, width, cfg, impl="ref")
            xf = f"golden_seg{seg}_b{batch}_in.bin"
            yf = f"golden_seg{seg}_b{batch}_out.bin"
            np.asarray(x, dtype=np.float32).tofile(os.path.join(out_dir, xf))
            np.asarray(y, dtype=np.float32).tofile(os.path.join(out_dir, yf))
            goldens.append(
                {
                    "segment": seg,
                    "width": width,
                    "batch": batch,
                    "artifact": artifact_name(seg, width, batch),
                    "input_file": xf,
                    "input_shape": list(in_shape),
                    "output_file": yf,
                    "output_shape": list(out_shape),
                }
            )
    return goldens


def export_probe(out_dir: str) -> dict:
    """Tiny matmul+2 probe for runtime smoke tests (mirrors xla-example)."""

    def fn(x, y):
        return (jnp.matmul(x, y) + 2.0,)

    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    text = to_hlo_text(jax.jit(fn).lower(spec, spec))
    with open(os.path.join(out_dir, "probe.hlo.txt"), "w") as f:
        f.write(text)
    return {"file": "probe.hlo.txt", "input_shape": [2, 2]}


def write_weights(params, cfg, out_dir: str) -> dict:
    """Flat f32 LE dump in param_specs order + offset table."""
    tensors = []
    offset = 0
    chunks = []
    for name, shape in M.param_specs(cfg):
        arr = np.asarray(params[name], dtype=np.float32)
        assert tuple(arr.shape) == tuple(shape), name
        chunks.append(arr.tobytes())
        size = arr.size * 4
        tensors.append(
            {"name": name, "shape": list(shape), "offset": offset, "bytes": size}
        )
        offset += size
    with open(os.path.join(out_dir, "weights.bin"), "wb") as f:
        f.write(b"".join(chunks))
    return {"file": "weights.bin", "total_bytes": offset, "tensors": tensors}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--scale", default=os.environ.get("SLIM_SCALE", "full"),
                    choices=["tiny", "small", "full"])
    ap.add_argument("--batches", default=os.environ.get("SLIM_BATCHES", ""),
                    help="comma list, default 1,4,16")
    ap.add_argument("--seed", type=int, default=42)
    args = ap.parse_args()

    batches = (
        tuple(int(b) for b in args.batches.split(",") if b)
        if args.batches
        else DEFAULT_BATCHES
    )
    cfg = M.make_config(args.scale)
    os.makedirs(args.out_dir, exist_ok=True)

    t0 = time.time()
    params = M.init_params(cfg, seed=args.seed)
    weights = write_weights(params, cfg, args.out_dir)
    print(f"weights.bin: {weights['total_bytes']} bytes "
          f"({len(weights['tensors'])} tensors)")

    artifacts = []
    for seg in range(M.NUM_SEGMENTS):
        for width in cfg["widths"]:
            for batch in batches:
                entry = export_segment(params, seg, width, batch, cfg, args.out_dir)
                artifacts.append(entry)
                print(f"  lowered {entry['file']} "
                      f"({time.time() - t0:.1f}s elapsed)")

    probe = export_probe(args.out_dir)
    goldens = export_goldens(params, cfg, args.out_dir,
                             batches=tuple(b for b in (1, 4) if b in batches))

    manifest = {
        "version": 1,
        "seed": args.seed,
        "model": cfg,
        "batches": list(batches),
        "segments": M.NUM_SEGMENTS,
        "weights": weights,
        "probe": probe,
        "goldens": goldens,
        "artifacts": artifacts,
        "segment_weight_bytes": [
            M.segment_weight_bytes(s, cfg) for s in range(M.NUM_SEGMENTS)
        ],
        "segment_activation_bytes": {
            str(b): [
                M.segment_activation_bytes(s, b, cfg)
                for s in range(M.NUM_SEGMENTS)
            ]
            for b in batches
        },
        "flops": {
            f"{s}|{w}|{wp}|{b}": M.segment_flops(s, w, wp, b, cfg)
            for s in range(M.NUM_SEGMENTS)
            for w in cfg["widths"]
            for wp in ([1.0] if s == 0 else cfg["widths"])
            for b in batches
        },
    }
    # manifest.json is written last: it is the Makefile's staleness stamp.
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest.json: {len(artifacts)} artifacts in "
          f"{time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
