"""L2: segmented, universally-slimmable SlimResNet (JAX, calls L1 kernels).

The backbone mirrors the paper's setup: four sequential segments, each
supporting width ratios w in {0.25, 0.50, 0.75, 1.00}, GroupNorm instead of
BatchNorm. Segment s (CIFAR 32x32x3 input):

  seg0: stem conv3x3 (3 -> C0, stride 1) + GN/ReLU + BasicBlock(C0)   @32x32
  seg1: down conv3x3 (C0 -> C1, stride 2) + GN/ReLU + BasicBlock(C1)  @16x16
  seg2: down conv3x3 (C1 -> C2, stride 2) + GN/ReLU + BasicBlock(C2)  @8x8
  seg3: down conv3x3 (C2 -> C3, stride 2) + GN/ReLU + BasicBlock(C3)  @4x4
        + global avg pool + slimmed FC -> num_classes logits

Slimming: within segment s at width w, every conv writes only the first
``c_act = w * C_s`` output channels (whole GroupNorm groups); interface
tensors stay full-size with exact zeros above c_act, so a segment can
consume any previous width without re-export (DESIGN.md §2).

Each public entry point takes ``impl`` = "pallas" (L1 kernels, the AOT
path) or "ref" (pure-jnp oracles) so pytest can diff them end-to-end.
"""

from __future__ import annotations

import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import masked_groupnorm, slim_conv2d, slim_matmul
from .kernels import ref as R

WIDTHS = (0.25, 0.50, 0.75, 1.00)
NUM_SEGMENTS = 4


def make_config(scale: str = "full") -> dict:
    """Model configuration. ``full`` is the paper-sized CIFAR backbone;
    ``tiny`` keeps tests and CI fast."""
    if scale == "full":
        base = [32, 64, 128, 256]
    elif scale == "small":
        base = [16, 32, 64, 128]
    elif scale == "tiny":
        base = [8, 8, 16, 16]
    else:
        raise ValueError(f"unknown scale {scale!r}")
    return {
        "scale": scale,
        "img": 32,
        "in_ch": 3,
        "num_classes": 100,
        "base_channels": base,
        "widths": list(WIDTHS),
        "groups": 8,
    }


def c_active(c: int, width: float) -> int:
    """Active channel count for width ratio w (always whole GN groups)."""
    return int(math.ceil(c * width))


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def param_specs(cfg: dict) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) list for every parameter tensor in the model.

    The order is the contract with ``aot.py`` (weights.bin layout) and the
    rust runtime (artifact parameter order)."""
    chans = cfg["base_channels"]
    in_ch = cfg["in_ch"]
    specs: List[Tuple[str, Tuple[int, ...]]] = []
    for s in range(NUM_SEGMENTS):
        c_in = in_ch if s == 0 else chans[s - 1]
        c = chans[s]
        head = "stem" if s == 0 else "down"
        specs.append((f"s{s}.{head}.w", (3, 3, c_in, c)))
        specs.append((f"s{s}.{head}.gn.g", (c,)))
        specs.append((f"s{s}.{head}.gn.b", (c,)))
        specs.append((f"s{s}.blk.c1.w", (3, 3, c, c)))
        specs.append((f"s{s}.blk.gn1.g", (c,)))
        specs.append((f"s{s}.blk.gn1.b", (c,)))
        specs.append((f"s{s}.blk.c2.w", (3, 3, c, c)))
        specs.append((f"s{s}.blk.gn2.g", (c,)))
        specs.append((f"s{s}.blk.gn2.b", (c,)))
    specs.append(("s3.fc.w", (chans[3], cfg["num_classes"])))
    specs.append(("s3.fc.b", (cfg["num_classes"],)))
    return specs


def segment_param_names(seg: int, cfg: dict) -> List[str]:
    """Names (ordered) of the parameters segment ``seg`` consumes."""
    names = [n for n, _ in param_specs(cfg) if n.startswith(f"s{seg}.")]
    return names


def init_params(cfg: dict, seed: int = 42) -> Dict[str, jax.Array]:
    """He-normal conv weights, unit gamma / zero beta, zero fc bias."""
    key = jax.random.PRNGKey(seed)
    params: Dict[str, jax.Array] = {}
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(".w") and len(shape) == 4:  # conv
            fan_in = shape[0] * shape[1] * shape[2]
            params[name] = jax.random.normal(sub, shape, jnp.float32) * math.sqrt(
                2.0 / fan_in
            )
        elif name.endswith(".w"):  # fc
            fan_in = shape[0]
            params[name] = jax.random.normal(sub, shape, jnp.float32) * math.sqrt(
                1.0 / fan_in
            )
        elif name.endswith(".g"):
            params[name] = jnp.ones(shape, jnp.float32)
        else:  # .b (gn beta / fc bias)
            params[name] = jnp.zeros(shape, jnp.float32)
    return params


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _conv(x, w, stride, c_act, impl):
    if impl == "pallas":
        return slim_conv2d(x, w, stride, c_act)
    return R.slim_conv2d_ref(x, w, stride, c_act)


def _gn(x, g, b, groups_act, group_size, relu, impl):
    if impl == "pallas":
        return masked_groupnorm(x, g, b, groups_act, group_size, relu=relu)
    return R.groupnorm_ref(x, g, b, groups_act, group_size, relu=relu)


def _fc(x, w, b, f_act, impl):
    if impl == "pallas":
        return slim_matmul(x, w, b, f_act)
    return R.slim_matmul_ref(x, w, b, f_act)


def segment_apply(
    params: Dict[str, jax.Array],
    x: jax.Array,
    seg: int,
    width: float,
    cfg: dict,
    impl: str = "pallas",
) -> jax.Array:
    """Run one segment at one width.

    x: full-size NHWC activation from the previous segment (zeros above the
    previous segment's active slice — any w_prev works unchanged).
    Returns the full-size activation for the next segment, or (N, classes)
    logits for seg 3.
    """
    if not 0 <= seg < NUM_SEGMENTS:
        raise ValueError(f"segment {seg} out of range")
    if width not in cfg["widths"]:
        raise ValueError(f"width {width} not in {cfg['widths']}")
    c = cfg["base_channels"][seg]
    groups = cfg["groups"]
    group_size = c // groups
    c_act = c_active(c, width)
    groups_act = c_act // group_size
    p = lambda k: params[f"s{seg}.{k}"]  # noqa: E731
    head = "stem" if seg == 0 else "down"
    stride = 1 if seg == 0 else 2

    h = _conv(x, p(f"{head}.w"), stride, c_act, impl)
    h = _gn(h, p(f"{head}.gn.g"), p(f"{head}.gn.b"), groups_act, group_size, True, impl)

    # BasicBlock with identity residual (same width throughout the segment).
    r = h
    h = _conv(h, p("blk.c1.w"), 1, c_act, impl)
    h = _gn(h, p("blk.gn1.g"), p("blk.gn1.b"), groups_act, group_size, True, impl)
    h = _conv(h, p("blk.c2.w"), 1, c_act, impl)
    h = _gn(h, p("blk.gn2.g"), p("blk.gn2.b"), groups_act, group_size, False, impl)
    h = jnp.maximum(h + r, 0.0)  # zeros + zeros stay zero above c_act

    if seg == 3:
        pooled = h.mean(axis=(1, 2))  # (N, C3) — zeros above c_act
        return _fc(pooled, p("fc.w"), p("fc.b"), c_act, impl)
    return h


def full_forward(
    params: Dict[str, jax.Array],
    x: jax.Array,
    widths: Tuple[float, float, float, float],
    cfg: dict,
    impl: str = "pallas",
) -> jax.Array:
    """Chain all four segments at a per-segment width tuple -> logits."""
    h = x
    for s in range(NUM_SEGMENTS):
        h = segment_apply(params, h, s, widths[s], cfg, impl)
    return h


# ---------------------------------------------------------------------------
# Shapes and cost model (exported into the artifact manifest)
# ---------------------------------------------------------------------------

def segment_io_shapes(seg: int, batch: int, cfg: dict):
    """(input_shape, output_shape) of a segment at batch size b (full-size
    interfaces — width does not change shapes)."""
    img = cfg["img"]
    chans = cfg["base_channels"]
    res = [img, img // 2, img // 4, img // 8]
    if seg == 0:
        in_shape = (batch, img, img, cfg["in_ch"])
        out_shape = (batch, res[0], res[0], chans[0])
    else:
        in_shape = (batch, res[seg - 1], res[seg - 1], chans[seg - 1])
        if seg == 3:
            out_shape = (batch, cfg["num_classes"])
        else:
            out_shape = (batch, res[seg], res[seg], chans[seg])
    return in_shape, out_shape


def segment_flops(
    seg: int, width: float, w_prev: float, batch: int, cfg: dict
) -> int:
    """Active FLOPs for one segment at (width, w_prev, batch).

    This is the *semantic* cost of the slimmed computation — the number the
    device simulator charges — accounting for input-side slimming that the
    full-interface HLO does not physically skip (DESIGN.md §2).
    """
    chans = cfg["base_channels"]
    img = cfg["img"]
    res_in = img if seg == 0 else img // (2 ** (seg - 1))
    res_out = img if seg == 0 else img // (2 ** seg)
    c = chans[seg]
    c_act = c_active(c, width)
    c_in = cfg["in_ch"] if seg == 0 else c_active(chans[seg - 1], w_prev)

    def conv_flops(ho, wo, k, ci, co):
        return 2 * batch * ho * wo * k * k * ci * co

    total = conv_flops(res_out, res_out, 3, c_in, c_act)      # stem/down
    total += 2 * conv_flops(res_out, res_out, 3, c_act, c_act)  # block convs
    # GroupNorm + ReLU + residual: ~10 flops/element over 4 activations.
    total += 10 * 4 * batch * res_out * res_out * c_act
    if seg == 3:
        total += 2 * batch * c_act * cfg["num_classes"]
    return int(total)


def segment_weight_bytes(seg: int, cfg: dict) -> int:
    """f32 bytes of the full (unslimmed) weight tensors of one segment —
    what an instance pins in VRAM."""
    total = 0
    for name, shape in param_specs(cfg):
        if name.startswith(f"s{seg}."):
            total += 4 * math.prod(shape)
    return total


def segment_activation_bytes(seg: int, batch: int, cfg: dict) -> int:
    """Peak f32 activation working set (input + output + one temp)."""
    in_shape, out_shape = segment_io_shapes(seg, batch, cfg)
    return 4 * (math.prod(in_shape) + 2 * math.prod(out_shape))
