"""Slimmable backbone training (build-time, §IV.1 of the paper).

The paper trains a universally slimmable SlimResNet with GroupNorm (no
cross-width statistics drift) before evaluating the scheduler. CIFAR-100
is unavailable in this offline environment, so we train on a synthetic
class-conditional dataset (Gaussian class prototypes + noise) — enough to
exercise the full slimmable-training machinery:

* **sandwich rule** (Yu et al.): every step accumulates gradients at the
  slimmest width, the widest width, and one random intermediate width, so
  one weight set serves every width.
* shared GroupNorm affine parameters across widths (masked GN keeps the
  inactive slice at exact zero, so statistics never mix across widths).
* cosine learning-rate schedule (the paper uses cosine over linear).

Run directly for a loss curve, or via pytest (``test_train.py``) for the
loss-decreases contract:

    cd python && python -m compile.train --steps 300
"""

from __future__ import annotations

import argparse
import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from . import model as M


def make_synthetic_dataset(
    cfg: dict,
    n_classes: int,
    n_per_class: int,
    noise_seed: int = 0,
    prototype_seed: int = 7,
) -> Tuple[jax.Array, jax.Array]:
    """Class-conditional Gaussians in image space: learnable but not
    trivial (prototypes overlap under noise). The prototypes are keyed by
    ``prototype_seed`` alone so train and held-out splits share classes
    while drawing independent noise."""
    img, ch = cfg["img"], cfg["in_ch"]
    kp = jax.random.PRNGKey(prototype_seed)
    prototypes = jax.random.normal(kp, (n_classes, img, img, ch)) * 0.8
    key = jax.random.PRNGKey(noise_seed)
    xs, ys = [], []
    for c in range(n_classes):
        key, kn = jax.random.split(key)
        noise = jax.random.normal(kn, (n_per_class, img, img, ch)) * 0.6
        xs.append(prototypes[c][None] + noise)
        ys.append(jnp.full((n_per_class,), c, jnp.int32))
    x = jnp.concatenate(xs)
    y = jnp.concatenate(ys)
    key, ks = jax.random.split(key)
    perm = jax.random.permutation(ks, x.shape[0])
    return x[perm], y[perm]


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()


def loss_at_width(params, x, y, widths, cfg):
    logits = M.full_forward(params, x, widths, cfg, impl="ref")
    return cross_entropy(logits, y)


def sandwich_loss(params, x, y, rand_width, cfg):
    """Sandwich rule: slimmest + widest + one random width tuple."""
    w_min = (0.25, 0.25, 0.25, 0.25)
    w_max = (1.0, 1.0, 1.0, 1.0)
    total = loss_at_width(params, x, y, w_min, cfg)
    total += loss_at_width(params, x, y, w_max, cfg)
    total += loss_at_width(params, x, y, rand_width, cfg)
    return total / 3.0


def cosine_lr(step: int, total: int, base: float, warmup: int = 20) -> float:
    """Cosine schedule with linear warmup (the paper's choice)."""
    if step < warmup:
        return base * (step + 1) / warmup
    t = (step - warmup) / max(1, total - warmup)
    return base * 0.5 * (1.0 + math.cos(math.pi * t))


def train(
    cfg: dict,
    steps: int = 200,
    batch: int = 32,
    lr: float = 0.05,
    n_classes: int = 10,
    seed: int = 0,
    log_every: int = 20,
) -> Dict[str, list]:
    """SGD-with-momentum sandwich training; returns the loss history."""
    params = M.init_params(cfg, seed=42)
    velocity = {k: jnp.zeros_like(v) for k, v in params.items()}
    x_all, y_all = make_synthetic_dataset(cfg, n_classes, 64, seed)
    n = x_all.shape[0]
    key = jax.random.PRNGKey(seed + 1)
    widths = cfg["widths"]

    grad_fn = jax.value_and_grad(sandwich_loss)

    history = {"step": [], "loss": [], "lr": []}
    momentum = 0.9
    for step in range(steps):
        key, kb, kw = jax.random.split(key, 3)
        idx = jax.random.randint(kb, (batch,), 0, n)
        xb, yb = x_all[idx], y_all[idx]
        rand_width = tuple(
            float(widths[int(i)])
            for i in jax.random.randint(kw, (4,), 0, len(widths))
        )
        loss, grads = grad_fn(params, xb, yb, rand_width, cfg)
        step_lr = cosine_lr(step, steps, lr)
        for k in params:
            velocity[k] = momentum * velocity[k] - step_lr * grads[k]
            params[k] = params[k] + velocity[k]
        if step % log_every == 0 or step == steps - 1:
            history["step"].append(step)
            history["loss"].append(float(loss))
            history["lr"].append(step_lr)
            print(f"step {step:>4}  loss {float(loss):.4f}  lr {step_lr:.4f}")
    history["params"] = params
    return history


def eval_accuracy(params, cfg, widths, n_classes=10, seed=123) -> float:
    """Top-1 on a held-out synthetic split at one width tuple."""
    x, y = make_synthetic_dataset(cfg, n_classes, 16, seed)
    logits = M.full_forward(params, x, widths, cfg, impl="ref")
    return float((jnp.argmax(logits, axis=-1) == y).mean())


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--scale", default="tiny", choices=["tiny", "small", "full"])
    ap.add_argument("--classes", type=int, default=10)
    args = ap.parse_args()

    cfg = M.make_config(args.scale)
    hist = train(cfg, steps=args.steps, batch=args.batch, lr=args.lr,
                 n_classes=args.classes)
    params = hist["params"]
    print("\nheld-out top-1 per uniform width (synthetic, 10-way):")
    for w in cfg["widths"]:
        acc = eval_accuracy(params, cfg, (w, w, w, w), args.classes)
        print(f"  w={w:>4}: {acc * 100:.1f}%")


if __name__ == "__main__":
    main()
