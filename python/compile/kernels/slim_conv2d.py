"""L1 Pallas kernel: width-slimmed NHWC 2-D convolution.

The paper's compute hot-spot is the slimmable conv stack of SlimResNet;
slimming means only the first ``c_act = ceil(width * C_out)`` output
channels are computed, the rest of the (full-size) interface tensor is
zero-filled. Input-channel slimming comes for free: the previous segment's
inactive channels are exact zeros, so contracting over the full C_in is
mathematically identical to slicing at ``w_prev`` (DESIGN.md §2).

Formulation — im2col as KH*KW accumulated matmuls. On a real TPU each
``(Ho*Wo, C_in) @ (C_in, c_act)`` product maps straight onto the 128x128
MXU systolic array; the BlockSpec grid walks the batch dimension so one
image's activation tile lives in VMEM while HBM streams the next
(DESIGN.md §Hardware-Adaptation / §Perf for the VMEM budget table).
``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret-mode lowers to plain HLO that the rust runtime
compiles and runs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _slim_conv2d_kernel(x_ref, w_ref, o_ref, *, stride: int, c_act: int):
    """One grid step = one batch element.

    x_ref: (1, H, W, Cin) VMEM block; w_ref: (KH, KW, Cin, Cout) resident;
    o_ref: (1, Ho, Wo, Cout) output block.
    """
    x = x_ref[0]  # (H, W, Cin)
    w = w_ref[...]
    kh_total, kw_total, c_in, c_out = w.shape
    h, w_dim, _ = x.shape
    pad = (kh_total - 1) // 2
    xp = jnp.pad(x, ((pad, pad), (pad, pad), (0, 0)))
    ho = (h + 2 * pad - kh_total) // stride + 1
    wo = (w_dim + 2 * pad - kw_total) // stride + 1

    # im2col: accumulate KH*KW shifted matmuls; each one is MXU-shaped
    # (rows = Ho*Wo output pixels, contraction = Cin, cols = c_act).
    acc = jnp.zeros((ho * wo, c_act), jnp.float32)
    for kh in range(kh_total):
        for kw in range(kw_total):
            patch = jax.lax.slice(
                xp,
                (kh, kw, 0),
                (kh + (ho - 1) * stride + 1, kw + (wo - 1) * stride + 1, c_in),
                (stride, stride, 1),
            )
            mat = patch.reshape(ho * wo, c_in)
            acc = acc + mat @ w[kh, kw, :, :c_act]

    out = acc.reshape(ho, wo, c_act)
    # Zero-fill the slimmed-away channels so the interface stays full-size.
    out = jnp.pad(out, ((0, 0), (0, 0), (0, c_out - c_act)))
    o_ref[0] = out


@functools.partial(jax.jit, static_argnames=("stride", "c_act"))
def slim_conv2d(x: jax.Array, w: jax.Array, stride: int, c_act: int) -> jax.Array:
    """Slimmed conv. x: (N,H,W,Cin) f32, w: (KH,KW,Cin,Cout) f32.

    Returns (N, Ho, Wo, Cout) with channels >= c_act exactly zero.
    """
    n, h, w_dim, c_in = x.shape
    kh, kw, _, c_out = w.shape
    pad = (kh - 1) // 2
    ho = (h + 2 * pad - kh) // stride + 1
    wo = (w_dim + 2 * pad - kw) // stride + 1
    kernel = functools.partial(_slim_conv2d_kernel, stride=stride, c_act=c_act)
    return pl.pallas_call(
        kernel,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, h, w_dim, c_in), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((kh, kw, c_in, c_out), lambda i: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, ho, wo, c_out), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, ho, wo, c_out), jnp.float32),
        interpret=True,
    )(x, w)
