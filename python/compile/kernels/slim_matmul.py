"""L1 Pallas kernel: width-slimmed dense head (classifier).

``logits = x[:, :f_act] @ W[:f_act, :] + b`` — the contraction is sliced to
the active feature count so the slimmed FLOPs are actually saved; the
output (class logits) is always full width. Single-program grid: the whole
(B <= 32, F <= 256, K = 100) problem fits one VMEM tile; on TPU it is one
MXU pass.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _slim_matmul_kernel(x_ref, w_ref, b_ref, o_ref, *, f_act: int):
    x = x_ref[...]
    w = w_ref[...]
    b = b_ref[...]
    o_ref[...] = x[:, :f_act] @ w[:f_act, :] + b


@functools.partial(jax.jit, static_argnames=("f_act",))
def slim_matmul(
    x: jax.Array, w: jax.Array, b: jax.Array, f_act: int
) -> jax.Array:
    """Slimmed dense: x (N,F), w (F,K), b (K) -> (N,K)."""
    n, f = x.shape
    _, k = w.shape
    kernel = functools.partial(_slim_matmul_kernel, f_act=f_act)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.float32),
        interpret=True,
    )(x, w, b)
