"""L1 Pallas kernel: masked GroupNorm (+ optional fused ReLU).

The paper uses GroupNorm instead of BatchNorm to avoid cross-width
statistics drift. With full-size interface tensors (zeros above the active
slice) a naive GroupNorm would normalize the zero padding to
``beta`` — leaking nonzeros into channels that must stay exactly zero for
the next segment's input-slimming identity to hold. This kernel therefore
normalizes only the active groups and writes exact zeros elsewhere.

Active-channel bookkeeping: ``C`` base channels are split into 8 groups of
``group_size = C // 8``; width ``w`` activates ``groups_act = 8 * w``
whole groups (the width set {0.25,0.5,0.75,1.0} always lands on a whole
group boundary).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _masked_gn_kernel(
    x_ref, g_ref, b_ref, o_ref, *, groups_act: int, group_size: int,
    eps: float, relu: bool,
):
    x = x_ref[0]  # (H, W, C)
    h, w_dim, c = x.shape
    c_act = groups_act * group_size
    xa = x[..., :c_act].reshape(h * w_dim, groups_act, group_size)
    mean = xa.mean(axis=(0, 2), keepdims=True)
    var = ((xa - mean) ** 2).mean(axis=(0, 2), keepdims=True)
    xn = (xa - mean) * jax.lax.rsqrt(var + eps)
    out = xn.reshape(h, w_dim, c_act) * g_ref[:c_act] + b_ref[:c_act]
    if relu:
        out = jnp.maximum(out, 0.0)
    o_ref[0] = jnp.pad(out, ((0, 0), (0, 0), (0, c - c_act)))


@functools.partial(
    jax.jit, static_argnames=("groups_act", "group_size", "eps", "relu")
)
def masked_groupnorm(
    x: jax.Array,
    gamma: jax.Array,
    beta: jax.Array,
    groups_act: int,
    group_size: int,
    eps: float = 1e-5,
    relu: bool = False,
) -> jax.Array:
    """Masked GroupNorm over NHWC x; channels >= groups_act*group_size are
    exact zeros in the output."""
    n, h, w_dim, c = x.shape
    kernel = functools.partial(
        _masked_gn_kernel,
        groups_act=groups_act,
        group_size=group_size,
        eps=eps,
        relu=relu,
    )
    return pl.pallas_call(
        kernel,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, h, w_dim, c), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((c,), lambda i: (0,)),
            pl.BlockSpec((c,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, h, w_dim, c), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, h, w_dim, c), jnp.float32),
        interpret=True,
    )(x, gamma, beta)
