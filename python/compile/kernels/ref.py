"""Pure-jnp reference oracles for the Pallas kernels.

Every kernel in this package has an exact functional twin here; pytest
(``python/tests/test_kernels.py``) sweeps shapes/widths with hypothesis and
asserts allclose between the Pallas (interpret=True) kernel and these
references. The references are written with ``jax.lax`` convolution
primitives so they are independent of the kernels' im2col formulation.

Slimming convention (shared with the rust side, see DESIGN.md §2):
interface tensors are *full* channel count (NHWC); only the first
``c_act = ceil(width * C)`` channels are live, the rest are exact zeros.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def conv2d_ref(x: jax.Array, w: jax.Array, stride: int = 1) -> jax.Array:
    """Plain NHWC conv with SAME padding for odd kernels, VALID for 1x1.

    x: (N, H, W, Cin); w: (KH, KW, Cin, Cout). Returns (N, Ho, Wo, Cout).
    """
    kh = w.shape[0]
    pad = (kh - 1) // 2
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=((pad, pad), (pad, pad)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def slim_conv2d_ref(
    x: jax.Array, w: jax.Array, stride: int, c_act: int
) -> jax.Array:
    """Slimmed conv: compute only the first ``c_act`` output channels, fill
    the remaining output channels with exact zeros."""
    y = conv2d_ref(x, w[..., :c_act], stride)
    c_out = w.shape[-1]
    return jnp.pad(y, ((0, 0), (0, 0), (0, 0), (0, c_out - c_act)))


def groupnorm_ref(
    x: jax.Array,
    gamma: jax.Array,
    beta: jax.Array,
    groups_act: int,
    group_size: int,
    eps: float = 1e-5,
    relu: bool = False,
) -> jax.Array:
    """Masked GroupNorm over the active channel slice only.

    Normalizes per-sample, per-group over the first
    ``c_act = groups_act * group_size`` channels; channels >= c_act are
    exact zeros in the output (so ``beta`` never leaks into the padding).
    """
    n, h, w_, c = x.shape
    c_act = groups_act * group_size
    xa = x[..., :c_act].reshape(n, h * w_, groups_act, group_size)
    mean = xa.mean(axis=(1, 3), keepdims=True)
    var = ((xa - mean) ** 2).mean(axis=(1, 3), keepdims=True)
    xn = (xa - mean) / jnp.sqrt(var + eps)
    xn = xn.reshape(n, h, w_, c_act) * gamma[:c_act] + beta[:c_act]
    if relu:
        xn = jnp.maximum(xn, 0.0)
    return jnp.pad(xn, ((0, 0), (0, 0), (0, 0), (0, c - c_act)))


def slim_matmul_ref(
    x: jax.Array, w: jax.Array, b: jax.Array, f_act: int
) -> jax.Array:
    """Slimmed dense head: logits = x[:, :f_act] @ w[:f_act] + b."""
    return x[:, :f_act] @ w[:f_act, :] + b


def avgpool_ref(x: jax.Array) -> jax.Array:
    """Global average pool NHWC -> NC."""
    return x.mean(axis=(1, 2))
