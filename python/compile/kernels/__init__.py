"""Pallas kernels (L1) for the Slim Scheduler SlimResNet backbone."""

from .groupnorm import masked_groupnorm
from .slim_conv2d import slim_conv2d
from .slim_matmul import slim_matmul

__all__ = ["masked_groupnorm", "slim_conv2d", "slim_matmul"]
