"""L1 correctness: Pallas kernels (interpret=True) vs pure-jnp oracles.

Hypothesis sweeps shapes, widths, strides and kernel sizes; every test
asserts allclose against ``kernels.ref`` and checks the zero-padding
invariant that the whole slimming scheme rests on.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import masked_groupnorm, slim_conv2d, slim_matmul
from compile.kernels import ref as R

jax.config.update("jax_platform_name", "cpu")

WIDTHS = [0.25, 0.5, 0.75, 1.0]


def rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------------------
# slim_conv2d
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 3),
    hw=st.sampled_from([4, 5, 8]),
    c_in=st.sampled_from([3, 8, 16]),
    c_out=st.sampled_from([8, 16]),
    k=st.sampled_from([1, 3]),
    stride=st.sampled_from([1, 2]),
    width=st.sampled_from(WIDTHS),
    seed=st.integers(0, 2**16),
)
def test_slim_conv2d_matches_ref(n, hw, c_in, c_out, k, stride, width, seed):
    c_act = int(np.ceil(c_out * width))
    x = rand(seed, (n, hw, hw, c_in))
    w = rand(seed + 1, (k, k, c_in, c_out)) * 0.2
    got = slim_conv2d(x, w, stride, c_act)
    want = R.slim_conv2d_ref(x, w, stride, c_act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@given(width=st.sampled_from(WIDTHS), seed=st.integers(0, 100))
@settings(max_examples=8, deadline=None)
def test_slim_conv2d_zero_padding_invariant(width, seed):
    c_out = 16
    c_act = int(np.ceil(c_out * width))
    x = rand(seed, (2, 6, 6, 8))
    w = rand(seed + 1, (3, 3, 8, c_out))
    y = np.asarray(slim_conv2d(x, w, 1, c_act))
    assert np.all(y[..., c_act:] == 0.0)
    if c_act > 0:
        assert np.any(y[..., :c_act] != 0.0)


def test_slim_conv2d_input_slimming_identity():
    """Zeroed input channels above c_prev == physically sliced weights:
    the invariant that lets one artifact serve every w_prev."""
    x = rand(0, (2, 8, 8, 16))
    c_prev = 8
    x_zeroed = x.at[..., c_prev:].set(0.0)
    w = rand(1, (3, 3, 16, 16)) * 0.2
    full = slim_conv2d(x_zeroed, w, 1, 16)
    sliced = R.conv2d_ref(x_zeroed[..., :c_prev], w[:, :, :c_prev, :], 1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(sliced),
                               rtol=2e-5, atol=2e-5)


def test_slim_conv2d_stride2_shape():
    x = rand(0, (1, 8, 8, 4))
    w = rand(1, (3, 3, 4, 8))
    assert slim_conv2d(x, w, 2, 8).shape == (1, 4, 4, 8)


def test_slim_conv2d_1x1_shape():
    x = rand(0, (1, 8, 8, 4))
    w = rand(1, (1, 1, 4, 8))
    assert slim_conv2d(x, w, 1, 8).shape == (1, 8, 8, 8)


# ---------------------------------------------------------------------------
# masked_groupnorm
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 3),
    hw=st.sampled_from([2, 4, 6]),
    group_size=st.sampled_from([2, 4]),
    width=st.sampled_from(WIDTHS),
    relu=st.booleans(),
    seed=st.integers(0, 2**16),
)
def test_groupnorm_matches_ref(n, hw, group_size, width, relu, seed):
    groups = 8
    c = groups * group_size
    groups_act = int(np.ceil(groups * width))
    x = rand(seed, (n, hw, hw, c))
    gamma = rand(seed + 1, (c,)) * 0.5 + 1.0
    beta = rand(seed + 2, (c,)) * 0.5
    got = masked_groupnorm(x, gamma, beta, groups_act, group_size, relu=relu)
    want = R.groupnorm_ref(x, gamma, beta, groups_act, group_size, relu=relu)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


@given(width=st.sampled_from(WIDTHS))
@settings(max_examples=4, deadline=None)
def test_groupnorm_beta_does_not_leak_into_padding(width):
    """With nonzero beta, inactive channels must still be EXACT zeros."""
    groups, group_size = 8, 4
    c = groups * group_size
    groups_act = int(np.ceil(groups * width))
    c_act = groups_act * group_size
    x = rand(0, (2, 4, 4, c))
    beta = jnp.full((c,), 3.14, jnp.float32)
    y = np.asarray(masked_groupnorm(x, jnp.ones(c), beta, groups_act, group_size))
    assert np.all(y[..., c_act:] == 0.0)


def test_groupnorm_normalizes():
    """Full-width GN output has ~zero mean / unit variance per group."""
    x = rand(0, (1, 8, 8, 16)) * 5.0 + 3.0
    y = np.asarray(
        masked_groupnorm(x, jnp.ones(16), jnp.zeros(16), 8, 2)
    ).reshape(64, 8, 2)
    mean = y.mean(axis=(0, 2))
    var = y.var(axis=(0, 2))
    np.testing.assert_allclose(mean, 0.0, atol=1e-4)
    np.testing.assert_allclose(var, 1.0, atol=1e-2)


def test_groupnorm_relu_fusion():
    x = rand(3, (1, 4, 4, 8))
    y = np.asarray(masked_groupnorm(x, jnp.ones(8), jnp.zeros(8), 8, 1, relu=True))
    assert np.all(y >= 0.0)


# ---------------------------------------------------------------------------
# slim_matmul
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 8),
    f=st.sampled_from([8, 16, 32]),
    k=st.sampled_from([10, 100]),
    width=st.sampled_from(WIDTHS),
    seed=st.integers(0, 2**16),
)
def test_slim_matmul_matches_ref(n, f, k, width, seed):
    f_act = int(np.ceil(f * width))
    x = rand(seed, (n, f))
    w = rand(seed + 1, (f, k)) * 0.1
    b = rand(seed + 2, (k,))
    got = slim_matmul(x, w, b, f_act)
    want = R.slim_matmul_ref(x, w, b, f_act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_slim_matmul_ignores_padded_features():
    """Features above f_act must not affect logits even if nonzero."""
    x = rand(0, (4, 16))
    w = rand(1, (16, 10))
    b = jnp.zeros((10,), jnp.float32)
    y1 = np.asarray(slim_matmul(x, w, b, 8))
    x_garbage = x.at[:, 8:].set(999.0)
    y2 = np.asarray(slim_matmul(x_garbage, w, b, 8))
    np.testing.assert_allclose(y1, y2)
