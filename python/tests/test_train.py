"""Slimmable-training contracts: the sandwich rule actually learns, at
every width, and the loss machinery behaves (masked GN keeps widths from
poisoning each other)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import train as T

jax.config.update("jax_platform_name", "cpu")

CFG = M.make_config("tiny")


def test_synthetic_dataset_shapes_and_labels():
    x, y = T.make_synthetic_dataset(CFG, n_classes=5, n_per_class=8)
    assert x.shape == (40, 32, 32, 3)
    assert y.shape == (40,)
    assert set(np.asarray(y).tolist()) == set(range(5))


def test_train_and_heldout_splits_share_prototypes():
    x1, _ = T.make_synthetic_dataset(CFG, 3, 4, noise_seed=0)
    x2, _ = T.make_synthetic_dataset(CFG, 3, 4, noise_seed=1)
    # different noise -> different samples...
    assert not np.allclose(np.asarray(x1), np.asarray(x2))
    # ...but same prototype scale/structure (correlated class means)
    m1 = np.asarray(x1).mean()
    m2 = np.asarray(x2).mean()
    assert abs(m1 - m2) < 0.1


def test_cross_entropy_basics():
    logits = jnp.array([[10.0, 0.0, 0.0], [0.0, 10.0, 0.0]])
    labels = jnp.array([0, 1])
    assert float(T.cross_entropy(logits, labels)) < 0.01
    wrong = jnp.array([1, 0])
    assert float(T.cross_entropy(logits, wrong)) > 5.0


def test_cosine_lr_schedule():
    assert T.cosine_lr(0, 100, 1.0, warmup=10) == pytest.approx(0.1)
    assert T.cosine_lr(9, 100, 1.0, warmup=10) == pytest.approx(1.0)
    mid = T.cosine_lr(55, 100, 1.0, warmup=10)
    assert 0.4 < mid < 0.6
    assert T.cosine_lr(99, 100, 1.0, warmup=10) < 0.01


def test_sandwich_training_reduces_loss_at_all_widths():
    hist = T.train(CFG, steps=40, batch=16, lr=0.05, n_classes=4,
                   seed=0, log_every=200)
    losses = hist["loss"]
    assert losses[-1] < losses[0] * 0.8, f"loss did not drop: {losses}"
    params = hist["params"]
    # loss at every uniform width must beat the untrained network
    fresh = M.init_params(CFG, seed=42)
    x, y = T.make_synthetic_dataset(CFG, 4, 8, noise_seed=99)
    for w in CFG["widths"]:
        trained = float(T.loss_at_width(params, x, y, (w,) * 4, CFG))
        untrained = float(T.loss_at_width(fresh, x, y, (w,) * 4, CFG))
        assert trained < untrained, f"w={w}: {trained} !< {untrained}"


def test_trained_params_keep_slimming_invariant():
    hist = T.train(CFG, steps=10, batch=8, lr=0.05, n_classes=3,
                   seed=1, log_every=200)
    params = hist["params"]
    x, _ = T.make_synthetic_dataset(CFG, 3, 2, noise_seed=5)
    h = M.segment_apply(params, x, 0, 0.5, CFG, impl="ref")
    c_act = M.c_active(CFG["base_channels"][0], 0.5)
    assert np.all(np.asarray(h)[..., c_act:] == 0.0)
