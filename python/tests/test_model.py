"""L2 correctness: segmented SlimResNet — pallas impl vs ref impl,
shape contracts, slimming invariants, and the cost model."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.make_config("tiny")
PARAMS = M.init_params(CFG, seed=42)
WIDTHS = list(M.WIDTHS)


def rand(seed, shape):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


def seg_input(seg, batch, seed=0):
    in_shape, _ = M.segment_io_shapes(seg, batch, CFG)
    return rand(seed, in_shape)


# ---------------------------------------------------------------------------
# impl equivalence
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    seg=st.integers(0, 3),
    width=st.sampled_from(WIDTHS),
    batch=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 1000),
)
def test_segment_pallas_matches_ref(seg, width, batch, seed):
    x = seg_input(seg, batch, seed)
    got = M.segment_apply(PARAMS, x, seg, width, CFG, impl="pallas")
    want = M.segment_apply(PARAMS, x, seg, width, CFG, impl="ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(
    widths=st.tuples(*(st.sampled_from(WIDTHS) for _ in range(4))),
    seed=st.integers(0, 100),
)
def test_full_forward_pallas_matches_ref(widths, seed):
    x = rand(seed, (2, 32, 32, 3))
    got = M.full_forward(PARAMS, x, widths, CFG, impl="pallas")
    want = M.full_forward(PARAMS, x, widths, CFG, impl="ref")
    assert got.shape == (2, CFG["num_classes"])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-4, atol=5e-4)


# ---------------------------------------------------------------------------
# shape and slimming contracts
# ---------------------------------------------------------------------------

def test_segment_shapes_all():
    for seg in range(4):
        for batch in (1, 3):
            x = seg_input(seg, batch)
            _, out_shape = M.segment_io_shapes(seg, batch, CFG)
            y = M.segment_apply(PARAMS, x, seg, 1.0, CFG, impl="ref")
            assert tuple(y.shape) == tuple(out_shape), (seg, batch)


@settings(max_examples=12, deadline=None)
@given(seg=st.integers(0, 2), width=st.sampled_from(WIDTHS))
def test_segment_output_padding_is_zero(seg, width):
    x = seg_input(seg, 2)
    y = np.asarray(M.segment_apply(PARAMS, x, seg, width, CFG, impl="pallas"))
    c = CFG["base_channels"][seg]
    c_act = M.c_active(c, width)
    assert np.all(y[..., c_act:] == 0.0)
    assert np.any(y[..., :c_act] != 0.0)


@settings(max_examples=12, deadline=None)
@given(
    seg=st.integers(1, 3),
    w_prev=st.sampled_from(WIDTHS),
    width=st.sampled_from(WIDTHS),
)
def test_wprev_independence(seg, w_prev, width):
    """A segment artifact must serve ANY previous width: feeding the
    full-size input produced at w_prev equals feeding the explicit slice."""
    x_prev = seg_input(seg - 1, 2, seed=7)
    h = M.segment_apply(PARAMS, x_prev, seg - 1, w_prev, CFG, impl="ref")
    y = M.segment_apply(PARAMS, h, seg, width, CFG, impl="ref")
    # zeroing the (already zero) padding again must change nothing
    c_prev_act = M.c_active(CFG["base_channels"][seg - 1], w_prev)
    h2 = h.at[..., c_prev_act:].set(0.0)
    y2 = M.segment_apply(PARAMS, h2, seg, width, CFG, impl="ref")
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2))


def test_width_changes_output():
    x = seg_input(0, 1)
    y25 = np.asarray(M.segment_apply(PARAMS, x, 0, 0.25, CFG, impl="ref"))
    y100 = np.asarray(M.segment_apply(PARAMS, x, 0, 1.0, CFG, impl="ref"))
    assert not np.allclose(y25, y100)


def test_deterministic_params():
    p2 = M.init_params(CFG, seed=42)
    for k in PARAMS:
        np.testing.assert_array_equal(np.asarray(PARAMS[k]), np.asarray(p2[k]))


def test_invalid_segment_and_width_raise():
    x = seg_input(0, 1)
    with pytest.raises(ValueError):
        M.segment_apply(PARAMS, x, 4, 1.0, CFG)
    with pytest.raises(ValueError):
        M.segment_apply(PARAMS, x, 0, 0.33, CFG)


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------

def test_flops_monotone_in_width():
    for seg in range(4):
        f = [M.segment_flops(seg, w, 1.0, 8, CFG) for w in WIDTHS]
        assert f == sorted(f) and f[0] < f[-1]


def test_flops_monotone_in_wprev():
    for seg in range(1, 4):
        f = [M.segment_flops(seg, 0.5, wp, 8, CFG) for wp in WIDTHS]
        assert f == sorted(f) and f[0] < f[-1]


def test_flops_linear_in_batch():
    a = M.segment_flops(1, 0.5, 0.5, 4, CFG)
    b = M.segment_flops(1, 0.5, 0.5, 8, CFG)
    assert b == 2 * a


def test_weight_bytes_match_param_specs():
    total = sum(
        4 * math.prod(shape) for _, shape in M.param_specs(CFG)
    )
    segs = sum(M.segment_weight_bytes(s, CFG) for s in range(4))
    fc = 4 * (CFG["base_channels"][3] * CFG["num_classes"] + CFG["num_classes"])
    assert segs == total  # fc belongs to s3
    assert M.segment_weight_bytes(3, CFG) > fc


def test_param_specs_cover_all_segments():
    names = [n for n, _ in M.param_specs(CFG)]
    assert len(names) == len(set(names))
    for s in range(4):
        seg_names = M.segment_param_names(s, CFG)
        assert seg_names and all(n.startswith(f"s{s}.") for n in seg_names)
    assert "s3.fc.w" in M.segment_param_names(3, CFG)
