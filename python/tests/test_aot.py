"""AOT exporter contracts: manifest consistency, weights layout, HLO text
round-trip (re-parse the emitted text through xla_client), name schema."""

import json
import os
import struct
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.make_config("tiny")
PARAMS = M.init_params(CFG, seed=42)


@pytest.fixture(scope="module")
def out_dir(tmp_path_factory):
    d = tmp_path_factory.mktemp("artifacts")
    return str(d)


def test_artifact_name_schema():
    assert aot.artifact_name(0, 0.25, 1) == "seg0_w025_b1.hlo.txt"
    assert aot.artifact_name(3, 1.0, 16) == "seg3_w100_b16.hlo.txt"
    assert aot.artifact_name(2, 0.5, 4) == "seg2_w050_b4.hlo.txt"


def test_export_segment_writes_parsable_hlo(out_dir):
    entry = aot.export_segment(PARAMS, 1, 0.5, 2, CFG, out_dir)
    path = os.path.join(out_dir, entry["file"])
    text = open(path).read()
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # the entry layout must list x + every segment param, in order
    n_params = len(entry["params"])
    assert n_params == len(M.segment_param_names(1, CFG))
    in_shape, out_shape = M.segment_io_shapes(1, 2, CFG)
    assert entry["input_shape"] == list(in_shape)
    assert entry["output_shape"] == list(out_shape)
    # input tensor signature appears in the entry computation layout
    dims = ",".join(str(d) for d in in_shape)
    assert f"f32[{dims}]" in text


def test_probe_export(out_dir):
    entry = aot.export_probe(out_dir)
    text = open(os.path.join(out_dir, entry["file"])).read()
    assert "HloModule" in text and "ENTRY" in text


def test_weights_bin_layout(out_dir):
    info = aot.write_weights(PARAMS, CFG, out_dir)
    blob = open(os.path.join(out_dir, info["file"]), "rb").read()
    assert len(blob) == info["total_bytes"]
    # offsets are contiguous and ordered
    offset = 0
    for t in info["tensors"]:
        assert t["offset"] == offset
        offset += t["bytes"]
    assert offset == info["total_bytes"]
    # spot-check round trip of one tensor
    t = next(t for t in info["tensors"] if t["name"] == "s0.stem.w")
    raw = blob[t["offset"]: t["offset"] + t["bytes"]]
    arr = np.frombuffer(raw, dtype="<f4").reshape(t["shape"])
    np.testing.assert_array_equal(arr, np.asarray(PARAMS["s0.stem.w"]))


def test_gn_gamma_roundtrip_is_ones(out_dir):
    info = aot.write_weights(PARAMS, CFG, out_dir)
    blob = open(os.path.join(out_dir, info["file"]), "rb").read()
    t = next(t for t in info["tensors"] if t["name"] == "s1.down.gn.g")
    raw = blob[t["offset"]: t["offset"] + t["bytes"]]
    arr = np.frombuffer(raw, dtype="<f4")
    np.testing.assert_array_equal(arr, np.ones_like(arr))


def test_exported_hlo_text_parses(out_dir):
    """The emitted text must survive the HLO text parser — the same parser
    `HloModuleProto::from_text_file` uses on the rust side. (Numeric
    equivalence vs the jax model is covered by the golden-pair fixtures
    checked in `rust/tests/runtime_golden.rs`.)"""
    from jax._src.lib import xla_client as xc

    entry = aot.export_segment(PARAMS, 0, 0.5, 1, CFG, out_dir)
    text = open(os.path.join(out_dir, entry["file"])).read()
    hlo_module = xc._xla.hlo_module_from_text(text)
    printed = hlo_module.to_string()
    assert "ENTRY" in printed
    # x + every segment param appear as parameters
    n_params = 1 + len(entry["params"])
    assert printed.count("parameter(") >= n_params


def test_golden_pairs(out_dir):
    """Golden (input, output) pairs are self-consistent with the ref model
    and serialized in the layout the rust test expects."""
    goldens = aot.export_goldens(PARAMS, CFG, out_dir, batches=(1,))
    assert goldens
    for g in goldens:
        x = np.fromfile(
            os.path.join(out_dir, g["input_file"]), dtype="<f4"
        ).reshape(g["input_shape"])
        y = np.fromfile(
            os.path.join(out_dir, g["output_file"]), dtype="<f4"
        ).reshape(g["output_shape"])
        want = np.asarray(
            M.segment_apply(
                PARAMS, jnp.asarray(x), g["segment"], g["width"], CFG, impl="ref"
            )
        )
        np.testing.assert_allclose(y, want, rtol=2e-4, atol=2e-4)
