//! Train the PPO router under both reward weightings and print the
//! learning curves plus the resulting Tables IV/V-style reports — the
//! paper's §III-B training pipeline end to end (simulated cluster,
//! virtual time: ~a minute of wall clock for ~10^5 scheduling steps).
//!
//!   cargo run --release --example train_ppo \
//!       [-- --episodes 10 --requests 8000 --workers 4 --scenario hetero-mixed]

use slim_scheduler::config::{Config, RewardCfg};
use slim_scheduler::experiments;
use slim_scheduler::utilx::Args;

fn learning_curve(label: &str, history: &[f64]) {
    println!("\n{label} learning curve (mean reward per update):");
    if history.is_empty() {
        println!("  (no updates)");
        return;
    }
    let min = history.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = history.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let buckets = 20usize.min(history.len());
    let per = history.len() / buckets;
    for b in 0..buckets {
        let chunk = &history[b * per..((b + 1) * per).min(history.len())];
        let mean = chunk.iter().sum::<f64>() / chunk.len() as f64;
        let frac = if max > min { (mean - min) / (max - min) } else { 0.5 };
        let bar = "#".repeat((frac * 46.0) as usize);
        println!("  [{:>3}] {mean:>+10.4} |{bar}", b * per);
    }
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let mut cfg = Config::default();
    cfg.workload.total_requests = args.usize_or("requests", 6000);
    cfg.apply_args(&args);
    let episodes = args.usize_or("episodes", 8);
    let workers = args.usize_or("workers", 1);

    println!(
        "cluster: {:?}, workload {} req @ {}/s (burst ×{}), {} rollout worker(s)",
        cfg.devices, cfg.workload.total_requests, cfg.workload.rate_hz,
        cfg.workload.burst_factor, workers
    );

    // baseline for reference
    let baseline = experiments::run_random_baseline(&cfg);
    println!("\n== Table III baseline (random routing) ==");
    print!("{}", baseline.report.to_table());

    // ---- overfit reward (Table IV) ----
    // --workers N collects the training episodes with N concurrent
    // seeded engines (merged synchronous updates); the wall-clock print
    // makes the speedup visible — compare --workers 1 vs 4.
    let t4 = std::time::Instant::now();
    let (out4, router4) = experiments::run_ppo_experiment_workers(
        &cfg,
        RewardCfg::overfit(),
        episodes,
        workers,
    );
    println!(
        "table IV training: {episodes} episodes, {workers} worker(s), {:.2?}",
        t4.elapsed()
    );
    learning_curve("overfit (β,γ heavy)", &router4.stats.reward_history);
    println!("\n== Table IV (PPO, overfit) ==");
    print!("{}", out4.report.to_table());
    println!("width histogram: {:?}", out4.width_histogram);
    println!(
        "Δ vs baseline: latency {:+.2}%, energy {:+.2}%, accuracy {:+.2} pp",
        experiments::pct_change(
            baseline.report.latency.mean(),
            out4.report.latency.mean()
        ),
        experiments::pct_change(
            baseline.report.energy.mean(),
            out4.report.energy.mean()
        ),
        out4.report.accuracy_pct - baseline.report.accuracy_pct,
    );

    // ---- balanced reward (Table V) ----
    let t5 = std::time::Instant::now();
    let (out5, router5) = experiments::run_ppo_experiment_online_workers(
        &cfg,
        RewardCfg::balanced(),
        episodes,
        workers,
    );
    println!(
        "table V training: {episodes} episodes, {workers} worker(s), {:.2?}",
        t5.elapsed()
    );
    learning_curve("balanced", &router5.stats.reward_history);
    println!("\n== Table V (PPO, balanced, online) ==");
    print!("{}", out5.report.to_table());
    println!("width histogram: {:?}", out5.width_histogram);
    println!(
        "Δ vs baseline: latency {:+.2}%, energy {:+.2}%, accuracy {:+.2} pp",
        experiments::pct_change(
            baseline.report.latency.mean(),
            out5.report.latency.mean()
        ),
        experiments::pct_change(
            baseline.report.energy.mean(),
            out5.report.energy.mean()
        ),
        out5.report.accuracy_pct - baseline.report.accuracy_pct,
    );

    // checkpoint both policies
    std::fs::write("ppo_overfit.json", router4.to_json().to_string_pretty())?;
    std::fs::write("ppo_balanced.json", router5.to_json().to_string_pretty())?;
    println!("\ncheckpoints: ppo_overfit.json, ppo_balanced.json");

    // sanity: the paper's reward presets produce the paper's trade-off
    let reward_cfgs = [RewardCfg::overfit(), RewardCfg::balanced()];
    assert!(reward_cfgs[0].beta > reward_cfgs[1].beta);
    assert!(out4.report.latency.mean() < baseline.report.latency.mean());
    assert!(out4.report.accuracy_pct <= out5.report.accuracy_pct);
    Ok(())
}
