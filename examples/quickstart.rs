//! Quickstart: load the AOT SlimResNet artifacts and run real inference
//! at every uniform width — the 60-second proof that the python-authored,
//! Pallas-kerneled network executes from rust with zero python.
//!
//!   make artifacts && cargo run --release --example quickstart

use slim_scheduler::model::{AccuracyPrior, ModelMeta, WIDTHS};
use slim_scheduler::runtime::{HostTensor, SegmentExecutor};
use slim_scheduler::utilx::Rng;

fn main() -> anyhow::Result<()> {
    let meta = ModelMeta::default();
    let prior = AccuracyPrior::new();
    let mut ex = SegmentExecutor::new("artifacts")?;
    println!(
        "loaded {} artifacts ({} segments × {:?} widths × {:?} batches)\n",
        ex.index.artifacts.len(),
        ex.index.num_segments,
        ex.index.widths,
        ex.index.batches
    );

    // one synthetic CIFAR-like batch
    let batch = 4;
    let (in_shape, _) = meta.seg_io_shapes(0, batch);
    let mut rng = Rng::new(7);
    let mut image = HostTensor::zeros(&in_shape);
    for v in &mut image.data {
        *v = rng.normal() as f32 * 0.5;
    }

    println!(
        "{:<8} {:>12} {:>12} {:>14} {:>10}",
        "width", "cold (compile)", "warm", "prior top-1", "top-1 row0"
    );
    for &w in &WIDTHS {
        let t_cold = std::time::Instant::now();
        let _ = ex.full_forward(&[w, w, w, w], &image)?;
        let cold = t_cold.elapsed();
        let t0 = std::time::Instant::now();
        let logits = ex.full_forward(&[w, w, w, w], &image)?;
        let dt = t0.elapsed();
        let top1 = logits.data[..meta.num_classes]
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        println!(
            "{:<8} {:>13.1?} {:>12.1?} {:>13.2}% {:>10}",
            w,
            cold,
            dt,
            prior.lookup(&[w, w, w, w]),
            top1
        );
    }

    // mixed-width chaining across segment boundaries (any w_prev works)
    let mixed = [0.25, 0.50, 0.75, 1.00];
    let logits = ex.full_forward(&mixed, &image)?;
    println!(
        "\nmixed tuple {:?}: prior {:.2}%, {} logits per image, all finite: {}",
        mixed,
        prior.lookup(&mixed),
        logits.shape[1],
        logits.data.iter().all(|v| v.is_finite())
    );
    println!(
        "PJRT compiles: {}, executions: {}",
        ex.pool.compiles, ex.executions
    );
    Ok(())
}
