//! END-TO-END VALIDATION DRIVER (DESIGN.md §5, EXPERIMENTS.md §E2E).
//!
//! Proves all three layers compose on a real workload:
//!
//!   L1/L2  Pallas-kerneled SlimResNet, AOT-lowered to HLO text
//!   runtime PJRT CPU execution of those artifacts (zero python)
//!   L3     PPO router trained in the simulated cluster (sim-to-real
//!          transfer — the paper's claim that the learned policy
//!          "generalizes across hardware"), greedy per-server dispatch,
//!          three real worker threads standing in for the 3-GPU cluster
//!
//! Serves a bursty stream of CIFAR-sized requests through router →
//! worker → segment chain and reports latency percentiles, throughput,
//! and the served width mix.
//!
//!   make artifacts && cargo run --release --example serve_cluster

use std::sync::mpsc;
use std::thread;
use std::time::Instant;

use slim_scheduler::config::{Config, RewardCfg};
use slim_scheduler::coordinator::router::Router;
use slim_scheduler::coordinator::telemetry::{ServerTelemetry, TelemetrySnapshot};
use slim_scheduler::experiments;
use slim_scheduler::metrics::Summary;
use slim_scheduler::model::{AccuracyPrior, ModelMeta, NUM_SEGMENTS};
use slim_scheduler::runtime::{HostTensor, SegmentExecutor};
use slim_scheduler::utilx::{Args, Rng};

struct Work {
    block_id: u64,
    seg: usize,
    width: f64,
    batch: HostTensor,
}

struct Done {
    worker: usize,
    block_id: u64,
    output: HostTensor,
    exec_ms: f64,
}

struct LiveRequest {
    arrival: Instant,
    seg: usize,
    activation: HostTensor,
    widths_used: [f64; NUM_SEGMENTS],
}

fn stack(batch: &[&HostTensor]) -> HostTensor {
    let mut shape = batch[0].shape.clone();
    shape[0] = batch.len();
    let mut data = Vec::with_capacity(batch[0].numel() * batch.len());
    for t in batch {
        data.extend_from_slice(&t.data);
    }
    HostTensor::from_vec(&shape, data)
}

fn unstack(t: &HostTensor) -> Vec<HostTensor> {
    let n = t.batch();
    (0..n).map(|i| {
        let row = t.numel() / n;
        let mut shape = t.shape.clone();
        shape[0] = 1;
        HostTensor::from_vec(&shape, t.data[i * row..(i + 1) * row].to_vec())
    }).collect()
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let total: usize = args.usize_or("images", 192);
    let n_workers = 3usize;

    // ---- phase 1: train the router in the simulated cluster ----
    println!("[1/3] training PPO router in the simulated 3-GPU cluster...");
    let mut sim_cfg = Config::default();
    sim_cfg.workload.total_requests = args.usize_or("train-requests", 4000);
    let mut router = experiments::train_ppo(&sim_cfg, RewardCfg::balanced(),
                                            args.usize_or("episodes", 5));
    router.eval_mode();
    println!(
        "      {} updates, final reward {:+.3}",
        router.stats.updates,
        router.stats.reward_history.last().copied().unwrap_or(0.0)
    );

    // ---- phase 2: spin up real PJRT workers ----
    println!("[2/3] starting {n_workers} PJRT CPU workers (compiling artifacts)...");
    let (done_tx, done_rx) = mpsc::channel::<Done>();
    let mut work_txs = Vec::new();
    let mut handles = Vec::new();
    for worker_id in 0..n_workers {
        let (tx, rx) = mpsc::channel::<Work>();
        work_txs.push(tx);
        let done = done_tx.clone();
        handles.push(thread::spawn(move || -> anyhow::Result<()> {
            let mut ex = SegmentExecutor::new("artifacts")?;
            // pre-compile every width so serving measures execution, not
            // compilation; signal readiness with a sentinel block id
            ex.warm_all(&[0.25, 0.5, 0.75, 1.0])?;
            done.send(Done {
                worker: worker_id,
                block_id: u64::MAX,
                output: HostTensor::zeros(&[1]),
                exec_ms: 0.0,
            })
            .ok();
            while let Ok(w) = rx.recv() {
                let t0 = Instant::now();
                let output = ex.execute(w.seg, w.width, &w.batch)?;
                done.send(Done {
                    worker: worker_id,
                    block_id: w.block_id,
                    output,
                    exec_ms: t0.elapsed().as_secs_f64() * 1e3,
                })
                .ok();
            }
            Ok(())
        }));
    }
    drop(done_tx);

    // wait until every worker has compiled its artifact set
    for _ in 0..n_workers {
        let ready = done_rx.recv().expect("worker ready");
        assert_eq!(ready.block_id, u64::MAX);
    }
    println!("      all workers warm");

    // ---- phase 3: serve ----
    println!("[3/3] serving {total} images...\n");
    let meta = ModelMeta::default();
    let prior = AccuracyPrior::new();
    let mut rng = Rng::new(99);
    let t_start = Instant::now();

    // all requests arrive in one burst (worst case for the router)
    let (in_shape, _) = meta.seg_io_shapes(0, 1);
    let mut requests: Vec<LiveRequest> = (0..total)
        .map(|_| {
            let mut x = HostTensor::zeros(&in_shape);
            for v in &mut x.data {
                *v = rng.normal() as f32 * 0.5;
            }
            LiveRequest {
                arrival: t_start,
                seg: 0,
                activation: x,
                widths_used: [0.0; NUM_SEGMENTS],
            }
        })
        .collect();

    let mut ready: Vec<usize> = (0..total).collect(); // request ids awaiting routing
    let mut busy = vec![false; n_workers];
    let mut inflight: std::collections::HashMap<u64, (Vec<usize>, usize, f64)> =
        std::collections::HashMap::new();
    let mut queues: Vec<std::collections::VecDeque<(u64, Work, Vec<usize>)>> =
        (0..n_workers).map(|_| Default::default()).collect();
    let mut next_block = 0u64;
    let mut completed = 0usize;
    let mut e2e = Summary::default();
    let mut exec_latency = Summary::default();
    let mut width_count = [0u64; 4];
    let mut per_worker_blocks = vec![0u64; n_workers];
    let mut acc_sum = 0.0;

    let widx = |w: f64| -> usize {
        [0.25, 0.5, 0.75, 1.0].iter().position(|&x| (x - w).abs() < 1e-9).unwrap_or(3)
    };

    while completed < total {
        // route everything ready
        while !ready.is_empty() {
            let snap = TelemetrySnapshot {
                fifo_len: ready.len(),
                done_count: completed as u64,
                total_requests: total,
                servers: (0..n_workers)
                    .map(|i| ServerTelemetry {
                        queue_len: queues[i].len() + busy[i] as usize,
                        power_w: 60.0 + 200.0 * (busy[i] as u8 as f64),
                        util_pct: if busy[i] { 80.0 } else { 5.0 },
                        mem_util: 0.2,
                        instances: 4,
                    })
                    .collect(),
            };
            let head = ready[0];
            let seg = requests[head].seg;
            let view = slim_scheduler::coordinator::HeadView::new(0.5, seg);
            let d = router.route_one(&snap, &view, &mut rng);
            // collect up to `group` ready requests at the same segment
            let mut members = Vec::new();
            let mut rest = Vec::new();
            for id in ready.drain(..) {
                if members.len() < d.group.max(1) && requests[id].seg == seg {
                    members.push(id);
                } else {
                    rest.push(id);
                }
            }
            ready = rest;
            let tensors: Vec<&HostTensor> =
                members.iter().map(|&id| &requests[id].activation).collect();
            let work = Work {
                block_id: next_block,
                seg,
                width: d.width,
                batch: stack(&tensors),
            };
            queues[d.server.min(n_workers - 1)].push_back((next_block, work, members));
            next_block += 1;
        }

        // dispatch to idle workers
        for w in 0..n_workers {
            if !busy[w] {
                if let Some((block_id, work, members)) = queues[w].pop_front() {
                    inflight.insert(block_id, (members, work.seg, work.width));
                    work_txs[w].send(work).expect("worker alive");
                    busy[w] = true;
                    per_worker_blocks[w] += 1;
                }
            }
        }

        // wait for a completion
        let Ok(done) = done_rx.recv() else { break };
        busy[done.worker] = false;
        exec_latency.record(done.exec_ms);
        let (members, seg, width) = inflight.remove(&done.block_id).expect("known block");
        width_count[widx(width)] += members.len() as u64;
        let outputs = unstack(&done.output);
        for (&id, out) in members.iter().zip(outputs) {
            requests[id].widths_used[seg] = width;
            requests[id].seg = seg + 1;
            if seg + 1 < NUM_SEGMENTS {
                requests[id].activation = out;
                ready.push(id);
            } else {
                completed += 1;
                acc_sum += prior.lookup(&requests[id].widths_used);
                e2e.record(requests[id].arrival.elapsed().as_secs_f64() * 1e3);
            }
        }
    }

    let wall = t_start.elapsed().as_secs_f64();
    drop(work_txs);
    for h in handles {
        h.join().expect("worker join").ok();
    }

    println!("=== serve_cluster results (real PJRT CPU inference) ===");
    println!("images completed:        {completed} / {total}");
    println!("wall time:               {wall:.2} s");
    println!("throughput:              {:.1} img/s", completed as f64 / wall);
    println!("e2e latency:             mean {:.1} ms  p50 {:.1}  p99 {:.1}",
             e2e.mean(), e2e.percentile(50.0), e2e.percentile(99.0));
    println!("segment exec latency:    mean {:.2} ms  p99 {:.2} ms",
             exec_latency.mean(), exec_latency.percentile(99.0));
    println!("served width mix:        {width_count:?} (0.25/0.50/0.75/1.00)");
    println!("per-worker blocks:       {per_worker_blocks:?}");
    println!("mean accuracy prior:     {:.2}%", acc_sum / completed as f64);
    assert_eq!(completed, total, "all requests must complete");
    Ok(())
}
