//! Width-tuple trade-off sweep on the REAL inference path.
//!
//! Regenerates the accuracy-vs-latency trade-off surface that motivates
//! the paper (§I): for a set of width tuples, measures wall-clock CPU
//! latency of the AOT-compiled SlimResNet and pairs it with the accuracy
//! prior. Also validates Table I/II orderings on real compute cost.
//!
//!   cargo run --release --example width_sweep

use slim_scheduler::benchx::Table;
use slim_scheduler::model::{AccuracyPrior, ModelMeta, NUM_SEGMENTS, WIDTHS};
use slim_scheduler::runtime::{HostTensor, SegmentExecutor};
use slim_scheduler::utilx::Rng;

fn main() -> anyhow::Result<()> {
    let meta = ModelMeta::default();
    let prior = AccuracyPrior::new();
    let mut ex = SegmentExecutor::new("artifacts")?;

    let batch = 16;
    let (in_shape, _) = meta.seg_io_shapes(0, batch);
    let mut rng = Rng::new(11);
    let mut image = HostTensor::zeros(&in_shape);
    for v in &mut image.data {
        *v = rng.normal() as f32 * 0.5;
    }

    // uniform tuples + the paper's Table II tuples + a few extremes
    let mut tuples: Vec<[f64; NUM_SEGMENTS]> =
        WIDTHS.iter().map(|&w| [w; NUM_SEGMENTS]).collect();
    tuples.extend(
        slim_scheduler::model::accuracy::MIXED_ACC
            .iter()
            .map(|&(t, _)| t),
    );
    tuples.push([0.25, 0.25, 0.25, 1.00]);
    tuples.push([1.00, 0.25, 0.25, 0.25]);

    let mut table = Table::new(
        "Accuracy/latency trade-off surface (real PJRT CPU path, batch 16)",
        &["w1", "w2", "w3", "w4", "prior_top1", "latency_ms", "sem_gflops"],
    );

    // warm the pool so timing excludes compilation
    ex.warm_all(&WIDTHS)?;

    for tuple in &tuples {
        // median of 3 runs
        let mut times = Vec::new();
        for _ in 0..3 {
            let t0 = std::time::Instant::now();
            let _ = ex.full_forward(tuple, &image)?;
            times.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let flops: u64 = (0..NUM_SEGMENTS)
            .map(|s| {
                let wp = if s == 0 { 1.0 } else { tuple[s - 1] };
                meta.seg_flops(s, tuple[s], wp, batch)
            })
            .sum();
        table.rowf(
            &[
                tuple[0],
                tuple[1],
                tuple[2],
                tuple[3],
                prior.lookup(tuple),
                times[1],
                flops as f64 / 1e9,
            ],
            3,
        );
    }
    table.print();
    println!(
        "\nNote: CPU latency tracks the semantic-FLOP column loosely (the\n\
         full-interface convention recomputes padded input channels; the\n\
         simulator charges the semantic cost — DESIGN.md §2)."
    );
    Ok(())
}
