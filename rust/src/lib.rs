//! # Slim Scheduler
//!
//! A reproduction of *"Slim Scheduler: A Runtime-Aware RL and Scheduler
//! System for Efficient CNN Inference"* as a three-layer rust + JAX +
//! Pallas stack (AOT via PJRT). Python authors and lowers the slimmable
//! SlimResNet once (`make artifacts`); this crate is the entire serving
//! system: the paper's greedy per-server scheduler (Algorithm 1), the PPO
//! router (eq. 1–13), the heterogeneous GPU cluster simulator that stands
//! in for the paper's 3-GPU testbed, and the PJRT runtime that executes
//! the real compiled segments on CPU.
//!
//! Module map (see DESIGN.md for the full inventory):
//!
//! * [`utilx`] — dependency-free substrates: PCG RNG, JSON, CLI, property
//!   testing (the offline crate cache has no rand/serde/clap/proptest).
//! * [`config`] — typed configuration: cluster topology, scheduler knobs,
//!   PPO hyper-parameters, workload spec.
//! * [`metrics`] — streaming histograms / run summaries used by every
//!   table and figure.
//! * [`model`] — SlimResNet metadata: shapes, FLOP/VRAM cost model,
//!   width-tuple accuracy prior (paper Tables I–II).
//! * [`sim`] — virtual clock, GPU device model (Figs 1–3 dynamics),
//!   WLAN link, workload generators, device profiles.
//! * [`coordinator`] — keyed FIFO, greedy scheduler, routers
//!   (Random/RoundRobin/LeastLoaded/PPO), telemetry, multi-server engine.
//! * [`ppo`] — from-scratch MLP/Adam/factored-categorical PPO.
//! * [`runtime`] — PJRT artifact loading and execution (the real
//!   inference path; zero python at serve time).
//! * [`trace`] — trace record/replay + counterfactual router A/B:
//!   byte-deterministic JSONL lifecycle traces, fixed-arrival replay,
//!   paired per-request delta reports.
//! * [`obs`] — deterministic observability: metrics registry,
//!   request-lifecycle stage timing, bounded per-tick series, and the
//!   `--metrics-out` / `repro report` bundle formats.
//! * [`ctrl`] — adaptive control plane: the tunable-knob subset of the
//!   config, pure zero-RNG feedback controllers over the obs tick
//!   stream, and the clamp that bounds whatever a controller returns.
//! * [`benchx`] — mini statistical bench harness (criterion substitute).

pub mod benchx;
pub mod config;
pub mod ctrl;
pub mod experiments;
pub mod coordinator;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod ppo;
pub mod runtime;
pub mod sim;
pub mod trace;
pub mod utilx;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
