//! Scenario registry: named cluster/workload configurations beyond the
//! paper's fixed 3-GPU testbed.
//!
//! The paper's central claim is that hierarchical PPO-plus-greedy
//! "mitigates overfitting to specific hardware" — which is only testable
//! against hardware and traffic the policy was *not* tuned on. Every
//! entry here is a complete, runnable configuration: heterogeneous
//! device mixes, bursty and diurnal arrival regimes, and mid-run device
//! dropout. They are selectable from the CLI (`--scenario <name>`,
//! `repro scenarios` to list), from the benches (`BENCH_SCENARIO=<name>`
//! via `experiments::bench_cfg`), and programmatically via
//! [`by_name`] / [`apply_named`], so Tables III–V can be regenerated per
//! scenario.
//!
//! A scenario is a function from the default [`Config`] to a modified
//! one; explicit CLI flags are applied afterwards and therefore override
//! the scenario's baseline.

use crate::config::{AdmissionKind, Config, DropoutCfg};

/// One registered scenario.
pub struct Scenario {
    pub name: &'static str,
    pub summary: &'static str,
    build: fn(&mut Config),
}

impl Scenario {
    /// Overlay this scenario onto `cfg` (records provenance).
    pub fn apply(&self, cfg: &mut Config) {
        (self.build)(cfg);
        cfg.scenario = Some(self.name.to_string());
    }

    /// A fresh default config with this scenario applied.
    pub fn config(&self) -> Config {
        let mut cfg = Config::default();
        self.apply(&mut cfg);
        cfg
    }
}

fn build_paper(_cfg: &mut Config) {
    // the default Config IS the paper testbed (2× 2080 Ti + 980 Ti,
    // bursty 140 req/s) — registered so "the paper setting" is a named,
    // provenance-tracked scenario like any other
}

fn build_hetero_mixed(cfg: &mut Config) {
    // four-way heterogeneous cluster spanning a ~4.5× capability range;
    // more aggregate capacity than the paper cluster, so a higher rate
    // keeps the saturation regime comparable
    cfg.devices = vec![
        "rtx2080ti".to_string(),
        "rtx3060".to_string(),
        "gtx980ti".to_string(),
        "gtx1650".to_string(),
    ];
    cfg.workload.rate_hz = 170.0;
}

fn build_edge_fleet(cfg: &mut Config) {
    // homogeneous fleet of weak edge nodes: per-device VRAM budget cut to
    // fit the 4 GB cards, offered load scaled to their capacity
    cfg.devices = vec!["gtx1650".to_string(); 4];
    cfg.scheduler.m_max_bytes = 3 * (1 << 30);
    cfg.workload.rate_hz = 55.0;
    cfg.workload.burst_factor = 2.0;
}

fn build_bursty_extreme(cfg: &mut Config) {
    // short, violent bursts: 8× rate for 15% of every 4 s window — the
    // regime where responsive scale-up (Q_th / N_new) earns its keep
    cfg.workload.rate_hz = 110.0;
    cfg.workload.burst_factor = 8.0;
    cfg.workload.burst_period_s = 4.0;
    cfg.workload.burst_duty = 0.15;
}

fn build_diurnal(cfg: &mut Config) {
    // sinusoidal day/night cycle (±80% around the mean, 40 s virtual
    // period) with the square-wave bursts disabled so the diurnal shape
    // is the only modulation
    cfg.workload.rate_hz = 130.0;
    cfg.workload.burst_factor = 1.0;
    cfg.workload.burst_period_s = 0.0;
    cfg.workload.diurnal_period_s = 40.0;
    cfg.workload.diurnal_depth = 0.8;
}

fn build_sharded_hot(cfg: &mut Config) {
    // the multi-leader stress case: a large homogeneous cluster whose
    // device capacity comfortably exceeds the offered load, routed
    // through a finite-capacity leader tier (1.5 ms of routing work per
    // head ≈ 667 heads/s/leader vs ~1280 heads/s offered) — a single
    // leader is the bottleneck, four are not. Arrival keys are skewed
    // slim-heavy so same-segment runs are long and hash-sharded depths
    // wander apart, which is what the rebalancer (enabled here) acts on.
    cfg.devices = vec!["rtx2080ti".to_string(); 6];
    cfg.workload.rate_hz = 320.0;
    cfg.workload.burst_factor = 2.0;
    cfg.workload.burst_period_s = 5.0;
    cfg.workload.burst_duty = 0.2;
    cfg.workload.width_mix = vec![0.25, 0.25, 0.25, 0.5];
    cfg.router.route_window = 8;
    cfg.shard.leader_service_s = 0.0015;
    cfg.shard.rebalance_threshold = 16;
    // leaders stay at the config default (1): the scenario models the
    // leader bottleneck; --leaders / BENCH_LEADERS choose the shard count
}

fn build_flash_crowd(cfg: &mut Config) {
    // multi-tenant overload: six Zipf-popular tenants on the paper
    // cluster at a calm 60 req/s, until the hottest tenant (≈46% share)
    // spikes 10× for t ∈ [2, 4) s — offered load ≈ 311 req/s, well past
    // cluster capacity. The DRR gate (on by default here) keeps the
    // cold tenants' latency at baseline: the hot tenant's deliberately
    // small pending queue sheds the excess, and backlog past
    // degrade_depth is served at the slimmest width instead of queueing
    // the cluster to death. `--admission none` shows the counterfactual
    // (one shared FIFO, everyone queues behind the crowd).
    cfg.workload.rate_hz = 60.0;
    cfg.workload.burst_factor = 1.0;
    cfg.workload.burst_period_s = 0.0;
    cfg.workload.tenants = 6;
    cfg.workload.tenant_zipf = 1.2;
    cfg.workload.flash_factor = 10.0;
    cfg.workload.flash_start_s = 2.0;
    cfg.workload.flash_end_s = 4.0;
    cfg.admission.kind = AdmissionKind::Drr;
    cfg.admission.quantum = 0.5;
    cfg.admission.burst_cap = 8.0;
    cfg.admission.queue_cap = 16;
    cfg.admission.degrade_depth = 8;
}

fn build_dropout(cfg: &mut Config) {
    // one of the fast servers dies 8 virtual seconds in; the survivors
    // (1× 2080 Ti + 980 Ti) must absorb the re-routed queue. Offered
    // load sized so the degraded cluster still drains.
    cfg.workload.rate_hz = 90.0;
    cfg.dropout = Some(DropoutCfg { server: 0, at_s: 8.0 });
}

static SCENARIOS: &[Scenario] = &[
    Scenario {
        name: "paper",
        summary: "the paper's 3-GPU testbed and bursty 140 req/s workload (the default)",
        build: build_paper,
    },
    Scenario {
        name: "hetero-mixed",
        summary: "4-way heterogeneous cluster (2080Ti/3060/980Ti/1650), 170 req/s",
        build: build_hetero_mixed,
    },
    Scenario {
        name: "edge-fleet",
        summary: "4x GTX 1650 edge nodes, 3 GiB VRAM budget, 55 req/s",
        build: build_edge_fleet,
    },
    Scenario {
        name: "bursty-extreme",
        summary: "8x arrival bursts, 15% duty over 4 s windows",
        build: build_bursty_extreme,
    },
    Scenario {
        name: "diurnal",
        summary: "sinusoidal day/night load, +/-80% around 130 req/s",
        build: build_diurnal,
    },
    Scenario {
        name: "dropout",
        summary: "paper cluster; server 0 (a 2080 Ti) dies at t=8s",
        build: build_dropout,
    },
    Scenario {
        name: "sharded-hot",
        summary: "6x 2080Ti, 320 req/s slim-skewed; finite-capacity leaders (--leaders)",
        build: build_sharded_hot,
    },
    Scenario {
        name: "flash-crowd",
        summary: "6 Zipf tenants; the hottest spikes 10x for t in [2,4)s; DRR admission",
        build: build_flash_crowd,
    },
];

/// Every registered scenario.
pub fn all() -> &'static [Scenario] {
    SCENARIOS
}

/// Registered scenario names, registry order.
pub fn names() -> Vec<&'static str> {
    SCENARIOS.iter().map(|s| s.name).collect()
}

/// Look a scenario up by name.
pub fn by_name(name: &str) -> Option<&'static Scenario> {
    SCENARIOS.iter().find(|s| s.name == name)
}

/// Overlay the named scenario onto `cfg`; Err lists valid names.
pub fn apply_named(name: &str, cfg: &mut Config) -> Result<(), String> {
    match by_name(name) {
        Some(s) => {
            s.apply(cfg);
            Ok(())
        }
        None => Err(format!(
            "unknown scenario {name:?} (known: {})",
            names().join(", ")
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AdmissionCfg;
    use crate::coordinator::router::RandomRouter;
    use crate::coordinator::Engine;
    use crate::sim::profiles;

    #[test]
    fn registry_has_paper_plus_at_least_three_more() {
        assert!(by_name("paper").is_some());
        let non_paper = all().iter().filter(|s| s.name != "paper").count();
        assert!(non_paper >= 3, "only {non_paper} non-paper scenarios");
    }

    #[test]
    fn names_are_unique_and_resolve() {
        let ns = names();
        let mut dedup = ns.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), ns.len(), "duplicate scenario names");
        for n in ns {
            assert!(by_name(n).is_some());
        }
    }

    #[test]
    fn every_scenario_builds_a_valid_config() {
        for s in all() {
            let cfg = s.config();
            assert_eq!(cfg.scenario.as_deref(), Some(s.name), "{}", s.name);
            assert!(!cfg.devices.is_empty(), "{}", s.name);
            for d in &cfg.devices {
                assert!(
                    profiles::by_name(d).is_some(),
                    "{}: unresolvable device {d}",
                    s.name
                );
            }
            assert!(cfg.workload.rate_hz > 0.0, "{}", s.name);
            assert!(cfg.workload.total_requests > 0, "{}", s.name);
            if let Some(dp) = cfg.dropout {
                assert!(dp.server < cfg.devices.len(), "{}", s.name);
                assert!(dp.at_s >= 0.0, "{}", s.name);
            }
        }
    }

    #[test]
    fn every_scenario_runs_a_short_workload_to_completion() {
        // end-to-end: each scenario's cluster drains a small request
        // budget without hanging against max_sim_time_s
        for s in all() {
            let mut cfg = s.config();
            cfg.workload.total_requests = 200;
            let widths = cfg.scheduler.widths.clone();
            let engine = Engine::new(cfg, RandomRouter::new(widths, true, 4));
            let max_t = engine.max_sim_time_s;
            let out = engine.run();
            // admission-gated scenarios may shed under backpressure;
            // every arrival is still accounted for
            assert_eq!(
                out.report.completed + out.shed,
                200,
                "{} did not drain (completed {}, shed {})",
                s.name,
                out.report.completed,
                out.shed
            );
            assert_eq!(
                out.e2e_latency.count(),
                out.report.completed as usize,
                "{}",
                s.name
            );
            assert!(out.report.completed > 0, "{} completed nothing", s.name);
            assert!(
                out.sim_duration_s < max_t,
                "{} ran into the safety cap",
                s.name
            );
            assert!(out.total_energy_j > 0.0, "{}", s.name);
        }
    }

    #[test]
    fn apply_named_reports_unknown_names() {
        let mut cfg = Config::default();
        let err = apply_named("marsbase", &mut cfg).unwrap_err();
        assert!(err.contains("unknown scenario"), "{err}");
        assert!(err.contains("paper"), "{err}");
        assert!(cfg.scenario.is_none());
    }

    #[test]
    fn scenarios_change_what_they_claim() {
        assert_eq!(by_name("hetero-mixed").unwrap().config().devices.len(), 4);
        assert!(by_name("dropout").unwrap().config().dropout.is_some());
        assert!(by_name("diurnal").unwrap().config().workload.diurnal_period_s > 0.0);
        let bursty = by_name("bursty-extreme").unwrap().config();
        assert!(bursty.workload.burst_factor >= 8.0);
        let edge = by_name("edge-fleet").unwrap().config();
        assert!(edge.devices.iter().all(|d| d == "gtx1650"));
        let hot = by_name("sharded-hot").unwrap().config();
        assert_eq!(hot.devices.len(), 6);
        assert!(hot.shard.leader_service_s > 0.0);
        assert!(hot.shard.rebalance_threshold > 0);
        assert!(hot.router.route_window > 1);
        assert_eq!(hot.shard.leaders, 1); // shard count is the caller's knob
        let flash = by_name("flash-crowd").unwrap().config();
        assert_eq!(flash.workload.tenants, 6);
        assert!(flash.workload.flash_factor > 1.0);
        assert!(flash.workload.flash_end_s > flash.workload.flash_start_s);
        assert_eq!(flash.admission.kind, AdmissionKind::Drr);
        assert!(flash.admission.queue_cap < AdmissionCfg::default().queue_cap);
        assert!(flash.admission.degrade_depth > 0);
        // paper scenario is the default config plus provenance
        let mut want = Config::default();
        want.scenario = Some("paper".to_string());
        assert_eq!(by_name("paper").unwrap().config(), want);
    }
}
