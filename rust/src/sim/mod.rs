//! Heterogeneous GPU-cluster simulator.
//!
//! Stands in for the paper's physical testbed (2× RTX 2080 Ti + 1× GTX
//! 980 Ti over Wi-Fi 5). The device model implements the dynamics the
//! paper measures in Figs 1–3 — utilization grows with batch·width,
//! latency and energy are near-linear in utilization until a ~90–95 %
//! knee and sharply super-linear beyond it — so cluster-level experiments
//! (Tables III–V) exercise the same feedback loop the PPO router learned
//! on real hardware. See DESIGN.md §Hardware-Adaptation for the
//! substitution argument.

pub mod clock;
pub mod device;
pub mod link;
pub mod profiles;
pub mod scenarios;
pub mod workload;

pub use clock::VirtualClock;
pub use device::SimDevice;
pub use link::Link;
pub use scenarios::Scenario;
pub use workload::{Workload, WorkloadEvent};
