//! Inter-server link model (the paper's Wi-Fi 5 WLAN).
//!
//! When consecutive segments of one request execute on different servers,
//! the full-size interface activation crosses the network; the transfer
//! delay (base latency + Gaussian jitter + bytes/bandwidth) is charged to
//! the request's end-to-end latency — this is the cost that makes naive
//! random routing expensive and gives the PPO router locality signal.

use crate::config::LinkCfg;
use crate::utilx::Rng;

/// Simulated WLAN link.
#[derive(Clone, Debug)]
pub struct Link {
    cfg: LinkCfg,
}

impl Link {
    pub fn new(cfg: LinkCfg) -> Self {
        Link { cfg }
    }

    /// Transfer delay for `bytes` between two distinct servers.
    pub fn transfer_s(&self, bytes: u64, rng: &mut Rng) -> f64 {
        let jitter = (rng.normal() * self.cfg.jitter_s).max(-self.cfg.base_latency_s * 0.9);
        self.cfg.base_latency_s + jitter + bytes as f64 / self.cfg.bandwidth_bytes_per_s
    }

    /// Delay for a same-server hop (device-local handoff): zero network.
    pub fn local_s(&self) -> f64 {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn link() -> Link {
        Link::new(LinkCfg::default())
    }

    #[test]
    fn transfer_positive_and_grows_with_bytes() {
        let l = link();
        let mut rng = Rng::new(1);
        let small: f64 = (0..100).map(|_| l.transfer_s(1_000, &mut rng)).sum::<f64>() / 100.0;
        let big: f64 =
            (0..100).map(|_| l.transfer_s(10_000_000, &mut rng)).sum::<f64>() / 100.0;
        assert!(small > 0.0);
        assert!(big > small + 0.1); // 10 MB over 50 MB/s ≈ 0.2 s
    }

    #[test]
    fn local_hop_is_free() {
        assert_eq!(link().local_s(), 0.0);
    }

    #[test]
    fn jitter_varies_but_never_negative_delay() {
        let l = link();
        let mut rng = Rng::new(2);
        let xs: Vec<f64> = (0..200).map(|_| l.transfer_s(0, &mut rng)).collect();
        assert!(xs.iter().all(|&x| x > 0.0));
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let spread = xs.iter().map(|x| (x - mean).abs()).sum::<f64>() / xs.len() as f64;
        assert!(spread > 0.0);
    }
}
