//! Analytical GPU device model.
//!
//! Implements the empirical dynamics the paper measures on real hardware:
//!
//! * **Fig 1** — memory/compute utilization grows with batch size, and
//!   wider configurations saturate earlier (occupancy ∝ batch × width).
//! * **Figs 2–3** — latency and energy are near-linear in utilization up
//!   to a ~90–95 % knee, then sharply super-linear (queueing delays and
//!   context-switch overheads dominate).
//!
//! A batch executes for `roofline_base × congestion(U)` seconds where the
//! roofline base is `flops/peak + bytes/bw + dispatch_overhead` and the
//! congestion multiplier blows up past the knee. Power is affine in
//! utilization between idle and max draw; energy is integrated exactly
//! between utilization change-points.

use crate::config::DeviceCfg;

/// One in-flight batch on the device.
#[derive(Clone, Debug)]
struct Running {
    occupancy: f64,
    finish: f64,
    id: u64,
}

/// Simulated GPU.
#[derive(Clone, Debug)]
pub struct SimDevice {
    pub cfg: DeviceCfg,
    vram_used: u64,
    running: Vec<Running>,
    energy_j: f64,
    last_integration_t: f64,
    next_batch_id: u64,
    pub completed_batches: u64,
}

impl SimDevice {
    pub fn new(cfg: DeviceCfg) -> Self {
        SimDevice {
            cfg,
            vram_used: 0,
            running: Vec::new(),
            energy_j: 0.0,
            last_integration_t: 0.0,
            next_batch_id: 0,
            completed_batches: 0,
        }
    }

    // ------------------------------------------------------------------
    // VRAM ledger
    // ------------------------------------------------------------------

    /// Reserve VRAM; false if it would exceed physical capacity.
    pub fn try_alloc_vram(&mut self, bytes: u64) -> bool {
        if self.vram_used + bytes > self.cfg.vram_bytes {
            return false;
        }
        self.vram_used += bytes;
        true
    }

    pub fn free_vram(&mut self, bytes: u64) {
        debug_assert!(bytes <= self.vram_used);
        self.vram_used = self.vram_used.saturating_sub(bytes);
    }

    pub fn vram_used(&self) -> u64 {
        self.vram_used
    }

    /// Memory utilization fraction in [0,1] (Fig 1's y-axis).
    pub fn mem_util(&self) -> f64 {
        self.vram_used as f64 / self.cfg.vram_bytes as f64
    }

    // ------------------------------------------------------------------
    // Compute utilization & occupancy
    // ------------------------------------------------------------------

    /// Occupancy one batch of (batch, width) contributes: batches fill the
    /// device proportionally to active channels × batch size, saturating
    /// at 1. The reference batch count scales with device capability so
    /// the 980 Ti saturates ~2.4× earlier than the 2080 Ti (Fig 1 shape).
    pub fn occupancy(&self, batch: usize, width: f64) -> f64 {
        let b_ref = self.cfg.peak_flops / 2.0e8; // 2080Ti≈23.5, 980Ti≈9.9
        ((batch as f64 * width) / b_ref).min(1.0)
    }

    /// Current compute utilization in percent (Figs 2–3 x-axis; eq. 1's
    /// U^(i) telemetry).
    pub fn util_pct(&self) -> f64 {
        let total: f64 = self.running.iter().map(|r| r.occupancy).sum();
        100.0 * total.min(1.0)
    }

    /// Instantaneous power draw (W): affine in utilization.
    pub fn power_w(&self) -> f64 {
        let u = self.util_pct() / 100.0;
        self.cfg.idle_power_w + (self.cfg.max_power_w - self.cfg.idle_power_w) * u
    }

    /// Congestion multiplier m(U): near-linear before the knee, sharply
    /// super-linear after it (the Figs 2–3 inflection).
    pub fn congestion(&self, util_pct: f64) -> f64 {
        let u = (util_pct / 100.0).clamp(0.0, 1.0);
        let knee = self.cfg.knee_util_pct / 100.0;
        let linear = 1.0 + 0.6 * u;
        let excess = (u - knee).max(0.0);
        let blowup =
            self.cfg.knee_sharpness * excess * excess / (1.02 - u).max(0.02);
        linear + blowup
    }

    /// Uncongested roofline execution time for (flops, bytes).
    pub fn base_exec_time(&self, flops: u64, mem_bytes: u64) -> f64 {
        flops as f64 / self.cfg.peak_flops
            + mem_bytes as f64 / self.cfg.mem_bw
            + self.cfg.dispatch_overhead_s
    }

    // ------------------------------------------------------------------
    // Energy integration
    // ------------------------------------------------------------------

    /// Integrate energy up to `now` at the current utilization.
    pub fn integrate_to(&mut self, now: f64) {
        let dt = now - self.last_integration_t;
        if dt > 0.0 {
            self.energy_j += self.power_w() * dt;
            self.last_integration_t = now;
        }
    }

    /// Total joules consumed so far (including idle draw).
    pub fn energy_j(&self) -> f64 {
        self.energy_j
    }

    // ------------------------------------------------------------------
    // Batch lifecycle
    // ------------------------------------------------------------------

    /// Start a batch at `now`; returns (batch_id, finish_time).
    ///
    /// The latency is the roofline base scaled by congestion at the
    /// utilization *including* this batch — operating near saturation is
    /// disproportionately slow, which is the feedback loop the PPO router
    /// learns to avoid.
    pub fn begin_batch(
        &mut self,
        now: f64,
        flops: u64,
        mem_bytes: u64,
        batch: usize,
        width: f64,
    ) -> (u64, f64) {
        self.integrate_to(now);
        let occ = self.occupancy(batch, width);
        let util_after =
            (self.util_pct() / 100.0 + occ).min(1.0) * 100.0;
        let t = self.base_exec_time(flops, mem_bytes) * self.congestion(util_after);
        let id = self.next_batch_id;
        self.next_batch_id += 1;
        self.running.push(Running { occupancy: occ, finish: now + t, id });
        (id, now + t)
    }

    /// Complete a batch by id at `now`.
    pub fn finish_batch(&mut self, now: f64, id: u64) {
        self.integrate_to(now);
        if let Some(pos) = self.running.iter().position(|r| r.id == id) {
            self.running.swap_remove(pos);
            self.completed_batches += 1;
        } else {
            debug_assert!(false, "finish_batch: unknown id {id}");
        }
    }

    /// Number of in-flight batches.
    pub fn inflight(&self) -> usize {
        self.running.len()
    }

    /// Earliest scheduled finish time among in-flight batches.
    pub fn next_finish(&self) -> Option<f64> {
        self.running
            .iter()
            .map(|r| r.finish)
            .min_by(|a, b| a.partial_cmp(b).unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::profiles;

    fn dev() -> SimDevice {
        SimDevice::new(profiles::rtx2080ti())
    }

    #[test]
    fn vram_ledger_enforces_capacity() {
        let mut d = SimDevice::new(profiles::toy_gpu());
        let cap = d.cfg.vram_bytes;
        assert!(d.try_alloc_vram(cap / 2));
        assert!(d.try_alloc_vram(cap / 2));
        assert!(!d.try_alloc_vram(1));
        d.free_vram(cap / 2);
        assert!(d.try_alloc_vram(cap / 4));
        assert!((d.mem_util() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn occupancy_monotone_in_batch_and_width_fig1() {
        let d = dev();
        // monotone in batch
        let us: Vec<f64> = [1, 2, 4, 8, 16, 32, 64]
            .iter()
            .map(|&b| d.occupancy(b, 1.0))
            .collect();
        assert!(us.windows(2).all(|w| w[1] >= w[0]), "{us:?}");
        // wider saturates earlier: find smallest batch hitting 1.0
        let sat_batch = |width: f64| {
            (1..=512).find(|&b| d.occupancy(b, width) >= 1.0).unwrap()
        };
        assert!(sat_batch(1.0) < sat_batch(0.5));
        assert!(sat_batch(0.5) < sat_batch(0.25));
    }

    #[test]
    fn heterogeneous_saturation() {
        let fast = dev();
        let slow = SimDevice::new(profiles::gtx980ti());
        assert!(slow.occupancy(8, 1.0) > fast.occupancy(8, 1.0));
    }

    #[test]
    fn congestion_linear_then_blows_up_fig23() {
        let d = dev();
        // near-linear region: second differences tiny
        let c50 = d.congestion(50.0);
        let c60 = d.congestion(60.0);
        let c70 = d.congestion(70.0);
        assert!(((c70 - c60) - (c60 - c50)).abs() < 1e-9);
        // post-knee blow-up: slope explodes
        let c92 = d.congestion(92.0);
        let c96 = d.congestion(96.0);
        let c100 = d.congestion(100.0);
        assert!(c96 - c92 > 2.0 * (c70 - c50), "{c92} {c96}");
        assert!(c100 > 2.0 * c92, "{c92} {c100}");
        // monotone overall
        let mut prev = 0.0;
        for u in 0..=100 {
            let c = d.congestion(u as f64);
            assert!(c >= prev);
            prev = c;
        }
    }

    #[test]
    fn base_exec_time_roofline_terms() {
        let d = dev();
        let t_small = d.base_exec_time(1_000_000, 1_000_000);
        let t_flops = d.base_exec_time(1_000_000_000_000, 1_000_000);
        let t_mem = d.base_exec_time(1_000_000, 100_000_000_000);
        assert!(t_flops > t_small);
        assert!(t_mem > t_small);
        assert!(t_small >= d.cfg.dispatch_overhead_s);
    }

    #[test]
    fn batch_lifecycle_and_util() {
        let mut d = dev();
        assert_eq!(d.util_pct(), 0.0);
        let (id1, f1) = d.begin_batch(0.0, 1_000_000_000, 10_000_000, 8, 1.0);
        assert!(d.util_pct() > 0.0);
        assert!(f1 > 0.0);
        let (id2, _f2) = d.begin_batch(0.0, 1_000_000_000, 10_000_000, 8, 1.0);
        let u2 = d.util_pct();
        d.finish_batch(f1, id1);
        assert!(d.util_pct() < u2);
        d.finish_batch(f1, id2);
        assert_eq!(d.inflight(), 0);
        assert_eq!(d.completed_batches, 2);
    }

    #[test]
    fn latency_increases_under_load() {
        let mut empty = dev();
        let (_, f_alone) = empty.begin_batch(0.0, 5_000_000_000, 50_000_000, 8, 1.0);

        let mut busy = dev();
        // pre-load to ~88% utilization
        for _ in 0..5 {
            busy.begin_batch(0.0, 5_000_000_000, 50_000_000, 4, 1.0);
        }
        let (_, f_busy) = busy.begin_batch(0.0, 5_000_000_000, 50_000_000, 8, 1.0);
        assert!(f_busy > f_alone * 1.5, "{f_busy} vs {f_alone}");
    }

    #[test]
    fn energy_integrates_power_over_time() {
        let mut d = dev();
        // idle for 10 s
        d.integrate_to(10.0);
        let idle_e = d.energy_j();
        assert!((idle_e - d.cfg.idle_power_w * 10.0).abs() < 1e-6);
        // run a big batch; energy rate must exceed idle
        let (id, f) = d.begin_batch(10.0, 100_000_000_000, 1_000_000_000, 24, 1.0);
        d.finish_batch(f, id);
        let run_e = d.energy_j() - idle_e;
        assert!(run_e > d.cfg.idle_power_w * (f - 10.0));
        assert!(run_e <= d.cfg.max_power_w * (f - 10.0) + 1e-6);
    }

    #[test]
    fn next_finish_ordering() {
        let mut d = dev();
        assert!(d.next_finish().is_none());
        let (_, f1) = d.begin_batch(0.0, 1_000_000_000, 1_000_000, 2, 0.5);
        let (_, f2) = d.begin_batch(0.0, 50_000_000_000, 1_000_000, 2, 0.5);
        assert!(f2 > f1);
        assert_eq!(d.next_finish(), Some(f1));
    }
}
