//! Virtual time. The cluster engine is a discrete-event simulation; all
//! timestamps are f64 seconds since run start. A virtual clock makes the
//! Tables III–V experiments deterministic and ~10^4× faster than wall
//! time; the real-serving path (examples/serve_cluster.rs) swaps in wall
//! time from `std::time::Instant`.

/// Monotonic virtual clock (seconds).
#[derive(Clone, Debug, Default)]
pub struct VirtualClock {
    now: f64,
}

impl VirtualClock {
    pub fn new() -> Self {
        VirtualClock { now: 0.0 }
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    /// Advance to an absolute timestamp (monotonicity enforced).
    pub fn advance_to(&mut self, t: f64) {
        assert!(
            t >= self.now - 1e-12,
            "clock must be monotonic: {} -> {t}",
            self.now
        );
        self.now = self.now.max(t);
    }

    /// Advance by a delta.
    pub fn advance_by(&mut self, dt: f64) {
        assert!(dt >= 0.0);
        self.now += dt;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_at_zero_and_advances() {
        let mut c = VirtualClock::new();
        assert_eq!(c.now(), 0.0);
        c.advance_to(1.5);
        assert_eq!(c.now(), 1.5);
        c.advance_by(0.5);
        assert_eq!(c.now(), 2.0);
    }

    #[test]
    #[should_panic(expected = "monotonic")]
    fn rejects_time_travel() {
        let mut c = VirtualClock::new();
        c.advance_to(2.0);
        c.advance_to(1.0);
    }

    #[test]
    fn advance_to_same_time_is_fine() {
        let mut c = VirtualClock::new();
        c.advance_to(1.0);
        c.advance_to(1.0);
        assert_eq!(c.now(), 1.0);
    }
}
