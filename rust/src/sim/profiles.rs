//! Calibrated device profiles for the paper's testbed.
//!
//! Two calibration decisions (DESIGN.md §Hardware-Adaptation):
//!
//! 1. **Ratios from spec sheets** — the 2080 Ti : 980 Ti capability gap
//!    (~2.4× compute, ~1.8× bandwidth, VRAM sizes) comes from the real
//!    cards, so heterogeneity-driven effects (the 980 Ti saturating
//!    first, routing around it) are faithful.
//! 2. **Absolute scale from the paper's operating point, not silicon.**
//!    The paper's cluster served segment batches in the 0.1–1 s range
//!    (baseline mean latency ≈ 9 s under queueing, per-block energies of
//!    hundreds of J): eager per-segment PyTorch dispatch + WLAN hops, not
//!    raw TFLOPs. We therefore scale *effective* throughput down by 10³
//!    from the 35 %-of-peak figure so the simulated cluster reaches the
//!    same saturation regime at the paper's request rates. Every
//!    experiment (Figs 1–3, Tables III–V) depends on the ratio of offered
//!    load to capacity and on the knee location — both preserved — not on
//!    absolute TFLOPs.

use crate::config::DeviceCfg;

/// Effective-throughput derating vs. 35 %-of-peak silicon (see module docs).
const OPERATING_POINT_SCALE: f64 = 1.0e-3;

/// NVIDIA RTX 2080 Ti: 13.45 TFLOPS fp32 peak, 616 GB/s, 11 GB GDDR6.
pub fn rtx2080ti() -> DeviceCfg {
    DeviceCfg {
        name: "rtx2080ti".to_string(),
        peak_flops: 13.45e12 * 0.35 * OPERATING_POINT_SCALE,
        mem_bw: 616.0e9 * 0.7 * OPERATING_POINT_SCALE,
        vram_bytes: 11 * (1 << 30),
        idle_power_w: 57.0,
        max_power_w: 260.0,
        knee_util_pct: 92.0,
        knee_sharpness: 18.0,
        dispatch_overhead_s: 8e-3,
    }
}

/// NVIDIA GTX 980 Ti: 5.63 TFLOPS fp32 peak, 336 GB/s, 6 GB GDDR5.
pub fn gtx980ti() -> DeviceCfg {
    DeviceCfg {
        name: "gtx980ti".to_string(),
        peak_flops: 5.63e12 * 0.35 * OPERATING_POINT_SCALE,
        mem_bw: 336.0e9 * 0.7 * OPERATING_POINT_SCALE,
        vram_bytes: 6 * (1 << 30),
        idle_power_w: 52.0,
        max_power_w: 275.0,
        knee_util_pct: 90.0,
        knee_sharpness: 22.0,
        dispatch_overhead_s: 12e-3,
    }
}

/// NVIDIA RTX 3060: 12.74 TFLOPS fp32 peak, 360 GB/s, 12 GB GDDR6 —
/// a mid-range card for the non-paper heterogeneous scenarios.
pub fn rtx3060() -> DeviceCfg {
    DeviceCfg {
        name: "rtx3060".to_string(),
        peak_flops: 12.74e12 * 0.35 * OPERATING_POINT_SCALE,
        mem_bw: 360.0e9 * 0.7 * OPERATING_POINT_SCALE,
        vram_bytes: 12 * (1 << 30),
        idle_power_w: 32.0,
        max_power_w: 170.0,
        knee_util_pct: 92.0,
        knee_sharpness: 18.0,
        dispatch_overhead_s: 8e-3,
    }
}

/// NVIDIA GTX 1650: 2.98 TFLOPS fp32 peak, 128 GB/s, 4 GB GDDR5 —
/// the weak edge node of the `edge-fleet` scenario.
pub fn gtx1650() -> DeviceCfg {
    DeviceCfg {
        name: "gtx1650".to_string(),
        peak_flops: 2.98e12 * 0.35 * OPERATING_POINT_SCALE,
        mem_bw: 128.0e9 * 0.7 * OPERATING_POINT_SCALE,
        vram_bytes: 4 * (1 << 30),
        idle_power_w: 10.0,
        max_power_w: 75.0,
        knee_util_pct: 88.0,
        knee_sharpness: 20.0,
        dispatch_overhead_s: 10e-3,
    }
}

/// A deliberately tiny device for failure-injection tests (VRAM pressure,
/// early saturation).
pub fn toy_gpu() -> DeviceCfg {
    DeviceCfg {
        name: "toy".to_string(),
        peak_flops: 1.0e9,
        mem_bw: 2.0e9,
        vram_bytes: 64 << 20,
        idle_power_w: 5.0,
        max_power_w: 25.0,
        knee_util_pct: 85.0,
        knee_sharpness: 10.0,
        dispatch_overhead_s: 20e-3,
    }
}

/// Resolve a profile by name (the `Config::devices` strings).
pub fn by_name(name: &str) -> Option<DeviceCfg> {
    match name {
        "rtx2080ti" => Some(rtx2080ti()),
        "gtx980ti" => Some(gtx980ti()),
        "rtx3060" => Some(rtx3060()),
        "gtx1650" => Some(gtx1650()),
        "toy" => Some(toy_gpu()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn heterogeneity_ratios_match_spec_sheets() {
        let fast = rtx2080ti();
        let slow = gtx980ti();
        let flops_ratio = fast.peak_flops / slow.peak_flops;
        let bw_ratio = fast.mem_bw / slow.mem_bw;
        assert!((flops_ratio - 2.39).abs() < 0.05, "{flops_ratio}");
        assert!((bw_ratio - 1.83).abs() < 0.05, "{bw_ratio}");
        assert!(fast.vram_bytes > slow.vram_bytes);
    }

    #[test]
    fn by_name_resolves_paper_cluster() {
        assert!(by_name("rtx2080ti").is_some());
        assert!(by_name("gtx980ti").is_some());
        assert!(by_name("rtx3060").is_some());
        assert!(by_name("gtx1650").is_some());
        assert!(by_name("toy").is_some());
        assert!(by_name("h100").is_none());
    }

    #[test]
    fn scenario_profiles_preserve_capability_ordering() {
        // the same spec-sheet-ratio argument as the paper pair: relative
        // capability must order 1650 < 980ti < 3060 < 2080ti
        let order = [gtx1650(), gtx980ti(), rtx3060(), rtx2080ti()];
        for pair in order.windows(2) {
            assert!(
                pair[0].peak_flops < pair[1].peak_flops,
                "{} !< {}",
                pair[0].name,
                pair[1].name
            );
            assert!(pair[0].mem_bw < pair[1].mem_bw);
        }
    }

    #[test]
    fn knees_sit_in_the_papers_band() {
        for cfg in [rtx2080ti(), gtx980ti()] {
            assert!(
                (85.0..=95.0).contains(&cfg.knee_util_pct),
                "{} knee {}",
                cfg.name,
                cfg.knee_util_pct
            );
        }
    }
}
