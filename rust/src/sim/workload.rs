//! Request arrival generation.
//!
//! Poisson arrivals with an optional bursty square-wave modulation (the
//! paper evaluates "responsive scale-up under bursty load"): during the
//! burst window the instantaneous rate is `rate × burst_factor`. Each
//! arrival carries a requested width sampled from the configured mix
//! (uniform over W by default). The trace mode ([`Workload::with_trace`]
//! — the trace-workload source behind `crate::trace::replay`) replays a
//! fixed event list verbatim instead of drawing from the generator, so
//! any router/scenario re-runs against bit-identical arrivals.

use std::collections::VecDeque;

use crate::config::WorkloadCfg;
use crate::utilx::Rng;

/// One generated arrival.
#[derive(Clone, Debug, PartialEq)]
pub struct WorkloadEvent {
    pub at: f64,
    pub request_id: u64,
    pub w_req: f64,
}

/// Arrival generator (iterator-style: `next_event` until exhausted).
#[derive(Clone, Debug)]
pub struct Workload {
    cfg: WorkloadCfg,
    widths: Vec<f64>,
    rng: Rng,
    t: f64,
    issued: usize,
    /// Fixed arrival stream (trace replay): when set, events pop from
    /// here verbatim and the stochastic generator (and its RNG) is
    /// never consulted.
    trace: Option<VecDeque<WorkloadEvent>>,
}

impl Workload {
    pub fn new(cfg: WorkloadCfg, widths: &[f64], rng: Rng) -> Self {
        let width_pool = if cfg.width_mix.is_empty() {
            widths.to_vec()
        } else {
            cfg.width_mix.clone()
        };
        Workload { cfg, widths: width_pool, rng, t: 0.0, issued: 0, trace: None }
    }

    /// Switch this workload into trace mode: `next_event` replays
    /// `events` in order and ignores the generator entirely. The
    /// construction path (and its RNG split) stays identical to the
    /// generative mode, which is what keeps a replayed engine's RNG
    /// stream bit-identical to the recording run's.
    pub fn with_trace(mut self, events: Vec<WorkloadEvent>) -> Self {
        self.trace = Some(events.into());
        self
    }

    /// Instantaneous arrival rate at time t: base rate, optionally
    /// modulated by a diurnal sinusoid (`diurnal_*`) and a square-wave
    /// burst window (`burst_*`). The modulations compose (a bursty
    /// day/night cycle is `diurnal × burst`).
    pub fn rate_at(&self, t: f64) -> f64 {
        let mut rate = self.cfg.rate_hz;
        if self.cfg.diurnal_period_s > 0.0 && self.cfg.diurnal_depth > 0.0 {
            let phase = t / self.cfg.diurnal_period_s * std::f64::consts::TAU;
            rate *= 1.0 + self.cfg.diurnal_depth.min(0.99) * phase.sin();
            rate = rate.max(self.cfg.rate_hz * 1e-2);
        }
        if self.cfg.burst_period_s > 0.0 && self.cfg.burst_factor > 1.0 {
            let phase = (t / self.cfg.burst_period_s).fract();
            if phase < self.cfg.burst_duty {
                rate *= self.cfg.burst_factor;
            }
        }
        rate
    }

    /// Next arrival, or None once `total_requests` have been issued
    /// (trace mode: the next recorded event, until the trace drains).
    pub fn next_event(&mut self) -> Option<WorkloadEvent> {
        if let Some(trace) = &mut self.trace {
            let ev = trace.pop_front();
            if ev.is_some() {
                self.issued += 1;
            }
            return ev;
        }
        if self.issued >= self.cfg.total_requests {
            return None;
        }
        // thinning-free approach: step with the current window's rate
        let rate = self.rate_at(self.t).max(1e-9);
        self.t += self.rng.exponential(rate);
        let w_req = *self.rng.choice(&self.widths);
        let ev = WorkloadEvent {
            at: self.t,
            request_id: self.issued as u64,
            w_req,
        };
        self.issued += 1;
        Some(ev)
    }

    /// Drain the whole trace (for tests and trace export).
    pub fn collect_all(mut self) -> Vec<WorkloadEvent> {
        let mut out = Vec::with_capacity(self.cfg.total_requests);
        while let Some(ev) = self.next_event() {
            out.push(ev);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadCfg;

    fn base_cfg() -> WorkloadCfg {
        WorkloadCfg {
            rate_hz: 100.0,
            burst_factor: 1.0,
            burst_period_s: 0.0,
            burst_duty: 0.0,
            diurnal_period_s: 0.0,
            diurnal_depth: 0.0,
            total_requests: 5000,
            width_mix: vec![],
        }
    }

    #[test]
    fn emits_exactly_total_requests_in_time_order() {
        let wl = Workload::new(base_cfg(), &[0.25, 0.5], Rng::new(1));
        let evs = wl.collect_all();
        assert_eq!(evs.len(), 5000);
        assert!(evs.windows(2).all(|w| w[0].at <= w[1].at));
        assert!(evs.windows(2).all(|w| w[0].request_id + 1 == w[1].request_id));
    }

    #[test]
    fn mean_rate_close_to_config() {
        let wl = Workload::new(base_cfg(), &[1.0], Rng::new(2));
        let evs = wl.collect_all();
        let span = evs.last().unwrap().at;
        let rate = evs.len() as f64 / span;
        assert!((rate - 100.0).abs() < 5.0, "rate={rate}");
    }

    #[test]
    fn widths_drawn_from_pool() {
        let mut cfg = base_cfg();
        cfg.width_mix = vec![0.25, 0.75];
        let wl = Workload::new(cfg, &[0.5], Rng::new(3));
        let evs = wl.collect_all();
        assert!(evs.iter().all(|e| e.w_req == 0.25 || e.w_req == 0.75));
        assert!(evs.iter().any(|e| e.w_req == 0.25));
        assert!(evs.iter().any(|e| e.w_req == 0.75));
    }

    #[test]
    fn bursts_concentrate_arrivals() {
        let mut cfg = base_cfg();
        cfg.burst_factor = 8.0;
        cfg.burst_period_s = 2.0;
        cfg.burst_duty = 0.25; // bursts in [0,0.5), [2,2.5), ...
        cfg.total_requests = 20_000;
        let wl = Workload::new(cfg.clone(), &[1.0], Rng::new(4));
        let evs = wl.collect_all();
        let in_burst = evs
            .iter()
            .filter(|e| (e.at / cfg.burst_period_s).fract() < cfg.burst_duty)
            .count() as f64
            / evs.len() as f64;
        // burst windows are 25% of time but 8x rate => ~73% of arrivals
        assert!(in_burst > 0.55, "in_burst={in_burst}");
    }

    #[test]
    fn deterministic_given_seed() {
        let a = Workload::new(base_cfg(), &[0.5], Rng::new(7)).collect_all();
        let b = Workload::new(base_cfg(), &[0.5], Rng::new(7)).collect_all();
        assert_eq!(a, b);
    }

    #[test]
    fn trace_mode_replays_the_event_list_verbatim() {
        // record a generated stream, feed it back through trace mode:
        // identical events, no RNG consultation (any seed reproduces)
        let recorded = Workload::new(base_cfg(), &[0.25, 1.0], Rng::new(9)).collect_all();
        let replayed = Workload::new(base_cfg(), &[0.25, 1.0], Rng::new(12345))
            .with_trace(recorded.clone())
            .collect_all();
        assert_eq!(recorded, replayed);

        // the trace drains exactly once, regardless of total_requests
        let mut short_cfg = base_cfg();
        short_cfg.total_requests = 1;
        let again = Workload::new(short_cfg, &[0.5], Rng::new(1))
            .with_trace(recorded.clone());
        let drained: Vec<WorkloadEvent> = again.collect_all();
        assert_eq!(drained.len(), recorded.len());
    }

    #[test]
    fn diurnal_rate_oscillates_around_the_mean() {
        let mut cfg = base_cfg();
        cfg.diurnal_period_s = 40.0;
        cfg.diurnal_depth = 0.8;
        let wl = Workload::new(cfg, &[1.0], Rng::new(21));
        // quarter-period peak, three-quarter trough
        let peak = wl.rate_at(10.0);
        let trough = wl.rate_at(30.0);
        assert!((peak - 180.0).abs() < 1e-6, "peak={peak}");
        assert!((trough - 20.0).abs() < 1e-6, "trough={trough}");
        // zero crossings sit at the base rate
        assert!((wl.rate_at(0.0) - 100.0).abs() < 1e-6);
        assert!((wl.rate_at(20.0) - 100.0).abs() < 1e-6);
        // rate never goes non-positive even at depth ~1
        assert!(wl.rate_at(30.0) > 0.0);
    }

    #[test]
    fn diurnal_concentrates_arrivals_in_the_day_half() {
        let mut cfg = base_cfg();
        cfg.diurnal_period_s = 20.0;
        cfg.diurnal_depth = 0.9;
        cfg.total_requests = 20_000;
        let wl = Workload::new(cfg.clone(), &[1.0], Rng::new(22));
        let evs = wl.collect_all();
        // "day" = first half of each period, where sin >= 0
        let day = evs
            .iter()
            .filter(|e| (e.at / cfg.diurnal_period_s).fract() < 0.5)
            .count() as f64
            / evs.len() as f64;
        assert!(day > 0.6, "day fraction {day}");
    }

    #[test]
    fn rate_at_respects_burst_window() {
        let mut cfg = base_cfg();
        cfg.burst_factor = 4.0;
        cfg.burst_period_s = 10.0;
        cfg.burst_duty = 0.3;
        let wl = Workload::new(cfg, &[1.0], Rng::new(5));
        assert_eq!(wl.rate_at(1.0), 400.0); // inside burst
        assert_eq!(wl.rate_at(5.0), 100.0); // outside
        assert_eq!(wl.rate_at(11.0), 400.0); // next period burst
    }
}
