//! Request arrival generation.
//!
//! Poisson arrivals with an optional bursty square-wave modulation (the
//! paper evaluates "responsive scale-up under bursty load"): during the
//! burst window the instantaneous rate is `rate × burst_factor`. Each
//! arrival carries a requested width sampled from the configured mix
//! (uniform over W by default). The trace mode ([`Workload::with_trace`]
//! — the trace-workload source behind `crate::trace::replay`) replays a
//! fixed event list verbatim instead of drawing from the generator, so
//! any router/scenario re-runs against bit-identical arrivals.

use std::sync::Arc;

use crate::config::WorkloadCfg;
use crate::utilx::Rng;

/// One generated arrival.
#[derive(Clone, Debug, PartialEq)]
pub struct WorkloadEvent {
    pub at: f64,
    pub request_id: u64,
    pub w_req: f64,
    /// Owning tenant (0 in single-tenant workloads and for imported
    /// traces recorded before the tenant dimension existed).
    pub tenant: u16,
}

/// Per-tenant SLA multiplier: the effective deadline for tenant `t` is
/// `RouterCfg::sla_s × sla_multiplier(t)`. Tenant 0 — the hottest
/// tenant under the Zipf mix — keeps the configured SLA *exactly*
/// (×1.0 is bit-exact, which is what keeps the single-tenant default
/// path identical to the pre-tenant engine); the rest cycle through
/// looser/stricter tiers. A pure function of the tenant id, so the
/// engine, metrics, and replay all agree without plumbing a `TenantMix`
/// around.
pub fn sla_multiplier(tenant: u16) -> f64 {
    if tenant == 0 {
        return 1.0;
    }
    const TIERS: [f64; 4] = [1.5, 0.75, 2.0, 1.0];
    TIERS[(tenant as usize - 1) % TIERS.len()]
}

/// Heavy-tailed tenant popularity (Zipf over tenant rank, tenant 0
/// hottest) plus the flash-crowd weighting used by the `flash-crowd`
/// scenario. Pure function of the workload config — no RNG state — so
/// `rate_at` stays a `&self` query.
#[derive(Clone, Debug)]
pub struct TenantMix {
    /// Normalized Zipf popularity weights (sum = 1), tenant 0 first.
    weights: Vec<f64>,
    /// Sampling weights during the flash window: tenant 0's weight
    /// multiplied by `flash_factor` (unnormalized — the categorical
    /// draw normalizes).
    flash_weights: Vec<f64>,
}

impl TenantMix {
    pub fn from_cfg(cfg: &WorkloadCfg) -> Self {
        let n = cfg.tenants.max(1);
        let s = cfg.tenant_zipf;
        let mut weights: Vec<f64> =
            (0..n).map(|t| 1.0 / ((t + 1) as f64).powf(s)).collect();
        let total: f64 = weights.iter().sum();
        for w in &mut weights {
            *w /= total;
        }
        let mut flash_weights = weights.clone();
        flash_weights[0] *= cfg.flash_factor.max(1.0);
        TenantMix { weights, flash_weights }
    }

    pub fn n(&self) -> usize {
        self.weights.len()
    }

    /// Tenant `t`'s share of the offered load (outside the flash).
    pub fn share(&self, tenant: usize) -> f64 {
        self.weights.get(tenant).copied().unwrap_or(0.0)
    }
}

/// Arrival generator (iterator-style: `next_event` until exhausted).
#[derive(Clone, Debug)]
pub struct Workload {
    cfg: WorkloadCfg,
    widths: Vec<f64>,
    rng: Rng,
    /// Tenant popularity model (always derivable from the config).
    mix: TenantMix,
    /// Dedicated RNG stream for tenant / width-preference draws. Split
    /// off **only when `tenants > 1`** — `Rng::split` consumes a draw
    /// from the parent, so a single-tenant workload must never touch
    /// it to keep the pre-tenant arrival stream bit-identical.
    tenant_rng: Option<Rng>,
    t: f64,
    issued: usize,
    /// Fixed arrival stream (trace replay): when set, events replay
    /// from the shared immutable arena via a cursor and the stochastic
    /// generator (and its RNG) is never consulted. The arena is
    /// `Arc`-shared with the trace that parsed it (and with any other
    /// concurrent replays), so switching a workload into trace mode
    /// copies no events.
    trace: Option<(Arc<[WorkloadEvent]>, usize)>,
}

impl Workload {
    pub fn new(cfg: WorkloadCfg, widths: &[f64], mut rng: Rng) -> Self {
        let width_pool = if cfg.width_mix.is_empty() {
            widths.to_vec()
        } else {
            cfg.width_mix.clone()
        };
        let mix = TenantMix::from_cfg(&cfg);
        let tenant_rng =
            if cfg.tenants > 1 { Some(rng.split(0x7e4a)) } else { None };
        Workload {
            cfg,
            widths: width_pool,
            rng,
            mix,
            tenant_rng,
            t: 0.0,
            issued: 0,
            trace: None,
        }
    }

    /// Switch this workload into trace mode: `next_event` replays
    /// `events` in order and ignores the generator entirely. The
    /// construction path (and its RNG split) stays identical to the
    /// generative mode, which is what keeps a replayed engine's RNG
    /// stream bit-identical to the recording run's. Accepts a `Vec`
    /// (owned events) or an `Arc<[WorkloadEvent]>` arena handle — the
    /// latter shares the arrival set zero-copy with its source trace.
    pub fn with_trace(mut self, events: impl Into<Arc<[WorkloadEvent]>>) -> Self {
        self.trace = Some((events.into(), 0));
        self
    }

    /// Instantaneous arrival rate at time t: base rate, optionally
    /// modulated by a diurnal sinusoid (`diurnal_*`) and a square-wave
    /// burst window (`burst_*`). The modulations compose (a bursty
    /// day/night cycle is `diurnal × burst`).
    pub fn rate_at(&self, t: f64) -> f64 {
        let mut rate = self.cfg.rate_hz;
        if self.cfg.diurnal_period_s > 0.0 && self.cfg.diurnal_depth > 0.0 {
            let phase = t / self.cfg.diurnal_period_s * std::f64::consts::TAU;
            rate *= 1.0 + self.cfg.diurnal_depth.min(0.99) * phase.sin();
            rate = rate.max(self.cfg.rate_hz * 1e-2);
        }
        if self.cfg.burst_period_s > 0.0 && self.cfg.burst_factor > 1.0 {
            let phase = (t / self.cfg.burst_period_s).fract();
            if phase < self.cfg.burst_duty {
                rate *= self.cfg.burst_factor;
            }
        }
        if self.in_flash(t) {
            // tenant 0's share of the offered load spikes by
            // flash_factor; everyone else keeps arriving at base rate
            rate *= 1.0 + self.mix.share(0) * (self.cfg.flash_factor - 1.0);
        }
        rate
    }

    /// Whether `t` falls inside the flash-crowd window.
    fn in_flash(&self, t: f64) -> bool {
        self.cfg.flash_factor > 1.0
            && t >= self.cfg.flash_start_s
            && t < self.cfg.flash_end_s
    }

    /// Next arrival, or None once `total_requests` have been issued
    /// (trace mode: the next recorded event, until the trace drains).
    pub fn next_event(&mut self) -> Option<WorkloadEvent> {
        if let Some((events, cursor)) = &mut self.trace {
            let ev = events.get(*cursor).cloned();
            if ev.is_some() {
                *cursor += 1;
                self.issued += 1;
            }
            return ev;
        }
        if self.issued >= self.cfg.total_requests {
            return None;
        }
        // thinning-free approach: step with the current window's rate.
        // The draw order on the main RNG (exponential, then width
        // choice) is load-bearing: it is what keeps single-tenant
        // workloads bit-identical to the pre-tenant generator. All
        // tenant-related draws go on the dedicated tenant stream.
        let rate = self.rate_at(self.t).max(1e-9);
        self.t += self.rng.exponential(rate);
        let mut w_req = *self.rng.choice(&self.widths);
        let mut tenant = 0u16;
        if let Some(tr) = &mut self.tenant_rng {
            let weights = if self.cfg.flash_factor > 1.0
                && self.t >= self.cfg.flash_start_s
                && self.t < self.cfg.flash_end_s
            {
                &self.mix.flash_weights
            } else {
                &self.mix.weights
            };
            tenant = tr.categorical(weights) as u16;
            // width preference: half of each tenant's traffic asks for
            // its house width (tenants cycle through the pool)
            if tr.index(2) == 0 {
                w_req = self.widths[tenant as usize % self.widths.len()];
            }
        }
        let ev = WorkloadEvent {
            at: self.t,
            request_id: self.issued as u64,
            w_req,
            tenant,
        };
        self.issued += 1;
        Some(ev)
    }

    /// Drain the whole trace (for tests and trace export).
    pub fn collect_all(mut self) -> Vec<WorkloadEvent> {
        let mut out = Vec::with_capacity(self.cfg.total_requests);
        while let Some(ev) = self.next_event() {
            out.push(ev);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadCfg;

    fn base_cfg() -> WorkloadCfg {
        WorkloadCfg {
            rate_hz: 100.0,
            burst_factor: 1.0,
            burst_period_s: 0.0,
            burst_duty: 0.0,
            total_requests: 5000,
            ..WorkloadCfg::default()
        }
    }

    #[test]
    fn emits_exactly_total_requests_in_time_order() {
        let wl = Workload::new(base_cfg(), &[0.25, 0.5], Rng::new(1));
        let evs = wl.collect_all();
        assert_eq!(evs.len(), 5000);
        assert!(evs.windows(2).all(|w| w[0].at <= w[1].at));
        assert!(evs.windows(2).all(|w| w[0].request_id + 1 == w[1].request_id));
    }

    #[test]
    fn mean_rate_close_to_config() {
        let wl = Workload::new(base_cfg(), &[1.0], Rng::new(2));
        let evs = wl.collect_all();
        let span = evs.last().unwrap().at;
        let rate = evs.len() as f64 / span;
        assert!((rate - 100.0).abs() < 5.0, "rate={rate}");
    }

    #[test]
    fn widths_drawn_from_pool() {
        let mut cfg = base_cfg();
        cfg.width_mix = vec![0.25, 0.75];
        let wl = Workload::new(cfg, &[0.5], Rng::new(3));
        let evs = wl.collect_all();
        assert!(evs.iter().all(|e| e.w_req == 0.25 || e.w_req == 0.75));
        assert!(evs.iter().any(|e| e.w_req == 0.25));
        assert!(evs.iter().any(|e| e.w_req == 0.75));
    }

    #[test]
    fn bursts_concentrate_arrivals() {
        let mut cfg = base_cfg();
        cfg.burst_factor = 8.0;
        cfg.burst_period_s = 2.0;
        cfg.burst_duty = 0.25; // bursts in [0,0.5), [2,2.5), ...
        cfg.total_requests = 20_000;
        let wl = Workload::new(cfg.clone(), &[1.0], Rng::new(4));
        let evs = wl.collect_all();
        let in_burst = evs
            .iter()
            .filter(|e| (e.at / cfg.burst_period_s).fract() < cfg.burst_duty)
            .count() as f64
            / evs.len() as f64;
        // burst windows are 25% of time but 8x rate => ~73% of arrivals
        assert!(in_burst > 0.55, "in_burst={in_burst}");
    }

    #[test]
    fn deterministic_given_seed() {
        let a = Workload::new(base_cfg(), &[0.5], Rng::new(7)).collect_all();
        let b = Workload::new(base_cfg(), &[0.5], Rng::new(7)).collect_all();
        assert_eq!(a, b);
    }

    #[test]
    fn trace_mode_replays_the_event_list_verbatim() {
        // record a generated stream, feed it back through trace mode:
        // identical events, no RNG consultation (any seed reproduces)
        let recorded = Workload::new(base_cfg(), &[0.25, 1.0], Rng::new(9)).collect_all();
        let replayed = Workload::new(base_cfg(), &[0.25, 1.0], Rng::new(12345))
            .with_trace(recorded.clone())
            .collect_all();
        assert_eq!(recorded, replayed);

        // the trace drains exactly once, regardless of total_requests
        let mut short_cfg = base_cfg();
        short_cfg.total_requests = 1;
        let again = Workload::new(short_cfg, &[0.5], Rng::new(1))
            .with_trace(recorded.clone());
        let drained: Vec<WorkloadEvent> = again.collect_all();
        assert_eq!(drained.len(), recorded.len());
    }

    #[test]
    fn trace_mode_shares_the_arena_instead_of_copying() {
        // an Arc arena handed to with_trace is aliased, not cloned: one
        // arrival allocation feeds any number of replaying workloads
        let recorded = Workload::new(base_cfg(), &[0.25, 1.0], Rng::new(9)).collect_all();
        let arena: Arc<[WorkloadEvent]> = recorded.clone().into();
        let wl_a = Workload::new(base_cfg(), &[0.25, 1.0], Rng::new(1))
            .with_trace(arena.clone());
        let wl_b = Workload::new(base_cfg(), &[0.25, 1.0], Rng::new(2))
            .with_trace(arena.clone());
        // three live handles: ours plus one per trace-mode workload
        assert_eq!(Arc::strong_count(&arena), 3);
        assert_eq!(wl_a.collect_all(), recorded);
        assert_eq!(wl_b.collect_all(), recorded);
        // collect_all consumed the workloads, releasing their handles
        assert_eq!(Arc::strong_count(&arena), 1);
    }

    #[test]
    fn diurnal_rate_oscillates_around_the_mean() {
        let mut cfg = base_cfg();
        cfg.diurnal_period_s = 40.0;
        cfg.diurnal_depth = 0.8;
        let wl = Workload::new(cfg, &[1.0], Rng::new(21));
        // quarter-period peak, three-quarter trough
        let peak = wl.rate_at(10.0);
        let trough = wl.rate_at(30.0);
        assert!((peak - 180.0).abs() < 1e-6, "peak={peak}");
        assert!((trough - 20.0).abs() < 1e-6, "trough={trough}");
        // zero crossings sit at the base rate
        assert!((wl.rate_at(0.0) - 100.0).abs() < 1e-6);
        assert!((wl.rate_at(20.0) - 100.0).abs() < 1e-6);
        // rate never goes non-positive even at depth ~1
        assert!(wl.rate_at(30.0) > 0.0);
    }

    #[test]
    fn diurnal_concentrates_arrivals_in_the_day_half() {
        let mut cfg = base_cfg();
        cfg.diurnal_period_s = 20.0;
        cfg.diurnal_depth = 0.9;
        cfg.total_requests = 20_000;
        let wl = Workload::new(cfg.clone(), &[1.0], Rng::new(22));
        let evs = wl.collect_all();
        // "day" = first half of each period, where sin >= 0
        let day = evs
            .iter()
            .filter(|e| (e.at / cfg.diurnal_period_s).fract() < 0.5)
            .count() as f64
            / evs.len() as f64;
        assert!(day > 0.6, "day fraction {day}");
    }

    #[test]
    fn single_tenant_stream_is_identical_to_the_pre_tenant_generator() {
        // tenants=1 must not consult the tenant RNG at all: every event
        // is tenant 0 and the (at, id, w_req) stream matches a config
        // that never heard of tenants. Pinned here because the
        // engine-level determinism suite relies on it.
        let evs = Workload::new(base_cfg(), &[0.25, 0.5], Rng::new(7)).collect_all();
        assert!(evs.iter().all(|e| e.tenant == 0));
        let mut multi = base_cfg();
        multi.tenants = 4;
        let multi_evs = Workload::new(multi, &[0.25, 0.5], Rng::new(7)).collect_all();
        assert_eq!(evs.len(), multi_evs.len());
        assert!(multi_evs.iter().any(|e| e.tenant != 0));
    }

    #[test]
    fn zipf_mix_makes_tenant_zero_hottest() {
        let mut cfg = base_cfg();
        cfg.tenants = 6;
        cfg.tenant_zipf = 1.2;
        cfg.total_requests = 20_000;
        let mix = TenantMix::from_cfg(&cfg);
        assert_eq!(mix.n(), 6);
        assert!(((0..6).map(|t| mix.share(t)).sum::<f64>() - 1.0).abs() < 1e-12);
        let evs = Workload::new(cfg, &[1.0], Rng::new(11)).collect_all();
        let mut counts = [0usize; 6];
        for e in &evs {
            counts[e.tenant as usize] += 1;
        }
        assert!(counts.windows(2).all(|w| w[0] >= w[1] / 2), "{counts:?}");
        assert!(counts[0] > counts[5], "{counts:?}");
        // empirical share tracks the Zipf weight
        let share0 = counts[0] as f64 / evs.len() as f64;
        assert!((share0 - mix.share(0)).abs() < 0.05, "share0={share0}");
    }

    #[test]
    fn flash_window_spikes_tenant_zero() {
        let mut cfg = base_cfg();
        cfg.tenants = 6;
        cfg.flash_factor = 10.0;
        cfg.flash_start_s = 5.0;
        cfg.flash_end_s = 15.0;
        cfg.total_requests = 30_000;
        let wl = Workload::new(cfg.clone(), &[1.0], Rng::new(13));
        // the overall rate is boosted by tenant 0's share × 10 inside
        // the window and untouched outside it
        assert!(wl.rate_at(10.0) > wl.rate_at(20.0) * 2.0);
        assert_eq!(wl.rate_at(20.0), 100.0);
        let evs = wl.collect_all();
        let in_window: Vec<_> =
            evs.iter().filter(|e| e.at >= 5.0 && e.at < 15.0).collect();
        let out_window: Vec<_> =
            evs.iter().filter(|e| e.at < 5.0 || e.at >= 15.0).collect();
        let share = |evs: &[&WorkloadEvent]| {
            evs.iter().filter(|e| e.tenant == 0).count() as f64 / evs.len() as f64
        };
        assert!(
            share(&in_window) > share(&out_window) + 0.2,
            "in={} out={}",
            share(&in_window),
            share(&out_window)
        );
    }

    #[test]
    fn sla_multiplier_keeps_tenant_zero_exact() {
        assert_eq!(sla_multiplier(0), 1.0);
        // every tier is positive and tenant-stable
        for t in 1..64u16 {
            assert!(sla_multiplier(t) > 0.0);
            assert_eq!(sla_multiplier(t), sla_multiplier(t));
        }
        assert_eq!(sla_multiplier(1), 1.5);
        assert_eq!(sla_multiplier(5), 1.5); // tiers cycle with period 4
    }

    #[test]
    fn rate_at_respects_burst_window() {
        let mut cfg = base_cfg();
        cfg.burst_factor = 4.0;
        cfg.burst_period_s = 10.0;
        cfg.burst_duty = 0.3;
        let wl = Workload::new(cfg, &[1.0], Rng::new(5));
        assert_eq!(wl.rate_at(1.0), 400.0); // inside burst
        assert_eq!(wl.rate_at(5.0), 100.0); // outside
        assert_eq!(wl.rate_at(11.0), 400.0); // next period burst
    }
}
