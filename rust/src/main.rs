//! `repro` — the Slim Scheduler CLI.
//!
//! Subcommands:
//!   simulate   run one cluster experiment (choose --router / --reward)
//!   tables     regenerate paper tables (I, II, III, IV, V)
//!   figures    regenerate paper figures (1, 2, 3) as data series
//!   train-ppo  train a PPO router, print learning curve, checkpoint it
//!   scenarios  list the registered cluster/workload scenarios
//!   accuracy   query the width-tuple accuracy prior
//!   serve      real-inference smoke: route batches through PJRT CPU
//!
//! Examples:
//!   repro simulate --router ppo --reward overfit --requests 5000
//!   repro simulate --scenario hetero-mixed --router least-loaded
//!   repro tables --which 4 --scenario dropout
//!   repro figures --which 1
//!   repro train-ppo --episodes 10 --workers 4 --out ppo.json
//!   repro scenarios

use slim_scheduler::benchx::Table;
use slim_scheduler::config::Config;
use slim_scheduler::coordinator::router::{EdfRouter, LeastLoadedRouter, RoundRobinRouter};
use slim_scheduler::coordinator::sharded_engine;
use slim_scheduler::experiments;
use slim_scheduler::model::{AccuracyPrior, ModelMeta, WIDTHS};
use slim_scheduler::ppo::router_impl::width_marginal;
use slim_scheduler::runtime::{HostTensor, SegmentExecutor};
use slim_scheduler::utilx::{Args, Rng};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()
        .describe("router", "random|round-robin|least-loaded|edf|ppo (simulate)")
        .describe("reward", "overfit|balanced (ppo reward preset)")
        .describe("requests", "total requests in the workload")
        .describe("rate", "mean arrival rate (req/s)")
        .describe("episodes", "PPO training episodes")
        .describe("workers", "parallel rollout workers (train-ppo/simulate --router ppo)")
        .describe("scenario", "named cluster/workload scenario (see `repro scenarios`)")
        .describe("route-window", "FIFO heads planned per routing event (1 = paper per-head loop)")
        .describe("sla", "soft per-request SLA (s) exposed to routers as deadline slack")
        .describe("leaders", "leader shards the global FIFO splits across (1 = paper single leader)")
        .describe("rebalance", "cross-shard rebalance threshold in requests (0 = off)")
        .describe("shard-assign", "request->shard policy: hash|round-robin")
        .describe("leader-service", "leader routing service time per head (s, 0 = infinitely fast)")
        .describe("dropout", "kill server mid-run: server@time, e.g. 0@5.0")
        .describe("diurnal-period", "sinusoidal load cycle length (s, 0=off)")
        .describe("diurnal-depth", "sinusoidal load modulation depth [0,1)")
        .describe("seed", "rng seed")
        .describe("which", "table/figure number to regenerate")
        .describe("artifacts-dir", "AOT artifacts directory (serve)")
        .describe("out", "output path (train-ppo checkpoint)");

    if args.wants_help() {
        print!("{}", args.help_text("repro <subcommand> [flags]"));
        return Ok(());
    }

    match args.subcommand.as_deref() {
        Some("simulate") => cmd_simulate(&args),
        Some("tables") => cmd_tables(&args),
        Some("figures") => cmd_figures(&args),
        Some("train-ppo") => cmd_train_ppo(&args),
        Some("scenarios") => cmd_scenarios(),
        Some("accuracy") => cmd_accuracy(&args),
        Some("serve") => cmd_serve(&args),
        other => {
            if let Some(name) = other {
                eprintln!("unknown subcommand {name:?}");
            }
            print!("{}", args.help_text("repro <subcommand> [flags]"));
            Ok(())
        }
    }
}

fn base_cfg(args: &Args) -> Config {
    let mut cfg = Config::default();
    cfg.apply_args(args);
    cfg
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    let cfg = base_cfg(args);
    let router = args.str_or("router", "random");
    println!(
        "router={router} scenario={} requests={} rate={}/s devices={:?} route_window={} leaders={}",
        cfg.scenario.as_deref().unwrap_or("paper(default)"),
        cfg.workload.total_requests,
        cfg.workload.rate_hz,
        cfg.devices,
        cfg.router.route_window,
        cfg.shard.leaders
    );
    let outcome = match router.as_str() {
        "random" => experiments::run_random_baseline(&cfg),
        "round-robin" => sharded_engine(
            cfg.clone(),
            RoundRobinRouter::new(cfg.scheduler.widths.clone(), 8),
        )
        .run(),
        "least-loaded" => sharded_engine(
            cfg.clone(),
            LeastLoadedRouter::new(cfg.scheduler.widths.clone(), 16),
        )
        .run(),
        "edf" => sharded_engine(
            cfg.clone(),
            EdfRouter::new(cfg.scheduler.widths.clone(), 16),
        )
        .run(),
        "ppo" => {
            if let Some(path) = args.get("checkpoint") {
                // serve a previously trained policy (no training)
                let text = std::fs::read_to_string(path)?;
                let json = slim_scheduler::utilx::Json::parse(&text)
                    .map_err(|e| anyhow::anyhow!("{e}"))?;
                let mut router = slim_scheduler::ppo::PpoRouter::new(
                    cfg.devices.len(),
                    cfg.scheduler.widths.clone(),
                    cfg.ppo.clone(),
                    cfg.seed,
                );
                anyhow::ensure!(
                    router.load_weights(&json),
                    "checkpoint {path} does not match the policy shape"
                );
                router.eval_mode();
                println!("loaded checkpoint {path}");
                slim_scheduler::ppo::run_ppo_episode(&cfg, router).0
            } else {
                let episodes = args.usize_or("episodes", 8);
                let workers = args.usize_or("workers", 1);
                let reward = cfg.ppo.reward; // preset + --alpha/... overrides
                let (out, router) = experiments::run_ppo_experiment_workers(
                    &cfg, reward, episodes, workers,
                );
                println!(
                    "ppo: {} updates ({} workers), final mean reward {:.3}",
                    router.stats.updates,
                    workers,
                    router.stats.reward_history.last().copied().unwrap_or(0.0)
                );
                out
            }
        }
        other => anyhow::bail!("unknown router {other}"),
    };
    print!("{}", outcome.report.to_table());
    println!("width histogram (width, execs): {:?}", outcome.width_histogram);
    println!(
        "e2e latency: mean {:.1} ms  p99 {:.1} ms",
        outcome.e2e_latency.mean() * 1e3,
        outcome.e2e_latency.percentile(99.0) * 1e3
    );
    println!(
        "sim duration {:.1}s, total energy {:.0} J",
        outcome.sim_duration_s, outcome.total_energy_j
    );
    if outcome.shard_stats.len() > 1 {
        for (i, s) in outcome.shard_stats.iter().enumerate() {
            println!(
                "leader shard {i}: assigned {} routed {} heads / {} blocks, \
                 migrated +{}/-{}, peak depth {}",
                s.assigned, s.routed_heads, s.blocks, s.migrated_in,
                s.migrated_out, s.max_depth
            );
        }
    }
    if outcome.plan_clamps > 0 {
        println!("plan clamps (router fields repaired): {}", outcome.plan_clamps);
    }
    Ok(())
}

fn cmd_tables(args: &Args) -> anyhow::Result<()> {
    let which = args.str_or("which", "all");
    let prior = AccuracyPrior::new();
    if which == "1" || which == "all" {
        let mut t = Table::new(
            "Table I — SlimResNet Top-1 under uniform widths (prior)",
            &["width", "top1_pct"],
        );
        for &w in &WIDTHS {
            t.rowf(&[w, prior.lookup(&[w, w, w, w])], 2);
        }
        t.print();
    }
    if which == "2" || which == "all" {
        let mut t = Table::new(
            "Table II — Top-1 under randomized mixed widths (prior)",
            &["w1", "w2", "w3", "w4", "top1_pct"],
        );
        for &(tuple, _) in &slim_scheduler::model::accuracy::MIXED_ACC {
            t.rowf(
                &[tuple[0], tuple[1], tuple[2], tuple[3], prior.lookup(&tuple)],
                2,
            );
        }
        t.print();
    }
    let cfg = base_cfg(args);
    if which == "3" || which == "all" {
        let out = experiments::run_random_baseline(&cfg);
        print!("{}", out.report.to_table());
    }
    if which == "4" || which == "all" {
        let episodes = args.usize_or("episodes", 10);
        let (out, _) = experiments::run_table4(&cfg, episodes);
        print!("{}", out.report.to_table());
        println!("width histogram: {:?}", out.width_histogram);
    }
    if which == "5" || which == "all" {
        let episodes = args.usize_or("episodes", 10);
        let (out, _) = experiments::run_table5(&cfg, episodes);
        print!("{}", out.report.to_table());
        println!("width histogram: {:?}", out.width_histogram);
    }
    Ok(())
}

fn cmd_figures(args: &Args) -> anyhow::Result<()> {
    let which = args.str_or("which", "all");
    if which == "1" || which == "all" {
        let mut t = Table::new(
            "Fig 1 — GPU memory utilization (%) vs batch size (RTX 2080 Ti)",
            &["batch", "w=0.25", "w=0.50", "w=0.75", "w=1.00"],
        );
        for row in experiments::fig1_rows() {
            t.rowf(&row, 2);
        }
        t.print();
    }
    if which == "2" || which == "all" {
        let mut t = Table::new(
            "Fig 2 — energy (J) vs GPU utilization (RTX 2080 Ti)",
            &["util_pct", "w=0.25", "w=0.50", "w=0.75", "w=1.00"],
        );
        for row in experiments::fig2_rows() {
            t.rowf(&row, 3);
        }
        t.print();
    }
    if which == "3" || which == "all" {
        let mut t = Table::new(
            "Fig 3 — batch latency (s) vs GPU utilization (RTX 2080 Ti)",
            &["util_pct", "w=0.25", "w=0.50", "w=0.75", "w=1.00"],
        );
        for row in experiments::fig3_rows() {
            t.rowf(&row, 4);
        }
        t.print();
    }
    Ok(())
}

fn cmd_scenarios() -> anyhow::Result<()> {
    println!("registered scenarios (select with --scenario <name>):\n");
    for s in slim_scheduler::sim::scenarios::all() {
        println!("  {:<16} {}", s.name, s.summary);
        let cfg = s.config();
        println!(
            "  {:<16}   devices {:?}, {} req/s",
            "", cfg.devices, cfg.workload.rate_hz
        );
    }
    println!("\nbenches honor BENCH_SCENARIO=<name>; flags override scenario fields.");
    Ok(())
}

fn cmd_train_ppo(args: &Args) -> anyhow::Result<()> {
    let cfg = base_cfg(args);
    let episodes = args.usize_or("episodes", 10);
    let workers = args.usize_or("workers", 1);
    let reward = cfg.ppo.reward;
    println!(
        "training PPO ({episodes} episodes of {} requests, {workers} worker{})...",
        cfg.workload.total_requests,
        if workers == 1 { "" } else { "s" }
    );
    let t0 = std::time::Instant::now();
    let router = experiments::train_ppo_workers(&cfg, reward, episodes, workers);
    println!("trained in {:.2?} wall clock", t0.elapsed());
    println!("updates: {}", router.stats.updates);
    let hist = &router.stats.reward_history;
    for (i, r) in hist.iter().enumerate() {
        if i % (hist.len() / 20).max(1) == 0 || i + 1 == hist.len() {
            println!("  update {i:>4}: mean reward {r:+.4}");
        }
    }
    let snap = slim_scheduler::coordinator::TelemetrySnapshot {
        fifo_len: 8,
        done_count: 0,
        total_requests: cfg.workload.total_requests,
        servers: (0..cfg.devices.len()).map(|_| Default::default()).collect(),
    };
    println!("width marginal @idle: {:?}", width_marginal(&router, &snap));
    if let Some(path) = args.get("out") {
        std::fs::write(path, router.to_json().to_string_pretty())?;
        println!("checkpoint written to {path}");
    }
    Ok(())
}

fn cmd_accuracy(args: &Args) -> anyhow::Result<()> {
    let prior = AccuracyPrior::new();
    let widths = args.f64_list_or("widths", &[1.0, 1.0, 1.0, 1.0]);
    anyhow::ensure!(widths.len() == 4, "--widths needs 4 comma-separated values");
    let tuple = [widths[0], widths[1], widths[2], widths[3]];
    println!("prior top-1 for {tuple:?}: {:.2}%", prior.lookup(&tuple));
    println!("normalized: {:.4}", prior.normalized(&tuple));
    println!("mean over all tuples: {:.2}%", prior.mean_top1());
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let dir = args.str_or("artifacts-dir", "artifacts");
    let batch = args.usize_or("batch", 4);
    let mut ex = SegmentExecutor::new(&dir)?;
    println!(
        "artifacts: {} (widths {:?}, batches {:?})",
        ex.index.artifacts.len(),
        ex.index.widths,
        ex.index.batches
    );
    let meta = ModelMeta::default();
    let (inp, _) = meta.seg_io_shapes(0, batch);
    let mut rng = Rng::new(args.u64_or("seed", 42));
    let mut x = HostTensor::zeros(&inp);
    for v in &mut x.data {
        *v = rng.normal() as f32;
    }
    for &w in &WIDTHS {
        let t0 = std::time::Instant::now();
        let logits = ex.full_forward(&[w, w, w, w], &x)?;
        let dt = t0.elapsed();
        let top1 = logits.data[..meta.num_classes]
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        println!(
            "width {w:>4}: batch {batch} through 4 segments in {dt:?} \
             (top-1 class of row 0: {top1})"
        );
    }
    println!("executions: {}, compiles: {}", ex.executions, ex.pool.compiles);
    Ok(())
}
