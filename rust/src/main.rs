//! `repro` — the Slim Scheduler CLI.
//!
//! Subcommands:
//!   simulate      run one cluster experiment (choose --router / --reward;
//!                 --trace-out records the run as a JSONL trace)
//!   replay        re-run a recorded trace's arrivals through any router
//!                 (--trace-in; --trace-out re-records the replay)
//!   trace-compare counterfactual A/B: N routers (algorithmic names or
//!                 ppo:<checkpoint> entrants) over one trace — paired
//!                 per-request deltas + sign-test/bootstrap significance
//!                 into BENCH_trace_ab.json
//!   trace-study   scenario-conditioned sweep: record one trace per
//!                 registry scenario and trace-compare a PPO checkpoint
//!                 against the algorithmic field (BENCH_trace_study.json)
//!   autotune      offline control-plane baseline: grid-sweep static knob
//!                 configs over one recorded trace per scenario, then pit
//!                 the adaptive backlog controller against the best static
//!                 point with paired deltas (BENCH_autotune.json)
//!   report        render a --metrics-out bundle (stage-latency table,
//!                 hottest ticks, per-tenant fairness trend) offline
//!   tables        regenerate paper tables (I, II, III, IV, V)
//!   figures       regenerate paper figures (1, 2, 3) as data series
//!   train-ppo     train a PPO router, print learning curve, checkpoint it
//!   scenarios     list the registered cluster/workload scenarios
//!   accuracy      query the width-tuple accuracy prior
//!   serve         real-inference smoke: route batches through PJRT CPU
//!
//! Examples:
//!   repro simulate --router ppo --reward overfit --requests 5000
//!   repro simulate --scenario hetero-mixed --router least-loaded
//!   repro simulate --router random --requests 2000 --trace-out run.jsonl
//!   repro simulate --scenario flash-crowd --metrics-out metrics.json
//!   repro report --metrics-in metrics.json --top 8
//!   repro replay --trace-in run.jsonl --router edf
//!   repro trace-compare --trace-in run.jsonl --routers random,edf,ppo:ppo.json
//!   repro trace-study --checkpoint ppo.json --requests 1500
//!   repro simulate --scenario flash-crowd --controller backlog --drr-queue-cap 64
//!   repro autotune --scenarios paper,sharded-hot,flash-crowd --requests 1200
//!   repro tables --which 4 --scenario dropout
//!   repro figures --which 1
//!   repro train-ppo --episodes 10 --workers 4 --out ppo.json
//!   repro scenarios

use std::sync::Arc;

use slim_scheduler::benchx::Table;
use slim_scheduler::config::Config;
use slim_scheduler::coordinator::router::AlgoRouter;
use slim_scheduler::coordinator::{sharded_engine, RunOutcome};
use slim_scheduler::experiments;
use slim_scheduler::model::{AccuracyPrior, ModelMeta, WIDTHS};
use slim_scheduler::ppo::router_impl::width_marginal;
use slim_scheduler::ppo::{run_ppo_episode_io, PpoRouter};
use slim_scheduler::runtime::{HostTensor, SegmentExecutor};
use slim_scheduler::trace::{
    compare_routers_opts, configure_for_replay, write_report, CompareOpts,
    StreamingTraceWriter, Trace, TraceSink,
};
use slim_scheduler::utilx::{Args, Json, Rng};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()
        .describe("router", "random|round-robin|least-loaded|edf|ppo (simulate)")
        .describe("reward", "overfit|balanced (ppo reward preset)")
        .describe("requests", "total requests in the workload")
        .describe("rate", "mean arrival rate (req/s)")
        .describe("episodes", "PPO training episodes")
        .describe("workers", "parallel rollout workers (train-ppo/simulate --router ppo)")
        .describe("scenario", "named cluster/workload scenario (see `repro scenarios`)")
        .describe("route-window", "FIFO heads planned per routing event (1 = paper per-head loop)")
        .describe("sla", "soft per-request SLA (s) exposed to routers as deadline slack; 0 disables (EDF degrades to FIFO, no misses counted)")
        .describe("leaders", "leader shards the global FIFO splits across (1 = paper single leader)")
        .describe("rebalance", "cross-shard rebalance threshold in requests (0 = off)")
        .describe("shard-assign", "request->shard policy: hash|round-robin|key-affine")
        .describe("leader-service", "leader routing service time per head (s, 0 = infinitely fast)")
        .describe("plan-threads", "threads for per-shard router planning (1 = sequential, byte-identical baseline)")
        .describe("eval-threads", "threads for the evaluation harness: entrant replays (trace-compare) / scenario cells (trace-study); any N is byte-identical to 1")
        .describe("no-timing", "drop the per-entrant replay_wall_s fields from trace-compare/trace-study reports (deterministic output for byte comparison)")
        .describe("state-slack", "append per-head SLA slack to the PPO state vector (opt-in)")
        .describe("tenants", "multi-tenant workload: number of tenants (1 = anonymous stream)")
        .describe("tenant-zipf", "Zipf exponent of tenant popularity (0 = uniform)")
        .describe("admission", "admission gate: none (raw FIFO, default) | drr (deficit round-robin)")
        .describe("drr-quantum", "DRR credit accrued per admission tick per backlogged tenant")
        .describe("drr-burst-cap", "DRR credit ceiling (burstiness cap)")
        .describe("drr-queue-cap", "per-tenant admission queue depth; overflow is shed deterministically")
        .describe("drr-cooldown", "admission ticks a tenant sits out after overflowing its queue (0 = off, bit-identical to the plain gate)")
        .describe("controller", "live knob controller: none (static config, default) | backlog (hysteresis relief on total shard depth)")
        .describe("scenarios", "comma list of scenario names to autotune (default paper,sharded-hot,flash-crowd)")
        .describe("obs", "observability collector: true (default) | false (skip metrics/stages/series; sim results identical either way)")
        .describe("obs-series-cap", "per-tick time-series ring capacity; overflow decimates deterministically to every 2nd row (default 4096, min 2)")
        .describe("metrics-out", "write the observability bundle (versioned JSON + Prometheus-style .prom sibling) after the run (simulate, replay)")
        .describe("metrics-in", "render a previously written metrics bundle (report)")
        .describe("top", "hottest ticks to list in `repro report` (default 5)")
        .describe("trace-out", "record the run as a JSONL trace at this path")
        .describe("trace-in", "replay/compare a recorded JSONL trace (replay, trace-compare)")
        .describe("routers", "comma list for trace-compare/trace-study; first is the baseline; ppo:<path> loads a checkpoint entrant (default random,edf)")
        .describe("checkpoint", "PPO checkpoint to load instead of training (simulate, replay, trace-study)")
        .describe("dropout", "kill server mid-run: server@time, e.g. 0@5.0")
        .describe("diurnal-period", "sinusoidal load cycle length (s, 0=off)")
        .describe("diurnal-depth", "sinusoidal load modulation depth [0,1)")
        .describe("seed", "rng seed")
        .describe("which", "table/figure number to regenerate")
        .describe("artifacts-dir", "AOT artifacts directory (serve)")
        .describe("out", "output path (train-ppo checkpoint; trace-compare report)");

    if args.wants_help() {
        print!("{}", args.help_text("repro <subcommand> [flags]"));
        return Ok(());
    }

    match args.subcommand.as_deref() {
        Some("simulate") => cmd_simulate(&args),
        Some("replay") => cmd_replay(&args),
        Some("trace-compare") => cmd_trace_compare(&args),
        Some("trace-study") => cmd_trace_study(&args),
        Some("autotune") => cmd_autotune(&args),
        Some("report") => cmd_report(&args),
        Some("tables") => cmd_tables(&args),
        Some("figures") => cmd_figures(&args),
        Some("train-ppo") => cmd_train_ppo(&args),
        Some("scenarios") => cmd_scenarios(),
        Some("accuracy") => cmd_accuracy(&args),
        Some("serve") => cmd_serve(&args),
        other => {
            if let Some(name) = other {
                eprintln!("unknown subcommand {name:?}");
            }
            print!("{}", args.help_text("repro <subcommand> [flags]"));
            Ok(())
        }
    }
}

fn base_cfg(args: &Args) -> Config {
    let mut cfg = Config::default();
    cfg.apply_args(args);
    cfg
}

/// Flush a streaming recording if one was requested (shared by
/// simulate/replay). Events were written to disk as they happened, so
/// this only flushes buffers and reports the count — the full trace is
/// never resident in memory regardless of run length.
fn finish_trace(
    writer: &Option<StreamingTraceWriter>,
    trace_out: &Option<String>,
) -> anyhow::Result<()> {
    if let (Some(w), Some(path)) = (writer, trace_out) {
        let n = w.finish()?;
        println!("trace written to {path} ({n} records)");
    }
    Ok(())
}

/// The PPO checkpoint-or-train entry shared by simulate and replay:
/// loads `--checkpoint` into an eval-mode router, or trains one per
/// `--episodes`/`--workers` and freezes it. The returned (cfg, router)
/// pair is what the measured episode runs under. `shift_eval_seed`
/// selects the Tables IV/V protocol (train on `cfg.seed`, measure on a
/// fresh evaluation seed — `simulate`); `replay` passes false so the
/// measured episode runs under the trace header's seed verbatim and a
/// replay-recorded PPO trace is a fixed point of replaying itself.
/// (Faithfully reproducing a trained-PPO recording still requires
/// `--checkpoint` — retraining from the header's eval seed cannot
/// recover the original policy.)
fn ppo_for_run(
    args: &Args,
    cfg: &Config,
    shift_eval_seed: bool,
) -> anyhow::Result<(Config, PpoRouter)> {
    if let Some(path) = args.get("checkpoint") {
        // serve a previously trained policy (no training)
        let text = std::fs::read_to_string(path)?;
        let json = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let mut router = PpoRouter::for_config(cfg);
        anyhow::ensure!(
            router.load_weights(&json),
            "checkpoint {path} does not match the policy shape \
             (state-slack checkpoints need --state-slack)"
        );
        router.eval_mode();
        println!("loaded checkpoint {path}");
        Ok((cfg.clone(), router))
    } else {
        let episodes = args.usize_or("episodes", 8);
        let workers = args.usize_or("workers", 1);
        let reward = cfg.ppo.reward; // preset + --alpha/... overrides
        let (run_cfg, router) = if shift_eval_seed {
            // the Tables IV/V protocol (one definition: experiments.rs)
            experiments::prepare_ppo_eval(cfg, reward, episodes, workers)
        } else {
            let mut router =
                experiments::train_ppo_workers(cfg, reward, episodes, workers);
            router.eval_mode();
            (cfg.clone(), router)
        };
        println!(
            "ppo: {} updates ({} workers), final mean reward {:.3}",
            router.stats.updates,
            workers,
            router.stats.reward_history.last().copied().unwrap_or(0.0)
        );
        Ok((run_cfg, router))
    }
}

fn print_outcome(outcome: &RunOutcome) {
    print!("{}", outcome.report.to_table());
    println!("width histogram (width, execs): {:?}", outcome.width_histogram);
    println!(
        "e2e latency: mean {:.1} ms  p99 {:.1} ms",
        outcome.e2e_latency.mean() * 1e3,
        outcome.e2e_latency.percentile(99.0) * 1e3
    );
    println!(
        "sla misses: {} of {} ({:.2}%)",
        outcome.sla_misses,
        outcome.report.completed,
        outcome.sla_miss_rate() * 100.0
    );
    println!(
        "sim duration {:.1}s, total energy {:.0} J",
        outcome.sim_duration_s, outcome.total_energy_j
    );
    if outcome.shed > 0 || outcome.tenant_stats.len() > 1 {
        println!(
            "admission: shed {} ({:.2}%), max starvation {:.3}s, \
             jain(latency) {:.4}, jain(throughput) {:.4}",
            outcome.shed,
            outcome.shed_rate() * 100.0,
            outcome.max_starvation_s,
            outcome.jain_latency(),
            outcome.jain_throughput()
        );
    }
    let cooldowns: u64 = outcome.tenant_stats.iter().map(|s| s.cooldowns).sum();
    if outcome.degraded > 0 || outcome.credit_forfeits > 0 || cooldowns > 0 {
        println!(
            "drr gate: degraded {} to slim width, credit forfeits {}, \
             cooldowns {}",
            outcome.degraded, outcome.credit_forfeits, cooldowns
        );
    }
    if outcome.tenant_stats.len() > 1 {
        for (t, s) in outcome.tenant_stats.iter().enumerate() {
            println!(
                "tenant {t}: arrived {} done {} shed {}, mean latency \
                 {:.1} ms, sla misses {} ({:.2}%)",
                s.arrivals,
                s.done,
                s.shed,
                s.mean_latency_s() * 1e3,
                s.sla_misses,
                s.sla_miss_rate() * 100.0
            );
        }
    }
    if outcome.shard_stats.len() > 1 {
        for (i, s) in outcome.shard_stats.iter().enumerate() {
            println!(
                "leader shard {i}: assigned {} routed {} heads / {} blocks, \
                 migrated +{}/-{}, peak depth {}",
                s.assigned, s.routed_heads, s.blocks, s.migrated_in,
                s.migrated_out, s.max_depth
            );
        }
    }
    if outcome.plan_clamps > 0 {
        println!("plan clamps (router fields repaired): {}", outcome.plan_clamps);
    }
}

/// Write the observability bundle if `--metrics-out` was given: the
/// versioned JSON at the requested path plus a Prometheus-style text
/// sibling (`.json` swapped for `.prom`, else `.prom` appended). Both
/// are byte-deterministic for a fixed (seed, scenario, leaders) so CI
/// can `cmp` bundles across reruns and `--plan-threads`.
fn write_metrics(
    args: &Args,
    cfg: &Config,
    router: &str,
    outcome: &RunOutcome,
) -> anyhow::Result<()> {
    let Some(path) = args.get("metrics-out") else {
        return Ok(());
    };
    let obs = outcome.obs.as_ref().ok_or_else(|| {
        anyhow::anyhow!(
            "--metrics-out needs the observability collector (remove `--obs false`)"
        )
    })?;
    let meta = slim_scheduler::obs::BundleMeta {
        scenario: cfg.scenario.clone().unwrap_or_else(|| "paper".to_string()),
        seed: cfg.seed,
        requests: cfg.workload.total_requests,
        leaders: cfg.shard.leaders,
        router: router.to_string(),
    };
    let mut text = slim_scheduler::obs::bundle_json(obs, &meta).to_string_pretty();
    text.push('\n');
    std::fs::write(path, text)?;
    let prom_path = match path.strip_suffix(".json") {
        Some(stem) => format!("{stem}.prom"),
        None => format!("{path}.prom"),
    };
    let prom = slim_scheduler::obs::prometheus_text(obs, &meta);
    std::fs::write(&prom_path, prom)?;
    println!("metrics bundle written to {path} (+ {prom_path})");
    Ok(())
}

/// Run one engine episode of `router_name` under `cfg`, optionally fed
/// by a fixed arrival stream and/or recorded to `trace_out` — the shared
/// body of `simulate` (arrivals = None) and `replay` (arrivals = Some).
fn run_routed(
    args: &Args,
    cfg: &Config,
    router_name: &str,
    arrivals: Option<Arc<[slim_scheduler::sim::WorkloadEvent]>>,
    trace_out: &Option<String>,
) -> anyhow::Result<RunOutcome> {
    if let Some(algo) = AlgoRouter::by_name(router_name, &cfg.scheduler.widths) {
        let writer = match trace_out {
            Some(path) => {
                Some(StreamingTraceWriter::create(path, cfg, router_name)?)
            }
            None => None,
        };
        let mut engine = sharded_engine(cfg.clone(), algo);
        if let Some(events) = arrivals {
            engine.set_arrivals(events);
        }
        if let Some(w) = &writer {
            engine.set_trace_sink(Box::new(w.clone()));
        }
        let out = engine.run();
        finish_trace(&writer, trace_out)?;
        Ok(out)
    } else if router_name == "ppo" {
        // replay (arrivals set) keeps the configured seed verbatim;
        // simulate shifts to the fresh Tables IV/V evaluation seed
        let (run_cfg, router) = ppo_for_run(args, cfg, arrivals.is_none())?;
        let writer = match trace_out {
            Some(path) => {
                Some(StreamingTraceWriter::create(path, &run_cfg, "ppo")?)
            }
            None => None,
        };
        let sink = writer
            .as_ref()
            .map(|w| Box::new(w.clone()) as Box<dyn TraceSink>);
        let (out, _router) = run_ppo_episode_io(&run_cfg, router, arrivals, sink);
        finish_trace(&writer, trace_out)?;
        Ok(out)
    } else {
        anyhow::bail!(
            "unknown router {router_name} (known: {}, ppo)",
            AlgoRouter::names().join(", ")
        )
    }
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    let cfg = base_cfg(args);
    let router = args.str_or("router", "random");
    println!(
        "router={router} scenario={} requests={} rate={}/s devices={:?} route_window={} leaders={}",
        cfg.scenario.as_deref().unwrap_or("paper(default)"),
        cfg.workload.total_requests,
        cfg.workload.rate_hz,
        cfg.devices,
        cfg.router.route_window,
        cfg.shard.leaders
    );
    let trace_out = args.get("trace-out").map(str::to_string);
    let outcome = run_routed(args, &cfg, &router, None, &trace_out)?;
    print_outcome(&outcome);
    write_metrics(args, &cfg, &router, &outcome)?;
    Ok(())
}

fn cmd_replay(args: &Args) -> anyhow::Result<()> {
    let path = args
        .get("trace-in")
        .ok_or_else(|| anyhow::anyhow!("replay needs --trace-in <trace.jsonl>"))?;
    // streaming load: only the arrival stream is kept resident, so
    // replaying a multi-gigabyte trace needs memory proportional to its
    // request count, not its record count
    let trace = Trace::load_streaming(path).map_err(|e| anyhow::anyhow!("{e}"))?;
    // the embedded header config reconstructs the recording run;
    // explicit CLI flags (applied after) override it, and the request
    // budget always becomes the trace's arrival count
    let mut cfg = trace.config().unwrap_or_default();
    cfg.apply_args(args);
    configure_for_replay(&mut cfg, &trace);
    let router = args
        .get("router")
        .map(str::to_string)
        .or_else(|| trace.router.clone())
        .unwrap_or_else(|| "random".to_string());
    println!(
        "replaying {path}: {} arrivals, router={router}, leaders={}, seed={}",
        cfg.workload.total_requests, cfg.shard.leaders, cfg.seed
    );
    let trace_out = args.get("trace-out").map(str::to_string);
    // zero-copy: the engine replays straight out of the trace's parsed
    // arrival arena
    let outcome =
        run_routed(args, &cfg, &router, Some(trace.arrivals_arena()), &trace_out)?;
    print_outcome(&outcome);
    write_metrics(args, &cfg, &router, &outcome)?;
    Ok(())
}

fn cmd_report(args: &Args) -> anyhow::Result<()> {
    let path = args
        .get("metrics-in")
        .ok_or_else(|| anyhow::anyhow!("report needs --metrics-in <metrics.json>"))?;
    let text = std::fs::read_to_string(path)?;
    let bundle = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
    let top_k = args.usize_or("top", 5);
    let rendered = slim_scheduler::obs::render_report(&bundle, top_k)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    print!("{rendered}");
    Ok(())
}

fn cmd_trace_compare(args: &Args) -> anyhow::Result<()> {
    let path = args
        .get("trace-in")
        .ok_or_else(|| anyhow::anyhow!("trace-compare needs --trace-in <trace.jsonl>"))?;
    let trace = Trace::load_streaming(path).map_err(|e| anyhow::anyhow!("{e}"))?;
    let mut cfg = trace.config().unwrap_or_default();
    cfg.apply_args(args);
    let routers: Vec<String> = args
        .str_or("routers", "random,edf")
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    let opts = CompareOpts {
        eval_threads: cfg.eval.threads,
        timing: !args.flag("no-timing"),
        ..CompareOpts::default()
    };
    println!(
        "counterfactual A/B over {path}: {} arrivals, routers {:?} (baseline {}), \
         eval threads {}",
        trace.arrivals().len(),
        routers,
        routers.first().map(String::as_str).unwrap_or("?"),
        opts.eval_threads
    );
    let report = compare_routers_opts(&cfg, &trace, &routers, opts)
        .map_err(|e| anyhow::anyhow!("{e}"))?;

    print_pair_table(&report);

    let out = args.str_or("out", "BENCH_trace_ab.json");
    write_report(&report, &out)?;
    println!("A/B report written to {out}");
    Ok(())
}

/// Render one A/B report's paired-difference rows (shared by
/// trace-compare and the per-scenario entries of trace-study).
fn print_pair_table(report: &Json) {
    let mut table = Table::new(
        "Paired per-request deltas vs baseline (candidate − baseline)",
        &[
            "router",
            "n",
            "lat_delta_s",
            "lat_ci95",
            "cohen_d",
            "hl_shift",
            "energy_delta_j",
            "sign_p",
            "w/l/t",
            "miss_rate_delta",
        ],
    );
    if let Some(pairs) = report.get("pairs").and_then(Json::as_arr) {
        for pair in pairs {
            let s = |k: &str| {
                pair.get(k).and_then(Json::as_str).unwrap_or("?").to_string()
            };
            let n = |k: &str| pair.get(k).and_then(Json::as_f64).unwrap_or(f64::NAN);
            let ci = pair
                .get("latency_delta_ci95")
                .and_then(Json::as_f64_vec)
                .filter(|v| v.len() == 2)
                .map(|v| format!("[{:+.4}, {:+.4}]", v[0], v[1]))
                .unwrap_or_else(|| "?".to_string());
            table.row(&[
                s("router"),
                format!("{}", n("n_pairs") as u64),
                format!("{:+.4}", n("latency_delta_mean_s")),
                ci,
                format!("{:+.3}", n("cohen_d")),
                format!("{:+.4}", n("hl_shift_s")),
                format!("{:+.2}", n("energy_delta_mean_j")),
                format!("{:.4}", n("sign_test_p")),
                format!(
                    "{}/{}/{}",
                    n("wins") as u64,
                    n("losses") as u64,
                    n("ties") as u64
                ),
                format!("{:+.4}", n("sla_miss_rate_delta")),
            ]);
        }
    }
    table.print();
}

fn cmd_trace_study(args: &Args) -> anyhow::Result<()> {
    let checkpoint = args.get("checkpoint").ok_or_else(|| {
        anyhow::anyhow!("trace-study needs --checkpoint <ppo.json> (train one with `repro train-ppo --out ppo.json`)")
    })?;
    let field: Vec<String> = args
        .str_or("routers", "random,round-robin,least-loaded,edf")
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    let requests = args.usize_or("requests", 1500);
    let seed = args.u64_or("seed", Config::default().seed);
    let eval_threads = args.usize_or("eval-threads", 1).max(1);
    let timing = !args.flag("no-timing");
    println!(
        "trace study: {} scenarios x {requests} requests, field {:?} \
         (baseline {}), checkpoint {checkpoint}, eval threads {eval_threads}",
        slim_scheduler::sim::scenarios::all().len(),
        field,
        field.first().map(String::as_str).unwrap_or("?"),
    );
    let report = experiments::trace_study(
        checkpoint,
        &field,
        requests,
        seed,
        eval_threads,
        timing,
    )
    .map_err(|e| anyhow::anyhow!("{e}"))?;

    if let Some(entries) = report.get("scenarios").and_then(Json::as_arr) {
        for entry in entries {
            let name = entry
                .get("scenario")
                .and_then(Json::as_str)
                .unwrap_or("?");
            if let Some(e) = entry.get("record_error").and_then(Json::as_str) {
                println!("\nscenario {name}: recording failed — {e}");
                continue;
            }
            let compat = entry
                .get("ppo_compatible")
                .and_then(Json::as_bool)
                .unwrap_or(false);
            println!(
                "\nscenario {name}{}:",
                if compat { "" } else { " (checkpoint shape-incompatible; algorithmic field only)" }
            );
            if let Some(rep) = entry.get("report") {
                print_pair_table(rep);
            }
        }
    }

    let out = args.str_or("out", "BENCH_trace_study.json");
    write_report(&report, &out)?;
    println!("\nper-scenario paired matrix written to {out}");
    Ok(())
}

fn cmd_autotune(args: &Args) -> anyhow::Result<()> {
    let names: Vec<String> = args
        .str_or("scenarios", experiments::AUTOTUNE_DEFAULT_SCENARIOS)
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    let requests = args.usize_or("requests", 1200);
    let seed = args.u64_or("seed", Config::default().seed);
    let eval_threads = args.usize_or("eval-threads", 1).max(1);
    println!(
        "autotune: {} scenarios x {requests} requests, seed {seed}, \
         eval threads {eval_threads}",
        names.len()
    );
    let report = experiments::autotune(&names, requests, seed, eval_threads)
        .map_err(|e| anyhow::anyhow!("{e}"))?;

    let mut table = Table::new(
        "Offline static optimum vs adaptive backlog controller (mean e2e, s)",
        &[
            "scenario",
            "best_rw",
            "best_q",
            "static_s",
            "adaptive_s",
            "delta_s",
            "retunes",
            "sign_p",
        ],
    );
    if let Some(entries) = report.get("entries").and_then(Json::as_arr) {
        for entry in entries {
            let name =
                entry.get("scenario").and_then(Json::as_str).unwrap_or("?");
            if let Some(e) = entry.get("record_error").and_then(Json::as_str) {
                println!("scenario {name}: recording failed — {e}");
                continue;
            }
            let n = |k: &str| entry.get(k).and_then(Json::as_f64).unwrap_or(f64::NAN);
            let adaptive = entry.get("adaptive");
            let an = |k: &str| {
                adaptive
                    .and_then(|a| a.get(k))
                    .and_then(Json::as_f64)
                    .unwrap_or(f64::NAN)
            };
            table.row(&[
                name.to_string(),
                format!("{}", n("autotune_best_route_window") as u64),
                format!("{:.2}", n("autotune_best_drr_quantum")),
                format!("{:.4}", n("autotune_best_mean_latency_s")),
                format!("{:.4}", an("mean_latency_s")),
                format!("{:+.4}", an("adaptive_vs_static_delta_s")),
                format!("{}", an("knob_changes") as u64),
                format!("{:.4}", an("sign_test_p")),
            ]);
        }
    }
    table.print();

    let out = args.str_or("out", "BENCH_autotune.json");
    write_report(&report, &out)?;
    println!("autotune report written to {out}");
    Ok(())
}

fn cmd_tables(args: &Args) -> anyhow::Result<()> {
    let which = args.str_or("which", "all");
    let prior = AccuracyPrior::new();
    if which == "1" || which == "all" {
        let mut t = Table::new(
            "Table I — SlimResNet Top-1 under uniform widths (prior)",
            &["width", "top1_pct"],
        );
        for &w in &WIDTHS {
            t.rowf(&[w, prior.lookup(&[w, w, w, w])], 2);
        }
        t.print();
    }
    if which == "2" || which == "all" {
        let mut t = Table::new(
            "Table II — Top-1 under randomized mixed widths (prior)",
            &["w1", "w2", "w3", "w4", "top1_pct"],
        );
        for &(tuple, _) in &slim_scheduler::model::accuracy::MIXED_ACC {
            t.rowf(
                &[tuple[0], tuple[1], tuple[2], tuple[3], prior.lookup(&tuple)],
                2,
            );
        }
        t.print();
    }
    let cfg = base_cfg(args);
    if which == "3" || which == "all" {
        let out = experiments::run_random_baseline(&cfg);
        print!("{}", out.report.to_table());
    }
    if which == "4" || which == "all" {
        let episodes = args.usize_or("episodes", 10);
        let (out, _) = experiments::run_table4(&cfg, episodes);
        print!("{}", out.report.to_table());
        println!("width histogram: {:?}", out.width_histogram);
    }
    if which == "5" || which == "all" {
        let episodes = args.usize_or("episodes", 10);
        let (out, _) = experiments::run_table5(&cfg, episodes);
        print!("{}", out.report.to_table());
        println!("width histogram: {:?}", out.width_histogram);
    }
    Ok(())
}

fn cmd_figures(args: &Args) -> anyhow::Result<()> {
    let which = args.str_or("which", "all");
    if which == "1" || which == "all" {
        let mut t = Table::new(
            "Fig 1 — GPU memory utilization (%) vs batch size (RTX 2080 Ti)",
            &["batch", "w=0.25", "w=0.50", "w=0.75", "w=1.00"],
        );
        for row in experiments::fig1_rows() {
            t.rowf(&row, 2);
        }
        t.print();
    }
    if which == "2" || which == "all" {
        let mut t = Table::new(
            "Fig 2 — energy (J) vs GPU utilization (RTX 2080 Ti)",
            &["util_pct", "w=0.25", "w=0.50", "w=0.75", "w=1.00"],
        );
        for row in experiments::fig2_rows() {
            t.rowf(&row, 3);
        }
        t.print();
    }
    if which == "3" || which == "all" {
        let mut t = Table::new(
            "Fig 3 — batch latency (s) vs GPU utilization (RTX 2080 Ti)",
            &["util_pct", "w=0.25", "w=0.50", "w=0.75", "w=1.00"],
        );
        for row in experiments::fig3_rows() {
            t.rowf(&row, 4);
        }
        t.print();
    }
    Ok(())
}

fn cmd_scenarios() -> anyhow::Result<()> {
    println!("registered scenarios (select with --scenario <name>):\n");
    for s in slim_scheduler::sim::scenarios::all() {
        println!("  {:<16} {}", s.name, s.summary);
        let cfg = s.config();
        println!(
            "  {:<16}   devices {:?}, {} req/s",
            "", cfg.devices, cfg.workload.rate_hz
        );
    }
    println!("\nbenches honor BENCH_SCENARIO=<name>; flags override scenario fields.");
    Ok(())
}

fn cmd_train_ppo(args: &Args) -> anyhow::Result<()> {
    let cfg = base_cfg(args);
    let episodes = args.usize_or("episodes", 10);
    let workers = args.usize_or("workers", 1);
    let reward = cfg.ppo.reward;
    println!(
        "training PPO ({episodes} episodes of {} requests, {workers} worker{})...",
        cfg.workload.total_requests,
        if workers == 1 { "" } else { "s" }
    );
    let t0 = std::time::Instant::now();
    let router = experiments::train_ppo_workers(&cfg, reward, episodes, workers);
    println!("trained in {:.2?} wall clock", t0.elapsed());
    println!("updates: {}", router.stats.updates);
    let hist = &router.stats.reward_history;
    for (i, r) in hist.iter().enumerate() {
        if i % (hist.len() / 20).max(1) == 0 || i + 1 == hist.len() {
            println!("  update {i:>4}: mean reward {r:+.4}");
        }
    }
    let snap = slim_scheduler::coordinator::TelemetrySnapshot {
        fifo_len: 8,
        done_count: 0,
        total_requests: cfg.workload.total_requests,
        servers: (0..cfg.devices.len()).map(|_| Default::default()).collect(),
    };
    println!("width marginal @idle: {:?}", width_marginal(&router, &snap));
    if let Some(path) = args.get("out") {
        std::fs::write(path, router.to_json().to_string_pretty())?;
        println!("checkpoint written to {path}");
    }
    Ok(())
}

fn cmd_accuracy(args: &Args) -> anyhow::Result<()> {
    let prior = AccuracyPrior::new();
    let widths = args.f64_list_or("widths", &[1.0, 1.0, 1.0, 1.0]);
    anyhow::ensure!(widths.len() == 4, "--widths needs 4 comma-separated values");
    let tuple = [widths[0], widths[1], widths[2], widths[3]];
    println!("prior top-1 for {tuple:?}: {:.2}%", prior.lookup(&tuple));
    println!("normalized: {:.4}", prior.normalized(&tuple));
    println!("mean over all tuples: {:.2}%", prior.mean_top1());
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let dir = args.str_or("artifacts-dir", "artifacts");
    let batch = args.usize_or("batch", 4);
    let mut ex = SegmentExecutor::new(&dir)?;
    println!(
        "artifacts: {} (widths {:?}, batches {:?})",
        ex.index.artifacts.len(),
        ex.index.widths,
        ex.index.batches
    );
    let meta = ModelMeta::default();
    let (inp, _) = meta.seg_io_shapes(0, batch);
    let mut rng = Rng::new(args.u64_or("seed", 42));
    let mut x = HostTensor::zeros(&inp);
    for v in &mut x.data {
        *v = rng.normal() as f32;
    }
    for &w in &WIDTHS {
        let t0 = std::time::Instant::now();
        let logits = ex.full_forward(&[w, w, w, w], &x)?;
        let dt = t0.elapsed();
        let top1 = logits.data[..meta.num_classes]
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        println!(
            "width {w:>4}: batch {batch} through 4 segments in {dt:?} \
             (top-1 class of row 0: {top1})"
        );
    }
    println!("executions: {}, compiles: {}", ex.executions, ex.pool.compiles);
    Ok(())
}
