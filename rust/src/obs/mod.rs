//! Deterministic observability: registry, stage timing, tick series.
//!
//! Everything in this module runs on the sim clock, draws no RNG, and
//! serializes in registration/insertion order, so for a fixed seed and
//! leader count the exported bundle is byte-identical across
//! `--plan-threads`, `--eval-threads`, and repeated runs — the same
//! discipline the trace and evaluation layers already follow. (Across
//! *different* `--leaders` values the sim itself — and therefore the
//! per-shard columns — legitimately differs; determinism is per
//! topology.)
//!
//! * [`hist`] — log-bucketed [`LogHistogram`]: percentiles without the
//!   RNG reservoir `metrics::Summary` uses.
//! * [`registry`] — named counters/gauges/histograms behind typed ids;
//!   one array bump per hot-path event.
//! * [`stage`] — request-lifecycle latency decomposition
//!   (gate → leader → network → device), global and per tenant.
//! * [`series`] — bounded per-tick ring of load snapshots, the
//!   `SystemLoad`-shaped feed for a future adaptive control plane.
//! * [`export`] — versioned JSON bundle + Prometheus-style text, and
//!   the `repro report` renderer.
//!
//! The engine owns one [`ObsCollector`] (when `cfg.obs.enabled`) and
//! hands it back in `RunOutcome::obs`; callers serialize it with
//! [`bundle_json`] / [`prometheus_text`].

pub mod export;
pub mod hist;
pub mod registry;
pub mod series;
pub mod stage;

pub use export::{bundle_json, prometheus_text, render_report, BundleMeta, METRICS_VERSION};
pub use hist::LogHistogram;
pub use registry::{CounterId, HistId, MetricsRegistry};
pub use series::{KnobPoint, TickRow, TickSeries};
pub use stage::{StageAccum, StageSet, STAGE_NAMES};

/// The engine-side collector: pre-registered hot-path ids plus the
/// stage accumulator and tick series. Cheap to carry as
/// `Option<ObsCollector>` — every hot-path hook is one id-indexed bump.
#[derive(Clone, Debug)]
pub struct ObsCollector {
    pub reg: MetricsRegistry,
    pub stages: StageAccum,
    pub series: TickSeries,
    /// Control-plane knob trajectory: the initial knob state plus one
    /// point per retune. Empty on controller-less runs, which keeps
    /// their exported bundles byte-identical to pre-control-plane ones.
    pub knob_log: Vec<KnobPoint>,
    ev_total: CounterId,
    ev_kinds: Vec<CounterId>,
    migrations: CounterId,
    batch_hists: Vec<HistId>,
}

impl ObsCollector {
    /// `kind_names` maps the engine's event-kind index to a metric
    /// label; `n_servers` sizes the per-device batch histograms.
    pub fn new(n_servers: usize, kind_names: &[&str], series_cap: usize) -> Self {
        let mut reg = MetricsRegistry::new();
        let ev_total = reg.counter("events_popped_total");
        let ev_kinds = kind_names
            .iter()
            .map(|k| reg.counter(&format!("events_popped{{kind=\"{k}\"}}")))
            .collect();
        let migrations = reg.counter("rebalance_migrations_total");
        let batch_hists = (0..n_servers)
            .map(|s| reg.hist(&format!("batch_size{{server=\"{s}\"}}")))
            .collect();
        ObsCollector {
            reg,
            stages: StageAccum::default(),
            series: TickSeries::new(series_cap),
            knob_log: Vec::new(),
            ev_total,
            ev_kinds,
            migrations,
            batch_hists,
        }
    }

    /// Count one popped event of the given kind index.
    #[inline]
    pub fn on_event(&mut self, kind: usize) {
        self.reg.inc(self.ev_total, 1);
        if let Some(&id) = self.ev_kinds.get(kind) {
            self.reg.inc(id, 1);
        }
    }

    /// Count cross-shard request migrations from one rebalance pass.
    #[inline]
    pub fn on_migrations(&mut self, n: u64) {
        if n > 0 {
            self.reg.inc(self.migrations, n);
        }
    }

    /// Record a dispatched batch size on `server`.
    #[inline]
    pub fn on_batch(&mut self, server: usize, size: usize) {
        if let Some(&id) = self.batch_hists.get(server) {
            self.reg.observe(id, size as f64);
        }
    }

    /// Fold a completed request's stage decomposition into the
    /// global and per-tenant histograms.
    #[inline]
    pub fn on_done(
        &mut self,
        tenant: u16,
        gate: f64,
        leader: f64,
        net: f64,
        device: f64,
        e2e: f64,
    ) {
        self.stages.record(tenant, gate, leader, net, device, e2e);
    }

    /// Offer a telemetry-tick snapshot to the bounded series.
    pub fn on_tick(&mut self, row: TickRow) {
        self.series.push(row);
    }

    /// Record a control-plane knob state (initial, or one retune).
    pub fn on_knobs(&mut self, point: KnobPoint) {
        self.knob_log.push(point);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collector_prereg_counts_in_order() {
        let mut o = ObsCollector::new(2, &["arrival", "batch_done"], 16);
        o.on_event(0);
        o.on_event(0);
        o.on_event(1);
        o.on_event(99); // unknown kinds count in the total only
        o.on_migrations(0);
        o.on_migrations(3);
        o.on_batch(1, 8);
        o.on_batch(7, 1); // out-of-range server is ignored
        assert_eq!(o.reg.counter_value("events_popped_total"), Some(4));
        assert_eq!(
            o.reg.counter_value("events_popped{kind=\"arrival\"}"),
            Some(2)
        );
        assert_eq!(
            o.reg.counter_value("events_popped{kind=\"batch_done\"}"),
            Some(1)
        );
        assert_eq!(o.reg.counter_value("rebalance_migrations_total"), Some(3));
        assert_eq!(
            o.reg.hist_ref("batch_size{server=\"1\"}").unwrap().count,
            1
        );
        assert_eq!(
            o.reg.hist_ref("batch_size{server=\"0\"}").unwrap().count,
            0
        );
    }
}
