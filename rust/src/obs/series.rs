//! Bounded per-tick time series.
//!
//! The engine's telemetry tick (every `TELEMETRY_DT` sim-seconds)
//! pushes one [`TickRow`] snapshot: per-shard queue depth, per-server
//! utilization / power / instance count, gate-held requests, and the
//! cumulative shed/done counters. This is the `SystemLoad`-shaped
//! stream a feedback controller consumes, and what `repro report`
//! renders as "hottest ticks".
//!
//! Memory is bounded by `cap`: when the ring fills, every other
//! retained row is dropped and the recording stride doubles, so a run
//! of any length keeps ≤ `cap` rows at uniform (power-of-two) spacing —
//! a deterministic decimation with no RNG and no wall-clock input.

use crate::utilx::json::{arr_f64, obj, Json};

/// One telemetry-tick snapshot (sim clock only).
#[derive(Clone, Debug, PartialEq)]
pub struct TickRow {
    pub t: f64,
    pub shard_depths: Vec<usize>,
    pub server_util: Vec<f64>,
    pub server_power: Vec<f64>,
    pub server_instances: Vec<usize>,
    /// Requests currently held in the DRR gate (0 when ungated).
    pub gate_pending: usize,
    /// Cumulative sheds at this tick.
    pub shed: u64,
    /// Cumulative completions at this tick.
    pub done: u64,
    /// Cumulative completions per tenant.
    pub tenant_done: Vec<u64>,
}

impl TickRow {
    /// Total leader-queue depth — the "hotness" rank key for reports.
    pub fn total_depth(&self) -> usize {
        self.shard_depths.iter().sum()
    }
}

/// One control-plane knob change (or the initial state): what the
/// engine's live knobs were from sim-time `t` on. The knob trajectory
/// is tiny (a handful of hysteresis flips per run), so it is kept
/// unbounded — no decimation, unlike [`TickSeries`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct KnobPoint {
    pub t: f64,
    pub route_window: usize,
    pub rebalance_threshold: usize,
    pub drr_quantum: f64,
    pub drr_burst_cap: f64,
    pub drr_queue_cap: usize,
}

impl KnobPoint {
    /// Bundle JSON row (compact array; see `knob_columns`).
    pub fn to_row(&self) -> Json {
        Json::Arr(vec![
            Json::Num(self.t),
            Json::Num(self.route_window as f64),
            Json::Num(self.rebalance_threshold as f64),
            Json::Num(self.drr_quantum),
            Json::Num(self.drr_burst_cap),
            Json::Num(self.drr_queue_cap as f64),
        ])
    }

    /// Column legend matching [`KnobPoint::to_row`].
    pub fn knob_columns() -> Json {
        Json::Arr(
            [
                "t",
                "route_window",
                "rebalance_threshold",
                "drr_quantum",
                "drr_burst_cap",
                "drr_queue_cap",
            ]
            .iter()
            .map(|s| Json::Str(s.to_string()))
            .collect(),
        )
    }
}

/// Stride-doubling bounded ring (see module docs).
#[derive(Clone, Debug)]
pub struct TickSeries {
    rows: Vec<TickRow>,
    cap: usize,
    stride: u64,
    /// Ticks offered so far (decides which survive the stride filter).
    offered: u64,
}

impl TickSeries {
    pub fn new(cap: usize) -> Self {
        TickSeries {
            rows: Vec::new(),
            cap: cap.max(2),
            stride: 1,
            offered: 0,
        }
    }

    /// Offer the next tick row; kept iff its index lands on the current
    /// stride. Doubling the stride on overflow keeps retained rows
    /// uniformly spaced because earlier survivors of stride `s` at even
    /// positions are exactly the survivors of stride `2s`.
    pub fn push(&mut self, row: TickRow) {
        let idx = self.offered;
        self.offered += 1;
        if idx % self.stride != 0 {
            return;
        }
        if self.rows.len() == self.cap {
            let mut keep = 0;
            for i in (0..self.rows.len()).step_by(2) {
                self.rows.swap(keep, i);
                keep += 1;
            }
            self.rows.truncate(keep);
            self.stride *= 2;
            if idx % self.stride != 0 {
                return;
            }
        }
        self.rows.push(row);
    }

    pub fn rows(&self) -> &[TickRow] {
        &self.rows
    }

    pub fn stride(&self) -> u64 {
        self.stride
    }

    pub fn offered(&self) -> u64 {
        self.offered
    }

    /// Bundle JSON: a `columns` legend plus compact per-row arrays.
    pub fn to_json(&self) -> Json {
        fn arr_usize(xs: &[usize]) -> Json {
            arr_f64(&xs.iter().map(|&x| x as f64).collect::<Vec<_>>())
        }
        fn arr_u64(xs: &[u64]) -> Json {
            arr_f64(&xs.iter().map(|&x| x as f64).collect::<Vec<_>>())
        }
        let rows = self
            .rows
            .iter()
            .map(|r| {
                Json::Arr(vec![
                    Json::Num(r.t),
                    arr_usize(&r.shard_depths),
                    arr_f64(&r.server_util),
                    arr_f64(&r.server_power),
                    arr_usize(&r.server_instances),
                    Json::Num(r.gate_pending as f64),
                    Json::Num(r.shed as f64),
                    Json::Num(r.done as f64),
                    arr_u64(&r.tenant_done),
                ])
            })
            .collect();
        obj(vec![
            ("stride", Json::Num(self.stride as f64)),
            ("ticks_seen", Json::Num(self.offered as f64)),
            (
                "columns",
                Json::Arr(
                    [
                        "t",
                        "shard_depths",
                        "server_util",
                        "server_power",
                        "server_instances",
                        "gate_pending",
                        "shed",
                        "done",
                        "tenant_done",
                    ]
                    .iter()
                    .map(|s| Json::Str(s.to_string()))
                    .collect(),
                ),
            ),
            ("rows", Json::Arr(rows)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(t: f64) -> TickRow {
        TickRow {
            t,
            shard_depths: vec![1, 2],
            server_util: vec![0.5],
            server_power: vec![3.0],
            server_instances: vec![1],
            gate_pending: 0,
            shed: 0,
            done: 0,
            tenant_done: vec![],
        }
    }

    #[test]
    fn under_cap_keeps_every_tick() {
        let mut s = TickSeries::new(8);
        for i in 0..5 {
            s.push(row(i as f64));
        }
        assert_eq!(s.rows().len(), 5);
        assert_eq!(s.stride(), 1);
    }

    #[test]
    fn overflow_decimates_to_uniform_stride() {
        let mut s = TickSeries::new(4);
        for i in 0..32 {
            s.push(row(i as f64));
        }
        // after three doublings stride is 8; retained rows sit at 0,8,16,24
        assert_eq!(s.stride(), 8);
        let ts: Vec<f64> = s.rows().iter().map(|r| r.t).collect();
        assert_eq!(ts, vec![0.0, 8.0, 16.0, 24.0]);
        assert_eq!(s.offered(), 32);
    }

    #[test]
    fn exact_cap_boundary_keeps_cap_rows_then_halves_on_overflow() {
        // exactly cap offers: no decimation has happened yet
        let mut s = TickSeries::new(4);
        for i in 0..4 {
            s.push(row(i as f64));
        }
        assert_eq!(s.rows().len(), 4);
        assert_eq!(s.stride(), 1);
        let ts: Vec<f64> = s.rows().iter().map(|r| r.t).collect();
        assert_eq!(ts, vec![0.0, 1.0, 2.0, 3.0]);

        // the cap+1'th offer triggers the halving: survivors are the
        // even indices, the stride doubles, and the new row (odd index
        // 4 % 2 == 0 — index 4 survives stride 2) is appended
        s.push(row(4.0));
        let ts: Vec<f64> = s.rows().iter().map(|r| r.t).collect();
        assert_eq!(ts, vec![0.0, 2.0, 4.0]);
        assert_eq!(s.stride(), 2);
        assert_eq!(s.offered(), 5);

        // filling back to the cap again stays under it until the next
        // boundary: indices 6, 8 land on stride 2 → rows [0,2,4,6]
        s.push(row(5.0)); // filtered (5 % 2 != 0)
        s.push(row(6.0));
        let ts: Vec<f64> = s.rows().iter().map(|r| r.t).collect();
        assert_eq!(ts, vec![0.0, 2.0, 4.0, 6.0]);
        assert_eq!(s.rows().len(), 4); // exactly at cap again
        // next surviving index (8) halves again: [0,4,8], stride 4
        s.push(row(7.0));
        s.push(row(8.0));
        let ts: Vec<f64> = s.rows().iter().map(|r| r.t).collect();
        assert_eq!(ts, vec![0.0, 4.0, 8.0]);
        assert_eq!(s.stride(), 4);
    }

    #[test]
    fn decimation_is_length_invariant() {
        // a series fed N rows then M more equals one fed N+M straight
        let mut a = TickSeries::new(4);
        let mut b = TickSeries::new(4);
        for i in 0..19 {
            a.push(row(i as f64));
        }
        for i in 0..11 {
            b.push(row(i as f64));
        }
        for i in 11..19 {
            b.push(row(i as f64));
        }
        assert_eq!(a.rows(), b.rows());
        assert_eq!(a.stride(), b.stride());
    }
}
