//! Metrics-bundle serialization and the `repro report` renderer.
//!
//! A bundle is versioned JSON (`metrics_version`) carrying the full
//! registry, stage histograms, and per-tick series, plus a
//! Prometheus-style text exposition for scrape-shaped consumers. Both
//! are pure functions of the collector and run metadata — the
//! determinism tests `cmp` them byte for byte across thread counts.
//!
//! Deliberately absent from `meta`: `plan_threads` / `eval_threads` and
//! anything wall-clock. Embedding either would break the byte-identity
//! guarantee the bundle exists to demonstrate.

use super::hist::{bucket_upper_edge, LogHistogram, NUM_BUCKETS};
use super::registry::split_labels;
use super::series::KnobPoint;
use super::stage::STAGE_NAMES;
use super::ObsCollector;
use crate::coordinator::core::jain_index;
use crate::utilx::json::{obj, Json};
use std::fmt::Write as _;

/// Bump when the bundle layout changes shape.
pub const METRICS_VERSION: u64 = 1;

/// Run identity stamped into every bundle. Thread counts are excluded
/// on purpose (see module docs).
#[derive(Clone, Debug)]
pub struct BundleMeta {
    pub scenario: String,
    pub seed: u64,
    pub requests: usize,
    pub leaders: usize,
    pub router: String,
}

impl BundleMeta {
    fn to_json(&self) -> Json {
        obj(vec![
            ("scenario", Json::Str(self.scenario.clone())),
            ("seed", Json::Num(self.seed as f64)),
            ("requests", Json::Num(self.requests as f64)),
            ("leaders", Json::Num(self.leaders as f64)),
            ("router", Json::Str(self.router.clone())),
        ])
    }
}

/// The versioned JSON bundle `--metrics-out` writes. The control-plane
/// `knobs` section appears only when the run carried a controller
/// (`knob_log` non-empty), so controller-less bundles stay byte-identical
/// to pre-control-plane ones.
pub fn bundle_json(obs: &ObsCollector, meta: &BundleMeta) -> Json {
    let mut fields = vec![
        ("metrics_version", Json::Num(METRICS_VERSION as f64)),
        ("meta", meta.to_json()),
        ("registry", obs.reg.to_json()),
        ("stages", obs.stages.to_json()),
        ("series", obs.series.to_json()),
    ];
    if !obs.knob_log.is_empty() {
        fields.push((
            "knobs",
            obj(vec![
                ("columns", KnobPoint::knob_columns()),
                (
                    "rows",
                    Json::Arr(obs.knob_log.iter().map(KnobPoint::to_row).collect()),
                ),
            ]),
        ));
    }
    obj(fields)
}

fn prom_hist(out: &mut String, name: &str, h: &LogHistogram) {
    let (base, labels) = split_labels(name);
    let _ = writeln!(out, "# TYPE {base} histogram");
    let labels_inner = labels.trim_start_matches('{').trim_end_matches('}');
    let with = |le: &str| {
        if labels_inner.is_empty() {
            format!("{{le=\"{le}\"}}")
        } else {
            format!("{{{labels_inner},le=\"{le}\"}}")
        }
    };
    let mut cum = h.underflow;
    if cum > 0 {
        let _ = writeln!(out, "{base}_bucket{} {cum}", with("0"));
    }
    for idx in 0..NUM_BUCKETS {
        let c = h.bucket_count(idx);
        if c == 0 {
            continue;
        }
        cum += c;
        let le = bucket_upper_edge(idx);
        let le_s = if le.is_infinite() {
            "+Inf".to_string()
        } else {
            format!("{le}")
        };
        let _ = writeln!(out, "{base}_bucket{} {cum}", with(&le_s));
    }
    let _ = writeln!(out, "{base}_bucket{} {}", with("+Inf"), h.count);
    let _ = writeln!(out, "{base}_sum{labels} {}", h.sum);
    let _ = writeln!(out, "{base}_count{labels} {}", h.count);
}

/// Prometheus-style text exposition: every counter and gauge, every
/// registry histogram, and the global stage histograms (the per-tenant
/// stage breakdown lives in the JSON bundle only).
pub fn prometheus_text(obs: &ObsCollector, meta: &BundleMeta) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "# slim_scheduler metrics v{METRICS_VERSION}");
    let _ = writeln!(
        out,
        "# meta scenario={} seed={} requests={} leaders={} router={}",
        meta.scenario, meta.seed, meta.requests, meta.leaders, meta.router
    );
    let mut last_base = String::new();
    for (name, v) in obs.reg.counters() {
        let (base, _) = split_labels(name);
        if base != last_base {
            let _ = writeln!(out, "# TYPE {base} counter");
            last_base = base.to_string();
        }
        let _ = writeln!(out, "{name} {v}");
    }
    for (name, v) in obs.reg.gauges() {
        let (base, _) = split_labels(name);
        if base != last_base {
            let _ = writeln!(out, "# TYPE {base} gauge");
            last_base = base.to_string();
        }
        let _ = writeln!(out, "{name} {v}");
    }
    for (name, h) in obs.reg.hists() {
        prom_hist(&mut out, name, h);
    }
    for (stage, h) in STAGE_NAMES.iter().zip(obs.stages.global.hists()) {
        prom_hist(
            &mut out,
            &format!("stage_seconds{{stage=\"{stage}\"}}"),
            h,
        );
    }
    out
}

fn fmt_ms(s: f64) -> String {
    format!("{:.3}", s * 1e3)
}

/// Render a human-readable report from a parsed bundle: stage-latency
/// table, top-k hottest ticks, per-tenant fairness trend, and the
/// counter dump. Errors name the missing/malformed field.
pub fn render_report(bundle: &Json, top_k: usize) -> Result<String, String> {
    let version = bundle
        .get("metrics_version")
        .and_then(Json::as_f64)
        .ok_or("bundle missing metrics_version")? as u64;
    if version != METRICS_VERSION {
        return Err(format!(
            "unsupported metrics_version {version} (expected {METRICS_VERSION})"
        ));
    }
    let meta = bundle.get("meta").ok_or("bundle missing meta")?;
    let mut out = String::new();
    let _ = writeln!(
        out,
        "metrics bundle v{version} — scenario={} seed={} requests={} leaders={} router={}",
        meta.get("scenario").and_then(Json::as_str).unwrap_or("?"),
        meta.get("seed").and_then(Json::as_f64).unwrap_or(0.0),
        meta.get("requests").and_then(Json::as_f64).unwrap_or(0.0),
        meta.get("leaders").and_then(Json::as_f64).unwrap_or(0.0),
        meta.get("router").and_then(Json::as_str).unwrap_or("?"),
    );

    // ---- stage-latency table -------------------------------------------
    let stages = bundle.get("stages").ok_or("bundle missing stages")?;
    let global = stages.get("global").ok_or("stages missing global")?;
    let _ = writeln!(out, "\nstage latency (global, ms):");
    let _ = writeln!(
        out,
        "  {:<12} {:>9} {:>10} {:>10} {:>10} {:>10}",
        "stage", "count", "mean", "p50", "p99", "max"
    );
    for name in STAGE_NAMES {
        let h = global
            .get(name)
            .and_then(LogHistogram::from_json)
            .ok_or_else(|| format!("stages.global missing {name}"))?;
        let _ = writeln!(
            out,
            "  {:<12} {:>9} {:>10} {:>10} {:>10} {:>10}",
            name,
            h.count,
            fmt_ms(h.mean()),
            fmt_ms(h.quantile(0.50)),
            fmt_ms(h.quantile(0.99)),
            fmt_ms(h.max),
        );
    }

    // ---- per-tenant e2e ------------------------------------------------
    let tenants = stages
        .get("tenants")
        .and_then(Json::as_arr)
        .ok_or("stages missing tenants")?;
    if tenants.len() > 1 {
        let _ = writeln!(out, "\nper-tenant e2e (ms):");
        let _ = writeln!(
            out,
            "  {:<8} {:>9} {:>10} {:>10} {:>10}",
            "tenant", "count", "mean", "p99", "gate_mean"
        );
        for (t, set) in tenants.iter().enumerate() {
            let e2e = set
                .get("e2e")
                .and_then(LogHistogram::from_json)
                .ok_or_else(|| format!("tenant {t} missing e2e"))?;
            let gate = set
                .get("gate_wait")
                .and_then(LogHistogram::from_json)
                .ok_or_else(|| format!("tenant {t} missing gate_wait"))?;
            let _ = writeln!(
                out,
                "  {:<8} {:>9} {:>10} {:>10} {:>10}",
                t,
                e2e.count,
                fmt_ms(e2e.mean()),
                fmt_ms(e2e.quantile(0.99)),
                fmt_ms(gate.mean()),
            );
        }
    }

    // ---- hottest ticks -------------------------------------------------
    let series = bundle.get("series").ok_or("bundle missing series")?;
    let rows = series
        .get("rows")
        .and_then(Json::as_arr)
        .ok_or("series missing rows")?;
    // columns: t, shard_depths, util, power, instances, gate_pending, shed, done, tenant_done
    let mut ticks: Vec<(f64, f64, f64, f64)> = Vec::with_capacity(rows.len());
    let mut last_tenant_done: Vec<Vec<f64>> = Vec::new();
    for (i, r) in rows.iter().enumerate() {
        let xs = r.as_arr().ok_or_else(|| format!("series row {i} not an array"))?;
        if xs.len() != 9 {
            return Err(format!("series row {i} has {} columns", xs.len()));
        }
        let t = xs[0].as_f64().ok_or("bad tick t")?;
        let depth: f64 = xs[1].as_f64_vec().ok_or("bad shard_depths")?.iter().sum();
        let util = xs[2]
            .as_f64_vec()
            .ok_or("bad server_util")?
            .iter()
            .fold(0.0f64, |a, &b| a.max(b));
        let gate = xs[5].as_f64().ok_or("bad gate_pending")?;
        ticks.push((t, depth, util, gate));
        last_tenant_done.push(xs[8].as_f64_vec().ok_or("bad tenant_done")?);
    }
    let mut ranked: Vec<usize> = (0..ticks.len()).collect();
    ranked.sort_by(|&a, &b| {
        ticks[b]
            .1
            .total_cmp(&ticks[a].1)
            .then(ticks[a].0.total_cmp(&ticks[b].0))
    });
    let _ = writeln!(
        out,
        "\nhottest ticks (of {} retained, stride {}):",
        rows.len(),
        series.get("stride").and_then(Json::as_f64).unwrap_or(1.0)
    );
    let _ = writeln!(
        out,
        "  {:<10} {:>11} {:>10} {:>12}",
        "t", "total_depth", "max_util", "gate_pending"
    );
    for &i in ranked.iter().take(top_k) {
        let (t, depth, util, gate) = ticks[i];
        let _ = writeln!(
            out,
            "  {:<10.3} {:>11} {:>10.1} {:>12}",
            t, depth as u64, util, gate as u64
        );
    }

    // ---- control-plane knob trajectory ---------------------------------
    // present only when the run carried a controller (see bundle_json)
    if let Some(knobs) = bundle.get("knobs") {
        let krows = knobs
            .get("rows")
            .and_then(Json::as_arr)
            .ok_or("knobs missing rows")?;
        let _ = writeln!(
            out,
            "\ncontrol-plane knob trajectory ({} states: initial + retunes):",
            krows.len()
        );
        let _ = writeln!(
            out,
            "  {:<10} {:>8} {:>10} {:>9} {:>10} {:>10}",
            "t", "route_w", "rebal_th", "drr_q", "burst_cap", "queue_cap"
        );
        for (i, r) in krows.iter().enumerate() {
            let xs = r
                .as_f64_vec()
                .ok_or_else(|| format!("knobs row {i} not numeric"))?;
            if xs.len() != 6 {
                return Err(format!("knobs row {i} has {} columns", xs.len()));
            }
            let _ = writeln!(
                out,
                "  {:<10.3} {:>8} {:>10} {:>9.2} {:>10.1} {:>10}",
                xs[0], xs[1] as u64, xs[2] as u64, xs[3], xs[4], xs[5] as u64
            );
        }
    }

    // ---- per-tenant fairness trend -------------------------------------
    let multi_tenant = last_tenant_done
        .last()
        .is_some_and(|d| d.len() > 1 && d.iter().sum::<f64>() > 0.0);
    if multi_tenant {
        let _ = writeln!(
            out,
            "\nfairness trend (Jain index of cumulative per-tenant completions):"
        );
        let n = last_tenant_done.len();
        let samples = 10.min(n);
        for k in 0..samples {
            let i = if samples == 1 { n - 1 } else { k * (n - 1) / (samples - 1) };
            let jain = jain_index(&last_tenant_done[i]);
            let bar_len = (jain * 40.0).round() as usize;
            let _ = writeln!(
                out,
                "  t={:<9.3} jain={:.4} {}",
                ticks[i].0,
                jain,
                "#".repeat(bar_len)
            );
        }
    }

    // ---- counters ------------------------------------------------------
    if let Some(counters) = bundle
        .get("registry")
        .and_then(|r| r.get("counters"))
    {
        if let Json::Obj(pairs) = counters {
            let _ = writeln!(out, "\ncounters:");
            for (name, v) in pairs {
                let _ = writeln!(
                    out,
                    "  {:<44} {}",
                    name,
                    v.as_f64().unwrap_or(0.0) as u64
                );
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::series::TickRow;

    fn tiny_collector() -> ObsCollector {
        let mut o = ObsCollector::new(2, &["arrival", "done"], 64);
        o.on_event(0);
        o.on_event(1);
        o.on_batch(0, 4);
        o.on_done(0, 0.0, 0.001, 0.002, 0.010, 0.013);
        o.on_done(1, 0.2, 0.001, 0.002, 0.010, 0.213);
        o.on_tick(TickRow {
            t: 0.05,
            shard_depths: vec![3, 1],
            server_util: vec![55.0, 10.0],
            server_power: vec![3.3, 1.1],
            server_instances: vec![2, 1],
            gate_pending: 1,
            shed: 0,
            done: 2,
            tenant_done: vec![1, 1],
        });
        o.reg.set_counter("span_retunes", 2);
        o
    }

    fn meta() -> BundleMeta {
        BundleMeta {
            scenario: "unit".into(),
            seed: 7,
            requests: 2,
            leaders: 2,
            router: "edf".into(),
        }
    }

    #[test]
    fn bundle_is_versioned_and_byte_stable() {
        let o = tiny_collector();
        let a = bundle_json(&o, &meta()).to_string_pretty();
        let b = bundle_json(&o, &meta()).to_string_pretty();
        assert_eq!(a, b);
        assert!(a.contains("\"metrics_version\": 1"));
        assert!(a.contains("\"span_retunes\""));
    }

    #[test]
    fn prometheus_text_has_types_and_stage_histograms() {
        let o = tiny_collector();
        let text = prometheus_text(&o, &meta());
        assert!(text.contains("# TYPE events_popped_total counter"));
        assert!(text.contains("stage_seconds_bucket{stage=\"e2e\",le=\"+Inf\"} 2"));
        assert!(text.contains("stage_seconds_count{stage=\"e2e\"} 2"));
    }

    #[test]
    fn report_round_trips_from_bundle_json() {
        let o = tiny_collector();
        let json = bundle_json(&o, &meta()).to_string_pretty();
        let parsed = Json::parse(&json).expect("bundle parses");
        let report = render_report(&parsed, 3).expect("report renders");
        assert!(report.contains("stage latency"), "{report}");
        assert!(report.contains("hottest ticks"), "{report}");
        assert!(report.contains("e2e"), "{report}");
    }

    #[test]
    fn knobs_section_appears_only_on_controller_runs() {
        let o = tiny_collector();
        let plain = bundle_json(&o, &meta()).to_string_pretty();
        assert!(
            !plain.contains("\"knobs\""),
            "controller-less bundles must not grow a knobs section"
        );

        let mut o = o;
        o.on_knobs(KnobPoint {
            t: 0.0,
            route_window: 4,
            rebalance_threshold: 6,
            drr_quantum: 2.0,
            drr_burst_cap: 16.0,
            drr_queue_cap: 32,
        });
        o.on_knobs(KnobPoint {
            t: 1.25,
            route_window: 16,
            rebalance_threshold: 3,
            drr_quantum: 4.0,
            drr_burst_cap: 32.0,
            drr_queue_cap: 16,
        });
        let tuned = bundle_json(&o, &meta());
        let rows = tuned
            .get("knobs")
            .and_then(|k| k.get("rows"))
            .and_then(Json::as_arr)
            .expect("knobs rows present");
        assert_eq!(rows.len(), 2);

        // the report grows a knob-trajectory section, and only then
        let parsed = Json::parse(&tuned.to_string_pretty()).unwrap();
        let report = render_report(&parsed, 3).expect("report renders");
        assert!(report.contains("knob trajectory (2 states"), "{report}");
        assert!(report.contains("route_w"), "{report}");
        let plain_parsed = Json::parse(&plain).unwrap();
        let plain_report = render_report(&plain_parsed, 3).unwrap();
        assert!(!plain_report.contains("knob trajectory"), "{plain_report}");
    }

    #[test]
    fn report_rejects_wrong_version() {
        let parsed = Json::parse("{\"metrics_version\": 99}").unwrap();
        let err = render_report(&parsed, 3).unwrap_err();
        assert!(err.contains("unsupported"), "{err}");
    }
}
