//! Named-metric registry: counters, gauges, log-bucketed histograms.
//!
//! Hot paths register once at construction and hold typed ids
//! ([`CounterId`] / [`HistId`] — plain vec indices), so a hot-path
//! increment is one bounds-checked array bump with no hashing or string
//! work. Export walks the vecs in registration order, which makes the
//! serialized registry a pure function of the (deterministic) program
//! order — no `HashMap` iteration anywhere near the output.
//!
//! Names follow the Prometheus idiom: a bare base name
//! (`events_popped_total`) or a base name with a label set baked into
//! the string (`drr_shed{tenant="3"}`). The text exposition groups
//! `# TYPE` lines by the prefix before `{`.

use super::hist::LogHistogram;
use crate::utilx::json::{obj, Json};

/// Handle to a registered counter (index into the registry's vec).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CounterId(usize);

/// Handle to a registered histogram.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HistId(usize);

/// Insertion-ordered metrics store (see module docs).
#[derive(Clone, Debug, Default)]
pub struct MetricsRegistry {
    counters: Vec<(String, u64)>,
    gauges: Vec<(String, f64)>,
    hists: Vec<(String, LogHistogram)>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register (or find) a counter by name.
    pub fn counter(&mut self, name: &str) -> CounterId {
        if let Some(i) = self.counters.iter().position(|(n, _)| n == name) {
            return CounterId(i);
        }
        self.counters.push((name.to_string(), 0));
        CounterId(self.counters.len() - 1)
    }

    #[inline]
    pub fn inc(&mut self, id: CounterId, n: u64) {
        self.counters[id.0].1 += n;
    }

    /// Find-or-create by name and overwrite — for end-of-run totals
    /// harvested from existing engine state, not hot-path use.
    pub fn set_counter(&mut self, name: &str, v: u64) {
        let id = self.counter(name);
        self.counters[id.0].1 = v;
    }

    pub fn set_gauge(&mut self, name: &str, v: f64) {
        if let Some(g) = self.gauges.iter_mut().find(|(n, _)| n == name) {
            g.1 = v;
        } else {
            self.gauges.push((name.to_string(), v));
        }
    }

    /// Register (or find) a histogram by name.
    pub fn hist(&mut self, name: &str) -> HistId {
        if let Some(i) = self.hists.iter().position(|(n, _)| n == name) {
            return HistId(i);
        }
        self.hists.push((name.to_string(), LogHistogram::new()));
        HistId(self.hists.len() - 1)
    }

    #[inline]
    pub fn observe(&mut self, id: HistId, v: f64) {
        self.hists[id.0].1.record(v);
    }

    pub fn counter_value(&self, name: &str) -> Option<u64> {
        self.counters.iter().find(|(n, _)| n == name).map(|&(_, v)| v)
    }

    pub fn gauge_value(&self, name: &str) -> Option<f64> {
        self.gauges.iter().find(|(n, _)| n == name).map(|&(_, v)| v)
    }

    pub fn hist_ref(&self, name: &str) -> Option<&LogHistogram> {
        self.hists.iter().find(|(n, _)| n == name).map(|(_, h)| h)
    }

    pub fn counters(&self) -> &[(String, u64)] {
        &self.counters
    }

    pub fn gauges(&self) -> &[(String, f64)] {
        &self.gauges
    }

    pub fn hists(&self) -> &[(String, LogHistogram)] {
        &self.hists
    }

    /// Bundle JSON: `{counters: {...}, gauges: {...}, histograms: {...}}`
    /// in registration order.
    pub fn to_json(&self) -> Json {
        obj(vec![
            (
                "counters",
                Json::Obj(
                    self.counters
                        .iter()
                        .map(|(n, v)| (n.clone(), Json::Num(*v as f64)))
                        .collect(),
                ),
            ),
            (
                "gauges",
                Json::Obj(
                    self.gauges
                        .iter()
                        .map(|(n, v)| (n.clone(), Json::Num(*v)))
                        .collect(),
                ),
            ),
            (
                "histograms",
                Json::Obj(
                    self.hists
                        .iter()
                        .map(|(n, h)| (n.clone(), h.to_json()))
                        .collect(),
                ),
            ),
        ])
    }
}

/// Split `drr_shed{tenant="3"}` into `("drr_shed", "{tenant=\"3\"}")`;
/// bare names yield an empty label part.
pub(crate) fn split_labels(name: &str) -> (&str, &str) {
    match name.find('{') {
        Some(i) => (&name[..i], &name[i..]),
        None => (name, ""),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_register_once_and_accumulate() {
        let mut r = MetricsRegistry::new();
        let a = r.counter("events_total");
        let b = r.counter("events_total");
        assert_eq!(a, b);
        r.inc(a, 3);
        r.inc(b, 2);
        assert_eq!(r.counter_value("events_total"), Some(5));
        r.set_counter("events_total", 7);
        assert_eq!(r.counter_value("events_total"), Some(7));
    }

    #[test]
    fn export_preserves_registration_order() {
        let mut r = MetricsRegistry::new();
        r.counter("zz_first");
        r.counter("aa_second");
        r.set_gauge("m_gauge", 1.5);
        let h = r.hist("lat");
        r.observe(h, 0.01);
        let json = r.to_json().to_string_compact();
        let zz = json.find("zz_first").unwrap();
        let aa = json.find("aa_second").unwrap();
        assert!(zz < aa, "insertion order must survive export: {json}");
        assert_eq!(r.hist_ref("lat").unwrap().count, 1);
    }

    #[test]
    fn label_split() {
        assert_eq!(split_labels("plain"), ("plain", ""));
        assert_eq!(
            split_labels("drr_shed{tenant=\"3\"}"),
            ("drr_shed", "{tenant=\"3\"}")
        );
    }
}
