//! Deterministic log-bucketed histogram.
//!
//! `metrics::Summary` estimates percentiles from an RNG-fed reservoir —
//! fine for run reports, useless for byte-stable metrics export. A
//! [`LogHistogram`] is a pure function of the recorded multiset: values
//! land in fixed log-spaced buckets derived from their IEEE-754 bit
//! pattern (no libm, no platform-dependent rounding), so two runs that
//! record the same values always serialize to identical bytes.
//!
//! Layout: 4 sub-buckets per octave (the top two mantissa bits) over the
//! 128 octaves `[2^-64, 2^64)` — ~19 % relative resolution, 512 buckets.
//! Zero, negatives, subnormals and NaN land in a dedicated underflow
//! bucket; `+inf` and anything at or beyond `2^64` clamp into the top
//! bucket. Exact count/sum/min/max ride alongside the buckets.

use crate::utilx::json::{obj, Json};

/// Sub-buckets per octave (top two mantissa bits).
const SUBS: usize = 4;
/// Octaves covered: `2^-64 ..= 2^63` (biased exponents 959..=1086).
const OCTAVES: usize = 128;
/// Biased-exponent offset of octave 0 (`2^-64`).
const EXP_LO: i64 = 1023 - 64;
/// Total bucket count.
pub const NUM_BUCKETS: usize = OCTAVES * SUBS;

/// Deterministic log-bucketed histogram (see module docs).
#[derive(Clone, Debug, PartialEq)]
pub struct LogHistogram {
    pub count: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
    /// Values with no positive-normal bucket: zero, negatives,
    /// subnormals, anything below `2^-64`, and NaN.
    pub underflow: u64,
    buckets: Vec<u64>,
}

impl Default for LogHistogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Bucket index for `v`, or `None` for the underflow bucket.
fn bucket_index(v: f64) -> Option<usize> {
    if v.is_nan() || v <= 0.0 {
        return None;
    }
    let bits = v.to_bits();
    let exp = ((bits >> 52) & 0x7ff) as i64;
    if exp == 0 {
        // subnormal: below every bucket edge
        return None;
    }
    let octave = exp - EXP_LO;
    if octave < 0 {
        return None;
    }
    if octave >= OCTAVES as i64 {
        // huge finite values and +inf clamp into the top bucket
        return Some(NUM_BUCKETS - 1);
    }
    let sub = ((bits >> 50) & 0x3) as usize;
    Some(octave as usize * SUBS + sub)
}

/// Exact lower edge of bucket `idx`: `2^(octave-64) · (1 + sub/4)`,
/// reconstructed bit-exactly (the edge is its own bucket's smallest
/// member, so `bucket_index(lower_edge(i)) == i`).
pub fn bucket_lower_edge(idx: usize) -> f64 {
    let octave = (idx / SUBS) as u64;
    let sub = (idx % SUBS) as u64;
    f64::from_bits(((octave + EXP_LO as u64) << 52) | (sub << 50))
}

/// Exclusive upper edge of bucket `idx` (`+inf` for the top bucket).
pub fn bucket_upper_edge(idx: usize) -> f64 {
    if idx + 1 >= NUM_BUCKETS {
        f64::INFINITY
    } else {
        bucket_lower_edge(idx + 1)
    }
}

impl LogHistogram {
    pub fn new() -> Self {
        LogHistogram {
            count: 0,
            sum: 0.0,
            min: 0.0,
            max: 0.0,
            underflow: 0,
            buckets: vec![0; NUM_BUCKETS],
        }
    }

    #[inline]
    pub fn record(&mut self, v: f64) {
        if self.count == 0 {
            self.min = v;
            self.max = v;
        } else {
            if v < self.min {
                self.min = v;
            }
            if v > self.max {
                self.max = v;
            }
        }
        self.count += 1;
        self.sum += v;
        match bucket_index(v) {
            Some(i) => self.buckets[i] += 1,
            None => self.underflow += 1,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn mean(&self) -> f64 {
        if self.count > 0 {
            self.sum / self.count as f64
        } else {
            0.0
        }
    }

    /// Count in bucket `idx` (tests / export).
    pub fn bucket_count(&self, idx: usize) -> u64 {
        self.buckets[idx]
    }

    /// Deterministic quantile estimate: the lower edge of the bucket
    /// holding the `q`-th ranked value (0.0 for underflow ranks). Exact
    /// to one bucket width — ~19 % relative — which is what a log
    /// histogram buys; `min`/`max` remain exact.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target =
            ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut cum = self.underflow;
        if cum >= target {
            return 0.0;
        }
        for (i, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if cum >= target {
                return bucket_lower_edge(i);
            }
        }
        self.max
    }

    /// Sparse `(lower_edge, count)` pairs over the non-empty buckets, in
    /// ascending edge order.
    pub fn nonzero_buckets(&self) -> Vec<(f64, u64)> {
        self.buckets
            .iter()
            .enumerate()
            .filter(|&(_, &c)| c > 0)
            .map(|(i, &c)| (bucket_lower_edge(i), c))
            .collect()
    }

    /// Versioned-bundle JSON: exact scalars plus the sparse bucket list.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("count", Json::Num(self.count as f64)),
            ("sum", Json::Num(self.sum)),
            ("min", Json::Num(self.min)),
            ("max", Json::Num(self.max)),
            ("underflow", Json::Num(self.underflow as f64)),
            (
                "buckets",
                Json::Arr(
                    self.nonzero_buckets()
                        .into_iter()
                        .map(|(edge, c)| {
                            Json::Arr(vec![
                                Json::Num(edge),
                                Json::Num(c as f64),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Rebuild from [`LogHistogram::to_json`] output (the `repro report`
    /// path). Bucket edges map back to their own buckets bit-exactly, so
    /// a JSON round trip preserves every bucket count.
    pub fn from_json(json: &Json) -> Option<LogHistogram> {
        let mut h = LogHistogram::new();
        h.count = json.get("count")?.as_f64()? as u64;
        h.sum = json.get("sum")?.as_f64()?;
        h.min = json.get("min")?.as_f64()?;
        h.max = json.get("max")?.as_f64()?;
        h.underflow = json.get("underflow")?.as_f64()? as u64;
        for pair in json.get("buckets")?.as_arr()? {
            let xs = pair.as_arr()?;
            if xs.len() != 2 {
                return None;
            }
            let edge = xs[0].as_f64()?;
            let c = xs[1].as_f64()? as u64;
            h.buckets[bucket_index(edge)?] += c;
        }
        Some(h)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucketing_is_a_pure_function_of_the_bits() {
        // same value, any order, any interleaving: identical buckets
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        let vals = [0.003, 7.5, 0.003, 1e-6, 42.0, 0.25, 7.5];
        for &v in &vals {
            a.record(v);
        }
        for &v in vals.iter().rev() {
            b.record(v);
        }
        assert_eq!(a, b);
        assert_eq!(a.to_json().to_string_compact(), b.to_json().to_string_compact());
    }

    #[test]
    fn zero_negative_and_nan_land_in_underflow() {
        let mut h = LogHistogram::new();
        h.record(0.0);
        h.record(-1.5);
        h.record(f64::NAN);
        assert_eq!(h.underflow, 3);
        assert_eq!(h.count, 3);
        assert!(h.nonzero_buckets().is_empty());
    }

    #[test]
    fn subnormals_underflow_instead_of_mis_bucketing() {
        let mut h = LogHistogram::new();
        h.record(1e-310); // subnormal
        h.record(f64::MIN_POSITIVE / 4.0);
        h.record(1e-20); // normal but below 2^-64
        assert_eq!(h.underflow, 3);
        assert_eq!(h.quantile(0.5), 0.0);
    }

    #[test]
    fn huge_values_clamp_into_the_top_bucket() {
        let mut h = LogHistogram::new();
        h.record(f64::MAX);
        h.record(f64::INFINITY);
        h.record(1e300);
        assert_eq!(h.underflow, 0);
        assert_eq!(h.bucket_count(NUM_BUCKETS - 1), 3);
        assert_eq!(h.max, f64::INFINITY);
    }

    #[test]
    fn edges_are_their_own_buckets() {
        for idx in [0, 1, 5, 255, 256, NUM_BUCKETS - 2, NUM_BUCKETS - 1] {
            let edge = bucket_lower_edge(idx);
            assert_eq!(bucket_index(edge), Some(idx), "edge {edge} of {idx}");
            // just under the edge falls in the previous bucket
            let below = f64::from_bits(edge.to_bits() - 1);
            if idx > 0 {
                assert_eq!(bucket_index(below), Some(idx - 1));
            }
        }
        assert_eq!(bucket_lower_edge(0), 2.0f64.powi(-64));
        assert!(bucket_upper_edge(NUM_BUCKETS - 1).is_infinite());
    }

    #[test]
    fn relative_error_is_one_sub_bucket() {
        // every value sits within [edge, edge·1.25) of its bucket
        let mut x = 1.3e-9;
        while x < 1e9 {
            let idx = bucket_index(x).unwrap();
            let lo = bucket_lower_edge(idx);
            let hi = bucket_upper_edge(idx);
            assert!(lo <= x && x < hi, "{x} not in [{lo}, {hi})");
            assert!(hi / lo <= 1.25 + 1e-12);
            x *= 1.7;
        }
    }

    #[test]
    fn quantiles_walk_the_cumulative_counts() {
        let mut h = LogHistogram::new();
        for i in 1..=100 {
            h.record(i as f64);
        }
        assert_eq!(h.count, 100);
        let p50 = h.quantile(0.50);
        let p99 = h.quantile(0.99);
        // bucket resolution: within 25 % below the true quantile
        assert!(p50 <= 50.0 && p50 >= 40.0, "{p50}");
        assert!(p99 <= 99.0 && p99 >= 79.0, "{p99}");
        assert_eq!(h.min, 1.0);
        assert_eq!(h.max, 100.0);
    }

    #[test]
    fn json_round_trip_preserves_buckets() {
        let mut h = LogHistogram::new();
        for &v in &[0.0, 1e-310, 0.004, 0.004, 9.0, 3.2e7, f64::MAX] {
            h.record(v);
        }
        let parsed = LogHistogram::from_json(&h.to_json()).expect("parses");
        assert_eq!(parsed, h);
    }
}
