//! Request-lifecycle stage timing.
//!
//! Every completed request decomposes its end-to-end latency into four
//! stages measured on the sim clock:
//!
//! - `gate_wait`  — arrival → DRR admission (0 when no gate is active)
//! - `leader_wait` — time queued in leader shards before each routing
//!   decision (summed across segments)
//! - `net_wait`   — WLAN transfer delay between route and device arrival
//!   (summed across segments)
//! - `device`     — time from device arrival to batch completion,
//!   including server queueing and service (summed across segments)
//! - `e2e`        — arrival → final completion
//!
//! In runs without dropout re-admission the first four stages sum to
//! `e2e` up to float addition order; a re-dispatched segment counts its
//! failed leg's network wait inside the retry's leader wait.

use super::hist::LogHistogram;
use crate::utilx::json::{obj, Json};

/// Stage names in export order (matches [`StageSet::hists`]).
pub const STAGE_NAMES: [&str; 5] = ["gate_wait", "leader_wait", "net_wait", "device", "e2e"];

/// One histogram per lifecycle stage.
#[derive(Clone, Debug, Default)]
pub struct StageSet {
    pub gate_wait: LogHistogram,
    pub leader_wait: LogHistogram,
    pub net_wait: LogHistogram,
    pub device: LogHistogram,
    pub e2e: LogHistogram,
}

impl StageSet {
    #[inline]
    fn record(&mut self, gate: f64, leader: f64, net: f64, device: f64, e2e: f64) {
        self.gate_wait.record(gate);
        self.leader_wait.record(leader);
        self.net_wait.record(net);
        self.device.record(device);
        self.e2e.record(e2e);
    }

    /// Histograms in [`STAGE_NAMES`] order.
    pub fn hists(&self) -> [&LogHistogram; 5] {
        [
            &self.gate_wait,
            &self.leader_wait,
            &self.net_wait,
            &self.device,
            &self.e2e,
        ]
    }

    pub fn to_json(&self) -> Json {
        obj(STAGE_NAMES
            .iter()
            .zip(self.hists())
            .map(|(n, h)| (*n, h.to_json()))
            .collect())
    }
}

/// Global stage histograms plus a per-tenant breakdown grown on demand
/// (tenant ids are dense small integers from the workload generator).
#[derive(Clone, Debug, Default)]
pub struct StageAccum {
    pub global: StageSet,
    pub tenants: Vec<StageSet>,
}

impl StageAccum {
    #[inline]
    pub fn record(
        &mut self,
        tenant: u16,
        gate: f64,
        leader: f64,
        net: f64,
        device: f64,
        e2e: f64,
    ) {
        self.global.record(gate, leader, net, device, e2e);
        let t = tenant as usize;
        if t >= self.tenants.len() {
            self.tenants.resize_with(t + 1, StageSet::default);
        }
        self.tenants[t].record(gate, leader, net, device, e2e);
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("global", self.global.to_json()),
            (
                "tenants",
                Json::Arr(self.tenants.iter().map(StageSet::to_json).collect()),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tenants_grow_on_demand_and_global_sees_all() {
        let mut acc = StageAccum::default();
        acc.record(0, 0.0, 0.001, 0.002, 0.01, 0.013);
        acc.record(3, 0.5, 0.002, 0.003, 0.02, 0.525);
        assert_eq!(acc.tenants.len(), 4);
        assert_eq!(acc.global.e2e.count, 2);
        assert_eq!(acc.tenants[0].e2e.count, 1);
        assert_eq!(acc.tenants[1].e2e.count, 0);
        assert_eq!(acc.tenants[3].gate_wait.count, 1);
        // gate_wait of an ungated request is a clean zero → underflow bucket
        assert_eq!(acc.tenants[0].gate_wait.underflow, 1);
    }

    #[test]
    fn export_names_every_stage() {
        let mut acc = StageAccum::default();
        acc.record(0, 0.0, 0.001, 0.002, 0.01, 0.013);
        let json = acc.to_json().to_string_compact();
        for name in STAGE_NAMES {
            assert!(json.contains(name), "missing stage {name} in {json}");
        }
    }
}
