//! Adaptive control plane: live knob retuning from the obs tick series.
//!
//! The engine historically captured every scheduling knob at
//! construction; this module inverts that. [`TunableKnobs`] is the
//! runtime-tunable subset of [`Config`] (router window, shard rebalance
//! threshold, DRR quantum/burst/queue caps). The engine owns one
//! `TunableKnobs` value and re-reads it at each decision site; a
//! [`Controller`] — a *pure, zero-RNG* function from the latest
//! [`TickRow`] snapshot to a knob proposal — may rewrite it on every
//! telemetry tick. Whatever a controller returns is passed through
//! [`clamp`] before it is applied, so a buggy controller can degrade
//! throughput but can never produce an invalid configuration.
//!
//! Determinism contract: controllers see only the sim-clock tick row
//! and the current knobs, so a run with any controller is a pure
//! function of the seed, and knob changes recorded into the trace
//! replay identically (the replay engine retunes on the same ticks).
//!
//! Two controllers ship:
//!
//! * `none` — no controller object at all; the engine's knob state is
//!   pinned to the config and the output is bit-identical to the
//!   pre-control-plane engine.
//! * `backlog` — two-state hysteresis on total shard depth (the gate
//!   folds held requests into shard depths, so that one scalar is the
//!   system backlog). Above [`BACKLOG_HI`] it switches to a relief
//!   tuple (wider route window, halved rebalance threshold, doubled
//!   DRR credit, halved queue cap); at or below [`BACKLOG_LO`] it
//!   returns to the base tuple. The controller is stateless — which
//!   regime it is in is recovered from the knobs it is handed.

use crate::config::{Config, ControllerKind};
use crate::obs::TickRow;

/// Hysteresis high-water mark (total shard depth) for `backlog`.
/// Sized against the regimes that actually build tick-time backlog:
/// gate-held queues (per-tenant caps are tens — flash-crowd pins the
/// hot tenant at its queue cap) and finite-capacity leaders
/// (sharded-hot's burst backlog). An idle or keeping-up system sits at
/// ~0 depth on every tick, far below this.
pub const BACKLOG_HI: usize = 24;
/// Hysteresis low-water mark for `backlog`; must sit well below
/// [`BACKLOG_HI`] so the controller cannot oscillate every tick.
pub const BACKLOG_LO: usize = 8;

/// The runtime-tunable subset of [`Config`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TunableKnobs {
    /// Candidate window the router scores per dispatch (≥ 1).
    pub route_window: usize,
    /// Shard-imbalance threshold that triggers a rebalance (0 = off).
    pub rebalance_threshold: usize,
    /// DRR credit added per tenant per admission tick.
    pub drr_quantum: f64,
    /// DRR per-tenant credit ceiling.
    pub drr_burst_cap: f64,
    /// DRR per-tenant queue cap (offers beyond it shed).
    pub drr_queue_cap: usize,
}

impl TunableKnobs {
    /// Snapshot the tunable subset out of a full config.
    pub fn from_config(cfg: &Config) -> TunableKnobs {
        TunableKnobs {
            route_window: cfg.router.route_window,
            rebalance_threshold: cfg.shard.rebalance_threshold,
            drr_quantum: cfg.admission.quantum,
            drr_burst_cap: cfg.admission.burst_cap,
            drr_queue_cap: cfg.admission.queue_cap,
        }
    }
}

/// Validated range for each knob; controller returns are clamped here
/// before the engine applies them. Non-finite floats collapse to the
/// range minimum (a NaN must not survive into credit arithmetic).
pub fn clamp(k: TunableKnobs) -> TunableKnobs {
    fn clamp_f64(x: f64, lo: f64, hi: f64) -> f64 {
        if !x.is_finite() {
            lo
        } else {
            x.max(lo).min(hi)
        }
    }
    TunableKnobs {
        route_window: k.route_window.clamp(1, 64),
        rebalance_threshold: k.rebalance_threshold.min(4096),
        drr_quantum: clamp_f64(k.drr_quantum, 0.25, 64.0),
        drr_burst_cap: clamp_f64(k.drr_burst_cap, 1.0, 256.0),
        drr_queue_cap: k.drr_queue_cap.clamp(1, 65536),
    }
}

/// A feedback controller: pure, zero-RNG, sim-clock only. `tune` is
/// called once per telemetry tick with the freshest [`TickRow`] and the
/// knobs currently in force, and returns the knobs it wants next (the
/// engine clamps and diffs them; an unchanged return is a no-op).
pub trait Controller: Send {
    fn name(&self) -> &'static str;
    fn tune(&self, row: &TickRow, knobs: &TunableKnobs) -> TunableKnobs;
}

/// Build the controller for a parsed `--controller` choice.
/// `ControllerKind::None` maps to no controller at all so the
/// engine's hot path stays byte-identical to the pre-control-plane
/// binary (no tick-row construction, no virtual call).
pub fn controller_for(
    kind: ControllerKind,
    base: &TunableKnobs,
) -> Option<Box<dyn Controller>> {
    match kind {
        ControllerKind::None => None,
        ControllerKind::Backlog => Some(Box::new(BacklogController::new(*base))),
    }
}

/// Two-state hysteresis controller over total shard depth.
pub struct BacklogController {
    base: TunableKnobs,
    relief: TunableKnobs,
}

impl BacklogController {
    pub fn new(base: TunableKnobs) -> BacklogController {
        let base = clamp(base);
        BacklogController {
            base,
            relief: clamp(relief_of(&base)),
        }
    }
}

/// The relief tuple: spend more routing effort and DRR credit to drain
/// a backlog, while shrinking the queue cap so sheds (and the cooldown
/// satellite, when armed) kick in earlier for misbehaving tenants.
fn relief_of(base: &TunableKnobs) -> TunableKnobs {
    TunableKnobs {
        route_window: base.route_window * 4,
        rebalance_threshold: if base.rebalance_threshold == 0 {
            0
        } else {
            (base.rebalance_threshold / 2).max(1)
        },
        drr_quantum: base.drr_quantum * 2.0,
        drr_burst_cap: base.drr_burst_cap * 2.0,
        drr_queue_cap: (base.drr_queue_cap / 2).max(1),
    }
}

impl Controller for BacklogController {
    fn name(&self) -> &'static str {
        "backlog"
    }

    fn tune(&self, row: &TickRow, knobs: &TunableKnobs) -> TunableKnobs {
        // Gate-held requests are already folded into shard depths by
        // the planner, so total depth alone is the system backlog.
        let pressure = row.total_depth();
        if *knobs == self.base && pressure >= BACKLOG_HI {
            self.relief
        } else if *knobs == self.relief && pressure <= BACKLOG_LO {
            self.base
        } else {
            *knobs
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row_with_depth(depth: usize) -> TickRow {
        TickRow {
            t: 1.0,
            shard_depths: vec![depth],
            server_util: vec![],
            server_power: vec![],
            server_instances: vec![],
            gate_pending: 0,
            shed: 0,
            done: 0,
            tenant_done: vec![],
        }
    }

    fn base() -> TunableKnobs {
        TunableKnobs::from_config(&Config::default())
    }

    #[test]
    fn from_config_snapshots_the_tunable_subset() {
        let cfg = Config::default();
        let k = TunableKnobs::from_config(&cfg);
        assert_eq!(k.route_window, cfg.router.route_window);
        assert_eq!(k.rebalance_threshold, cfg.shard.rebalance_threshold);
        assert_eq!(k.drr_quantum, cfg.admission.quantum);
        assert_eq!(k.drr_burst_cap, cfg.admission.burst_cap);
        assert_eq!(k.drr_queue_cap, cfg.admission.queue_cap);
    }

    #[test]
    fn clamp_is_identity_on_defaults() {
        let k = base();
        assert_eq!(clamp(k), k);
    }

    #[test]
    fn clamp_bounds_out_of_range_returns() {
        // the satellite case: a controller returning wild values must
        // come back inside the validated ranges
        let wild = TunableKnobs {
            route_window: 0,
            rebalance_threshold: usize::MAX,
            drr_quantum: f64::NAN,
            drr_burst_cap: 1.0e12,
            drr_queue_cap: 0,
        };
        let k = clamp(wild);
        assert_eq!(k.route_window, 1);
        assert_eq!(k.rebalance_threshold, 4096);
        assert_eq!(k.drr_quantum, 0.25); // NaN collapses to the minimum
        assert_eq!(k.drr_burst_cap, 256.0);
        assert_eq!(k.drr_queue_cap, 1);

        let wild = TunableKnobs {
            route_window: 10_000,
            rebalance_threshold: 0,
            drr_quantum: f64::NEG_INFINITY,
            drr_burst_cap: f64::INFINITY,
            drr_queue_cap: usize::MAX,
        };
        let k = clamp(wild);
        assert_eq!(k.route_window, 64);
        assert_eq!(k.rebalance_threshold, 0);
        assert_eq!(k.drr_quantum, 0.25);
        assert_eq!(k.drr_burst_cap, 256.0);
        assert_eq!(k.drr_queue_cap, 65536);
    }

    #[test]
    fn controller_for_none_is_no_controller() {
        assert!(controller_for(ControllerKind::None, &base()).is_none());
        let c = controller_for(ControllerKind::Backlog, &base()).unwrap();
        assert_eq!(c.name(), "backlog");
    }

    #[test]
    fn backlog_hysteresis_switches_and_holds() {
        let b = base();
        let ctrl = BacklogController::new(b);
        let relief = clamp(relief_of(&b));

        // quiet system: stays on base
        assert_eq!(ctrl.tune(&row_with_depth(BACKLOG_LO), &b), b);
        // crosses high water: relief
        assert_eq!(ctrl.tune(&row_with_depth(BACKLOG_HI), &b), relief);
        // in relief, mid-band pressure holds relief (hysteresis)
        assert_eq!(
            ctrl.tune(&row_with_depth(BACKLOG_LO + 1), &relief),
            relief
        );
        // drains to low water: back to base
        assert_eq!(ctrl.tune(&row_with_depth(BACKLOG_LO), &relief), b);
        // on base, mid-band pressure holds base
        assert_eq!(ctrl.tune(&row_with_depth(BACKLOG_HI - 1), &b), b);
    }

    #[test]
    fn backlog_relief_is_in_range() {
        let ctrl = BacklogController::new(base());
        let relief = ctrl.tune(&row_with_depth(BACKLOG_HI), &base());
        assert_eq!(clamp(relief), relief);
        assert!(relief.route_window >= base().route_window);
        assert!(relief.drr_quantum > base().drr_quantum);
        assert!(relief.drr_queue_cap <= base().drr_queue_cap);
    }

    #[test]
    fn tune_is_pure() {
        let ctrl = BacklogController::new(base());
        let row = row_with_depth(BACKLOG_HI + 5);
        let a = ctrl.tune(&row, &base());
        let b = ctrl.tune(&row, &base());
        assert_eq!(a, b);
    }
}
