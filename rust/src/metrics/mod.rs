//! Streaming metrics: the measurement substrate behind every table and
//! figure — Welford mean/variance, reservoir percentiles, EWMA, and
//! throughput counters. All f64, allocation-light, no external deps.

/// Streaming summary: exact count/mean/variance (Welford) + bounded
/// reservoir for percentiles.
#[derive(Clone, Debug)]
pub struct Summary {
    count: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
    reservoir: Vec<f64>,
    cap: usize,
    seen_for_reservoir: u64,
    /// cheap xorshift state for reservoir sampling (decoupled from sim RNG)
    rstate: u64,
}

impl Default for Summary {
    fn default() -> Self {
        Self::with_capacity(4096)
    }
}

impl Summary {
    /// Reservoir capacity bounds percentile memory; 4096 gives ~1.6 %
    /// worst-case p99 error which is far below run-to-run variance.
    pub fn with_capacity(cap: usize) -> Self {
        Summary {
            count: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            reservoir: Vec::with_capacity(cap.min(4096)),
            cap,
            seen_for_reservoir: 0,
            rstate: 0x243f6a8885a308d3,
        }
    }

    fn next_r(&mut self) -> u64 {
        // xorshift64*
        let mut x = self.rstate;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.rstate = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Record one observation.
    pub fn record(&mut self, x: f64) {
        self.count += 1;
        let delta = x - self.mean;
        self.mean += delta / self.count as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);

        self.seen_for_reservoir += 1;
        if self.reservoir.len() < self.cap {
            self.reservoir.push(x);
        } else {
            let j = self.next_r() % self.seen_for_reservoir;
            if (j as usize) < self.cap {
                self.reservoir[j as usize] = x;
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.mean
        }
    }

    /// Sample standard deviation.
    pub fn std(&self) -> f64 {
        if self.count < 2 {
            0.0
        } else {
            (self.m2 / (self.count - 1) as f64).sqrt()
        }
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Approximate percentile (p in [0,100]) from the reservoir.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.reservoir.is_empty() {
            return 0.0;
        }
        let mut xs = self.reservoir.clone();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = (p / 100.0 * (xs.len() - 1) as f64).round() as usize;
        xs[rank.min(xs.len() - 1)]
    }

    /// Merge another summary (mean/m2 via Chan's parallel formula;
    /// reservoirs concatenated then down-sampled deterministically).
    pub fn merge(&mut self, other: &Summary) {
        if other.count == 0 {
            return;
        }
        if self.count == 0 {
            *self = other.clone();
            return;
        }
        let n1 = self.count as f64;
        let n2 = other.count as f64;
        let delta = other.mean - self.mean;
        self.mean = (n1 * self.mean + n2 * other.mean) / (n1 + n2);
        self.m2 += other.m2 + delta * delta * n1 * n2 / (n1 + n2);
        self.count += other.count;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        for &x in &other.reservoir {
            if self.reservoir.len() < self.cap {
                self.reservoir.push(x);
            } else {
                self.seen_for_reservoir += 1;
                let j = self.next_r() % self.seen_for_reservoir;
                if (j as usize) < self.cap {
                    self.reservoir[j as usize] = x;
                }
            }
        }
    }
}

/// Exponentially-weighted moving average (telemetry smoothing).
#[derive(Clone, Debug)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        Ewma { alpha, value: None }
    }

    pub fn record(&mut self, x: f64) {
        self.value = Some(match self.value {
            None => x,
            Some(v) => self.alpha * x + (1.0 - self.alpha) * v,
        });
    }

    pub fn value(&self) -> f64 {
        self.value.unwrap_or(0.0)
    }
}

/// Items-per-second counter over a run window.
#[derive(Clone, Debug, Default)]
pub struct Throughput {
    pub items: u64,
    pub window_s: f64,
}

impl Throughput {
    pub fn add(&mut self, n: u64) {
        self.items += n;
    }

    pub fn per_second(&self) -> f64 {
        if self.window_s <= 0.0 {
            0.0
        } else {
            self.items as f64 / self.window_s
        }
    }
}

/// One row of a paper-style results table (Tables III–V schema).
#[derive(Clone, Debug)]
pub struct RunReport {
    pub label: String,
    pub accuracy_pct: f64,
    pub latency: Summary,
    pub energy: Summary,
    pub gpu_var: Summary,
    pub completed: u64,
    pub duration_s: f64,
}

impl RunReport {
    /// Image-completion throughput in the paper's unit (images completed
    /// scaled to a fixed wall-window).
    pub fn throughput(&self) -> f64 {
        if self.duration_s <= 0.0 {
            0.0
        } else {
            self.completed as f64 / self.duration_s
        }
    }

    /// Render in the paper's table layout.
    pub fn to_table(&self) -> String {
        format!(
            "{}\n\
             {:<32} {:>12} {:>12}\n\
             {:<32} {:>12.2} {:>12}\n\
             {:<32} {:>12.3} {:>12.3}\n\
             {:<32} {:>12.2} {:>12.2}\n\
             {:<32} {:>12.4} {:>12.4}\n\
             {:<32} {:>12.0} {:>12}\n",
            self.label,
            "Metric", "Mean", "Std",
            "Accuracy (%)", self.accuracy_pct, "",
            "Latency (ms)", self.latency.mean() * 1e3, self.latency.std() * 1e3,
            "Energy (J)", self.energy.mean(), self.energy.std(),
            "GPU Var (%)", self.gpu_var.mean(), self.gpu_var.std(),
            "Throughput (img/s)", self.throughput(), "",
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_mean_std_exact() {
        let mut s = Summary::default();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.record(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        // sample std of that classic dataset = sqrt(32/7)
        assert!((s.std() - (32.0f64 / 7.0).sqrt()).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn summary_empty_is_zeroes() {
        let s = Summary::default();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.std(), 0.0);
        assert_eq!(s.percentile(99.0), 0.0);
    }

    #[test]
    fn percentiles_without_overflow() {
        let mut s = Summary::with_capacity(256);
        for i in 0..10_000 {
            s.record(i as f64);
        }
        let p50 = s.percentile(50.0);
        let p99 = s.percentile(99.0);
        assert!((p50 - 5000.0).abs() < 1500.0, "p50={p50}");
        assert!(p99 > 8000.0, "p99={p99}");
        assert!(s.percentile(0.0) <= p50 && p50 <= s.percentile(100.0));
    }

    #[test]
    fn merge_matches_single_stream() {
        let mut a = Summary::default();
        let mut b = Summary::default();
        let mut whole = Summary::default();
        for i in 0..1000 {
            let x = (i as f64 * 0.37).sin() * 10.0;
            whole.record(x);
            if i % 2 == 0 {
                a.record(x);
            } else {
                b.record(x);
            }
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert!((a.mean() - whole.mean()).abs() < 1e-9);
        assert!((a.std() - whole.std()).abs() < 1e-9);
    }

    #[test]
    fn ewma_converges() {
        let mut e = Ewma::new(0.5);
        assert_eq!(e.value(), 0.0);
        for _ in 0..50 {
            e.record(10.0);
        }
        assert!((e.value() - 10.0).abs() < 1e-6);
    }

    #[test]
    fn throughput_math() {
        let mut t = Throughput::default();
        t.add(500);
        t.window_s = 2.0;
        assert_eq!(t.per_second(), 250.0);
    }

    #[test]
    fn run_report_renders_paper_layout() {
        let mut lat = Summary::default();
        lat.record(0.008);
        let mut en = Summary::default();
        en.record(1000.0);
        let report = RunReport {
            label: "baseline".into(),
            accuracy_pct: 74.43,
            latency: lat,
            energy: en,
            gpu_var: Summary::default(),
            completed: 1000,
            duration_s: 4.0,
        };
        let t = report.to_table();
        assert!(t.contains("Accuracy"));
        assert!(t.contains("74.43"));
        assert!(t.contains("250"));
    }
}
