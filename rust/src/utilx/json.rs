//! Minimal JSON: recursive-descent parser + writer (serde substitute).
//!
//! Consumers: the artifact `manifest.json` written by `python/compile/
//! aot.py`, config files, telemetry dumps, and PPO checkpoint metadata.
//! Numbers are f64 (like JavaScript); objects preserve insertion order.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

/// Parse / access errors.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---------------------------------------------------------------
    // Parsing
    // ---------------------------------------------------------------

    /// Parse a complete JSON document (trailing whitespace allowed).
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let bytes = text.as_bytes();
        let mut p = Parser { bytes, pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---------------------------------------------------------------
    // Typed accessors
    // ---------------------------------------------------------------

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Object field lookup that errors with the key name.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or(JsonError {
            msg: format!("missing field '{key}'"),
            offset: 0,
        })
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| if x >= 0.0 { Some(x as usize) } else { None })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(xs) => Some(xs),
            _ => None,
        }
    }

    /// Array of numbers -> Vec<f64>.
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()
            .map(|xs| xs.iter().filter_map(Json::as_f64).collect::<Vec<_>>())
            .filter(|v| Some(v.len()) == self.as_arr().map(<[Json]>::len))
    }

    /// Array of numbers -> Vec<usize>.
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_f64_vec()
            .map(|v| v.into_iter().map(|x| x as usize).collect())
    }

    /// Object as map (for iteration convenience).
    pub fn as_map(&self) -> Option<BTreeMap<&str, &Json>> {
        match self {
            Json::Obj(fields) => {
                Some(fields.iter().map(|(k, v)| (k.as_str(), v)).collect())
            }
            _ => None,
        }
    }

    // ---------------------------------------------------------------
    // Writing
    // ---------------------------------------------------------------

    /// Compact serialization.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    x.write(out, indent, depth + 1);
                }
                if !xs.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !fields.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience object builder.
pub fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Convenience number array.
pub fn arr_f64(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(xs));
        }
        loop {
            self.skip_ws();
            xs.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(xs));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("bad \\u escape"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            // BMP only (sufficient for our documents).
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 scalar
                    let start = self.pos;
                    let text = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = text.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let doc = r#"{"a": [1, 2, {"b": null}], "c": {"d": true}}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().get("d"), Some(&Json::Bool(true)));
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let v = Json::parse(r#""a\nb\t\"q\" A é""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\" A é");
    }

    #[test]
    fn parse_empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(vec![]));
        assert_eq!(Json::parse(" [ ] ").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("[1] x").is_err());
        assert!(Json::parse("{'a': 1}").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let doc = r#"{"name":"seg0_w050_b4.hlo.txt","shape":[4,32,32,3],"w":0.5,"ok":true,"n":null}"#;
        let v = Json::parse(doc).unwrap();
        let compact = v.to_string_compact();
        assert_eq!(Json::parse(&compact).unwrap(), v);
        let pretty = v.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn escapes_roundtrip_through_writer() {
        let v = Json::Str("line1\nline2\t\"x\" \\ \u{1}".into());
        let s = v.to_string_compact();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"xs":[1,2,3],"s":"hi","n":7}"#).unwrap();
        assert_eq!(v.get("xs").unwrap().as_usize_vec().unwrap(), vec![1, 2, 3]);
        assert_eq!(v.get("s").unwrap().as_str().unwrap(), "hi");
        assert_eq!(v.get("n").unwrap().as_i64().unwrap(), 7);
        assert!(v.get("missing").is_none());
        assert!(v.req("missing").is_err());
        // heterogeneous array is not a number vec
        let bad = Json::parse(r#"[1,"x"]"#).unwrap();
        assert!(bad.as_f64_vec().is_none());
    }

    #[test]
    fn integers_print_without_decimal_point() {
        assert_eq!(Json::Num(5.0).to_string_compact(), "5");
        assert_eq!(Json::Num(5.25).to_string_compact(), "5.25");
    }

    #[test]
    fn parses_real_manifest_shape() {
        // mirror of the aot.py manifest structure
        let doc = r#"{
            "version": 1,
            "model": {"base_channels": [32,64,128,256], "widths": [0.25,0.5,0.75,1.0]},
            "artifacts": [{"file": "seg0_w025_b1.hlo.txt", "segment": 0,
                           "width": 0.25, "batch": 1,
                           "input_shape": [1,32,32,3], "params": ["s0.stem.w"]}]
        }"#;
        let v = Json::parse(doc).unwrap();
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("segment").unwrap().as_usize().unwrap(), 0);
        assert_eq!(
            arts[0].get("width").unwrap().as_f64().unwrap(),
            0.25
        );
    }
}
