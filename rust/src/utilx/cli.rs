//! Tiny declarative CLI parser (clap substitute).
//!
//! Supports `subcommand --flag value --flag=value --bool-flag` plus
//! positional arguments, typed getters with defaults, and `--help`
//! generation from registered flag descriptions.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First non-flag token (if any).
    pub subcommand: Option<String>,
    flags: BTreeMap<String, String>,
    positionals: Vec<String>,
    /// (name, description) pairs registered for --help output.
    registered: Vec<(String, String)>,
}

impl Args {
    /// Parse from an explicit token list (first token = argv[1]).
    pub fn parse_from<I: IntoIterator<Item = String>>(tokens: I) -> Args {
        let mut args = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|next| !next.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    args.flags.insert(stripped.to_string(), v);
                } else {
                    args.flags.insert(stripped.to_string(), "true".to_string());
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(tok);
            } else {
                args.positionals.push(tok);
            }
        }
        args
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Self::parse_from(std::env::args().skip(1))
    }

    /// Register a flag for help output; returns self for chaining.
    pub fn describe(mut self, name: &str, desc: &str) -> Self {
        self.registered.push((name.to_string(), desc.to_string()));
        self
    }

    /// Raw string flag.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    /// String flag with default.
    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    /// f64 flag with default; panics with a clear message on bad value.
    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        match self.get(name) {
            None => default,
            Some(v) => v
                .parse()
                .unwrap_or_else(|_| panic!("--{name} expects a number, got {v:?}")),
        }
    }

    /// usize flag with default.
    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        match self.get(name) {
            None => default,
            Some(v) => v
                .parse()
                .unwrap_or_else(|_| panic!("--{name} expects an integer, got {v:?}")),
        }
    }

    /// u64 flag with default.
    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        match self.get(name) {
            None => default,
            Some(v) => v
                .parse()
                .unwrap_or_else(|_| panic!("--{name} expects an integer, got {v:?}")),
        }
    }

    /// Boolean flag: present (or =true) => true.
    pub fn flag(&self, name: &str) -> bool {
        matches!(self.get(name), Some("true") | Some("1") | Some("yes"))
    }

    /// Comma-separated f64 list.
    pub fn f64_list_or(&self, name: &str, default: &[f64]) -> Vec<f64> {
        match self.get(name) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.trim()
                        .parse()
                        .unwrap_or_else(|_| panic!("--{name}: bad number {s:?}"))
                })
                .collect(),
        }
    }

    /// Positional arguments (after the subcommand).
    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }

    /// Whether --help was requested.
    pub fn wants_help(&self) -> bool {
        self.flag("help")
    }

    /// Render registered flag help.
    pub fn help_text(&self, usage: &str) -> String {
        let mut s = format!("usage: {usage}\n\nflags:\n");
        for (name, desc) in &self.registered {
            s.push_str(&format!("  --{name:<24} {desc}\n"));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse_from(toks.iter().map(|s| s.to_string()))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse(&["simulate", "--router", "ppo", "--steps=500", "--verbose"]);
        assert_eq!(a.subcommand.as_deref(), Some("simulate"));
        assert_eq!(a.get("router"), Some("ppo"));
        assert_eq!(a.usize_or("steps", 0), 500);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults() {
        let a = parse(&["serve"]);
        assert_eq!(a.f64_or("rate", 2.5), 2.5);
        assert_eq!(a.str_or("dir", "artifacts"), "artifacts");
        assert_eq!(a.u64_or("seed", 42), 42);
    }

    #[test]
    fn equals_and_space_forms_agree() {
        let a = parse(&["x", "--k=v"]);
        let b = parse(&["x", "--k", "v"]);
        assert_eq!(a.get("k"), b.get("k"));
    }

    #[test]
    fn positionals_collected() {
        let a = parse(&["run", "one", "--f", "2", "two"]);
        assert_eq!(a.positionals(), &["one".to_string(), "two".to_string()]);
    }

    #[test]
    fn f64_list() {
        let a = parse(&["x", "--widths", "0.25,0.5,1.0"]);
        assert_eq!(a.f64_list_or("widths", &[]), vec![0.25, 0.5, 1.0]);
        assert_eq!(a.f64_list_or("other", &[9.0]), vec![9.0]);
    }

    #[test]
    fn negative_number_as_flag_value() {
        // "--bias -3" : "-3" does not start with "--" so it is a value
        let a = parse(&["x", "--bias", "-3.5"]);
        assert_eq!(a.f64_or("bias", 0.0), -3.5);
    }

    #[test]
    #[should_panic(expected = "expects a number")]
    fn bad_number_panics() {
        let a = parse(&["x", "--rate", "abc"]);
        a.f64_or("rate", 0.0);
    }

    #[test]
    fn help_text_lists_registered() {
        let a = parse(&["x", "--help"]).describe("rate", "arrival rate");
        assert!(a.wants_help());
        let h = a.help_text("repro simulate [flags]");
        assert!(h.contains("--rate"));
        assert!(h.contains("arrival rate"));
    }
}
