//! Mini property-testing driver (proptest substitute).
//!
//! `check` runs a property over `cases` seeded inputs; on failure it
//! reports the failing case index and seed so the case can be replayed
//! deterministically (`PROP_SEED` env var re-runs a single seed). Shrinking
//! is intentionally out of scope — failures print the generated scenario,
//! which for our domains (request traces, width tuples, telemetry vectors)
//! is already small and readable.

use super::rng::Rng;

/// Outcome of a single property case.
pub type CaseResult = Result<(), String>;

/// Run `prop` over `cases` generated cases. Panics (test failure) on the
/// first counterexample with its replay seed.
pub fn check<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Rng) -> CaseResult,
{
    // Replay mode: PROP_SEED=<n> runs exactly one case.
    if let Ok(seed_text) = std::env::var("PROP_SEED") {
        if let Ok(seed) = seed_text.parse::<u64>() {
            let mut rng = Rng::new(seed);
            if let Err(msg) = prop(&mut rng) {
                panic!("property '{name}' failed on replay seed {seed}: {msg}");
            }
            return;
        }
    }
    let base = 0x5eed_0000u64;
    for case in 0..cases {
        let seed = base + case as u64;
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed (case {case}/{cases}, replay with \
                 PROP_SEED={seed}): {msg}"
            );
        }
    }
}

/// Assert-style helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("trivial", 25, |rng| {
            count += 1;
            let x = rng.f64();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("x out of range: {x}"))
            }
        });
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_seed() {
        check("always-fails", 5, |_rng| Err("nope".to_string()));
    }

    #[test]
    fn prop_assert_macro_returns_err() {
        fn body(flag: bool) -> CaseResult {
            prop_assert!(flag, "flag was {}", flag);
            Ok(())
        }
        assert!(body(true).is_ok());
        assert_eq!(body(false).unwrap_err(), "flag was false");
    }
}
