//! PCG64-based pseudo-random number generator + distributions.
//!
//! `rand` is not in the offline crate cache, so the simulator, workload
//! generators, PPO initialization and exploration all draw from this
//! self-contained implementation. PCG-XSH-RR 64/32 core, extended to 64-bit
//! output by pairing two draws; deterministic given a seed, `Send`, cheap
//! to fork into independent streams (`split`).

/// Deterministic PRNG (PCG-XSH-RR) with distribution helpers.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
    inc: u64,
    /// Cached second normal from Box-Muller.
    spare_normal: Option<f64>,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Rng {
    /// Create a generator from a seed; `stream` selects an independent
    /// sequence (useful to decorrelate per-server/per-worker RNGs).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Rng { state: 0, inc: (stream << 1) | 1, spare_normal: None };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Seed-only constructor (stream 0xda3e39cb94b95bdb).
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e39cb94b95bdb)
    }

    /// Fork an independent child stream keyed by `tag`.
    pub fn split(&mut self, tag: u64) -> Rng {
        let seed = self.next_u64() ^ tag.wrapping_mul(0x9e3779b97f4a7c15);
        Rng::with_stream(seed, tag.wrapping_add(1))
    }

    /// Core PCG step: uniform u32.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Uniform u64.
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [0, n).
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (with spare caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u = self.f64();
            if u <= f64::EPSILON {
                continue;
            }
            let v = self.f64();
            let r = (-2.0 * u.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * v;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Normal with given mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with rate `lambda` (mean 1/lambda).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        let mut u = self.f64();
        if u <= f64::EPSILON {
            u = f64::EPSILON;
        }
        -u.ln() / lambda
    }

    /// Poisson(lambda): Knuth product method for small lambda, normal
    /// approximation (rounded, clamped at 0) above 30 — plenty for
    /// request-arrival counts.
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        debug_assert!(lambda >= 0.0);
        if lambda <= 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let z = self.normal_ms(lambda, lambda.sqrt());
            if z < 0.0 {
                0
            } else {
                z.round() as u64
            }
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0, "categorical needs positive mass");
        let mut target = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            target -= w;
            if target < 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Uniformly pick a reference from a non-empty slice.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut rng = Rng::new(3);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut rng = Rng::new(4);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[rng.below(5) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(5);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = rng.normal();
            s += z;
            s2 += z * z;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Rng::new(6);
        let n = 50_000;
        let lambda = 4.0;
        let mean: f64 = (0..n).map(|_| rng.exponential(lambda)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn poisson_small_and_large_lambda() {
        let mut rng = Rng::new(7);
        for &lambda in &[0.5, 3.0, 12.0, 80.0] {
            let n = 20_000;
            let mean: f64 =
                (0..n).map(|_| rng.poisson(lambda) as f64).sum::<f64>() / n as f64;
            assert!(
                (mean - lambda).abs() < lambda.max(1.0) * 0.05,
                "lambda={lambda} mean={mean}"
            );
        }
    }

    #[test]
    fn poisson_zero() {
        let mut rng = Rng::new(8);
        assert_eq!(rng.poisson(0.0), 0);
    }

    #[test]
    fn categorical_respects_weights() {
        let mut rng = Rng::new(9);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[rng.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio={ratio}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(10);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn split_streams_are_independent() {
        let mut root = Rng::new(11);
        let mut a = root.split(1);
        let mut b = root.split(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn range_i64_bounds() {
        let mut rng = Rng::new(12);
        for _ in 0..1000 {
            let x = rng.range_i64(-3, 3);
            assert!((-3..=3).contains(&x));
        }
    }
}
