//! Dependency-free substrates.
//!
//! The build environment is fully offline and its crate cache carries only
//! `xla` + `anyhow`; everything a serving framework usually pulls from the
//! ecosystem (rand, serde_json, clap, proptest) is implemented here from
//! scratch, with its own unit tests (DESIGN.md §2, dependency
//! substitutions).

pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;

pub use cli::Args;
pub use json::Json;
pub use rng::Rng;
