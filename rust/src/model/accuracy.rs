//! Width-tuple accuracy prior (eq. 7's `p~_acc`).
//!
//! The PPO reward couples an *empirical accuracy prior looked up from a
//! width-combination table* with latency/energy/imbalance costs. The paper
//! publishes eight measured points (Tables I and II); we use them verbatim
//! and fill the remaining 4^4 − 8 tuples with an additive model fitted to
//! those points by least squares (residual < 0.15 pp on every published
//! tuple):
//!
//!   acc(w1..w4) = A_min + (A_max − A_min) · Σ_s λ_s · u(w_s)
//!
//! where `u` is the normalized uniform-width curve from Table I and λ the
//! per-segment importance (later segments dominate — exactly Table II's
//! signal). Unknown off-grid widths fall back to nearest-neighbour on the
//! width set, mirroring the paper's "nearest-neighbor fallback".
//!
//! This substitution (published table instead of re-training on CIFAR-100,
//! which is unavailable in the offline environment) is documented in
//! DESIGN.md §Hardware-Adaptation.

use super::WIDTHS;

/// Table I: Top-1 accuracy (%) under uniform width ratios.
pub const UNIFORM_ACC: [(f64, f64); 4] = [
    (0.25, 70.30),
    (0.50, 72.99),
    (0.75, 74.93),
    (1.00, 76.43),
];

/// Table II: Top-1 accuracy (%) under the four published mixed tuples.
pub const MIXED_ACC: [([f64; 4], f64); 4] = [
    ([1.00, 0.75, 0.50, 0.25], 71.35),
    ([0.75, 1.00, 0.25, 0.50], 72.33),
    ([0.50, 0.25, 1.00, 0.75], 74.53),
    ([0.25, 0.50, 0.75, 1.00], 75.33),
];

/// Least-squares per-segment importance λ (fitted offline from the eight
/// published points with a Σλ=1 soft constraint; see module docs).
const LAMBDA: [f64; 4] = [-0.02110884, 0.11567141, 0.28616053, 0.57420129];

const A_MIN: f64 = 70.30;
const A_MAX: f64 = 76.43;

/// Accuracy prior lookup with nearest-neighbour fallback.
#[derive(Clone, Debug, Default)]
pub struct AccuracyPrior;

fn snap(w: f64) -> f64 {
    // nearest width in W (the paper's nearest-neighbor fallback)
    let mut best = WIDTHS[0];
    let mut dist = f64::INFINITY;
    for &cand in &WIDTHS {
        let d = (cand - w).abs();
        if d < dist {
            dist = d;
            best = cand;
        }
    }
    best
}

/// Normalized uniform-width accuracy u(w) in [0,1] (from Table I).
fn u(w: f64) -> f64 {
    let w = snap(w);
    for &(wi, acc) in &UNIFORM_ACC {
        if (wi - w).abs() < 1e-9 {
            return (acc - A_MIN) / (A_MAX - A_MIN);
        }
    }
    unreachable!("snap always lands on the width set")
}

impl AccuracyPrior {
    pub fn new() -> Self {
        AccuracyPrior
    }

    /// Top-1 accuracy (%) prior for a 4-segment width tuple.
    pub fn lookup(&self, widths: &[f64; 4]) -> f64 {
        let snapped = [snap(widths[0]), snap(widths[1]), snap(widths[2]), snap(widths[3])];
        // exact published points first
        if snapped.iter().skip(1).all(|&w| (w - snapped[0]).abs() < 1e-9) {
            for &(w, acc) in &UNIFORM_ACC {
                if (w - snapped[0]).abs() < 1e-9 {
                    return acc;
                }
            }
        }
        for &(tuple, acc) in &MIXED_ACC {
            if tuple
                .iter()
                .zip(&snapped)
                .all(|(a, b)| (a - b).abs() < 1e-9)
            {
                return acc;
            }
        }
        // additive model for the remaining tuples
        let score: f64 = snapped.iter().zip(&LAMBDA).map(|(&w, &l)| l * u(w)).sum();
        (A_MIN + (A_MAX - A_MIN) * score).clamp(A_MIN - 1.0, A_MAX)
    }

    /// The prior normalized to [0,1] (what the reward consumes before the
    /// optional zero-mean centering).
    pub fn normalized(&self, widths: &[f64; 4]) -> f64 {
        (self.lookup(widths) - A_MIN) / (A_MAX - A_MIN)
    }

    /// Mean top-1 across all 4^4 snapped tuples — used as `p̄_top-1` for
    /// the optional zero-mean centering in eq. 7.
    pub fn mean_top1(&self) -> f64 {
        let mut total = 0.0;
        let mut n = 0usize;
        for &w1 in &WIDTHS {
            for &w2 in &WIDTHS {
                for &w3 in &WIDTHS {
                    for &w4 in &WIDTHS {
                        total += self.lookup(&[w1, w2, w3, w4]);
                        n += 1;
                    }
                }
            }
        }
        total / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_exact() {
        let p = AccuracyPrior::new();
        for &(w, acc) in &UNIFORM_ACC {
            assert_eq!(p.lookup(&[w, w, w, w]), acc);
        }
    }

    #[test]
    fn table2_exact() {
        let p = AccuracyPrior::new();
        for &(tuple, acc) in &MIXED_ACC {
            assert_eq!(p.lookup(&tuple), acc);
        }
    }

    #[test]
    fn later_segments_matter_more() {
        // Table II's central observation: widening the LAST segment buys
        // more accuracy than widening the first.
        let p = AccuracyPrior::new();
        let wide_last = p.lookup(&[0.25, 0.25, 0.25, 1.00]);
        let wide_first = p.lookup(&[1.00, 0.25, 0.25, 0.25]);
        assert!(wide_last > wide_first + 1.0, "{wide_last} vs {wide_first}");
    }

    #[test]
    fn bounded_by_min_max() {
        let p = AccuracyPrior::new();
        for &w1 in &WIDTHS {
            for &w2 in &WIDTHS {
                for &w3 in &WIDTHS {
                    for &w4 in &WIDTHS {
                        let acc = p.lookup(&[w1, w2, w3, w4]);
                        assert!((A_MIN - 1.0..=A_MAX).contains(&acc), "{acc}");
                    }
                }
            }
        }
    }

    #[test]
    fn nearest_neighbour_fallback_for_offgrid_widths() {
        let p = AccuracyPrior::new();
        assert_eq!(p.lookup(&[0.3, 0.3, 0.3, 0.3]), p.lookup(&[0.25; 4]));
        assert_eq!(p.lookup(&[0.9, 1.0, 1.0, 1.0]), p.lookup(&[1.0; 4]));
    }

    #[test]
    fn normalized_range() {
        let p = AccuracyPrior::new();
        assert_eq!(p.normalized(&[0.25; 4]), 0.0);
        assert_eq!(p.normalized(&[1.0; 4]), 1.0);
        let mid = p.normalized(&[0.5; 4]);
        assert!(mid > 0.0 && mid < 1.0);
    }

    #[test]
    fn mean_top1_between_extremes() {
        let p = AccuracyPrior::new();
        let mean = p.mean_top1();
        assert!(mean > A_MIN && mean < A_MAX, "{mean}");
    }

    #[test]
    fn monotone_in_every_coordinate_under_the_additive_model() {
        let p = AccuracyPrior::new();
        // skip exact-table points by using tuples the tables don't publish
        for s in 1..4 {
            // (widening any later segment should not hurt)
            let mut lo = [0.5, 0.25, 0.5, 0.75];
            let mut hi = lo;
            lo[s] = 0.25;
            hi[s] = 1.0;
            assert!(
                p.lookup(&hi) >= p.lookup(&lo),
                "seg {s}: {:?} vs {:?}",
                p.lookup(&hi),
                p.lookup(&lo)
            );
        }
    }
}
