//! SlimResNet metadata: segment shapes, the FLOP/VRAM cost model the
//! simulator charges, and the width-tuple accuracy prior (paper
//! Tables I–II). The formulas mirror `python/compile/model.py` exactly —
//! an integration test cross-checks them against the AOT manifest.

pub mod accuracy;

pub use accuracy::AccuracyPrior;

/// Number of backbone segments (paper: 4).
pub const NUM_SEGMENTS: usize = 4;

/// The slimming width set W.
pub const WIDTHS: [f64; 4] = [0.25, 0.50, 0.75, 1.00];

/// Static description of the exported SlimResNet.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelMeta {
    pub img: usize,
    pub in_ch: usize,
    pub num_classes: usize,
    pub base_channels: [usize; NUM_SEGMENTS],
    pub widths: Vec<f64>,
}

impl Default for ModelMeta {
    /// The paper-scale CIFAR backbone (matches `make_config("full")`).
    fn default() -> Self {
        ModelMeta {
            img: 32,
            in_ch: 3,
            num_classes: 100,
            base_channels: [32, 64, 128, 256],
            widths: WIDTHS.to_vec(),
        }
    }
}

/// Active channels for a width ratio (ceil, same as python's c_active).
pub fn c_active(c: usize, width: f64) -> usize {
    (c as f64 * width).ceil() as usize
}

impl ModelMeta {
    /// Spatial resolution of segment `seg`'s *output*.
    pub fn seg_resolution(&self, seg: usize) -> usize {
        if seg == 0 {
            self.img
        } else {
            self.img >> seg
        }
    }

    /// (input_shape, output_shape) of a segment at batch `b` (full-size
    /// interface tensors — width does not change shapes).
    pub fn seg_io_shapes(&self, seg: usize, b: usize) -> (Vec<usize>, Vec<usize>) {
        assert!(seg < NUM_SEGMENTS);
        let input = if seg == 0 {
            vec![b, self.img, self.img, self.in_ch]
        } else {
            let r = self.seg_resolution(seg - 1);
            vec![b, r, r, self.base_channels[seg - 1]]
        };
        let output = if seg == NUM_SEGMENTS - 1 {
            vec![b, self.num_classes]
        } else {
            let r = self.seg_resolution(seg);
            vec![b, r, r, self.base_channels[seg]]
        };
        (input, output)
    }

    /// Semantic FLOPs of one segment at (width, w_prev, batch) — the cost
    /// the device simulator charges (mirrors python `segment_flops`).
    pub fn seg_flops(&self, seg: usize, width: f64, w_prev: f64, b: usize) -> u64 {
        assert!(seg < NUM_SEGMENTS);
        let res_out = self.seg_resolution(seg);
        let c = self.base_channels[seg];
        let c_act = c_active(c, width);
        let c_in = if seg == 0 {
            self.in_ch
        } else {
            c_active(self.base_channels[seg - 1], w_prev)
        };
        let conv =
            |ho: usize, wo: usize, k: usize, ci: usize, co: usize| -> u64 {
                2 * (b * ho * wo * k * k * ci * co) as u64
            };
        let mut total = conv(res_out, res_out, 3, c_in, c_act);
        total += 2 * conv(res_out, res_out, 3, c_act, c_act);
        total += (10 * 4 * b * res_out * res_out * c_act) as u64;
        if seg == NUM_SEGMENTS - 1 {
            total += 2 * (b * c_act * self.num_classes) as u64;
        }
        total
    }

    /// f32 bytes of the full weight tensors of one segment — what an
    /// instance pins in VRAM (mirrors python `segment_weight_bytes`).
    pub fn seg_weight_bytes(&self, seg: usize) -> u64 {
        assert!(seg < NUM_SEGMENTS);
        let c = self.base_channels[seg];
        let c_in = if seg == 0 { self.in_ch } else { self.base_channels[seg - 1] };
        let mut floats = 3 * 3 * c_in * c; // stem/down conv
        floats += 2 * (3 * 3 * c * c); // block convs
        floats += 6 * c; // three GN (gamma, beta) pairs
        if seg == NUM_SEGMENTS - 1 {
            floats += c * self.num_classes + self.num_classes;
        }
        4 * floats as u64
    }

    /// Peak f32 activation working set (input + 2×output), mirrors python
    /// `segment_activation_bytes`.
    pub fn seg_activation_bytes(&self, seg: usize, b: usize) -> u64 {
        let (inp, out) = self.seg_io_shapes(seg, b);
        let p = |v: &[usize]| v.iter().product::<usize>() as u64;
        4 * (p(&inp) + 2 * p(&out))
    }

    /// VRAM an instance of (seg, batch) pins: weights + activations.
    pub fn instance_vram_bytes(&self, seg: usize, b: usize) -> u64 {
        self.seg_weight_bytes(seg) + self.seg_activation_bytes(seg, b)
    }

    /// *Semantic* VRAM of a slimmed instance — what a real deployment
    /// would pin: conv weights scale ~w² (both channel dims sliced),
    /// activations ~w (channel slice). The simulator's CANLOAD budget and
    /// the Fig 1 memory-utilization curves charge this; the CPU serving
    /// path pins full-size buffers (interface convention, DESIGN.md §2).
    pub fn instance_vram_semantic(&self, seg: usize, width: f64, b: usize) -> u64 {
        let w2 = (width * width).max(1e-6);
        (self.seg_weight_bytes(seg) as f64 * w2
            + self.seg_activation_bytes(seg, b) as f64 * width) as u64
    }

    /// HBM/VRAM traffic of one segment execution (weights + in + out once),
    /// for the roofline latency term.
    pub fn seg_mem_bytes(&self, seg: usize, b: usize) -> u64 {
        let (inp, out) = self.seg_io_shapes(seg, b);
        let p = |v: &[usize]| v.iter().product::<usize>() as u64;
        self.seg_weight_bytes(seg) + 4 * (p(&inp) + p(&out))
    }

    /// Nearest width in the width set (>= requested if possible — the
    /// greedy best-fit semantics).
    pub fn snap_width_up(&self, w_req: f64) -> f64 {
        let mut best: Option<f64> = None;
        for &w in &self.widths {
            if w >= w_req - 1e-9 {
                best = Some(best.map_or(w, |b: f64| b.min(w)));
            }
        }
        best.unwrap_or_else(|| {
            self.widths.iter().cloned().fold(0.0, f64::max)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_match_the_python_contract() {
        let m = ModelMeta::default();
        assert_eq!(m.seg_io_shapes(0, 4), (vec![4, 32, 32, 3], vec![4, 32, 32, 32]));
        assert_eq!(m.seg_io_shapes(1, 1), (vec![1, 32, 32, 32], vec![1, 16, 16, 64]));
        assert_eq!(m.seg_io_shapes(2, 2), (vec![2, 16, 16, 64], vec![2, 8, 8, 128]));
        assert_eq!(m.seg_io_shapes(3, 1), (vec![1, 8, 8, 128], vec![1, 100]));
    }

    #[test]
    fn c_active_matches_width_set() {
        assert_eq!(c_active(32, 0.25), 8);
        assert_eq!(c_active(32, 0.5), 16);
        assert_eq!(c_active(256, 0.75), 192);
        assert_eq!(c_active(256, 1.0), 256);
    }

    #[test]
    fn flops_monotone_in_width_and_wprev() {
        let m = ModelMeta::default();
        for seg in 0..NUM_SEGMENTS {
            let f: Vec<u64> =
                WIDTHS.iter().map(|&w| m.seg_flops(seg, w, 1.0, 8)).collect();
            assert!(f.windows(2).all(|p| p[0] < p[1]), "seg{seg}: {f:?}");
        }
        for seg in 1..NUM_SEGMENTS {
            let f: Vec<u64> =
                WIDTHS.iter().map(|&wp| m.seg_flops(seg, 0.5, wp, 8)).collect();
            assert!(f.windows(2).all(|p| p[0] < p[1]), "seg{seg}: {f:?}");
        }
    }

    #[test]
    fn flops_linear_in_batch() {
        let m = ModelMeta::default();
        assert_eq!(
            2 * m.seg_flops(1, 0.5, 0.5, 4),
            m.seg_flops(1, 0.5, 0.5, 8)
        );
    }

    #[test]
    fn weight_bytes_reasonable() {
        let m = ModelMeta::default();
        // seg3 is the heaviest (two 256-channel convs + fc)
        let w: Vec<u64> = (0..4).map(|s| m.seg_weight_bytes(s)).collect();
        assert!(w[3] > w[2] && w[2] > w[1] && w[1] > w[0], "{w:?}");
        // full model a few MB, not KB, not GB
        let total: u64 = w.iter().sum();
        assert!(total > 1 << 20 && total < 64 << 20, "{total}");
    }

    #[test]
    fn vram_grows_with_batch() {
        let m = ModelMeta::default();
        assert!(m.instance_vram_bytes(0, 16) > m.instance_vram_bytes(0, 1));
    }

    #[test]
    fn semantic_vram_monotone_in_width_and_below_full() {
        let m = ModelMeta::default();
        for seg in 0..NUM_SEGMENTS {
            let v: Vec<u64> = WIDTHS
                .iter()
                .map(|&w| m.instance_vram_semantic(seg, w, 8))
                .collect();
            assert!(v.windows(2).all(|p| p[0] < p[1]), "seg{seg}: {v:?}");
            assert!(v[3] <= m.instance_vram_bytes(seg, 8));
            // quarter-width conv weights are ~16x smaller
            assert!(v[0] < v[3] / 3, "seg{seg}: {v:?}");
        }
    }

    #[test]
    fn snap_width_up_best_fit() {
        let m = ModelMeta::default();
        assert_eq!(m.snap_width_up(0.25), 0.25);
        assert_eq!(m.snap_width_up(0.3), 0.5);
        assert_eq!(m.snap_width_up(0.75), 0.75);
        assert_eq!(m.snap_width_up(0.9), 1.0);
        // over the max snaps down to max (serve with the widest model)
        assert_eq!(m.snap_width_up(1.5), 1.0);
    }
}
