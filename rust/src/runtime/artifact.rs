//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust serving path. Parses `manifest.json`, loads `weights.bin`, and
//! resolves the best artifact for a requested `(segment, width, batch)`.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context};

use crate::coordinator::wkey;
use crate::utilx::Json;

/// One exported HLO artifact.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub file: String,
    pub segment: usize,
    pub width: f64,
    pub batch: usize,
    pub input_shape: Vec<usize>,
    pub output_shape: Vec<usize>,
    /// Ordered parameter tensor names (after the activation input).
    pub params: Vec<String>,
}

/// One golden (input, output) pair for cross-language validation.
#[derive(Clone, Debug)]
pub struct GoldenMeta {
    pub segment: usize,
    pub width: f64,
    pub batch: usize,
    pub artifact: String,
    pub input_file: String,
    pub input_shape: Vec<usize>,
    pub output_file: String,
    pub output_shape: Vec<usize>,
}

/// A named weight tensor inside weights.bin.
#[derive(Clone, Debug)]
pub struct WeightMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub bytes: usize,
}

/// Parsed manifest + loaded weights.
#[derive(Clone, Debug)]
pub struct ArtifactIndex {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactMeta>,
    pub goldens: Vec<GoldenMeta>,
    pub weights: Vec<WeightMeta>,
    pub weight_data: Vec<f32>,
    pub batches: Vec<usize>,
    pub widths: Vec<f64>,
    pub num_segments: usize,
    by_key: HashMap<(usize, u16, usize), usize>,
}

fn usize_vec(j: &Json, key: &str) -> anyhow::Result<Vec<usize>> {
    j.get(key)
        .and_then(Json::as_usize_vec)
        .ok_or_else(|| anyhow!("manifest: bad '{key}'"))
}

impl ArtifactIndex {
    /// Load manifest.json + weights.bin from an artifacts directory.
    pub fn load(dir: &str) -> anyhow::Result<Self> {
        let dir = PathBuf::from(dir);
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let json = Json::parse(&text).map_err(|e| anyhow!("manifest: {e}"))?;

        let model = json.req("model").map_err(|e| anyhow!("{e}"))?;
        let widths = model
            .get("widths")
            .and_then(Json::as_f64_vec)
            .ok_or_else(|| anyhow!("manifest: bad model.widths"))?;
        let batches = usize_vec(&json, "batches")?;
        let num_segments = json
            .get("segments")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("manifest: bad segments"))?;

        let mut artifacts = Vec::new();
        for a in json
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest: bad artifacts"))?
        {
            artifacts.push(ArtifactMeta {
                file: a
                    .get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("artifact: bad file"))?
                    .to_string(),
                segment: a
                    .get("segment")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("artifact: bad segment"))?,
                width: a
                    .get("width")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| anyhow!("artifact: bad width"))?,
                batch: a
                    .get("batch")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("artifact: bad batch"))?,
                input_shape: usize_vec(a, "input_shape")?,
                output_shape: usize_vec(a, "output_shape")?,
                params: a
                    .get("params")
                    .and_then(Json::as_arr)
                    .map(|xs| {
                        xs.iter()
                            .filter_map(Json::as_str)
                            .map(str::to_string)
                            .collect()
                    })
                    .ok_or_else(|| anyhow!("artifact: bad params"))?,
            });
        }

        let mut goldens = Vec::new();
        if let Some(gs) = json.get("goldens").and_then(Json::as_arr) {
            for g in gs {
                goldens.push(GoldenMeta {
                    segment: g.get("segment").and_then(Json::as_usize).unwrap_or(0),
                    width: g.get("width").and_then(Json::as_f64).unwrap_or(1.0),
                    batch: g.get("batch").and_then(Json::as_usize).unwrap_or(1),
                    artifact: g
                        .get("artifact")
                        .and_then(Json::as_str)
                        .unwrap_or_default()
                        .to_string(),
                    input_file: g
                        .get("input_file")
                        .and_then(Json::as_str)
                        .unwrap_or_default()
                        .to_string(),
                    input_shape: usize_vec(g, "input_shape")?,
                    output_file: g
                        .get("output_file")
                        .and_then(Json::as_str)
                        .unwrap_or_default()
                        .to_string(),
                    output_shape: usize_vec(g, "output_shape")?,
                });
            }
        }

        // weights
        let weights_json = json.req("weights").map_err(|e| anyhow!("{e}"))?;
        let weights_file = weights_json
            .get("file")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("manifest: bad weights.file"))?;
        let mut weights = Vec::new();
        for t in weights_json
            .get("tensors")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest: bad weights.tensors"))?
        {
            weights.push(WeightMeta {
                name: t
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("weight: bad name"))?
                    .to_string(),
                shape: usize_vec(t, "shape")?,
                offset: t
                    .get("offset")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("weight: bad offset"))?,
                bytes: t
                    .get("bytes")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("weight: bad bytes"))?,
            });
        }
        let blob = std::fs::read(dir.join(weights_file))
            .with_context(|| format!("reading {weights_file}"))?;
        let expected: usize = weights.iter().map(|w| w.bytes).sum();
        if blob.len() != expected {
            bail!("weights.bin: {} bytes, manifest says {expected}", blob.len());
        }
        let weight_data: Vec<f32> = blob
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();

        let mut by_key = HashMap::new();
        for (i, a) in artifacts.iter().enumerate() {
            by_key.insert((a.segment, wkey(a.width), a.batch), i);
        }

        Ok(ArtifactIndex {
            dir,
            artifacts,
            goldens,
            weights,
            weight_data,
            batches,
            widths,
            num_segments,
            by_key,
        })
    }

    /// Exact lookup.
    pub fn find(&self, seg: usize, width: f64, batch: usize) -> Option<&ArtifactMeta> {
        self.by_key
            .get(&(seg, wkey(width), batch))
            .map(|&i| &self.artifacts[i])
    }

    /// Smallest exported batch ≥ `n` (requests are padded up to it); falls
    /// back to the largest exported batch (caller splits).
    pub fn best_batch(&self, n: usize) -> usize {
        let mut sorted = self.batches.clone();
        sorted.sort_unstable();
        for &b in &sorted {
            if b >= n {
                return b;
            }
        }
        *sorted.last().unwrap_or(&1)
    }

    /// Absolute path of an artifact file.
    pub fn path_of(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }

    /// View of one weight tensor's f32 data.
    pub fn weight_slice(&self, name: &str) -> Option<&[f32]> {
        let w = self.weights.iter().find(|w| w.name == name)?;
        let start = w.offset / 4;
        Some(&self.weight_data[start..start + w.bytes / 4])
    }

    /// Shape of one weight tensor.
    pub fn weight_shape(&self, name: &str) -> Option<&[usize]> {
        self.weights
            .iter()
            .find(|w| w.name == name)
            .map(|w| w.shape.as_slice())
    }
}

/// Convenience: does an artifacts directory look complete?
pub fn artifacts_available(dir: &str) -> bool {
    Path::new(dir).join("manifest.json").exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    const DIR: &str = "artifacts";

    fn index() -> Option<ArtifactIndex> {
        if !artifacts_available(DIR) {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        Some(ArtifactIndex::load(DIR).expect("manifest parses"))
    }

    #[test]
    fn manifest_loads_with_full_grid() {
        let Some(idx) = index() else { return };
        assert_eq!(idx.num_segments, 4);
        assert_eq!(idx.widths, vec![0.25, 0.5, 0.75, 1.0]);
        assert_eq!(
            idx.artifacts.len(),
            idx.num_segments * idx.widths.len() * idx.batches.len()
        );
        // every artifact file exists on disk
        for a in &idx.artifacts {
            assert!(idx.path_of(&a.file).exists(), "{}", a.file);
        }
        assert!(!idx.goldens.is_empty());
    }

    #[test]
    fn lookup_and_best_batch() {
        let Some(idx) = index() else { return };
        let a = idx.find(0, 0.5, 1).expect("seg0 w050 b1");
        assert_eq!(a.segment, 0);
        assert_eq!(a.input_shape[0], 1);
        assert!(idx.find(0, 0.33, 1).is_none());
        assert_eq!(idx.best_batch(1), 1);
        assert_eq!(idx.best_batch(2), 4);
        assert_eq!(idx.best_batch(5), 16);
        assert_eq!(idx.best_batch(99), 16); // clamps to max
    }

    #[test]
    fn weights_roundtrip_gamma_ones() {
        let Some(idx) = index() else { return };
        // every GN gamma is initialized to 1.0 by python init_params
        let g = idx.weight_slice("s1.down.gn.g").expect("gamma tensor");
        assert!(!g.is_empty());
        assert!(g.iter().all(|&x| x == 1.0));
        let shape = idx.weight_shape("s0.stem.w").expect("stem");
        assert_eq!(shape, &[3, 3, 3, 32]);
    }

    #[test]
    fn artifact_params_resolve_to_weights() {
        let Some(idx) = index() else { return };
        for a in &idx.artifacts {
            for p in &a.params {
                assert!(idx.weight_slice(p).is_some(), "missing weight {p}");
            }
        }
    }
}
