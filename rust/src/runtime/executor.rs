//! SegmentExecutor — execute one SlimResNet segment on the PJRT CPU
//! client. Resolves `(segment, width, batch)` to the exported artifact
//! (padding the batch up to the nearest exported size), marshals the
//! activation plus the segment's weight tensors into XLA literals, runs,
//! and slices the batch back down.

use anyhow::{anyhow, Context, Result};

use super::artifact::{ArtifactIndex, ArtifactMeta};
use super::pool::ExecutablePool;
use super::tensor::HostTensor;

/// Real-inference engine over the AOT artifacts.
pub struct SegmentExecutor {
    pub index: ArtifactIndex,
    pub pool: ExecutablePool,
    /// Cached weight literals per artifact file (built on first use).
    pub executions: u64,
}

fn literal_from_tensor(t: &HostTensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(&t.data)
        .reshape(&dims)
        .map_err(|e| anyhow!("reshape to {dims:?}: {e:?}"))
}

fn literal_from_slice(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow!("reshape to {dims:?}: {e:?}"))
}

impl SegmentExecutor {
    pub fn new(artifacts_dir: &str) -> Result<Self> {
        let index = ArtifactIndex::load(artifacts_dir)?;
        let pool = ExecutablePool::cpu()?;
        Ok(SegmentExecutor { index, pool, executions: 0 })
    }

    /// Pre-compile every artifact for the given widths (serving warm-up).
    pub fn warm_all(&mut self, widths: &[f64]) -> Result<usize> {
        let paths: Vec<String> = self
            .index
            .artifacts
            .iter()
            .filter(|a| widths.iter().any(|w| (w - a.width).abs() < 1e-9))
            .map(|a| self.index.path_of(&a.file).to_string_lossy().into_owned())
            .collect();
        self.pool.warm(&paths)
    }

    fn artifact_for(&self, seg: usize, width: f64, n: usize) -> Result<&ArtifactMeta> {
        let batch = self.index.best_batch(n);
        self.index
            .find(seg, width, batch)
            .ok_or_else(|| anyhow!("no artifact for seg{seg} w{width} b{batch}"))
    }

    /// Execute segment `seg` at `width` on a batch activation tensor.
    ///
    /// `x` is the full-interface NHWC input (batch, H, W, C_full) — or the
    /// image tensor for seg 0. Output is the next segment's input (or
    /// logits for seg 3), sliced back to the true batch size.
    pub fn execute(&mut self, seg: usize, width: f64, x: &HostTensor) -> Result<HostTensor> {
        let n = x.batch();
        if n == 0 {
            return Err(anyhow!("empty batch"));
        }
        let meta = self.artifact_for(seg, width, n)?.clone();
        if n > meta.batch {
            // split oversized batches and stitch outputs
            let first = x.slice_batch(meta.batch);
            let rest = {
                let row = x.numel() / n;
                let mut shape = x.shape.clone();
                shape[0] = n - meta.batch;
                HostTensor::from_vec(&shape, x.data[row * meta.batch..].to_vec())
            };
            let y1 = self.execute(seg, width, &first)?;
            let y2 = self.execute(seg, width, &rest)?;
            let mut shape = y1.shape.clone();
            shape[0] = n;
            let mut data = y1.data;
            data.extend_from_slice(&y2.data);
            return Ok(HostTensor::from_vec(&shape, data));
        }

        let padded = x.pad_batch(meta.batch);
        if padded.shape != meta.input_shape {
            return Err(anyhow!(
                "input shape {:?} != artifact {:?}",
                padded.shape,
                meta.input_shape
            ));
        }

        let mut literals = Vec::with_capacity(1 + meta.params.len());
        literals.push(literal_from_tensor(&padded)?);
        for name in &meta.params {
            let data = self
                .index
                .weight_slice(name)
                .ok_or_else(|| anyhow!("missing weight {name}"))?;
            let shape = self.index.weight_shape(name).unwrap().to_vec();
            literals.push(literal_from_slice(data, &shape)?);
        }

        let path = self.index.path_of(&meta.file).to_string_lossy().into_owned();
        let exe = self.pool.get(&path)?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {}", meta.file))?[0][0]
            .to_literal_sync()?;
        let out = result.to_tuple1().context("unwrapping 1-tuple output")?;
        let values: Vec<f32> = out.to_vec::<f32>()?;
        self.executions += 1;

        let full = HostTensor::from_vec(&meta.output_shape, values);
        Ok(full.slice_batch(n))
    }

    /// Run all four segments at a width tuple -> logits (quickstart path).
    pub fn full_forward(
        &mut self,
        widths: &[f64; 4],
        image: &HostTensor,
    ) -> Result<HostTensor> {
        let mut h = image.clone();
        for (seg, &w) in widths.iter().enumerate() {
            h = self.execute(seg, w, &h)?;
        }
        Ok(h)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::artifacts_available;

    fn executor() -> Option<SegmentExecutor> {
        if !artifacts_available("artifacts") {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        Some(SegmentExecutor::new("artifacts").expect("executor"))
    }

    fn read_bin(path: &std::path::Path, shape: &[usize]) -> HostTensor {
        let blob = std::fs::read(path).expect("golden file");
        let data: Vec<f32> = blob
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        HostTensor::from_vec(shape, data)
    }

    #[test]
    fn golden_pairs_match_exactly_enough() {
        let Some(mut ex) = executor() else { return };
        let goldens = ex.index.goldens.clone();
        assert!(!goldens.is_empty());
        for g in &goldens {
            let x = read_bin(&ex.index.path_of(&g.input_file), &g.input_shape);
            let want = read_bin(&ex.index.path_of(&g.output_file), &g.output_shape);
            let got = ex
                .execute(g.segment, g.width, &x)
                .unwrap_or_else(|e| panic!("exec seg{} failed: {e:#}", g.segment));
            assert_eq!(got.shape, want.shape);
            let diff = got.max_abs_diff(&want);
            assert!(
                diff < 2e-3,
                "seg{} w{} b{}: max abs diff {diff}",
                g.segment,
                g.width,
                g.batch
            );
        }
    }

    #[test]
    fn batch_padding_equals_direct_execution() {
        let Some(mut ex) = executor() else { return };
        // batch 2 pads to artifact batch 4: results must equal b=2 slice of b=4
        let g = ex.index.goldens.iter().find(|g| g.segment == 0).unwrap().clone();
        let x4 = read_bin(&ex.index.path_of(&g.input_file), &g.input_shape).pad_batch(2);
        let y2 = ex.execute(0, g.width, &x4.slice_batch(2)).expect("b2");
        let y_direct = ex.execute(0, g.width, &x4).expect("b2 padded");
        assert_eq!(y2.shape[0], 2);
        assert_eq!(y_direct.shape[0], 2);
        assert!(y2.max_abs_diff(&y_direct) < 1e-5);
    }

    #[test]
    fn oversized_batch_splits() {
        let Some(mut ex) = executor() else { return };
        let max_b = *ex.index.batches.iter().max().unwrap();
        let (inp, _) = crate::model::ModelMeta::default().seg_io_shapes(0, max_b + 3);
        let x = HostTensor::zeros(&inp);
        let y = ex.execute(0, 0.25, &x).expect("split execution");
        assert_eq!(y.batch(), max_b + 3);
    }

    #[test]
    fn full_forward_produces_logits() {
        let Some(mut ex) = executor() else { return };
        let meta = crate::model::ModelMeta::default();
        let (inp, _) = meta.seg_io_shapes(0, 1);
        let mut x = HostTensor::zeros(&inp);
        for (i, v) in x.data.iter_mut().enumerate() {
            *v = ((i % 17) as f32 - 8.0) / 8.0;
        }
        let logits = ex
            .full_forward(&[0.25, 0.5, 0.75, 1.0], &x)
            .expect("full forward");
        assert_eq!(logits.shape, vec![1, meta.num_classes]);
        assert!(logits.data.iter().all(|v| v.is_finite()));
        // not all equal (the network actually computed something)
        let first = logits.data[0];
        assert!(logits.data.iter().any(|&v| (v - first).abs() > 1e-6));
    }

    #[test]
    fn zero_padding_invariant_on_real_path() {
        let Some(mut ex) = executor() else { return };
        let meta = crate::model::ModelMeta::default();
        let (inp, _) = meta.seg_io_shapes(0, 1);
        let x = HostTensor::from_vec(&inp, vec![0.5; inp.iter().product()]);
        let y = ex.execute(0, 0.5, &x).expect("seg0 at 0.5");
        // channels >= 16 (0.5 * 32) must be exactly zero
        let c = *y.shape.last().unwrap();
        let c_act = 16;
        for (i, &v) in y.data.iter().enumerate() {
            if i % c >= c_act {
                assert_eq!(v, 0.0, "leak at flat index {i}");
            }
        }
    }
}
