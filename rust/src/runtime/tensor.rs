//! Host-side f32 tensors (NHWC activations, flat weights).

/// A dense f32 tensor on the host.
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        HostTensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(),
                   "shape {shape:?} != data len {}", data.len());
        HostTensor { shape: shape.to_vec(), data }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Leading (batch) dimension.
    pub fn batch(&self) -> usize {
        *self.shape.first().unwrap_or(&0)
    }

    /// Zero-pad the batch dimension up to `target` rows.
    pub fn pad_batch(&self, target: usize) -> HostTensor {
        let b = self.batch();
        assert!(target >= b, "cannot shrink batch {b} -> {target}");
        if target == b {
            return self.clone();
        }
        let row = self.numel() / b.max(1);
        let mut shape = self.shape.clone();
        shape[0] = target;
        let mut data = vec![0.0f32; row * target];
        data[..self.data.len()].copy_from_slice(&self.data);
        HostTensor { shape, data }
    }

    /// Take the first `n` batch rows.
    pub fn slice_batch(&self, n: usize) -> HostTensor {
        let b = self.batch();
        assert!(n <= b, "cannot take {n} rows from batch {b}");
        let row = self.numel() / b.max(1);
        let mut shape = self.shape.clone();
        shape[0] = n;
        HostTensor { shape, data: self.data[..row * n].to_vec() }
    }

    /// Max |a-b| against another tensor (test helper).
    pub fn max_abs_diff(&self, other: &HostTensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_numel() {
        let t = HostTensor::zeros(&[2, 3, 4]);
        assert_eq!(t.numel(), 24);
        assert_eq!(t.batch(), 2);
        let u = HostTensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(u.data[3], 4.0);
    }

    #[test]
    #[should_panic(expected = "shape")]
    fn from_vec_validates() {
        HostTensor::from_vec(&[2, 2], vec![1.0]);
    }

    #[test]
    fn pad_batch_zero_fills() {
        let t = HostTensor::from_vec(&[1, 3], vec![1.0, 2.0, 3.0]);
        let p = t.pad_batch(3);
        assert_eq!(p.shape, vec![3, 3]);
        assert_eq!(&p.data[..3], &[1.0, 2.0, 3.0]);
        assert!(p.data[3..].iter().all(|&x| x == 0.0));
        // padding to the same size is identity
        assert_eq!(t.pad_batch(1), t);
    }

    #[test]
    fn slice_batch_inverts_pad() {
        let t = HostTensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let round = t.pad_batch(5).slice_batch(2);
        assert_eq!(round, t);
    }

    #[test]
    fn max_abs_diff() {
        let a = HostTensor::from_vec(&[2], vec![1.0, 5.0]);
        let b = HostTensor::from_vec(&[2], vec![1.5, 4.0]);
        assert_eq!(a.max_abs_diff(&b), 1.0);
    }
}
