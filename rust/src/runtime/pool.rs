//! Lazy compile cache: HLO text → PJRT executable, compiled at most once
//! per artifact file and shared across instances. Compilation is the
//! expensive step (tens of ms), execution is the hot path.

use std::collections::HashMap;

use anyhow::{Context, Result};

/// Caching wrapper around the PJRT CPU client.
pub struct ExecutablePool {
    pub client: xla::PjRtClient,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    pub compiles: u64,
    pub hits: u64,
}

impl ExecutablePool {
    /// Create with a fresh PJRT CPU client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(ExecutablePool { client, cache: HashMap::new(), compiles: 0, hits: 0 })
    }

    /// Get (compiling if needed) the executable for an HLO-text file.
    pub fn get(&mut self, path: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(path) {
            let proto = xla::HloModuleProto::from_text_file(path)
                .with_context(|| format!("parsing HLO text {path}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {path}"))?;
            self.cache.insert(path.to_string(), exe);
            self.compiles += 1;
        } else {
            self.hits += 1;
        }
        Ok(self.cache.get(path).unwrap())
    }

    /// Pre-compile a list of artifacts (warm start before serving).
    pub fn warm(&mut self, paths: &[String]) -> Result<usize> {
        for p in paths {
            self.get(p)?;
        }
        Ok(self.cache.len())
    }

    pub fn cached(&self) -> usize {
        self.cache.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::artifacts_available;

    #[test]
    fn compiles_probe_once_and_caches() {
        if !artifacts_available("artifacts") {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let mut pool = ExecutablePool::cpu().expect("cpu client");
        let path = "artifacts/probe.hlo.txt";
        pool.get(path).expect("compile probe");
        assert_eq!(pool.compiles, 1);
        pool.get(path).expect("cache hit");
        assert_eq!(pool.compiles, 1);
        assert_eq!(pool.hits, 1);
        assert_eq!(pool.cached(), 1);
    }

    #[test]
    fn probe_executes_correctly() {
        if !artifacts_available("artifacts") {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let mut pool = ExecutablePool::cpu().expect("cpu client");
        let exe = pool.get("artifacts/probe.hlo.txt").expect("compile");
        let x = xla::Literal::vec1(&[1f32, 2., 3., 4.]).reshape(&[2, 2]).unwrap();
        let y = xla::Literal::vec1(&[1f32, 1., 1., 1.]).reshape(&[2, 2]).unwrap();
        let result = exe.execute::<xla::Literal>(&[x, y]).expect("execute")[0][0]
            .to_literal_sync()
            .expect("to literal");
        let out = result.to_tuple1().expect("unwrap tuple");
        let values = out.to_vec::<f32>().expect("to vec");
        assert_eq!(values, vec![5.0, 5.0, 9.0, 9.0]);
    }

    #[test]
    fn missing_file_is_an_error() {
        let mut pool = ExecutablePool::cpu().expect("cpu client");
        assert!(pool.get("artifacts/definitely_missing.hlo.txt").is_err());
    }
}
