//! PJRT runtime — the real inference path.
//!
//! Loads the HLO-text artifacts `python/compile/aot.py` exported (one per
//! `(segment, width, batch)`), compiles them on the PJRT CPU client via
//! the `xla` crate, and executes them with zero python at serve time:
//!
//! ```text
//! manifest.json ──> ArtifactIndex ──┐
//! weights.bin   ──> WeightStore  ──┼──> SegmentExecutor::execute(seg, w, x)
//! *.hlo.txt     ──> ExecutablePool ┘        (pad batch → PJRT → slice)
//! ```
//!
//! HLO *text* is the interchange format: jax ≥ 0.5 serializes protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see aot.py and /opt/xla-example/README.md).

pub mod artifact;
pub mod executor;
pub mod pool;
pub mod tensor;

pub use artifact::{ArtifactIndex, ArtifactMeta};
pub use executor::SegmentExecutor;
pub use pool::ExecutablePool;
pub use tensor::HostTensor;
