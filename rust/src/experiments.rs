//! Experiment drivers shared by the CLI, the examples, and every
//! table/figure bench — one function per paper experiment so the numbers
//! printed by `cargo bench`, `repro tables` and EXPERIMENTS.md all come
//! from identical code paths.

use crate::config::{Config, RewardCfg};
use crate::coordinator::router::RandomRouter;
use crate::coordinator::{sharded_engine, RunOutcome};
use crate::ppo::{run_ppo_episode, PpoRouter};

/// Standard evaluation configuration (the paper's 3-GPU cluster) with a
/// chosen request count.
pub fn paper_cluster_cfg(total_requests: usize, seed: u64) -> Config {
    let mut cfg = Config::default();
    cfg.workload.total_requests = total_requests;
    cfg.seed = seed;
    cfg
}

/// Bench configuration: the paper cluster unless `BENCH_SCENARIO=<name>`
/// selects a `sim::scenarios` entry — the hook that lets every table
/// bench re-run per scenario without code changes.
/// `BENCH_ROUTE_WINDOW=<n>` widens the leader's routing window (default
/// 1 = the paper-faithful per-head loop); `BENCH_LEADERS=<n>` shards the
/// leader tier (default 1 = the paper's single leader).
pub fn bench_cfg(total_requests: usize, seed: u64) -> Config {
    let mut cfg = paper_cluster_cfg(total_requests, seed);
    if let Ok(name) = std::env::var("BENCH_SCENARIO") {
        if !name.is_empty() {
            crate::sim::scenarios::apply_named(&name, &mut cfg)
                .unwrap_or_else(|e| panic!("BENCH_SCENARIO: {e}"));
            // the scenario overrides the workload; keep the bench budget
            cfg.workload.total_requests = total_requests;
            cfg.seed = seed;
        }
    }
    if let Ok(w) = std::env::var("BENCH_ROUTE_WINDOW") {
        if !w.is_empty() {
            let w: usize = w
                .parse()
                .unwrap_or_else(|e| panic!("BENCH_ROUTE_WINDOW: {e}"));
            cfg.router.route_window = w.max(1);
        }
    }
    if let Ok(l) = std::env::var("BENCH_LEADERS") {
        if !l.is_empty() {
            let l: usize =
                l.parse().unwrap_or_else(|e| panic!("BENCH_LEADERS: {e}"));
            cfg.shard.leaders = l.max(1);
        }
    }
    cfg
}

/// Worker count for benches/examples: `BENCH_WORKERS=<n>` (default 1,
/// which preserves the sequential trainer's exact numbers).
pub fn bench_workers() -> usize {
    std::env::var("BENCH_WORKERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1)
}

/// Table III: greedy executors + uniformly random routing (and random
/// width selection — "purely randomized task distribution"). Honors
/// `cfg.shard.leaders` (one leader is the paper protocol and stays
/// bit-identical per seed to the pre-shard engine).
pub fn run_random_baseline(cfg: &Config) -> RunOutcome {
    let router = RandomRouter::new(cfg.scheduler.widths.clone(), true, 8);
    sharded_engine(cfg.clone(), router).run()
}

/// Train a PPO router online against the simulated cluster for
/// `episodes` workloads under the given reward weighting, then return it
/// (still in training mode). Sequential: one episode at a time, updates
/// running in-place as the engine schedules (the paper's online loop).
pub fn train_ppo(cfg: &Config, reward: RewardCfg, episodes: usize) -> PpoRouter {
    let mut ppo_cfg = cfg.ppo.clone();
    ppo_cfg.reward = reward;
    let mut router = PpoRouter::with_state_slack(
        cfg.devices.len(),
        cfg.scheduler.widths.clone(),
        ppo_cfg,
        cfg.seed,
        cfg.router.state_slack,
    );
    for ep in 0..episodes {
        let mut episode_cfg = cfg.clone();
        episode_cfg.seed = crate::ppo::parallel::episode_seed(cfg.seed, ep);
        let (_outcome, r) = run_ppo_episode(&episode_cfg, router);
        router = r;
    }
    router
}

/// Train with a `--workers` knob: `workers <= 1` is the sequential
/// online trainer above (bit-identical to the seed's numbers);
/// `workers > 1` runs `ppo::parallel::train_parallel` — concurrent
/// seeded worker engines with synchronous merged updates. Both are
/// deterministic per (seed, episodes, workers).
pub fn train_ppo_workers(
    cfg: &Config,
    reward: RewardCfg,
    episodes: usize,
    workers: usize,
) -> PpoRouter {
    if workers <= 1 {
        train_ppo(cfg, reward, episodes)
    } else {
        crate::ppo::train_parallel(cfg, reward, episodes, workers)
    }
}

/// Train, freeze, evaluate: the Tables IV/V protocol. Returns the frozen
/// evaluation outcome plus the trained router (for checkpointing or
/// policy inspection).
pub fn run_ppo_experiment(
    cfg: &Config,
    reward: RewardCfg,
    train_episodes: usize,
) -> (RunOutcome, PpoRouter) {
    run_ppo_experiment_workers(cfg, reward, train_episodes, 1)
}

/// The Tables IV/V evaluation protocol, up to (but not including) the
/// measured episode: train under `cfg`, freeze the policy, and shift to
/// the fresh evaluation seed. Callers run the returned `(eval_cfg,
/// router)` pair through whatever episode harness they need (plain,
/// traced, or replayed) — one definition, so the CLI and the table
/// benches can never drift on what "train then evaluate" means.
pub fn prepare_ppo_eval(
    cfg: &Config,
    reward: RewardCfg,
    train_episodes: usize,
    workers: usize,
) -> (Config, PpoRouter) {
    let mut router = train_ppo_workers(cfg, reward, train_episodes, workers);
    router.eval_mode();
    let mut eval_cfg = cfg.clone();
    eval_cfg.seed = cfg.seed.wrapping_add(0xEA1);
    (eval_cfg, router)
}

/// [`run_ppo_experiment`] with a parallel-rollout worker count.
pub fn run_ppo_experiment_workers(
    cfg: &Config,
    reward: RewardCfg,
    train_episodes: usize,
    workers: usize,
) -> (RunOutcome, PpoRouter) {
    let (eval_cfg, router) = prepare_ppo_eval(cfg, reward, train_episodes, workers);
    let (outcome, router) = run_ppo_episode(&eval_cfg, router);
    (outcome, router)
}

/// Train, then measure one episode with learning and exploration still
/// on — the paper's online protocol: Table V's elevated latency/energy
/// variance is explicitly attributed to "the scheduler's dynamic
/// experimentation with different slimming ratios", i.e. a policy that
/// keeps adapting while being measured.
pub fn run_ppo_experiment_online(
    cfg: &Config,
    reward: RewardCfg,
    train_episodes: usize,
) -> (RunOutcome, PpoRouter) {
    run_ppo_experiment_online_workers(cfg, reward, train_episodes, 1)
}

/// [`run_ppo_experiment_online`] with a parallel-rollout worker count
/// for the training episodes; the measured episode itself stays online
/// (learning + exploration on) by construction.
pub fn run_ppo_experiment_online_workers(
    cfg: &Config,
    reward: RewardCfg,
    train_episodes: usize,
    workers: usize,
) -> (RunOutcome, PpoRouter) {
    let router =
        train_ppo_workers(cfg, reward, train_episodes.saturating_sub(1), workers);
    let mut eval_cfg = cfg.clone();
    eval_cfg.seed = cfg.seed.wrapping_add(0xEA1);
    let (outcome, router) = run_ppo_episode(&eval_cfg, router);
    (outcome, router)
}

/// Table IV: heavy latency/energy penalties (the "overfit" policy —
/// converged and frozen, hence its tiny spread).
pub fn run_table4(cfg: &Config, train_episodes: usize) -> (RunOutcome, PpoRouter) {
    run_ppo_experiment(cfg, RewardCfg::overfit(), train_episodes)
}

/// Table V: balanced weighting, measured online (the "averaged" policy).
pub fn run_table5(cfg: &Config, train_episodes: usize) -> (RunOutcome, PpoRouter) {
    run_ppo_experiment_online(cfg, RewardCfg::balanced(), train_episodes)
}

/// Percentage change helper for EXPERIMENTS.md-style deltas.
pub fn pct_change(from: f64, to: f64) -> f64 {
    if from == 0.0 {
        0.0
    } else {
        (to - from) / from * 100.0
    }
}

// ---------------------------------------------------------------------
// Figure regenerators (shared by `repro figures` and the fig benches)
// ---------------------------------------------------------------------

use crate::model::{ModelMeta, WIDTHS};
use crate::sim::{profiles, SimDevice};

/// Fig 1 sweep points (batch sizes) and utilization levels for Figs 2–3.
pub const FIG1_BATCHES: [usize; 7] = [1, 2, 4, 8, 16, 32, 64];
pub const FIG23_UTILS: [f64; 9] =
    [10.0, 30.0, 50.0, 70.0, 80.0, 90.0, 93.0, 96.0, 99.0];

/// Fig 1 — GPU memory utilization (%) vs batch size, one column per
/// width (RTX 2080 Ti). Row = [batch, w025, w050, w075, w100].
pub fn fig1_rows() -> Vec<Vec<f64>> {
    let meta = ModelMeta::default();
    let dev = SimDevice::new(profiles::rtx2080ti());
    FIG1_BATCHES
        .iter()
        .map(|&batch| {
            let mut row = vec![batch as f64];
            for &w in &WIDTHS {
                let bytes: u64 = (0..4)
                    .map(|s| meta.instance_vram_semantic(s, w, batch))
                    .sum();
                row.push(bytes as f64 / dev.cfg.vram_bytes as f64 * 100.0);
            }
            row
        })
        .collect()
}

/// One (latency s, power W) point of the Figs 2–3 sweep: a width-w
/// 8-image batch through all four segments at pinned utilization.
pub fn fig23_point(meta: &ModelMeta, util_pct: f64, w: f64) -> (f64, f64) {
    let dev = SimDevice::new(profiles::rtx2080ti());
    let flops: u64 = (0..4).map(|s| meta.seg_flops(s, w, w, 8)).sum();
    let mem: u64 = (0..4)
        .map(|s| (meta.seg_mem_bytes(s, 8) as f64 * w) as u64)
        .sum();
    let latency = dev.base_exec_time(flops, mem) * dev.congestion(util_pct);
    let power = dev.cfg.idle_power_w
        + (dev.cfg.max_power_w - dev.cfg.idle_power_w) * util_pct / 100.0;
    (latency, power)
}

/// Fig 2 — energy (J) vs utilization. Row = [util, E(w) per width].
pub fn fig2_rows() -> Vec<Vec<f64>> {
    let meta = ModelMeta::default();
    FIG23_UTILS
        .iter()
        .map(|&u| {
            let mut row = vec![u];
            for &w in &WIDTHS {
                let (latency, power) = fig23_point(&meta, u, w);
                row.push(power * latency);
            }
            row
        })
        .collect()
}

/// Fig 3 — batch latency (s) vs utilization. Row = [util, L(w) per width].
pub fn fig3_rows() -> Vec<Vec<f64>> {
    let meta = ModelMeta::default();
    FIG23_UTILS
        .iter()
        .map(|&u| {
            let mut row = vec![u];
            for &w in &WIDTHS {
                let (latency, _) = fig23_point(&meta, u, w);
                row.push(latency);
            }
            row
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> Config {
        // small but saturating enough to expose the trade-off
        paper_cluster_cfg(1200, 42)
    }

    #[test]
    fn baseline_saturates_the_cluster() {
        let out = run_random_baseline(&quick_cfg());
        assert_eq!(out.report.completed, 1200);
        // the random baseline must be operating in the congested regime
        // (mean block latency far above a single uncongested execution)
        assert!(
            out.report.latency.mean() > 0.2,
            "baseline too fast: {}",
            out.report.latency.mean()
        );
        assert!(out.report.accuracy_pct > 71.0 && out.report.accuracy_pct < 76.0);
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "slow without --release; run `cargo test --release`")]
    fn table4_overfit_collapses_to_slim_and_slashes_latency() {
        let cfg = paper_cluster_cfg(2500, 42);
        let baseline = run_random_baseline(&cfg);
        let (ppo, router) = run_table4(&cfg, 8);
        assert_eq!(ppo.report.completed, 2500);
        // latency and energy crushed relative to baseline
        let lat_red = pct_change(baseline.report.latency.mean(), ppo.report.latency.mean());
        assert!(lat_red < -60.0, "latency reduction only {lat_red:.1}%");
        // width histogram concentrates on slim widths
        let slim_frac = ppo.width_frac_at_most(0.5);
        assert!(slim_frac > 0.6, "slim fraction {slim_frac}: {:?}", ppo.width_histogram);
        // accuracy sinks toward the slimmest model's 70.3
        assert!(ppo.report.accuracy_pct < baseline.report.accuracy_pct);
        assert!(router.stats.updates > 0);
    }

    #[test]
    fn bench_cfg_defaults_to_paper_cluster() {
        // (BENCH_SCENARIO is only set by explicit bench invocations)
        if std::env::var("BENCH_SCENARIO").is_err() {
            assert_eq!(bench_cfg(100, 7), paper_cluster_cfg(100, 7));
        }
        assert!(bench_workers() >= 1 || std::env::var("BENCH_WORKERS").is_ok());
    }

    #[test]
    fn workers_flag_routes_both_trainers() {
        let mut cfg = quick_cfg();
        cfg.workload.total_requests = 400;
        cfg.ppo.horizon = 64;
        let seq = train_ppo_workers(&cfg, RewardCfg::overfit(), 1, 1);
        assert!(seq.stats.decisions > 0);
        let par = train_ppo_workers(&cfg, RewardCfg::overfit(), 2, 2);
        assert!(par.stats.updates > 0);
    }

    #[test]
    fn pct_change_math() {
        assert!((pct_change(8.98, 0.318) + 96.458).abs() < 0.01);
        assert_eq!(pct_change(0.0, 5.0), 0.0);
    }
}
