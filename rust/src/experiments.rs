//! Experiment drivers shared by the CLI, the examples, and every
//! table/figure bench — one function per paper experiment so the numbers
//! printed by `cargo bench`, `repro tables` and EXPERIMENTS.md all come
//! from identical code paths.

use crate::config::{Config, RewardCfg};
use crate::coordinator::router::RandomRouter;
use crate::coordinator::{sharded_engine, RunOutcome};
use crate::ppo::{run_ppo_episode, PpoRouter};

/// Standard evaluation configuration (the paper's 3-GPU cluster) with a
/// chosen request count.
pub fn paper_cluster_cfg(total_requests: usize, seed: u64) -> Config {
    let mut cfg = Config::default();
    cfg.workload.total_requests = total_requests;
    cfg.seed = seed;
    cfg
}

/// Bench configuration: the paper cluster unless `BENCH_SCENARIO=<name>`
/// selects a `sim::scenarios` entry — the hook that lets every table
/// bench re-run per scenario without code changes.
/// `BENCH_ROUTE_WINDOW=<n>` widens the leader's routing window (default
/// 1 = the paper-faithful per-head loop); `BENCH_LEADERS=<n>` shards the
/// leader tier (default 1 = the paper's single leader).
pub fn bench_cfg(total_requests: usize, seed: u64) -> Config {
    let mut cfg = paper_cluster_cfg(total_requests, seed);
    if let Ok(name) = std::env::var("BENCH_SCENARIO") {
        if !name.is_empty() {
            crate::sim::scenarios::apply_named(&name, &mut cfg)
                .unwrap_or_else(|e| panic!("BENCH_SCENARIO: {e}"));
            // the scenario overrides the workload; keep the bench budget
            cfg.workload.total_requests = total_requests;
            cfg.seed = seed;
        }
    }
    if let Ok(w) = std::env::var("BENCH_ROUTE_WINDOW") {
        if !w.is_empty() {
            let w: usize = w
                .parse()
                .unwrap_or_else(|e| panic!("BENCH_ROUTE_WINDOW: {e}"));
            cfg.router.route_window = w.max(1);
        }
    }
    if let Ok(l) = std::env::var("BENCH_LEADERS") {
        if !l.is_empty() {
            let l: usize =
                l.parse().unwrap_or_else(|e| panic!("BENCH_LEADERS: {e}"));
            cfg.shard.leaders = l.max(1);
        }
    }
    cfg
}

/// Worker count for benches/examples: `BENCH_WORKERS=<n>` (default 1,
/// which preserves the sequential trainer's exact numbers).
pub fn bench_workers() -> usize {
    std::env::var("BENCH_WORKERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1)
}

/// Table III: greedy executors + uniformly random routing (and random
/// width selection — "purely randomized task distribution"). Honors
/// `cfg.shard.leaders` (one leader is the paper protocol and stays
/// bit-identical per seed to the pre-shard engine).
pub fn run_random_baseline(cfg: &Config) -> RunOutcome {
    let router = RandomRouter::new(cfg.scheduler.widths.clone(), true, 8);
    sharded_engine(cfg.clone(), router).run()
}

/// Train a PPO router online against the simulated cluster for
/// `episodes` workloads under the given reward weighting, then return it
/// (still in training mode). Sequential: one episode at a time, updates
/// running in-place as the engine schedules (the paper's online loop).
pub fn train_ppo(cfg: &Config, reward: RewardCfg, episodes: usize) -> PpoRouter {
    let mut ppo_cfg = cfg.ppo.clone();
    ppo_cfg.reward = reward;
    let mut router = PpoRouter::with_state_slack(
        cfg.devices.len(),
        cfg.scheduler.widths.clone(),
        ppo_cfg,
        cfg.seed,
        cfg.router.state_slack,
    );
    for ep in 0..episodes {
        let mut episode_cfg = cfg.clone();
        episode_cfg.seed = crate::ppo::parallel::episode_seed(cfg.seed, ep);
        let (_outcome, r) = run_ppo_episode(&episode_cfg, router);
        router = r;
    }
    router
}

/// Train with a `--workers` knob: `workers <= 1` is the sequential
/// online trainer above (bit-identical to the seed's numbers);
/// `workers > 1` runs `ppo::parallel::train_parallel` — concurrent
/// seeded worker engines with synchronous merged updates. Both are
/// deterministic per (seed, episodes, workers).
pub fn train_ppo_workers(
    cfg: &Config,
    reward: RewardCfg,
    episodes: usize,
    workers: usize,
) -> PpoRouter {
    if workers <= 1 {
        train_ppo(cfg, reward, episodes)
    } else {
        crate::ppo::train_parallel(cfg, reward, episodes, workers)
    }
}

/// Train, freeze, evaluate: the Tables IV/V protocol. Returns the frozen
/// evaluation outcome plus the trained router (for checkpointing or
/// policy inspection).
pub fn run_ppo_experiment(
    cfg: &Config,
    reward: RewardCfg,
    train_episodes: usize,
) -> (RunOutcome, PpoRouter) {
    run_ppo_experiment_workers(cfg, reward, train_episodes, 1)
}

/// The Tables IV/V evaluation protocol, up to (but not including) the
/// measured episode: train under `cfg`, freeze the policy, and shift to
/// the fresh evaluation seed. Callers run the returned `(eval_cfg,
/// router)` pair through whatever episode harness they need (plain,
/// traced, or replayed) — one definition, so the CLI and the table
/// benches can never drift on what "train then evaluate" means.
pub fn prepare_ppo_eval(
    cfg: &Config,
    reward: RewardCfg,
    train_episodes: usize,
    workers: usize,
) -> (Config, PpoRouter) {
    let mut router = train_ppo_workers(cfg, reward, train_episodes, workers);
    router.eval_mode();
    let mut eval_cfg = cfg.clone();
    eval_cfg.seed = cfg.seed.wrapping_add(0xEA1);
    (eval_cfg, router)
}

/// [`run_ppo_experiment`] with a parallel-rollout worker count.
pub fn run_ppo_experiment_workers(
    cfg: &Config,
    reward: RewardCfg,
    train_episodes: usize,
    workers: usize,
) -> (RunOutcome, PpoRouter) {
    let (eval_cfg, router) = prepare_ppo_eval(cfg, reward, train_episodes, workers);
    let (outcome, router) = run_ppo_episode(&eval_cfg, router);
    (outcome, router)
}

/// Train, then measure one episode with learning and exploration still
/// on — the paper's online protocol: Table V's elevated latency/energy
/// variance is explicitly attributed to "the scheduler's dynamic
/// experimentation with different slimming ratios", i.e. a policy that
/// keeps adapting while being measured.
pub fn run_ppo_experiment_online(
    cfg: &Config,
    reward: RewardCfg,
    train_episodes: usize,
) -> (RunOutcome, PpoRouter) {
    run_ppo_experiment_online_workers(cfg, reward, train_episodes, 1)
}

/// [`run_ppo_experiment_online`] with a parallel-rollout worker count
/// for the training episodes; the measured episode itself stays online
/// (learning + exploration on) by construction.
pub fn run_ppo_experiment_online_workers(
    cfg: &Config,
    reward: RewardCfg,
    train_episodes: usize,
    workers: usize,
) -> (RunOutcome, PpoRouter) {
    let router =
        train_ppo_workers(cfg, reward, train_episodes.saturating_sub(1), workers);
    let mut eval_cfg = cfg.clone();
    eval_cfg.seed = cfg.seed.wrapping_add(0xEA1);
    let (outcome, router) = run_ppo_episode(&eval_cfg, router);
    (outcome, router)
}

/// Table IV: heavy latency/energy penalties (the "overfit" policy —
/// converged and frozen, hence its tiny spread).
pub fn run_table4(cfg: &Config, train_episodes: usize) -> (RunOutcome, PpoRouter) {
    run_ppo_experiment(cfg, RewardCfg::overfit(), train_episodes)
}

/// Table V: balanced weighting, measured online (the "averaged" policy).
pub fn run_table5(cfg: &Config, train_episodes: usize) -> (RunOutcome, PpoRouter) {
    run_ppo_experiment_online(cfg, RewardCfg::balanced(), train_episodes)
}

// ---------------------------------------------------------------------
// Scenario-conditioned trace study (`repro trace-study`)
// ---------------------------------------------------------------------

use crate::trace::{compare_routers_opts, record_trace, CompareOpts};
use crate::utilx::json::{obj, Json};

/// The scenario-conditioned paired study from the ROADMAP: for every
/// scenario in the registry, record one arrival trace under the baseline
/// (`field[0]`) and counterfactually replay the full algorithmic field
/// plus the given PPO checkpoint over it, collecting the paired
/// significance matrix. Each scenario's entry carries the A/B report
/// (summary + significance, no per-request rows — this is a matrix, not
/// a dump) or, when the scenario can't run the checkpoint (different
/// cluster size or width set ⇒ different policy shape), the algorithmic
/// field alone plus the load error under `ppo_error` — an honest
/// "policy not transferable as-is" cell instead of a silent skip.
///
/// Deterministic end to end: every scenario records and replays under
/// `seed`, and the significance block's bootstrap streams are seeded
/// from it too — so the matrix is byte-identical at any `eval_threads`
/// (the scenario fan-out reassembles entries in registry order) unless
/// `timing` adds the per-entrant `replay_wall_s` wall-clock fields.
/// Per-scenario failures (a starved recording, a failed compare) land
/// in that scenario's entry (`record_error` / `compare_error`) instead
/// of sinking the study. Returns the `BENCH_trace_study.json` document.
pub fn trace_study(
    checkpoint: &str,
    field: &[String],
    requests: usize,
    seed: u64,
    eval_threads: usize,
    timing: bool,
) -> Result<Json, String> {
    if field.is_empty() {
        return Err("trace-study needs at least one algorithmic router".into());
    }
    // an unreadable or unparsable checkpoint is a *global* failure —
    // abort the study rather than letting a typoed path masquerade as
    // "shape-incompatible" on every scenario (a false green). Parsed
    // once; the per-scenario probe below only re-checks the shape.
    let ckpt_text = std::fs::read_to_string(checkpoint)
        .map_err(|e| format!("cannot read checkpoint {checkpoint}: {e}"))?;
    let ckpt_json = Json::parse(&ckpt_text)
        .map_err(|e| format!("checkpoint {checkpoint} is not valid JSON: {e}"))?;

    // one scenario's study cell: record under the baseline, probe the
    // checkpoint shape, compare the field. Infallible by design — every
    // failure mode lands inside the entry, which is also what lets the
    // scenario fan-out below run cells independently.
    let scenario_entry = |scenario: &crate::sim::scenarios::Scenario| -> Json {
        let mut cfg = scenario.config();
        cfg.workload.total_requests = requests;
        cfg.seed = seed;

        let mut fields: Vec<(String, Json)> = vec![(
            "scenario".to_string(),
            Json::Str(scenario.name.to_string()),
        )];
        let trace = match record_trace(&cfg, &field[0]) {
            Ok(trace) => trace,
            Err(e) => {
                // a scenario whose recording starves (overload past the
                // safety cap) reports itself instead of sinking the study
                fields.push(("record_error".to_string(), Json::Str(e)));
                return Json::Obj(fields);
            }
        };

        // shape probe against the pre-parsed weights: can this
        // scenario's cluster run the checkpoint? (Different device
        // count or width set ⇒ different policy dimensions.)
        let ppo_compatible =
            PpoRouter::for_config(&cfg).load_weights(&ckpt_json);
        let mut names: Vec<String> = field.to_vec();
        if ppo_compatible {
            names.push(format!("ppo:{checkpoint}"));
        } else {
            fields.push((
                "ppo_error".to_string(),
                Json::Str(format!(
                    "checkpoint shape does not fit this scenario \
                     ({} servers, {} widths)",
                    cfg.devices.len(),
                    cfg.scheduler.widths.len()
                )),
            ));
        }
        fields.push(("ppo_compatible".to_string(), Json::Bool(ppo_compatible)));
        if names.len() >= 2 {
            // the study parallelizes across scenarios, so each cell's
            // compare replays its entrants sequentially (no nested
            // fan-out oversubscribing the pool). A failed compare is a
            // per-scenario fact, exactly like a failed recording — not
            // a study-wide abort.
            let inner =
                CompareOpts { per_request: false, eval_threads: 1, timing };
            match compare_routers_opts(&cfg, &trace, &names, inner) {
                Ok(report) => fields.push(("report".to_string(), report)),
                Err(e) => {
                    fields.push(("compare_error".to_string(), Json::Str(e)))
                }
            }
        }
        // (a one-router field with an incompatible checkpoint leaves no
        // candidates — the entry still records why)
        Json::Obj(fields)
    };

    let scenarios = crate::sim::scenarios::all();
    let threads = eval_threads.max(1).min(scenarios.len());
    let entries: Vec<Json> = if threads <= 1 {
        scenarios.iter().map(scenario_entry).collect()
    } else {
        // scenario-level fan-out, mirroring the compare harness's
        // entrant fan-out: strided assignment over scoped workers,
        // entries reassembled in registry order so the matrix is
        // byte-identical to the sequential walk
        let mut slots: Vec<Option<Json>> =
            (0..scenarios.len()).map(|_| None).collect();
        let cell = &scenario_entry;
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..threads)
                .map(|worker| {
                    scope.spawn(move || {
                        let mut out = Vec::new();
                        let mut i = worker;
                        while i < scenarios.len() {
                            out.push((i, cell(&scenarios[i])));
                            i += threads;
                        }
                        out
                    })
                })
                .collect();
            for h in handles {
                for (i, entry) in h.join().expect("study worker panicked") {
                    slots[i] = Some(entry);
                }
            }
        });
        slots
            .into_iter()
            .map(|s| s.expect("every scenario is assigned to a worker"))
            .collect()
    };
    Ok(obj(vec![
        ("checkpoint", Json::Str(checkpoint.to_string())),
        (
            "field",
            Json::Arr(field.iter().cloned().map(Json::Str).collect()),
        ),
        ("requests_per_scenario", Json::Num(requests as f64)),
        ("seed", Json::Num(seed as f64)),
        ("scenarios", Json::Arr(entries)),
    ]))
}

// ---------------------------------------------------------------------
// Offline autotune baseline (`repro autotune`)
// ---------------------------------------------------------------------

/// Scenarios `repro autotune` sweeps when `--scenarios` is not given:
/// the paper cluster (ungated control), sharded-hot (finite-capacity
/// leaders — the one regime that builds genuine FIFO backlog), and
/// flash-crowd (the gated multi-tenant spike).
pub const AUTOTUNE_DEFAULT_SCENARIOS: &str = "paper,sharded-hot,flash-crowd";

/// One static-knob replay: the recorded arrivals re-run under `cfg`
/// (no controller), harvesting per-request completions and the shed
/// count. Pure function of (trace, cfg) — same contract as the compare
/// harness's entrant replays.
fn replay_static(
    cfg: &crate::config::Config,
    trace: &crate::trace::Trace,
) -> (std::collections::BTreeMap<u64, crate::trace::DoneStats>, u64, String) {
    use crate::coordinator::router::AlgoRouter;
    use crate::trace::{configure_for_replay, TraceRecorder};
    let mut cfg = cfg.clone();
    configure_for_replay(&mut cfg, trace);
    let router = AlgoRouter::by_name("edf", &cfg.scheduler.widths)
        .expect("edf is a registered router");
    let recorder = TraceRecorder::new(&cfg, "edf");
    let mut engine = sharded_engine(cfg, router);
    engine.set_arrivals(trace.arrivals_arena());
    engine.set_trace_sink(Box::new(recorder.clone()));
    let out = engine.run();
    (recorder.done_map(), out.shed, recorder.to_jsonl())
}

/// The offline autotune baseline: for each named scenario, record one
/// trace under the stock (static, controller-less) config, grid-sweep
/// static knob settings over it restart-per-trial, and pit the adaptive
/// `backlog` controller against the *best* static point with paired
/// per-request deltas — the honest question being "does live retuning
/// beat the best config you could have picked offline?".
///
/// The grid is deliberately small (route window × DRR quantum, ~3–6
/// trials per scenario): this is a baseline protocol, not a tuner.
/// Deterministic end to end — every trial replays the same recorded
/// arrivals under `seed`, the paired significance block's bootstrap is
/// seeded, and the scenario fan-out reassembles entries in name order —
/// so the `BENCH_autotune.json` document is byte-identical at any
/// `eval_threads`. Per-scenario failures land in that scenario's entry
/// (`record_error`), mirroring [`trace_study`].
pub fn autotune(
    scenario_names: &[String],
    requests: usize,
    seed: u64,
    eval_threads: usize,
) -> Result<Json, String> {
    use crate::config::ControllerKind;
    use crate::sim::scenarios;
    use crate::trace::paired_stats;

    if scenario_names.is_empty() {
        return Err("autotune needs at least one scenario".into());
    }
    // validate every name up front so a typo aborts the sweep instead
    // of surfacing as the last scenario's entry after minutes of work
    for name in scenario_names {
        let mut probe = Config::default();
        scenarios::apply_named(name, &mut probe)?;
    }

    let scenario_entry = |si: usize, name: &str| -> Json {
        let mut cfg = Config::default();
        scenarios::apply_named(name, &mut cfg)
            .expect("names validated above");
        cfg.workload.total_requests = requests;
        cfg.seed = seed;
        cfg.ctrl.controller = ControllerKind::None;

        let mut fields: Vec<(String, Json)> =
            vec![("scenario".to_string(), Json::Str(name.to_string()))];
        let trace = match record_trace(&cfg, "edf") {
            Ok(trace) => trace,
            Err(e) => {
                fields.push(("record_error".to_string(), Json::Str(e)));
                return Json::Obj(fields);
            }
        };

        // restart-per-trial static grid: route window × DRR quantum
        // (the quantum axis only exists when the scenario is gated)
        let gated = cfg.admission.kind == crate::config::AdmissionKind::Drr;
        let quanta: Vec<f64> = if gated {
            vec![cfg.admission.quantum, cfg.admission.quantum * 2.0]
        } else {
            vec![cfg.admission.quantum]
        };
        let mut grid = Vec::new();
        for &w in &[1usize, 4, 8] {
            for &q in &quanta {
                let mut trial_cfg = cfg.clone();
                trial_cfg.router.route_window = w;
                trial_cfg.admission.quantum = q;
                let (done, shed, _) = replay_static(&trial_cfg, &trace);
                let mut lat = crate::metrics::Summary::default();
                for d in done.values() {
                    lat.record(d.e2e_s);
                }
                grid.push((w, q, lat.mean(), done, shed));
            }
        }
        // best static point: lowest mean e2e, grid order breaking ties
        let best = grid
            .iter()
            .enumerate()
            .min_by(|(ai, a), (bi, b)| {
                a.2.total_cmp(&b.2).then(ai.cmp(bi))
            })
            .map(|(i, _)| i)
            .expect("grid is non-empty");
        fields.push((
            "grid".to_string(),
            Json::Arr(
                grid.iter()
                    .map(|(w, q, mean, done, shed)| {
                        obj(vec![
                            ("route_window", Json::Num(*w as f64)),
                            ("drr_quantum", Json::Num(*q)),
                            ("mean_latency_s", Json::Num(*mean)),
                            ("completed", Json::Num(done.len() as f64)),
                            ("shed", Json::Num(*shed as f64)),
                        ])
                    })
                    .collect(),
            ),
        ));
        let (best_w, best_q, best_mean, best_done, _) = &grid[best];
        fields.push((
            "autotune_best_route_window".to_string(),
            Json::Num(*best_w as f64),
        ));
        fields.push(("autotune_best_drr_quantum".to_string(), Json::Num(*best_q)));
        fields.push((
            "autotune_best_mean_latency_s".to_string(),
            Json::Num(*best_mean),
        ));

        // the adaptive entrant: same arrivals, stock knobs, live
        // backlog controller. Retunes (knob changes past the initial
        // state) are counted out of the replayed trace's knobs events.
        let mut adaptive_cfg = cfg.clone();
        adaptive_cfg.ctrl.controller = ControllerKind::Backlog;
        let (adaptive_done, adaptive_shed, adaptive_trace) =
            replay_static(&adaptive_cfg, &trace);
        let knob_states = adaptive_trace
            .lines()
            .filter(|l| l.contains("\"ev\":\"knobs\""))
            .count();
        let mut adaptive_lat = crate::metrics::Summary::default();
        for d in adaptive_done.values() {
            adaptive_lat.record(d.e2e_s);
        }
        // paired per-request deltas, adaptive − best-static: negative
        // means live retuning beats the offline optimum
        let mut deltas = Vec::new();
        for (id, b) in best_done {
            if let Some(a) = adaptive_done.get(id) {
                deltas.push(a.e2e_s - b.e2e_s);
            }
        }
        let mut adaptive_fields: Vec<(String, Json)> = vec![
            ("controller".to_string(), Json::Str("backlog".to_string())),
            (
                "knob_changes".to_string(),
                Json::Num(knob_states.saturating_sub(1) as f64),
            ),
            (
                "completed".to_string(),
                Json::Num(adaptive_done.len() as f64),
            ),
            ("shed".to_string(), Json::Num(adaptive_shed as f64)),
            ("mean_latency_s".to_string(), Json::Num(adaptive_lat.mean())),
            ("n_pairs".to_string(), Json::Num(deltas.len() as f64)),
        ];
        if !deltas.is_empty() {
            let mean = deltas.iter().sum::<f64>() / deltas.len() as f64;
            adaptive_fields.push((
                "adaptive_vs_static_delta_s".to_string(),
                Json::Num(mean),
            ));
            let stats = paired_stats(&deltas, seed ^ 0xA070_70E ^ si as u64);
            adaptive_fields.push((
                "sign_test_p".to_string(),
                Json::Num(stats.sign_test_p),
            ));
            adaptive_fields.push((
                "delta_ci95".to_string(),
                Json::Arr(vec![Json::Num(stats.ci_lo), Json::Num(stats.ci_hi)]),
            ));
            adaptive_fields.push(("win_rate".to_string(), Json::Num(stats.win_rate)));
        }
        fields.push(("adaptive".to_string(), Json::Obj(adaptive_fields)));
        Json::Obj(fields)
    };

    let threads = eval_threads.max(1).min(scenario_names.len());
    let entries: Vec<Json> = if threads <= 1 {
        scenario_names
            .iter()
            .enumerate()
            .map(|(i, n)| scenario_entry(i, n))
            .collect()
    } else {
        // strided scenario fan-out, reassembled in name order — the
        // same pattern (and the same byte-identity argument) as
        // `trace_study`'s scenario cells
        let mut slots: Vec<Option<Json>> =
            (0..scenario_names.len()).map(|_| None).collect();
        let cell = &scenario_entry;
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..threads)
                .map(|worker| {
                    scope.spawn(move || {
                        let mut out = Vec::new();
                        let mut i = worker;
                        while i < scenario_names.len() {
                            out.push((i, cell(i, &scenario_names[i])));
                            i += threads;
                        }
                        out
                    })
                })
                .collect();
            for h in handles {
                for (i, entry) in h.join().expect("autotune worker panicked") {
                    slots[i] = Some(entry);
                }
            }
        });
        slots
            .into_iter()
            .map(|s| s.expect("every scenario is assigned to a worker"))
            .collect()
    };
    Ok(obj(vec![
        (
            "scenarios",
            Json::Arr(
                scenario_names.iter().cloned().map(Json::Str).collect(),
            ),
        ),
        ("requests_per_scenario", Json::Num(requests as f64)),
        ("seed", Json::Num(seed as f64)),
        ("entries", Json::Arr(entries)),
    ]))
}

/// Percentage change helper for EXPERIMENTS.md-style deltas.
pub fn pct_change(from: f64, to: f64) -> f64 {
    if from == 0.0 {
        0.0
    } else {
        (to - from) / from * 100.0
    }
}

// ---------------------------------------------------------------------
// Figure regenerators (shared by `repro figures` and the fig benches)
// ---------------------------------------------------------------------

use crate::model::{ModelMeta, WIDTHS};
use crate::sim::{profiles, SimDevice};

/// Fig 1 sweep points (batch sizes) and utilization levels for Figs 2–3.
pub const FIG1_BATCHES: [usize; 7] = [1, 2, 4, 8, 16, 32, 64];
pub const FIG23_UTILS: [f64; 9] =
    [10.0, 30.0, 50.0, 70.0, 80.0, 90.0, 93.0, 96.0, 99.0];

/// Fig 1 — GPU memory utilization (%) vs batch size, one column per
/// width (RTX 2080 Ti). Row = [batch, w025, w050, w075, w100].
pub fn fig1_rows() -> Vec<Vec<f64>> {
    let meta = ModelMeta::default();
    let dev = SimDevice::new(profiles::rtx2080ti());
    FIG1_BATCHES
        .iter()
        .map(|&batch| {
            let mut row = vec![batch as f64];
            for &w in &WIDTHS {
                let bytes: u64 = (0..4)
                    .map(|s| meta.instance_vram_semantic(s, w, batch))
                    .sum();
                row.push(bytes as f64 / dev.cfg.vram_bytes as f64 * 100.0);
            }
            row
        })
        .collect()
}

/// One (latency s, power W) point of the Figs 2–3 sweep: a width-w
/// 8-image batch through all four segments at pinned utilization.
pub fn fig23_point(meta: &ModelMeta, util_pct: f64, w: f64) -> (f64, f64) {
    let dev = SimDevice::new(profiles::rtx2080ti());
    let flops: u64 = (0..4).map(|s| meta.seg_flops(s, w, w, 8)).sum();
    let mem: u64 = (0..4)
        .map(|s| (meta.seg_mem_bytes(s, 8) as f64 * w) as u64)
        .sum();
    let latency = dev.base_exec_time(flops, mem) * dev.congestion(util_pct);
    let power = dev.cfg.idle_power_w
        + (dev.cfg.max_power_w - dev.cfg.idle_power_w) * util_pct / 100.0;
    (latency, power)
}

/// Fig 2 — energy (J) vs utilization. Row = [util, E(w) per width].
pub fn fig2_rows() -> Vec<Vec<f64>> {
    let meta = ModelMeta::default();
    FIG23_UTILS
        .iter()
        .map(|&u| {
            let mut row = vec![u];
            for &w in &WIDTHS {
                let (latency, power) = fig23_point(&meta, u, w);
                row.push(power * latency);
            }
            row
        })
        .collect()
}

/// Fig 3 — batch latency (s) vs utilization. Row = [util, L(w) per width].
pub fn fig3_rows() -> Vec<Vec<f64>> {
    let meta = ModelMeta::default();
    FIG23_UTILS
        .iter()
        .map(|&u| {
            let mut row = vec![u];
            for &w in &WIDTHS {
                let (latency, _) = fig23_point(&meta, u, w);
                row.push(latency);
            }
            row
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> Config {
        // small but saturating enough to expose the trade-off
        paper_cluster_cfg(1200, 42)
    }

    #[test]
    fn baseline_saturates_the_cluster() {
        let out = run_random_baseline(&quick_cfg());
        assert_eq!(out.report.completed, 1200);
        // the random baseline must be operating in the congested regime
        // (mean block latency far above a single uncongested execution)
        assert!(
            out.report.latency.mean() > 0.2,
            "baseline too fast: {}",
            out.report.latency.mean()
        );
        assert!(out.report.accuracy_pct > 71.0 && out.report.accuracy_pct < 76.0);
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "slow without --release; run `cargo test --release`")]
    fn table4_overfit_collapses_to_slim_and_slashes_latency() {
        let cfg = paper_cluster_cfg(2500, 42);
        let baseline = run_random_baseline(&cfg);
        let (ppo, router) = run_table4(&cfg, 8);
        assert_eq!(ppo.report.completed, 2500);
        // latency and energy crushed relative to baseline
        let lat_red = pct_change(baseline.report.latency.mean(), ppo.report.latency.mean());
        assert!(lat_red < -60.0, "latency reduction only {lat_red:.1}%");
        // width histogram concentrates on slim widths
        let slim_frac = ppo.width_frac_at_most(0.5);
        assert!(slim_frac > 0.6, "slim fraction {slim_frac}: {:?}", ppo.width_histogram);
        // accuracy sinks toward the slimmest model's 70.3
        assert!(ppo.report.accuracy_pct < baseline.report.accuracy_pct);
        assert!(router.stats.updates > 0);
    }

    #[test]
    fn bench_cfg_defaults_to_paper_cluster() {
        // (BENCH_SCENARIO is only set by explicit bench invocations)
        if std::env::var("BENCH_SCENARIO").is_err() {
            assert_eq!(bench_cfg(100, 7), paper_cluster_cfg(100, 7));
        }
        assert!(bench_workers() >= 1 || std::env::var("BENCH_WORKERS").is_ok());
    }

    #[test]
    fn workers_flag_routes_both_trainers() {
        let mut cfg = quick_cfg();
        cfg.workload.total_requests = 400;
        cfg.ppo.horizon = 64;
        let seq = train_ppo_workers(&cfg, RewardCfg::overfit(), 1, 1);
        assert!(seq.stats.decisions > 0);
        let par = train_ppo_workers(&cfg, RewardCfg::overfit(), 2, 2);
        assert!(par.stats.updates > 0);
    }

    #[test]
    fn trace_study_builds_a_per_scenario_matrix() {
        use crate::config::{PpoCfg, WIDTHS};

        // shape is all from_checkpoint guards — an untrained policy
        // checkpoint keeps the study test fast
        let ppo = PpoRouter::new(3, WIDTHS.to_vec(), PpoCfg::default(), 7);
        let path = std::env::temp_dir().join(format!(
            "slim_sched_study_ckpt_{}.json",
            std::process::id()
        ));
        let path = path.to_str().unwrap().to_string();
        std::fs::write(&path, ppo.to_json().to_string_pretty()).unwrap();

        let field: Vec<String> =
            ["random", "edf"].iter().map(|s| s.to_string()).collect();
        let report = trace_study(&path, &field, 100, 42, 1, false).unwrap();
        let entries = report.get("scenarios").and_then(Json::as_arr).unwrap();
        assert_eq!(entries.len(), crate::sim::scenarios::all().len());

        let by_name = |name: &str| {
            entries
                .iter()
                .find(|e| e.get("scenario").and_then(Json::as_str) == Some(name))
                .unwrap_or_else(|| panic!("scenario {name} missing"))
        };
        // the paper cluster matches the checkpoint shape: the ppo entrant
        // joins the field and its pair carries the significance block
        let paper = by_name("paper");
        assert_eq!(paper.get("ppo_compatible").and_then(Json::as_bool), Some(true));
        let pairs = paper
            .get("report")
            .and_then(|r| r.get("pairs"))
            .and_then(Json::as_arr)
            .unwrap();
        assert_eq!(pairs.len(), 2); // edf + ppo vs the random baseline
        let ppo_pair = &pairs[1];
        assert!(ppo_pair
            .get("router")
            .and_then(Json::as_str)
            .unwrap()
            .starts_with("ppo:"));
        assert!(ppo_pair.get("sign_test_p").is_some());
        assert!(ppo_pair.get("latency_delta_ci95").is_some());
        assert!(ppo_pair.get("per_request").is_none()); // matrix, not dump

        // a 4-device scenario cannot load the 3-device checkpoint: the
        // study records the incompatibility and compares the field alone
        let hetero = by_name("hetero-mixed");
        assert_eq!(
            hetero.get("ppo_compatible").and_then(Json::as_bool),
            Some(false)
        );
        assert!(hetero.get("ppo_error").is_some());
        let pairs = hetero
            .get("report")
            .and_then(|r| r.get("pairs"))
            .and_then(Json::as_arr)
            .unwrap();
        assert_eq!(pairs.len(), 1); // edf only

        // the whole matrix is deterministic
        let again = trace_study(&path, &field, 100, 42, 1, false).unwrap();
        assert_eq!(report.to_string_pretty(), again.to_string_pretty());
        std::fs::remove_file(&path).ok();

        // a typoed checkpoint path is a global failure, not a quiet
        // all-scenarios-incompatible matrix
        let err =
            trace_study("/nonexistent/x.json", &field, 50, 1, 1, false).unwrap_err();
        assert!(err.contains("cannot read"), "{err}");
    }

    #[test]
    fn study_is_byte_identical_across_eval_threads() {
        use crate::config::{PpoCfg, WIDTHS};

        // the 3-device checkpoint is shape-incompatible with the
        // 4-device hetero-mixed scenario, so the fan-out also covers
        // the ppo_error path concurrently
        let ppo = PpoRouter::new(3, WIDTHS.to_vec(), PpoCfg::default(), 11);
        let path = std::env::temp_dir().join(format!(
            "slim_sched_study_fanout_ckpt_{}.json",
            std::process::id()
        ));
        let path = path.to_str().unwrap().to_string();
        std::fs::write(&path, ppo.to_json().to_string_pretty()).unwrap();

        let field: Vec<String> =
            ["random", "edf"].iter().map(|s| s.to_string()).collect();
        let sequential = trace_study(&path, &field, 80, 42, 1, false)
            .unwrap()
            .to_string_pretty();
        for threads in [2usize, 4] {
            let parallel = trace_study(&path, &field, 80, 42, threads, false)
                .unwrap()
                .to_string_pretty();
            assert_eq!(sequential, parallel, "study diverged at {threads} threads");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn compare_failures_land_per_scenario_not_study_wide() {
        use crate::config::{PpoCfg, WIDTHS};

        let ppo = PpoRouter::new(3, WIDTHS.to_vec(), PpoCfg::default(), 7);
        let path = std::env::temp_dir().join(format!(
            "slim_sched_study_cerr_ckpt_{}.json",
            std::process::id()
        ));
        let path = path.to_str().unwrap().to_string();
        std::fs::write(&path, ppo.to_json().to_string_pretty()).unwrap();

        // "edf+bogus" records fine under the baseline but fails every
        // scenario's compare (unknown router) — the study must report
        // the failure cell by cell, not abort
        let field: Vec<String> =
            ["random", "edf+bogus"].iter().map(|s| s.to_string()).collect();
        let report = trace_study(&path, &field, 60, 7, 2, false).unwrap();
        let entries = report.get("scenarios").and_then(Json::as_arr).unwrap();
        assert_eq!(entries.len(), crate::sim::scenarios::all().len());
        for e in entries {
            if e.get("record_error").is_some() {
                continue;
            }
            let err = e
                .get("compare_error")
                .and_then(Json::as_str)
                .unwrap_or_else(|| panic!("entry lacks compare_error: {e:?}"));
            assert!(err.contains("unknown router"), "{err}");
            assert!(e.get("report").is_none());
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn pct_change_math() {
        assert!((pct_change(8.98, 0.318) + 96.458).abs() < 0.01);
        assert_eq!(pct_change(0.0, 5.0), 0.0);
    }
}
