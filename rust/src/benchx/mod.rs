//! Mini statistical benchmark harness (criterion substitute).
//!
//! Each `[[bench]]` target sets `harness = false` and drives this module:
//! warmup, timed samples, mean/σ/p50/p99 in adaptive units, and a
//! `Table`/`Series` printer so every paper table and figure regenerator
//! emits the same rows the paper reports. Honors `--quick` (fewer samples)
//! and `BENCH_FILTER=<substr>`.

use std::time::Instant;

use crate::metrics::Summary;
use crate::utilx::json::{obj, Json};

/// Timing result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub samples: usize,
    pub iters_per_sample: u64,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

impl BenchResult {
    /// Machine-readable form for the `BENCH_*.json` perf trajectory.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("samples", Json::Num(self.samples as f64)),
            ("iters_per_sample", Json::Num(self.iters_per_sample as f64)),
            ("mean_ns", Json::Num(self.mean_ns)),
            ("std_ns", Json::Num(self.std_ns)),
            ("p50_ns", Json::Num(self.p50_ns)),
            ("p99_ns", Json::Num(self.p99_ns)),
        ])
    }

    pub fn print(&self) {
        println!(
            "{:<44} {:>12}/iter  σ {:>10}  p50 {:>10}  p99 {:>10}  ({} samples × {} iters)",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.std_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p99_ns),
            self.samples,
            self.iters_per_sample,
        );
    }
}

/// Benchmark driver.
pub struct Bench {
    quick: bool,
    filter: Option<String>,
    results: Vec<BenchResult>,
    /// Named derived scalars (ratios, speedups) carried into the JSON
    /// emission alongside the raw timings.
    metrics: Vec<(String, f64)>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::from_env()
    }
}

impl Bench {
    /// Configure from argv + env (`--quick`, `BENCH_FILTER`).
    pub fn from_env() -> Self {
        let argv: Vec<String> = std::env::args().collect();
        // `cargo bench` passes `--bench`; treat `--quick` anywhere.
        let quick = argv.iter().any(|a| a == "--quick")
            || std::env::var("BENCH_QUICK").is_ok();
        let filter = std::env::var("BENCH_FILTER").ok();
        Bench { quick, filter, results: Vec::new(), metrics: Vec::new() }
    }

    /// Whether quick mode (`--quick` / `BENCH_QUICK`) is active — the
    /// single source of truth for benches that size their own workloads.
    pub fn quick(&self) -> bool {
        self.quick
    }

    fn skip(&self, name: &str) -> bool {
        match &self.filter {
            Some(f) => !name.contains(f.as_str()),
            None => false,
        }
    }

    /// Time `f`, auto-calibrating iterations per sample to ~5 ms.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) {
        if self.skip(name) {
            return;
        }
        // Warmup + calibration.
        let mut iters = 1u64;
        loop {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            let dt = t0.elapsed().as_nanos() as f64;
            if dt > 2e6 || iters >= 1 << 20 {
                let target = 5e6; // 5 ms / sample
                iters = ((iters as f64) * (target / dt.max(1.0)))
                    .clamp(1.0, 1e7) as u64;
                break;
            }
            iters *= 4;
        }
        let samples = if self.quick { 10 } else { 30 };
        let mut summary = Summary::default();
        for _ in 0..samples {
            let t0 = Instant::now();
            for _ in 0..iters.max(1) {
                f();
            }
            let per_iter = t0.elapsed().as_nanos() as f64 / iters.max(1) as f64;
            summary.record(per_iter);
        }
        let result = BenchResult {
            name: name.to_string(),
            samples,
            iters_per_sample: iters.max(1),
            mean_ns: summary.mean(),
            std_ns: summary.std(),
            p50_ns: summary.percentile(50.0),
            p99_ns: summary.percentile(99.0),
        };
        result.print();
        self.results.push(result);
    }

    /// Run a one-shot (non-repeated) measured section — for end-to-end
    /// simulations where a single run is already statistically aggregated.
    /// Recorded as a one-sample result so it lands in the JSON emission.
    pub fn once<F: FnOnce()>(&mut self, name: &str, f: F) {
        if self.skip(name) {
            return;
        }
        let t0 = Instant::now();
        f();
        let dt = t0.elapsed().as_nanos() as f64;
        println!("{:<44} {:>12} (single run)", name, fmt_ns(dt));
        self.results.push(BenchResult {
            name: name.to_string(),
            samples: 1,
            iters_per_sample: 1,
            mean_ns: dt,
            std_ns: 0.0,
            p50_ns: dt,
            p99_ns: dt,
        });
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Mean time of an already-recorded bench by exact name.
    pub fn mean_ns_of(&self, name: &str) -> Option<f64> {
        self.results.iter().find(|r| r.name == name).map(|r| r.mean_ns)
    }

    /// Record a named derived scalar (e.g. a batched-vs-per-head
    /// speedup ratio); emitted under `"metrics"` in the bench JSON.
    pub fn metric(&mut self, name: &str, value: f64) {
        println!("{name:<44} {value:>12.3}  (derived metric)");
        self.metrics.push((name.to_string(), value));
    }

    /// Write `BENCH_<bench_name>.json` (into `BENCH_JSON_DIR`, default
    /// cwd) so CI and perf-trajectory tooling can diff runs — every
    /// bench target calls this after printing its human-readable output.
    /// Write failures only warn: benches must not fail on a read-only fs.
    pub fn emit_json(&self, bench_name: &str) {
        let dir = std::env::var("BENCH_JSON_DIR").unwrap_or_else(|_| ".".to_string());
        let path = format!("{dir}/BENCH_{bench_name}.json");
        let doc = obj(vec![
            ("bench", Json::Str(bench_name.to_string())),
            ("quick", Json::Bool(self.quick)),
            (
                "scenario",
                match std::env::var("BENCH_SCENARIO") {
                    Ok(s) if !s.is_empty() => Json::Str(s),
                    _ => Json::Str("paper".to_string()),
                },
            ),
            (
                "results",
                Json::Arr(self.results.iter().map(BenchResult::to_json).collect()),
            ),
            (
                "metrics",
                Json::Obj(
                    self.metrics
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::Num(*v)))
                        .collect(),
                ),
            ),
        ]);
        match std::fs::write(&path, doc.to_string_pretty()) {
            Ok(()) => println!("bench json: {path}"),
            Err(e) => eprintln!("bench json: cannot write {path}: {e}"),
        }
    }
}

/// Fixed-width table printer for paper-style rows.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        self.rows.push(cells.to_vec());
    }

    pub fn rowf(&mut self, cells: &[f64], precision: usize) {
        self.rows
            .push(cells.iter().map(|x| format!("{x:.precision$}")).collect());
    }

    /// Render with per-column widths.
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(String::len).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate().take(ncols) {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = format!("\n=== {} ===\n", self.title);
        let fmt_row = |cells: &[String], widths: &[usize]| {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncols - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_timing() {
        let mut b = Bench { quick: true, filter: None, results: vec![], metrics: vec![] };
        let mut acc = 0u64;
        b.bench("noop-ish", || {
            acc = acc.wrapping_add(std::hint::black_box(1));
        });
        let r = &b.results()[0];
        assert!(r.mean_ns > 0.0 && r.mean_ns < 1e6, "mean={}", r.mean_ns);
        assert!(r.p50_ns > 0.0);
    }

    #[test]
    fn filter_skips() {
        let mut b = Bench {
            quick: true,
            filter: Some("match-me".into()),
            results: vec![],
            metrics: vec![],
        };
        b.bench("other", || {});
        assert!(b.results().is_empty());
        b.bench("match-me-1", || {});
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn once_records_a_single_sample_result() {
        let mut b = Bench { quick: true, filter: None, results: vec![], metrics: vec![] };
        b.once("one-shot", || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(b.results().len(), 1);
        let r = &b.results()[0];
        assert_eq!(r.samples, 1);
        assert!(r.mean_ns > 0.0);
        assert_eq!(r.p50_ns, r.mean_ns);
    }

    #[test]
    fn metrics_and_lookup() {
        let mut b = Bench { quick: true, filter: None, results: vec![], metrics: vec![] };
        b.bench("a/fast", || {
            std::hint::black_box(1 + 1);
        });
        let mean = b.mean_ns_of("a/fast").expect("recorded");
        assert!(mean > 0.0);
        assert!(b.mean_ns_of("missing").is_none());
        b.metric("a/speedup_x", 2.5);
        assert_eq!(b.metrics.len(), 1);
        assert_eq!(b.metrics[0].0, "a/speedup_x");
    }

    #[test]
    fn bench_result_json_shape() {
        let r = BenchResult {
            name: "x/y".into(),
            samples: 30,
            iters_per_sample: 100,
            mean_ns: 1234.5,
            std_ns: 10.0,
            p50_ns: 1200.0,
            p99_ns: 1500.0,
        };
        let j = r.to_json();
        assert_eq!(j.get("name").and_then(Json::as_str), Some("x/y"));
        assert_eq!(j.get("mean_ns").and_then(Json::as_f64), Some(1234.5));
        assert_eq!(j.get("samples").and_then(Json::as_usize), Some(30));
        // round-trips through the parser (the trajectory tooling's path)
        let parsed = Json::parse(&j.to_string_pretty()).expect("parses");
        assert_eq!(parsed.get("p99_ns").and_then(Json::as_f64), Some(1500.0));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Fig 1", &["batch", "util_025", "util_100"]);
        t.rowf(&[1.0, 0.05, 0.2], 2);
        t.rowf(&[32.0, 0.55, 0.99], 2);
        let s = t.render();
        assert!(s.contains("Fig 1"));
        assert!(s.contains("batch"));
        assert!(s.lines().count() >= 5);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5.0e4).contains("µs"));
        assert!(fmt_ns(5.0e7).contains("ms"));
        assert!(fmt_ns(5.0e9).contains("s"));
    }
}
