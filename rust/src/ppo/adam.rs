//! Adam optimizer over MLP-shaped parameters (Kingma & Ba), with the
//! bias-corrected moment estimates. Gradients arrive in an `Mlp`-shaped
//! accumulator (see [`super::mlp::Mlp::zeros_like`]).

use super::mlp::Mlp;

/// Adam state (first/second moments mirror the parameter shapes).
#[derive(Clone, Debug)]
pub struct Adam {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    m: Mlp,
    v: Mlp,
    t: u64,
}

impl Adam {
    pub fn new(params: &Mlp, lr: f64) -> Self {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            m: params.zeros_like(),
            v: params.zeros_like(),
            t: 0,
        }
    }

    /// One Adam step: params ← params − lr·m̂/(√v̂+ε).
    pub fn step(&mut self, params: &mut Mlp, grads: &Mlp) {
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        let (beta1, beta2, eps, lr) = (self.beta1, self.beta2, self.eps, self.lr);

        for l in 0..params.w.len() {
            for i in 0..params.w[l].data.len() {
                let g = grads.w[l].data[i];
                let m = &mut self.m.w[l].data[i];
                let v = &mut self.v.w[l].data[i];
                *m = beta1 * *m + (1.0 - beta1) * g;
                *v = beta2 * *v + (1.0 - beta2) * g * g;
                let mhat = *m / b1t;
                let vhat = *v / b2t;
                params.w[l].data[i] -= lr * mhat / (vhat.sqrt() + eps);
            }
            for i in 0..params.b[l].len() {
                let g = grads.b[l][i];
                let m = &mut self.m.b[l][i];
                let v = &mut self.v.b[l][i];
                *m = beta1 * *m + (1.0 - beta1) * g;
                *v = beta2 * *v + (1.0 - beta2) * g * g;
                let mhat = *m / b1t;
                let vhat = *v / b2t;
                params.b[l][i] -= lr * mhat / (vhat.sqrt() + eps);
            }
        }
    }

    pub fn steps_taken(&self) -> u64 {
        self.t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::utilx::Rng;

    /// Minimize ||Wx - y||² over a fixed (x, y) pair; Adam should reach
    /// near-zero loss quickly on this convex toy problem.
    #[test]
    fn converges_on_least_squares() {
        let mut rng = Rng::new(1);
        let mut mlp = Mlp::new(&[4, 3], &mut rng);
        let mut adam = Adam::new(&mlp, 0.05);
        let x = vec![1.0, -0.5, 0.25, 2.0];
        let target = vec![0.3, -0.7, 1.1];

        let mut last = f64::INFINITY;
        for _ in 0..300 {
            let (y, cache) = mlp.forward(&x);
            let dout: Vec<f64> =
                y.iter().zip(&target).map(|(yi, ti)| 2.0 * (yi - ti)).collect();
            let mut grads = mlp.zeros_like();
            mlp.backward(&cache, &dout, &mut grads);
            adam.step(&mut mlp, &grads);
            last = y.iter().zip(&target).map(|(a, b)| (a - b) * (a - b)).sum();
        }
        assert!(last < 1e-6, "loss={last}");
        assert_eq!(adam.steps_taken(), 300);
    }

    #[test]
    fn nonconvex_loss_decreases() {
        let mut rng = Rng::new(2);
        let mut mlp = Mlp::new(&[6, 16, 1], &mut rng);
        let mut adam = Adam::new(&mlp, 0.01);
        let inputs: Vec<Vec<f64>> =
            (0..16).map(|_| (0..6).map(|_| rng.normal()).collect()).collect();
        let targets: Vec<f64> =
            inputs.iter().map(|x| x[0] * x[1] + x[2].sin()).collect();

        let loss_of = |m: &Mlp| -> f64 {
            inputs
                .iter()
                .zip(&targets)
                .map(|(x, t)| {
                    let (y, _) = m.forward(x);
                    (y[0] - t) * (y[0] - t)
                })
                .sum::<f64>()
                / inputs.len() as f64
        };
        let initial = loss_of(&mlp);
        for _ in 0..400 {
            let mut grads = mlp.zeros_like();
            for (x, t) in inputs.iter().zip(&targets) {
                let (y, cache) = mlp.forward(x);
                mlp.backward(&cache, &[2.0 * (y[0] - t)], &mut grads);
            }
            grads.scale(1.0 / inputs.len() as f64);
            adam.step(&mut mlp, &grads);
        }
        let fin = loss_of(&mlp);
        assert!(fin < initial * 0.2, "initial={initial} final={fin}");
    }

    #[test]
    fn zero_gradient_keeps_params() {
        let mut rng = Rng::new(3);
        let mut mlp = Mlp::new(&[2, 2], &mut rng);
        let before = mlp.clone();
        let zeros = mlp.zeros_like();
        let mut adam = Adam::new(&mlp, 0.1);
        adam.step(&mut mlp, &zeros);
        for l in 0..mlp.w.len() {
            for (a, b) in mlp.w[l].data.iter().zip(&before.w[l].data) {
                assert!((a - b).abs() < 1e-12);
            }
        }
    }
}
