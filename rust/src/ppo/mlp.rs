//! Dense MLP with manual forward/backward (no autograd in the offline
//! crate set). tanh hidden layers, linear output; f64 everywhere — the
//! networks are tiny (≈11→64→64→12) so precision beats speed here.

use crate::utilx::Rng;

/// Row-major matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn at(&self, r: usize, c: usize) -> f64 {
        self.data[r * self.cols + c]
    }

    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f64 {
        &mut self.data[r * self.cols + c]
    }

    /// y = W x (W: rows×cols, x: cols) -> rows
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        debug_assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0; self.rows];
        for r in 0..self.rows {
            let row = &self.data[r * self.cols..(r + 1) * self.cols];
            y[r] = row.iter().zip(x).map(|(w, xi)| w * xi).sum();
        }
        y
    }

    /// y = Wᵀ g (for backprop through the layer input).
    pub fn matvec_t(&self, g: &[f64]) -> Vec<f64> {
        debug_assert_eq!(g.len(), self.rows);
        let mut y = vec![0.0; self.cols];
        for r in 0..self.rows {
            let row = &self.data[r * self.cols..(r + 1) * self.cols];
            for (c, w) in row.iter().enumerate() {
                y[c] += w * g[r];
            }
        }
        y
    }
}

/// MLP parameters (and, reused, their gradients).
#[derive(Clone, Debug)]
pub struct Mlp {
    pub sizes: Vec<usize>,
    pub w: Vec<Mat>,
    pub b: Vec<Vec<f64>>,
}

/// Forward cache for one input (activations per layer).
#[derive(Clone, Debug)]
pub struct Cache {
    /// acts[0] = input; acts[i] = post-activation of layer i.
    pub acts: Vec<Vec<f64>>,
}

impl Mlp {
    /// Orthogonal-ish init: scaled He-normal for tanh.
    pub fn new(sizes: &[usize], rng: &mut Rng) -> Self {
        assert!(sizes.len() >= 2);
        let mut w = Vec::new();
        let mut b = Vec::new();
        for i in 0..sizes.len() - 1 {
            let (fan_in, fan_out) = (sizes[i], sizes[i + 1]);
            let scale = (1.0 / fan_in as f64).sqrt();
            let mut m = Mat::zeros(fan_out, fan_in);
            for v in &mut m.data {
                *v = rng.normal() * scale;
            }
            w.push(m);
            b.push(vec![0.0; fan_out]);
        }
        Mlp { sizes: sizes.to_vec(), w, b }
    }

    /// Zero-shaped clone for gradient accumulation.
    pub fn zeros_like(&self) -> Self {
        Mlp {
            sizes: self.sizes.clone(),
            w: self.w.iter().map(|m| Mat::zeros(m.rows, m.cols)).collect(),
            b: self.b.iter().map(|v| vec![0.0; v.len()]).collect(),
        }
    }

    pub fn n_layers(&self) -> usize {
        self.w.len()
    }

    /// Allocation-light forward for the serving hot path (no cache): two
    /// ping-pong buffers instead of one Vec per layer. ~2× faster than
    /// [`Mlp::forward`] on the router-sized net (see EXPERIMENTS.md §Perf).
    pub fn forward_nocache(&self, x: &[f64], scratch: &mut (Vec<f64>, Vec<f64>)) {
        let (a, b) = scratch;
        a.clear();
        a.extend_from_slice(x);
        for l in 0..self.n_layers() {
            let w = &self.w[l];
            b.clear();
            b.resize(w.rows, 0.0);
            for r in 0..w.rows {
                let row = &w.data[r * w.cols..(r + 1) * w.cols];
                let mut z: f64 = self.b[l][r];
                for (wi, xi) in row.iter().zip(a.iter()) {
                    z += wi * xi;
                }
                b[r] = if l + 1 < self.n_layers() { z.tanh() } else { z };
            }
            std::mem::swap(a, b);
        }
        // result lives in `a` (post-swap)
    }

    /// Matrix forward over `n` stacked inputs (row-major `[n, in_dim]`
    /// in `xs`): every layer is computed into one shared activation
    /// buffer, with the weight row streamed once across all samples —
    /// the batched path `Router::plan` amortizes policy inference with.
    /// Outputs land in `scratch.0` as row-major `[n, out_dim]`.
    pub fn forward_batch(
        &self,
        xs: &[f64],
        n: usize,
        scratch: &mut (Vec<f64>, Vec<f64>),
    ) {
        debug_assert_eq!(xs.len(), n * self.sizes[0]);
        let (a, b) = scratch;
        a.clear();
        a.extend_from_slice(xs);
        let mut width_in = self.sizes[0];
        for l in 0..self.n_layers() {
            let w = &self.w[l];
            let rows = w.rows;
            let last = l + 1 == self.n_layers();
            b.clear();
            b.resize(n * rows, 0.0);
            for r in 0..rows {
                let row = &w.data[r * w.cols..(r + 1) * w.cols];
                let bias = self.b[l][r];
                for s in 0..n {
                    let x = &a[s * width_in..(s + 1) * width_in];
                    let mut z: f64 = bias;
                    for (wi, xi) in row.iter().zip(x) {
                        z += wi * xi;
                    }
                    b[s * rows + r] = if last { z } else { z.tanh() };
                }
            }
            std::mem::swap(a, b);
            width_in = rows;
        }
        // result lives in `a` (post-swap)
    }

    /// Forward pass; output layer is linear, hiddens are tanh.
    pub fn forward(&self, x: &[f64]) -> (Vec<f64>, Cache) {
        debug_assert_eq!(x.len(), self.sizes[0]);
        let mut acts = vec![x.to_vec()];
        let mut h = x.to_vec();
        for l in 0..self.n_layers() {
            let mut z = self.w[l].matvec(&h);
            for (zi, bi) in z.iter_mut().zip(&self.b[l]) {
                *zi += bi;
            }
            if l + 1 < self.n_layers() {
                for zi in &mut z {
                    *zi = zi.tanh();
                }
            }
            acts.push(z.clone());
            h = z;
        }
        (h, Cache { acts })
    }

    /// Backward: accumulate dL/dW, dL/db into `grads` given dL/d(output).
    pub fn backward(&self, cache: &Cache, dout: &[f64], grads: &mut Mlp) {
        let mut delta = dout.to_vec();
        for l in (0..self.n_layers()).rev() {
            // delta currently refers to post-activation of layer l;
            // apply tanh' for hidden layers (output layer is linear)
            if l + 1 < self.n_layers() {
                let a = &cache.acts[l + 1];
                for (d, ai) in delta.iter_mut().zip(a) {
                    *d *= 1.0 - ai * ai;
                }
            }
            let input = &cache.acts[l];
            for r in 0..self.w[l].rows {
                let g = delta[r];
                let row =
                    &mut grads.w[l].data[r * self.w[l].cols..(r + 1) * self.w[l].cols];
                for (c, xi) in input.iter().enumerate() {
                    row[c] += g * xi;
                }
                grads.b[l][r] += g;
            }
            if l > 0 {
                delta = self.w[l].matvec_t(&delta);
            }
        }
    }

    /// Iterate all parameters mutably alongside another Mlp's (for Adam).
    pub fn for_each_param(&mut self, other: &Mlp, mut f: impl FnMut(&mut f64, f64)) {
        for l in 0..self.w.len() {
            for (p, g) in self.w[l].data.iter_mut().zip(&other.w[l].data) {
                f(p, *g);
            }
            for (p, g) in self.b[l].iter_mut().zip(&other.b[l]) {
                f(p, *g);
            }
        }
    }

    /// Global L2 norm of all entries (for gradient clipping).
    pub fn global_norm(&self) -> f64 {
        let mut s = 0.0;
        for l in 0..self.w.len() {
            s += self.w[l].data.iter().map(|x| x * x).sum::<f64>();
            s += self.b[l].iter().map(|x| x * x).sum::<f64>();
        }
        s.sqrt()
    }

    /// Scale all entries (gradient clipping / averaging).
    pub fn scale(&mut self, k: f64) {
        for l in 0..self.w.len() {
            for v in &mut self.w[l].data {
                *v *= k;
            }
            for v in &mut self.b[l] {
                *v *= k;
            }
        }
    }

    pub fn param_count(&self) -> usize {
        self.w.iter().map(|m| m.data.len()).sum::<usize>()
            + self.b.iter().map(Vec::len).sum::<usize>()
    }

    /// Serialize to JSON (checkpointing trained routers).
    pub fn to_json(&self) -> crate::utilx::Json {
        use crate::utilx::json::{arr_f64, obj, Json};
        obj(vec![
            (
                "sizes",
                Json::Arr(self.sizes.iter().map(|&s| Json::Num(s as f64)).collect()),
            ),
            (
                "w",
                Json::Arr(self.w.iter().map(|m| arr_f64(&m.data)).collect()),
            ),
            (
                "b",
                Json::Arr(self.b.iter().map(|v| arr_f64(v)).collect()),
            ),
        ])
    }

    /// Deserialize from `to_json` output.
    pub fn from_json(json: &crate::utilx::Json) -> Option<Mlp> {
        let sizes = json.get("sizes")?.as_usize_vec()?;
        if sizes.len() < 2 {
            return None;
        }
        let w_arrays = json.get("w")?.as_arr()?;
        let b_arrays = json.get("b")?.as_arr()?;
        if w_arrays.len() != sizes.len() - 1 || b_arrays.len() != sizes.len() - 1 {
            return None;
        }
        let mut w = Vec::new();
        let mut b = Vec::new();
        for i in 0..sizes.len() - 1 {
            let data = w_arrays[i].as_f64_vec()?;
            if data.len() != sizes[i + 1] * sizes[i] {
                return None;
            }
            w.push(Mat { rows: sizes[i + 1], cols: sizes[i], data });
            let bias = b_arrays[i].as_f64_vec()?;
            if bias.len() != sizes[i + 1] {
                return None;
            }
            b.push(bias);
        }
        Some(Mlp { sizes, w, b })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn finite_diff_check(sizes: &[usize], seed: u64) {
        let mut rng = Rng::new(seed);
        let mlp = Mlp::new(sizes, &mut rng);
        let x: Vec<f64> = (0..sizes[0]).map(|_| rng.normal()).collect();
        // scalar loss = sum of squares of outputs
        let loss = |m: &Mlp| {
            let (y, _) = m.forward(&x);
            y.iter().map(|v| v * v).sum::<f64>()
        };
        let (y, cache) = mlp.forward(&x);
        let dout: Vec<f64> = y.iter().map(|v| 2.0 * v).collect();
        let mut grads = mlp.zeros_like();
        mlp.backward(&cache, &dout, &mut grads);

        let eps = 1e-6;
        // check a few random parameters per layer
        let mut check_rng = Rng::new(seed + 1);
        for l in 0..mlp.n_layers() {
            for _ in 0..4 {
                let idx = check_rng.index(mlp.w[l].data.len());
                let mut plus = mlp.clone();
                plus.w[l].data[idx] += eps;
                let mut minus = mlp.clone();
                minus.w[l].data[idx] -= eps;
                let numeric = (loss(&plus) - loss(&minus)) / (2.0 * eps);
                let analytic = grads.w[l].data[idx];
                assert!(
                    (numeric - analytic).abs() < 1e-4 * (1.0 + numeric.abs()),
                    "layer {l} idx {idx}: numeric {numeric} vs analytic {analytic}"
                );
            }
            let bidx = check_rng.index(mlp.b[l].len());
            let mut plus = mlp.clone();
            plus.b[l][bidx] += eps;
            let mut minus = mlp.clone();
            minus.b[l][bidx] -= eps;
            let numeric = (loss(&plus) - loss(&minus)) / (2.0 * eps);
            let analytic = grads.b[l][bidx];
            assert!(
                (numeric - analytic).abs() < 1e-4 * (1.0 + numeric.abs()),
                "layer {l} bias {bidx}: numeric {numeric} vs analytic {analytic}"
            );
        }
    }

    #[test]
    fn gradients_match_finite_differences() {
        finite_diff_check(&[5, 16, 8], 1);
        finite_diff_check(&[11, 32, 32, 12], 2);
        finite_diff_check(&[3, 4], 3); // single linear layer
    }

    #[test]
    fn forward_shapes_and_determinism() {
        let mut rng = Rng::new(4);
        let mlp = Mlp::new(&[6, 10, 4], &mut rng);
        let x = vec![0.5; 6];
        let (y1, _) = mlp.forward(&x);
        let (y2, _) = mlp.forward(&x);
        assert_eq!(y1.len(), 4);
        assert_eq!(y1, y2);
    }

    #[test]
    fn forward_batch_rows_match_single_forward() {
        let mut rng = Rng::new(11);
        let mlp = Mlp::new(&[6, 12, 5], &mut rng);
        let n = 7;
        let xs: Vec<f64> = (0..n * 6).map(|_| rng.normal()).collect();
        let mut scratch = (Vec::new(), Vec::new());
        mlp.forward_batch(&xs, n, &mut scratch);
        assert_eq!(scratch.0.len(), n * 5);
        let mut single = (Vec::new(), Vec::new());
        for s in 0..n {
            // same accumulation order as forward_nocache → bit-identical
            mlp.forward_nocache(&xs[s * 6..(s + 1) * 6], &mut single);
            for (r, &want) in single.0.iter().enumerate() {
                let got = scratch.0[s * 5 + r];
                assert_eq!(got.to_bits(), want.to_bits(), "row {s} out {r}");
            }
        }
    }

    #[test]
    fn forward_batch_of_one_matches_forward() {
        let mut rng = Rng::new(12);
        let mlp = Mlp::new(&[4, 8, 3], &mut rng);
        let x: Vec<f64> = (0..4).map(|_| rng.normal()).collect();
        let mut scratch = (Vec::new(), Vec::new());
        mlp.forward_batch(&x, 1, &mut scratch);
        let (y, _) = mlp.forward(&x);
        for (a, b) in scratch.0.iter().zip(&y) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn hidden_activations_bounded_by_tanh() {
        let mut rng = Rng::new(5);
        let mlp = Mlp::new(&[4, 8, 2], &mut rng);
        let x = vec![100.0; 4];
        let (_, cache) = mlp.forward(&x);
        assert!(cache.acts[1].iter().all(|a| a.abs() <= 1.0));
    }

    #[test]
    fn matvec_t_is_transpose() {
        let mut m = Mat::zeros(2, 3);
        m.data = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        assert_eq!(m.matvec(&[1.0, 0.0, 0.0]), vec![1.0, 4.0]);
        assert_eq!(m.matvec_t(&[1.0, 0.0]), vec![1.0, 2.0, 3.0]);
        assert_eq!(m.matvec_t(&[0.0, 1.0]), vec![4.0, 5.0, 6.0]);
    }

    #[test]
    fn global_norm_and_scale() {
        let mut rng = Rng::new(6);
        let mut mlp = Mlp::new(&[2, 2], &mut rng);
        let n0 = mlp.global_norm();
        assert!(n0 > 0.0);
        mlp.scale(0.5);
        assert!((mlp.global_norm() - 0.5 * n0).abs() < 1e-12);
    }

    #[test]
    fn param_count() {
        let mut rng = Rng::new(7);
        let mlp = Mlp::new(&[11, 64, 64, 12], &mut rng);
        assert_eq!(
            mlp.param_count(),
            11 * 64 + 64 + 64 * 64 + 64 + 64 * 12 + 12
        );
    }

    #[test]
    fn json_roundtrip_preserves_function() {
        let mut rng = Rng::new(8);
        let mlp = Mlp::new(&[5, 8, 3], &mut rng);
        let json = mlp.to_json();
        let text = json.to_string_compact();
        let parsed = crate::utilx::Json::parse(&text).unwrap();
        let restored = Mlp::from_json(&parsed).unwrap();
        let x = vec![0.1, -0.4, 0.9, 0.0, 2.0];
        let (y1, _) = mlp.forward(&x);
        let (y2, _) = restored.forward(&x);
        for (a, b) in y1.iter().zip(&y2) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn from_json_rejects_malformed() {
        let bad = crate::utilx::Json::parse(r#"{"sizes":[2,3],"w":[[1,2]],"b":[[0,0,0]]}"#)
            .unwrap();
        assert!(Mlp::from_json(&bad).is_none());
    }
}
