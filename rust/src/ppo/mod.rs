//! The PPO router (§III-B), implemented from scratch.
//!
//! A shared MLP maps the eq. 1 telemetry state to three categorical heads
//! (server, width, micro-batch group — eq. 2–4) and a scalar value. The
//! server head is ε-mixed with a uniform distribution and the mixture is
//! accounted for in the PPO ratio (eq. 5–6). Rewards follow eq. 7; one-
//! step advantages with normalization (eq. 8); the update minimizes the
//! clipped-surrogate + value + entropy objective (eq. 10–13) for K epochs
//! with gradient-norm clipping — all hyper-parameters in
//! [`crate::config::PpoCfg`].
//!
//! No autograd framework exists in the offline crate set, so
//! [`mlp`]/[`adam`] implement dense forward/backward and Adam by hand;
//! [`policy`] adds the factored heads and their analytic gradients;
//! [`update`] assembles the PPO step; [`router_impl`] adapts everything to
//! the [`crate::coordinator::Router`] trait so the engine can drive
//! training and evaluation identically.

pub mod adam;
pub mod buffer;
pub mod mlp;
pub mod parallel;
pub mod policy;
pub mod router_impl;
pub mod update;

pub use buffer::{RolloutBuffer, Transition};
pub use mlp::Mlp;
pub use parallel::train_parallel;
pub use policy::{ActionTriple, BatchHeadEval, Policy, PolicyEval};
pub use router_impl::{
    run_ppo_episode, run_ppo_episode_io, PpoRouter, SharedPpoRouter, TrainStats,
};
pub use update::ppo_update;
