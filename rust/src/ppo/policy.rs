//! Factored categorical policy (eq. 2–6).
//!
//! One shared MLP emits `[logits_srv | logits_w | logits_g | value]`. The
//! policy factorizes as a product of categoricals (eq. 4); the server head
//! is ε-mixed with uniform exploration and the mixture enters the
//! likelihood (eq. 5), so the PPO ratio stays on-policy (eq. 6, 9).

use crate::utilx::Rng;

use super::mlp::{Cache, Mlp};

/// Factored action (indices into the server/width/group sets).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ActionTriple {
    pub srv: usize,
    pub w: usize,
    pub g: usize,
}

/// Per-head probabilities and value from one batched matrix forward
/// (diagnostics over a routing window; no backward cache).
#[derive(Clone, Debug)]
pub struct BatchHeadEval {
    pub p_srv: Vec<f64>,
    pub p_w: Vec<f64>,
    pub p_g: Vec<f64>,
    pub value: f64,
}

/// Everything the update needs about one state evaluation.
#[derive(Clone, Debug)]
pub struct PolicyEval {
    /// Joint log π̃(a|s) (eq. 6 — server head mixed).
    pub logp: f64,
    pub value: f64,
    /// Σ_head H(π_θ^head) — unmixed, as in eq. 12.
    pub entropy: f64,
    pub p_srv: Vec<f64>,
    pub p_w: Vec<f64>,
    pub p_g: Vec<f64>,
    pub cache: Cache,
}

/// The factored policy network.
#[derive(Clone, Debug)]
pub struct Policy {
    pub mlp: Mlp,
    pub n_srv: usize,
    pub n_w: usize,
    pub n_g: usize,
}

/// Numerically-stable softmax.
pub fn softmax(logits: &[f64]) -> Vec<f64> {
    let max = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let exps: Vec<f64> = logits.iter().map(|l| (l - max).exp()).collect();
    let z: f64 = exps.iter().sum();
    exps.iter().map(|e| e / z).collect()
}

/// Shannon entropy of a categorical.
pub fn entropy(p: &[f64]) -> f64 {
    -p.iter().filter(|&&x| x > 1e-12).map(|&x| x * x.ln()).sum::<f64>()
}

/// First argmax of a logit slice (softmax is monotonic, so the argmax
/// over logits is the mode of the head's categorical). `total_cmp`
/// keeps a NaN logit from panicking; ties break to the lowest index,
/// so greedy decoding is a pure function of the weights and state.
fn argmax(logits: &[f64]) -> usize {
    debug_assert!(!logits.is_empty());
    let mut best = 0usize;
    for (j, &l) in logits.iter().enumerate().skip(1) {
        if l.total_cmp(&logits[best]) == std::cmp::Ordering::Greater {
            best = j;
        }
    }
    best
}

/// Softmax + categorical draw on a stack buffer (heap fallback past 32
/// logits, so huge server heads sample instead of overrunning the
/// stack array); returns the sampled index and its (optionally ε-mixed)
/// probability. Shared by the allocation-light serving path and the
/// batched planner.
fn sample_head_stack(
    logits: &[f64],
    mix: Option<f64>,
    rng: &mut Rng,
) -> (usize, f64) {
    debug_assert!(!logits.is_empty());
    let mut stack = [0.0f64; 32];
    let mut heap: Vec<f64>;
    let probs: &mut [f64] = if logits.len() <= stack.len() {
        &mut stack[..logits.len()]
    } else {
        heap = vec![0.0; logits.len()];
        &mut heap
    };
    let max = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let mut z = 0.0;
    for (e, &l) in probs.iter_mut().zip(logits) {
        *e = (l - max).exp();
        z += *e;
    }
    let n = logits.len() as f64;
    for p in probs.iter_mut() {
        *p /= z;
        if let Some(eps) = mix {
            *p = (1.0 - eps) * *p + eps / n;
        }
    }
    let target = rng.f64();
    let mut acc = 0.0;
    for (j, &p) in probs.iter().enumerate() {
        acc += p;
        if target < acc {
            return (j, p);
        }
    }
    let j = logits.len() - 1;
    (j, probs[j])
}

impl Policy {
    pub fn new(state_dim: usize, hidden: &[usize], n_srv: usize, n_w: usize,
               n_g: usize, rng: &mut Rng) -> Self {
        let mut sizes = vec![state_dim];
        sizes.extend_from_slice(hidden);
        sizes.push(n_srv + n_w + n_g + 1);
        Policy { mlp: Mlp::new(&sizes, rng), n_srv, n_w, n_g }
    }

    /// Output layout: [srv | w | g | value].
    fn split<'a>(&self, out: &'a [f64]) -> (&'a [f64], &'a [f64], &'a [f64], f64) {
        let s = &out[..self.n_srv];
        let w = &out[self.n_srv..self.n_srv + self.n_w];
        let g = &out[self.n_srv + self.n_w..self.n_srv + self.n_w + self.n_g];
        let v = out[self.n_srv + self.n_w + self.n_g];
        (s, w, g, v)
    }

    /// ε-mixed server probability (eq. 5).
    pub fn mixed_srv(&self, p_srv: &[f64], eps: f64) -> Vec<f64> {
        let n = p_srv.len() as f64;
        p_srv.iter().map(|&p| (1.0 - eps) * p + eps / n).collect()
    }

    /// Evaluate a state; compute probabilities, value and (if an action is
    /// given) its joint mixed log-likelihood.
    pub fn evaluate(&self, state: &[f64], action: Option<ActionTriple>, eps: f64)
        -> (PolicyEval, Option<ActionTriple>) {
        let (out, cache) = self.mlp.forward(state);
        let (ls, lw, lg, value) = self.split(&out);
        let p_srv = softmax(ls);
        let p_w = softmax(lw);
        let p_g = softmax(lg);
        let ent = entropy(&p_srv) + entropy(&p_w) + entropy(&p_g);
        let logp = action.map(|a| {
            let mixed = self.mixed_srv(&p_srv, eps);
            mixed[a.srv].max(1e-12).ln()
                + p_w[a.w].max(1e-12).ln()
                + p_g[a.g].max(1e-12).ln()
        });
        (
            PolicyEval {
                logp: logp.unwrap_or(0.0),
                value,
                entropy: ent,
                p_srv,
                p_w,
                p_g,
                cache,
            },
            action,
        )
    }

    /// Sample an action from the ε-mixed policy (the behaviour policy the
    /// engine executes).
    pub fn sample(&self, state: &[f64], eps: f64, rng: &mut Rng)
        -> (ActionTriple, PolicyEval) {
        let (mut eval, _) = self.evaluate(state, None, eps);
        let mixed = self.mixed_srv(&eval.p_srv, eps);
        let srv = rng.categorical(&mixed);
        let w = rng.categorical(&eval.p_w);
        let g = rng.categorical(&eval.p_g);
        let a = ActionTriple { srv, w, g };
        eval.logp = mixed[srv].max(1e-12).ln()
            + eval.p_w[w].max(1e-12).ln()
            + eval.p_g[g].max(1e-12).ln();
        (a, eval)
    }

    /// Allocation-light sampling for the serving hot path (eval mode: no
    /// cache, no logp/value bookkeeping). `scratch` is reused across
    /// calls; see EXPERIMENTS.md §Perf.
    pub fn sample_notrain(
        &self,
        state: &[f64],
        eps: f64,
        rng: &mut Rng,
        scratch: &mut (Vec<f64>, Vec<f64>),
    ) -> ActionTriple {
        self.mlp.forward_nocache(state, scratch);
        let out = &scratch.0;
        let (srv, _) = sample_head_stack(&out[..self.n_srv], Some(eps), rng);
        let (w, _) =
            sample_head_stack(&out[self.n_srv..self.n_srv + self.n_w], None, rng);
        let (g, _) = sample_head_stack(
            &out[self.n_srv + self.n_w..self.n_srv + self.n_w + self.n_g],
            None,
            rng,
        );
        ActionTriple { srv, w, g }
    }

    /// Greedy (mode) decoding: the argmax action of every head, no
    /// sampling and no RNG. This is what frozen evaluation replays use —
    /// the decision stream is a pure function of (weights, state), so a
    /// counterfactual replay cannot be perturbed by draw-order effects.
    pub fn greedy(
        &self,
        state: &[f64],
        scratch: &mut (Vec<f64>, Vec<f64>),
    ) -> ActionTriple {
        self.mlp.forward_nocache(state, scratch);
        let out = &scratch.0;
        ActionTriple {
            srv: argmax(&out[..self.n_srv]),
            w: argmax(&out[self.n_srv..self.n_srv + self.n_w]),
            g: argmax(
                &out[self.n_srv + self.n_w..self.n_srv + self.n_w + self.n_g],
            ),
        }
    }

    /// Batched [`Policy::greedy`]: one matrix forward over `n` stacked
    /// states, argmax per head per state.
    pub fn greedy_batch(
        &self,
        states: &[f64],
        n: usize,
        scratch: &mut (Vec<f64>, Vec<f64>),
    ) -> Vec<ActionTriple> {
        let out_dim = self.n_srv + self.n_w + self.n_g + 1;
        self.mlp.forward_batch(states, n, scratch);
        (0..n)
            .map(|k| {
                let out = &scratch.0[k * out_dim..(k + 1) * out_dim];
                ActionTriple {
                    srv: argmax(&out[..self.n_srv]),
                    w: argmax(&out[self.n_srv..self.n_srv + self.n_w]),
                    g: argmax(
                        &out[self.n_srv + self.n_w
                            ..self.n_srv + self.n_w + self.n_g],
                    ),
                }
            })
            .collect()
    }

    /// Batched diagnostic evaluation over `n` stacked states (row-major
    /// `[n, state_dim]`): per-head probabilities and value from one
    /// matrix forward, no backward caches.
    pub fn evaluate_batch(
        &self,
        states: &[f64],
        n: usize,
        scratch: &mut (Vec<f64>, Vec<f64>),
    ) -> Vec<BatchHeadEval> {
        let out_dim = self.n_srv + self.n_w + self.n_g + 1;
        self.mlp.forward_batch(states, n, scratch);
        (0..n)
            .map(|k| {
                let out = &scratch.0[k * out_dim..(k + 1) * out_dim];
                let (ls, lw, lg, value) = self.split(out);
                BatchHeadEval {
                    p_srv: softmax(ls),
                    p_w: softmax(lw),
                    p_g: softmax(lg),
                    value,
                }
            })
            .collect()
    }

    /// Batched behaviour-policy sampling over `n` stacked states: one
    /// matrix forward, then per-head stack-softmax draws in head order
    /// (`eps[k]` is head k's ε-mixing). Returns, per head, the sampled
    /// action, its joint mixed log-likelihood (eq. 6) and the value
    /// estimate — exactly what the rollout buffer stages.
    pub fn sample_batch(
        &self,
        states: &[f64],
        n: usize,
        eps: &[f64],
        rng: &mut Rng,
        scratch: &mut (Vec<f64>, Vec<f64>),
    ) -> Vec<(ActionTriple, f64, f64)> {
        debug_assert_eq!(eps.len(), n);
        let out_dim = self.n_srv + self.n_w + self.n_g + 1;
        self.mlp.forward_batch(states, n, scratch);
        let mut sampled = Vec::with_capacity(n);
        for k in 0..n {
            let out = &scratch.0[k * out_dim..(k + 1) * out_dim];
            let (srv, p_srv) =
                sample_head_stack(&out[..self.n_srv], Some(eps[k]), rng);
            let (w, p_w) = sample_head_stack(
                &out[self.n_srv..self.n_srv + self.n_w],
                None,
                rng,
            );
            let (g, p_g) = sample_head_stack(
                &out[self.n_srv + self.n_w..self.n_srv + self.n_w + self.n_g],
                None,
                rng,
            );
            let value = out[self.n_srv + self.n_w + self.n_g];
            let logp = p_srv.max(1e-12).ln()
                + p_w.max(1e-12).ln()
                + p_g.max(1e-12).ln();
            sampled.push((ActionTriple { srv, w, g }, logp, value));
        }
        sampled
    }

    /// Build dJ/d(mlp output) for one transition and backprop it.
    ///
    /// * `coef_logp` — ∂J/∂logπ̃ (the clipped-surrogate scalar).
    /// * `coef_ent`  — entropy weight (−c_H in J, so passing +c_H here
    ///   *reduces* J along increasing entropy).
    /// * `dvalue`    — ∂J/∂V (c_v·(V−R)).
    pub fn backward_transition(
        &self,
        eval: &PolicyEval,
        action: ActionTriple,
        eps: f64,
        coef_logp: f64,
        coef_ent: f64,
        dvalue: f64,
        grads: &mut Mlp,
    ) {
        let mut dout = vec![0.0; self.n_srv + self.n_w + self.n_g + 1];

        // server head: mixed likelihood gradient (eq. 5)
        {
            let p = &eval.p_srv;
            let a = action.srv;
            let mixed_a = (1.0 - eps) * p[a] + eps / self.n_srv as f64;
            let h = entropy(p);
            for j in 0..self.n_srv {
                let delta = if j == a { 1.0 } else { 0.0 };
                let dlogp = (1.0 - eps) * p[a] * (delta - p[j]) / mixed_a.max(1e-12);
                // J = -L_clip - c_H H  =>  dJ/dl = coef_logp·dlogp + coef_ent·p_j(ln p_j + H)
                dout[j] = coef_logp * dlogp
                    + coef_ent * p[j] * (p[j].max(1e-12).ln() + h);
            }
        }
        // width head: plain categorical
        {
            let p = &eval.p_w;
            let a = action.w;
            let h = entropy(p);
            for j in 0..self.n_w {
                let delta = if j == a { 1.0 } else { 0.0 };
                dout[self.n_srv + j] = coef_logp * (delta - p[j])
                    + coef_ent * p[j] * (p[j].max(1e-12).ln() + h);
            }
        }
        // group head: plain categorical
        {
            let p = &eval.p_g;
            let a = action.g;
            let h = entropy(p);
            for j in 0..self.n_g {
                let delta = if j == a { 1.0 } else { 0.0 };
                dout[self.n_srv + self.n_w + j] = coef_logp * (delta - p[j])
                    + coef_ent * p[j] * (p[j].max(1e-12).ln() + h);
            }
        }
        // value head
        dout[self.n_srv + self.n_w + self.n_g] = dvalue;

        self.mlp.backward(&eval.cache, &dout, grads);
    }
}

/// ε schedule (eq. 5): linear decay from ε_max to ε_min over T_dec steps.
pub fn eps_at(step: u64, eps_max: f64, eps_min: f64, t_dec: f64) -> f64 {
    (eps_max + step as f64 / t_dec * (eps_min - eps_max)).max(eps_min)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> Policy {
        let mut rng = Rng::new(1);
        Policy::new(11, &[32, 32], 3, 4, 3, &mut rng)
    }

    #[test]
    fn probabilities_normalize() {
        let p = policy();
        let state = vec![0.3; 11];
        let (eval, _) = p.evaluate(&state, None, 0.1);
        for probs in [&eval.p_srv, &eval.p_w, &eval.p_g] {
            assert!((probs.iter().sum::<f64>() - 1.0).abs() < 1e-9);
            assert!(probs.iter().all(|&x| x > 0.0));
        }
        assert_eq!(eval.p_srv.len(), 3);
        assert_eq!(eval.p_w.len(), 4);
        assert_eq!(eval.p_g.len(), 3);
    }

    #[test]
    fn mixed_likelihood_formula() {
        let p = policy();
        let probs = vec![0.7, 0.2, 0.1];
        let mixed = p.mixed_srv(&probs, 0.3);
        assert!((mixed[0] - (0.7 * 0.7 + 0.1)).abs() < 1e-12);
        assert!((mixed.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        // eps=1 => uniform
        let uni = p.mixed_srv(&probs, 1.0);
        assert!(uni.iter().all(|&x| (x - 1.0 / 3.0).abs() < 1e-12));
    }

    #[test]
    fn joint_logp_is_sum_of_heads() {
        let p = policy();
        let state = vec![0.1; 11];
        let a = ActionTriple { srv: 1, w: 2, g: 0 };
        let (eval, _) = p.evaluate(&state, Some(a), 0.2);
        let mixed = p.mixed_srv(&eval.p_srv, 0.2);
        let expect = mixed[1].ln() + eval.p_w[2].ln() + eval.p_g[0].ln();
        assert!((eval.logp - expect).abs() < 1e-12);
    }

    #[test]
    fn sampling_matches_probabilities() {
        let p = policy();
        let state = vec![0.5; 11];
        let mut rng = Rng::new(9);
        let (eval, _) = p.evaluate(&state, None, 0.0);
        let mut counts = vec![0usize; 4];
        let n = 40_000;
        for _ in 0..n {
            let (a, _) = p.sample(&state, 0.0, &mut rng);
            counts[a.w] += 1;
        }
        for (j, &c) in counts.iter().enumerate() {
            let emp = c as f64 / n as f64;
            assert!(
                (emp - eval.p_w[j]).abs() < 0.015,
                "head w[{j}]: emp {emp} vs {}",
                eval.p_w[j]
            );
        }
    }

    #[test]
    fn exploration_covers_servers_under_eps() {
        // even with a confident policy, ε-mixing keeps all servers sampled
        let p = policy();
        let state = vec![2.0; 11];
        let mut rng = Rng::new(10);
        let mut seen = [0usize; 3];
        for _ in 0..3000 {
            let (a, _) = p.sample(&state, 0.5, &mut rng);
            seen[a.srv] += 1;
        }
        assert!(seen.iter().all(|&c| c > 150), "{seen:?}");
    }

    fn stacked_states(n: usize, dim: usize) -> Vec<f64> {
        (0..n * dim).map(|i| ((i as f64) * 0.173).sin() * 0.8).collect()
    }

    #[test]
    fn evaluate_batch_matches_per_state_evaluate() {
        let p = policy();
        let n = 6;
        let states = stacked_states(n, 11);
        let mut scratch = (Vec::new(), Vec::new());
        let batch = p.evaluate_batch(&states, n, &mut scratch);
        assert_eq!(batch.len(), n);
        for (k, head) in batch.iter().enumerate() {
            let (eval, _) = p.evaluate(&states[k * 11..(k + 1) * 11], None, 0.0);
            for (a, b) in head.p_srv.iter().zip(&eval.p_srv) {
                assert!((a - b).abs() < 1e-9, "head {k} srv {a} vs {b}");
            }
            for (a, b) in head.p_w.iter().zip(&eval.p_w) {
                assert!((a - b).abs() < 1e-9);
            }
            for (a, b) in head.p_g.iter().zip(&eval.p_g) {
                assert!((a - b).abs() < 1e-9);
            }
            assert!((head.value - eval.value).abs() < 1e-9);
        }
    }

    #[test]
    fn sample_batch_of_one_matches_sample_notrain_bitwise() {
        // the batched and per-head serving paths share the matrix math
        // and the stack sampler, so a window of 1 is bit-identical
        let p = policy();
        let state = stacked_states(1, 11);
        let mut rng_a = Rng::new(21);
        let mut rng_b = rng_a.clone();
        let mut s_a = (Vec::new(), Vec::new());
        let mut s_b = (Vec::new(), Vec::new());
        for _ in 0..50 {
            let batched = p.sample_batch(&state, 1, &[0.1], &mut rng_a, &mut s_a);
            let single = p.sample_notrain(&state, 0.1, &mut rng_b, &mut s_b);
            assert_eq!(batched[0].0, single);
        }
    }

    #[test]
    fn sample_batch_logp_matches_evaluate_logp() {
        let p = policy();
        let n = 4;
        let states = stacked_states(n, 11);
        let eps = [0.0, 0.1, 0.2, 0.3];
        let mut rng = Rng::new(22);
        let mut scratch = (Vec::new(), Vec::new());
        let sampled = p.sample_batch(&states, n, &eps, &mut rng, &mut scratch);
        for (k, (action, logp, value)) in sampled.iter().enumerate() {
            let (eval, _) =
                p.evaluate(&states[k * 11..(k + 1) * 11], Some(*action), eps[k]);
            assert!((logp - eval.logp).abs() < 1e-9, "head {k}");
            assert!((value - eval.value).abs() < 1e-9, "head {k}");
        }
    }

    #[test]
    fn sample_batch_handles_heads_wider_than_the_stack_buffer() {
        // a 40-server head exceeds the 32-slot stack sampler: the heap
        // fallback must sample (not panic) across the full index range
        let mut rng = Rng::new(33);
        let p = Policy::new(8, &[16], 40, 4, 3, &mut rng);
        let states = stacked_states(3, 8);
        let eps = [0.1, 0.2, 0.3];
        let mut scratch = (Vec::new(), Vec::new());
        let mut max_srv = 0usize;
        for _ in 0..300 {
            for (a, logp, _v) in
                p.sample_batch(&states, 3, &eps, &mut rng, &mut scratch)
            {
                assert!(a.srv < 40 && a.w < 4 && a.g < 3);
                assert!(logp.is_finite());
                max_srv = max_srv.max(a.srv);
            }
        }
        assert!(max_srv > 31, "upper server range never sampled: {max_srv}");
    }

    #[test]
    fn sample_batch_respects_probabilities() {
        let p = policy();
        let state = stacked_states(1, 11);
        let (eval, _) = p.evaluate(&state, None, 0.0);
        let mut rng = Rng::new(23);
        let mut scratch = (Vec::new(), Vec::new());
        // a wide window of identical states: the width-head marginal of
        // the samples must track the single-state distribution
        let n = 16;
        let mut states = Vec::new();
        for _ in 0..n {
            states.extend_from_slice(&state);
        }
        let eps = vec![0.0; n];
        let mut counts = vec![0usize; 4];
        let rounds = 2500;
        for _ in 0..rounds {
            for (a, _, _) in p.sample_batch(&states, n, &eps, &mut rng, &mut scratch) {
                counts[a.w] += 1;
            }
        }
        let total = (rounds * n) as f64;
        for (j, &c) in counts.iter().enumerate() {
            let emp = c as f64 / total;
            assert!(
                (emp - eval.p_w[j]).abs() < 0.015,
                "head w[{j}]: emp {emp} vs {}",
                eval.p_w[j]
            );
        }
    }

    #[test]
    fn greedy_is_the_distribution_mode_and_needs_no_rng() {
        let p = policy();
        let mut scratch = (Vec::new(), Vec::new());
        for k in 0..6 {
            let state = stacked_states(6, 11)[k * 11..(k + 1) * 11].to_vec();
            let a = p.greedy(&state, &mut scratch);
            let (eval, _) = p.evaluate(&state, None, 0.0);
            assert_eq!(a.srv, argmax(&eval.p_srv), "state {k}");
            assert_eq!(a.w, argmax(&eval.p_w), "state {k}");
            assert_eq!(a.g, argmax(&eval.p_g), "state {k}");
            // pure function of (weights, state): repeat calls agree
            assert_eq!(a, p.greedy(&state, &mut scratch));
        }
    }

    #[test]
    fn greedy_batch_matches_per_state_greedy() {
        let p = policy();
        let n = 5;
        let states = stacked_states(n, 11);
        let mut s_a = (Vec::new(), Vec::new());
        let mut s_b = (Vec::new(), Vec::new());
        let batch = p.greedy_batch(&states, n, &mut s_a);
        assert_eq!(batch.len(), n);
        for (k, a) in batch.iter().enumerate() {
            let single =
                p.greedy(&states[k * 11..(k + 1) * 11], &mut s_b);
            assert_eq!(*a, single, "state {k}");
        }
    }

    #[test]
    fn argmax_breaks_ties_low_and_survives_nan() {
        assert_eq!(argmax(&[1.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[2.0, 2.0, 1.0]), 0); // tie → lowest index
        assert_eq!(argmax(&[f64::NAN, 1.0]), 0); // NaN ranks above by
                                                 // total_cmp — but never panics
        assert_eq!(argmax(&[0.5]), 0);
    }

    #[test]
    fn entropy_helpers() {
        let uniform = vec![0.25; 4];
        assert!((entropy(&uniform) - (4.0f64).ln()).abs() < 1e-12);
        let peaked = vec![1.0, 0.0, 0.0, 0.0];
        assert!(entropy(&peaked).abs() < 1e-9);
    }

    #[test]
    fn eps_schedule_decays_linearly_with_floor() {
        assert_eq!(eps_at(0, 0.3, 0.02, 1000.0), 0.3);
        let mid = eps_at(500, 0.3, 0.02, 1000.0);
        assert!((mid - 0.16).abs() < 1e-9);
        assert_eq!(eps_at(100_000, 0.3, 0.02, 1000.0), 0.02);
    }

    /// Finite-difference check of the full transition gradient: perturb a
    /// weight, recompute J = -logπ̃·Â - c_H·H + c_v/2 (V-R)², compare.
    #[test]
    fn transition_gradient_matches_finite_difference() {
        let p = policy();
        let state: Vec<f64> = (0..11).map(|i| (i as f64 * 0.37).sin()).collect();
        let a = ActionTriple { srv: 2, w: 1, g: 2 };
        let eps = 0.15;
        let adv = 0.8; // fixed advantage -> coef_logp = -adv (maximize logp·adv)
        let ret = 0.5;
        let (c_h, c_v) = (0.01, 0.5);

        let j_of = |pol: &Policy| -> f64 {
            let (eval, _) = pol.evaluate(&state, Some(a), eps);
            -eval.logp * adv - c_h * eval.entropy
                + 0.5 * c_v * (eval.value - ret) * (eval.value - ret)
        };

        let (eval, _) = p.evaluate(&state, Some(a), eps);
        let mut grads = p.mlp.zeros_like();
        p.backward_transition(
            &eval,
            a,
            eps,
            -adv,
            c_h,
            c_v * (eval.value - ret),
            &mut grads,
        );

        let mut rng = Rng::new(77);
        let h = 1e-6;
        for l in 0..p.mlp.n_layers() {
            for _ in 0..3 {
                let idx = rng.index(p.mlp.w[l].data.len());
                let mut plus = p.clone();
                plus.mlp.w[l].data[idx] += h;
                let mut minus = p.clone();
                minus.mlp.w[l].data[idx] -= h;
                let numeric = (j_of(&plus) - j_of(&minus)) / (2.0 * h);
                let analytic = grads.w[l].data[idx];
                assert!(
                    (numeric - analytic).abs() < 1e-4 * (1.0 + numeric.abs()),
                    "layer {l} idx {idx}: numeric {numeric} analytic {analytic}"
                );
            }
        }
    }
}
