//! [`PpoRouter`] — the learned global policy behind Tables IV–V, adapted
//! to the engine's windowed [`Router`] plan API.
//!
//! In training mode every routed head stages a transition; the
//! block-completion feedback computes the eq. 7 reward and finishes it;
//! once `horizon` transitions accumulate, a clipped PPO update runs
//! in-place (the engine keeps scheduling while the policy learns — the
//! paper trains the router online against the live cluster). In eval mode
//! the same object routes greedily from the learned distribution with
//! exploration off.
//!
//! A one-head plan takes the original scalar path (bit-identical to the
//! pre-plan router per seed); wider windows featurize every head into
//! one stacked state buffer and run a single `Policy::sample_batch`
//! matrix forward, amortizing the MLP cost across the queue.
//!
//! For the multi-leader coordinator (`coordinator::shard`),
//! [`SharedPpoRouter`] wraps one `PpoRouter` behind a cheap cloneable
//! handle: every leader shard plans through the same policy and stages
//! into the same rollout buffer, so training sees every shard's
//! transitions exactly as it would a single leader's.

use std::sync::{Arc, Mutex};

use crate::config::{Config, PpoCfg};
use crate::coordinator::router::{
    BlockFeedback, Decision, HeadView, Router, RoutingPlan,
};
use crate::coordinator::telemetry::TelemetrySnapshot;
use crate::coordinator::{Engine, RunOutcome};
use crate::sim::WorkloadEvent;
use crate::trace::record::TraceSink;
use crate::utilx::{Json, Rng};

use super::adam::Adam;
use super::buffer::{RolloutBuffer, Transition};
use super::policy::{eps_at, Policy};
use super::update::{ppo_update, UpdateStats};

/// Aggregated training diagnostics.
#[derive(Clone, Debug, Default)]
pub struct TrainStats {
    pub decisions: u64,
    pub updates: u64,
    /// Transitions consumed by PPO updates (conservation check: together
    /// with the buffered remainder this accounts for every completion).
    pub transitions_trained: u64,
    pub last_update: UpdateStats,
    pub reward_history: Vec<f64>,
    pub entropy_history: Vec<f64>,
}

/// PPO-learned router.
pub struct PpoRouter {
    pub policy: Policy,
    adam: Adam,
    pub cfg: PpoCfg,
    widths: Vec<f64>,
    groups: Vec<usize>,
    buffer: RolloutBuffer,
    step: u64,
    next_tag: u64,
    pub training: bool,
    /// Frozen greedy decoding (eval mode only): every head takes the
    /// argmax action, so the decision stream is a pure function of the
    /// checkpoint and the replayed state — no RNG draws at all. This is
    /// what the counterfactual A/B harness runs checkpoints under.
    greedy: bool,
    /// Collect transitions but never update in-place (parallel rollout
    /// workers harvest the buffer; the central trainer owns updates).
    collect_only: bool,
    /// Normalized mean prior for the optional zero-mean centering.
    prior_mean_norm: f64,
    /// Append the head's SLA slack as one extra state feature
    /// (`RouterCfg::state_slack` / `--state-slack`; the policy input is
    /// one dimension wider when on, so checkpoints don't cross the flag).
    state_slack: bool,
    pub stats: TrainStats,
    /// Reused forward buffers for the eval-mode hot path (§Perf).
    scratch: (Vec<f64>, Vec<f64>),
}

/// Slack feature for the PPO state vector: clamped to [-4, 4] seconds
/// (synthetic heads carry infinite slack — they clamp to the "no
/// pressure" end; a poisoned NaN reads as neutral 0 instead of
/// propagating into the policy forward).
fn slack_feature(slack_s: f64) -> f64 {
    if slack_s.is_nan() {
        0.0
    } else {
        slack_s.clamp(-4.0, 4.0)
    }
}

impl PpoRouter {
    pub fn new(
        n_servers: usize,
        widths: Vec<f64>,
        cfg: PpoCfg,
        seed: u64,
    ) -> Self {
        Self::with_state_slack(n_servers, widths, cfg, seed, false)
    }

    /// [`PpoRouter::new`] with the opt-in slack state feature: the
    /// policy input is `TelemetrySnapshot::state_dim(n_servers,
    /// state_slack)` wide. With the flag off this is exactly `new`.
    pub fn with_state_slack(
        n_servers: usize,
        widths: Vec<f64>,
        cfg: PpoCfg,
        seed: u64,
        state_slack: bool,
    ) -> Self {
        let mut rng = Rng::new(seed ^ 0x9e37);
        let state_dim = TelemetrySnapshot::state_dim(n_servers, state_slack);
        let policy = Policy::new(
            state_dim,
            &cfg.hidden.clone(),
            n_servers,
            widths.len(),
            cfg.groups.len(),
            &mut rng,
        );
        let adam = Adam::new(&policy.mlp, cfg.lr);
        let prior = crate::model::AccuracyPrior::new();
        let prior_mean_norm = (prior.mean_top1() - 70.30) / (76.43 - 70.30);
        PpoRouter {
            policy,
            adam,
            groups: cfg.groups.clone(),
            cfg,
            widths,
            buffer: RolloutBuffer::new(),
            step: 0,
            next_tag: 0,
            training: true,
            greedy: false,
            collect_only: false,
            prior_mean_norm,
            state_slack,
            stats: TrainStats::default(),
            scratch: (Vec::new(), Vec::new()),
        }
    }

    /// Standard construction from a full run configuration: cluster
    /// size, width set, PPO hyper-parameters, seed and the
    /// `--state-slack` opt-in all come from `cfg`.
    pub fn for_config(cfg: &Config) -> Self {
        Self::with_state_slack(
            cfg.devices.len(),
            cfg.scheduler.widths.clone(),
            cfg.ppo.clone(),
            cfg.seed,
            cfg.router.state_slack,
        )
    }

    /// Freeze the policy for evaluation runs (stochastic: actions are
    /// still sampled from the learned distribution, exploration off).
    pub fn eval_mode(&mut self) {
        self.training = false;
    }

    /// Freeze the policy in *greedy* evaluation mode: every head takes
    /// its argmax action deterministically, with no RNG draws. Used by
    /// the trace-compare harness so a checkpoint replay is a pure
    /// function of (weights, trace) — two replays are byte-identical by
    /// construction, not merely by seed discipline.
    pub fn greedy_eval_mode(&mut self) {
        self.training = false;
        self.greedy = true;
    }

    /// Build a frozen greedy-eval router from a checkpoint file,
    /// shape-guarded against `cfg` (cluster size, width/group sets, the
    /// `--state-slack` feature flag — all of which change the policy
    /// dimensions, so a mismatched checkpoint is rejected, never
    /// silently truncated).
    pub fn from_checkpoint(cfg: &Config, path: &str) -> Result<PpoRouter, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("cannot read checkpoint {path}: {e}"))?;
        let json = Json::parse(&text)
            .map_err(|e| format!("checkpoint {path} is not valid JSON: {e}"))?;
        let mut router = PpoRouter::for_config(cfg);
        if !router.load_weights(&json) {
            return Err(format!(
                "checkpoint {path} does not match the policy shape for this \
                 config ({} servers, {} widths, state_slack={}; state-slack \
                 checkpoints need --state-slack and the recording cluster)",
                cfg.devices.len(),
                cfg.scheduler.widths.len(),
                cfg.router.state_slack,
            ));
        }
        router.greedy_eval_mode();
        Ok(router)
    }

    /// Spawn a rollout collector: same weights, cfg and exploration
    /// schedule position, but it only stages transitions — `ppo::parallel`
    /// harvests them with [`PpoRouter::take_transitions`] and the central
    /// router performs the updates.
    pub fn fork_collector(&self) -> PpoRouter {
        let mut worker = PpoRouter::with_state_slack(
            self.policy.n_srv,
            self.widths.clone(),
            self.cfg.clone(),
            0,
            self.state_slack,
        );
        worker.policy = self.policy.clone();
        worker.step = self.step;
        worker.collect_only = true;
        worker
    }

    /// Drain the finished transitions collected so far (worker harvest).
    pub fn take_transitions(&mut self) -> Vec<Transition> {
        self.buffer.drain()
    }

    /// Finished transitions waiting for the next update (carry-over
    /// remainder between parallel-trainer rounds).
    pub fn buffered_transitions(&self) -> usize {
        self.buffer.ready()
    }

    /// Merge a worker's harvested transitions into this router's buffer
    /// and advance the exploration schedule by the decisions that
    /// produced them.
    pub fn absorb_rollout(&mut self, transitions: Vec<Transition>, decisions: u64) {
        self.step += decisions;
        self.stats.decisions += decisions;
        self.buffer.absorb(transitions);
    }

    /// Run synchronous PPO updates over everything buffered, in rollout
    /// order, one full-`horizon` chunk at a time. The sub-horizon tail
    /// is **carried** back into the buffer for the next round instead of
    /// being dropped, so no collected transition is ever lost at round
    /// seams. [`PpoRouter::end_of_run`] flushes a final remainder of 16+
    /// transitions; a smaller one stays buffered (accounted, untrained —
    /// the same noisy-tiny-batch guard as before). Returns how many
    /// updates ran.
    pub fn update_from_buffer(&mut self) -> u64 {
        let mut all = self.buffer.drain();
        let horizon = self.cfg.horizon.max(1);
        let mut ran = 0;
        let mut idx = 0usize;
        while all.len() - idx >= horizon {
            self.run_update(&all[idx..idx + horizon]);
            idx += horizon;
            ran += 1;
        }
        if idx < all.len() {
            // leftover sub-horizon transitions ride into the next round
            self.buffer.carry(all.split_off(idx));
        }
        ran
    }

    /// One clipped PPO update over `batch`, with the shared diagnostics
    /// bookkeeping (update/transition counters, reward & entropy
    /// curves) every update site must keep consistent.
    fn run_update(&mut self, batch: &[Transition]) {
        let stats = ppo_update(&mut self.policy, &mut self.adam, batch, &self.cfg);
        self.stats.updates += 1;
        self.stats.transitions_trained += batch.len() as u64;
        self.stats.last_update = stats;
        self.stats.reward_history.push(stats.mean_reward);
        self.stats.entropy_history.push(stats.entropy);
    }

    fn eps(&self) -> f64 {
        if self.training {
            eps_at(self.step, self.cfg.eps_max, self.cfg.eps_min, self.cfg.t_dec)
        } else {
            0.0
        }
    }

    /// eq. 7: r = α·p̃_acc − β·L − γ·E − δ·Var(U) + b.
    pub fn reward(&self, fb: &BlockFeedback) -> f64 {
        let r = &self.cfg.reward;
        let acc = if r.center_acc {
            fb.acc_prior_norm - self.prior_mean_norm
        } else {
            fb.acc_prior_norm
        };
        r.alpha * acc - r.beta * fb.latency_s - r.gamma * fb.energy_j
            - r.delta * fb.util_variance
            + r.bonus
    }

    /// Checkpoint the policy weights.
    pub fn to_json(&self) -> Json {
        self.policy.mlp.to_json()
    }

    /// Restore policy weights from a checkpoint (shape-checked).
    pub fn load_weights(&mut self, json: &Json) -> bool {
        match super::mlp::Mlp::from_json(json) {
            Some(mlp) if mlp.sizes == self.policy.mlp.sizes => {
                self.policy.mlp = mlp;
                true
            }
            _ => false,
        }
    }

    fn maybe_update(&mut self) {
        if self.collect_only {
            return;
        }
        if self.training && self.buffer.ready() >= self.cfg.horizon {
            let batch = self.buffer.drain();
            self.run_update(&batch);
        }
    }

    /// The original scalar path: one head, one `Policy::sample` /
    /// `sample_notrain` invocation — bit-identical to the pre-plan
    /// router per seed (the optional slack feature appends to the state
    /// without touching the draw order).
    fn route_head(
        &mut self,
        snap: &TelemetrySnapshot,
        head: &HeadView,
        rng: &mut Rng,
    ) -> Decision {
        let mut state = snap.to_state_vector();
        if self.state_slack {
            state.push(slack_feature(head.slack_s));
        }
        let eps = self.eps();
        self.step += 1;
        self.stats.decisions += 1;
        let tag = self.next_tag;
        self.next_tag += 1;
        let action = if self.training {
            let (action, eval) = self.policy.sample(&state, eps, rng);
            self.buffer.stage(tag, state, action, eval.logp, eval.value, eps);
            action
        } else if self.greedy {
            // frozen greedy replay: argmax decoding, no RNG at all
            self.policy.greedy(&state, &mut self.scratch)
        } else {
            // serving hot path: allocation-light forward, no rollout
            self.policy.sample_notrain(&state, eps, rng, &mut self.scratch)
        };
        Decision {
            server: action.srv.min(snap.servers.len().saturating_sub(1)),
            width: self.widths[action.w.min(self.widths.len() - 1)],
            group: self.groups[action.g.min(self.groups.len() - 1)],
            tag,
        }
    }

    /// The batched path: featurize every head into one stacked state
    /// buffer and sample all actions from a single matrix forward pass,
    /// staging one transition per head in training mode.
    fn plan_batched(
        &mut self,
        snap: &TelemetrySnapshot,
        heads: &[HeadView],
        rng: &mut Rng,
    ) -> RoutingPlan {
        let n = heads.len();
        let base = snap.to_state_vector();
        let dim = base.len() + self.state_slack as usize;
        let mut states = Vec::with_capacity(n * dim);
        for head in heads {
            let start = states.len();
            states.extend_from_slice(&base);
            if self.state_slack {
                // per-head deadline pressure rides as the last feature
                states.push(slack_feature(head.slack_s));
            }
            // queue-position signal: a deeper head sees fewer pending
            // entries ahead of it, mirroring the sequential loop where
            // each routed block shrank the next snapshot's fifo_len
            let remaining = snap.fifo_len.saturating_sub(head.fifo_index);
            states[start] = (remaining as f64 / 64.0).min(4.0);
        }
        let eps: Vec<f64> = (0..n)
            .map(|k| {
                if self.training {
                    eps_at(
                        self.step + k as u64,
                        self.cfg.eps_max,
                        self.cfg.eps_min,
                        self.cfg.t_dec,
                    )
                } else {
                    0.0
                }
            })
            .collect();
        self.step += n as u64;
        self.stats.decisions += n as u64;
        let sampled: Vec<(super::policy::ActionTriple, f64, f64)> =
            if !self.training && self.greedy {
                // frozen greedy replay: one matrix forward, argmax per
                // head, no RNG draws (logp/value are never staged here)
                self.policy
                    .greedy_batch(&states, n, &mut self.scratch)
                    .into_iter()
                    .map(|a| (a, 0.0, 0.0))
                    .collect()
            } else {
                self.policy
                    .sample_batch(&states, n, &eps, rng, &mut self.scratch)
            };
        let mut decisions = Vec::with_capacity(n);
        for (k, (action, logp, value)) in sampled.into_iter().enumerate() {
            let tag = self.next_tag;
            self.next_tag += 1;
            if self.training {
                let state = states[k * dim..(k + 1) * dim].to_vec();
                self.buffer.stage(tag, state, action, logp, value, eps[k]);
            }
            decisions.push(Decision {
                server: action.srv.min(snap.servers.len().saturating_sub(1)),
                width: self.widths[action.w.min(self.widths.len() - 1)],
                group: self.groups[action.g.min(self.groups.len() - 1)],
                tag,
            });
        }
        RoutingPlan::new(decisions)
    }
}

impl Router for PpoRouter {
    fn name(&self) -> &'static str {
        "ppo"
    }

    fn plan(
        &mut self,
        snap: &TelemetrySnapshot,
        heads: &[HeadView],
        rng: &mut Rng,
    ) -> RoutingPlan {
        match heads.len() {
            0 => RoutingPlan::new(Vec::new()),
            // route_window = 1: the pre-plan scalar path, bit-identical
            1 => RoutingPlan::new(vec![self.route_head(snap, &heads[0], rng)]),
            _ => self.plan_batched(snap, heads, rng),
        }
    }

    fn feedback(&mut self, fb: &BlockFeedback) {
        if !self.training {
            return;
        }
        let r = self.reward(fb);
        self.buffer.complete(fb.tag, r);
        self.maybe_update();
    }

    fn abandon(&mut self, tag: u64) {
        self.buffer.abandon(tag);
    }

    fn end_of_run(&mut self) {
        if self.collect_only {
            // collectors keep their harvest; the central trainer flushes
            return;
        }
        // flush whatever is ready, even under horizon
        if self.training && self.buffer.ready() >= 16 {
            let batch = self.buffer.drain();
            self.run_update(&batch);
        }
    }
}

/// One `PpoRouter` shared across leader shards behind a cheap cloneable
/// handle. The engine's event loop is single-threaded, so the mutex is
/// uncontended — it exists to satisfy `Send` (parallel rollout workers
/// move whole engines across threads), not to arbitrate.
///
/// Every shard replica plans through the same policy, stages into the
/// same rollout buffer, and advances the same exploration schedule, so a
/// sharded run trains exactly one router. Tag uniqueness across shards
/// falls out for free: the shared `next_tag` counter is global.
pub struct SharedPpoRouter {
    inner: Arc<Mutex<PpoRouter>>,
}

impl Clone for SharedPpoRouter {
    fn clone(&self) -> Self {
        SharedPpoRouter { inner: Arc::clone(&self.inner) }
    }
}

impl SharedPpoRouter {
    pub fn new(router: PpoRouter) -> Self {
        SharedPpoRouter { inner: Arc::new(Mutex::new(router)) }
    }

    /// Recover the underlying router. Panics if other handles are still
    /// alive — callers must let the engine (and its shard replicas) drop
    /// first, which `Engine::run_returning_router` guarantees.
    pub fn into_inner(self) -> PpoRouter {
        Arc::try_unwrap(self.inner)
            .ok()
            .expect("shard replicas still hold the shared PPO router")
            .into_inner()
            .unwrap_or_else(|poisoned| poisoned.into_inner())
    }
}

impl Router for SharedPpoRouter {
    fn name(&self) -> &'static str {
        "ppo"
    }

    fn plan(
        &mut self,
        snap: &TelemetrySnapshot,
        heads: &[HeadView],
        rng: &mut Rng,
    ) -> RoutingPlan {
        self.inner.lock().unwrap().plan(snap, heads, rng)
    }

    fn feedback(&mut self, fb: &BlockFeedback) {
        self.inner.lock().unwrap().feedback(fb)
    }

    fn abandon(&mut self, tag: u64) {
        self.inner.lock().unwrap().abandon(tag)
    }

    fn end_of_run(&mut self) {
        // called once per shard replica at drain; the flush inside is
        // buffer-guarded, so repeat calls are no-ops
        self.inner.lock().unwrap().end_of_run()
    }
}

/// Run one engine episode with this PPO router, honoring
/// `cfg.shard.leaders`: one leader drives the classic engine directly
/// (bit-identical per seed to the pre-shard trainer); multiple leaders
/// share the router — and its one `Policy` — across shards behind a
/// [`SharedPpoRouter`], so every shard's transitions land in the same
/// rollout buffer. Returns the outcome and the router (trained state
/// intact) either way.
pub fn run_ppo_episode(cfg: &Config, router: PpoRouter) -> (RunOutcome, PpoRouter) {
    run_ppo_episode_io(cfg, router, None, None)
}

/// [`run_ppo_episode`] with the trace layer attached: an optional fixed
/// arrival stream (trace replay — an `Arc` arena handle, shared
/// zero-copy with the trace that parsed it and with any concurrent
/// replays) and an optional [`TraceSink`] receiving the run's lifecycle
/// records — so PPO evaluation episodes are recordable and replayable
/// exactly like the algorithmic routers.
pub fn run_ppo_episode_io(
    cfg: &Config,
    router: PpoRouter,
    arrivals: Option<Arc<[WorkloadEvent]>>,
    sink: Option<Box<dyn TraceSink>>,
) -> (RunOutcome, PpoRouter) {
    if cfg.shard.leaders > 1 {
        let shared = SharedPpoRouter::new(router);
        let mut engine = crate::coordinator::sharded_engine(cfg.clone(), shared);
        if let Some(events) = arrivals {
            engine.set_arrivals(events);
        }
        if let Some(sink) = sink {
            engine.set_trace_sink(sink);
        }
        let (outcome, handle) = engine.run_returning_router();
        (outcome, handle.into_inner())
    } else {
        let mut engine = Engine::new(cfg.clone(), router);
        if let Some(events) = arrivals {
            engine.set_arrivals(events);
        }
        if let Some(sink) = sink {
            engine.set_trace_sink(sink);
        }
        let (outcome, router) = engine.run_returning_router();
        (outcome, router)
    }
}

/// Width-index histogram of a trained policy's marginal (diagnostics for
/// the Table IV collapse check).
pub fn width_marginal(router: &PpoRouter, snap: &TelemetrySnapshot) -> Vec<f64> {
    let state = snap.to_state_vector();
    let (eval, _) = router.policy.evaluate(&state, None, 0.0);
    eval.p_w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{PpoCfg, RewardCfg};
    use crate::coordinator::telemetry::ServerTelemetry;

    fn snap(n: usize) -> TelemetrySnapshot {
        TelemetrySnapshot {
            fifo_len: 5,
            done_count: 10,
            total_requests: 100,
            servers: (0..n)
                .map(|i| ServerTelemetry {
                    queue_len: i,
                    power_w: 100.0,
                    util_pct: 30.0 * i as f64,
                    mem_util: 0.2,
                    instances: 1,
                })
                .collect(),
        }
    }

    fn router() -> PpoRouter {
        PpoRouter::new(3, vec![0.25, 0.5, 0.75, 1.0], PpoCfg::default(), 1)
    }

    #[test]
    fn decisions_are_in_range() {
        let mut r = router();
        let mut rng = Rng::new(2);
        let s = snap(3);
        for _ in 0..200 {
            let d = r.route_one(&s, &HeadView::new(0.5, 0), &mut rng);
            assert!(d.server < 3);
            assert!([0.25, 0.5, 0.75, 1.0].contains(&d.width));
            assert!([1usize, 4, 16].contains(&d.group));
        }
        assert_eq!(r.stats.decisions, 200);
    }

    #[test]
    fn reward_follows_eq7_signs() {
        let mut r = router();
        r.cfg.reward = RewardCfg {
            alpha: 2.0,
            beta: 1.0,
            gamma: 0.1,
            delta: 5.0,
            bonus: 0.25,
            center_acc: false,
        };
        let fb = BlockFeedback {
            tag: 0,
            acc_prior_norm: 0.5,
            latency_s: 0.2,
            energy_j: 3.0,
            util_variance: 0.01,
        };
        let want = 2.0 * 0.5 - 1.0 * 0.2 - 0.1 * 3.0 - 5.0 * 0.01 + 0.25;
        assert!((r.reward(&fb) - want).abs() < 1e-12);
        // higher latency strictly lowers reward
        let worse = BlockFeedback { latency_s: 1.0, ..fb };
        assert!(r.reward(&worse) < r.reward(&fb));
    }

    #[test]
    fn centering_subtracts_mean_prior() {
        let mut r = router();
        r.cfg.reward = RewardCfg { center_acc: true, beta: 0.0, gamma: 0.0,
                                   delta: 0.0, alpha: 1.0, bonus: 0.0 };
        let fb = BlockFeedback {
            tag: 0,
            acc_prior_norm: r.prior_mean_norm,
            latency_s: 0.0,
            energy_j: 0.0,
            util_variance: 0.0,
        };
        assert!(r.reward(&fb).abs() < 1e-9);
    }

    #[test]
    fn training_accumulates_and_updates() {
        let mut r = router();
        r.cfg.horizon = 32;
        let mut rng = Rng::new(3);
        let s = snap(3);
        for _i in 0..40 {
            let d = r.route_one(&s, &HeadView::new(0.5, 0), &mut rng);
            r.feedback(&BlockFeedback {
                tag: d.tag,
                acc_prior_norm: 0.5,
                latency_s: 0.01,
                energy_j: 1.0,
                util_variance: 0.001,
            });
        }
        assert!(r.stats.updates >= 1, "updates={}", r.stats.updates);
        assert!(!r.stats.reward_history.is_empty());
    }

    #[test]
    fn eval_mode_stops_learning_and_exploration() {
        let mut r = router();
        r.eval_mode();
        let mut rng = Rng::new(4);
        let s = snap(3);
        let d = r.route_one(&s, &HeadView::new(0.5, 0), &mut rng);
        r.feedback(&BlockFeedback {
            tag: d.tag,
            acc_prior_norm: 1.0,
            latency_s: 0.0,
            energy_j: 0.0,
            util_variance: 0.0,
        });
        assert_eq!(r.stats.updates, 0);
        assert_eq!(r.buffer.ready(), 0);
        assert_eq!(r.eps(), 0.0);
    }

    #[test]
    fn collector_stages_but_never_updates() {
        let mut central = router();
        let mut worker = central.fork_collector();
        let mut rng = Rng::new(5);
        let s = snap(3);
        for _ in 0..40 {
            let d = worker.route_one(&s, &HeadView::new(0.5, 0), &mut rng);
            worker.feedback(&BlockFeedback {
                tag: d.tag,
                acc_prior_norm: 0.5,
                latency_s: 0.02,
                energy_j: 1.0,
                util_variance: 0.001,
            });
        }
        worker.end_of_run();
        // the collector held its fire even past any horizon
        assert_eq!(worker.stats.updates, 0);
        let ts = worker.take_transitions();
        assert_eq!(ts.len(), 40);

        // central trainer absorbs the harvest and updates synchronously;
        // the sub-horizon tail carries instead of being dropped
        central.cfg.horizon = 32;
        central.absorb_rollout(ts, 40);
        assert_eq!(central.stats.decisions, 40);
        assert_eq!(central.update_from_buffer(), 1);
        assert_eq!(central.stats.updates, 1);
        assert_eq!(central.stats.transitions_trained, 32);
        assert_eq!(central.buffered_transitions(), 8); // carried, not lost
        assert!(!central.stats.reward_history.is_empty());
    }

    #[test]
    fn update_from_buffer_carries_subhorizon_leftovers() {
        let mut central = router();
        central.cfg.horizon = 16;
        let mut worker = central.fork_collector();
        let mut rng = Rng::new(6);
        let s = snap(3);
        // two "rounds" of 24 completions each: each round leaves an
        // 8-transition remainder that must survive into the next one
        for round in 0..2u64 {
            for _ in 0..24 {
                let d = worker.route_one(&s, &HeadView::new(0.5, 0), &mut rng);
                worker.feedback(&BlockFeedback {
                    tag: d.tag,
                    acc_prior_norm: 0.5,
                    latency_s: 0.02,
                    energy_j: 1.0,
                    util_variance: 0.001,
                });
            }
            central.absorb_rollout(worker.take_transitions(), 24);
            central.update_from_buffer();
            // conservation at every round seam
            assert_eq!(
                central.stats.transitions_trained
                    + central.buffered_transitions() as u64,
                24 * (round + 1),
                "round {round}"
            );
        }
        // round 1: 24 → one chunk of 16, carry 8.
        // round 2: 8 + 24 = 32 → two chunks, carry 0.
        assert_eq!(central.stats.updates, 3);
        assert_eq!(central.stats.transitions_trained, 48);
        assert_eq!(central.buffered_transitions(), 0);
    }

    #[test]
    fn batched_plan_stages_one_transition_per_head() {
        let mut r = router();
        r.cfg.horizon = 10_000; // keep everything staged
        let mut rng = Rng::new(7);
        let s = snap(3);
        let heads: Vec<HeadView> = (0..5)
            .map(|i| HeadView {
                fifo_index: i,
                w_req: 0.5,
                seg: i % 4,
                age_s: 0.0,
                slack_s: 1.0,
            })
            .collect();
        let plan = r.plan(&s, &heads, &mut rng);
        assert_eq!(plan.len(), 5);
        assert!(plan.validate(5, 3, &[0.25, 0.5, 0.75, 1.0]).is_ok());
        assert_eq!(r.stats.decisions, 5);
        assert_eq!(r.buffer.pending_len(), 5);
        // completing every tag finishes every staged transition
        for d in plan.decisions() {
            r.feedback(&BlockFeedback {
                tag: d.tag,
                acc_prior_norm: 0.5,
                latency_s: 0.01,
                energy_j: 1.0,
                util_variance: 0.0,
            });
        }
        assert_eq!(r.buffer.ready(), 5);
        // tags are distinct
        let mut tags: Vec<u64> = plan.decisions().iter().map(|d| d.tag).collect();
        tags.dedup();
        assert_eq!(tags.len(), 5);
    }

    #[test]
    fn batched_plan_matches_eval_distribution_in_eval_mode() {
        // in eval mode a window of identical-position heads samples from
        // the same learned distribution as the scalar path
        let mut r = router();
        r.eval_mode();
        let mut rng = Rng::new(8);
        let s = snap(3);
        let heads: Vec<HeadView> =
            (0..8).map(|_| HeadView::new(0.5, 0)).collect();
        let mut widths_seen = std::collections::BTreeSet::new();
        for _ in 0..60 {
            let plan = r.plan(&s, &heads, &mut rng);
            assert_eq!(plan.len(), 8);
            for d in plan.decisions() {
                assert!(d.server < 3);
                widths_seen.insert((d.width * 100.0) as u32);
            }
        }
        assert!(widths_seen.len() >= 2, "no width diversity: {widths_seen:?}");
        assert_eq!(r.buffer.pending_len(), 0); // eval mode stages nothing
    }

    #[test]
    fn fork_collector_copies_weights_and_schedule() {
        let mut central = router();
        central.step = 12_345; // pretend mid-training
        let worker = central.fork_collector();
        assert_eq!(worker.step, 12_345);
        assert!(worker.training);
        let s = snap(3).to_state_vector();
        let (ec, _) = central.policy.evaluate(&s, None, 0.0);
        let (ew, _) = worker.policy.evaluate(&s, None, 0.0);
        for (a, b) in ec.p_w.iter().zip(&ew.p_w) {
            assert!((a - b).abs() < 1e-15);
        }
    }

    #[test]
    fn shared_handle_trains_one_router_across_replicas() {
        // two handles onto one router: decisions through either advance
        // the same schedule, buffer and tag space
        let shared = SharedPpoRouter::new(router());
        let mut a = shared.clone();
        let mut b = shared.clone();
        let mut rng = Rng::new(14);
        let s = snap(3);
        let d0 = a.route_one(&s, &HeadView::new(0.5, 0), &mut rng);
        let d1 = b.route_one(&s, &HeadView::new(0.5, 1), &mut rng);
        assert_ne!(d0.tag, d1.tag, "tag space must be shared");
        b.feedback(&BlockFeedback {
            tag: d0.tag,
            acc_prior_norm: 0.5,
            latency_s: 0.01,
            energy_j: 1.0,
            util_variance: 0.0,
        });
        drop(a);
        drop(b);
        let inner = shared.into_inner();
        assert_eq!(inner.stats.decisions, 2);
        assert_eq!(inner.buffer.ready(), 1); // d0 completed, d1 pending
    }

    #[test]
    fn run_ppo_episode_routes_single_and_sharded() {
        let mut cfg = Config::default();
        cfg.workload.total_requests = 300;
        cfg.workload.rate_hz = 250.0;
        cfg.ppo.horizon = 64;

        let ppo = PpoRouter::new(
            cfg.devices.len(),
            cfg.scheduler.widths.clone(),
            cfg.ppo.clone(),
            cfg.seed,
        );
        let (out, r) = run_ppo_episode(&cfg, ppo);
        assert_eq!(out.report.completed, 300);
        assert_eq!(out.shard_stats.len(), 1);
        assert!(r.stats.decisions > 0);

        cfg.shard.leaders = 3;
        let ppo = PpoRouter::new(
            cfg.devices.len(),
            cfg.scheduler.widths.clone(),
            cfg.ppo.clone(),
            cfg.seed,
        );
        let (out, r) = run_ppo_episode(&cfg, ppo);
        assert_eq!(out.report.completed, 300);
        assert_eq!(out.shard_stats.len(), 3);
        // every shard fed the one shared router
        let assigned: u64 = out.shard_stats.iter().map(|s| s.assigned).sum();
        assert!(assigned >= 300);
        assert!(r.stats.decisions > 0);
    }

    #[test]
    fn checkpoint_roundtrip() {
        let a = router();
        let ck = a.to_json();
        let mut b = router();
        // perturb b so the restore is observable
        b.policy.mlp.w[0].data[0] += 1.0;
        assert!(b.load_weights(&ck));
        let s = snap(3);
        let (ea, _) = a.policy.evaluate(&s.to_state_vector(), None, 0.0);
        let (eb, _) = b.policy.evaluate(&s.to_state_vector(), None, 0.0);
        for (x, y) in ea.p_w.iter().zip(&eb.p_w) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn greedy_eval_mode_is_rng_independent_and_stages_nothing() {
        let mut r = router();
        r.greedy_eval_mode();
        let s = snap(3);
        // two *different* RNG streams: greedy decoding must not consult
        // either, so the decision streams agree action for action
        let mut rng_a = Rng::new(1);
        let mut rng_b = Rng::new(999);
        for i in 0..20 {
            let da = r.route_one(&s, &HeadView::new(0.5, i % 4), &mut rng_a);
            let db = r.route_one(&s, &HeadView::new(0.5, i % 4), &mut rng_b);
            assert_eq!((da.server, da.width, da.group), (db.server, db.width, db.group));
        }
        assert_eq!(r.buffer.pending_len(), 0);
        assert_eq!(r.stats.updates, 0);

        // the batched path decodes the same way
        let heads: Vec<HeadView> = (0..6)
            .map(|i| HeadView {
                fifo_index: i,
                w_req: 0.5,
                seg: i % 4,
                age_s: 0.0,
                slack_s: 1.0,
            })
            .collect();
        let pa = r.plan(&s, &heads, &mut rng_a).into_decisions();
        let pb = r.plan(&s, &heads, &mut rng_b).into_decisions();
        for (a, b) in pa.iter().zip(&pb) {
            assert_eq!((a.server, a.width, a.group), (b.server, b.width, b.group));
        }
        assert_eq!(r.buffer.pending_len(), 0);
    }

    #[test]
    fn from_checkpoint_restores_a_frozen_greedy_router() {
        let trained = router();
        let dir = std::env::temp_dir();
        let path = dir.join(format!(
            "slim_sched_ckpt_{}.json",
            std::process::id()
        ));
        let path = path.to_str().unwrap().to_string();
        std::fs::write(&path, trained.to_json().to_string_pretty()).unwrap();

        let mut cfg = Config::default();
        cfg.workload.total_requests = 10;
        let mut restored =
            PpoRouter::from_checkpoint(&cfg, &path).expect("checkpoint loads");
        assert!(!restored.training);
        let s = snap(3);
        let mut rng = Rng::new(5);
        let d = restored.route_one(&s, &HeadView::new(0.5, 0), &mut rng);
        assert!(d.server < 3);

        // wrong-shape config (extra device) is rejected with the guard
        let mut wide = Config::default();
        wide.devices.push("gtx980ti".to_string());
        let err = PpoRouter::from_checkpoint(&wide, &path).unwrap_err();
        assert!(err.contains("does not match the policy shape"), "{err}");

        // unreadable path is a load error, not a panic
        let err = PpoRouter::from_checkpoint(&cfg, "/nonexistent/x.json")
            .unwrap_err();
        assert!(err.contains("cannot read"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_rejects_wrong_shape() {
        let mut r = router();
        let other = PpoRouter::new(2, vec![0.5, 1.0], PpoCfg::default(), 9);
        assert!(!r.load_weights(&other.to_json()));
    }

    #[test]
    fn state_slack_widens_the_policy_input_by_one() {
        let plain = router();
        let slack = PpoRouter::with_state_slack(
            3,
            vec![0.25, 0.5, 0.75, 1.0],
            PpoCfg::default(),
            1,
            true,
        );
        assert_eq!(
            plain.policy.mlp.sizes[0],
            TelemetrySnapshot::state_dim(3, false)
        );
        assert_eq!(
            slack.policy.mlp.sizes[0],
            TelemetrySnapshot::state_dim(3, true)
        );
        assert_eq!(slack.policy.mlp.sizes[0], plain.policy.mlp.sizes[0] + 1);
    }

    #[test]
    fn checkpoints_do_not_cross_the_state_slack_flag() {
        // dimension-compat guard: a slack-state checkpoint must not load
        // into a plain router (and vice versa) — shapes differ by design
        let mut plain = router();
        let mut slack = PpoRouter::with_state_slack(
            3,
            vec![0.25, 0.5, 0.75, 1.0],
            PpoCfg::default(),
            1,
            true,
        );
        assert!(!plain.load_weights(&slack.to_json()));
        assert!(!slack.load_weights(&plain.to_json()));
        // same-flag checkpoints still roundtrip
        let twin = PpoRouter::with_state_slack(
            3,
            vec![0.25, 0.5, 0.75, 1.0],
            PpoCfg::default(),
            99,
            true,
        );
        assert!(slack.load_weights(&twin.to_json()));
    }

    #[test]
    fn slack_feature_clamps_and_sanitizes() {
        assert_eq!(slack_feature(0.5), 0.5);
        assert_eq!(slack_feature(-100.0), -4.0);
        assert_eq!(slack_feature(f64::INFINITY), 4.0);
        assert_eq!(slack_feature(f64::NEG_INFINITY), -4.0);
        assert_eq!(slack_feature(f64::NAN), 0.0);
    }

    #[test]
    fn slack_state_router_routes_and_trains_end_to_end() {
        let mut cfg = Config::default();
        cfg.workload.total_requests = 250;
        cfg.workload.rate_hz = 220.0;
        cfg.router.state_slack = true;
        cfg.router.route_window = 4; // exercise the batched featurizer too
        cfg.ppo.horizon = 64;
        let ppo = PpoRouter::for_config(&cfg);
        assert_eq!(
            ppo.policy.mlp.sizes[0],
            TelemetrySnapshot::state_dim(cfg.devices.len(), true)
        );
        let (out, r) = run_ppo_episode(&cfg, ppo);
        assert_eq!(out.report.completed, 250);
        assert!(r.stats.decisions > 0);
        // collectors inherit the flag (same policy shape)
        let worker = r.fork_collector();
        assert_eq!(worker.policy.mlp.sizes[0], r.policy.mlp.sizes[0]);
    }

    #[test]
    fn state_slack_off_is_bit_identical_to_the_old_constructor() {
        // flag off must not perturb weight init or the decision stream
        let a = router();
        let b = PpoRouter::with_state_slack(
            3,
            vec![0.25, 0.5, 0.75, 1.0],
            PpoCfg::default(),
            1,
            false,
        );
        let s = snap(3).to_state_vector();
        let (ea, _) = a.policy.evaluate(&s, None, 0.0);
        let (eb, _) = b.policy.evaluate(&s, None, 0.0);
        for (x, y) in ea.p_w.iter().zip(&eb.p_w) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }
}
