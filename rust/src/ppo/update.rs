//! The clipped PPO update (eq. 8–13).
//!
//! One-step advantages `A_t = r_t − V_old(s_t)`, normalized over the
//! batch (eq. 8); importance ratio against the *mixed* old likelihood
//! (eq. 9); clipped surrogate + value loss + entropy bonus minimized for
//! K epochs with global gradient-norm clipping.

use crate::config::PpoCfg;

use super::adam::Adam;
use super::buffer::Transition;
use super::policy::Policy;

/// Diagnostics from one update.
#[derive(Clone, Copy, Debug, Default)]
pub struct UpdateStats {
    pub transitions: usize,
    pub mean_reward: f64,
    pub mean_advantage_raw: f64,
    pub policy_loss: f64,
    pub value_loss: f64,
    pub entropy: f64,
    pub clip_fraction: f64,
    pub grad_norm: f64,
}

/// Run K epochs of clipped PPO on a finished rollout.
pub fn ppo_update(
    policy: &mut Policy,
    adam: &mut Adam,
    batch: &[Transition],
    cfg: &PpoCfg,
) -> UpdateStats {
    let n = batch.len();
    if n == 0 {
        return UpdateStats::default();
    }

    // eq. 8: one-step returns & normalized advantages (against V_old)
    let advantages_raw: Vec<f64> =
        batch.iter().map(|t| t.reward - t.value_old).collect();
    let mean_a = advantages_raw.iter().sum::<f64>() / n as f64;
    let var_a = advantages_raw
        .iter()
        .map(|a| (a - mean_a) * (a - mean_a))
        .sum::<f64>()
        / n as f64;
    let std_a = var_a.sqrt();
    let advantages: Vec<f64> = advantages_raw
        .iter()
        .map(|a| (a - mean_a) / (std_a + 1e-8))
        .collect();

    let mut stats = UpdateStats {
        transitions: n,
        mean_reward: batch.iter().map(|t| t.reward).sum::<f64>() / n as f64,
        mean_advantage_raw: mean_a,
        ..Default::default()
    };

    for _epoch in 0..cfg.epochs {
        let mut grads = policy.mlp.zeros_like();
        let mut policy_loss = 0.0;
        let mut value_loss = 0.0;
        let mut entropy_sum = 0.0;
        let mut clipped = 0usize;

        for (t, &adv) in batch.iter().zip(&advantages) {
            let (eval, _) = policy.evaluate(&t.state, Some(t.action), t.eps);
            let ratio = (eval.logp - t.logp_old).exp();
            let unclipped = ratio * adv;
            let ratio_clipped = ratio.clamp(1.0 - cfg.clip, 1.0 + cfg.clip);
            let clipped_term = ratio_clipped * adv;

            // surrogate L = min(unclipped, clipped); J = -L
            let (surrogate, coef_logp) = if unclipped <= clipped_term {
                // gradient flows through the unclipped branch:
                // dJ/dlogp = -ratio·adv
                (unclipped, -ratio * adv)
            } else {
                clipped += 1;
                // clipped branch is constant in θ (hard clip)
                (clipped_term, 0.0)
            };
            policy_loss -= surrogate;

            // value loss 0.5 (R - V)^2, dJ/dV = c_v (V - R)
            let vr = t.reward;
            value_loss += 0.5 * (vr - eval.value) * (vr - eval.value);
            let dvalue = cfg.c_v * (eval.value - vr);

            entropy_sum += eval.entropy;

            policy.backward_transition(
                &eval,
                t.action,
                t.eps,
                coef_logp,
                cfg.c_h,
                dvalue,
                &mut grads,
            );
        }

        grads.scale(1.0 / n as f64);
        let norm = grads.global_norm();
        if norm > cfg.grad_clip {
            grads.scale(cfg.grad_clip / norm);
        }
        adam.step(&mut policy.mlp, &grads);

        stats.policy_loss = policy_loss / n as f64;
        stats.value_loss = value_loss / n as f64;
        stats.entropy = entropy_sum / n as f64;
        stats.clip_fraction = clipped as f64 / n as f64;
        stats.grad_norm = norm;
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PpoCfg;
    use crate::utilx::Rng;

    fn make_policy(seed: u64) -> (Policy, Adam) {
        let mut rng = Rng::new(seed);
        let p = Policy::new(4, &[16], 3, 4, 3, &mut rng);
        let adam = Adam::new(&p.mlp, 5e-3);
        (p, adam)
    }

    /// Bandit check: server 1 always pays +1, others −1. After a few
    /// updates the policy mass should concentrate on server 1.
    #[test]
    fn learns_a_contextual_bandit() {
        let (mut policy, mut adam) = make_policy(1);
        let mut cfg = PpoCfg::default();
        cfg.epochs = 3;
        cfg.c_h = 0.001;
        let state = vec![0.5, -0.2, 0.1, 0.9];
        let mut rng = Rng::new(2);

        for _round in 0..60 {
            let mut batch = Vec::new();
            for _ in 0..64 {
                let (a, eval) = policy.sample(&state, 0.1, &mut rng);
                let reward = if a.srv == 1 { 1.0 } else { -1.0 };
                batch.push(Transition {
                    state: state.clone(),
                    action: a,
                    logp_old: eval.logp,
                    value_old: eval.value,
                    eps: 0.1,
                    reward,
                });
            }
            ppo_update(&mut policy, &mut adam, &batch, &cfg);
        }
        let (eval, _) = policy.evaluate(&state, None, 0.0);
        assert!(
            eval.p_srv[1] > 0.8,
            "policy did not concentrate: {:?}",
            eval.p_srv
        );
    }

    /// Width-head bandit: reward = +1 for width index 0 (slimmest), −1
    /// otherwise — the Table IV collapse in miniature.
    #[test]
    fn width_head_collapses_under_heavy_latency_penalty() {
        let (mut policy, mut adam) = make_policy(3);
        let cfg = PpoCfg { c_h: 0.001, ..PpoCfg::default() };
        let state = vec![0.1, 0.2, 0.3, 0.4];
        let mut rng = Rng::new(4);
        for _ in 0..60 {
            let mut batch = Vec::new();
            for _ in 0..64 {
                let (a, eval) = policy.sample(&state, 0.05, &mut rng);
                let reward = if a.w == 0 { 1.0 } else { -1.0 };
                batch.push(Transition {
                    state: state.clone(),
                    action: a,
                    logp_old: eval.logp,
                    value_old: eval.value,
                    eps: 0.05,
                    reward,
                });
            }
            ppo_update(&mut policy, &mut adam, &batch, &cfg);
        }
        let (eval, _) = policy.evaluate(&state, None, 0.0);
        assert!(eval.p_w[0] > 0.8, "{:?}", eval.p_w);
    }

    #[test]
    fn value_head_regresses_to_reward() {
        let (mut policy, mut adam) = make_policy(5);
        let cfg = PpoCfg { c_h: 0.0, ..PpoCfg::default() };
        let state = vec![0.0, 1.0, 0.0, -1.0];
        let mut rng = Rng::new(6);
        for _ in 0..80 {
            let mut batch = Vec::new();
            for _ in 0..32 {
                let (a, eval) = policy.sample(&state, 0.1, &mut rng);
                batch.push(Transition {
                    state: state.clone(),
                    action: a,
                    logp_old: eval.logp,
                    value_old: eval.value,
                    eps: 0.1,
                    reward: 3.0, // constant reward
                });
            }
            ppo_update(&mut policy, &mut adam, &batch, &cfg);
        }
        let (eval, _) = policy.evaluate(&state, None, 0.0);
        assert!((eval.value - 3.0).abs() < 0.5, "value={}", eval.value);
    }

    #[test]
    fn empty_batch_is_noop() {
        let (mut policy, mut adam) = make_policy(7);
        let before = policy.mlp.clone();
        let stats = ppo_update(&mut policy, &mut adam, &[], &PpoCfg::default());
        assert_eq!(stats.transitions, 0);
        assert_eq!(policy.mlp.w[0].data, before.w[0].data);
    }

    #[test]
    fn gradient_clipping_bounds_update() {
        let (mut policy, mut adam) = make_policy(8);
        let mut cfg = PpoCfg::default();
        cfg.grad_clip = 1e-6; // absurdly tight
        let state = vec![1.0; 4];
        let mut rng = Rng::new(9);
        let (a, eval) = policy.sample(&state, 0.1, &mut rng);
        let batch = vec![Transition {
            state,
            action: a,
            logp_old: eval.logp,
            value_old: eval.value,
            eps: 0.1,
            reward: 100.0,
        }];
        let before = policy.mlp.clone();
        ppo_update(&mut policy, &mut adam, &batch, &cfg);
        // params moved, but only a hair (Adam step bounded by lr anyway;
        // the clipped gradient is tiny)
        let mut max_delta: f64 = 0.0;
        for l in 0..policy.mlp.w.len() {
            for (a, b) in policy.mlp.w[l].data.iter().zip(&before.w[l].data) {
                max_delta = max_delta.max((a - b).abs());
            }
        }
        assert!(max_delta < 0.02, "max_delta={max_delta}");
    }

    #[test]
    fn clip_fraction_rises_with_stale_logp() {
        let (mut policy, mut adam) = make_policy(10);
        let cfg = PpoCfg::default();
        let state = vec![0.3; 4];
        let mut rng = Rng::new(11);
        let mut batch = Vec::new();
        for _ in 0..32 {
            let (a, eval) = policy.sample(&state, 0.1, &mut rng);
            batch.push(Transition {
                state: state.clone(),
                action: a,
                // deliberately stale: pretend old policy was very different
                logp_old: eval.logp - 1.0,
                value_old: eval.value,
                eps: 0.1,
                reward: rng.normal(),
            });
        }
        let stats = ppo_update(&mut policy, &mut adam, &batch, &cfg);
        assert!(stats.clip_fraction > 0.2, "{}", stats.clip_fraction);
    }
}
