//! Parallel PPO rollouts: N seeded worker engines per round, one OS
//! thread each, merging their transition harvests into the central
//! router's `RolloutBuffer` for synchronous updates.
//!
//! The sequential trainer (`experiments::train_ppo`) threads one router
//! through one engine at a time, so wall-clock scales linearly with the
//! episode budget. Engines are cheap to construct and `Send`
//! (`coordinator::core`), which makes the data-parallel shape natural:
//!
//! ```text
//!   round k:   central policy θ_k
//!      ├─ worker 0: Engine(seed(ep))   ─ collect transitions ┐
//!      ├─ worker 1: Engine(seed(ep+1)) ─ collect transitions ┼─ merge
//!      └─ worker W: Engine(seed(ep+W)) ─ collect transitions ┘   │
//!                                                  θ_{k+1} ◄─ PPO updates
//! ```
//!
//! Every worker runs a *collector* fork of the central router (same
//! weights, same ε-schedule position, updates disabled), so within a
//! round all workers act under the identical policy — the classic
//! synchronous-PPO setup. Harvests merge in worker-index order and each
//! worker's engine is independently seeded with the same episode-seed
//! formula the sequential trainer uses, so a run is deterministic for a
//! fixed (seed, episodes, workers) triple regardless of thread timing.
//!
//! Updates consume full-`horizon` chunks in rollout order; the
//! sub-horizon remainder of each round **carries** into the next round's
//! buffer instead of being dropped (`RolloutBuffer::carry`), so no
//! collected transition is lost at round seams. A final end-of-training
//! flush trains any tail at or above the 16-transition noise floor; a
//! smaller tail stays buffered (accounted, deliberately untrained).
//!
//! With `workers = 1` the trainer degenerates to one collector per
//! round; `experiments::train_ppo_workers` routes that case to the
//! original sequential online trainer instead, which keeps the paper's
//! Tables IV–V training dynamics bit-identical to the seed.

use std::thread;

use crate::config::{Config, RewardCfg};
use crate::coordinator::Router;

use super::buffer::Transition;
use super::router_impl::{run_ppo_episode, PpoRouter};

/// Episode seed formula shared with `experiments::train_ppo`.
pub fn episode_seed(base: u64, episode: usize) -> u64 {
    base.wrapping_add(1 + episode as u64 * 7919)
}

/// One worker's harvest.
struct Harvest {
    transitions: Vec<Transition>,
    decisions: u64,
    completed: u64,
}

/// Train a PPO router for `episodes` simulated workloads, running up to
/// `workers` episodes concurrently per round and updating synchronously
/// between rounds. Returns the router still in training mode (freeze
/// with `eval_mode` for Tables IV–V style evaluation).
pub fn train_parallel(
    cfg: &Config,
    reward: RewardCfg,
    episodes: usize,
    workers: usize,
) -> PpoRouter {
    let workers = workers.max(1);
    let mut ppo_cfg = cfg.ppo.clone();
    ppo_cfg.reward = reward;
    let mut central = PpoRouter::with_state_slack(
        cfg.devices.len(),
        cfg.scheduler.widths.clone(),
        ppo_cfg,
        cfg.seed,
        cfg.router.state_slack,
    );

    let mut ep = 0usize;
    while ep < episodes {
        let round = workers.min(episodes - ep);
        let mut harvests: Vec<Harvest> = Vec::with_capacity(round);
        thread::scope(|scope| {
            let mut handles = Vec::with_capacity(round);
            for k in 0..round {
                let mut worker_cfg = cfg.clone();
                worker_cfg.seed = episode_seed(cfg.seed, ep + k);
                let collector = central.fork_collector();
                handles.push(scope.spawn(move || {
                    // honors cfg.shard.leaders: a sharded worker engine
                    // shares the collector across its leader shards
                    let (outcome, mut router) =
                        run_ppo_episode(&worker_cfg, collector);
                    Harvest {
                        transitions: router.take_transitions(),
                        decisions: router.stats.decisions,
                        completed: outcome.report.completed,
                    }
                }));
            }
            // join in spawn order: the merge below is deterministic no
            // matter how the OS interleaved the workers
            for h in handles {
                harvests.push(h.join().expect("rollout worker panicked"));
            }
        });

        for h in &harvests {
            debug_assert!(h.completed > 0 || cfg.workload.total_requests == 0);
        }
        for h in harvests {
            central.absorb_rollout(h.transitions, h.decisions);
        }
        central.update_from_buffer();
        ep += round;
    }
    // flush the carried tail (≥ the end-of-run noise floor) so the last
    // round's remainder still informs the returned policy
    central.end_of_run();
    central
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::telemetry::{ServerTelemetry, TelemetrySnapshot};

    fn tiny_cfg() -> Config {
        let mut cfg = Config::default();
        cfg.workload.total_requests = 500;
        cfg.ppo.horizon = 64;
        cfg
    }

    fn probe_snapshot(n: usize) -> TelemetrySnapshot {
        TelemetrySnapshot {
            fifo_len: 6,
            done_count: 40,
            total_requests: 500,
            servers: (0..n)
                .map(|i| ServerTelemetry {
                    queue_len: 2 * i,
                    power_w: 110.0,
                    util_pct: 20.0 * i as f64,
                    mem_util: 0.25,
                    instances: 1,
                })
                .collect(),
        }
    }

    fn policy_fingerprint(router: &PpoRouter) -> Vec<f64> {
        let state = probe_snapshot(3).to_state_vector();
        let (eval, _) = router.policy.evaluate(&state, None, 0.0);
        let mut v = eval.p_srv;
        v.extend(eval.p_w);
        v.extend(eval.p_g);
        v.push(eval.value);
        v
    }

    #[test]
    fn parallel_training_learns_and_counts_episodes() {
        let cfg = tiny_cfg();
        let router = train_parallel(&cfg, RewardCfg::overfit(), 4, 2);
        assert!(router.stats.updates > 0, "no updates ran");
        assert!(router.stats.decisions > 0);
        assert!(!router.stats.reward_history.is_empty());
    }

    #[test]
    fn parallel_training_is_deterministic_per_seed() {
        let cfg = tiny_cfg();
        let a = train_parallel(&cfg, RewardCfg::balanced(), 4, 2);
        let b = train_parallel(&cfg, RewardCfg::balanced(), 4, 2);
        assert_eq!(a.stats.decisions, b.stats.decisions);
        assert_eq!(a.stats.updates, b.stats.updates);
        let fa = policy_fingerprint(&a);
        let fb = policy_fingerprint(&b);
        for (x, y) in fa.iter().zip(&fb) {
            assert!((x - y).abs() < 1e-12, "{x} vs {y}");
        }
    }

    #[test]
    fn worker_counts_see_the_same_episode_seeds() {
        // the episode-seed formula is shared with the sequential trainer,
        // so scenario sweeps stay comparable across --workers settings
        assert_eq!(episode_seed(42, 0), 42 + 1);
        assert_eq!(episode_seed(42, 3), 42 + 1 + 3 * 7919);
    }

    #[test]
    fn single_worker_round_still_trains() {
        let cfg = tiny_cfg();
        let router = train_parallel(&cfg, RewardCfg::overfit(), 2, 1);
        assert!(router.stats.updates > 0);
    }

    #[test]
    fn no_transition_is_lost_at_round_seams() {
        // every decision of a drained episode completes into exactly one
        // transition, so across rounds the trained + still-buffered
        // counts must equal the decision count — the old per-round
        // tail-drop broke this whenever an episode wasn't a multiple of
        // the horizon
        let mut cfg = tiny_cfg();
        cfg.workload.total_requests = 300;
        cfg.ppo.horizon = 128; // guarantees a sub-horizon tail per round
        let router = train_parallel(&cfg, RewardCfg::balanced(), 3, 2);
        assert!(router.stats.decisions > 0);
        assert_eq!(
            router.stats.transitions_trained
                + router.buffered_transitions() as u64,
            router.stats.decisions,
            "transitions vanished at a round seam"
        );
        // the final flush leaves at most the noise floor buffered
        assert!(router.buffered_transitions() < 16);
    }
}
