//! Rollout storage between PPO updates.
//!
//! The router acts at block granularity; the reward for a decision only
//! materializes when its block completes (possibly many events later), so
//! transitions are staged in a pending map keyed by the decision tag and
//! move into the finished rollout when `complete` is called with the
//! reward. One-step returns: R_t ≡ r_t (eq. 8).

use std::collections::HashMap;

use super::policy::ActionTriple;

/// One finished (state, action, logπ_old, V_old, reward) tuple.
#[derive(Clone, Debug)]
pub struct Transition {
    pub state: Vec<f64>,
    pub action: ActionTriple,
    pub logp_old: f64,
    pub value_old: f64,
    pub eps: f64,
    pub reward: f64,
}

/// Staged + finished transitions.
#[derive(Clone, Debug, Default)]
pub struct RolloutBuffer {
    pending: HashMap<u64, Transition>,
    finished: Vec<Transition>,
    /// Rewards observed (for logging).
    pub reward_sum: f64,
    pub reward_count: u64,
}

impl RolloutBuffer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Stage a decision awaiting its block completion.
    pub fn stage(
        &mut self,
        tag: u64,
        state: Vec<f64>,
        action: ActionTriple,
        logp_old: f64,
        value_old: f64,
        eps: f64,
    ) {
        self.pending.insert(
            tag,
            Transition { state, action, logp_old, value_old, eps, reward: 0.0 },
        );
    }

    /// Drop a staged transition whose block was cancelled before
    /// executing (device dropout re-route) — its reward never arrives.
    pub fn abandon(&mut self, tag: u64) {
        self.pending.remove(&tag);
    }

    /// Attach the reward and finish the transition. Unknown tags are
    /// ignored (e.g. blocks completing after a buffer reset).
    pub fn complete(&mut self, tag: u64, reward: f64) {
        if let Some(mut t) = self.pending.remove(&tag) {
            t.reward = reward;
            self.reward_sum += reward;
            self.reward_count += 1;
            self.finished.push(t);
        }
    }

    pub fn ready(&self) -> usize {
        self.finished.len()
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Take the finished transitions (leaves staged ones in place).
    pub fn drain(&mut self) -> Vec<Transition> {
        std::mem::take(&mut self.finished)
    }

    /// Merge already-completed transitions from another rollout (the
    /// parallel workers' harvests), preserving their order.
    pub fn absorb(&mut self, transitions: Vec<Transition>) {
        for t in &transitions {
            self.reward_sum += t.reward;
            self.reward_count += 1;
        }
        self.finished.extend(transitions);
    }

    /// Re-buffer transitions this buffer already accounted for (the
    /// sub-horizon remainder of an update round, carried across round
    /// seams). Unlike [`RolloutBuffer::absorb`] this does **not** touch
    /// the reward statistics — the transitions were counted when they
    /// first completed or were absorbed.
    pub fn carry(&mut self, transitions: Vec<Transition>) {
        self.finished.extend(transitions);
    }

    pub fn mean_reward(&self) -> f64 {
        if self.reward_count == 0 {
            0.0
        } else {
            self.reward_sum / self.reward_count as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn act() -> ActionTriple {
        ActionTriple { srv: 0, w: 1, g: 2 }
    }

    #[test]
    fn stage_then_complete_moves_to_finished() {
        let mut buf = RolloutBuffer::new();
        buf.stage(7, vec![0.1], act(), -1.2, 0.3, 0.1);
        assert_eq!(buf.pending_len(), 1);
        assert_eq!(buf.ready(), 0);
        buf.complete(7, 2.5);
        assert_eq!(buf.pending_len(), 0);
        assert_eq!(buf.ready(), 1);
        let ts = buf.drain();
        assert_eq!(ts[0].reward, 2.5);
        assert_eq!(ts[0].logp_old, -1.2);
        assert_eq!(buf.ready(), 0);
    }

    #[test]
    fn abandon_drops_pending_without_reward() {
        let mut buf = RolloutBuffer::new();
        buf.stage(5, vec![0.2], act(), -0.5, 0.1, 0.0);
        buf.abandon(5);
        assert_eq!(buf.pending_len(), 0);
        // a late completion for the abandoned tag is a no-op
        buf.complete(5, 9.0);
        assert_eq!(buf.ready(), 0);
        assert_eq!(buf.reward_count, 0);
    }

    #[test]
    fn unknown_tag_ignored() {
        let mut buf = RolloutBuffer::new();
        buf.complete(99, 1.0);
        assert_eq!(buf.ready(), 0);
        assert_eq!(buf.reward_count, 0);
    }

    #[test]
    fn mean_reward_tracks_completions() {
        let mut buf = RolloutBuffer::new();
        for (tag, r) in [(1u64, 1.0), (2, 3.0)] {
            buf.stage(tag, vec![], act(), 0.0, 0.0, 0.0);
            buf.complete(tag, r);
        }
        assert_eq!(buf.mean_reward(), 2.0);
    }

    #[test]
    fn absorb_merges_finished_transitions() {
        let mut a = RolloutBuffer::new();
        a.stage(1, vec![], act(), 0.0, 0.0, 0.0);
        a.complete(1, 1.0);

        let mut b = RolloutBuffer::new();
        for (tag, r) in [(10u64, 2.0), (11, 4.0)] {
            b.stage(tag, vec![], act(), 0.0, 0.0, 0.0);
            b.complete(tag, r);
        }
        a.absorb(b.drain());
        assert_eq!(a.ready(), 3);
        assert_eq!(a.reward_count, 3);
        assert!((a.mean_reward() - 7.0 / 3.0).abs() < 1e-12);
        // worker order preserved after the local transitions
        let ts = a.drain();
        assert_eq!(ts[1].reward, 2.0);
        assert_eq!(ts[2].reward, 4.0);
    }

    #[test]
    fn carry_requeues_without_recounting_rewards() {
        let mut buf = RolloutBuffer::new();
        for (tag, r) in [(1u64, 2.0), (2, 4.0), (3, 6.0)] {
            buf.stage(tag, vec![], act(), 0.0, 0.0, 0.0);
            buf.complete(tag, r);
        }
        let mut drained = buf.drain();
        assert_eq!(buf.ready(), 0);
        let tail = drained.split_off(2);
        buf.carry(tail);
        assert_eq!(buf.ready(), 1);
        // reward stats unchanged by the carry
        assert_eq!(buf.reward_count, 3);
        assert!((buf.mean_reward() - 4.0).abs() < 1e-12);
        // the carried transition precedes anything absorbed later
        let mut other = RolloutBuffer::new();
        other.stage(9, vec![], act(), 0.0, 0.0, 0.0);
        other.complete(9, 8.0);
        buf.absorb(other.drain());
        let ts = buf.drain();
        assert_eq!(ts[0].reward, 6.0);
        assert_eq!(ts[1].reward, 8.0);
        assert_eq!(buf.reward_count, 4);
    }

    #[test]
    fn drain_leaves_pending() {
        let mut buf = RolloutBuffer::new();
        buf.stage(1, vec![], act(), 0.0, 0.0, 0.0);
        buf.stage(2, vec![], act(), 0.0, 0.0, 0.0);
        buf.complete(1, 1.0);
        let drained = buf.drain();
        assert_eq!(drained.len(), 1);
        assert_eq!(buf.pending_len(), 1);
        buf.complete(2, 1.0);
        assert_eq!(buf.ready(), 1);
    }
}
