//! Trace recording: the event schema and the JSONL sink.
//!
//! A trace is one JSONL document: a header line (`{"trace":
//! "slim-scheduler", "version": 1, ...}` carrying the run's router name,
//! declared request count and the full serialized [`Config`]) followed by
//! one line per [`TraceEvent`]. Field order inside every line is fixed
//! (the JSON writer preserves insertion order and renders floats with
//! Rust's shortest-round-trip formatting), so two runs of the same
//! seeded configuration produce **byte-identical** files and two seeds
//! byte-diff — the property the round-trip tests pin.
//!
//! The engine emits events through the [`TraceSink`] trait (a no-op when
//! no sink is installed); [`TraceRecorder`] is the standard in-memory
//! sink behind a cheap cloneable handle, so callers keep a handle while
//! the engine owns the boxed sink and can serialize ([`TraceRecorder::
//! to_jsonl`]) or persist ([`TraceRecorder::write`]) after the run.

use std::sync::{Arc, Mutex};

use crate::config::Config;
use crate::utilx::json::{arr_f64, obj, Json};

/// Trace format version — bump on any schema change.
///
/// v2 appends a `tenant` field to `arrival` and `done` records (v1
/// traces parse with tenant defaulting to 0 — see [`TraceEvent::
/// from_json`] and the replay-side version gate).
///
/// v3 adds the `knobs` record: the control plane's knob state at run
/// start and at every retune, so a replay can verify the controller
/// retuned identically. v1/v2 traces (no controller) still load.
pub const TRACE_VERSION: u64 = 3;

/// One per-request lifecycle (or run-level telemetry) record.
#[derive(Clone, Debug, PartialEq)]
pub enum TraceEvent {
    /// A request reached the leader tier (before any admission gate —
    /// shed requests still record their arrival, which is what lets an
    /// overloaded `--admission drr` trace replay byte-identically).
    Arrival { t: f64, id: u64, w_req: f64, tenant: u16 },
    /// A request landed on a leader shard — via the assignment policy
    /// (arrival, segment re-entry, device-dropout readmission) or via a
    /// cross-shard *rebalance* migration, which re-emits the record
    /// with the destination shard. The latest `assign` for a request id
    /// is therefore always its authoritative placement.
    Assign { t: f64, id: u64, seg: usize, shard: usize },
    /// A routing decision was applied: `size` requests of segment `seg`
    /// dispatched as one block to `server`, arriving at `arrive_t`.
    /// `tag` is the router-local decision tag (`shard` disambiguates —
    /// local tags stay far below 2^53 so the JSON number is exact);
    /// `clamped` counts the decision fields the explicit repair path
    /// corrected (0 for well-behaved routers).
    Route {
        t: f64,
        shard: usize,
        tag: u64,
        seg: usize,
        server: usize,
        width: f64,
        group: usize,
        size: usize,
        clamped: u64,
        arrive_t: f64,
    },
    /// A request crossed its final segment: end-to-end latency,
    /// accumulated per-request energy, SLA slack at completion
    /// (negative = missed) and the executed width tuple.
    Done {
        t: f64,
        id: u64,
        e2e_s: f64,
        energy_j: f64,
        slack_s: f64,
        widths: Vec<f64>,
        tenant: u16,
    },
    /// Run-level telemetry tick: leader FIFO depth, completions, and
    /// per-server utilization / power samples.
    Tick { t: f64, fifo: usize, done: u64, util: Vec<f64>, power: Vec<f64> },
    /// Control-plane knob state (v3): emitted once at run start and
    /// again whenever a controller retunes, so replays can assert the
    /// adaptive path re-derived the same knob trajectory.
    Knobs {
        t: f64,
        route_window: usize,
        rebalance_threshold: usize,
        drr_quantum: f64,
        drr_burst_cap: f64,
        drr_queue_cap: usize,
    },
}

impl TraceEvent {
    /// Serialize with the fixed field order (v2: `tenant` appended last
    /// on `arrival`/`done` so v1 field prefixes are unchanged).
    pub fn to_json(&self) -> Json {
        match self {
            TraceEvent::Arrival { t, id, w_req, tenant } => obj(vec![
                ("ev", Json::Str("arrival".into())),
                ("t", Json::Num(*t)),
                ("id", Json::Num(*id as f64)),
                ("w_req", Json::Num(*w_req)),
                ("tenant", Json::Num(*tenant as f64)),
            ]),
            TraceEvent::Assign { t, id, seg, shard } => obj(vec![
                ("ev", Json::Str("assign".into())),
                ("t", Json::Num(*t)),
                ("id", Json::Num(*id as f64)),
                ("seg", Json::Num(*seg as f64)),
                ("shard", Json::Num(*shard as f64)),
            ]),
            TraceEvent::Route {
                t,
                shard,
                tag,
                seg,
                server,
                width,
                group,
                size,
                clamped,
                arrive_t,
            } => obj(vec![
                ("ev", Json::Str("route".into())),
                ("t", Json::Num(*t)),
                ("shard", Json::Num(*shard as f64)),
                ("tag", Json::Num(*tag as f64)),
                ("seg", Json::Num(*seg as f64)),
                ("server", Json::Num(*server as f64)),
                ("width", Json::Num(*width)),
                ("group", Json::Num(*group as f64)),
                ("size", Json::Num(*size as f64)),
                ("clamped", Json::Num(*clamped as f64)),
                ("arrive_t", Json::Num(*arrive_t)),
            ]),
            TraceEvent::Done { t, id, e2e_s, energy_j, slack_s, widths, tenant } => {
                obj(vec![
                    ("ev", Json::Str("done".into())),
                    ("t", Json::Num(*t)),
                    ("id", Json::Num(*id as f64)),
                    ("e2e_s", Json::Num(*e2e_s)),
                    ("energy_j", Json::Num(*energy_j)),
                    ("slack_s", Json::Num(*slack_s)),
                    ("widths", arr_f64(widths)),
                    ("tenant", Json::Num(*tenant as f64)),
                ])
            }
            TraceEvent::Tick { t, fifo, done, util, power } => obj(vec![
                ("ev", Json::Str("tick".into())),
                ("t", Json::Num(*t)),
                ("fifo", Json::Num(*fifo as f64)),
                ("done", Json::Num(*done as f64)),
                ("util", arr_f64(util)),
                ("power", arr_f64(power)),
            ]),
            TraceEvent::Knobs {
                t,
                route_window,
                rebalance_threshold,
                drr_quantum,
                drr_burst_cap,
                drr_queue_cap,
            } => obj(vec![
                ("ev", Json::Str("knobs".into())),
                ("t", Json::Num(*t)),
                ("route_window", Json::Num(*route_window as f64)),
                ("rebalance_threshold", Json::Num(*rebalance_threshold as f64)),
                ("drr_quantum", Json::Num(*drr_quantum)),
                ("drr_burst_cap", Json::Num(*drr_burst_cap)),
                ("drr_queue_cap", Json::Num(*drr_queue_cap as f64)),
            ]),
        }
    }

    /// Parse one record line; `Err` names the missing/invalid piece.
    pub fn from_json(json: &Json) -> Result<TraceEvent, String> {
        let kind = json
            .get("ev")
            .and_then(Json::as_str)
            .ok_or_else(|| "record missing \"ev\" kind".to_string())?;
        let num = |key: &str| -> Result<f64, String> {
            json.get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("{kind} record missing numeric {key:?}"))
        };
        let vec = |key: &str| -> Result<Vec<f64>, String> {
            json.get(key)
                .and_then(Json::as_f64_vec)
                .ok_or_else(|| format!("{kind} record missing array {key:?}"))
        };
        // v1 records carry no tenant field — default to tenant 0 so old
        // traces keep parsing (the replay version gate relies on this)
        let tenant =
            || json.get("tenant").and_then(Json::as_f64).unwrap_or(0.0) as u16;
        match kind {
            "arrival" => Ok(TraceEvent::Arrival {
                t: num("t")?,
                id: num("id")? as u64,
                w_req: num("w_req")?,
                tenant: tenant(),
            }),
            "assign" => Ok(TraceEvent::Assign {
                t: num("t")?,
                id: num("id")? as u64,
                seg: num("seg")? as usize,
                shard: num("shard")? as usize,
            }),
            "route" => Ok(TraceEvent::Route {
                t: num("t")?,
                shard: num("shard")? as usize,
                tag: num("tag")? as u64,
                seg: num("seg")? as usize,
                server: num("server")? as usize,
                width: num("width")?,
                group: num("group")? as usize,
                size: num("size")? as usize,
                clamped: num("clamped")? as u64,
                arrive_t: num("arrive_t")?,
            }),
            "done" => Ok(TraceEvent::Done {
                t: num("t")?,
                id: num("id")? as u64,
                e2e_s: num("e2e_s")?,
                energy_j: num("energy_j")?,
                slack_s: num("slack_s")?,
                widths: vec("widths")?,
                tenant: tenant(),
            }),
            "tick" => Ok(TraceEvent::Tick {
                t: num("t")?,
                fifo: num("fifo")? as usize,
                done: num("done")? as u64,
                util: vec("util")?,
                power: vec("power")?,
            }),
            "knobs" => Ok(TraceEvent::Knobs {
                t: num("t")?,
                route_window: num("route_window")? as usize,
                rebalance_threshold: num("rebalance_threshold")? as usize,
                drr_quantum: num("drr_quantum")?,
                drr_burst_cap: num("drr_burst_cap")?,
                drr_queue_cap: num("drr_queue_cap")? as usize,
            }),
            other => Err(format!("unknown record kind {other:?}")),
        }
    }
}

/// Where the engine's lifecycle hooks deliver events. Implementations
/// must be cheap: hooks fire on the discrete-event hot path.
pub trait TraceSink: Send {
    fn record(&mut self, ev: &TraceEvent);
}

/// Build the header line for a run of `cfg` under `router`.
pub fn header_json(cfg: &Config, router: &str) -> Json {
    obj(vec![
        ("trace", Json::Str("slim-scheduler".into())),
        ("version", Json::Num(TRACE_VERSION as f64)),
        ("router", Json::Str(router.to_string())),
        ("requests", Json::Num(cfg.workload.total_requests as f64)),
        ("config", cfg.to_json()),
    ])
}

/// The standard in-memory recording sink. Cloning yields another handle
/// onto the same buffer (the engine owns one boxed clone; the caller
/// keeps another to extract the trace after the run). The mutex exists
/// for `Send` — the engine's event loop is single-threaded, so it is
/// never contended.
#[derive(Clone)]
pub struct TraceRecorder {
    header: String,
    events: Arc<Mutex<Vec<TraceEvent>>>,
}

/// Per-request completion stats extracted from a recording (the paired
/// unit of the counterfactual A/B harness).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DoneStats {
    pub e2e_s: f64,
    pub energy_j: f64,
    pub slack_s: f64,
    /// Mean executed width over the request's segments.
    pub mean_width: f64,
    /// Owning tenant (0 for v1 traces and single-tenant runs).
    pub tenant: u16,
}

/// Per-request completion stats from a record stream, keyed by request
/// id — the one extraction both the in-memory recorder and the parsed
/// trace use, so the two sides of a paired comparison can never drift.
pub fn done_stats(events: &[TraceEvent]) -> std::collections::BTreeMap<u64, DoneStats> {
    events
        .iter()
        .filter_map(|ev| match ev {
            TraceEvent::Done { id, e2e_s, energy_j, slack_s, widths, tenant, .. } => {
                let mean_width = if widths.is_empty() {
                    0.0
                } else {
                    widths.iter().sum::<f64>() / widths.len() as f64
                };
                Some((
                    *id,
                    DoneStats {
                        e2e_s: *e2e_s,
                        energy_j: *energy_j,
                        slack_s: *slack_s,
                        mean_width,
                        tenant: *tenant,
                    },
                ))
            }
            _ => None,
        })
        .collect()
}

impl TraceRecorder {
    pub fn new(cfg: &Config, router: &str) -> Self {
        TraceRecorder {
            header: header_json(cfg, router).to_string_compact(),
            events: Arc::new(Mutex::new(Vec::new())),
        }
    }

    /// Events recorded so far (cloned out of the shared buffer).
    pub fn events(&self) -> Vec<TraceEvent> {
        self.events.lock().unwrap().clone()
    }

    pub fn len(&self) -> usize {
        self.events.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Completion stats keyed by request id.
    pub fn done_map(&self) -> std::collections::BTreeMap<u64, DoneStats> {
        done_stats(&self.events.lock().unwrap())
    }

    /// Serialize header + every event as JSONL (deterministic byte-wise
    /// for a deterministic run).
    pub fn to_jsonl(&self) -> String {
        let events = self.events.lock().unwrap();
        let mut out = String::with_capacity(64 * (events.len() + 1));
        out.push_str(&self.header);
        out.push('\n');
        for ev in events.iter() {
            out.push_str(&ev.to_json().to_string_compact());
            out.push('\n');
        }
        out
    }

    /// Persist the trace to `path`.
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_jsonl())
    }
}

impl TraceSink for TraceRecorder {
    fn record(&mut self, ev: &TraceEvent) {
        self.events.lock().unwrap().push(ev.clone());
    }
}

struct StreamingInner {
    out: std::io::BufWriter<std::fs::File>,
    records: u64,
}

/// Streaming recording sink: serializes each event to the trace file as
/// it fires instead of buffering the run in memory, so recording a
/// 10M-request trace needs O(1) memory rather than O(events). The bytes
/// written are exactly what [`TraceRecorder::to_jsonl`] would produce
/// for the same run (header line, then one compact-JSON line per event)
/// — `tests/trace_roundtrip.rs` pins the equality.
///
/// Like [`TraceRecorder`], cloning yields another handle onto the same
/// underlying writer: the engine owns one boxed clone while the caller
/// keeps another to [`StreamingTraceWriter::finish`] after the run. An
/// I/O error mid-run panics rather than silently truncating the trace —
/// a partial trace that replays is worse than a loud failure.
#[derive(Clone)]
pub struct StreamingTraceWriter {
    inner: Arc<Mutex<StreamingInner>>,
}

impl StreamingTraceWriter {
    /// Create `path` and write the header line for a run of `cfg`
    /// under `router`.
    pub fn create(path: &str, cfg: &Config, router: &str) -> std::io::Result<Self> {
        use std::io::Write;
        let file = std::fs::File::create(path)?;
        let mut out = std::io::BufWriter::new(file);
        out.write_all(header_json(cfg, router).to_string_compact().as_bytes())?;
        out.write_all(b"\n")?;
        Ok(StreamingTraceWriter {
            inner: Arc::new(Mutex::new(StreamingInner { out, records: 0 })),
        })
    }

    /// Event records written so far (header line excluded).
    pub fn records(&self) -> u64 {
        self.inner.lock().unwrap().records
    }

    /// Flush buffered bytes to disk and return the record count. The
    /// file stays open; callers normally drop the last handle right
    /// after.
    pub fn finish(&self) -> std::io::Result<u64> {
        use std::io::Write;
        let mut inner = self.inner.lock().unwrap();
        inner.out.flush()?;
        Ok(inner.records)
    }
}

impl TraceSink for StreamingTraceWriter {
    fn record(&mut self, ev: &TraceEvent) {
        use std::io::Write;
        let mut inner = self.inner.lock().unwrap();
        inner
            .out
            .write_all(ev.to_json().to_string_compact().as_bytes())
            .and_then(|()| inner.out.write_all(b"\n"))
            .expect("trace stream write failed");
        inner.records += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn samples() -> Vec<TraceEvent> {
        vec![
            TraceEvent::Arrival { t: 0.125, id: 3, w_req: 0.5, tenant: 2 },
            TraceEvent::Assign { t: 0.125, id: 3, seg: 0, shard: 1 },
            TraceEvent::Route {
                t: 0.25,
                shard: 1,
                tag: 7,
                seg: 0,
                server: 2,
                width: 0.75,
                group: 4,
                size: 3,
                clamped: 1,
                arrive_t: 0.2512345678901234,
            },
            TraceEvent::Done {
                t: 1.5,
                id: 3,
                e2e_s: 1.375,
                energy_j: 210.25,
                slack_s: -0.375,
                widths: vec![0.5, 0.75, 0.25, 1.0],
                tenant: 2,
            },
            TraceEvent::Tick {
                t: 0.05,
                fifo: 12,
                done: 0,
                util: vec![10.0, 0.0],
                power: vec![60.5, 55.0],
            },
            TraceEvent::Knobs {
                t: 0.35,
                route_window: 8,
                rebalance_threshold: 3,
                drr_quantum: 2.5,
                drr_burst_cap: 16.0,
                drr_queue_cap: 32,
            },
        ]
    }

    #[test]
    fn every_event_kind_roundtrips_through_json() {
        for ev in samples() {
            let line = ev.to_json().to_string_compact();
            let parsed = Json::parse(&line).expect("line parses");
            assert_eq!(TraceEvent::from_json(&parsed).unwrap(), ev, "{line}");
        }
    }

    #[test]
    fn float_serialization_is_lossless() {
        // shortest-round-trip formatting: exact f64 recovery, which is
        // what makes record → replay byte equality possible at all
        let t = 0.1 + 0.2; // classic non-representable sum
        let ev = TraceEvent::Arrival { t, id: 0, w_req: 1.0 / 3.0, tenant: 0 };
        let line = ev.to_json().to_string_compact();
        match TraceEvent::from_json(&Json::parse(&line).unwrap()).unwrap() {
            TraceEvent::Arrival { t: t2, w_req, .. } => {
                assert_eq!(t.to_bits(), t2.to_bits());
                assert_eq!((1.0f64 / 3.0).to_bits(), w_req.to_bits());
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn from_json_rejects_malformed_records() {
        let bad = Json::parse(r#"{"t": 1.0}"#).unwrap();
        assert!(TraceEvent::from_json(&bad).unwrap_err().contains("ev"));
        let unknown = Json::parse(r#"{"ev":"warp","t":1}"#).unwrap();
        assert!(TraceEvent::from_json(&unknown)
            .unwrap_err()
            .contains("unknown record kind"));
        let missing = Json::parse(r#"{"ev":"arrival","t":1}"#).unwrap();
        assert!(TraceEvent::from_json(&missing).unwrap_err().contains("id"));
    }

    #[test]
    fn recorder_handles_share_one_buffer() {
        let cfg = Config::default();
        let rec = TraceRecorder::new(&cfg, "random");
        let mut engine_side: Box<dyn TraceSink> = Box::new(rec.clone());
        for ev in samples() {
            engine_side.record(&ev);
        }
        assert_eq!(rec.len(), 6);
        assert_eq!(rec.events(), samples());
        let jsonl = rec.to_jsonl();
        assert_eq!(jsonl.lines().count(), 7); // header + 6 records
        let header = Json::parse(jsonl.lines().next().unwrap()).unwrap();
        assert_eq!(header.get("trace").and_then(Json::as_str), Some("slim-scheduler"));
        assert_eq!(header.get("version").and_then(Json::as_f64), Some(3.0));
        assert_eq!(header.get("router").and_then(Json::as_str), Some("random"));
        assert!(header.get("config").is_some());
    }

    #[test]
    fn done_map_extracts_completions() {
        let cfg = Config::default();
        let mut rec = TraceRecorder::new(&cfg, "edf");
        for ev in samples() {
            rec.record(&ev);
        }
        let map = rec.done_map();
        assert_eq!(map.len(), 1);
        let d = map[&3];
        assert_eq!(d.e2e_s, 1.375);
        assert_eq!(d.energy_j, 210.25);
        assert_eq!(d.slack_s, -0.375);
        assert!((d.mean_width - 0.625).abs() < 1e-12);
        assert_eq!(d.tenant, 2);
    }

    #[test]
    fn v1_records_without_tenant_parse_as_tenant_zero() {
        let arrival =
            Json::parse(r#"{"ev":"arrival","t":0.5,"id":9,"w_req":0.75}"#).unwrap();
        match TraceEvent::from_json(&arrival).unwrap() {
            TraceEvent::Arrival { tenant, id, .. } => {
                assert_eq!(tenant, 0);
                assert_eq!(id, 9);
            }
            other => panic!("parsed {other:?}"),
        }
        let done = Json::parse(
            r#"{"ev":"done","t":1.0,"id":9,"e2e_s":0.5,"energy_j":10.0,"slack_s":0.1,"widths":[1.0,1.0,1.0,1.0]}"#,
        )
        .unwrap();
        match TraceEvent::from_json(&done).unwrap() {
            TraceEvent::Done { tenant, .. } => assert_eq!(tenant, 0),
            other => panic!("parsed {other:?}"),
        }
    }

    #[test]
    fn streaming_writer_matches_in_memory_recorder_byte_for_byte() {
        let cfg = Config::default();
        let path = std::env::temp_dir().join(format!(
            "slim_sched_stream_rec_{}.jsonl",
            std::process::id()
        ));
        let path = path.to_str().unwrap().to_string();
        let writer = StreamingTraceWriter::create(&path, &cfg, "random").unwrap();
        let mut engine_side: Box<dyn TraceSink> = Box::new(writer.clone());
        let mut rec = TraceRecorder::new(&cfg, "random");
        for ev in samples() {
            engine_side.record(&ev);
            rec.record(&ev);
        }
        assert_eq!(writer.records(), 6);
        assert_eq!(writer.finish().unwrap(), 6);
        let streamed = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(streamed, rec.to_jsonl());
    }

    #[test]
    fn identical_inputs_serialize_byte_identically() {
        let cfg = Config::default();
        let mk = || {
            let mut rec = TraceRecorder::new(&cfg, "random");
            for ev in samples() {
                rec.record(&ev);
            }
            rec.to_jsonl()
        };
        assert_eq!(mk(), mk());
    }
}
