//! Paired significance statistics for the counterfactual A/B harness.
//!
//! The per-request delta rows `trace::compare` emits are a *paired*
//! sample: every request is measured under both routers over the same
//! arrival stream, so the right question is not "are the two means
//! different?" but "is the per-request difference consistently signed,
//! and how tight is its mean?". Two classic answers, both exact or
//! deterministic (no asymptotic approximations, no unseeded
//! randomness — two runs of the harness must stay byte-identical):
//!
//! * [`sign_test_p`] — the exact two-sided sign test. Under H₀ ("the
//!   candidate is no better or worse than the baseline per request")
//!   each non-tied delta is an independent fair coin; the p-value is
//!   the exact binomial tail probability of a split at least as
//!   lopsided as the observed (wins, losses). Ties carry no sign
//!   information and are excluded, per the standard construction.
//! * [`bootstrap_mean_ci`] — a seeded percentile-bootstrap confidence
//!   interval on the mean delta. Resamples are drawn from a dedicated
//!   [`Rng`] stream, so the interval is a pure function of
//!   (data, resamples, seed) and replays byte-identically.
//!
//! [`paired_stats`] bundles both plus the win/loss/tie decomposition
//! into the [`PairedStats`] block `BENCH_trace_ab.json` surfaces per
//! candidate router.

use crate::utilx::Rng;

/// Bootstrap resample count used by the A/B harness: large enough that
/// the 2.5 %/97.5 % order statistics are stable, small enough that a
/// 20 k-pair trace re-samples in well under a second.
pub const BOOTSTRAP_RESAMPLES: usize = 1000;

/// Confidence level of the reported interval.
pub const CI_LEVEL: f64 = 0.95;

/// The paired-significance block computed over one delta column.
#[derive(Clone, Debug, PartialEq)]
pub struct PairedStats {
    /// Paired observations (wins + losses + ties).
    pub n: usize,
    /// Deltas strictly below zero (candidate strictly better when the
    /// delta is a cost such as latency or energy).
    pub wins: u64,
    /// Deltas strictly above zero.
    pub losses: u64,
    /// Exact zeros — excluded from the sign test.
    pub ties: u64,
    /// wins / n (ties count against neither side but stay in the
    /// denominator, so a tie-heavy comparison reads as indecisive).
    pub win_rate: f64,
    /// Exact two-sided sign-test p-value over (wins, losses).
    pub sign_test_p: f64,
    /// Seeded percentile-bootstrap CI on the mean delta.
    pub ci_lo: f64,
    pub ci_hi: f64,
    /// Paired Cohen's d: mean delta over the sample standard deviation
    /// of the deltas — the standardized effect size that makes deltas
    /// comparable across metrics with different units and spreads.
    pub cohen_d: f64,
    /// Hodges–Lehmann shift: the median of the Walsh averages
    /// (d_i + d_j)/2, a robust location estimate of the per-pair shift
    /// (in the delta's own units) that a handful of outlier requests
    /// cannot drag the way the mean can.
    pub hl_shift: f64,
}

/// Paired Cohen's d over a delta column: `mean / sd` with the unbiased
/// (n−1) sample standard deviation. Degenerate samples (fewer than two
/// observations, or zero spread) report 0 — no standardizable effect.
pub fn paired_cohen_d(deltas: &[f64]) -> f64 {
    let n = deltas.len();
    if n < 2 {
        return 0.0;
    }
    let mean = deltas.iter().sum::<f64>() / n as f64;
    let var = deltas.iter().map(|d| (d - mean) * (d - mean)).sum::<f64>()
        / (n - 1) as f64;
    if var <= 0.0 || !var.is_finite() {
        return 0.0;
    }
    mean / var.sqrt()
}

/// Walsh-average pairs at or below this count are enumerated exactly
/// (n ≈ 1000); larger samples fall back to a seeded subsample of the
/// same size, keeping the estimator deterministic per (data, seed) and
/// the cost independent of trace length.
const HL_EXACT_PAIR_CAP: usize = 500_000;

/// Hodges–Lehmann one-sample shift estimate: the median of all Walsh
/// averages `(d_i + d_j)/2` for `i ≤ j`. Exact for samples whose pair
/// count fits [`HL_EXACT_PAIR_CAP`]; beyond that, the median is taken
/// over a seeded with-replacement sample of pairs — deterministic per
/// (data, seed), like the bootstrap. Empty input reports 0.
pub fn hodges_lehmann(deltas: &[f64], seed: u64) -> f64 {
    let n = deltas.len();
    if n == 0 {
        return 0.0;
    }
    let pairs = n * (n + 1) / 2;
    let mut walsh: Vec<f64>;
    if pairs <= HL_EXACT_PAIR_CAP {
        walsh = Vec::with_capacity(pairs);
        for i in 0..n {
            for j in i..n {
                walsh.push((deltas[i] + deltas[j]) * 0.5);
            }
        }
    } else {
        let mut rng = Rng::new(seed);
        walsh = Vec::with_capacity(HL_EXACT_PAIR_CAP);
        for _ in 0..HL_EXACT_PAIR_CAP {
            let i = rng.index(n);
            let j = rng.index(n);
            walsh.push((deltas[i] + deltas[j]) * 0.5);
        }
    }
    walsh.sort_by(|a, b| a.total_cmp(b));
    let m = walsh.len();
    if m % 2 == 1 {
        walsh[m / 2]
    } else {
        (walsh[m / 2 - 1] + walsh[m / 2]) * 0.5
    }
}

/// Exact two-sided sign test: the probability, under a fair coin, of a
/// (wins, losses) split at least as extreme as observed. Ties are the
/// caller's to exclude (pass only strictly signed counts). Returns 1.0
/// for an empty sample — no evidence either way.
pub fn sign_test_p(wins: u64, losses: u64) -> f64 {
    let n = wins + losses;
    if n == 0 {
        return 1.0;
    }
    let k = wins.min(losses);
    // P(X <= k) for X ~ Bin(n, 1/2), summed in log space: the individual
    // terms underflow f64 around n ≈ 1075 while the tail itself is
    // perfectly representable (a 20 k-request trace is routine here).
    let ln_half_n = -(n as f64) * std::f64::consts::LN_2;
    let mut ln_terms = Vec::with_capacity(k as usize + 1);
    let mut ln_choose = 0.0; // ln C(n, 0)
    ln_terms.push(ln_half_n);
    for i in 1..=k {
        ln_choose += ((n - i + 1) as f64).ln() - (i as f64).ln();
        ln_terms.push(ln_choose + ln_half_n);
    }
    let max = ln_terms.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if !max.is_finite() {
        return 0.0; // tail beneath f64 range: p-value is effectively zero
    }
    let tail: f64 = ln_terms.iter().map(|&l| (l - max).exp()).sum();
    (2.0 * max.exp() * tail).min(1.0)
}

/// Seeded percentile bootstrap on the mean of `xs`: `resamples` means of
/// with-replacement resamples, sorted; the interval is the `(1−level)/2`
/// and `1−(1−level)/2` order statistics. Deterministic per
/// (xs, resamples, seed). Degenerate inputs collapse cleanly: an empty
/// sample yields (0, 0), a constant sample yields (c, c).
pub fn bootstrap_mean_ci(
    xs: &[f64],
    resamples: usize,
    seed: u64,
    level: f64,
) -> (f64, f64) {
    if xs.is_empty() || resamples == 0 {
        return (0.0, 0.0);
    }
    let n = xs.len();
    let mut rng = Rng::new(seed);
    let mut means = Vec::with_capacity(resamples);
    for _ in 0..resamples {
        let mut sum = 0.0;
        for _ in 0..n {
            sum += xs[rng.index(n)];
        }
        means.push(sum / n as f64);
    }
    means.sort_by(|a, b| a.total_cmp(b));
    let pick = |q: f64| {
        let rank = (q * (resamples - 1) as f64).round() as usize;
        means[rank.min(resamples - 1)]
    };
    let alpha = (1.0 - level) / 2.0;
    (pick(alpha), pick(1.0 - alpha))
}

/// The full paired block over one delta column (negative = candidate
/// better): win/loss/tie split, exact sign test over the signed pairs,
/// the seeded bootstrap CI on the mean delta, and the effect sizes
/// (paired Cohen's d, Hodges–Lehmann shift) that say how *large* a
/// significant difference actually is.
pub fn paired_stats(deltas: &[f64], seed: u64) -> PairedStats {
    let mut wins = 0u64;
    let mut losses = 0u64;
    let mut ties = 0u64;
    for &d in deltas {
        match d.partial_cmp(&0.0) {
            Some(std::cmp::Ordering::Less) => wins += 1,
            Some(std::cmp::Ordering::Greater) => losses += 1,
            // exact zeros; a poisoned NaN delta carries no sign either
            _ => ties += 1,
        }
    }
    let n = deltas.len();
    let (ci_lo, ci_hi) =
        bootstrap_mean_ci(deltas, BOOTSTRAP_RESAMPLES, seed, CI_LEVEL);
    PairedStats {
        n,
        wins,
        losses,
        ties,
        win_rate: if n == 0 { 0.0 } else { wins as f64 / n as f64 },
        sign_test_p: sign_test_p(wins, losses),
        ci_lo,
        ci_hi,
        cohen_d: paired_cohen_d(deltas),
        hl_shift: hodges_lehmann(deltas, seed ^ 0x4831_5EED),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sign_test_matches_hand_computed_binomials() {
        // n = 10, k = 2: 2·(C(10,0)+C(10,1)+C(10,2))/2^10 = 112/1024
        assert!((sign_test_p(2, 8) - 0.109375).abs() < 1e-12);
        assert!((sign_test_p(8, 2) - 0.109375).abs() < 1e-12); // symmetric
        // n = 5, k = 0: 2/32
        assert!((sign_test_p(0, 5) - 0.0625).abs() < 1e-12);
        // a perfectly balanced split carries no evidence (capped at 1)
        assert_eq!(sign_test_p(5, 5), 1.0);
        assert_eq!(sign_test_p(0, 0), 1.0);
        // one-sided sweep: more lopsided splits are strictly stronger
        let p_weak = sign_test_p(40, 60);
        let p_strong = sign_test_p(10, 90);
        assert!(p_strong < p_weak, "{p_strong} vs {p_weak}");
    }

    #[test]
    fn sign_test_survives_large_n_without_underflow() {
        // 20 k pairs, modest skew: the per-term probabilities underflow
        // f64 but the log-space tail must not
        let p = sign_test_p(9_800, 10_200);
        assert!(p > 0.0 && p < 1.0, "{p}");
        // extreme skew at large n: effectively zero, never NaN
        let p = sign_test_p(0, 20_000);
        assert!(p >= 0.0 && p < 1e-100, "{p}");
        assert!(!p.is_nan());
    }

    #[test]
    fn bootstrap_is_deterministic_and_brackets_the_mean() {
        let xs: Vec<f64> =
            (0..500).map(|i| ((i * 37) % 100) as f64 / 100.0 - 0.3).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let a = bootstrap_mean_ci(&xs, 1000, 7, 0.95);
        let b = bootstrap_mean_ci(&xs, 1000, 7, 0.95);
        assert_eq!(a, b, "same seed must reproduce the interval exactly");
        assert!(a.0 <= mean && mean <= a.1, "{a:?} vs mean {mean}");
        assert!(a.0 < a.1);
        // a different seed moves the interval but not by much
        let c = bootstrap_mean_ci(&xs, 1000, 8, 0.95);
        assert!((a.0 - c.0).abs() < 0.05 && (a.1 - c.1).abs() < 0.05);
    }

    #[test]
    fn bootstrap_degenerate_inputs() {
        assert_eq!(bootstrap_mean_ci(&[], 100, 1, 0.95), (0.0, 0.0));
        let (lo, hi) = bootstrap_mean_ci(&[2.5; 40], 100, 1, 0.95);
        assert_eq!((lo, hi), (2.5, 2.5)); // constant sample: point interval
        let (lo, hi) = bootstrap_mean_ci(&[1.0], 100, 1, 0.95);
        assert_eq!((lo, hi), (1.0, 1.0)); // single observation
    }

    #[test]
    fn cohen_d_standardizes_the_mean_shift() {
        // constant shift with unit spread: d = mean/sd exactly
        let deltas = [-2.0, -1.0, 0.0, 1.0, -3.0, -1.0];
        let n = deltas.len() as f64;
        let mean = deltas.iter().sum::<f64>() / n;
        let sd = (deltas.iter().map(|d| (d - mean) * (d - mean)).sum::<f64>()
            / (n - 1.0))
            .sqrt();
        assert!((paired_cohen_d(&deltas) - mean / sd).abs() < 1e-12);
        // scale invariance: multiplying every delta by 1000 (seconds →
        // milliseconds) leaves d unchanged
        let scaled: Vec<f64> = deltas.iter().map(|d| d * 1000.0).collect();
        assert!((paired_cohen_d(&scaled) - paired_cohen_d(&deltas)).abs() < 1e-9);
        // degenerate samples carry no standardizable effect
        assert_eq!(paired_cohen_d(&[]), 0.0);
        assert_eq!(paired_cohen_d(&[1.0]), 0.0);
        assert_eq!(paired_cohen_d(&[0.5; 10]), 0.0);
    }

    #[test]
    fn hodges_lehmann_is_robust_and_exact_for_small_n() {
        // symmetric sample: HL sits at the center
        assert!((hodges_lehmann(&[-1.0, 0.0, 1.0], 1) - 0.0).abs() < 1e-12);
        // hand-computed: deltas [1, 2, 6] → Walsh averages
        // {1, 1.5, 3.5, 2, 4, 6}, sorted {1, 1.5, 2, 3.5, 4, 6},
        // median = (2 + 3.5)/2
        assert!((hodges_lehmann(&[1.0, 2.0, 6.0], 1) - 2.75).abs() < 1e-12);
        // one wild outlier barely moves HL while it drags the mean
        let mut deltas = vec![-0.1; 99];
        deltas.push(1000.0);
        let mean = deltas.iter().sum::<f64>() / deltas.len() as f64;
        let hl = hodges_lehmann(&deltas, 1);
        assert!(mean > 9.0, "{mean}");
        assert!(hl < 0.0, "{hl}");
        assert_eq!(hodges_lehmann(&[], 1), 0.0);
    }

    #[test]
    fn hodges_lehmann_sampled_path_is_deterministic_and_close() {
        // n = 2000 → 2 001 000 pairs, beyond the exact cap: the seeded
        // subsample must reproduce per seed and land near the exact
        // value of the underlying symmetric distribution
        let deltas: Vec<f64> =
            (0..2000).map(|i| ((i * 53) % 401) as f64 / 100.0 - 2.0).collect();
        let a = hodges_lehmann(&deltas, 9);
        let b = hodges_lehmann(&deltas, 9);
        assert_eq!(a, b, "same seed must reproduce the estimate exactly");
        assert!((a - 0.0).abs() < 0.05, "{a}");
    }

    #[test]
    fn hodges_lehmann_exact_path_holds_right_up_to_the_pair_cap() {
        // n = 999 → 999·1000/2 = 499 500 Walsh pairs, the largest sample
        // the exact path still covers. For the symmetric arithmetic set
        // {0, 1, …, 998} the Walsh-average multiset is symmetric around
        // 499, every average is an exactly representable half-integer,
        // and the even-count median lands on the center with no error —
        // and no seed sensitivity, because no sampling happened.
        let deltas: Vec<f64> = (0..999).map(|i| i as f64).collect();
        assert_eq!(hodges_lehmann(&deltas, 1), 499.0);
        assert_eq!(hodges_lehmann(&deltas, 2), 499.0, "exact path ignores seed");
    }

    #[test]
    fn hodges_lehmann_first_sample_past_the_cap_stays_deterministic() {
        // n = 1000 → 500 500 pairs, one step over the cap: the estimator
        // switches to the seeded subsample. Same seed ⇒ bit-identical;
        // the estimate stays near the symmetric center 499.5 even though
        // it is no longer exact.
        let deltas: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        let a = hodges_lehmann(&deltas, 9);
        let b = hodges_lehmann(&deltas, 9);
        assert_eq!(a, b, "same seed must reproduce the estimate exactly");
        assert!((a - 499.5).abs() < 5.0, "{a}");
        // the sampled path *does* consult the seed (the estimator now
        // medians a 500 000-draw subsample instead of the full pair set)
        let c = hodges_lehmann(&deltas, 10);
        assert!((c - 499.5).abs() < 5.0, "{c}");
    }

    #[test]
    fn paired_stats_decomposes_and_scores() {
        // 6 wins, 2 losses, 2 ties
        let deltas = [-1.0, -0.5, -0.25, -2.0, -0.1, -0.2, 0.5, 1.0, 0.0, 0.0];
        let s = paired_stats(&deltas, 11);
        assert_eq!(s.n, 10);
        assert_eq!((s.wins, s.losses, s.ties), (6, 2, 2));
        assert!((s.win_rate - 0.6).abs() < 1e-12);
        // sign test over the 8 signed pairs: 2·(C(8,0)+C(8,1)+C(8,2))/2^8
        assert!((s.sign_test_p - 74.0 / 256.0).abs() < 1e-12);
        assert!(s.ci_lo <= s.ci_hi);
        // reproducible end to end
        assert_eq!(paired_stats(&deltas, 11), s);
    }
}
