//! Trace replay: parse a recorded (or externally imported) JSONL trace
//! back into a fixed arrival stream.
//!
//! A parsed [`Trace`] yields the exact [`WorkloadEvent`] sequence the
//! recording engine consumed; feeding it through the trace mode of
//! [`crate::sim::Workload`] (`Workload::with_trace` — the trace-workload
//! source) re-runs **any** router / shard-assignment / scenario
//! combination against bit-identical arrivals. Recording such a replay
//! with the same router and seed reproduces the original trace byte for
//! byte (`tests/trace_roundtrip.rs` pins this).
//!
//! Externally imported traces only need the header line plus `arrival`
//! records — `{"ev":"arrival","t":<s>,"id":<n>,"w_req":<width>}` — in
//! non-decreasing time order; `assign`/`route`/`done`/`tick` records are
//! optional recording detail. The optional `tenant` field (v2) defaults
//! to 0, so v1 and external tenant-less traces import unchanged.

use std::sync::Arc;

use crate::config::Config;
use crate::sim::WorkloadEvent;
use crate::utilx::json::Json;

use super::record::{DoneStats, TraceEvent, TRACE_VERSION};

/// Why a trace failed to load (1-based line number when applicable).
#[derive(Clone, Debug, PartialEq)]
pub struct TraceError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for TraceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.line > 0 {
            write!(f, "trace line {}: {}", self.line, self.msg)
        } else {
            write!(f, "trace: {}", self.msg)
        }
    }
}

impl std::error::Error for TraceError {}

fn err(line: usize, msg: impl Into<String>) -> TraceError {
    TraceError { line, msg: msg.into() }
}

/// A parsed trace: the header's provenance plus every record.
#[derive(Clone, Debug)]
pub struct Trace {
    pub version: u64,
    /// Router name the header declared (imported traces may omit it).
    pub router: Option<String>,
    /// Declared request count (validated against the arrival records
    /// when present — a truncated file fails here).
    pub requests: Option<usize>,
    /// Full serialized configuration of the recording run, when present.
    config: Option<Json>,
    pub events: Vec<TraceEvent>,
    /// The arrival stream, extracted once at parse time into a shared
    /// immutable arena (large traces are mostly non-arrival records;
    /// callers hit this repeatedly). Replays borrow the arena via
    /// [`Trace::arrivals_arena`] — a million-request trace is parsed
    /// and held once no matter how many entrants replay it.
    arrivals: Arc<[WorkloadEvent]>,
}

/// Header fields shared by the in-memory parser and the streaming
/// loader: (version, router, requests, config).
type TraceHeader = (u64, Option<String>, Option<usize>, Option<Json>);

/// Parse and validate the header line (magic, version) of a trace.
fn parse_header(header_line: &str) -> Result<TraceHeader, TraceError> {
    let header = Json::parse(header_line)
        .map_err(|e| err(1, format!("header is not valid JSON: {e}")))?;
    if header.get("trace").and_then(Json::as_str) != Some("slim-scheduler") {
        return Err(err(1, "not a slim-scheduler trace (header magic missing)"));
    }
    let version = header
        .get("version")
        .and_then(Json::as_f64)
        .ok_or_else(|| err(1, "header missing version"))? as u64;
    // older versions stay loadable for arrival-only replay: v1 records
    // simply predate the tenant field, which parses as tenant 0
    if !(1..=TRACE_VERSION).contains(&version) {
        return Err(err(
            1,
            format!("unsupported trace version {version} (supported: 1..={TRACE_VERSION})"),
        ));
    }
    let router = header.get("router").and_then(Json::as_str).map(str::to_string);
    let requests = header.get("requests").and_then(Json::as_usize);
    let config = header.get("config").cloned();
    Ok((version, router, requests, config))
}

impl Trace {
    /// Parse a JSONL trace document.
    pub fn parse(text: &str) -> Result<Trace, TraceError> {
        let mut lines = text.lines().enumerate();
        let (_, header_line) = lines
            .next()
            .ok_or_else(|| err(0, "empty document (missing header line)"))?;
        let (version, router, requests, config) = parse_header(header_line)?;

        let mut events = Vec::new();
        for (i, line) in lines {
            if line.trim().is_empty() {
                continue;
            }
            let json = Json::parse(line)
                .map_err(|e| err(i + 1, format!("invalid JSON: {e}")))?;
            events.push(TraceEvent::from_json(&json).map_err(|m| err(i + 1, m))?);
        }

        let arrivals: Arc<[WorkloadEvent]> = events
            .iter()
            .filter_map(|ev| match ev {
                TraceEvent::Arrival { t, id, w_req, tenant } => Some(WorkloadEvent {
                    at: *t,
                    request_id: *id,
                    w_req: *w_req,
                    tenant: *tenant,
                }),
                _ => None,
            })
            .collect();
        let trace = Trace { version, router, requests, config, events, arrivals };
        trace.validate()?;
        Ok(trace)
    }

    /// Load and parse a trace file.
    pub fn load(path: &str) -> Result<Trace, TraceError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| err(0, format!("cannot read {path}: {e}")))?;
        Trace::parse(&text)
    }

    /// Load a trace file line by line, keeping only what replay needs:
    /// the header (config/router/declared count) and the arrival stream.
    /// Non-arrival records are parsed for validity and dropped, so the
    /// resident footprint is O(arrivals) regardless of trace length — a
    /// 10M-request recording (mostly `route`/`done`/`tick` detail)
    /// replays in bounded memory where [`Trace::load`] would buffer the
    /// whole document. The returned trace has an empty `events` vector;
    /// use [`Trace::load`] when completion records are needed (the A/B
    /// harness).
    pub fn load_streaming(path: &str) -> Result<Trace, TraceError> {
        use std::io::BufRead;
        let file = std::fs::File::open(path)
            .map_err(|e| err(0, format!("cannot read {path}: {e}")))?;
        let reader = std::io::BufReader::new(file);
        let mut lines = reader.lines().enumerate();
        let (_, header_line) = lines
            .next()
            .ok_or_else(|| err(0, "empty document (missing header line)"))?;
        let header_line =
            header_line.map_err(|e| err(1, format!("cannot read {path}: {e}")))?;
        let (version, router, requests, config) = parse_header(&header_line)?;

        let mut arrivals = Vec::new();
        for (i, line) in lines {
            let line = line.map_err(|e| err(i + 1, format!("cannot read {path}: {e}")))?;
            if line.trim().is_empty() {
                continue;
            }
            let json = Json::parse(&line)
                .map_err(|e| err(i + 1, format!("invalid JSON: {e}")))?;
            match TraceEvent::from_json(&json).map_err(|m| err(i + 1, m))? {
                TraceEvent::Arrival { t, id, w_req, tenant } => {
                    arrivals.push(WorkloadEvent {
                        at: t,
                        request_id: id,
                        w_req,
                        tenant,
                    })
                }
                _ => {} // recording detail: validated, not retained
            }
        }
        let trace = Trace {
            version,
            router,
            requests,
            config,
            events: Vec::new(),
            arrivals: arrivals.into(),
        };
        trace.validate()?;
        Ok(trace)
    }

    fn validate(&self) -> Result<(), TraceError> {
        let arrivals = &self.arrivals;
        if arrivals.is_empty() {
            return Err(err(0, "trace carries no arrival records"));
        }
        if let Some(declared) = self.requests {
            if declared != arrivals.len() {
                return Err(err(
                    0,
                    format!(
                        "truncated or inconsistent trace: header declares {declared} \
                         requests but {} arrival records are present",
                        arrivals.len()
                    ),
                ));
            }
        }
        let mut last = f64::NEG_INFINITY;
        let mut seen = std::collections::BTreeSet::new();
        for ev in arrivals.iter() {
            if !ev.at.is_finite() || ev.at < last {
                return Err(err(
                    0,
                    format!(
                        "arrival times must be finite and non-decreasing (id {})",
                        ev.request_id
                    ),
                ));
            }
            // ids key the paired A/B maps: a repeated id would silently
            // collapse pairs instead of comparing them — fail loudly
            if !seen.insert(ev.request_id) {
                return Err(err(
                    0,
                    format!("duplicate arrival request id {}", ev.request_id),
                ));
            }
            last = ev.at;
        }
        Ok(())
    }

    /// The fixed arrival stream, in record order (extracted at parse
    /// time into the shared arena).
    pub fn arrivals(&self) -> &[WorkloadEvent] {
        &self.arrivals
    }

    /// A shared handle on the arrival arena — pass it to
    /// [`crate::coordinator::Engine::set_arrivals`] (or
    /// [`crate::sim::Workload::with_trace`]). Cloning the handle is
    /// O(1) and copies nothing, so N concurrent entrant replays all
    /// read the single parsed arrival set.
    pub fn arrivals_arena(&self) -> Arc<[WorkloadEvent]> {
        Arc::clone(&self.arrivals)
    }

    /// Per-request completion stats keyed by request id.
    pub fn done_map(&self) -> std::collections::BTreeMap<u64, DoneStats> {
        super::record::done_stats(&self.events)
    }

    /// Reconstruct the recording run's configuration from the header
    /// (None for imported traces that omit `config`). CLI flags are
    /// applied on top by callers, so explicit overrides still win.
    pub fn config(&self) -> Option<Config> {
        self.config.as_ref().map(Config::from_json)
    }
}

/// Point `cfg` at this trace: the run budget becomes exactly the trace's
/// arrival count (the generator budget is meaningless under replay).
pub fn configure_for_replay(cfg: &mut Config, trace: &Trace) {
    cfg.workload.total_requests = trace.arrivals().len();
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mini_trace() -> String {
        let cfg = Config::default();
        let header = super::super::record::header_json(
            &{
                let mut c = cfg.clone();
                c.workload.total_requests = 2;
                c
            },
            "random",
        );
        let lines = [
            header.to_string_compact(),
            r#"{"ev":"arrival","t":0.25,"id":0,"w_req":0.5}"#.to_string(),
            r#"{"ev":"arrival","t":0.5,"id":1,"w_req":1}"#.to_string(),
            r#"{"ev":"done","t":1,"id":0,"e2e_s":0.75,"energy_j":10,"slack_s":0.25,"widths":[0.5,0.5,0.5,0.5]}"#
                .to_string(),
        ];
        lines.join("\n") + "\n"
    }

    #[test]
    fn parses_header_arrivals_and_completions() {
        let trace = Trace::parse(&mini_trace()).unwrap();
        assert_eq!(trace.version, TRACE_VERSION);
        assert_eq!(trace.router.as_deref(), Some("random"));
        assert_eq!(trace.requests, Some(2));
        let arr = trace.arrivals();
        assert_eq!(arr.len(), 2);
        assert_eq!(
            arr[0],
            WorkloadEvent { at: 0.25, request_id: 0, w_req: 0.5, tenant: 0 }
        );
        assert_eq!(trace.done_map().len(), 1);
        let cfg = trace.config().expect("recorded traces embed the config");
        assert_eq!(cfg.workload.total_requests, 2);

        let mut replay_cfg = Config::default();
        configure_for_replay(&mut replay_cfg, &trace);
        assert_eq!(replay_cfg.workload.total_requests, 2);
    }

    #[test]
    fn arrival_arena_is_shared_not_copied() {
        let trace = Trace::parse(&mini_trace()).unwrap();
        let a = trace.arrivals_arena();
        let b = trace.arrivals_arena();
        assert!(Arc::ptr_eq(&a, &b), "arena handles alias one allocation");
        // three live handles: the trace's own plus the two taken above
        assert_eq!(Arc::strong_count(&a), 3);
        assert_eq!(&a[..], trace.arrivals());
    }

    #[test]
    fn rejects_empty_and_foreign_documents() {
        assert!(Trace::parse("").unwrap_err().msg.contains("empty"));
        let e = Trace::parse("{\"not\":\"ours\"}\n").unwrap_err();
        assert!(e.msg.contains("magic"), "{e}");
        assert_eq!(e.line, 1);
    }

    #[test]
    fn rejects_wrong_version() {
        let doc = r#"{"trace":"slim-scheduler","version":99}"#;
        let e = Trace::parse(doc).unwrap_err();
        assert!(e.msg.contains("unsupported trace version 99"), "{e}");
    }

    #[test]
    fn rejects_malformed_record_lines_with_line_numbers() {
        let mut doc = mini_trace();
        doc.push_str("{\"ev\":\"arrival\",\"t\":9}\n"); // missing id/w_req
        let e = Trace::parse(&doc).unwrap_err();
        assert_eq!(e.line, 5);
        assert!(e.msg.contains("id"), "{e}");
    }

    #[test]
    fn rejects_truncated_traces() {
        // cut the document mid-line: invalid JSON on the last line
        let doc = mini_trace();
        let cut = &doc[..doc.len() - 20];
        let e = Trace::parse(cut).unwrap_err();
        assert!(e.line > 1, "{e}");

        // drop a whole arrival record: the declared count catches it
        let kept: Vec<&str> = doc.lines().filter(|l| !l.contains("\"id\":1")).collect();
        let e = Trace::parse(&(kept.join("\n") + "\n")).unwrap_err();
        assert!(e.msg.contains("truncated"), "{e}");
    }

    #[test]
    fn rejects_duplicate_arrival_ids() {
        // an imported log with a constant/missing id column would
        // collapse the paired A/B maps to one row — reject at parse time
        let doc = [
            r#"{"trace":"slim-scheduler","version":1}"#,
            r#"{"ev":"arrival","t":0.5,"id":3,"w_req":0.5}"#,
            r#"{"ev":"arrival","t":1.0,"id":3,"w_req":0.5}"#,
        ]
        .join("\n");
        let e = Trace::parse(&doc).unwrap_err();
        assert!(e.msg.contains("duplicate arrival request id 3"), "{e}");
    }

    #[test]
    fn rejects_out_of_order_arrivals() {
        let doc = [
            r#"{"trace":"slim-scheduler","version":1,"requests":2}"#,
            r#"{"ev":"arrival","t":1.0,"id":0,"w_req":0.5}"#,
            r#"{"ev":"arrival","t":0.5,"id":1,"w_req":0.5}"#,
        ]
        .join("\n");
        let e = Trace::parse(&doc).unwrap_err();
        assert!(e.msg.contains("non-decreasing"), "{e}");
    }

    #[test]
    fn streaming_load_matches_in_memory_parse() {
        let doc = mini_trace();
        let path = std::env::temp_dir().join(format!(
            "slim_sched_stream_load_{}.jsonl",
            std::process::id()
        ));
        let path = path.to_str().unwrap().to_string();
        std::fs::write(&path, &doc).unwrap();

        let streamed = Trace::load_streaming(&path).unwrap();
        let parsed = Trace::parse(&doc).unwrap();
        assert_eq!(streamed.arrivals(), parsed.arrivals());
        assert_eq!(streamed.version, parsed.version);
        assert_eq!(streamed.router, parsed.router);
        assert_eq!(streamed.requests, parsed.requests);
        assert!(streamed.events.is_empty(), "streaming load drops detail records");
        assert_eq!(
            streamed.config().map(|c| c.workload.total_requests),
            parsed.config().map(|c| c.workload.total_requests)
        );

        // same validation as the in-memory path: a gutted arrival stream
        // trips the declared-count check
        let gutted: String = doc
            .lines()
            .filter(|l| !l.contains("\"id\":1"))
            .map(|l| format!("{l}\n"))
            .collect();
        std::fs::write(&path, &gutted).unwrap();
        let e = Trace::load_streaming(&path).unwrap_err();
        assert!(e.msg.contains("truncated"), "{e}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn imported_traces_need_only_header_and_arrivals() {
        // minimal external import: no config, no router, no completions
        let doc = [
            r#"{"trace":"slim-scheduler","version":1}"#,
            r#"{"ev":"arrival","t":0.1,"id":0,"w_req":0.25}"#,
            r#"{"ev":"arrival","t":0.2,"id":1,"w_req":1}"#,
        ]
        .join("\n");
        let trace = Trace::parse(&doc).unwrap();
        assert!(trace.config().is_none());
        assert!(trace.router.is_none());
        assert_eq!(trace.arrivals().len(), 2);
    }

    #[test]
    fn v1_traces_still_load_with_tenant_defaulting_to_zero() {
        // a pre-tenant (version 1) fixture, tenant-less records included:
        // arrival-only import must keep working, every arrival tenant 0
        let doc = [
            r#"{"trace":"slim-scheduler","version":1,"router":"edf","requests":2}"#,
            r#"{"ev":"arrival","t":0.1,"id":0,"w_req":0.25}"#,
            r#"{"ev":"arrival","t":0.3,"id":1,"w_req":0.75}"#,
            r#"{"ev":"done","t":0.9,"id":0,"e2e_s":0.8,"energy_j":5,"slack_s":0.2,"widths":[0.25,0.25,0.25,0.25]}"#,
        ]
        .join("\n");
        let trace = Trace::parse(&doc).unwrap();
        assert_eq!(trace.version, 1);
        assert_eq!(trace.arrivals().len(), 2);
        assert!(trace.arrivals().iter().all(|ev| ev.tenant == 0));
        assert_eq!(trace.done_map()[&0].tenant, 0);

        // and through the streaming loader too
        let path = std::env::temp_dir().join(format!(
            "slim_sched_v1_fixture_{}.jsonl",
            std::process::id()
        ));
        let path = path.to_str().unwrap().to_string();
        std::fs::write(&path, doc + "\n").unwrap();
        let streamed = Trace::load_streaming(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(streamed.version, 1);
        assert!(streamed.arrivals().iter().all(|ev| ev.tenant == 0));
    }
}
