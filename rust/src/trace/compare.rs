//! Counterfactual router A/B over one trace.
//!
//! The paper (and the Table III–V protocol) compares schedulers on
//! *different* stochastic arrival streams, which inflates the variance
//! of exactly the metrics it reports most cautiously (latency/energy
//! std-dev). This harness replays **one** fixed arrival stream through N
//! router configurations and reports **paired per-request deltas** —
//! every request is its own control, so the arrival-process noise
//! cancels instead of being averaged over.
//!
//! Entrants are [`RouterSpec`] spellings: the algorithmic router names
//! plus `ppo:<checkpoint.json>` — a trained policy restored from disk
//! and run in frozen *greedy* evaluation mode
//! (`PpoRouter::greedy_eval_mode`), so a checkpoint replay is a pure
//! function of (weights, trace, cfg): no exploration, no sampling, no
//! RNG draws, and two replays are byte-identical by construction.
//!
//! Output (`BENCH_trace_ab.json` by default, via `repro trace-compare`):
//! absolute per-router summaries, and for every non-baseline router a
//! paired-difference block (`latency_delta_mean_s`, `…_std_s`, energy,
//! mean executed width, SLA slack, miss-rate delta, win/loss/tie counts)
//! plus the full per-request delta rows. Deltas are `router − baseline`,
//! so negative latency/energy deltas mean the candidate improves on the
//! baseline for the *same* requests. Every pair also carries the
//! [`super::stats`] significance block — exact sign-test p-value, seeded
//! bootstrap 95 % CIs on the mean latency/energy deltas, and effect
//! sizes (paired Cohen's d, Hodges–Lehmann shift) — so a report can
//! answer "did the policy actually win, was it noise, and by how much?"
//! without a separate analysis step.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::config::Config;
use crate::coordinator::core::TenantStat;
use crate::coordinator::router::{AlgoRouter, RouterSpec};
use crate::coordinator::sharded_engine;
use crate::metrics::Summary;
use crate::ppo::{run_ppo_episode_io, PpoRouter};
use crate::utilx::json::{obj, Json};

use super::record::{DoneStats, TraceRecorder, TraceSink};
use super::replay::{configure_for_replay, Trace};
use super::stats::paired_stats;

/// Options for [`compare_routers_opts`].
#[derive(Clone, Copy, Debug)]
pub struct CompareOpts {
    /// Emit the full per-request delta rows in every pair block.
    /// Multi-scenario sweeps (`repro trace-study`) turn this off — the
    /// rows dominate the report size at study scale.
    pub per_request: bool,
    /// Worker threads for the entrant replays (`--eval-threads`). Every
    /// replay is an independent pure function of (trace, cfg, spec), so
    /// they fan out across scoped threads and the results are gathered
    /// in entrant order — the report is byte-identical at any thread
    /// count. `1` (the default) keeps the sequential loop.
    pub eval_threads: usize,
    /// Emit per-entrant replay wall-clock (`replay_wall_s`) in each
    /// router block. Off by default: wall-clock is the one
    /// nondeterministic field, and the library default keeps two
    /// identical calls byte-identical. The CLI turns it on (and
    /// `--no-timing` restores the deterministic document).
    pub timing: bool,
}

impl Default for CompareOpts {
    fn default() -> Self {
        CompareOpts { per_request: true, eval_threads: 1, timing: false }
    }
}

/// One replayed router's harvest.
struct RouterRun {
    name: String,
    done: BTreeMap<u64, DoneStats>,
    sla_miss_rate: f64,
    plan_clamps: u64,
    jain_latency: f64,
    jain_throughput: f64,
    shed_rate: f64,
    shed: u64,
    /// DRR gate aggregates (0 for gate-less entrants): admissions
    /// degraded to the slim width, and credit-forfeit ticks.
    degraded: u64,
    credit_forfeits: u64,
    /// Per-tenant accounting rows (one row on single-tenant runs) —
    /// carries the gate's per-tenant shed/degraded/forfeit split.
    tenant_stats: Vec<TenantStat>,
    /// Wall-clock seconds this entrant's replay took (measured around
    /// the engine run; reported only under [`CompareOpts::timing`]).
    replay_wall_s: f64,
}

/// Replay `trace` through one router spec — an algorithmic name or a
/// `ppo:<checkpoint>` entrant, optionally suffixed `+drr` / `+none` to
/// force the admission gate on or off for this entrant (so one compare
/// can pit DRR admission against raw FIFO over the same arrivals) — and
/// collect per-request completions. `cfg` supplies everything except
/// the arrival stream (cluster, seed, windows, shards, SLA).
/// Checkpoints run in frozen greedy-eval mode
/// ([`PpoRouter::greedy_eval_mode`]), so a replay is a pure function of
/// (weights, trace, cfg) and two replays are byte-identical.
fn replay_run(cfg: &Config, trace: &Trace, spec: &str) -> Result<RouterRun, String> {
    let mut cfg = cfg.clone();
    let base_spec = if let Some(s) = spec.strip_suffix("+drr") {
        cfg.admission.kind = crate::config::AdmissionKind::Drr;
        s
    } else if let Some(s) = spec.strip_suffix("+none") {
        cfg.admission.kind = crate::config::AdmissionKind::None;
        s
    } else {
        spec
    };
    let parsed = RouterSpec::parse(base_spec).ok_or_else(|| {
        format!(
            "unknown router {spec:?} (trace compare supports: {}, each \
             optionally suffixed +drr or +none)",
            RouterSpec::spellings()
        )
    })?;
    configure_for_replay(&mut cfg, trace);
    let recorder = TraceRecorder::new(&cfg, spec);
    let wall = Instant::now();
    let outcome = match parsed {
        RouterSpec::Algo(name) => {
            let router = AlgoRouter::by_name(name, &cfg.scheduler.widths)
                .expect("RouterSpec::Algo spellings construct");
            let mut engine = sharded_engine(cfg, router);
            // zero-copy: the engine aliases the trace's arrival arena,
            // so N entrants share one parsed arrival set
            engine.set_arrivals(trace.arrivals_arena());
            engine.set_trace_sink(Box::new(recorder.clone()));
            engine.run()
        }
        RouterSpec::PpoCheckpoint(path) => {
            let router = PpoRouter::from_checkpoint(&cfg, &path)?;
            let sink: Box<dyn TraceSink> = Box::new(recorder.clone());
            let (outcome, _router) = run_ppo_episode_io(
                &cfg,
                router,
                Some(trace.arrivals_arena()),
                Some(sink),
            );
            outcome
        }
    };
    Ok(RouterRun {
        name: spec.to_string(),
        done: recorder.done_map(),
        sla_miss_rate: outcome.sla_miss_rate(),
        plan_clamps: outcome.plan_clamps,
        jain_latency: outcome.jain_latency(),
        jain_throughput: outcome.jain_throughput(),
        shed_rate: outcome.shed_rate(),
        shed: outcome.shed,
        degraded: outcome.degraded,
        credit_forfeits: outcome.credit_forfeits,
        tenant_stats: outcome.tenant_stats,
        replay_wall_s: wall.elapsed().as_secs_f64(),
    })
}

/// Replay every entrant, sequentially (`eval_threads <= 1` — the
/// pre-fan-out loop, byte for byte) or across a pool of scoped worker
/// threads with strided entrant assignment. Results come back in
/// entrant order either way, and on failure the error reported is the
/// *first failing entrant's* (in entrant order, not completion order),
/// so the parallel path is observationally identical to the loop.
fn replay_all(
    cfg: &Config,
    trace: &Trace,
    names: &[String],
    eval_threads: usize,
) -> Result<Vec<RouterRun>, String> {
    let threads = eval_threads.max(1).min(names.len());
    if threads <= 1 {
        let mut runs = Vec::with_capacity(names.len());
        for name in names {
            runs.push(replay_run(cfg, trace, name)?);
        }
        return Ok(runs);
    }
    let mut slots: Vec<Option<Result<RouterRun, String>>> =
        (0..names.len()).map(|_| None).collect();
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|worker| {
                scope.spawn(move || {
                    let mut out = Vec::new();
                    let mut i = worker;
                    while i < names.len() {
                        out.push((i, replay_run(cfg, trace, &names[i])));
                        i += threads;
                    }
                    out
                })
            })
            .collect();
        for h in handles {
            for (i, run) in h.join().expect("eval worker panicked") {
                slots[i] = Some(run);
            }
        }
    });
    let mut runs = Vec::with_capacity(names.len());
    for slot in slots {
        runs.push(slot.expect("every entrant is assigned to a worker")?);
    }
    Ok(runs)
}

fn summary_json(prefix: &str, unit: &str, s: &Summary) -> Vec<(String, Json)> {
    vec![
        (format!("{prefix}_mean{unit}"), Json::Num(s.mean())),
        (format!("{prefix}_std{unit}"), Json::Num(s.std())),
    ]
}

/// Run `names[0]` (the baseline) and every other router over one trace
/// and build the paired A/B report. Deterministic: every run replays the
/// identical arrivals under `cfg.seed`.
pub fn compare_routers(
    cfg: &Config,
    trace: &Trace,
    names: &[String],
) -> Result<Json, String> {
    compare_routers_opts(cfg, trace, names, CompareOpts::default())
}

/// [`compare_routers`] with the harness knobs exposed — per-request
/// rows optional, entrant replays optionally fanned out across
/// `opts.eval_threads` scoped threads (byte-identical to the sequential
/// loop at any thread count), and per-entrant wall-clock optionally
/// reported (`opts.timing`).
pub fn compare_routers_opts(
    cfg: &Config,
    trace: &Trace,
    names: &[String],
    opts: CompareOpts,
) -> Result<Json, String> {
    if names.len() < 2 {
        return Err(format!(
            "trace compare needs at least two routers (baseline + candidates), got {names:?}"
        ));
    }
    let runs = replay_all(cfg, trace, names, opts.eval_threads)?;

    let routers_json: Vec<Json> = runs
        .iter()
        .map(|r| {
            let mut lat = Summary::default();
            let mut energy = Summary::default();
            let mut width = Summary::default();
            for d in r.done.values() {
                lat.record(d.e2e_s);
                energy.record(d.energy_j);
                width.record(d.mean_width);
            }
            let mut fields: Vec<(String, Json)> = vec![
                ("name".to_string(), Json::Str(r.name.clone())),
                ("completed".to_string(), Json::Num(r.done.len() as f64)),
            ];
            // the only nondeterministic field in the report, placed
            // mid-block so stripping its lines (`--no-timing` has no
            // line to strip) recovers the deterministic document
            if opts.timing {
                fields.push((
                    "replay_wall_s".to_string(),
                    Json::Num(r.replay_wall_s),
                ));
            }
            fields.extend(summary_json("latency", "_s", &lat));
            fields.extend(summary_json("energy", "_j", &energy));
            fields.push(("width_mean".to_string(), Json::Num(width.mean())));
            fields.push(("sla_miss_rate".to_string(), Json::Num(r.sla_miss_rate)));
            fields.push(("plan_clamps".to_string(), Json::Num(r.plan_clamps as f64)));
            // fairness/admission block: always present (1.0 / 0 on
            // single-tenant, gate-less runs) so downstream greps never
            // depend on the workload shape
            fields.push(("jain_latency".to_string(), Json::Num(r.jain_latency)));
            fields.push((
                "jain_throughput".to_string(),
                Json::Num(r.jain_throughput),
            ));
            fields.push(("shed_rate".to_string(), Json::Num(r.shed_rate)));
            fields.push(("shed".to_string(), Json::Num(r.shed as f64)));
            fields.push(("degraded".to_string(), Json::Num(r.degraded as f64)));
            fields.push((
                "credit_forfeits".to_string(),
                Json::Num(r.credit_forfeits as f64),
            ));
            // per-tenant admission/fairness rows (one row single-tenant)
            let tenants: Vec<Json> = r
                .tenant_stats
                .iter()
                .enumerate()
                .map(|(t, ts)| {
                    obj(vec![
                        ("tenant", Json::Num(t as f64)),
                        ("arrivals", Json::Num(ts.arrivals as f64)),
                        ("done", Json::Num(ts.done as f64)),
                        ("shed", Json::Num(ts.shed as f64)),
                        ("degraded", Json::Num(ts.degraded as f64)),
                        ("credit_forfeits", Json::Num(ts.credit_forfeits as f64)),
                        ("cooldowns", Json::Num(ts.cooldowns as f64)),
                        ("mean_latency_s", Json::Num(ts.mean_latency_s())),
                        ("sla_miss_rate", Json::Num(ts.sla_miss_rate())),
                    ])
                })
                .collect();
            fields.push(("tenants".to_string(), Json::Arr(tenants)));
            Json::Obj(fields)
        })
        .collect();

    let base = &runs[0];
    let mut pairs = Vec::with_capacity(runs.len() - 1);
    for (ci, cand) in runs[1..].iter().enumerate() {
        let mut lat = Summary::default();
        let mut energy = Summary::default();
        let mut width = Summary::default();
        let mut slack = Summary::default();
        // raw delta columns for the significance block (the Summary
        // accumulators stream; the sign test / bootstrap need the rows)
        let mut lat_deltas = Vec::with_capacity(base.done.len());
        let mut energy_deltas = Vec::with_capacity(base.done.len());
        let mut per_request = Vec::new();
        for (id, b) in &base.done {
            let Some(c) = cand.done.get(id) else { continue };
            let d_lat = c.e2e_s - b.e2e_s;
            let d_energy = c.energy_j - b.energy_j;
            let d_width = c.mean_width - b.mean_width;
            let d_slack = c.slack_s - b.slack_s;
            lat.record(d_lat);
            energy.record(d_energy);
            width.record(d_width);
            slack.record(d_slack);
            lat_deltas.push(d_lat);
            energy_deltas.push(d_energy);
            if opts.per_request {
                per_request.push(obj(vec![
                    ("id", Json::Num(*id as f64)),
                    ("latency_delta_s", Json::Num(d_lat)),
                    ("energy_delta_j", Json::Num(d_energy)),
                    ("width_delta", Json::Num(d_width)),
                    ("slack_delta_s", Json::Num(d_slack)),
                ]));
            }
        }
        if lat.count() == 0 {
            return Err(format!(
                "no paired completions between {} and {}",
                base.name, cand.name
            ));
        }
        // paired significance: seeded per candidate so the report is a
        // pure function of (trace, cfg, names) — byte-identical reruns
        let stats_seed = cfg.seed ^ 0xB007_57A7 ^ (ci as u64);
        let lat_stats = paired_stats(&lat_deltas, stats_seed);
        let energy_stats = paired_stats(&energy_deltas, stats_seed ^ 0xE);
        let mut fields: Vec<(String, Json)> = vec![
            ("router".to_string(), Json::Str(cand.name.clone())),
            ("baseline".to_string(), Json::Str(base.name.clone())),
            ("n_pairs".to_string(), Json::Num(lat.count() as f64)),
        ];
        fields.extend(summary_json("latency_delta", "_s", &lat));
        fields.extend(summary_json("energy_delta", "_j", &energy));
        fields.push(("width_delta_mean".to_string(), Json::Num(width.mean())));
        fields.push(("slack_delta_mean_s".to_string(), Json::Num(slack.mean())));
        fields.push((
            "sla_miss_rate_delta".to_string(),
            Json::Num(cand.sla_miss_rate - base.sla_miss_rate),
        ));
        // positive = the candidate spreads latency more evenly across
        // tenants than the baseline does
        fields.push((
            "jain_latency_delta".to_string(),
            Json::Num(cand.jain_latency - base.jain_latency),
        ));
        fields.push((
            "shed_rate_delta".to_string(),
            Json::Num(cand.shed_rate - base.shed_rate),
        ));
        fields.push(("wins".to_string(), Json::Num(lat_stats.wins as f64)));
        fields.push(("losses".to_string(), Json::Num(lat_stats.losses as f64)));
        fields.push(("ties".to_string(), Json::Num(lat_stats.ties as f64)));
        fields.push(("win_rate".to_string(), Json::Num(lat_stats.win_rate)));
        fields.push((
            "sign_test_p".to_string(),
            Json::Num(lat_stats.sign_test_p),
        ));
        fields.push((
            "latency_delta_ci95".to_string(),
            Json::Arr(vec![
                Json::Num(lat_stats.ci_lo),
                Json::Num(lat_stats.ci_hi),
            ]),
        ));
        fields.push(("cohen_d".to_string(), Json::Num(lat_stats.cohen_d)));
        fields.push(("hl_shift_s".to_string(), Json::Num(lat_stats.hl_shift)));
        fields.push((
            "energy_sign_test_p".to_string(),
            Json::Num(energy_stats.sign_test_p),
        ));
        fields.push((
            "energy_delta_ci95".to_string(),
            Json::Arr(vec![
                Json::Num(energy_stats.ci_lo),
                Json::Num(energy_stats.ci_hi),
            ]),
        ));
        fields.push((
            "energy_cohen_d".to_string(),
            Json::Num(energy_stats.cohen_d),
        ));
        fields.push((
            "energy_hl_shift_j".to_string(),
            Json::Num(energy_stats.hl_shift),
        ));
        if opts.per_request {
            fields.push(("per_request".to_string(), Json::Arr(per_request)));
        }
        pairs.push(Json::Obj(fields));
    }

    Ok(obj(vec![
        ("trace_requests", Json::Num(trace.arrivals().len() as f64)),
        ("sla_s", Json::Num(cfg.router.sla_s)),
        ("seed", Json::Num(cfg.seed as f64)),
        ("baseline", Json::Str(base.name.clone())),
        ("routers", Json::Arr(routers_json)),
        ("pairs", Json::Arr(pairs)),
    ]))
}

/// Record a fresh trace of `cfg` under a named algorithmic router — the
/// per-scenario recording step of `repro trace-study` (and the test
/// harness). The recording is parsed straight back, so the returned
/// [`Trace`] is exactly what a file round-trip would yield.
pub fn record_trace(cfg: &Config, router_name: &str) -> Result<Trace, String> {
    let router =
        AlgoRouter::by_name(router_name, &cfg.scheduler.widths).ok_or_else(|| {
            format!(
                "unknown recording router {router_name:?} (known: {})",
                AlgoRouter::names().join(", ")
            )
        })?;
    let recorder = TraceRecorder::new(cfg, router_name);
    let mut engine = sharded_engine(cfg.clone(), router);
    engine.set_trace_sink(Box::new(recorder.clone()));
    let outcome = engine.run();
    // shed requests are deliberate admission backpressure, not a
    // starved recording: they count toward the drained total
    if outcome.report.completed + outcome.shed != cfg.workload.total_requests as u64 {
        return Err(format!(
            "recording under {router_name:?} completed {} (+{} shed) of {} \
             requests (overload or dropout starved the trace)",
            outcome.report.completed, outcome.shed, cfg.workload.total_requests
        ));
    }
    Trace::parse(&recorder.to_jsonl()).map_err(|e| e.to_string())
}

/// Persist an A/B report (pretty-printed, newline-terminated so
/// line-oriented tools — the CI's timing-line strip — round-trip the
/// file exactly; `BENCH_trace_ab.json` is the conventional name the CI
/// grep checks).
pub fn write_report(report: &Json, path: &str) -> std::io::Result<()> {
    std::fs::write(path, report.to_string_pretty() + "\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record_small_trace(cfg: &Config) -> Trace {
        record_trace(cfg, "random").expect("recording succeeds")
    }

    fn small_cfg() -> Config {
        let mut cfg = Config::default();
        cfg.workload.total_requests = 150;
        cfg.workload.rate_hz = 220.0;
        cfg
    }

    /// The trace-study shape: summary + significance, no row dump.
    fn lean_opts() -> CompareOpts {
        CompareOpts { per_request: false, ..CompareOpts::default() }
    }

    /// Train a tiny checkpoint for `cfg` and write it to a temp file
    /// (caller removes it); returns the path.
    fn tiny_checkpoint(cfg: &Config, tag: &str) -> String {
        let mut cfg = cfg.clone();
        cfg.ppo.horizon = 64;
        let trained = crate::experiments::train_ppo(
            &cfg,
            crate::config::RewardCfg::overfit(),
            1,
        );
        let path = std::env::temp_dir().join(format!(
            "slim_sched_{tag}_ckpt_{}.json",
            std::process::id()
        ));
        let path = path.to_str().unwrap().to_string();
        std::fs::write(&path, trained.to_json().to_string_pretty()).unwrap();
        path
    }

    #[test]
    fn compare_emits_paired_deltas_for_every_candidate() {
        let cfg = small_cfg();
        let trace = record_small_trace(&cfg);
        let names: Vec<String> =
            ["random", "edf", "least-loaded"].iter().map(|s| s.to_string()).collect();
        let report = compare_routers(&cfg, &trace, &names).unwrap();

        assert_eq!(report.get("baseline").and_then(Json::as_str), Some("random"));
        assert_eq!(report.get("trace_requests").and_then(Json::as_usize), Some(150));
        let pairs = report.get("pairs").and_then(Json::as_arr).unwrap();
        assert_eq!(pairs.len(), 2);
        for pair in pairs {
            assert_eq!(pair.get("n_pairs").and_then(Json::as_usize), Some(150));
            let mean = pair.get("latency_delta_mean_s").and_then(Json::as_f64);
            assert!(mean.is_some_and(f64::is_finite), "{pair:?}");
            assert!(pair.get("latency_delta_std_s").is_some());
            assert!(pair.get("energy_delta_mean_j").is_some());
            assert!(pair.get("width_delta_mean").is_some());
            assert!(pair.get("slack_delta_mean_s").is_some());
            assert!(pair.get("sla_miss_rate_delta").is_some());
            let rows = pair.get("per_request").and_then(Json::as_arr).unwrap();
            assert_eq!(rows.len(), 150);
            assert!(rows[0].get("latency_delta_s").is_some());
        }
        // paired slack and latency deltas are the same comparison seen
        // from opposite sides: slack = sla − e2e, so Δslack = −Δlatency
        let p0 = &pairs[0];
        let dl = p0.get("latency_delta_mean_s").and_then(Json::as_f64).unwrap();
        let ds = p0.get("slack_delta_mean_s").and_then(Json::as_f64).unwrap();
        assert!((dl + ds).abs() < 1e-9, "Δlat {dl} vs Δslack {ds}");
    }

    #[test]
    fn pairs_carry_the_significance_block() {
        let cfg = small_cfg();
        let trace = record_small_trace(&cfg);
        let names: Vec<String> =
            ["random", "edf"].iter().map(|s| s.to_string()).collect();
        let report = compare_routers(&cfg, &trace, &names).unwrap();
        let pair = &report.get("pairs").and_then(Json::as_arr).unwrap()[0];

        let p = pair.get("sign_test_p").and_then(Json::as_f64).unwrap();
        assert!((0.0..=1.0).contains(&p), "p = {p}");
        let wins = pair.get("wins").and_then(Json::as_f64).unwrap();
        let losses = pair.get("losses").and_then(Json::as_f64).unwrap();
        let ties = pair.get("ties").and_then(Json::as_f64).unwrap();
        assert_eq!(wins + losses + ties, 150.0);
        let wr = pair.get("win_rate").and_then(Json::as_f64).unwrap();
        assert!((wr - wins / 150.0).abs() < 1e-12);

        // the CI must bracket the reported mean delta, for both columns
        for (ci_key, mean_key) in [
            ("latency_delta_ci95", "latency_delta_mean_s"),
            ("energy_delta_ci95", "energy_delta_mean_j"),
        ] {
            let ci = pair.get(ci_key).and_then(Json::as_f64_vec).unwrap();
            assert_eq!(ci.len(), 2, "{ci_key}");
            let mean = pair.get(mean_key).and_then(Json::as_f64).unwrap();
            assert!(
                ci[0] <= mean && mean <= ci[1],
                "{ci_key} {ci:?} does not bracket {mean}"
            );
        }
        assert!(pair.get("energy_sign_test_p").is_some());

        // effect sizes ride along with the significance block, and the
        // robust shift lands inside the latency CI's ballpark
        let d = pair.get("cohen_d").and_then(Json::as_f64).unwrap();
        assert!(d.is_finite(), "cohen_d = {d}");
        let hl = pair.get("hl_shift_s").and_then(Json::as_f64).unwrap();
        assert!(hl.is_finite(), "hl_shift_s = {hl}");
        assert!(pair.get("energy_cohen_d").and_then(Json::as_f64).is_some());
        assert!(pair.get("energy_hl_shift_j").and_then(Json::as_f64).is_some());
    }

    #[test]
    fn ppo_checkpoint_entrant_compares_and_replays_byte_identically() {
        // the acceptance cycle in miniature: train → checkpoint →
        // trace-compare against the algorithmic field, twice, and demand
        // byte equality of the full report
        let mut cfg = small_cfg();
        cfg.ppo.horizon = 64;
        let trained = crate::experiments::train_ppo(
            &cfg,
            crate::config::RewardCfg::overfit(),
            1,
        );
        let path = std::env::temp_dir().join(format!(
            "slim_sched_ab_ckpt_{}.json",
            std::process::id()
        ));
        let path = path.to_str().unwrap().to_string();
        std::fs::write(&path, trained.to_json().to_string_pretty()).unwrap();

        let trace = record_small_trace(&cfg);
        let names: Vec<String> = vec![
            "random".to_string(),
            "edf".to_string(),
            format!("ppo:{path}"),
        ];
        let a = compare_routers(&cfg, &trace, &names).unwrap();
        let b = compare_routers(&cfg, &trace, &names).unwrap();
        assert_eq!(
            a.to_string_pretty(),
            b.to_string_pretty(),
            "checkpoint replay must be deterministic"
        );

        let pairs = a.get("pairs").and_then(Json::as_arr).unwrap();
        assert_eq!(pairs.len(), 2);
        let ppo_pair = &pairs[1];
        assert_eq!(
            ppo_pair.get("router").and_then(Json::as_str),
            Some(format!("ppo:{path}").as_str())
        );
        assert_eq!(ppo_pair.get("n_pairs").and_then(Json::as_usize), Some(150));
        assert!(ppo_pair.get("sign_test_p").is_some());
        assert!(ppo_pair.get("latency_delta_ci95").is_some());

        // a missing checkpoint is a load error, not a panic
        let bad: Vec<String> =
            vec!["random".to_string(), "ppo:/nonexistent/x.json".to_string()];
        assert!(compare_routers(&cfg, &trace, &bad)
            .unwrap_err()
            .contains("cannot read"));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn per_request_rows_are_optional() {
        let cfg = small_cfg();
        let trace = record_small_trace(&cfg);
        let names: Vec<String> =
            ["random", "edf"].iter().map(|s| s.to_string()).collect();
        let lean = compare_routers_opts(&cfg, &trace, &names, lean_opts()).unwrap();
        let pair = &lean.get("pairs").and_then(Json::as_arr).unwrap()[0];
        assert!(pair.get("per_request").is_none());
        assert!(pair.get("sign_test_p").is_some()); // stats survive
    }

    #[test]
    fn compare_is_deterministic() {
        let cfg = small_cfg();
        let trace = record_small_trace(&cfg);
        let names: Vec<String> = ["random", "edf"].iter().map(|s| s.to_string()).collect();
        let a = compare_routers(&cfg, &trace, &names).unwrap();
        let b = compare_routers(&cfg, &trace, &names).unwrap();
        assert_eq!(a.to_string_pretty(), b.to_string_pretty());
    }

    #[test]
    fn compare_rejects_bad_inputs() {
        let cfg = small_cfg();
        let trace = record_small_trace(&cfg);
        let one: Vec<String> = vec!["random".to_string()];
        assert!(compare_routers(&cfg, &trace, &one)
            .unwrap_err()
            .contains("at least two"));
        let unknown: Vec<String> =
            ["random", "marsbase"].iter().map(|s| s.to_string()).collect();
        assert!(compare_routers(&cfg, &trace, &unknown)
            .unwrap_err()
            .contains("unknown router"));
    }

    #[test]
    fn admission_suffix_pits_drr_against_fifo_over_one_flash_crowd() {
        // the PR's headline study in miniature: record the flash-crowd
        // scenario once (arrivals land in the trace *before* the gate, so
        // the stream is admission-complete), then replay the same router
        // with the gate forced off and on. The +drr entrant must shed
        // under the spike while +none absorbs everything, and the pair
        // must carry the fairness delta columns.
        let mut cfg = Config::default();
        crate::sim::scenarios::apply_named("flash-crowd", &mut cfg).unwrap();
        cfg.workload.total_requests = 400;
        cfg.seed = 42;
        let trace = record_small_trace(&cfg);
        assert_eq!(trace.arrivals().len(), 400, "shed arrivals stay in the trace");

        let names: Vec<String> =
            ["edf+none", "edf+drr"].iter().map(|s| s.to_string()).collect();
        let a = compare_routers_opts(&cfg, &trace, &names, lean_opts()).unwrap();
        let b = compare_routers_opts(&cfg, &trace, &names, lean_opts()).unwrap();
        assert_eq!(a.to_string_pretty(), b.to_string_pretty());

        let routers = a.get("routers").and_then(Json::as_arr).unwrap();
        for r in routers {
            for key in [
                "jain_latency",
                "jain_throughput",
                "shed_rate",
                "shed",
                "degraded",
                "credit_forfeits",
            ] {
                let v = r.get(key).and_then(Json::as_f64).unwrap();
                assert!(v.is_finite(), "{key} = {v}");
            }
            let jain = r.get("jain_latency").and_then(Json::as_f64).unwrap();
            assert!(jain > 0.0 && jain <= 1.0, "jain_latency = {jain}");
            // per-tenant rows: flash-crowd is a 6-tenant workload (rows
            // cover every tenant id seen in the arrival stream)
            let tenants = r.get("tenants").and_then(Json::as_arr).unwrap();
            assert!(
                (2..=6).contains(&tenants.len()),
                "flash-crowd tenant rows: {}",
                tenants.len()
            );
        }
        let fifo = &routers[0];
        let drr = &routers[1];
        assert_eq!(fifo.get("name").and_then(Json::as_str), Some("edf+none"));
        assert_eq!(fifo.get("shed_rate").and_then(Json::as_f64), Some(0.0));
        assert_eq!(fifo.get("completed").and_then(Json::as_usize), Some(400));
        let drr_shed = drr.get("shed_rate").and_then(Json::as_f64).unwrap();
        assert!(drr_shed > 0.0, "DRR must shed under the 10x spike");
        // the gate-on entrant's counters are live and split per tenant
        assert_eq!(fifo.get("degraded").and_then(Json::as_f64), Some(0.0));
        let drr_shed_n = drr.get("shed").and_then(Json::as_f64).unwrap();
        let tenant_shed: f64 = drr
            .get("tenants")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .map(|t| t.get("shed").and_then(Json::as_f64).unwrap())
            .sum();
        assert_eq!(tenant_shed, drr_shed_n, "per-tenant shed sums to the total");

        // pairs only cover requests both runs completed, and carry the
        // fairness deltas
        let pair = &a.get("pairs").and_then(Json::as_arr).unwrap()[0];
        let n = pair.get("n_pairs").and_then(Json::as_usize).unwrap();
        assert!(n > 0 && n < 400, "n_pairs = {n}");
        assert!(pair
            .get("jain_latency_delta")
            .and_then(Json::as_f64)
            .unwrap()
            .is_finite());
        assert!(pair
            .get("shed_rate_delta")
            .and_then(Json::as_f64)
            .is_some_and(|d| d > 0.0));
        let p = pair.get("sign_test_p").and_then(Json::as_f64).unwrap();
        assert!((0.0..=1.0).contains(&p), "p = {p}");

        // an unknown base router keeps its suffix in the error message
        let bad: Vec<String> =
            ["edf", "marsbase+drr"].iter().map(|s| s.to_string()).collect();
        assert!(compare_routers_opts(&cfg, &trace, &bad, lean_opts())
            .unwrap_err()
            .contains("marsbase+drr"));
    }

    #[test]
    fn eval_threads_fanout_is_byte_identical_across_thread_counts_and_leaders() {
        // the tentpole invariant: the threaded fan-out must emit the
        // same bytes as the sequential loop — for a 5-entrant field
        // spanning algorithmic, +drr-suffixed, and checkpoint entrants,
        // under single- and multi-leader sharding alike
        let base = small_cfg();
        let path = tiny_checkpoint(&base, "fanout");
        let trace = record_small_trace(&base);
        let names: Vec<String> = vec![
            "random".to_string(),
            "edf".to_string(),
            "edf+drr".to_string(),
            "least-loaded".to_string(),
            format!("ppo:{path}"),
        ];
        for leaders in [1usize, 4] {
            let mut cfg = base.clone();
            cfg.shard.leaders = leaders;
            let sequential =
                compare_routers_opts(&cfg, &trace, &names, CompareOpts::default())
                    .unwrap()
                    .to_string_pretty();
            // 16 > entrant count exercises the thread-count clamp
            for threads in [2usize, 4, 16] {
                let opts = CompareOpts {
                    eval_threads: threads,
                    ..CompareOpts::default()
                };
                let parallel = compare_routers_opts(&cfg, &trace, &names, opts)
                    .unwrap()
                    .to_string_pretty();
                assert_eq!(
                    sequential, parallel,
                    "fan-out diverged (leaders {leaders}, threads {threads})"
                );
            }
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn entrant_errors_surface_in_entrant_order_at_any_thread_count() {
        use crate::config::{PpoCfg, WIDTHS};
        let cfg = small_cfg();
        let trace = record_small_trace(&cfg);
        // a 4-device checkpoint cannot load into the 3-device cluster —
        // and it sits mid-field, so the parallel path must still report
        // the first failing entrant in entrant order
        let ppo = PpoRouter::new(4, WIDTHS.to_vec(), PpoCfg::default(), 7);
        let path = std::env::temp_dir().join(format!(
            "slim_sched_incompat_ckpt_{}.json",
            std::process::id()
        ));
        let path = path.to_str().unwrap().to_string();
        std::fs::write(&path, ppo.to_json().to_string_pretty()).unwrap();
        let names: Vec<String> = vec![
            "random".to_string(),
            format!("ppo:{path}"),
            "edf".to_string(),
        ];
        let seq_err =
            compare_routers_opts(&cfg, &trace, &names, CompareOpts::default())
                .unwrap_err();
        assert!(seq_err.contains("does not match the policy shape"), "{seq_err}");
        for threads in [2usize, 4] {
            let opts =
                CompareOpts { eval_threads: threads, ..CompareOpts::default() };
            let par_err =
                compare_routers_opts(&cfg, &trace, &names, opts).unwrap_err();
            assert_eq!(seq_err, par_err, "threads {threads}");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn timing_emits_replay_wall_s_and_strips_back_to_the_deterministic_report() {
        let cfg = small_cfg();
        let trace = record_small_trace(&cfg);
        let names: Vec<String> =
            ["random", "edf", "least-loaded"].iter().map(|s| s.to_string()).collect();
        let plain =
            compare_routers_opts(&cfg, &trace, &names, CompareOpts::default())
                .unwrap();
        let timed = compare_routers_opts(
            &cfg,
            &trace,
            &names,
            CompareOpts { timing: true, eval_threads: 2, ..CompareOpts::default() },
        )
        .unwrap();
        let routers = timed.get("routers").and_then(Json::as_arr).unwrap();
        assert_eq!(routers.len(), 3);
        for r in routers {
            let w = r.get("replay_wall_s").and_then(Json::as_f64).unwrap();
            assert!(w.is_finite() && w >= 0.0, "replay_wall_s = {w}");
        }
        assert!(plain
            .get("routers")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .all(|r| r.get("replay_wall_s").is_none()));
        // wall-clock is the report's only nondeterministic field:
        // dropping its lines recovers the deterministic document (the
        // CI leans on exactly this to cmp timed vs untimed runs)
        let stripped: String = timed
            .to_string_pretty()
            .lines()
            .filter(|l| !l.contains("\"replay_wall_s\""))
            .collect::<Vec<_>>()
            .join("\n");
        assert_eq!(stripped, plain.to_string_pretty());
    }

    #[test]
    fn baseline_self_comparison_is_all_zero() {
        // replaying the same router twice over one trace must pair to
        // exactly zero deltas — the determinism the A/B design rests on
        let cfg = small_cfg();
        let trace = record_small_trace(&cfg);
        let names: Vec<String> = ["edf", "edf"].iter().map(|s| s.to_string()).collect();
        let report = compare_routers(&cfg, &trace, &names).unwrap();
        let pair = &report.get("pairs").and_then(Json::as_arr).unwrap()[0];
        assert_eq!(pair.get("latency_delta_mean_s").and_then(Json::as_f64), Some(0.0));
        assert_eq!(pair.get("latency_delta_std_s").and_then(Json::as_f64), Some(0.0));
        assert_eq!(pair.get("energy_delta_mean_j").and_then(Json::as_f64), Some(0.0));
        assert_eq!(pair.get("wins").and_then(Json::as_f64), Some(0.0));
        assert_eq!(pair.get("losses").and_then(Json::as_f64), Some(0.0));
    }
}
