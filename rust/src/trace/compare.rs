//! Counterfactual router A/B over one trace.
//!
//! The paper (and the Table III–V protocol) compares schedulers on
//! *different* stochastic arrival streams, which inflates the variance
//! of exactly the metrics it reports most cautiously (latency/energy
//! std-dev). This harness replays **one** fixed arrival stream through N
//! router configurations and reports **paired per-request deltas** —
//! every request is its own control, so the arrival-process noise
//! cancels instead of being averaged over.
//!
//! Output (`BENCH_trace_ab.json` by default, via `repro trace-compare`):
//! absolute per-router summaries, and for every non-baseline router a
//! paired-difference block (`latency_delta_mean_s`, `…_std_s`, energy,
//! mean executed width, SLA slack, miss-rate delta, win/loss counts)
//! plus the full per-request delta rows. Deltas are `router − baseline`,
//! so negative latency/energy deltas mean the candidate improves on the
//! baseline for the *same* requests.

use std::collections::BTreeMap;

use crate::config::Config;
use crate::coordinator::router::AlgoRouter;
use crate::coordinator::sharded_engine;
use crate::metrics::Summary;
use crate::utilx::json::{obj, Json};

use super::record::{DoneStats, TraceRecorder};
use super::replay::{configure_for_replay, Trace};

/// One replayed router's harvest.
struct RouterRun {
    name: String,
    done: BTreeMap<u64, DoneStats>,
    sla_miss_rate: f64,
    plan_clamps: u64,
}

/// Replay `trace` through one named algorithmic router and collect
/// per-request completions. `cfg` supplies everything except the
/// arrival stream (cluster, seed, windows, shards, SLA).
fn replay_run(cfg: &Config, trace: &Trace, name: &str) -> Result<RouterRun, String> {
    let router = AlgoRouter::by_name(name, &cfg.scheduler.widths).ok_or_else(|| {
        format!(
            "unknown router {name:?} (trace compare supports: {})",
            AlgoRouter::names().join(", ")
        )
    })?;
    let mut cfg = cfg.clone();
    configure_for_replay(&mut cfg, trace);
    let recorder = TraceRecorder::new(&cfg, name);
    let mut engine = sharded_engine(cfg, router);
    engine.set_arrivals(trace.arrivals().to_vec());
    engine.set_trace_sink(Box::new(recorder.clone()));
    let outcome = engine.run();
    Ok(RouterRun {
        name: name.to_string(),
        done: recorder.done_map(),
        sla_miss_rate: outcome.sla_miss_rate(),
        plan_clamps: outcome.plan_clamps,
    })
}

fn summary_json(prefix: &str, unit: &str, s: &Summary) -> Vec<(String, Json)> {
    vec![
        (format!("{prefix}_mean{unit}"), Json::Num(s.mean())),
        (format!("{prefix}_std{unit}"), Json::Num(s.std())),
    ]
}

/// Run `names[0]` (the baseline) and every other router over one trace
/// and build the paired A/B report. Deterministic: every run replays the
/// identical arrivals under `cfg.seed`.
pub fn compare_routers(
    cfg: &Config,
    trace: &Trace,
    names: &[String],
) -> Result<Json, String> {
    if names.len() < 2 {
        return Err(format!(
            "trace compare needs at least two routers (baseline + candidates), got {names:?}"
        ));
    }
    let mut runs = Vec::with_capacity(names.len());
    for name in names {
        runs.push(replay_run(cfg, trace, name)?);
    }

    let routers_json: Vec<Json> = runs
        .iter()
        .map(|r| {
            let mut lat = Summary::default();
            let mut energy = Summary::default();
            let mut width = Summary::default();
            for d in r.done.values() {
                lat.record(d.e2e_s);
                energy.record(d.energy_j);
                width.record(d.mean_width);
            }
            let mut fields: Vec<(String, Json)> = vec![
                ("name".to_string(), Json::Str(r.name.clone())),
                ("completed".to_string(), Json::Num(r.done.len() as f64)),
            ];
            fields.extend(summary_json("latency", "_s", &lat));
            fields.extend(summary_json("energy", "_j", &energy));
            fields.push(("width_mean".to_string(), Json::Num(width.mean())));
            fields.push(("sla_miss_rate".to_string(), Json::Num(r.sla_miss_rate)));
            fields.push(("plan_clamps".to_string(), Json::Num(r.plan_clamps as f64)));
            Json::Obj(fields)
        })
        .collect();

    let base = &runs[0];
    let mut pairs = Vec::with_capacity(runs.len() - 1);
    for cand in &runs[1..] {
        let mut lat = Summary::default();
        let mut energy = Summary::default();
        let mut width = Summary::default();
        let mut slack = Summary::default();
        let mut wins = 0u64; // candidate strictly faster on this request
        let mut losses = 0u64;
        let mut per_request = Vec::new();
        for (id, b) in &base.done {
            let Some(c) = cand.done.get(id) else { continue };
            let d_lat = c.e2e_s - b.e2e_s;
            let d_energy = c.energy_j - b.energy_j;
            let d_width = c.mean_width - b.mean_width;
            let d_slack = c.slack_s - b.slack_s;
            lat.record(d_lat);
            energy.record(d_energy);
            width.record(d_width);
            slack.record(d_slack);
            if d_lat < 0.0 {
                wins += 1;
            } else if d_lat > 0.0 {
                losses += 1;
            }
            per_request.push(obj(vec![
                ("id", Json::Num(*id as f64)),
                ("latency_delta_s", Json::Num(d_lat)),
                ("energy_delta_j", Json::Num(d_energy)),
                ("width_delta", Json::Num(d_width)),
                ("slack_delta_s", Json::Num(d_slack)),
            ]));
        }
        if lat.count() == 0 {
            return Err(format!(
                "no paired completions between {} and {}",
                base.name, cand.name
            ));
        }
        let mut fields: Vec<(String, Json)> = vec![
            ("router".to_string(), Json::Str(cand.name.clone())),
            ("baseline".to_string(), Json::Str(base.name.clone())),
            ("n_pairs".to_string(), Json::Num(lat.count() as f64)),
        ];
        fields.extend(summary_json("latency_delta", "_s", &lat));
        fields.extend(summary_json("energy_delta", "_j", &energy));
        fields.push(("width_delta_mean".to_string(), Json::Num(width.mean())));
        fields.push(("slack_delta_mean_s".to_string(), Json::Num(slack.mean())));
        fields.push((
            "sla_miss_rate_delta".to_string(),
            Json::Num(cand.sla_miss_rate - base.sla_miss_rate),
        ));
        fields.push(("wins".to_string(), Json::Num(wins as f64)));
        fields.push(("losses".to_string(), Json::Num(losses as f64)));
        fields.push(("per_request".to_string(), Json::Arr(per_request)));
        pairs.push(Json::Obj(fields));
    }

    Ok(obj(vec![
        ("trace_requests", Json::Num(trace.arrivals().len() as f64)),
        ("sla_s", Json::Num(cfg.router.sla_s)),
        ("seed", Json::Num(cfg.seed as f64)),
        ("baseline", Json::Str(base.name.clone())),
        ("routers", Json::Arr(routers_json)),
        ("pairs", Json::Arr(pairs)),
    ]))
}

/// Persist an A/B report (pretty-printed; `BENCH_trace_ab.json` is the
/// conventional name the CI grep checks).
pub fn write_report(report: &Json, path: &str) -> std::io::Result<()> {
    std::fs::write(path, report.to_string_pretty())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Router;

    fn record_small_trace(cfg: &Config) -> Trace {
        let router = AlgoRouter::by_name("random", &cfg.scheduler.widths).unwrap();
        let recorder = TraceRecorder::new(cfg, router.name());
        let mut engine = sharded_engine(cfg.clone(), router);
        engine.set_trace_sink(Box::new(recorder.clone()));
        let out = engine.run();
        assert_eq!(out.report.completed, cfg.workload.total_requests as u64);
        Trace::parse(&recorder.to_jsonl()).expect("recorded trace parses")
    }

    fn small_cfg() -> Config {
        let mut cfg = Config::default();
        cfg.workload.total_requests = 150;
        cfg.workload.rate_hz = 220.0;
        cfg
    }

    #[test]
    fn compare_emits_paired_deltas_for_every_candidate() {
        let cfg = small_cfg();
        let trace = record_small_trace(&cfg);
        let names: Vec<String> =
            ["random", "edf", "least-loaded"].iter().map(|s| s.to_string()).collect();
        let report = compare_routers(&cfg, &trace, &names).unwrap();

        assert_eq!(report.get("baseline").and_then(Json::as_str), Some("random"));
        assert_eq!(report.get("trace_requests").and_then(Json::as_usize), Some(150));
        let pairs = report.get("pairs").and_then(Json::as_arr).unwrap();
        assert_eq!(pairs.len(), 2);
        for pair in pairs {
            assert_eq!(pair.get("n_pairs").and_then(Json::as_usize), Some(150));
            let mean = pair.get("latency_delta_mean_s").and_then(Json::as_f64);
            assert!(mean.is_some_and(f64::is_finite), "{pair:?}");
            assert!(pair.get("latency_delta_std_s").is_some());
            assert!(pair.get("energy_delta_mean_j").is_some());
            assert!(pair.get("width_delta_mean").is_some());
            assert!(pair.get("slack_delta_mean_s").is_some());
            assert!(pair.get("sla_miss_rate_delta").is_some());
            let rows = pair.get("per_request").and_then(Json::as_arr).unwrap();
            assert_eq!(rows.len(), 150);
            assert!(rows[0].get("latency_delta_s").is_some());
        }
        // paired slack and latency deltas are the same comparison seen
        // from opposite sides: slack = sla − e2e, so Δslack = −Δlatency
        let p0 = &pairs[0];
        let dl = p0.get("latency_delta_mean_s").and_then(Json::as_f64).unwrap();
        let ds = p0.get("slack_delta_mean_s").and_then(Json::as_f64).unwrap();
        assert!((dl + ds).abs() < 1e-9, "Δlat {dl} vs Δslack {ds}");
    }

    #[test]
    fn compare_is_deterministic() {
        let cfg = small_cfg();
        let trace = record_small_trace(&cfg);
        let names: Vec<String> = ["random", "edf"].iter().map(|s| s.to_string()).collect();
        let a = compare_routers(&cfg, &trace, &names).unwrap();
        let b = compare_routers(&cfg, &trace, &names).unwrap();
        assert_eq!(a.to_string_pretty(), b.to_string_pretty());
    }

    #[test]
    fn compare_rejects_bad_inputs() {
        let cfg = small_cfg();
        let trace = record_small_trace(&cfg);
        let one: Vec<String> = vec!["random".to_string()];
        assert!(compare_routers(&cfg, &trace, &one)
            .unwrap_err()
            .contains("at least two"));
        let unknown: Vec<String> =
            ["random", "marsbase"].iter().map(|s| s.to_string()).collect();
        assert!(compare_routers(&cfg, &trace, &unknown)
            .unwrap_err()
            .contains("unknown router"));
    }

    #[test]
    fn baseline_self_comparison_is_all_zero() {
        // replaying the same router twice over one trace must pair to
        // exactly zero deltas — the determinism the A/B design rests on
        let cfg = small_cfg();
        let trace = record_small_trace(&cfg);
        let names: Vec<String> = ["edf", "edf"].iter().map(|s| s.to_string()).collect();
        let report = compare_routers(&cfg, &trace, &names).unwrap();
        let pair = &report.get("pairs").and_then(Json::as_arr).unwrap()[0];
        assert_eq!(pair.get("latency_delta_mean_s").and_then(Json::as_f64), Some(0.0));
        assert_eq!(pair.get("latency_delta_std_s").and_then(Json::as_f64), Some(0.0));
        assert_eq!(pair.get("energy_delta_mean_j").and_then(Json::as_f64), Some(0.0));
        assert_eq!(pair.get("wins").and_then(Json::as_f64), Some(0.0));
        assert_eq!(pair.get("losses").and_then(Json::as_f64), Some(0.0));
    }
}
