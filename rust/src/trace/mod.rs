//! Trace subsystem: record, replay, and counterfactual router A/B.
//!
//! Stochastic arrival generation makes every run a fresh draw, so
//! comparing two routers confounds the policy difference with the
//! arrival difference — exactly where the paper's weakest numbers
//! (latency/energy spread) live. This subsystem removes that confound:
//!
//! * [`record`] — a [`record::TraceSink`] wired into the engine's
//!   lifecycle hooks captures per-request records (arrival, shard
//!   assignment, routing decision incl. clamp repairs, dispatch,
//!   completion with energy/width/SLA slack) and run-level telemetry
//!   ticks into a versioned, byte-deterministic JSONL format
//!   (`repro simulate --trace-out`).
//! * [`replay`] — [`replay::Trace`] parses a recorded (or externally
//!   imported) trace back into the fixed arrival stream the trace-mode
//!   workload source feeds through the engine, so any router / shard
//!   assignment / scenario re-runs against bit-identical arrivals
//!   (`repro replay --trace-in`). Recording a replay reproduces the
//!   original trace byte for byte (`tests/trace_roundtrip.rs`).
//! * [`compare`] — the counterfactual A/B harness: N routers over one
//!   trace, paired per-request deltas (latency, energy, width, SLA
//!   slack) and a paired-difference summary into `BENCH_trace_ab.json`
//!   (`repro trace-compare`). Paired statistics, not independent runs —
//!   the arrival noise cancels request by request. Entrants are
//!   [`compare`]-level `RouterSpec` spellings: the algorithmic names
//!   plus `ppo:<checkpoint>` (frozen greedy-eval replay of a trained
//!   policy).
//! * [`stats`] — paired significance over the delta rows: exact
//!   sign-test p-values, seeded (deterministic) bootstrap confidence
//!   intervals on the mean deltas, and effect sizes (paired Cohen's d,
//!   Hodges–Lehmann shift), surfaced per candidate in the A/B report
//!   and the `repro trace-study` per-scenario matrix.

pub mod compare;
pub mod record;
pub mod replay;
pub mod stats;

pub use compare::{
    compare_routers, compare_routers_opts, record_trace, write_report,
    CompareOpts,
};
pub use record::{
    done_stats, DoneStats, StreamingTraceWriter, TraceEvent, TraceRecorder,
    TraceSink, TRACE_VERSION,
};
pub use replay::{configure_for_replay, Trace, TraceError};
pub use stats::{
    bootstrap_mean_ci, hodges_lehmann, paired_cohen_d, paired_stats, sign_test_p,
    PairedStats,
};
