//! Typed configuration system.
//!
//! Everything tunable in the paper is a field here: the greedy scheduler
//! knobs of Algorithm 1 (`r, B_max, M_max, U_blk, t_idle, Q_th, N_new, W`),
//! the PPO hyper-parameters (§III-B), the reward weights (eq. 7), the
//! cluster topology (2× RTX 2080 Ti + 1× GTX 980 Ti) and the workload.
//! Configs load from JSON files, apply CLI overrides, and serialize back
//! to JSON for run provenance.

use crate::utilx::json::{arr_f64, obj, Json};
use crate::utilx::Args;

/// The slimming width set W from the paper.
pub const WIDTHS: [f64; 4] = [0.25, 0.50, 0.75, 1.00];

/// Greedy scheduler knobs (Algorithm 1).
#[derive(Clone, Debug, PartialEq)]
pub struct SchedulerCfg {
    /// Batch limit B_max (requests per formed batch).
    pub b_max: usize,
    /// VRAM cap M_max in bytes per device.
    pub m_max_bytes: u64,
    /// Utilization block threshold U_blk in percent (0-100).
    pub u_blk_pct: f64,
    /// Idle unload timeout t_idle in (virtual) seconds.
    pub t_idle_s: f64,
    /// Queue-length scale trigger Q_th.
    pub q_th: usize,
    /// Scale-up cap N_new (instances per scale event).
    pub n_new: usize,
    /// Slimming set W.
    pub widths: Vec<f64>,
}

impl Default for SchedulerCfg {
    fn default() -> Self {
        SchedulerCfg {
            b_max: 16,
            m_max_bytes: 8 * (1 << 30),
            u_blk_pct: 90.0,
            t_idle_s: 5.0,
            q_th: 32,
            n_new: 2,
            widths: WIDTHS.to_vec(),
        }
    }
}

/// Leader routing-plan knobs (the windowed `Router::plan` API).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RouterCfg {
    /// Maximum FIFO heads planned per routing event. `1` (the default)
    /// is the paper's per-head loop and reproduces the pre-plan engine
    /// bit-identically per seed; larger windows amortize one policy
    /// invocation across the queue (batched PPO inference).
    pub route_window: usize,
    /// Nominal per-request soft SLA (s) used to derive
    /// `HeadView::slack_s` for deadline-aware routers. Non-positive
    /// (`--sla 0`) means **no SLA**: heads carry infinite slack (EDF
    /// degrades to its deterministic FIFO fallback) and no completion
    /// counts as a miss.
    pub sla_s: f64,
    /// Opt-in (`--state-slack`): append the head's SLA slack to the PPO
    /// state vector as one extra feature. Off by default — the paper's
    /// eq. 1 state — and `TelemetrySnapshot::state_dim` accounts for it,
    /// so checkpoints are shape-incompatible across the flag.
    pub state_slack: bool,
}

impl Default for RouterCfg {
    fn default() -> Self {
        RouterCfg { route_window: 1, sla_s: 1.0, state_slack: false }
    }
}

impl RouterCfg {
    /// Whether a soft SLA is configured at all (`--sla 0` disables it).
    pub fn sla_enabled(&self) -> bool {
        self.sla_s > 0.0
    }

    /// Deadline slack for a head that has been queued for `age_s`
    /// seconds: `sla − age`, or +∞ when no SLA is configured — the same
    /// "no deadline pressure" sentinel synthetic heads use, so
    /// deadline-aware routers fall back to their no-SLA behaviour
    /// instead of ordering on a poisoned uniform slack.
    pub fn slack_at(&self, age_s: f64) -> f64 {
        if self.sla_enabled() {
            self.sla_s - age_s
        } else {
            f64::INFINITY
        }
    }

    /// Per-tenant deadline slack: `sla × mult − age`, or +∞ when no SLA
    /// is configured. `slack_for(age, 1.0)` is bit-identical to
    /// `slack_at(age)` (×1.0 is exact), which keeps the single-tenant
    /// default path byte-stable.
    pub fn slack_for(&self, age_s: f64, sla_mult: f64) -> f64 {
        if self.sla_enabled() {
            self.sla_s * sla_mult - age_s
        } else {
            f64::INFINITY
        }
    }

    /// The effective SLA threshold (s) for a given tenant multiplier;
    /// non-positive still means "no SLA".
    pub fn sla_for(&self, sla_mult: f64) -> f64 {
        self.sla_s * sla_mult
    }
}

/// Request→shard assignment policy for the multi-leader coordinator
/// (`coordinator::shard`). Both are deterministic per seed and worker
/// count: `Hash` is a pure function of the request id, `RoundRobin`
/// cycles a cursor in (deterministic) enqueue order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardAssignKind {
    Hash,
    RoundRobin,
    /// Batch-key affinity: hash of `(segment, requested width)`, so
    /// same-key requests concentrate on one leader and its FIFO grows
    /// long same-segment runs (bigger micro-batch groups per decision).
    KeyAffine,
}

impl ShardAssignKind {
    /// Parse a CLI/JSON spelling (`hash` | `round-robin` | `key-affine`).
    pub fn parse(s: &str) -> Option<ShardAssignKind> {
        match s {
            "hash" => Some(ShardAssignKind::Hash),
            "round-robin" | "rr" => Some(ShardAssignKind::RoundRobin),
            "key-affine" | "affine" => Some(ShardAssignKind::KeyAffine),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            ShardAssignKind::Hash => "hash",
            ShardAssignKind::RoundRobin => "round-robin",
            ShardAssignKind::KeyAffine => "key-affine",
        }
    }
}

/// Admission-control policy ahead of shard routing. `None` (the
/// default) feeds arrivals straight to the leader shards — the
/// pre-admission engine, bit-identical per seed; `Drr` runs arrivals
/// through the deficit-round-robin `coordinator::admission::DrrGate`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmissionKind {
    None,
    Drr,
}

impl AdmissionKind {
    /// Parse a CLI/JSON spelling (`none` | `drr`).
    pub fn parse(s: &str) -> Option<AdmissionKind> {
        match s {
            "none" | "off" => Some(AdmissionKind::None),
            "drr" => Some(AdmissionKind::Drr),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            AdmissionKind::None => "none",
            AdmissionKind::Drr => "drr",
        }
    }
}

/// Deficit-round-robin admission knobs (`coordinator::admission`). The
/// bounded-everything shape follows the Kaskade DRR exemplar named in
/// the ROADMAP: bounded credit (burstiness cap), bounded scan width per
/// tick, bounded batch admission per tick, and a finite per-tenant
/// queue as backpressure (overflow sheds deterministically).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AdmissionCfg {
    pub kind: AdmissionKind,
    /// Credits a backlogged tenant accrues per admission tick; each
    /// admitted request charges 1 credit.
    pub quantum: f64,
    /// Credit ceiling — caps how big a burst an idle-then-active tenant
    /// can push through in one tick.
    pub burst_cap: f64,
    /// Tenants examined per tick (round-robin cursor resumes where the
    /// previous tick stopped).
    pub scan_width: usize,
    /// Total requests admitted per tick across all scanned tenants.
    pub batch_max: usize,
    /// Per-tenant pending-queue capacity; arrivals beyond it are shed.
    pub queue_cap: usize,
    /// Overload policy: once a tenant's pending queue is deeper than
    /// this, its admitted requests are degraded to the slimmest width
    /// (serve everyone slim rather than queue the hot tenant to death).
    /// `0` disables degradation.
    pub degrade_depth: usize,
    /// Kaskade-style failure cooldown (`--drr-cooldown`): a tenant whose
    /// queue sheds waits this many admission ticks before re-accruing
    /// credit — deterministic backoff for misbehaving tenants. `0` (the
    /// default) disables the cooldown and is bit-identical to the
    /// cooldown-less gate.
    pub cooldown_ticks: u64,
}

impl Default for AdmissionCfg {
    fn default() -> Self {
        AdmissionCfg {
            kind: AdmissionKind::None,
            quantum: 4.0,
            burst_cap: 32.0,
            scan_width: 16,
            batch_max: 64,
            queue_cap: 512,
            degrade_depth: 128,
            cooldown_ticks: 0,
        }
    }
}

/// Multi-leader sharding knobs (`coordinator::shard`'s `ShardedEngine`,
/// built via `sharded_engine`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ShardCfg {
    /// Leader shards the global FIFO is split across. `1` (the default)
    /// is the paper's single-leader hierarchy and reproduces the
    /// pre-shard engine bit-identically per seed.
    pub leaders: usize,
    /// Request→shard assignment policy.
    pub assign: ShardAssignKind,
    /// Cross-shard rebalance trigger: migrate the deepest shard's head
    /// run to the shallowest shard when their FIFO depths differ by more
    /// than this many requests. `0` disables rebalancing.
    pub rebalance_threshold: usize,
    /// Leader routing service time per routed head (s). `0` (the
    /// default) models an infinitely fast leader — the pre-shard
    /// behaviour; a positive value caps each leader shard's routing
    /// throughput at `1/leader_service_s` heads per second, which is
    /// what makes multi-leader scaling measurable.
    pub leader_service_s: f64,
    /// OS threads used to run per-shard `Router::plan` calls in
    /// parallel. `1` (the default) is the sequential loop, pinned
    /// byte-identical in `tests/determinism.rs`; higher values plan
    /// independent shards concurrently on per-shard RNG streams and
    /// apply the plans in deterministic shard order, so results are
    /// reproducible per seed at any thread count.
    pub plan_threads: usize,
}

impl Default for ShardCfg {
    fn default() -> Self {
        ShardCfg {
            leaders: 1,
            assign: ShardAssignKind::Hash,
            rebalance_threshold: 0,
            leader_service_s: 0.0,
            plan_threads: 1,
        }
    }
}

/// Evaluation-harness fan-out (`trace::compare`, `repro trace-study`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EvalCfg {
    /// OS threads for the evaluation harness (`--eval-threads`):
    /// entrant replays in `trace-compare`, scenario cells in
    /// `trace-study`. `1` (the default) is the sequential loop; higher
    /// values fan the independent replays across scoped threads and
    /// reassemble results in entrant / registry order, so reports are
    /// byte-identical at any thread count (the `trace::compare` tests
    /// pin this).
    pub threads: usize,
}

impl Default for EvalCfg {
    fn default() -> Self {
        EvalCfg { threads: 1 }
    }
}

/// Observability layer (`crate::obs`): metrics registry, stage timing,
/// per-tick series.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ObsCfg {
    /// Master switch (`--obs false` disables collection entirely). The
    /// collector draws no RNG and never feeds back into scheduling, so
    /// sim results are bit-identical either way — off saves only the
    /// bookkeeping cost itself (`obs_overhead_pct` in the benches).
    pub enabled: bool,
    /// Per-tick series capacity in rows (`--obs-series-cap`). On
    /// overflow the series decimates to every other row and doubles its
    /// recording stride, so memory stays bounded for runs of any length.
    pub series_cap: usize,
}

impl Default for ObsCfg {
    fn default() -> Self {
        ObsCfg { enabled: true, series_cap: 4096 }
    }
}

/// Live-control-plane policy (`crate::ctrl`). `None` (the default) pins
/// the engine to its construction-time knobs — bit-identical to the
/// pre-control-plane engine; `Backlog` installs the hysteresis
/// backlog controller that retunes the tunable knob subset from the
/// per-tick observability row.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ControllerKind {
    None,
    Backlog,
}

impl ControllerKind {
    /// Parse a CLI/JSON spelling (`none` | `backlog`).
    pub fn parse(s: &str) -> Option<ControllerKind> {
        match s {
            "none" | "off" => Some(ControllerKind::None),
            "backlog" => Some(ControllerKind::Backlog),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            ControllerKind::None => "none",
            ControllerKind::Backlog => "backlog",
        }
    }
}

/// Control-plane knobs (`--controller`). The controller is pure and
/// zero-RNG: it maps each telemetry-tick row to a (clamped) knob
/// vector, so controller-on runs stay pure functions of the seed and
/// knob changes are recorded in the trace for identical replays.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CtrlCfg {
    pub controller: ControllerKind,
}

impl Default for CtrlCfg {
    fn default() -> Self {
        CtrlCfg { controller: ControllerKind::None }
    }
}

/// Reward weights (eq. 7): r = α·p_acc − β·L − γ·E − δ·Var(U) + b.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RewardCfg {
    pub alpha: f64,
    pub beta: f64,
    pub gamma: f64,
    pub delta: f64,
    pub bonus: f64,
    /// Center the accuracy prior at the top-1 mean (zero-mean option).
    pub center_acc: bool,
}

impl RewardCfg {
    /// Heavy latency/energy weighting — the paper's "overfit" policy
    /// (Table IV): collapses onto the slimmest width. α is kept tiny so
    /// even the base (uncongested) latency gap between widths dominates
    /// the accuracy prior.
    pub fn overfit() -> Self {
        RewardCfg {
            alpha: 0.02,
            beta: 60.0,
            gamma: 0.05,
            delta: 0.2,
            bonus: 0.0,
            center_acc: false,
        }
    }

    /// Balanced weighting — the paper's "averaged" policy (Table V):
    /// recovers accuracy at the cost of higher latency/energy variance.
    /// α sits at the boundary where a wide block's accuracy gain roughly
    /// equals its congested-latency cost, so the learned policy mixes
    /// widths with load instead of collapsing either way.
    pub fn balanced() -> Self {
        RewardCfg {
            alpha: 3.5,
            beta: 1.2,
            gamma: 0.0008,
            delta: 0.4,
            bonus: 0.0,
            center_acc: true,
        }
    }
}

impl Default for RewardCfg {
    fn default() -> Self {
        RewardCfg::balanced()
    }
}

/// PPO hyper-parameters (§III-B).
#[derive(Clone, Debug, PartialEq)]
pub struct PpoCfg {
    /// Hidden layer sizes of the shared MLP trunk.
    pub hidden: Vec<usize>,
    pub lr: f64,
    /// Clipping ε in eq. 10.
    pub clip: f64,
    /// Value-loss coefficient c_v.
    pub c_v: f64,
    /// Entropy coefficient c_H.
    pub c_h: f64,
    /// Optimization epochs per update (paper: K = 3).
    pub epochs: usize,
    /// Gradient-norm clip.
    pub grad_clip: f64,
    /// ε-mixing schedule for the server head (eq. 5).
    pub eps_max: f64,
    pub eps_min: f64,
    pub t_dec: f64,
    /// Rollout length between updates.
    pub horizon: usize,
    /// Reward shaping.
    pub reward: RewardCfg,
    /// Micro-batch group sizes the g-head chooses from.
    pub groups: Vec<usize>,
}

impl Default for PpoCfg {
    fn default() -> Self {
        PpoCfg {
            hidden: vec![64, 64],
            lr: 3e-4,
            clip: 0.2,
            c_v: 0.5,
            c_h: 0.01,
            epochs: 3,
            grad_clip: 0.5,
            eps_max: 0.30,
            eps_min: 0.02,
            t_dec: 20_000.0,
            horizon: 256,
            reward: RewardCfg::default(),
            groups: vec![1, 4, 16],
        }
    }
}

/// One simulated GPU's static profile (see `sim::profiles` for the
/// calibrated 2080 Ti / 980 Ti instances).
#[derive(Clone, Debug, PartialEq)]
pub struct DeviceCfg {
    pub name: String,
    /// Peak f32 throughput used by the analytical latency model (FLOP/s).
    pub peak_flops: f64,
    /// Memory bandwidth (bytes/s) for the roofline latency term.
    pub mem_bw: f64,
    /// Total VRAM bytes.
    pub vram_bytes: u64,
    pub idle_power_w: f64,
    pub max_power_w: f64,
    /// Utilization where latency/energy go super-linear (Figs 2-3 knee).
    pub knee_util_pct: f64,
    /// Strength of the super-linear blow-up past the knee.
    pub knee_sharpness: f64,
    /// Per-dispatch fixed overhead (kernel launch, s).
    pub dispatch_overhead_s: f64,
}

/// Inter-server link model (the paper used Wi-Fi 5 WLAN).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkCfg {
    pub base_latency_s: f64,
    pub jitter_s: f64,
    pub bandwidth_bytes_per_s: f64,
}

impl Default for LinkCfg {
    fn default() -> Self {
        // Wi-Fi 5 802.11ac-ish: ~2 ms RTT/2, 400 Mbit/s effective.
        LinkCfg {
            base_latency_s: 1.0e-3,
            jitter_s: 0.4e-3,
            bandwidth_bytes_per_s: 50.0e6,
        }
    }
}

/// Workload generator settings.
#[derive(Clone, Debug, PartialEq)]
pub struct WorkloadCfg {
    /// Mean arrival rate r (requests/s).
    pub rate_hz: f64,
    /// Bursty modulation: rate multiplier during bursts.
    pub burst_factor: f64,
    /// Burst period (s) and duty cycle in [0,1].
    pub burst_period_s: f64,
    pub burst_duty: f64,
    /// Diurnal (sinusoidal) rate modulation: cycle length in virtual
    /// seconds (0 disables) and modulation depth in [0,1).
    pub diurnal_period_s: f64,
    pub diurnal_depth: f64,
    /// Total requests to issue.
    pub total_requests: usize,
    /// Requested widths distribution (uniform over the scheduler widths
    /// when empty).
    pub width_mix: Vec<f64>,
    /// Tenants sharing the cluster. `1` (the default) is the anonymous
    /// single-stream workload — the pre-tenant engine, bit-identical
    /// per seed (the tenant RNG stream is only split off when > 1).
    pub tenants: usize,
    /// Zipf exponent for tenant popularity (tenant 0 is the hottest);
    /// only meaningful when `tenants > 1`.
    pub tenant_zipf: f64,
    /// Flash-crowd injection: tenant 0's arrival share is multiplied by
    /// this factor inside `[flash_start_s, flash_end_s)`. `1` (the
    /// default) disables the flash entirely.
    pub flash_factor: f64,
    pub flash_start_s: f64,
    pub flash_end_s: f64,
}

impl Default for WorkloadCfg {
    fn default() -> Self {
        WorkloadCfg {
            // Calibrated against the simulated cluster's capacity (~120
            // img/s at mixed widths, ~350 img/s all-slim): the mean
            // offered load of 210 img/s keeps a random-routing baseline
            // past saturation (the paper's ~9 s mean-latency regime)
            // while an all-slim policy drains comfortably.
            rate_hz: 140.0,
            burst_factor: 3.0,
            burst_period_s: 10.0,
            burst_duty: 0.25,
            diurnal_period_s: 0.0,
            diurnal_depth: 0.0,
            total_requests: 20_000,
            width_mix: vec![],
            tenants: 1,
            tenant_zipf: 1.1,
            flash_factor: 1.0,
            flash_start_s: 0.0,
            flash_end_s: 0.0,
        }
    }
}

/// Mid-run device failure injection: `server` stops accepting work at
/// virtual time `at_s` (scenario `dropout`; the engine re-routes its
/// queue and remaps later decisions to surviving servers).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DropoutCfg {
    pub server: usize,
    pub at_s: f64,
}

/// Top-level configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct Config {
    pub seed: u64,
    pub artifacts_dir: String,
    /// Device profile names resolved via `sim::profiles::by_name`.
    pub devices: Vec<String>,
    pub router: RouterCfg,
    pub shard: ShardCfg,
    pub eval: EvalCfg,
    pub obs: ObsCfg,
    pub ctrl: CtrlCfg,
    pub admission: AdmissionCfg,
    pub scheduler: SchedulerCfg,
    pub ppo: PpoCfg,
    pub link: LinkCfg,
    pub workload: WorkloadCfg,
    /// Name of the `sim::scenarios` entry this config came from (run
    /// provenance; None for hand-built configs).
    pub scenario: Option<String>,
    /// Optional mid-run device failure injection.
    pub dropout: Option<DropoutCfg>,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            seed: 42,
            artifacts_dir: "artifacts".to_string(),
            // the paper's heterogeneous 3-GPU cluster
            devices: vec![
                "rtx2080ti".to_string(),
                "rtx2080ti".to_string(),
                "gtx980ti".to_string(),
            ],
            router: RouterCfg::default(),
            shard: ShardCfg::default(),
            eval: EvalCfg::default(),
            obs: ObsCfg::default(),
            ctrl: CtrlCfg::default(),
            admission: AdmissionCfg::default(),
            scheduler: SchedulerCfg::default(),
            ppo: PpoCfg::default(),
            link: LinkCfg::default(),
            workload: WorkloadCfg::default(),
            scenario: None,
            dropout: None,
        }
    }
}

impl Config {
    /// Apply CLI overrides (a flat, documented subset — the fields every
    /// example/bench sweeps). `--scenario <name>` is applied first, so
    /// explicit flags override the scenario's baseline.
    pub fn apply_args(&mut self, args: &Args) {
        if let Some(name) = args.get("scenario") {
            crate::sim::scenarios::apply_named(name, self).unwrap_or_else(|e| {
                panic!("--scenario: {e}")
            });
        }
        self.seed = args.u64_or("seed", self.seed);
        self.artifacts_dir = args.str_or("artifacts-dir", &self.artifacts_dir);
        self.workload.rate_hz = args.f64_or("rate", self.workload.rate_hz);
        self.workload.total_requests =
            args.usize_or("requests", self.workload.total_requests);
        self.workload.burst_factor =
            args.f64_or("burst-factor", self.workload.burst_factor);
        self.workload.diurnal_period_s =
            args.f64_or("diurnal-period", self.workload.diurnal_period_s);
        self.workload.diurnal_depth =
            args.f64_or("diurnal-depth", self.workload.diurnal_depth);
        if let Some(spec) = args.get("dropout") {
            // "server@time", e.g. --dropout 0@5.0
            let parsed = spec.split_once('@').and_then(|(s, t)| {
                Some(DropoutCfg {
                    server: s.trim().parse().ok()?,
                    at_s: t.trim().parse().ok()?,
                })
            });
            match parsed {
                Some(dp) => self.dropout = Some(dp),
                None => panic!("--dropout expects server@time (e.g. 0@5.0), got {spec:?}"),
            }
        }
        self.router.route_window =
            args.usize_or("route-window", self.router.route_window).max(1);
        self.router.sla_s = args.f64_or("sla", self.router.sla_s);
        if args.flag("state-slack") {
            self.router.state_slack = true;
        }
        self.shard.leaders = args.usize_or("leaders", self.shard.leaders).max(1);
        self.shard.rebalance_threshold =
            args.usize_or("rebalance", self.shard.rebalance_threshold);
        self.shard.leader_service_s =
            args.f64_or("leader-service", self.shard.leader_service_s);
        self.shard.plan_threads =
            args.usize_or("plan-threads", self.shard.plan_threads).max(1);
        self.eval.threads =
            args.usize_or("eval-threads", self.eval.threads).max(1);
        if let Some(v) = args.get("obs") {
            // `flag()` can only turn things on; --obs needs the off path
            self.obs.enabled = match v {
                "true" | "1" | "yes" | "on" => true,
                "false" | "0" | "no" | "off" => false,
                other => panic!("--obs expects true|false, got {other:?}"),
            };
        }
        self.obs.series_cap =
            args.usize_or("obs-series-cap", self.obs.series_cap).max(2);
        if let Some(kind) = args.get("shard-assign") {
            self.shard.assign = ShardAssignKind::parse(kind).unwrap_or_else(|| {
                panic!("--shard-assign expects hash|round-robin|key-affine, got {kind:?}")
            });
        }
        self.workload.tenants =
            args.usize_or("tenants", self.workload.tenants).max(1);
        self.workload.tenant_zipf =
            args.f64_or("tenant-zipf", self.workload.tenant_zipf);
        if let Some(kind) = args.get("admission") {
            self.admission.kind = AdmissionKind::parse(kind).unwrap_or_else(|| {
                panic!("--admission expects drr|none, got {kind:?}")
            });
        }
        self.admission.quantum = args.f64_or("drr-quantum", self.admission.quantum);
        self.admission.burst_cap =
            args.f64_or("drr-burst-cap", self.admission.burst_cap);
        self.admission.queue_cap =
            args.usize_or("drr-queue-cap", self.admission.queue_cap).max(1);
        self.admission.cooldown_ticks =
            args.u64_or("drr-cooldown", self.admission.cooldown_ticks);
        if let Some(kind) = args.get("controller") {
            self.ctrl.controller =
                ControllerKind::parse(kind).unwrap_or_else(|| {
                    panic!("--controller expects none|backlog, got {kind:?}")
                });
        }
        self.scheduler.b_max = args.usize_or("b-max", self.scheduler.b_max);
        self.scheduler.u_blk_pct = args.f64_or("u-blk", self.scheduler.u_blk_pct);
        self.scheduler.t_idle_s = args.f64_or("t-idle", self.scheduler.t_idle_s);
        self.scheduler.n_new = args.usize_or("n-new", self.scheduler.n_new);
        self.ppo.lr = args.f64_or("lr", self.ppo.lr);
        self.ppo.horizon = args.usize_or("horizon", self.ppo.horizon);
        self.ppo.c_h = args.f64_or("entropy", self.ppo.c_h);
        match args.get("reward") {
            Some("overfit") => self.ppo.reward = RewardCfg::overfit(),
            Some("balanced") => self.ppo.reward = RewardCfg::balanced(),
            _ => {}
        }
        // fine-grained reward-weight overrides (ablation sweeps)
        self.ppo.reward.alpha = args.f64_or("alpha", self.ppo.reward.alpha);
        self.ppo.reward.beta = args.f64_or("beta", self.ppo.reward.beta);
        self.ppo.reward.gamma = args.f64_or("gamma", self.ppo.reward.gamma);
        self.ppo.reward.delta = args.f64_or("delta", self.ppo.reward.delta);
        if let Some(n) = args.get("devices") {
            self.devices = n.split(',').map(str::to_string).collect();
        }
    }

    /// Serialize for run provenance.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("seed", Json::Num(self.seed as f64)),
            ("artifacts_dir", Json::Str(self.artifacts_dir.clone())),
            (
                "scenario",
                match &self.scenario {
                    Some(s) => Json::Str(s.clone()),
                    None => Json::Null,
                },
            ),
            (
                "dropout",
                match self.dropout {
                    Some(dp) => obj(vec![
                        ("server", Json::Num(dp.server as f64)),
                        ("at_s", Json::Num(dp.at_s)),
                    ]),
                    None => Json::Null,
                },
            ),
            (
                "devices",
                Json::Arr(self.devices.iter().cloned().map(Json::Str).collect()),
            ),
            (
                "router",
                obj(vec![
                    ("route_window", Json::Num(self.router.route_window as f64)),
                    ("sla_s", Json::Num(self.router.sla_s)),
                    ("state_slack", Json::Bool(self.router.state_slack)),
                ]),
            ),
            (
                "shard",
                obj(vec![
                    ("leaders", Json::Num(self.shard.leaders as f64)),
                    ("assign", Json::Str(self.shard.assign.as_str().to_string())),
                    (
                        "rebalance_threshold",
                        Json::Num(self.shard.rebalance_threshold as f64),
                    ),
                    ("leader_service_s", Json::Num(self.shard.leader_service_s)),
                    ("plan_threads", Json::Num(self.shard.plan_threads as f64)),
                ]),
            ),
            (
                "eval",
                obj(vec![(
                    "threads",
                    Json::Num(self.eval.threads as f64),
                )]),
            ),
            (
                "obs",
                obj(vec![
                    ("enabled", Json::Bool(self.obs.enabled)),
                    ("series_cap", Json::Num(self.obs.series_cap as f64)),
                ]),
            ),
            (
                "ctrl",
                obj(vec![(
                    "controller",
                    Json::Str(self.ctrl.controller.as_str().to_string()),
                )]),
            ),
            (
                "admission",
                obj(vec![
                    ("kind", Json::Str(self.admission.kind.as_str().to_string())),
                    ("quantum", Json::Num(self.admission.quantum)),
                    ("burst_cap", Json::Num(self.admission.burst_cap)),
                    ("scan_width", Json::Num(self.admission.scan_width as f64)),
                    ("batch_max", Json::Num(self.admission.batch_max as f64)),
                    ("queue_cap", Json::Num(self.admission.queue_cap as f64)),
                    (
                        "degrade_depth",
                        Json::Num(self.admission.degrade_depth as f64),
                    ),
                    (
                        "cooldown_ticks",
                        Json::Num(self.admission.cooldown_ticks as f64),
                    ),
                ]),
            ),
            (
                "scheduler",
                obj(vec![
                    ("b_max", Json::Num(self.scheduler.b_max as f64)),
                    ("m_max_bytes", Json::Num(self.scheduler.m_max_bytes as f64)),
                    ("u_blk_pct", Json::Num(self.scheduler.u_blk_pct)),
                    ("t_idle_s", Json::Num(self.scheduler.t_idle_s)),
                    ("q_th", Json::Num(self.scheduler.q_th as f64)),
                    ("n_new", Json::Num(self.scheduler.n_new as f64)),
                    ("widths", arr_f64(&self.scheduler.widths)),
                ]),
            ),
            (
                "ppo",
                obj(vec![
                    (
                        "hidden",
                        Json::Arr(
                            self.ppo.hidden.iter().map(|&h| Json::Num(h as f64)).collect(),
                        ),
                    ),
                    ("lr", Json::Num(self.ppo.lr)),
                    ("clip", Json::Num(self.ppo.clip)),
                    ("c_v", Json::Num(self.ppo.c_v)),
                    ("c_h", Json::Num(self.ppo.c_h)),
                    ("epochs", Json::Num(self.ppo.epochs as f64)),
                    ("grad_clip", Json::Num(self.ppo.grad_clip)),
                    ("eps_max", Json::Num(self.ppo.eps_max)),
                    ("eps_min", Json::Num(self.ppo.eps_min)),
                    ("t_dec", Json::Num(self.ppo.t_dec)),
                    ("horizon", Json::Num(self.ppo.horizon as f64)),
                    (
                        "groups",
                        Json::Arr(
                            self.ppo
                                .groups
                                .iter()
                                .map(|&g| Json::Num(g as f64))
                                .collect(),
                        ),
                    ),
                    (
                        "reward",
                        obj(vec![
                            ("alpha", Json::Num(self.ppo.reward.alpha)),
                            ("beta", Json::Num(self.ppo.reward.beta)),
                            ("gamma", Json::Num(self.ppo.reward.gamma)),
                            ("delta", Json::Num(self.ppo.reward.delta)),
                            ("bonus", Json::Num(self.ppo.reward.bonus)),
                            ("center_acc", Json::Bool(self.ppo.reward.center_acc)),
                        ]),
                    ),
                ]),
            ),
            (
                "workload",
                obj(vec![
                    ("rate_hz", Json::Num(self.workload.rate_hz)),
                    ("burst_factor", Json::Num(self.workload.burst_factor)),
                    ("burst_period_s", Json::Num(self.workload.burst_period_s)),
                    ("burst_duty", Json::Num(self.workload.burst_duty)),
                    ("diurnal_period_s", Json::Num(self.workload.diurnal_period_s)),
                    ("diurnal_depth", Json::Num(self.workload.diurnal_depth)),
                    (
                        "total_requests",
                        Json::Num(self.workload.total_requests as f64),
                    ),
                    ("width_mix", arr_f64(&self.workload.width_mix)),
                    ("tenants", Json::Num(self.workload.tenants as f64)),
                    ("tenant_zipf", Json::Num(self.workload.tenant_zipf)),
                    ("flash_factor", Json::Num(self.workload.flash_factor)),
                    ("flash_start_s", Json::Num(self.workload.flash_start_s)),
                    ("flash_end_s", Json::Num(self.workload.flash_end_s)),
                ]),
            ),
        ])
    }

    /// Load overrides from a JSON config file (fields are optional — the
    /// file only needs the keys it changes).
    pub fn from_json(json: &Json) -> Config {
        let mut cfg = Config::default();
        if let Some(x) = json.get("seed").and_then(Json::as_f64) {
            cfg.seed = x as u64;
        }
        if let Some(x) = json.get("artifacts_dir").and_then(Json::as_str) {
            cfg.artifacts_dir = x.to_string();
        }
        if let Some(xs) = json.get("devices").and_then(Json::as_arr) {
            cfg.devices = xs.iter().filter_map(Json::as_str).map(str::to_string).collect();
        }
        if let Some(s) = json.get("scenario").and_then(Json::as_str) {
            cfg.scenario = Some(s.to_string());
        }
        if let Some(dp) = json.get("dropout") {
            let server = dp.get("server").and_then(Json::as_usize);
            let at_s = dp.get("at_s").and_then(Json::as_f64);
            if let (Some(server), Some(at_s)) = (server, at_s) {
                cfg.dropout = Some(DropoutCfg { server, at_s });
            }
        }
        if let Some(r) = json.get("router") {
            if let Some(x) = r.get("route_window").and_then(Json::as_usize) {
                cfg.router.route_window = x.max(1);
            }
            if let Some(x) = r.get("sla_s").and_then(Json::as_f64) {
                cfg.router.sla_s = x;
            }
            if let Some(x) = r.get("state_slack").and_then(Json::as_bool) {
                cfg.router.state_slack = x;
            }
        }
        if let Some(sh) = json.get("shard") {
            if let Some(x) = sh.get("leaders").and_then(Json::as_usize) {
                cfg.shard.leaders = x.max(1);
            }
            if let Some(x) = sh.get("assign").and_then(Json::as_str) {
                if let Some(kind) = ShardAssignKind::parse(x) {
                    cfg.shard.assign = kind;
                }
            }
            if let Some(x) = sh.get("rebalance_threshold").and_then(Json::as_usize) {
                cfg.shard.rebalance_threshold = x;
            }
            if let Some(x) = sh.get("leader_service_s").and_then(Json::as_f64) {
                cfg.shard.leader_service_s = x;
            }
            if let Some(x) = sh.get("plan_threads").and_then(Json::as_usize) {
                cfg.shard.plan_threads = x.max(1);
            }
        }
        if let Some(ev) = json.get("eval") {
            if let Some(x) = ev.get("threads").and_then(Json::as_usize) {
                cfg.eval.threads = x.max(1);
            }
        }
        // pre-observability trace headers have no "obs" key: defaults apply
        if let Some(o) = json.get("obs") {
            if let Some(x) = o.get("enabled").and_then(Json::as_bool) {
                cfg.obs.enabled = x;
            }
            if let Some(x) = o.get("series_cap").and_then(Json::as_usize) {
                cfg.obs.series_cap = x.max(2);
            }
        }
        // pre-control-plane trace headers have no "ctrl" key: defaults apply
        if let Some(c) = json.get("ctrl") {
            if let Some(x) = c.get("controller").and_then(Json::as_str) {
                if let Some(kind) = ControllerKind::parse(x) {
                    cfg.ctrl.controller = kind;
                }
            }
        }
        if let Some(a) = json.get("admission") {
            if let Some(x) = a.get("kind").and_then(Json::as_str) {
                if let Some(kind) = AdmissionKind::parse(x) {
                    cfg.admission.kind = kind;
                }
            }
            if let Some(x) = a.get("quantum").and_then(Json::as_f64) {
                cfg.admission.quantum = x;
            }
            if let Some(x) = a.get("burst_cap").and_then(Json::as_f64) {
                cfg.admission.burst_cap = x;
            }
            if let Some(x) = a.get("scan_width").and_then(Json::as_usize) {
                cfg.admission.scan_width = x.max(1);
            }
            if let Some(x) = a.get("batch_max").and_then(Json::as_usize) {
                cfg.admission.batch_max = x.max(1);
            }
            if let Some(x) = a.get("queue_cap").and_then(Json::as_usize) {
                cfg.admission.queue_cap = x.max(1);
            }
            if let Some(x) = a.get("degrade_depth").and_then(Json::as_usize) {
                cfg.admission.degrade_depth = x;
            }
            if let Some(x) = a.get("cooldown_ticks").and_then(Json::as_f64) {
                cfg.admission.cooldown_ticks = x as u64;
            }
        }
        if let Some(s) = json.get("scheduler") {
            if let Some(x) = s.get("b_max").and_then(Json::as_usize) {
                cfg.scheduler.b_max = x;
            }
            if let Some(x) = s.get("m_max_bytes").and_then(Json::as_f64) {
                cfg.scheduler.m_max_bytes = x as u64;
            }
            if let Some(x) = s.get("u_blk_pct").and_then(Json::as_f64) {
                cfg.scheduler.u_blk_pct = x;
            }
            if let Some(x) = s.get("t_idle_s").and_then(Json::as_f64) {
                cfg.scheduler.t_idle_s = x;
            }
            if let Some(x) = s.get("q_th").and_then(Json::as_usize) {
                cfg.scheduler.q_th = x;
            }
            if let Some(x) = s.get("n_new").and_then(Json::as_usize) {
                cfg.scheduler.n_new = x;
            }
            if let Some(x) = s.get("widths").and_then(Json::as_f64_vec) {
                cfg.scheduler.widths = x;
            }
        }
        if let Some(w) = json.get("workload") {
            if let Some(x) = w.get("rate_hz").and_then(Json::as_f64) {
                cfg.workload.rate_hz = x;
            }
            if let Some(x) = w.get("total_requests").and_then(Json::as_usize) {
                cfg.workload.total_requests = x;
            }
            if let Some(x) = w.get("burst_factor").and_then(Json::as_f64) {
                cfg.workload.burst_factor = x;
            }
            if let Some(x) = w.get("burst_period_s").and_then(Json::as_f64) {
                cfg.workload.burst_period_s = x;
            }
            if let Some(x) = w.get("burst_duty").and_then(Json::as_f64) {
                cfg.workload.burst_duty = x;
            }
            if let Some(x) = w.get("width_mix").and_then(Json::as_f64_vec) {
                cfg.workload.width_mix = x;
            }
            if let Some(x) = w.get("diurnal_period_s").and_then(Json::as_f64) {
                cfg.workload.diurnal_period_s = x;
            }
            if let Some(x) = w.get("diurnal_depth").and_then(Json::as_f64) {
                cfg.workload.diurnal_depth = x;
            }
            if let Some(x) = w.get("tenants").and_then(Json::as_usize) {
                cfg.workload.tenants = x.max(1);
            }
            if let Some(x) = w.get("tenant_zipf").and_then(Json::as_f64) {
                cfg.workload.tenant_zipf = x;
            }
            if let Some(x) = w.get("flash_factor").and_then(Json::as_f64) {
                cfg.workload.flash_factor = x;
            }
            if let Some(x) = w.get("flash_start_s").and_then(Json::as_f64) {
                cfg.workload.flash_start_s = x;
            }
            if let Some(x) = w.get("flash_end_s").and_then(Json::as_f64) {
                cfg.workload.flash_end_s = x;
            }
        }
        if let Some(p) = json.get("ppo") {
            if let Some(x) = p.get("hidden").and_then(Json::as_usize_vec) {
                cfg.ppo.hidden = x;
            }
            if let Some(x) = p.get("lr").and_then(Json::as_f64) {
                cfg.ppo.lr = x;
            }
            if let Some(x) = p.get("clip").and_then(Json::as_f64) {
                cfg.ppo.clip = x;
            }
            if let Some(x) = p.get("c_v").and_then(Json::as_f64) {
                cfg.ppo.c_v = x;
            }
            if let Some(x) = p.get("c_h").and_then(Json::as_f64) {
                cfg.ppo.c_h = x;
            }
            if let Some(x) = p.get("grad_clip").and_then(Json::as_f64) {
                cfg.ppo.grad_clip = x;
            }
            if let Some(x) = p.get("eps_max").and_then(Json::as_f64) {
                cfg.ppo.eps_max = x;
            }
            if let Some(x) = p.get("eps_min").and_then(Json::as_f64) {
                cfg.ppo.eps_min = x;
            }
            if let Some(x) = p.get("t_dec").and_then(Json::as_f64) {
                cfg.ppo.t_dec = x;
            }
            if let Some(x) = p.get("horizon").and_then(Json::as_usize) {
                cfg.ppo.horizon = x;
            }
            if let Some(x) = p.get("epochs").and_then(Json::as_usize) {
                cfg.ppo.epochs = x;
            }
            if let Some(x) = p.get("groups").and_then(Json::as_usize_vec) {
                cfg.ppo.groups = x;
            }
            if let Some(r) = p.get("reward") {
                if let Some(x) = r.get("alpha").and_then(Json::as_f64) {
                    cfg.ppo.reward.alpha = x;
                }
                if let Some(x) = r.get("beta").and_then(Json::as_f64) {
                    cfg.ppo.reward.beta = x;
                }
                if let Some(x) = r.get("gamma").and_then(Json::as_f64) {
                    cfg.ppo.reward.gamma = x;
                }
                if let Some(x) = r.get("delta").and_then(Json::as_f64) {
                    cfg.ppo.reward.delta = x;
                }
                if let Some(x) = r.get("bonus").and_then(Json::as_f64) {
                    cfg.ppo.reward.bonus = x;
                }
                if let Some(x) = r.get("center_acc").and_then(Json::as_bool) {
                    cfg.ppo.reward.center_acc = x;
                }
            }
        }
        cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::utilx::Args;

    #[test]
    fn default_is_papers_cluster() {
        let cfg = Config::default();
        assert_eq!(cfg.devices.len(), 3);
        assert_eq!(
            cfg.devices.iter().filter(|d| d.as_str() == "rtx2080ti").count(),
            2
        );
        assert_eq!(cfg.scheduler.widths, WIDTHS.to_vec());
        assert_eq!(cfg.ppo.epochs, 3); // paper: K = 3
        assert_eq!(cfg.ppo.clip, 0.2); // paper: ε = 0.2
        assert_eq!(cfg.ppo.c_v, 0.5); // paper: c_v = 0.5
    }

    #[test]
    fn args_override() {
        let mut cfg = Config::default();
        let args = Args::parse_from(
            ["simulate", "--rate", "123", "--b-max", "8", "--reward", "overfit",
             "--devices", "gtx980ti,rtx2080ti"]
                .iter()
                .map(|s| s.to_string()),
        );
        cfg.apply_args(&args);
        assert_eq!(cfg.workload.rate_hz, 123.0);
        assert_eq!(cfg.scheduler.b_max, 8);
        assert_eq!(cfg.ppo.reward, RewardCfg::overfit());
        assert_eq!(cfg.devices, vec!["gtx980ti", "rtx2080ti"]);
    }

    #[test]
    fn json_roundtrip_preserves_core_fields() {
        let mut cfg = Config::default();
        cfg.seed = 7;
        cfg.workload.rate_hz = 55.5;
        cfg.scheduler.b_max = 4;
        cfg.ppo.reward.beta = 9.0;
        let json = cfg.to_json();
        let parsed = Config::from_json(&json);
        assert_eq!(parsed.seed, 7);
        assert_eq!(parsed.workload.rate_hz, 55.5);
        assert_eq!(parsed.scheduler.b_max, 4);
        assert_eq!(parsed.ppo.reward.beta, 9.0);
    }

    #[test]
    fn from_json_accepts_partial_documents() {
        let json = Json::parse(r#"{"workload": {"rate_hz": 10}}"#).unwrap();
        let cfg = Config::from_json(&json);
        assert_eq!(cfg.workload.rate_hz, 10.0);
        // everything else defaulted
        assert_eq!(cfg.devices.len(), 3);
    }

    #[test]
    fn dropout_and_diurnal_roundtrip() {
        let mut cfg = Config::default();
        cfg.dropout = Some(DropoutCfg { server: 1, at_s: 7.5 });
        cfg.workload.diurnal_period_s = 60.0;
        cfg.workload.diurnal_depth = 0.5;
        cfg.scenario = Some("diurnal".to_string());
        let parsed = Config::from_json(&cfg.to_json());
        assert_eq!(parsed.dropout, Some(DropoutCfg { server: 1, at_s: 7.5 }));
        assert_eq!(parsed.workload.diurnal_period_s, 60.0);
        assert_eq!(parsed.workload.diurnal_depth, 0.5);
        assert_eq!(parsed.scenario.as_deref(), Some("diurnal"));
    }

    #[test]
    fn dropout_arg_parses_server_at_time() {
        let mut cfg = Config::default();
        let args = Args::parse_from(
            ["simulate", "--dropout", "2@4.5"].iter().map(|s| s.to_string()),
        );
        cfg.apply_args(&args);
        assert_eq!(cfg.dropout, Some(DropoutCfg { server: 2, at_s: 4.5 }));
    }

    #[test]
    fn scenario_arg_applies_before_flag_overrides() {
        let mut cfg = Config::default();
        let args = Args::parse_from(
            ["simulate", "--scenario", "bursty-extreme", "--rate", "77"]
                .iter()
                .map(|s| s.to_string()),
        );
        cfg.apply_args(&args);
        assert_eq!(cfg.scenario.as_deref(), Some("bursty-extreme"));
        // explicit flag wins over the scenario's baseline rate
        assert_eq!(cfg.workload.rate_hz, 77.0);
        // scenario's other knobs survive
        assert!(cfg.workload.burst_factor > 3.0);
    }

    #[test]
    #[should_panic(expected = "unknown scenario")]
    fn unknown_scenario_panics_with_hint() {
        let mut cfg = Config::default();
        let args = Args::parse_from(
            ["simulate", "--scenario", "nope"].iter().map(|s| s.to_string()),
        );
        cfg.apply_args(&args);
    }

    #[test]
    fn route_window_defaults_parses_and_roundtrips() {
        let cfg = Config::default();
        assert_eq!(cfg.router.route_window, 1); // per-head, paper-faithful
        assert!(cfg.router.sla_s > 0.0);

        let mut cfg = Config::default();
        let args = Args::parse_from(
            ["simulate", "--route-window", "8", "--sla", "0.5"]
                .iter()
                .map(|s| s.to_string()),
        );
        cfg.apply_args(&args);
        assert_eq!(cfg.router.route_window, 8);
        assert_eq!(cfg.router.sla_s, 0.5);

        let parsed = Config::from_json(&cfg.to_json());
        assert_eq!(parsed.router.route_window, 8);
        assert_eq!(parsed.router.sla_s, 0.5);

        // a pathological 0 floors at 1 (the engine always needs progress)
        let mut cfg = Config::default();
        let args = Args::parse_from(
            ["simulate", "--route-window", "0"].iter().map(|s| s.to_string()),
        );
        cfg.apply_args(&args);
        assert_eq!(cfg.router.route_window, 1);
    }

    #[test]
    fn sla_zero_means_disabled_with_infinite_slack() {
        let mut cfg = Config::default();
        assert!(cfg.router.sla_enabled()); // the 1 s soft default
        assert_eq!(cfg.router.slack_at(0.25), 0.75);

        let args = Args::parse_from(
            ["simulate", "--sla", "0"].iter().map(|s| s.to_string()),
        );
        cfg.apply_args(&args);
        assert!(!cfg.router.sla_enabled());
        assert_eq!(cfg.router.slack_at(0.25), f64::INFINITY);
        assert_eq!(cfg.router.slack_at(1e9), f64::INFINITY);
        // roundtrips through JSON like any other value
        let parsed = Config::from_json(&cfg.to_json());
        assert!(!parsed.router.sla_enabled());
    }

    #[test]
    fn shard_defaults_parse_and_roundtrip() {
        let cfg = Config::default();
        assert_eq!(cfg.shard.leaders, 1); // single leader, paper-faithful
        assert_eq!(cfg.shard.assign, ShardAssignKind::Hash);
        assert_eq!(cfg.shard.rebalance_threshold, 0); // rebalance off
        assert_eq!(cfg.shard.leader_service_s, 0.0); // infinitely fast leader
        assert_eq!(cfg.shard.plan_threads, 1); // sequential planning

        let mut cfg = Config::default();
        let args = Args::parse_from(
            ["simulate", "--leaders", "4", "--rebalance", "24",
             "--shard-assign", "round-robin", "--leader-service", "0.0015",
             "--plan-threads", "4"]
                .iter()
                .map(|s| s.to_string()),
        );
        cfg.apply_args(&args);
        assert_eq!(cfg.shard.leaders, 4);
        assert_eq!(cfg.shard.rebalance_threshold, 24);
        assert_eq!(cfg.shard.assign, ShardAssignKind::RoundRobin);
        assert_eq!(cfg.shard.leader_service_s, 0.0015);
        assert_eq!(cfg.shard.plan_threads, 4);

        let parsed = Config::from_json(&cfg.to_json());
        assert_eq!(parsed.shard, cfg.shard);

        // a pathological 0 floors at 1 (the coordinator needs a leader,
        // and planning needs a thread)
        let mut cfg = Config::default();
        let args = Args::parse_from(
            ["simulate", "--leaders", "0", "--plan-threads", "0"]
                .iter()
                .map(|s| s.to_string()),
        );
        cfg.apply_args(&args);
        assert_eq!(cfg.shard.leaders, 1);
        assert_eq!(cfg.shard.plan_threads, 1);
    }

    #[test]
    fn eval_threads_default_parse_and_roundtrip() {
        let cfg = Config::default();
        assert_eq!(cfg.eval.threads, 1); // sequential evaluation harness

        let mut cfg = Config::default();
        let args = Args::parse_from(
            ["trace-compare", "--eval-threads", "4"].iter().map(|s| s.to_string()),
        );
        cfg.apply_args(&args);
        assert_eq!(cfg.eval.threads, 4);
        let parsed = Config::from_json(&cfg.to_json());
        assert_eq!(parsed.eval, cfg.eval);

        // a pathological 0 floors at 1, via flags and via JSON alike
        let mut cfg = Config::default();
        let args = Args::parse_from(
            ["trace-compare", "--eval-threads", "0"].iter().map(|s| s.to_string()),
        );
        cfg.apply_args(&args);
        assert_eq!(cfg.eval.threads, 1);
    }

    #[test]
    fn obs_defaults_parse_and_roundtrip() {
        let cfg = Config::default();
        assert!(cfg.obs.enabled); // collection is on unless opted out
        assert_eq!(cfg.obs.series_cap, 4096);

        let mut cfg = Config::default();
        let args = Args::parse_from(
            ["simulate", "--obs", "false", "--obs-series-cap", "128"]
                .iter()
                .map(|s| s.to_string()),
        );
        cfg.apply_args(&args);
        assert!(!cfg.obs.enabled);
        assert_eq!(cfg.obs.series_cap, 128);
        let parsed = Config::from_json(&cfg.to_json());
        assert_eq!(parsed.obs, cfg.obs);

        // pre-observability trace headers (no "obs" key) keep defaults
        let old_header = Json::parse("{\"seed\": 7}").unwrap();
        let parsed = Config::from_json(&old_header);
        assert_eq!(parsed.obs, ObsCfg::default());

        // a pathological cap floors at 2 so decimation can always halve
        let mut cfg = Config::default();
        let args = Args::parse_from(
            ["simulate", "--obs-series-cap", "0"].iter().map(|s| s.to_string()),
        );
        cfg.apply_args(&args);
        assert_eq!(cfg.obs.series_cap, 2);
    }

    #[test]
    fn shard_assign_kind_spellings() {
        assert_eq!(ShardAssignKind::parse("hash"), Some(ShardAssignKind::Hash));
        assert_eq!(
            ShardAssignKind::parse("round-robin"),
            Some(ShardAssignKind::RoundRobin)
        );
        assert_eq!(ShardAssignKind::parse("rr"), Some(ShardAssignKind::RoundRobin));
        assert_eq!(
            ShardAssignKind::parse("key-affine"),
            Some(ShardAssignKind::KeyAffine)
        );
        assert_eq!(
            ShardAssignKind::parse("affine"),
            Some(ShardAssignKind::KeyAffine)
        );
        assert_eq!(ShardAssignKind::parse("nope"), None);
        assert_eq!(ShardAssignKind::Hash.as_str(), "hash");
        assert_eq!(ShardAssignKind::RoundRobin.as_str(), "round-robin");
        assert_eq!(ShardAssignKind::KeyAffine.as_str(), "key-affine");
    }

    #[test]
    fn key_affine_assign_parses_and_roundtrips() {
        let mut cfg = Config::default();
        let args = Args::parse_from(
            ["simulate", "--leaders", "3", "--shard-assign", "key-affine"]
                .iter()
                .map(|s| s.to_string()),
        );
        cfg.apply_args(&args);
        assert_eq!(cfg.shard.assign, ShardAssignKind::KeyAffine);
        let parsed = Config::from_json(&cfg.to_json());
        assert_eq!(parsed.shard.assign, ShardAssignKind::KeyAffine);
    }

    #[test]
    fn state_slack_defaults_off_parses_and_roundtrips() {
        let cfg = Config::default();
        assert!(!cfg.router.state_slack); // paper's eq. 1 state by default

        let mut cfg = Config::default();
        let args = Args::parse_from(
            ["simulate", "--state-slack"].iter().map(|s| s.to_string()),
        );
        cfg.apply_args(&args);
        assert!(cfg.router.state_slack);

        let parsed = Config::from_json(&cfg.to_json());
        assert!(parsed.router.state_slack);
    }

    #[test]
    fn full_ppo_cfg_roundtrips_through_json() {
        // the trace header must reconstruct the recording run's PPO
        // hyper-parameters exactly — including the ones only JSON (not
        // the CLI) can set — or `repro replay` retrains a different
        // policy than the one the trace documents
        let mut cfg = Config::default();
        cfg.ppo.hidden = vec![32, 16];
        cfg.ppo.clip = 0.3;
        cfg.ppo.c_v = 0.7;
        cfg.ppo.c_h = 0.05; // --entropy
        cfg.ppo.grad_clip = 1.5;
        cfg.ppo.eps_max = 0.4;
        cfg.ppo.eps_min = 0.01;
        cfg.ppo.t_dec = 9999.0;
        cfg.ppo.groups = vec![1, 2, 8];
        cfg.ppo.reward = RewardCfg::overfit(); // bonus/center_acc too
        let parsed = Config::from_json(&cfg.to_json());
        assert_eq!(parsed.ppo, cfg.ppo);
    }

    #[test]
    fn workload_shape_fields_roundtrip_through_json() {
        // the trace header embeds to_json(); replay reconstructs with
        // from_json — burst shape and width mix must survive the trip
        let mut cfg = Config::default();
        cfg.workload.burst_period_s = 4.0;
        cfg.workload.burst_duty = 0.15;
        cfg.workload.width_mix = vec![0.25, 0.25, 0.5];
        let parsed = Config::from_json(&cfg.to_json());
        assert_eq!(parsed.workload.burst_period_s, 4.0);
        assert_eq!(parsed.workload.burst_duty, 0.15);
        assert_eq!(parsed.workload.width_mix, vec![0.25, 0.25, 0.5]);
    }

    #[test]
    fn admission_defaults_parse_and_roundtrip() {
        let cfg = Config::default();
        assert_eq!(cfg.admission.kind, AdmissionKind::None); // pre-PR engine
        assert_eq!(cfg.workload.tenants, 1); // anonymous stream
        assert_eq!(cfg.workload.flash_factor, 1.0); // no flash

        let mut cfg = Config::default();
        let args = Args::parse_from(
            ["simulate", "--tenants", "6", "--tenant-zipf", "1.3",
             "--admission", "drr", "--drr-quantum", "2.5",
             "--drr-burst-cap", "12", "--drr-queue-cap", "64",
             "--drr-cooldown", "8"]
                .iter()
                .map(|s| s.to_string()),
        );
        cfg.apply_args(&args);
        assert_eq!(cfg.workload.tenants, 6);
        assert_eq!(cfg.workload.tenant_zipf, 1.3);
        assert_eq!(cfg.admission.kind, AdmissionKind::Drr);
        assert_eq!(cfg.admission.quantum, 2.5);
        assert_eq!(cfg.admission.burst_cap, 12.0);
        assert_eq!(cfg.admission.queue_cap, 64);
        assert_eq!(cfg.admission.cooldown_ticks, 8);

        let parsed = Config::from_json(&cfg.to_json());
        assert_eq!(parsed.admission, cfg.admission);
        assert_eq!(parsed.workload.tenants, 6);
        assert_eq!(parsed.workload.tenant_zipf, 1.3);

        // a pathological 0 floors at 1 (the workload needs a tenant)
        let mut cfg = Config::default();
        let args = Args::parse_from(
            ["simulate", "--tenants", "0"].iter().map(|s| s.to_string()),
        );
        cfg.apply_args(&args);
        assert_eq!(cfg.workload.tenants, 1);
    }

    #[test]
    fn admission_kind_spellings() {
        assert_eq!(AdmissionKind::parse("none"), Some(AdmissionKind::None));
        assert_eq!(AdmissionKind::parse("off"), Some(AdmissionKind::None));
        assert_eq!(AdmissionKind::parse("drr"), Some(AdmissionKind::Drr));
        assert_eq!(AdmissionKind::parse("nope"), None);
        assert_eq!(AdmissionKind::None.as_str(), "none");
        assert_eq!(AdmissionKind::Drr.as_str(), "drr");
    }

    #[test]
    fn controller_kind_spellings() {
        assert_eq!(ControllerKind::parse("none"), Some(ControllerKind::None));
        assert_eq!(ControllerKind::parse("off"), Some(ControllerKind::None));
        assert_eq!(
            ControllerKind::parse("backlog"),
            Some(ControllerKind::Backlog)
        );
        assert_eq!(ControllerKind::parse("nope"), None);
        assert_eq!(ControllerKind::None.as_str(), "none");
        assert_eq!(ControllerKind::Backlog.as_str(), "backlog");
    }

    #[test]
    fn controller_defaults_parse_and_roundtrip() {
        let cfg = Config::default();
        assert_eq!(cfg.ctrl.controller, ControllerKind::None); // pinned knobs

        let mut cfg = Config::default();
        let args = Args::parse_from(
            ["simulate", "--controller", "backlog"].iter().map(|s| s.to_string()),
        );
        cfg.apply_args(&args);
        assert_eq!(cfg.ctrl.controller, ControllerKind::Backlog);

        let parsed = Config::from_json(&cfg.to_json());
        assert_eq!(parsed.ctrl, cfg.ctrl);

        // pre-control-plane trace headers (no "ctrl" key) keep defaults
        let old_header = Json::parse("{\"seed\": 7}").unwrap();
        let parsed = Config::from_json(&old_header);
        assert_eq!(parsed.ctrl, CtrlCfg::default());
        assert_eq!(parsed.admission.cooldown_ticks, 0);
    }

    #[test]
    #[should_panic(expected = "--controller expects")]
    fn unknown_controller_panics_with_hint() {
        let mut cfg = Config::default();
        let args = Args::parse_from(
            ["simulate", "--controller", "pid"].iter().map(|s| s.to_string()),
        );
        cfg.apply_args(&args);
    }

    #[test]
    fn flash_crowd_fields_roundtrip_through_json() {
        // the trace header embeds to_json(); replay reconstructs with
        // from_json — the flash window must survive or a replayed
        // flash-crowd run regenerates a different arrival process
        let mut cfg = Config::default();
        cfg.workload.tenants = 6;
        cfg.workload.flash_factor = 10.0;
        cfg.workload.flash_start_s = 5.0;
        cfg.workload.flash_end_s = 11.0;
        let parsed = Config::from_json(&cfg.to_json());
        assert_eq!(parsed.workload.flash_factor, 10.0);
        assert_eq!(parsed.workload.flash_start_s, 5.0);
        assert_eq!(parsed.workload.flash_end_s, 11.0);
    }

    #[test]
    fn reward_presets_differ_in_the_right_direction() {
        let overfit = RewardCfg::overfit();
        let balanced = RewardCfg::balanced();
        // overfit punishes latency/energy much harder relative to accuracy
        assert!(overfit.beta / overfit.alpha > balanced.beta / balanced.alpha);
        assert!(overfit.gamma / overfit.alpha > balanced.gamma / balanced.alpha);
    }
}
