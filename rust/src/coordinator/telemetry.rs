//! Telemetry: eq. 1's compact state vector and run-wide sampling.
//!
//! At scheduling step t the router sees
//! `s_t = [q_fifo, c_done, {(q^i, P^i, U^i)}_{i=1..N}]` — global FIFO
//! length and completion count plus per-server queue length, power draw
//! and GPU utilization. `TelemetrySnapshot::to_state_vector` normalizes
//! these into the PPO observation; `TelemetryLog` samples the same values
//! on a fixed tick for the GPU-variance metric (Tables III–V) and the
//! figure regenerators.

use crate::metrics::Summary;

/// Per-server live telemetry.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServerTelemetry {
    pub queue_len: usize,
    pub power_w: f64,
    pub util_pct: f64,
    pub mem_util: f64,
    pub instances: usize,
}

/// Full cluster snapshot at one scheduling step (eq. 1).
#[derive(Clone, Debug, Default)]
pub struct TelemetrySnapshot {
    pub fifo_len: usize,
    pub done_count: u64,
    pub total_requests: usize,
    pub servers: Vec<ServerTelemetry>,
}

impl TelemetrySnapshot {
    /// State dimension for N servers: 2 global + 3 per server, plus one
    /// trailing per-head SLA-slack feature when `state_slack` is on
    /// (`RouterCfg::state_slack` / `--state-slack` — the PPO router
    /// appends the head's clamped slack after the snapshot features, so
    /// the policy input grows by exactly one dimension).
    pub fn state_dim(n_servers: usize, state_slack: bool) -> usize {
        2 + 3 * n_servers + state_slack as usize
    }

    /// Normalized observation vector for the PPO router (the snapshot
    /// part only — the optional slack feature is per-head and appended
    /// by the router).
    pub fn to_state_vector(&self) -> Vec<f64> {
        let mut v = Vec::with_capacity(Self::state_dim(self.servers.len(), false));
        v.push((self.fifo_len as f64 / 64.0).min(4.0));
        v.push(self.done_count as f64 / (self.total_requests.max(1) as f64));
        for s in &self.servers {
            v.push((s.queue_len as f64 / 64.0).min(4.0));
            v.push(s.power_w / 300.0);
            v.push(s.util_pct / 100.0);
        }
        v
    }

    /// Variance of normalized utilizations — eq. 7's imbalance penalty and
    /// the "GPU Var" row of Tables III–V.
    pub fn util_variance(&self) -> f64 {
        if self.servers.is_empty() {
            return 0.0;
        }
        let us: Vec<f64> = self.servers.iter().map(|s| s.util_pct / 100.0).collect();
        let mean = us.iter().sum::<f64>() / us.len() as f64;
        us.iter().map(|u| (u - mean) * (u - mean)).sum::<f64>() / us.len() as f64
    }

    /// Mean power across servers (the paper's E_t = P̄_t · L_t).
    pub fn mean_power_w(&self) -> f64 {
        if self.servers.is_empty() {
            return 0.0;
        }
        self.servers.iter().map(|s| s.power_w).sum::<f64>() / self.servers.len() as f64
    }
}

/// Periodic sampling log: feeds GPU-variance statistics and the Fig 1–3
/// series.
#[derive(Clone, Debug, Default)]
pub struct TelemetryLog {
    pub samples: usize,
    pub util_variance: Summary,
    pub per_server_util: Vec<Summary>,
    pub per_server_mem: Vec<Summary>,
    /// Loaded-instance counts per server, sampled on the same tick —
    /// the paper's instance-scaling mechanism, visible in run output.
    pub per_server_instances: Vec<Summary>,
    /// Per-leader-shard FIFO depth, sampled on the same tick — the
    /// imbalance signal the cross-shard rebalancer acts on (one entry
    /// per shard; the engine sizes this at construction).
    pub shard_depths: Vec<Summary>,
}

impl TelemetryLog {
    pub fn new(n_servers: usize) -> Self {
        TelemetryLog {
            samples: 0,
            util_variance: Summary::default(),
            per_server_util: vec![Summary::default(); n_servers],
            per_server_mem: vec![Summary::default(); n_servers],
            per_server_instances: vec![Summary::default(); n_servers],
            shard_depths: Vec::new(),
        }
    }

    pub fn record(&mut self, snap: &TelemetrySnapshot) {
        self.samples += 1;
        self.util_variance.record(snap.util_variance());
        for (i, s) in snap.servers.iter().enumerate() {
            if i < self.per_server_util.len() {
                self.per_server_util[i].record(s.util_pct);
                self.per_server_mem[i].record(s.mem_util);
                self.per_server_instances[i].record(s.instances as f64);
            }
        }
    }

    /// Record one per-shard FIFO-depth sample (entries beyond the sized
    /// shard count are ignored, mirroring `record`'s server guard).
    pub fn record_shard_depths(&mut self, depths: &[usize]) {
        for (i, &d) in depths.iter().enumerate() {
            if i < self.shard_depths.len() {
                self.shard_depths[i].record(d as f64);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(utils: &[f64]) -> TelemetrySnapshot {
        TelemetrySnapshot {
            fifo_len: 10,
            done_count: 50,
            total_requests: 100,
            servers: utils
                .iter()
                .map(|&u| ServerTelemetry {
                    queue_len: 5,
                    power_w: 100.0 + u,
                    util_pct: u,
                    mem_util: 0.3,
                    instances: 2,
                })
                .collect(),
        }
    }

    #[test]
    fn state_dim_accounts_for_the_optional_slack_feature() {
        assert_eq!(TelemetrySnapshot::state_dim(3, false), 11);
        assert_eq!(TelemetrySnapshot::state_dim(3, true), 12);
        assert_eq!(
            TelemetrySnapshot::state_dim(5, true),
            TelemetrySnapshot::state_dim(5, false) + 1
        );
    }

    #[test]
    fn state_vector_dimension_and_normalization() {
        let s = snap(&[50.0, 80.0, 20.0]);
        let v = s.to_state_vector();
        assert_eq!(v.len(), TelemetrySnapshot::state_dim(3, false));
        assert!(v.iter().all(|x| x.is_finite()));
        // util entries normalized to [0,1]
        assert!((v[4] - 0.5).abs() < 1e-12);
        assert!((v[7] - 0.8).abs() < 1e-12);
        assert!((v[10] - 0.2).abs() < 1e-12);
        // done fraction
        assert!((v[1] - 0.5).abs() < 1e-12);
    }

    #[test]
    fn util_variance_zero_when_balanced() {
        assert!(snap(&[60.0, 60.0, 60.0]).util_variance() < 1e-15);
        let v = snap(&[0.0, 100.0, 50.0]).util_variance();
        // var of {0, 1, 0.5} = 0.1666…
        assert!((v - 1.0 / 6.0).abs() < 1e-9, "{v}");
    }

    #[test]
    fn mean_power() {
        let s = snap(&[0.0, 100.0]);
        assert!((s.mean_power_w() - 150.0).abs() < 1e-12);
    }

    #[test]
    fn log_accumulates() {
        let mut log = TelemetryLog::new(2);
        log.record(&snap(&[10.0, 90.0]));
        log.record(&snap(&[50.0, 50.0]));
        assert_eq!(log.samples, 2);
        assert!(log.util_variance.mean() > 0.0);
        assert!((log.per_server_util[0].mean() - 30.0).abs() < 1e-9);
        assert!((log.per_server_util[1].mean() - 70.0).abs() < 1e-9);
        // instance counts are logged too (snap() pins 2 per server)
        assert_eq!(log.per_server_instances.len(), 2);
        assert!((log.per_server_instances[0].mean() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn shard_depths_record_when_sized() {
        let mut log = TelemetryLog::new(1);
        // unsized: samples are ignored, not panicking
        log.record_shard_depths(&[5, 9]);
        assert!(log.shard_depths.is_empty());
        log.shard_depths = vec![Summary::default(); 2];
        log.record_shard_depths(&[4, 8]);
        log.record_shard_depths(&[6, 10]);
        assert!((log.shard_depths[0].mean() - 5.0).abs() < 1e-12);
        assert!((log.shard_depths[1].mean() - 9.0).abs() < 1e-12);
        // extra entries beyond the sized count are dropped
        log.record_shard_depths(&[1, 1, 99]);
        assert_eq!(log.shard_depths.len(), 2);
    }

    #[test]
    fn fifo_clamp_keeps_state_bounded() {
        let mut s = snap(&[50.0]);
        s.fifo_len = 100_000;
        let v = s.to_state_vector();
        assert!(v[0] <= 4.0);
    }
}
