//! Multi-leader sharding of the global FIFO.
//!
//! The paper's hierarchy has **one** leader holding the global FIFO and
//! the router, which caps the whole reproduction at single-leader
//! routing throughput. This module splits the leader tier into N shards:
//! each [`LeaderShard`] owns a slice of the global FIFO, a router
//! replica (algorithmic routers are cloned; the PPO router is shared
//! across shards behind `ppo::SharedPpoRouter`, so training still sees
//! every shard's transitions in one rollout buffer), a routing-capacity
//! clock, and per-shard telemetry counters.
//!
//! * [`ShardAssign`] — deterministic request→shard placement, with
//!   [`HashAssign`] (pure function of the request id),
//!   [`RoundRobinAssign`] (cursor in enqueue order) and
//!   [`KeyAffineAssign`] (pure function of the batch key `(seg, w_req)`,
//!   concentrating same-key runs on one leader) behind it. All are
//!   pure functions of the (seeded, deterministic) event stream, so
//!   sharded runs stay reproducible across `--workers` counts.
//! * [`rebalance`] — the optional cross-shard step: when the deepest and
//!   shallowest FIFOs differ by more than a threshold, whole
//!   same-segment head runs migrate deepest → shallowest.
//! * [`global_tag`] / [`split_tag`] — per-shard routers keep their own
//!   tag counters; the engine namespaces them into globally unique
//!   block tags (shard index in the top byte) so the block ledger never
//!   collides. Shard 0 is the identity mapping, which is what keeps
//!   `--leaders 1` bit-identical to the pre-shard engine.
//! * [`sharded_engine`] — the construction entry point: builds an
//!   [`Engine`](super::Engine) whose leader tier carries
//!   `cfg.shard.leaders` replicas of the given router.
//!
//! With `ShardCfg::leader_service_s > 0` each shard's leader is a
//! finite-capacity server (`1/leader_service_s` routed heads per
//! second): planning defers while the leader is busy, backlog accrues in
//! the shard's FIFO slice, and a `LeaderFree` event resumes routing.
//! That is what makes the multi-leader scaling *measurable* — the
//! `shard_scaling` section of the `micro_hotpath` bench reports
//! `leaders4_speedup_x` on the `sharded-hot` scenario. At the default
//! `leader_service_s = 0` the leader is infinitely fast and the engine
//! reproduces the pre-shard event stream exactly.

use std::collections::VecDeque;

use crate::config::{Config, ShardAssignKind};
use crate::sim::SimDevice;
use crate::utilx::Rng;

use super::engine::Engine;
use super::greedy::GreedyScheduler;
use super::queue::head_runs;
use super::request::Request;
use super::router::Router;

/// Shard index occupies the top byte of a block tag; router-local tag
/// counters own the low 56 bits (far beyond any run's decision count).
const TAG_SHARD_SHIFT: u32 = 56;

/// Namespace a router-local decision tag under its shard. Shard 0 is the
/// identity, so single-leader runs keep their historical tag values.
pub fn global_tag(shard: usize, local: u64) -> u64 {
    debug_assert!(local < 1u64 << TAG_SHARD_SHIFT, "local tag overflow");
    ((shard as u64) << TAG_SHARD_SHIFT) | local
}

/// Recover `(shard, local_tag)` from a namespaced block tag.
pub fn split_tag(tag: u64) -> (usize, u64) {
    (
        (tag >> TAG_SHARD_SHIFT) as usize,
        tag & ((1u64 << TAG_SHARD_SHIFT) - 1),
    )
}

/// Dedicated planning RNG stream for shard `si`, a pure function of
/// `(seed, shard)`. The parallel planner (`--plan-threads N`, N ≥ 2)
/// gives each shard's `Router::plan` its own stream so plans are
/// independent of how shards are chunked over threads — any N ≥ 2
/// yields bit-identical runs. Sequential planning (`N = 1`) keeps
/// threading the engine's main RNG instead, preserving the historical
/// event stream byte for byte.
pub fn plan_stream_rng(seed: u64, shard: usize) -> Rng {
    let tag = 0x9e3779b97f4a7c15u64.wrapping_mul(shard as u64 + 1);
    Rng::with_stream(seed ^ tag, 0x7054_11A5u64.wrapping_add(shard as u64))
}

/// Deterministic request→shard placement policy.
pub trait ShardAssign: Send {
    fn name(&self) -> &'static str;
    /// Shard for `req` among `n_shards` (callers guarantee
    /// `n_shards >= 1`; the result must be `< n_shards`).
    fn assign(&mut self, req: &Request, n_shards: usize) -> usize;
}

/// splitmix64 — a well-mixed pure function of the request id, so a
/// request keeps its shard across segments and across runs.
fn mix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e3779b97f4a7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d049bb133111eb);
    x ^ (x >> 31)
}

/// Hash placement: shard = mix64(request id) mod N. Stateless — the
/// same request always lands on the same shard, so a request's four
/// segment routings stay on one leader (no cross-leader handoff).
#[derive(Clone, Copy, Debug, Default)]
pub struct HashAssign;

impl ShardAssign for HashAssign {
    fn name(&self) -> &'static str {
        "hash"
    }
    fn assign(&mut self, req: &Request, n_shards: usize) -> usize {
        (mix64(req.id) % n_shards.max(1) as u64) as usize
    }
}

/// Round-robin placement: a cursor advanced on every enqueue (arrival
/// and segment re-entry alike), in deterministic event order.
#[derive(Clone, Copy, Debug, Default)]
pub struct RoundRobinAssign {
    cursor: usize,
}

impl ShardAssign for RoundRobinAssign {
    fn name(&self) -> &'static str {
        "round-robin"
    }
    fn assign(&mut self, _req: &Request, n_shards: usize) -> usize {
        let n = n_shards.max(1);
        let s = self.cursor % n;
        self.cursor = (self.cursor + 1) % n;
        s
    }
}

/// Batch-key affinity: shard = mix64(segment, requested width) mod N.
/// All requests sharing a batch key land on one leader, so its FIFO
/// grows long same-segment runs — exactly what lets a windowed plan
/// issue large micro-batch groups per decision. Stateless and a pure
/// function of `(seg, w_req)`, so placement is deterministic per seed
/// and worker count; a request *changes* shard as it crosses segments
/// (by design — affinity is to the key, not to the request).
#[derive(Clone, Copy, Debug, Default)]
pub struct KeyAffineAssign;

impl ShardAssign for KeyAffineAssign {
    fn name(&self) -> &'static str {
        "key-affine"
    }
    fn assign(&mut self, req: &Request, n_shards: usize) -> usize {
        let key = ((req.seg as u64) << 32) | super::request::wkey(req.w_req) as u64;
        (mix64(key) % n_shards.max(1) as u64) as usize
    }
}

/// Build the configured assignment policy.
pub fn assigner_for(kind: ShardAssignKind) -> Box<dyn ShardAssign> {
    match kind {
        ShardAssignKind::Hash => Box::new(HashAssign),
        ShardAssignKind::RoundRobin => Box::new(RoundRobinAssign::default()),
        ShardAssignKind::KeyAffine => Box::new(KeyAffineAssign),
    }
}

/// Per-shard telemetry counters (surfaced in `RunOutcome::shard_stats`).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ShardStats {
    /// Requests placed on this shard (arrivals + segment re-entries).
    pub assigned: u64,
    /// FIFO heads routed by this shard's leader.
    pub routed_heads: u64,
    /// Blocks dispatched by this shard's leader.
    pub blocks: u64,
    /// Requests migrated in/out by the cross-shard rebalancer.
    pub migrated_in: u64,
    pub migrated_out: u64,
    /// Plan fields repaired by the explicit clamp path.
    pub plan_clamps: u64,
    /// Peak FIFO depth observed at planning time.
    pub max_depth: usize,
}

/// One leader shard: a slice of the global FIFO plus its router replica.
pub struct LeaderShard<R: Router> {
    pub fifo: VecDeque<Request>,
    pub router: R,
    /// Virtual time until which this shard's leader is busy routing
    /// (only advanced when `leader_service_s > 0`).
    pub busy_until: f64,
    /// Whether a `LeaderFree` wake-up event is already scheduled.
    pub wake_scheduled: bool,
    pub stats: ShardStats,
}

impl<R: Router> LeaderShard<R> {
    pub fn new(router: R) -> Self {
        LeaderShard {
            fifo: VecDeque::new(),
            router,
            busy_until: 0.0,
            wake_scheduled: false,
            stats: ShardStats::default(),
        }
    }
}

/// Cap on run migrations per rebalance invocation (the rebalancer runs
/// on every routing event, so a small budget converges quickly without
/// ever turning one event into an O(backlog) reshuffle).
const MAX_MIGRATIONS_PER_STEP: usize = 4;

/// One migrated head run: which shard it left, which shard now owns it,
/// and the moved requests' `(id, segment)` pairs in FIFO order — what
/// the engine needs to re-attribute the requests' shard placement in
/// the trace (`assign` records) after the move. Block tags need no
/// re-namespacing: tags are minted at *routing* time from the routing
/// shard's counter (`global_tag`), so a migrated run's blocks are
/// namespaced under the destination shard automatically.
#[derive(Clone, Debug, PartialEq)]
pub struct Migration {
    pub from: usize,
    pub to: usize,
    pub ids: Vec<(u64, usize)>,
}

/// Requests moved across all runs of a rebalance step.
pub fn migrated_count(migrations: &[Migration]) -> usize {
    migrations.iter().map(|m| m.ids.len()).sum()
}

/// One cross-shard rebalance step over the leader FIFOs: while the
/// deepest and shallowest FIFOs differ by more than `threshold`
/// requests, migrate the deepest shard's whole same-segment head run to
/// the back of the shallowest FIFO. A run only moves when it is at most
/// half the imbalance (`2·len <= diff`), so the depth gap shrinks but
/// never changes sign — a migration can never invert the imbalance it
/// is fixing (no ping-pong). Ties break on the lowest shard index;
/// migration order is therefore deterministic. Returns one [`Migration`]
/// record per moved run (the engine re-attributes trace placement from
/// them), and records per-shard in/out counters.
pub fn rebalance<R: Router>(
    shards: &mut [LeaderShard<R>],
    threshold: usize,
    run_cap: usize,
) -> Vec<Migration> {
    let mut migrations = Vec::new();
    if threshold == 0 || shards.len() < 2 {
        return migrations;
    }
    for _ in 0..MAX_MIGRATIONS_PER_STEP {
        let deep = (0..shards.len())
            .max_by_key(|&i| (shards[i].fifo.len(), shards.len() - i))
            .unwrap();
        let shallow = (0..shards.len())
            .min_by_key(|&i| (shards[i].fifo.len(), i))
            .unwrap();
        let diff = shards[deep].fifo.len() - shards[shallow].fifo.len();
        if diff <= threshold {
            break;
        }
        let runs = head_runs(&shards[deep].fifo, 1, run_cap);
        let take = match runs.first() {
            Some(run) if 2 * run.len <= diff => run.len,
            _ => break, // whole-run move would invert the gap; leave it
        };
        let moved: Vec<Request> =
            shards[deep].fifo.drain(..take).collect();
        shards[deep].stats.migrated_out += take as u64;
        shards[shallow].stats.migrated_in += take as u64;
        migrations.push(Migration {
            from: deep,
            to: shallow,
            ids: moved.iter().map(|r| (r.id, r.seg)).collect(),
        });
        shards[shallow].fifo.extend(moved);
    }
    migrations
}

/// The multi-leader coordinator. Since the shard refactor the engine
/// itself is shard-structured — `Engine::new` is simply the one-shard
/// special case — so `ShardedEngine` is `Engine` viewed through its
/// multi-leader construction path ([`sharded_engine`] /
/// [`Engine::with_shard_parts`]).
pub type ShardedEngine<R, D = SimDevice, S = GreedyScheduler> = Engine<R, D, S>;

/// Build a [`ShardedEngine`] whose leader tier is sharded per
/// `cfg.shard`: the router is replicated once per leader (`leaders <= 1`
/// yields the classic single-leader engine, bit-identical per seed to
/// `Engine::new`). Algorithmic routers clone cheaply; for PPO pass a
/// `ppo::SharedPpoRouter`, whose clones share one policy and rollout
/// buffer.
pub fn sharded_engine<R: Router + Clone>(cfg: Config, router: R) -> ShardedEngine<R> {
    let n = cfg.shard.leaders.max(1);
    let mut routers = Vec::with_capacity(n);
    for _ in 0..n.saturating_sub(1) {
        routers.push(router.clone());
    }
    routers.push(router);
    let (devices, scheds) = super::engine::default_parts(&cfg);
    Engine::with_shard_parts(cfg, routers, devices, scheds)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::router::RandomRouter;

    fn req(id: u64, seg: usize) -> Request {
        let mut r = Request::new(id, 0.0, 1.0);
        r.seg = seg;
        r
    }

    fn shard_of_segs(segs: &[usize], base_id: u64) -> LeaderShard<RandomRouter> {
        let mut sh = LeaderShard::new(RandomRouter::new(
            vec![0.25, 0.5, 0.75, 1.0],
            false,
            4,
        ));
        for (i, &seg) in segs.iter().enumerate() {
            sh.fifo.push_back(req(base_id + i as u64, seg));
        }
        sh
    }

    #[test]
    fn tag_namespace_roundtrips_and_shard0_is_identity() {
        assert_eq!(global_tag(0, 12345), 12345);
        assert_eq!(split_tag(12345), (0, 12345));
        for shard in [0usize, 1, 3, 7] {
            for local in [0u64, 1, 999_999] {
                assert_eq!(split_tag(global_tag(shard, local)), (shard, local));
            }
        }
    }

    #[test]
    fn hash_assign_is_a_pure_function_of_the_id() {
        // determinism across instances and call order — the property
        // that keeps sharded runs reproducible across --workers counts
        let mut a = HashAssign;
        let mut b = HashAssign;
        let forward: Vec<usize> =
            (0..64u64).map(|id| a.assign(&req(id, 0), 4)).collect();
        let backward: Vec<usize> =
            (0..64u64).rev().map(|id| b.assign(&req(id, 0), 4)).collect();
        let backward: Vec<usize> = backward.into_iter().rev().collect();
        assert_eq!(forward, backward);
        // covers every shard and respects the range
        assert!(forward.iter().all(|&s| s < 4));
        for s in 0..4 {
            assert!(forward.contains(&s), "shard {s} never hit");
        }
        // one shard degenerates to 0
        assert_eq!(a.assign(&req(7, 2), 1), 0);
    }

    #[test]
    fn hash_assign_is_stable_across_segments() {
        let mut a = HashAssign;
        for id in 0..32u64 {
            let home = a.assign(&req(id, 0), 4);
            for seg in 1..4 {
                assert_eq!(a.assign(&req(id, seg), 4), home, "id {id} seg {seg}");
            }
        }
    }

    #[test]
    fn round_robin_assign_cycles() {
        let mut rr = RoundRobinAssign::default();
        let got: Vec<usize> =
            (0..7u64).map(|id| rr.assign(&req(id, 0), 3)).collect();
        assert_eq!(got, vec![0, 1, 2, 0, 1, 2, 0]);
        assert_eq!(rr.assign(&req(9, 0), 1), 0);
    }

    #[test]
    fn assigner_for_builds_the_named_policy() {
        assert_eq!(assigner_for(ShardAssignKind::Hash).name(), "hash");
        assert_eq!(
            assigner_for(ShardAssignKind::RoundRobin).name(),
            "round-robin"
        );
        assert_eq!(
            assigner_for(ShardAssignKind::KeyAffine).name(),
            "key-affine"
        );
    }

    #[test]
    fn key_affine_concentrates_same_key_requests_on_one_shard() {
        let mut a = KeyAffineAssign;
        // every request with the same (seg, w_req) lands on one shard,
        // regardless of request id
        let mut r1 = req(1, 2);
        r1.w_req = 0.5;
        let home = a.assign(&r1, 4);
        for id in 2..40u64 {
            let mut r = req(id, 2);
            r.w_req = 0.5;
            assert_eq!(a.assign(&r, 4), home, "id {id}");
        }
        // distinct keys spread: over the 4 segments × 4 widths key grid
        // at least two shards are hit (16 keys over 4 shards)
        let mut seen = std::collections::BTreeSet::new();
        for seg in 0..4usize {
            for &w in &[0.25, 0.5, 0.75, 1.0] {
                let mut r = req(99, seg);
                r.w_req = w;
                let s = a.assign(&r, 4);
                assert!(s < 4);
                seen.insert(s);
            }
        }
        assert!(seen.len() >= 2, "all 16 keys collapsed onto {seen:?}");
        // one shard degenerates to 0
        assert_eq!(a.assign(&req(7, 1), 1), 0);
    }

    #[test]
    fn key_affine_moves_requests_between_shards_across_segments() {
        // affinity is to the batch key, not the request: as a request
        // advances through segments its shard may change; what must hold
        // is that the mapping is a pure function of (seg, w_req)
        let mut a = KeyAffineAssign;
        let mut b = KeyAffineAssign;
        for seg in 0..4usize {
            let mut r = req(5, seg);
            r.w_req = 0.75;
            assert_eq!(a.assign(&r, 8), b.assign(&r, 8));
        }
    }

    #[test]
    fn rebalance_migrates_a_whole_head_run_deep_to_shallow() {
        // shard 0: deep, head run of three seg-1 entries; shard 1 shallow
        let mut shards = vec![
            shard_of_segs(&[1, 1, 1, 0, 2, 0, 1, 2], 0),
            shard_of_segs(&[3], 100),
        ];
        let migrations = rebalance(&mut shards, 2, 64);
        assert_eq!(migrated_count(&migrations), 3);
        // the migration record names source, destination, and the moved
        // requests in FIFO order — the trace re-attribution inputs
        assert_eq!(migrations.len(), 1);
        assert_eq!(migrations[0].from, 0);
        assert_eq!(migrations[0].to, 1);
        assert_eq!(migrations[0].ids, vec![(0, 1), (1, 1), (2, 1)]);
        assert_eq!(shards[0].stats.migrated_out, 3);
        assert_eq!(shards[1].stats.migrated_in, 3);
        // the run landed at the back of the shallow fifo, in order
        let tail: Vec<u64> =
            shards[1].fifo.iter().map(|r| r.id).collect();
        assert_eq!(tail, vec![100, 0, 1, 2]);
        // conservation
        assert_eq!(shards[0].fifo.len() + shards[1].fifo.len(), 9);
    }

    #[test]
    fn rebalance_noop_below_threshold_or_single_shard() {
        let mut shards = vec![
            shard_of_segs(&[0, 0, 1], 0),
            shard_of_segs(&[2], 10),
        ];
        // diff = 2, threshold 2: not strictly above, no move
        assert!(rebalance(&mut shards, 2, 64).is_empty());
        // threshold 0 disables
        assert!(rebalance(&mut shards, 0, 64).is_empty());
        let mut one = vec![shard_of_segs(&[0, 0, 0, 0], 0)];
        assert!(rebalance(&mut one, 1, 64).is_empty());
    }

    #[test]
    fn rebalance_never_inverts_the_imbalance() {
        // deep shard's head run (5) >= diff (5): whole-run move would
        // overshoot, so the rebalancer leaves it alone
        let mut shards = vec![
            shard_of_segs(&[2, 2, 2, 2, 2], 0),
            shard_of_segs(&[], 50),
        ];
        assert!(rebalance(&mut shards, 2, 64).is_empty());
        assert_eq!(shards[0].fifo.len(), 5);

        // a shorter head run (2) < diff (5) does migrate
        let mut shards = vec![
            shard_of_segs(&[1, 1, 2, 2, 2], 0),
            shard_of_segs(&[], 50),
        ];
        assert_eq!(migrated_count(&rebalance(&mut shards, 2, 64)), 2);
        assert!(shards[0].fifo.len() >= shards[1].fifo.len());
    }

    #[test]
    fn rebalance_is_budgeted_per_step() {
        // many length-1 runs: one step migrates at most
        // MAX_MIGRATIONS_PER_STEP runs
        let segs: Vec<usize> = (0..40).map(|i| i % 4).collect();
        let mut shards = vec![
            shard_of_segs(&segs, 0),
            shard_of_segs(&[], 100),
        ];
        let moved = rebalance(&mut shards, 1, 64);
        assert!(moved.len() <= MAX_MIGRATIONS_PER_STEP);
        assert!(migrated_count(&moved) > 0);
    }
}
