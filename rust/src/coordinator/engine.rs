//! The multi-server discrete-event engine.
//!
//! Binds the whole hierarchy together exactly as the paper describes it:
//! a leader holds the global FIFO and the router (PPO or algorithmic);
//! every routed block crosses the WLAN link to its target server, whose
//! local greedy scheduler (Algorithm 1) batches it onto a loaded instance
//! of the simulated GPU. Block completions feed reward signals back to
//! the router — the training loop of §III-B and the measurement loop of
//! Tables III–V are the same code path.
//!
//! The event heap, block ledger and metric accumulators live in
//! [`super::core`]; the router, per-server scheduler and device model
//! attach through the [`Router`], [`LocalScheduler`] and [`DeviceModel`]
//! traits, so the engine itself is just the event loop plus the routing
//! glue. An engine is plain data and `Send` — `ppo::parallel` constructs
//! one per worker thread for concurrent rollouts.
//!
//! Virtual time (discrete events) makes a 20 k-request cluster run finish
//! in tens of milliseconds, so PPO training over hundreds of thousands of
//! scheduling steps is practical on one CPU.

use std::collections::VecDeque;

use crate::config::Config;
use crate::metrics::{RunReport, Summary};
use crate::model::{AccuracyPrior, ModelMeta, NUM_SEGMENTS};
use crate::sim::{profiles, Link, SimDevice, VirtualClock, Workload};
use crate::utilx::Rng;

use super::core::{BlockLedger, BlockState, DeviceModel, EventQueue, LocalScheduler, RunMetrics};
use super::greedy::{Dispatch, GreedyScheduler, GreedyStats};
use super::queue::{head_runs, HeadRun, Queued};
use super::request::Request;
use super::router::{width_eq, BlockFeedback, HeadView, PlanError, Router};
use super::telemetry::{ServerTelemetry, TelemetryLog, TelemetrySnapshot};

const TELEMETRY_DT: f64 = 0.05;
const UNLOAD_DT: f64 = 0.5;
/// Per-run scan budget for windowed head discovery — comfortably above
/// every micro-batch group size in use (≤ 16), so it never shortens a
/// block, while keeping each planning event's FIFO scan bounded at
/// `route_window · RUN_SCAN_CAP` entries on deep same-segment backlogs.
const RUN_SCAN_CAP: usize = 64;

/// Event kinds (ordering by time, then sequence — see `core::EventQueue`).
#[derive(Debug)]
enum EvKind {
    Arrival(Request),
    BlockArrive { server: usize, entries: Vec<Queued> },
    BatchDone { server: usize, device_batch: u64, dispatch: Dispatch },
    TelemetryTick,
    UnloadTick,
    /// Mid-run failure injection: the server stops accepting work
    /// (scenario `dropout`; `Config::dropout`).
    DeviceDown { server: usize },
}

/// Everything a finished run reports.
#[derive(Clone, Debug)]
pub struct RunOutcome {
    pub report: RunReport,
    /// End-to-end (arrival → final segment) request latency.
    pub e2e_latency: Summary,
    pub telemetry: TelemetryLog,
    pub greedy_stats: Vec<GreedyStats>,
    /// Executed-width histogram over all segment executions, keyed by
    /// the scenario's width set: `(width, count)` pairs in W order, so
    /// scenarios with |W| ≠ 4 report correctly.
    pub width_histogram: Vec<(f64, u64)>,
    pub blocks_completed: u64,
    pub sim_duration_s: f64,
    /// Total cluster energy (J) integrated over the run.
    pub total_energy_j: f64,
}

impl RunOutcome {
    /// Total segment executions across all widths.
    pub fn width_execs(&self) -> u64 {
        self.width_histogram.iter().map(|&(_, c)| c).sum()
    }

    /// Executions at exactly width `w` (0 when `w` is not in W).
    pub fn width_count(&self, w: f64) -> u64 {
        self.width_histogram
            .iter()
            .find(|&&(x, _)| width_eq(x, w))
            .map(|&(_, c)| c)
            .unwrap_or(0)
    }

    /// Fraction of executions at widths ≤ `w` (0 when nothing executed).
    pub fn width_frac_at_most(&self, w: f64) -> f64 {
        let total = self.width_execs();
        if total == 0 {
            return 0.0;
        }
        let at_most: u64 = self
            .width_histogram
            .iter()
            .filter(|&&(x, _)| x <= w + 1e-9)
            .map(|&(_, c)| c)
            .sum();
        at_most as f64 / total as f64
    }
}

/// The engine itself — generic over the router (so trained PPO routers
/// can be recovered after a run; `Box<dyn Router>` also implements
/// [`Router`] for dynamic use), the device model, and the per-server
/// scheduler. The defaults are the paper configuration: simulated GPUs
/// driven by Algorithm 1.
pub struct Engine<R: Router, D: DeviceModel = SimDevice, S: LocalScheduler = GreedyScheduler> {
    pub cfg: Config,
    pub meta: ModelMeta,
    prior: AccuracyPrior,
    devices: Vec<D>,
    scheds: Vec<S>,
    link: Link,
    router: R,
    global_fifo: VecDeque<Request>,
    ledger: BlockLedger,
    events: EventQueue<EvKind>,
    clock: VirtualClock,
    rng: Rng,
    metrics: RunMetrics,
    /// Servers knocked out by a `DeviceDown` event.
    down: Vec<bool>,
    /// Safety cap for pathological configurations.
    pub max_sim_time_s: f64,
}

impl<R: Router> Engine<R> {
    /// Standard construction: device profiles resolved by name, one
    /// greedy scheduler per device.
    pub fn new(cfg: Config, router: R) -> Self {
        let meta = ModelMeta::default();
        let devices: Vec<SimDevice> = cfg
            .devices
            .iter()
            .map(|name| {
                SimDevice::new(
                    profiles::by_name(name)
                        .unwrap_or_else(|| panic!("unknown device profile {name}")),
                )
            })
            .collect();
        let scheds = devices
            .iter()
            .map(|_| GreedyScheduler::new(cfg.scheduler.clone(), meta.clone()))
            .collect();
        Engine::with_parts(cfg, router, devices, scheds)
    }
}

impl<R: Router, D: DeviceModel, S: LocalScheduler> Engine<R, D, S> {
    /// Assemble an engine from explicit parts (custom device models or
    /// scheduling policies).
    pub fn with_parts(cfg: Config, router: R, devices: Vec<D>, scheds: Vec<S>) -> Self {
        assert_eq!(devices.len(), scheds.len(), "one scheduler per device");
        assert!(!devices.is_empty(), "engine needs at least one device");
        let n = devices.len();
        let total = cfg.workload.total_requests;
        Engine {
            link: Link::new(cfg.link),
            rng: Rng::new(cfg.seed),
            meta: ModelMeta::default(),
            prior: AccuracyPrior::new(),
            devices,
            scheds,
            router,
            global_fifo: VecDeque::new(),
            ledger: BlockLedger::new(),
            events: EventQueue::new(),
            clock: VirtualClock::new(),
            metrics: RunMetrics::new(n, total, cfg.scheduler.widths.len()),
            down: vec![false; n],
            max_sim_time_s: 3600.0,
            cfg,
        }
    }

    fn push_event(&mut self, t: f64, kind: EvKind) {
        self.events.push(t, kind);
    }

    /// eq. 1 snapshot of the cluster. A downed server reports a
    /// saturated-and-powerless signature (util 100 %, huge queue, zero
    /// power) so telemetry-driven routers — LeastLoaded's load score,
    /// the PPO state vector — steer away from it instead of seeing an
    /// attractive idle machine; `alive_server` remains the safety net.
    fn snapshot(&self) -> TelemetrySnapshot {
        TelemetrySnapshot {
            fifo_len: self.global_fifo.len(),
            done_count: self.metrics.done,
            total_requests: self.metrics.total,
            servers: self
                .devices
                .iter()
                .zip(&self.scheds)
                .zip(&self.down)
                .map(|((d, s), &down)| {
                    if down {
                        ServerTelemetry {
                            queue_len: usize::MAX,
                            power_w: 0.0,
                            util_pct: 100.0,
                            mem_util: 0.0,
                            instances: 0,
                        }
                    } else {
                        ServerTelemetry {
                            queue_len: s.queue_len(),
                            power_w: d.power_w(),
                            util_pct: d.util_pct(),
                            mem_util: d.mem_util(),
                            instances: s.instances_loaded(),
                        }
                    }
                })
                .collect(),
        }
    }

    fn width_index(&self, w: f64) -> usize {
        self.cfg
            .scheduler
            .widths
            .iter()
            .position(|&x| width_eq(x, w))
            .unwrap_or(0)
    }

    /// First alive server at or cyclically after `want` (dropout remap;
    /// identity while every server is up).
    fn alive_server(&self, want: usize) -> usize {
        if !self.down[want] {
            return want;
        }
        let n = self.devices.len();
        (1..n)
            .map(|k| (want + k) % n)
            .find(|&i| !self.down[i])
            .unwrap_or(want)
    }

    /// Route every request waiting at the leader: present up to
    /// `RouterCfg::route_window` FIFO heads (one per consecutive
    /// same-segment run) to a single `Router::plan` call, apply the plan
    /// atomically, repeat until the FIFO drains. With `route_window = 1`
    /// this is the pre-plan per-head loop, bit-identical per seed.
    fn route_pending(&mut self) {
        let window = self.cfg.router.route_window.max(1);
        while !self.global_fifo.is_empty() {
            let snap = self.snapshot();
            let now = self.clock.now();
            let runs = if window == 1 {
                // fast path: the single head needs no run-length scan —
                // block extraction below is bounded by the segment check,
                // so a deep same-segment backlog costs O(group), not
                // O(backlog), per routing event
                let front = &self.global_fifo[0];
                vec![HeadRun { start: 0, len: usize::MAX, seg: front.seg }]
            } else {
                head_runs(&self.global_fifo, window, RUN_SCAN_CAP)
            };
            let heads: Vec<HeadView> = runs
                .iter()
                .map(|run| {
                    let req = &self.global_fifo[run.start];
                    let age = now - req.arrival;
                    HeadView {
                        fifo_index: run.start,
                        w_req: req.w_req,
                        seg: run.seg,
                        age_s: age,
                        slack_s: self.cfg.router.sla_s - age,
                    }
                })
                .collect();

            let plan = self.router.plan(&snap, &heads, &mut self.rng);
            let plan = match plan.validate(
                heads.len(),
                self.devices.len(),
                &self.cfg.scheduler.widths,
            ) {
                // the common case: a valid plan passes through untouched
                // (seeds stay bit-identical)
                Ok(()) => plan,
                // arity is a router contract violation, not routable data
                Err(e @ PlanError::WrongArity { .. }) => {
                    panic!("router {}: {e}", self.router.name())
                }
                // out-of-range servers/widths/groups are repairable:
                // clamp explicitly instead of indexing out of bounds
                Err(_) => {
                    plan.clamp(self.devices.len(), &self.cfg.scheduler.widths).0
                }
            };
            let decisions = plan.into_decisions();

            // apply atomically: one ranged drain per decision (up to
            // `group` members of each head's run), processed back to
            // front so earlier runs' offsets stay valid; sub-group
            // leftovers never leave the queue
            let mut blocks: Vec<Vec<Queued>> =
                Vec::with_capacity(decisions.len());
            for k in (0..decisions.len()).rev() {
                let run = &runs[k];
                let d = &decisions[k];
                let want = d.group.max(1);
                // count this block's members (consecutive same-segment
                // entries from the run start, capped by the group)
                let mut take = 0usize;
                while take < want
                    && take < run.len
                    && self
                        .global_fifo
                        .get(run.start + take)
                        .map_or(false, |r| r.seg == run.seg)
                {
                    take += 1;
                }
                let entries: Vec<Queued> = self
                    .global_fifo
                    .drain(run.start..run.start + take)
                    .map(|mut req| {
                        req.block_tag = d.tag;
                        req.routed_at = now;
                        req.enqueued_at = now;
                        Queued { req, width: d.width }
                    })
                    .collect();
                blocks.push(entries);
            }
            blocks.reverse();

            for ((decision, run), entries) in
                decisions.iter().zip(&runs).zip(blocks)
            {
                debug_assert!(!entries.is_empty());
                let head_seg = run.seg;

                // representative tuple for the partial-accuracy prior:
                // executed widths so far, this block's width for the
                // current segment, nearest-neighbour for the rest.
                let mut tuple = [decision.width; NUM_SEGMENTS];
                for s in 0..head_seg {
                    tuple[s] = entries[0].req.widths_used[s];
                }

                self.ledger.open(
                    decision.tag,
                    BlockState {
                        routed_at: now,
                        remaining: entries.len(),
                        width: decision.width,
                        seg: head_seg,
                        tuple,
                    },
                );

                let server = self
                    .alive_server(decision.server.min(self.devices.len() - 1));

                // WLAN transfer: charge the slowest member of the block
                let mut arrive = now;
                for q in &entries {
                    let bytes = if head_seg == 0 {
                        // input image
                        (self.meta.img * self.meta.img * self.meta.in_ch * 4) as u64
                    } else {
                        let (inp, _) = self.meta.seg_io_shapes(head_seg, 1);
                        (inp.iter().product::<usize>() * 4) as u64
                    };
                    let dt = match q.req.last_server {
                        Some(s) if s == server => self.link.local_s(),
                        _ => self.link.transfer_s(bytes, &mut self.rng),
                    };
                    arrive = arrive.max(now + dt);
                }
                self.push_event(arrive, EvKind::BlockArrive { server, entries });
            }
        }
    }

    /// Run the scheduler on one server and execute its dispatches.
    fn pump_server(&mut self, server: usize) {
        if self.down[server] {
            return;
        }
        let now = self.clock.now();
        let dispatches = {
            let dev = &mut self.devices[server];
            self.scheds[server].step(now, dev)
        };
        for d in dispatches {
            // semantic cost of the batch: per-request FLOPs at the
            // instance's width and the request's true w_prev
            let flops: u64 = d
                .batch
                .iter()
                .map(|q| {
                    self.meta
                        .seg_flops(d.key.seg, d.width, q.req.w_prev, 1)
                })
                .sum();
            let mem = (self.meta.seg_mem_bytes(d.key.seg, d.batch.len()) as f64
                * d.width) as u64;
            let start = now + d.load_penalty_s;
            let (device_batch, finish) = self.devices[server].begin_batch(
                start,
                flops,
                mem,
                d.batch.len(),
                d.width,
            );
            self.push_event(
                finish,
                EvKind::BatchDone { server, device_batch, dispatch: d },
            );
        }
    }

    fn handle_batch_done(&mut self, server: usize, device_batch: u64, d: Dispatch) {
        let now = self.clock.now();
        self.devices[server].finish_batch(now, device_batch);
        self.scheds[server].complete(d.instance_id, now);
        self.metrics.width_histogram[self.width_index(d.width)] +=
            d.batch.len() as u64;

        let snap = self.snapshot();
        for q in d.batch {
            let mut req = q.req;
            let tag = req.block_tag;
            if let Some(block) = self.ledger.note_done(tag) {
                let latency = now - block.routed_at;
                let energy = snap.mean_power_w() * latency;
                self.metrics.record_block(latency, energy);
                let fb = BlockFeedback {
                    tag,
                    acc_prior_norm: self.prior.normalized(&block.tuple),
                    latency_s: latency,
                    energy_j: energy,
                    util_variance: snap.util_variance(),
                };
                self.router.feedback(&fb);
            }

            if req.advance(d.width, now, server) {
                self.global_fifo.push_back(req);
            } else {
                let acc = self.prior.lookup(&req.width_tuple());
                self.metrics.record_request_done(now - req.arrival, acc);
            }
        }
        // freed instance may unblock queued batches
        self.pump_server(server);
        // requests that advanced need routing
        self.route_pending();
    }

    /// Re-admit requests whose routed block never executed (device
    /// dropout): abandon their old decision tags — close the ledger
    /// entries and let a learning router drop the staged transitions
    /// (no reward will ever arrive for them) — then re-route.
    fn readmit(&mut self, entries: Vec<Queued>) {
        for q in entries {
            let tag = q.req.block_tag;
            if self.ledger.abandon(tag).is_some() {
                self.router.abandon(tag);
            }
            self.global_fifo.push_back(q.req);
        }
        self.route_pending();
    }

    /// A server goes offline: settle its energy at the failure instant
    /// (a dead machine draws nothing afterwards), stop dispatching
    /// there, and hand its queued requests back to the leader for
    /// re-routing. In-flight batches are allowed to finish (their
    /// `BatchDone` events are already scheduled).
    fn handle_device_down(&mut self, server: usize) {
        let now = self.clock.now();
        self.devices[server].integrate_to(now);
        self.down[server] = true;
        let drained = self.scheds[server].drain_queue();
        self.readmit(drained);
    }

    /// Run the configured workload to completion; returns the outcome.
    pub fn run(self) -> RunOutcome {
        self.run_returning_router().0
    }

    /// Like [`Engine::run`] but hands the router back — used to train a
    /// PPO router across multiple episodes and then freeze it for
    /// evaluation.
    pub fn run_returning_router(mut self) -> (RunOutcome, R) {
        let mut workload = Workload::new(
            self.cfg.workload.clone(),
            &self.cfg.scheduler.widths,
            self.rng.split(0xA11),
        );
        if let Some(first) = workload.next_event() {
            let req = Request::new(first.request_id, first.at, first.w_req);
            self.push_event(first.at, EvKind::Arrival(req));
        }
        self.push_event(TELEMETRY_DT, EvKind::TelemetryTick);
        self.push_event(UNLOAD_DT, EvKind::UnloadTick);
        if let Some(dp) = self.cfg.dropout {
            if dp.server < self.devices.len() {
                self.push_event(
                    dp.at_s.max(0.0),
                    EvKind::DeviceDown { server: dp.server },
                );
            }
        }

        while let Some((t, ev)) = self.events.pop() {
            if t > self.max_sim_time_s {
                break;
            }
            self.clock.advance_to(t);
            match ev {
                EvKind::Arrival(req) => {
                    self.global_fifo.push_back(req);
                    if let Some(next) = workload.next_event() {
                        let r = Request::new(next.request_id, next.at, next.w_req);
                        self.push_event(next.at, EvKind::Arrival(r));
                    }
                    self.route_pending();
                }
                EvKind::BlockArrive { server, entries } => {
                    if self.down[server] {
                        // the block raced the dropout: re-route its members
                        self.readmit(entries);
                    } else {
                        for q in entries {
                            self.scheds[server].enqueue(q);
                        }
                        self.pump_server(server);
                    }
                }
                EvKind::BatchDone { server, device_batch, dispatch } => {
                    self.handle_batch_done(server, device_batch, dispatch);
                }
                EvKind::TelemetryTick => {
                    let now = self.clock.now();
                    for (d, &down) in self.devices.iter_mut().zip(&self.down) {
                        // a dead server's energy is settled at the
                        // failure instant, not accrued forever
                        if !down {
                            d.integrate_to(now);
                        }
                    }
                    let snap = self.snapshot();
                    self.metrics.telemetry_log.record(&snap);
                    if !self.metrics.all_done() {
                        self.push_event(now + TELEMETRY_DT, EvKind::TelemetryTick);
                    }
                }
                EvKind::UnloadTick => {
                    let now = self.clock.now();
                    for i in 0..self.scheds.len() {
                        let dev = &mut self.devices[i];
                        self.scheds[i].unload_idle(now, dev);
                        // unloads may free VRAM another key was waiting for
                    }
                    for i in 0..self.scheds.len() {
                        self.pump_server(i);
                    }
                    if !self.metrics.all_done() {
                        self.push_event(now + UNLOAD_DT, EvKind::UnloadTick);
                    }
                }
                EvKind::DeviceDown { server } => {
                    self.handle_device_down(server);
                }
            }
            if self.metrics.all_done() {
                // drain: all requests served
                break;
            }
        }
        self.router.end_of_run();

        let now = self.clock.now();
        for (d, &down) in self.devices.iter_mut().zip(&self.down) {
            if !down {
                d.integrate_to(now);
            }
        }
        let total_energy: f64 = self.devices.iter().map(|d| d.energy_j()).sum();
        let greedy_stats: Vec<GreedyStats> =
            self.scheds.iter().map(|s| s.stats()).collect();
        let m = self.metrics;
        let width_histogram: Vec<(f64, u64)> = self
            .cfg
            .scheduler
            .widths
            .iter()
            .cloned()
            .zip(m.width_histogram.iter().cloned())
            .collect();
        let outcome = RunOutcome {
            report: RunReport {
                label: self.router.name().to_string(),
                accuracy_pct: m.mean_accuracy(),
                latency: m.block_latency,
                energy: m.block_energy,
                gpu_var: m.telemetry_log.util_variance.clone(),
                completed: m.done,
                duration_s: now,
            },
            e2e_latency: m.e2e_latency,
            telemetry: m.telemetry_log,
            greedy_stats,
            width_histogram,
            blocks_completed: m.blocks_completed,
            sim_duration_s: now,
            total_energy_j: total_energy,
        };
        (outcome, self.router)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DropoutCfg;
    use crate::coordinator::router::{LeastLoadedRouter, RandomRouter, RoundRobinRouter};

    fn small_cfg(requests: usize, rate: f64) -> Config {
        let mut cfg = Config::default();
        cfg.workload.total_requests = requests;
        cfg.workload.rate_hz = rate;
        cfg.workload.burst_factor = 1.0;
        cfg.workload.burst_period_s = 0.0;
        cfg
    }

    fn run_with(cfg: Config, router: Box<dyn Router>) -> RunOutcome {
        Engine::new(cfg, router).run()
    }

    #[test]
    fn completes_every_request_random_router() {
        let cfg = small_cfg(300, 200.0);
        let widths = cfg.scheduler.widths.clone();
        let out = run_with(cfg, Box::new(RandomRouter::new(widths, false, 4)));
        assert_eq!(out.report.completed, 300);
        assert_eq!(out.e2e_latency.count(), 300);
        assert!(out.blocks_completed > 0);
        assert!(out.report.latency.mean() > 0.0);
        assert!(out.report.energy.mean() > 0.0);
        assert!(out.total_energy_j > 0.0);
        // every request crossed 4 segments
        assert_eq!(out.width_execs(), 4 * 300);
    }

    #[test]
    fn width_histogram_keys_follow_the_scenario_width_set() {
        // |W| = 2 scenario: the histogram must carry exactly those keys
        let mut cfg = small_cfg(120, 150.0);
        cfg.scheduler.widths = vec![0.25, 1.0];
        let widths = cfg.scheduler.widths.clone();
        let out = run_with(cfg, Box::new(RandomRouter::new(widths, true, 4)));
        assert_eq!(out.report.completed, 120);
        let keys: Vec<f64> = out.width_histogram.iter().map(|&(w, _)| w).collect();
        assert_eq!(keys, vec![0.25, 1.0]);
        assert_eq!(out.width_execs(), 4 * 120);
        assert_eq!(
            out.width_count(0.25) + out.width_count(1.0),
            out.width_execs()
        );
        assert_eq!(out.width_count(0.5), 0); // not in this W
        let f = out.width_frac_at_most(0.25);
        assert!((0.0..=1.0).contains(&f));
    }

    #[test]
    fn windowed_routing_completes_and_conserves() {
        for window in [2usize, 4, 16] {
            let mut cfg = small_cfg(300, 250.0);
            cfg.router.route_window = window;
            let widths = cfg.scheduler.widths.clone();
            let out =
                run_with(cfg, Box::new(RandomRouter::new(widths, true, 4)));
            assert_eq!(out.report.completed, 300, "window={window}");
            assert_eq!(out.e2e_latency.count(), 300, "window={window}");
            assert_eq!(out.width_execs(), 4 * 300, "window={window}");
        }
    }

    #[test]
    fn windowed_routing_is_deterministic() {
        let mk = || {
            let mut cfg = small_cfg(200, 300.0);
            cfg.router.route_window = 4;
            let widths = cfg.scheduler.widths.clone();
            run_with(cfg, Box::new(RandomRouter::new(widths, true, 4)))
        };
        let a = mk();
        let b = mk();
        assert_eq!(a.width_histogram, b.width_histogram);
        assert_eq!(a.report.latency.mean().to_bits(), b.report.latency.mean().to_bits());
        assert_eq!(a.total_energy_j.to_bits(), b.total_energy_j.to_bits());
    }

    #[test]
    fn accuracy_within_prior_bounds() {
        let cfg = small_cfg(200, 200.0);
        let widths = cfg.scheduler.widths.clone();
        let out = run_with(cfg, Box::new(RandomRouter::new(widths, true, 4)));
        assert!(out.report.accuracy_pct >= 69.0 && out.report.accuracy_pct <= 77.0,
                "{}", out.report.accuracy_pct);
    }

    #[test]
    fn deterministic_given_seed() {
        let mk = || {
            let cfg = small_cfg(150, 300.0);
            let widths = cfg.scheduler.widths.clone();
            run_with(cfg, Box::new(RandomRouter::new(widths, true, 4)))
        };
        let a = mk();
        let b = mk();
        assert_eq!(a.report.completed, b.report.completed);
        assert!((a.report.latency.mean() - b.report.latency.mean()).abs() < 1e-12);
        assert!((a.total_energy_j - b.total_energy_j).abs() < 1e-9);
        assert_eq!(a.width_histogram, b.width_histogram);
    }

    #[test]
    fn round_robin_and_least_loaded_complete() {
        let cfg = small_cfg(200, 250.0);
        let widths = cfg.scheduler.widths.clone();
        let out_rr =
            run_with(cfg.clone(), Box::new(RoundRobinRouter::new(widths.clone(), 4)));
        assert_eq!(out_rr.report.completed, 200);
        let out_ll = run_with(cfg, Box::new(LeastLoadedRouter::new(widths, 16)));
        assert_eq!(out_ll.report.completed, 200);
    }

    #[test]
    fn slim_widths_are_cheaper() {
        // force all-slim vs all-wide via the width mix and compare energy
        let mut slim_cfg = small_cfg(300, 200.0);
        slim_cfg.workload.width_mix = vec![0.25];
        let widths = slim_cfg.scheduler.widths.clone();
        let slim = run_with(
            slim_cfg,
            Box::new(RandomRouter::new(widths.clone(), false, 4)),
        );

        let mut wide_cfg = small_cfg(300, 200.0);
        wide_cfg.workload.width_mix = vec![1.0];
        let wide = run_with(wide_cfg, Box::new(RandomRouter::new(widths, false, 4)));

        assert!(slim.report.latency.mean() < wide.report.latency.mean());
        assert!(slim.report.energy.mean() < wide.report.energy.mean());
        // and the accuracy ordering is the paper's Table I
        assert!(slim.report.accuracy_pct < wide.report.accuracy_pct);
        assert!((slim.report.accuracy_pct - 70.30).abs() < 0.2);
        assert!((wide.report.accuracy_pct - 76.43).abs() < 0.2);
    }

    #[test]
    fn telemetry_sampled_and_instances_loaded() {
        let cfg = small_cfg(150, 150.0);
        let widths = cfg.scheduler.widths.clone();
        let out = run_with(cfg, Box::new(RandomRouter::new(widths, false, 4)));
        assert!(out.telemetry.samples > 0);
        let loads: u64 = out.greedy_stats.iter().map(|s| s.loads).sum();
        assert!(loads > 0);
    }

    #[test]
    fn overload_increases_latency() {
        let widths = Config::default().scheduler.widths.clone();
        let calm = run_with(
            small_cfg(300, 100.0),
            Box::new(RandomRouter::new(widths.clone(), false, 4)),
        );
        let slammed = run_with(
            small_cfg(300, 3000.0),
            Box::new(RandomRouter::new(widths, false, 4)),
        );
        assert!(
            slammed.report.latency.mean() > calm.report.latency.mean(),
            "{} vs {}",
            slammed.report.latency.mean(),
            calm.report.latency.mean()
        );
    }

    #[test]
    fn device_dropout_still_completes_every_request() {
        let mut cfg = small_cfg(250, 150.0);
        cfg.dropout = Some(DropoutCfg { server: 0, at_s: 0.3 });
        let widths = cfg.scheduler.widths.clone();
        let out = run_with(cfg, Box::new(RandomRouter::new(widths, true, 4)));
        assert_eq!(out.report.completed, 250);
        assert_eq!(out.e2e_latency.count(), 250);
    }

    #[test]
    fn dropout_shifts_load_off_the_dead_server() {
        // hammer server 0 via round-robin, kill it early: the survivors
        // must absorb everything and the run still drains.
        let mut cfg = small_cfg(300, 200.0);
        cfg.dropout = Some(DropoutCfg { server: 2, at_s: 0.2 });
        let widths = cfg.scheduler.widths.clone();
        let out = run_with(cfg, Box::new(RoundRobinRouter::new(widths, 4)));
        assert_eq!(out.report.completed, 300);
        // the dead server stops dispatching after the dropout instant, so
        // its share of loads is below an even split
        let loads: Vec<u64> = out.greedy_stats.iter().map(|s| s.loads).collect();
        let total: u64 = loads.iter().sum();
        assert!(total > 0);
        assert!(
            (loads[2] as f64) < total as f64 / 2.0,
            "dead server kept working: {loads:?}"
        );
    }
}
