//! The multi-server discrete-event engine.
//!
//! Binds the whole hierarchy together exactly as the paper describes it:
//! a leader holds the global FIFO and the router (PPO or algorithmic);
//! every routed block crosses the WLAN link to its target server, whose
//! local greedy scheduler (Algorithm 1) batches it onto a loaded instance
//! of the simulated GPU. Block completions feed reward signals back to
//! the router — the training loop of §III-B and the measurement loop of
//! Tables III–V are the same code path.
//!
//! Virtual time (discrete events) makes a 20 k-request cluster run finish
//! in tens of milliseconds, so PPO training over hundreds of thousands of
//! scheduling steps is practical on one CPU.

use std::collections::{BinaryHeap, HashMap, VecDeque};

use crate::config::Config;
use crate::metrics::{RunReport, Summary};
use crate::model::{AccuracyPrior, ModelMeta, NUM_SEGMENTS};
use crate::sim::{profiles, Link, SimDevice, VirtualClock, Workload};
use crate::utilx::Rng;

use super::greedy::{Dispatch, GreedyScheduler, GreedyStats};
use super::queue::Queued;
use super::request::Request;
use super::router::{BlockFeedback, Router};
use super::telemetry::{ServerTelemetry, TelemetryLog, TelemetrySnapshot};

const TELEMETRY_DT: f64 = 0.05;
const UNLOAD_DT: f64 = 0.5;

/// Event kinds (ordering by time, then sequence for determinism).
#[derive(Debug)]
enum EvKind {
    Arrival(Request),
    BlockArrive { server: usize, entries: Vec<Queued> },
    BatchDone { server: usize, device_batch: u64, dispatch: Dispatch },
    TelemetryTick,
    UnloadTick,
}

struct Ev {
    t: f64,
    seq: u64,
    kind: EvKind,
}

impl PartialEq for Ev {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.seq == other.seq
    }
}
impl Eq for Ev {}
impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Ev {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // reversed: BinaryHeap is a max-heap, we need earliest-first
        other
            .t
            .total_cmp(&self.t)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// In-flight routed block (for block-level latency/energy and reward).
#[derive(Clone, Debug)]
struct BlockState {
    routed_at: f64,
    remaining: usize,
    width: f64,
    seg: usize,
    /// representative width tuple (first request's history + this width)
    tuple: [f64; NUM_SEGMENTS],
}

/// Everything a finished run reports.
#[derive(Clone, Debug)]
pub struct RunOutcome {
    pub report: RunReport,
    /// End-to-end (arrival → final segment) request latency.
    pub e2e_latency: Summary,
    pub telemetry: TelemetryLog,
    pub greedy_stats: Vec<GreedyStats>,
    /// Executed-width histogram over all segment executions (W order).
    pub width_histogram: [u64; 4],
    pub blocks_completed: u64,
    pub sim_duration_s: f64,
    /// Total cluster energy (J) integrated over the run.
    pub total_energy_j: f64,
}

/// The engine itself (generic over the router so trained PPO routers can
/// be recovered after a run; `Box<dyn Router>` also implements [`Router`]
/// for dynamic use).
pub struct Engine<R: Router> {
    pub cfg: Config,
    pub meta: ModelMeta,
    prior: AccuracyPrior,
    devices: Vec<SimDevice>,
    scheds: Vec<GreedyScheduler>,
    link: Link,
    router: R,
    global_fifo: VecDeque<Request>,
    blocks: HashMap<u64, BlockState>,
    events: BinaryHeap<Ev>,
    clock: VirtualClock,
    rng: Rng,
    seq: u64,
    // metrics
    done: u64,
    total: usize,
    block_latency: Summary,
    block_energy: Summary,
    e2e_latency: Summary,
    acc_sum: f64,
    telemetry_log: TelemetryLog,
    width_histogram: [u64; 4],
    blocks_completed: u64,
    /// Safety cap for pathological configurations.
    pub max_sim_time_s: f64,
}

impl<R: Router> Engine<R> {
    pub fn new(cfg: Config, router: R) -> Self {
        let meta = ModelMeta::default();
        let devices: Vec<SimDevice> = cfg
            .devices
            .iter()
            .map(|name| {
                SimDevice::new(
                    profiles::by_name(name)
                        .unwrap_or_else(|| panic!("unknown device profile {name}")),
                )
            })
            .collect();
        let scheds = devices
            .iter()
            .map(|_| GreedyScheduler::new(cfg.scheduler.clone(), meta.clone()))
            .collect();
        let n = devices.len();
        let total = cfg.workload.total_requests;
        Engine {
            link: Link::new(cfg.link),
            rng: Rng::new(cfg.seed),
            meta,
            prior: AccuracyPrior::new(),
            devices,
            scheds,
            router,
            global_fifo: VecDeque::new(),
            blocks: HashMap::new(),
            events: BinaryHeap::new(),
            clock: VirtualClock::new(),
            seq: 0,
            done: 0,
            total,
            block_latency: Summary::default(),
            block_energy: Summary::default(),
            e2e_latency: Summary::default(),
            acc_sum: 0.0,
            telemetry_log: TelemetryLog::new(n),
            width_histogram: [0; 4],
            blocks_completed: 0,
            max_sim_time_s: 3600.0,
            cfg,
        }
    }

    fn push_event(&mut self, t: f64, kind: EvKind) {
        let seq = self.seq;
        self.seq += 1;
        self.events.push(Ev { t, seq, kind });
    }

    /// eq. 1 snapshot of the cluster.
    fn snapshot(&self) -> TelemetrySnapshot {
        TelemetrySnapshot {
            fifo_len: self.global_fifo.len(),
            done_count: self.done,
            total_requests: self.total,
            servers: self
                .devices
                .iter()
                .zip(&self.scheds)
                .map(|(d, s)| ServerTelemetry {
                    queue_len: s.queue_len(),
                    power_w: d.power_w(),
                    util_pct: d.util_pct(),
                    mem_util: d.mem_util(),
                    instances: s.pool.len(),
                })
                .collect(),
        }
    }

    fn width_index(&self, w: f64) -> usize {
        self.cfg
            .scheduler
            .widths
            .iter()
            .position(|&x| (x - w).abs() < 1e-9)
            .unwrap_or(0)
    }

    /// Route every request waiting at the leader.
    fn route_pending(&mut self) {
        while !self.global_fifo.is_empty() {
            let snap = self.snapshot();
            let head_seg = self.global_fifo[0].seg;
            let head_w_req = self.global_fifo[0].w_req;
            let decision =
                self.router.route(&snap, head_w_req, head_seg, &mut self.rng);
            let now = self.clock.now();

            // pull a block: consecutive head requests of the same segment
            let mut entries: Vec<Queued> = Vec::new();
            while entries.len() < decision.group.max(1) {
                match self.global_fifo.front() {
                    Some(r) if r.seg == head_seg => {
                        let mut req = self.global_fifo.pop_front().unwrap();
                        req.block_tag = decision.tag;
                        req.routed_at = now;
                        req.enqueued_at = now;
                        entries.push(Queued { req, width: decision.width });
                    }
                    _ => break,
                }
            }
            debug_assert!(!entries.is_empty());

            // representative tuple for the partial-accuracy prior:
            // executed widths so far, this block's width for the current
            // segment, nearest-neighbour (same width) for the rest.
            let mut tuple = [decision.width; NUM_SEGMENTS];
            for s in 0..head_seg {
                tuple[s] = entries[0].req.widths_used[s];
            }

            self.blocks.insert(
                decision.tag,
                BlockState {
                    routed_at: now,
                    remaining: entries.len(),
                    width: decision.width,
                    seg: head_seg,
                    tuple,
                },
            );

            // WLAN transfer: charge the slowest member of the block
            let mut arrive = now;
            for q in &entries {
                let bytes = if head_seg == 0 {
                    // input image
                    (self.meta.img * self.meta.img * self.meta.in_ch * 4) as u64
                } else {
                    let (inp, _) = self.meta.seg_io_shapes(head_seg, 1);
                    (inp.iter().product::<usize>() * 4) as u64
                };
                let dt = match q.req.last_server {
                    Some(s) if s == decision.server => self.link.local_s(),
                    _ => self.link.transfer_s(bytes, &mut self.rng),
                };
                arrive = arrive.max(now + dt);
            }
            let server = decision.server.min(self.devices.len() - 1);
            self.push_event(arrive, EvKind::BlockArrive { server, entries });
        }
    }

    /// Run the greedy scheduler on one server and execute its dispatches.
    fn pump_server(&mut self, server: usize) {
        let now = self.clock.now();
        let dispatches = {
            let dev = &mut self.devices[server];
            self.scheds[server].step(now, dev)
        };
        for d in dispatches {
            // semantic cost of the batch: per-request FLOPs at the
            // instance's width and the request's true w_prev
            let flops: u64 = d
                .batch
                .iter()
                .map(|q| {
                    self.meta
                        .seg_flops(d.key.seg, d.width, q.req.w_prev, 1)
                })
                .sum();
            let mem = (self.meta.seg_mem_bytes(d.key.seg, d.batch.len()) as f64
                * d.width) as u64;
            let start = now + d.load_penalty_s;
            let (device_batch, finish) = self.devices[server].begin_batch(
                start,
                flops,
                mem,
                d.batch.len(),
                d.width,
            );
            self.push_event(
                finish,
                EvKind::BatchDone { server, device_batch, dispatch: d },
            );
        }
    }

    fn handle_batch_done(&mut self, server: usize, device_batch: u64, d: Dispatch) {
        let now = self.clock.now();
        self.devices[server].finish_batch(now, device_batch);
        self.scheds[server].complete(d.instance_id, now);
        self.width_histogram[self.width_index(d.width)] += d.batch.len() as u64;

        let snap = self.snapshot();
        for q in d.batch {
            let mut req = q.req;
            let tag = req.block_tag;
            let mut block_finished = false;
            if let Some(block) = self.blocks.get_mut(&tag) {
                block.remaining -= 1;
                if block.remaining == 0 {
                    block_finished = true;
                }
            }
            if block_finished {
                let block = self.blocks.remove(&tag).unwrap();
                let latency = now - block.routed_at;
                let energy = snap.mean_power_w() * latency;
                self.block_latency.record(latency);
                self.block_energy.record(energy);
                self.blocks_completed += 1;
                let fb = BlockFeedback {
                    tag,
                    acc_prior_norm: self.prior.normalized(&block.tuple),
                    latency_s: latency,
                    energy_j: energy,
                    util_variance: snap.util_variance(),
                };
                let _ = (block.width, block.seg);
                self.router.feedback(&fb);
            }

            if req.advance(d.width, now, server) {
                self.global_fifo.push_back(req);
            } else {
                self.done += 1;
                self.e2e_latency.record(now - req.arrival);
                self.acc_sum += self.prior.lookup(&req.width_tuple());
            }
        }
        // freed instance may unblock queued batches
        self.pump_server(server);
        // requests that advanced need routing
        self.route_pending();
    }

    /// Run the configured workload to completion; returns the outcome.
    pub fn run(self) -> RunOutcome {
        self.run_returning_router().0
    }

    /// Like [`Engine::run`] but hands the router back — used to train a
    /// PPO router across multiple episodes and then freeze it for
    /// evaluation.
    pub fn run_returning_router(mut self) -> (RunOutcome, R) {
        let mut workload = Workload::new(
            self.cfg.workload.clone(),
            &self.cfg.scheduler.widths,
            self.rng.split(0xA11),
        );
        if let Some(first) = workload.next_event() {
            let req = Request::new(first.request_id, first.at, first.w_req);
            self.push_event(first.at, EvKind::Arrival(req));
        }
        self.push_event(TELEMETRY_DT, EvKind::TelemetryTick);
        self.push_event(UNLOAD_DT, EvKind::UnloadTick);

        while let Some(ev) = self.events.pop() {
            if ev.t > self.max_sim_time_s {
                break;
            }
            self.clock.advance_to(ev.t);
            match ev.kind {
                EvKind::Arrival(req) => {
                    self.global_fifo.push_back(req);
                    if let Some(next) = workload.next_event() {
                        let r = Request::new(next.request_id, next.at, next.w_req);
                        self.push_event(next.at, EvKind::Arrival(r));
                    }
                    self.route_pending();
                }
                EvKind::BlockArrive { server, entries } => {
                    for q in entries {
                        self.scheds[server].enqueue(q);
                    }
                    self.pump_server(server);
                }
                EvKind::BatchDone { server, device_batch, dispatch } => {
                    self.handle_batch_done(server, device_batch, dispatch);
                }
                EvKind::TelemetryTick => {
                    let now = self.clock.now();
                    for d in &mut self.devices {
                        d.integrate_to(now);
                    }
                    let snap = self.snapshot();
                    self.telemetry_log.record(&snap);
                    if self.done < self.total as u64 {
                        self.push_event(now + TELEMETRY_DT, EvKind::TelemetryTick);
                    }
                }
                EvKind::UnloadTick => {
                    let now = self.clock.now();
                    for i in 0..self.scheds.len() {
                        let dev = &mut self.devices[i];
                        self.scheds[i].unload_idle(now, dev);
                        // unloads may free VRAM another key was waiting for
                    }
                    for i in 0..self.scheds.len() {
                        self.pump_server(i);
                    }
                    if self.done < self.total as u64 {
                        self.push_event(now + UNLOAD_DT, EvKind::UnloadTick);
                    }
                }
            }
            if self.done >= self.total as u64 {
                // drain: all requests served
                break;
            }
        }
        self.router.end_of_run();

        let now = self.clock.now();
        for d in &mut self.devices {
            d.integrate_to(now);
        }
        let total_energy: f64 = self.devices.iter().map(|d| d.energy_j()).sum();
        let accuracy = if self.done > 0 {
            self.acc_sum / self.done as f64
        } else {
            0.0
        };
        let outcome = RunOutcome {
            report: RunReport {
                label: self.router.name().to_string(),
                accuracy_pct: accuracy,
                latency: self.block_latency,
                energy: self.block_energy,
                gpu_var: self.telemetry_log.util_variance.clone(),
                completed: self.done,
                duration_s: now,
            },
            e2e_latency: self.e2e_latency,
            telemetry: self.telemetry_log,
            greedy_stats: self.scheds.iter().map(|s| s.stats.clone()).collect(),
            width_histogram: self.width_histogram,
            blocks_completed: self.blocks_completed,
            sim_duration_s: now,
            total_energy_j: total_energy,
        };
        (outcome, self.router)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::router::{LeastLoadedRouter, RandomRouter, RoundRobinRouter};

    fn small_cfg(requests: usize, rate: f64) -> Config {
        let mut cfg = Config::default();
        cfg.workload.total_requests = requests;
        cfg.workload.rate_hz = rate;
        cfg.workload.burst_factor = 1.0;
        cfg.workload.burst_period_s = 0.0;
        cfg
    }

    fn run_with(cfg: Config, router: Box<dyn Router>) -> RunOutcome {
        Engine::new(cfg, router).run()
    }

    #[test]
    fn completes_every_request_random_router() {
        let cfg = small_cfg(300, 200.0);
        let widths = cfg.scheduler.widths.clone();
        let out = run_with(cfg, Box::new(RandomRouter::new(widths, false, 4)));
        assert_eq!(out.report.completed, 300);
        assert_eq!(out.e2e_latency.count(), 300);
        assert!(out.blocks_completed > 0);
        assert!(out.report.latency.mean() > 0.0);
        assert!(out.report.energy.mean() > 0.0);
        assert!(out.total_energy_j > 0.0);
        // every request crossed 4 segments
        let execs: u64 = out.width_histogram.iter().sum();
        assert_eq!(execs, 4 * 300);
    }

    #[test]
    fn accuracy_within_prior_bounds() {
        let cfg = small_cfg(200, 200.0);
        let widths = cfg.scheduler.widths.clone();
        let out = run_with(cfg, Box::new(RandomRouter::new(widths, true, 4)));
        assert!(out.report.accuracy_pct >= 69.0 && out.report.accuracy_pct <= 77.0,
                "{}", out.report.accuracy_pct);
    }

    #[test]
    fn deterministic_given_seed() {
        let mk = || {
            let cfg = small_cfg(150, 300.0);
            let widths = cfg.scheduler.widths.clone();
            run_with(cfg, Box::new(RandomRouter::new(widths, true, 4)))
        };
        let a = mk();
        let b = mk();
        assert_eq!(a.report.completed, b.report.completed);
        assert!((a.report.latency.mean() - b.report.latency.mean()).abs() < 1e-12);
        assert!((a.total_energy_j - b.total_energy_j).abs() < 1e-9);
        assert_eq!(a.width_histogram, b.width_histogram);
    }

    #[test]
    fn round_robin_and_least_loaded_complete() {
        let cfg = small_cfg(200, 250.0);
        let widths = cfg.scheduler.widths.clone();
        let out_rr =
            run_with(cfg.clone(), Box::new(RoundRobinRouter::new(widths.clone(), 4)));
        assert_eq!(out_rr.report.completed, 200);
        let out_ll = run_with(cfg, Box::new(LeastLoadedRouter::new(widths, 16)));
        assert_eq!(out_ll.report.completed, 200);
    }

    #[test]
    fn slim_widths_are_cheaper() {
        // force all-slim vs all-wide via the width mix and compare energy
        let mut slim_cfg = small_cfg(300, 200.0);
        slim_cfg.workload.width_mix = vec![0.25];
        let widths = slim_cfg.scheduler.widths.clone();
        let slim = run_with(
            slim_cfg,
            Box::new(RandomRouter::new(widths.clone(), false, 4)),
        );

        let mut wide_cfg = small_cfg(300, 200.0);
        wide_cfg.workload.width_mix = vec![1.0];
        let wide = run_with(wide_cfg, Box::new(RandomRouter::new(widths, false, 4)));

        assert!(slim.report.latency.mean() < wide.report.latency.mean());
        assert!(slim.report.energy.mean() < wide.report.energy.mean());
        // and the accuracy ordering is the paper's Table I
        assert!(slim.report.accuracy_pct < wide.report.accuracy_pct);
        assert!((slim.report.accuracy_pct - 70.30).abs() < 0.2);
        assert!((wide.report.accuracy_pct - 76.43).abs() < 0.2);
    }

    #[test]
    fn telemetry_sampled_and_instances_loaded() {
        let cfg = small_cfg(150, 150.0);
        let widths = cfg.scheduler.widths.clone();
        let out = run_with(cfg, Box::new(RandomRouter::new(widths, false, 4)));
        assert!(out.telemetry.samples > 0);
        let loads: u64 = out.greedy_stats.iter().map(|s| s.loads).sum();
        assert!(loads > 0);
    }

    #[test]
    fn overload_increases_latency() {
        let widths = Config::default().scheduler.widths.clone();
        let calm = run_with(
            small_cfg(300, 100.0),
            Box::new(RandomRouter::new(widths.clone(), false, 4)),
        );
        let slammed = run_with(
            small_cfg(300, 3000.0),
            Box::new(RandomRouter::new(widths, false, 4)),
        );
        assert!(
            slammed.report.latency.mean() > calm.report.latency.mean(),
            "{} vs {}",
            slammed.report.latency.mean(),
            calm.report.latency.mean()
        );
    }
}
