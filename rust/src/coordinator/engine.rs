//! The multi-server discrete-event engine.
//!
//! Binds the whole hierarchy together exactly as the paper describes it:
//! a leader tier holds the global FIFO and the router (PPO or
//! algorithmic); every routed block crosses the WLAN link to its target
//! server, whose local greedy scheduler (Algorithm 1) batches it onto a
//! loaded instance of the simulated GPU. Block completions feed reward
//! signals back to the router — the training loop of §III-B and the
//! measurement loop of Tables III–V are the same code path.
//!
//! Since the multi-leader refactor the leader tier is a set of
//! [`LeaderShard`]s (`coordinator::shard`): each shard owns a slice of
//! the global FIFO and a router replica, requests land on shards through
//! a deterministic [`ShardAssign`] policy, and an optional cross-shard
//! rebalance step migrates head runs from the deepest to the shallowest
//! FIFO. A single-shard engine (`Engine::new`, the default) is the
//! paper's one-leader hierarchy, bit-identical per seed to the pre-shard
//! engine; `shard::sharded_engine` builds the N-leader configuration.
//!
//! The event heap, block ledger and metric accumulators live in
//! [`super::core`]; the router, per-server scheduler and device model
//! attach through the [`Router`], [`LocalScheduler`] and [`DeviceModel`]
//! traits, so the engine itself is just the event loop plus the routing
//! glue. An engine is plain data and `Send` — `ppo::parallel` constructs
//! one per worker thread for concurrent rollouts.
//!
//! The trace layer (`crate::trace`) attaches here too: an optional
//! [`TraceSink`] receives per-request lifecycle records (arrival, shard
//! assignment, routing decisions incl. clamp repairs, dispatch,
//! completion) and telemetry ticks, and [`Engine::set_arrivals`] replays
//! a recorded arrival stream in place of the generated workload —
//! together they make any run recordable and any recording replayable
//! bit-identically.
//!
//! Virtual time (discrete events) makes a 20 k-request cluster run finish
//! in tens of milliseconds, so PPO training over hundreds of thousands of
//! scheduling steps is practical on one CPU.

use std::sync::Arc;

use crate::config::{AdmissionKind, Config};
use crate::ctrl::{controller_for, Controller, TunableKnobs};
use crate::metrics::{RunReport, Summary};
use crate::model::{AccuracyPrior, ModelMeta, NUM_SEGMENTS};
use crate::obs::{KnobPoint, ObsCollector, TickRow};
use crate::sim::workload::sla_multiplier;
use crate::sim::{profiles, Link, SimDevice, VirtualClock, Workload, WorkloadEvent};
use crate::trace::record::{TraceEvent, TraceSink};
use crate::utilx::Rng;

use super::admission::{DrrGate, Offer};
use super::core::{
    jain_index, BlockLedger, BlockState, DeviceModel, EventQueue, LocalScheduler,
    MemberDone, RunMetrics, TenantStat,
};
use super::greedy::{Dispatch, GreedyScheduler, GreedyStats};
use super::queue::{head_runs, head_runs_into, HeadRun, Queued};
use super::request::Request;
use super::router::{
    width_eq, BlockFeedback, Decision, HeadView, PlanError, Router, RoutingPlan,
};
use super::shard::{
    assigner_for, global_tag, plan_stream_rng, rebalance, split_tag, LeaderShard,
    ShardAssign, ShardStats,
};
use super::telemetry::{ServerTelemetry, TelemetryLog, TelemetrySnapshot};

const TELEMETRY_DT: f64 = 0.05;
const UNLOAD_DT: f64 = 0.5;
/// Admission-tick period for the DRR gate (`--admission drr`). An order
/// of magnitude finer than telemetry so the gate never becomes the
/// latency floor; the event is only ever scheduled when a gate exists,
/// so `--admission none` runs carry zero structural change.
const ADMIT_DT: f64 = 0.005;
/// Per-run scan budget for windowed head discovery — comfortably above
/// every micro-batch group size in use (≤ 16), so it never shortens a
/// block, while keeping each planning event's FIFO scan bounded at
/// `route_window · RUN_SCAN_CAP` entries on deep same-segment backlogs.
const RUN_SCAN_CAP: usize = 64;

/// Event kinds (ordering by time, then sequence — see `core::EventQueue`).
#[derive(Debug)]
enum EvKind {
    Arrival(Request),
    BlockArrive { server: usize, entries: Vec<Queued> },
    BatchDone { server: usize, device_batch: u64, dispatch: Dispatch },
    TelemetryTick,
    UnloadTick,
    /// Mid-run failure injection: the server stops accepting work
    /// (scenario `dropout`; `Config::dropout`).
    DeviceDown { server: usize },
    /// A shard's leader finished routing its backlog window and can plan
    /// again (only scheduled when `ShardCfg::leader_service_s > 0`).
    LeaderFree { shard: usize },
    /// DRR admission tick: drain the gate's credit round into the leader
    /// tier (only scheduled when `--admission drr` installs a gate).
    AdmitTick,
}

/// Metric labels for the per-kind pop counters, indexed by
/// [`EvKind::index`].
const EV_KIND_NAMES: [&str; 8] = [
    "arrival",
    "block_arrive",
    "batch_done",
    "telemetry_tick",
    "unload_tick",
    "device_down",
    "leader_free",
    "admit_tick",
];

impl EvKind {
    /// Dense index into [`EV_KIND_NAMES`].
    fn index(&self) -> usize {
        match self {
            EvKind::Arrival(_) => 0,
            EvKind::BlockArrive { .. } => 1,
            EvKind::BatchDone { .. } => 2,
            EvKind::TelemetryTick => 3,
            EvKind::UnloadTick => 4,
            EvKind::DeviceDown { .. } => 5,
            EvKind::LeaderFree { .. } => 6,
            EvKind::AdmitTick => 7,
        }
    }
}

/// Everything a finished run reports.
#[derive(Clone, Debug)]
pub struct RunOutcome {
    pub report: RunReport,
    /// End-to-end (arrival → final segment) request latency.
    pub e2e_latency: Summary,
    pub telemetry: TelemetryLog,
    pub greedy_stats: Vec<GreedyStats>,
    /// Executed-width histogram over all segment executions, keyed by
    /// the scenario's width set: `(width, count)` pairs in W order, so
    /// scenarios with |W| ≠ 4 report correctly.
    pub width_histogram: Vec<(f64, u64)>,
    pub blocks_completed: u64,
    pub sim_duration_s: f64,
    /// Total cluster energy (J) integrated over the run.
    pub total_energy_j: f64,
    /// Per-leader-shard counters (one entry per shard; single-leader
    /// runs report exactly one).
    pub shard_stats: Vec<ShardStats>,
    /// Plan fields repaired by the explicit `RoutingPlan::clamp` path
    /// across the run — non-zero means a router emitted out-of-range
    /// servers/widths/groups that were silently corrected.
    pub plan_clamps: u64,
    /// Completions whose end-to-end latency exceeded the soft SLA
    /// (`RouterCfg::sla_s`) — the deadline counterpart of the latency
    /// mean, surfaced per run for the EDF-vs-PPO SLA sweeps.
    pub sla_misses: u64,
    /// Per-tenant accounting (arrivals / completions / sheds / latency
    /// sums / per-tenant SLA misses), indexed by tenant id.
    pub tenant_stats: Vec<TenantStat>,
    /// Requests shed by admission backpressure (counted toward run
    /// completion alongside `report.completed`).
    pub shed: u64,
    /// Requests the DRR gate admitted at the degraded (slim) width
    /// (0 without a gate).
    pub degraded: u64,
    /// DRR deficit forfeits summed across tenants (0 without a gate).
    pub credit_forfeits: u64,
    /// Worst admission-queue wait observed (s).
    pub max_starvation_s: f64,
    /// The observability collector, when `ObsCfg::enabled` — serialize
    /// with `obs::bundle_json` / `obs::prometheus_text`.
    pub obs: Option<ObsCollector>,
}

impl RunOutcome {
    /// Fraction of completed requests that missed the soft SLA
    /// (0 when nothing completed).
    pub fn sla_miss_rate(&self) -> f64 {
        if self.report.completed == 0 {
            0.0
        } else {
            self.sla_misses as f64 / self.report.completed as f64
        }
    }

    /// Jain fairness index over per-tenant *mean latency* — 1.0 when
    /// every tenant sees the same mean, →1/n when one tenant absorbs
    /// all the queueing (single-tenant runs report exactly 1.0).
    pub fn jain_latency(&self) -> f64 {
        let xs: Vec<f64> =
            self.tenant_stats.iter().map(TenantStat::mean_latency_s).collect();
        jain_index(&xs)
    }

    /// Jain fairness index over per-tenant *throughput* (completion
    /// counts — the run-length factor cancels inside the index).
    pub fn jain_throughput(&self) -> f64 {
        let xs: Vec<f64> =
            self.tenant_stats.iter().map(|t| t.done as f64).collect();
        jain_index(&xs)
    }

    /// Fraction of the offered load shed by admission backpressure
    /// (0 when nothing arrived).
    pub fn shed_rate(&self) -> f64 {
        let total = self.report.completed + self.shed;
        if total == 0 {
            0.0
        } else {
            self.shed as f64 / total as f64
        }
    }

    /// Total segment executions across all widths.
    pub fn width_execs(&self) -> u64 {
        self.width_histogram.iter().map(|&(_, c)| c).sum()
    }

    /// Executions at exactly width `w` (0 when `w` is not in W).
    pub fn width_count(&self, w: f64) -> u64 {
        self.width_histogram
            .iter()
            .find(|&&(x, _)| width_eq(x, w))
            .map(|&(_, c)| c)
            .unwrap_or(0)
    }

    /// Fraction of executions at widths ≤ `w` (0 when nothing executed).
    pub fn width_frac_at_most(&self, w: f64) -> f64 {
        let total = self.width_execs();
        if total == 0 {
            return 0.0;
        }
        let at_most: u64 = self
            .width_histogram
            .iter()
            .filter(|&&(x, _)| x <= w + 1e-9)
            .map(|&(_, c)| c)
            .sum();
        at_most as f64 / total as f64
    }
}

/// The engine itself — generic over the router (so trained PPO routers
/// can be recovered after a run; `Box<dyn Router>` also implements
/// [`Router`] for dynamic use), the device model, and the per-server
/// scheduler. The defaults are the paper configuration: simulated GPUs
/// driven by Algorithm 1.
pub struct Engine<R: Router, D: DeviceModel = SimDevice, S: LocalScheduler = GreedyScheduler> {
    pub cfg: Config,
    pub meta: ModelMeta,
    prior: AccuracyPrior,
    devices: Vec<D>,
    scheds: Vec<S>,
    link: Link,
    /// Leader tier: one FIFO slice + router replica per shard
    /// (`coordinator::shard`). `Engine::new` builds exactly one shard —
    /// the paper's single-leader hierarchy.
    shards: Vec<LeaderShard<R>>,
    /// Deterministic request→shard placement.
    assign: Box<dyn ShardAssign>,
    /// DRR admission gate (`--admission drr`); `None` (the default)
    /// feeds arrivals straight to the shards — the pre-admission path,
    /// structurally unchanged.
    gate: Option<DrrGate>,
    /// Scratch buffer for gate drains (admitted requests per tick).
    admit_scratch: Vec<Request>,
    ledger: BlockLedger,
    events: EventQueue<EvKind>,
    clock: VirtualClock,
    rng: Rng,
    metrics: RunMetrics,
    /// Servers knocked out by a `DeviceDown` event.
    down: Vec<bool>,
    /// Fixed arrival stream (trace replay) — replaces the generated
    /// workload when set via [`Engine::set_arrivals`]. Held as a
    /// shared immutable arena so N engines replaying one trace alias a
    /// single arrival allocation.
    arrivals: Option<Arc<[WorkloadEvent]>>,
    /// Trace sink: when installed, the engine's lifecycle hooks deliver
    /// per-request records and telemetry ticks here (`crate::trace`).
    sink: Option<Box<dyn TraceSink>>,
    /// Per-shard RNG streams for parallel planning (`--plan-threads`):
    /// derived from (seed, shard index) only, so plans drawn on them are
    /// reproducible at any thread count and never touch the main stream.
    plan_rngs: Vec<Rng>,
    /// Scratch buffers reused across routing events so the hot path
    /// allocates nothing per planning call (§Perf): head runs, head
    /// views, the per-decision block list (outer vector only — the inner
    /// entry vectors escape into `BlockArrive` events), and the
    /// telemetry snapshot (its `servers` vector is the reused part).
    runs_scratch: Vec<HeadRun>,
    heads_scratch: Vec<HeadView>,
    blocks_scratch: Vec<Vec<Queued>>,
    snap_scratch: TelemetrySnapshot,
    /// The observability collector (`cfg.obs.enabled`): hot-path
    /// counters, stage histograms, tick series. Never touches the RNG
    /// or scheduling state, so enabling it cannot change sim results.
    obs: Option<ObsCollector>,
    /// Live knob state (the control plane): `route_window`, the
    /// rebalance threshold, and the DRR credit/queue knobs are re-read
    /// from here at each decision site instead of captured from `cfg`
    /// at construction. Initialized from the config and only ever
    /// rewritten by `controller` on telemetry ticks, so runs without a
    /// controller are bit-identical to the pre-control-plane engine.
    knobs: TunableKnobs,
    /// The feedback controller (`--controller`); `None` (the default)
    /// pins `knobs` to the config for the whole run.
    controller: Option<Box<dyn Controller>>,
    /// Safety cap for pathological configurations.
    pub max_sim_time_s: f64,
}

/// One shard's gathered planning work for a parallel round: the shard's
/// snapshot view plus its head runs/views, captured while holding the
/// whole engine so the planning threads only need the shard itself.
struct PlanInput {
    snap: TelemetrySnapshot,
    runs: Vec<HeadRun>,
    heads: Vec<HeadView>,
}

/// Resolve the configured device profiles and build one greedy
/// scheduler per device — the standard parts both [`Engine::new`] and
/// [`super::shard::sharded_engine`] assemble engines from (one
/// definition, so single- and multi-leader runs can never build
/// different clusters).
pub(crate) fn default_parts(cfg: &Config) -> (Vec<SimDevice>, Vec<GreedyScheduler>) {
    let meta = ModelMeta::default();
    let devices: Vec<SimDevice> = cfg
        .devices
        .iter()
        .map(|name| {
            SimDevice::new(
                profiles::by_name(name)
                    .unwrap_or_else(|| panic!("unknown device profile {name}")),
            )
        })
        .collect();
    let scheds = devices
        .iter()
        .map(|_| GreedyScheduler::new(cfg.scheduler.clone(), meta.clone()))
        .collect();
    (devices, scheds)
}

impl<R: Router> Engine<R> {
    /// Standard construction: device profiles resolved by name, one
    /// greedy scheduler per device.
    pub fn new(cfg: Config, router: R) -> Self {
        let (devices, scheds) = default_parts(&cfg);
        Engine::with_parts(cfg, router, devices, scheds)
    }
}

impl<R: Router, D: DeviceModel, S: LocalScheduler> Engine<R, D, S> {
    /// Assemble a single-leader engine from explicit parts (custom device
    /// models or scheduling policies). Note this always builds one leader
    /// shard regardless of `cfg.shard.leaders` — multi-leader engines go
    /// through [`super::shard::sharded_engine`] /
    /// [`Engine::with_shard_parts`], which need one router replica per
    /// shard.
    pub fn with_parts(cfg: Config, router: R, devices: Vec<D>, scheds: Vec<S>) -> Self {
        Engine::with_shard_parts(cfg, vec![router], devices, scheds)
    }

    /// Assemble an engine whose leader tier is sharded across
    /// `routers.len()` replicas (assignment/rebalance/service knobs come
    /// from `cfg.shard`). One router yields the classic single-leader
    /// engine, bit-identical per seed to the pre-shard code.
    pub fn with_shard_parts(
        cfg: Config,
        routers: Vec<R>,
        devices: Vec<D>,
        scheds: Vec<S>,
    ) -> Self {
        assert_eq!(devices.len(), scheds.len(), "one scheduler per device");
        assert!(!devices.is_empty(), "engine needs at least one device");
        assert!(!routers.is_empty(), "engine needs at least one leader shard");
        // the tag namespace reserves the top byte for the shard index
        // (`shard::global_tag`); more shards would silently collide tags
        assert!(
            routers.len() <= 256,
            "at most 256 leader shards (tag namespace), got {}",
            routers.len()
        );
        let n = devices.len();
        let total = cfg.workload.total_requests;
        let mut metrics =
            RunMetrics::new(n, total, cfg.scheduler.widths.len(), cfg.router.sla_s);
        metrics.telemetry_log.shard_depths =
            vec![Summary::default(); routers.len()];
        let plan_rngs: Vec<Rng> = (0..routers.len())
            .map(|si| plan_stream_rng(cfg.seed, si))
            .collect();
        let obs = cfg
            .obs
            .enabled
            .then(|| ObsCollector::new(n, &EV_KIND_NAMES, cfg.obs.series_cap));
        let knobs = TunableKnobs::from_config(&cfg);
        let controller = controller_for(cfg.ctrl.controller, &knobs);
        Engine {
            link: Link::new(cfg.link),
            rng: Rng::new(cfg.seed),
            plan_rngs,
            runs_scratch: Vec::new(),
            heads_scratch: Vec::new(),
            blocks_scratch: Vec::new(),
            snap_scratch: TelemetrySnapshot::default(),
            meta: ModelMeta::default(),
            prior: AccuracyPrior::new(),
            devices,
            scheds,
            assign: assigner_for(cfg.shard.assign),
            gate: match cfg.admission.kind {
                AdmissionKind::None => None,
                AdmissionKind::Drr => Some(DrrGate::new(cfg.admission)),
            },
            admit_scratch: Vec::new(),
            shards: routers.into_iter().map(LeaderShard::new).collect(),
            ledger: BlockLedger::new(),
            events: EventQueue::new(),
            clock: VirtualClock::new(),
            metrics,
            down: vec![false; n],
            arrivals: None,
            sink: None,
            obs,
            knobs,
            controller,
            max_sim_time_s: 3600.0,
            cfg,
        }
    }

    /// Install a trace sink: the lifecycle hooks (arrival, shard
    /// assignment, routing decision incl. clamp repairs, dispatch,
    /// completion, telemetry tick) deliver [`TraceEvent`]s to it for the
    /// whole run. Recording never touches the RNG stream, so a traced
    /// run stays bit-identical to an untraced one.
    pub fn set_trace_sink(&mut self, sink: Box<dyn TraceSink>) {
        self.sink = Some(sink);
    }

    /// Replace the generated arrival stream with a fixed event list
    /// (trace replay): the workload replays `events` verbatim while the
    /// engine's own RNG stream stays identical to a generative run's.
    /// The run budget (drain condition, done-fraction telemetry) is
    /// reconciled to the event count, so a caller that skips
    /// `trace::configure_for_replay` cannot silently run a short trace
    /// into the safety cap. Accepts a `Vec` (owned events) or an
    /// `Arc<[WorkloadEvent]>` arena handle (`Trace::arrivals_arena`) —
    /// the latter shares the parsed arrival set zero-copy across any
    /// number of replaying engines.
    pub fn set_arrivals(&mut self, events: impl Into<Arc<[WorkloadEvent]>>) {
        let events = events.into();
        self.metrics.total = events.len();
        self.arrivals = Some(events);
    }

    /// Deliver one trace event. Callers gate on `self.sink.is_some()`
    /// first so record construction stays off the untraced hot path.
    fn emit(&mut self, ev: TraceEvent) {
        if let Some(sink) = self.sink.as_mut() {
            sink.record(&ev);
        }
    }

    fn push_event(&mut self, t: f64, kind: EvKind) {
        self.events.push(t, kind);
    }

    /// Record the current knob state into the trace and the obs knob
    /// log. Only ever called on controller runs (the initial state and
    /// each retune), so controller-less traces and bundles stay
    /// byte-identical to the pre-control-plane engine.
    fn note_knobs(&mut self, t: f64) {
        let k = self.knobs;
        if self.sink.is_some() {
            self.emit(TraceEvent::Knobs {
                t,
                route_window: k.route_window,
                rebalance_threshold: k.rebalance_threshold,
                drr_quantum: k.drr_quantum,
                drr_burst_cap: k.drr_burst_cap,
                drr_queue_cap: k.drr_queue_cap,
            });
        }
        if let Some(o) = self.obs.as_mut() {
            o.on_knobs(KnobPoint {
                t,
                route_window: k.route_window,
                rebalance_threshold: k.rebalance_threshold,
                drr_quantum: k.drr_quantum,
                drr_burst_cap: k.drr_burst_cap,
                drr_queue_cap: k.drr_queue_cap,
            });
        }
    }

    /// eq. 1 snapshot of the cluster. A downed server reports a
    /// saturated-and-powerless signature (util 100 %, huge queue, zero
    /// power) so telemetry-driven routers — LeastLoaded's load score,
    /// the PPO state vector — steer away from it instead of seeing an
    /// attractive idle machine; `alive_server` remains the safety net.
    fn snapshot(&self) -> TelemetrySnapshot {
        let mut snap = TelemetrySnapshot::default();
        self.fill_snapshot(&mut snap);
        snap
    }

    /// [`Engine::snapshot`] into a caller-owned buffer: `out.servers` is
    /// cleared and refilled in place, so the routing hot path reuses one
    /// scratch snapshot instead of allocating a servers vector per
    /// planning call (§Perf).
    fn fill_snapshot(&self, out: &mut TelemetrySnapshot) {
        out.fifo_len = self.shards.iter().map(|s| s.fifo.len()).sum();
        out.done_count = self.metrics.done;
        out.total_requests = self.metrics.total;
        out.servers.clear();
        out.servers.extend(
            self.devices
                .iter()
                .zip(&self.scheds)
                .zip(&self.down)
                .map(|((d, s), &down)| {
                    if down {
                        ServerTelemetry {
                            queue_len: usize::MAX,
                            power_w: 0.0,
                            util_pct: 100.0,
                            mem_util: 0.0,
                            instances: 0,
                        }
                    } else {
                        ServerTelemetry {
                            queue_len: s.queue_len(),
                            power_w: d.power_w(),
                            util_pct: d.util_pct(),
                            mem_util: d.mem_util(),
                            instances: s.instances_loaded(),
                        }
                    }
                }),
        );
    }

    fn width_index(&self, w: f64) -> usize {
        self.cfg
            .scheduler
            .widths
            .iter()
            .position(|&x| width_eq(x, w))
            .unwrap_or(0)
    }

    /// First alive server at or cyclically after `want` (dropout remap;
    /// identity while every server is up).
    fn alive_server(&self, want: usize) -> usize {
        if !self.down[want] {
            return want;
        }
        let n = self.devices.len();
        (1..n)
            .map(|k| (want + k) % n)
            .find(|&i| !self.down[i])
            .unwrap_or(want)
    }

    /// Place a request on its leader shard (deterministic assignment).
    fn enqueue_leader(&mut self, req: Request) {
        let si = self.assign.assign(&req, self.shards.len());
        self.shards[si].stats.assigned += 1;
        if self.sink.is_some() {
            self.emit(TraceEvent::Assign {
                t: self.clock.now(),
                id: req.id,
                seg: req.seg,
                shard: si,
            });
        }
        self.shards[si].fifo.push_back(req);
    }

    /// Offer an arrival to the DRR gate (callers check `gate.is_some()`
    /// first). Queue-cap overflow sheds the request on the spot — it
    /// never reaches a shard, and the shed count drives termination.
    fn offer_to_gate(&mut self, req: Request) {
        let gate = self.gate.as_mut().expect("offer_to_gate requires a gate");
        if gate.offer(req) == Offer::Shed {
            self.metrics.record_shed(req.tenant);
        }
    }

    /// One DRR admission round: tick the gate, enqueue what it admitted
    /// (tracking worst-case admission wait), and route.
    fn drain_gate(&mut self, now: f64) {
        let slim = self
            .cfg
            .scheduler
            .widths
            .iter()
            .cloned()
            .fold(f64::INFINITY, f64::min);
        let mut admitted = std::mem::take(&mut self.admit_scratch);
        admitted.clear();
        if let Some(gate) = self.gate.as_mut() {
            gate.tick(&mut admitted, slim);
        }
        let any = !admitted.is_empty();
        for mut req in admitted.drain(..) {
            self.metrics.record_starvation(now - req.arrival);
            // the gate released it just now: stage timing splits here —
            // wait so far is gate wait, leader wait starts fresh
            req.admitted_at = now;
            req.enqueued_at = now;
            self.enqueue_leader(req);
        }
        self.admit_scratch = admitted;
        if any {
            self.route_pending();
        }
    }

    /// Current per-shard queue depths for telemetry. Requests parked in
    /// the admission gate count too — they are queued work the cluster
    /// owes, and depth telemetry that ignored them would silently
    /// under-report under backpressure. Gate requests have no shard yet
    /// (assignment happens at admission), so tenant `t`'s pending rides
    /// shard `t % leaders` as a bookkeeping attribution.
    fn shard_depths_now(&self) -> Vec<usize> {
        let mut depths: Vec<usize> =
            self.shards.iter().map(|s| s.fifo.len()).collect();
        if let Some(gate) = &self.gate {
            let n = depths.len();
            for t in 0..gate.tenant_count() {
                depths[t % n] += gate.pending_for(t as u16);
            }
        }
        depths
    }

    /// Cross-shard rebalance (no-op unless configured and multi-leader).
    /// Migrated requests are re-attributed in the trace: each one gets a
    /// fresh `assign` record naming the destination shard, so the
    /// trace's latest placement for a request id is always the shard
    /// whose leader actually routes it — stale source-shard attribution
    /// must not leak into shard-level trace analysis.
    fn maybe_rebalance(&mut self) {
        let th = self.knobs.rebalance_threshold;
        if th > 0 && self.shards.len() > 1 {
            let migrations = rebalance(&mut self.shards, th, RUN_SCAN_CAP);
            if let Some(o) = self.obs.as_mut() {
                let moved: usize = migrations.iter().map(|m| m.ids.len()).sum();
                o.on_migrations(moved as u64);
            }
            if self.sink.is_some() {
                let t = self.clock.now();
                for m in migrations {
                    for (id, seg) in m.ids {
                        self.emit(TraceEvent::Assign { t, id, seg, shard: m.to });
                    }
                }
            }
        }
    }

    /// Route every request waiting at the leader tier: rebalance if
    /// configured, then drain each shard's FIFO. `--plan-threads 1` (the
    /// default) drains shard by shard in order — the pre-shard routing
    /// loop, bit-identical per seed; higher thread counts run the
    /// per-shard `Router::plan` calls concurrently
    /// ([`Engine::route_all_parallel`]).
    fn route_pending(&mut self) {
        self.maybe_rebalance();
        if self.cfg.shard.plan_threads > 1 && self.shards.len() > 1 {
            self.route_all_parallel();
        } else {
            for si in 0..self.shards.len() {
                self.route_shard(si);
            }
        }
    }

    /// Route shard `si`'s backlog: present up to `RouterCfg::route_window`
    /// FIFO heads (one per consecutive same-segment run) to a single
    /// `Router::plan` call on the shard's router replica, apply the plan
    /// atomically, repeat until the shard FIFO drains. When
    /// `ShardCfg::leader_service_s > 0` the shard's leader has finite
    /// routing capacity: planning defers while it is busy and a
    /// `LeaderFree` event resumes the loop, so backlog genuinely accrues
    /// in the FIFO slice. With `route_window = 1` (and the default
    /// infinitely fast leader) this is the pre-plan per-head loop.
    fn route_shard(&mut self, si: usize) {
        let window = self.knobs.route_window.max(1);
        let service = self.cfg.shard.leader_service_s;
        while !self.shards[si].fifo.is_empty() {
            let now = self.clock.now();
            if service > 0.0 && self.shards[si].busy_until > now {
                // the leader is still routing earlier heads: defer and
                // wake up exactly when it frees
                if !self.shards[si].wake_scheduled {
                    self.shards[si].wake_scheduled = true;
                    let at = self.shards[si].busy_until;
                    self.push_event(at, EvKind::LeaderFree { shard: si });
                }
                return;
            }
            let depth = self.shards[si].fifo.len();
            if depth > self.shards[si].stats.max_depth {
                self.shards[si].stats.max_depth = depth;
            }
            let mut snap = std::mem::take(&mut self.snap_scratch);
            self.fill_snapshot(&mut snap);
            // the router sees its own shard's backlog as the FIFO-length
            // signal (equal to the global length at one leader)
            snap.fifo_len = depth;
            let mut runs = std::mem::take(&mut self.runs_scratch);
            if window == 1 {
                // fast path: the single head needs no run-length scan —
                // block extraction below is bounded by the segment check,
                // so a deep same-segment backlog costs O(group), not
                // O(backlog), per routing event
                runs.clear();
                let front = &self.shards[si].fifo[0];
                runs.push(HeadRun { start: 0, len: usize::MAX, seg: front.seg });
            } else {
                head_runs_into(&self.shards[si].fifo, window, RUN_SCAN_CAP, &mut runs);
            }
            let mut heads = std::mem::take(&mut self.heads_scratch);
            heads.clear();
            heads.extend(runs.iter().map(|run| {
                let req = &self.shards[si].fifo[run.start];
                let age = now - req.arrival;
                HeadView {
                    fifo_index: run.start,
                    w_req: req.w_req,
                    seg: run.seg,
                    age_s: age,
                    // per-tenant deadline: sla × tier − age, or +∞ when
                    // no SLA is configured (`--sla 0`) — deadline-aware
                    // routers see "no pressure", not a poisoned uniform
                    // slack. Tenant 0's tier is ×1.0 exact, so
                    // single-tenant runs stay bit-identical.
                    slack_s: self
                        .cfg
                        .router
                        .slack_for(age, sla_multiplier(req.tenant)),
                }
            }));

            let plan = self.shards[si].router.plan(&snap, &heads, &mut self.rng);
            self.snap_scratch = snap;
            let heads_len = heads.len();
            heads.clear();
            self.heads_scratch = heads;
            self.apply_shard_plan(si, now, &runs, heads_len, plan);
            runs.clear();
            self.runs_scratch = runs;
        }
    }

    /// Validate, repair (clamp), and apply one shard's routing plan:
    /// drain the planned blocks out of the FIFO, open ledger entries,
    /// charge WLAN transfers, emit trace records, and schedule the
    /// `BlockArrive` events. Shared verbatim by the sequential
    /// [`Engine::route_shard`] loop and the parallel planner, so the two
    /// paths can only differ in *where* `Router::plan` ran.
    fn apply_shard_plan(
        &mut self,
        si: usize,
        now: f64,
        runs: &[HeadRun],
        heads_len: usize,
        plan: RoutingPlan,
    ) {
        let service = self.cfg.shard.leader_service_s;
        // pre-repair decisions, kept only while tracing so the trace
        // can attribute clamp corrections to individual decisions
        let mut pre_clamp: Option<Vec<Decision>> = None;
        let plan = match plan.validate(
            heads_len,
            self.devices.len(),
            &self.cfg.scheduler.widths,
        ) {
            // the common case: a valid plan passes through untouched
            // (seeds stay bit-identical)
            Ok(()) => plan,
            // arity is a router contract violation, not routable data
            Err(e @ PlanError::WrongArity { .. }) => {
                panic!("router {}: {e}", self.shards[si].router.name())
            }
            // out-of-range servers/widths/groups are repairable:
            // clamp explicitly instead of indexing out of bounds,
            // and surface the correction count instead of dropping it
            Err(_) => {
                if self.sink.is_some() {
                    pre_clamp = Some(plan.decisions().to_vec());
                }
                let (repaired, clamped) =
                    plan.clamp(self.devices.len(), &self.cfg.scheduler.widths);
                self.metrics.plan_clamps += clamped as u64;
                self.shards[si].stats.plan_clamps += clamped as u64;
                repaired
            }
        };
        let decisions = plan.into_decisions();

        // apply atomically: one ranged drain per decision (up to
        // `group` members of each head's run), processed back to
        // front so earlier runs' offsets stay valid; sub-group
        // leftovers never leave the queue
        let mut blocks = std::mem::take(&mut self.blocks_scratch);
        debug_assert!(blocks.is_empty());
        for k in (0..decisions.len()).rev() {
            let run = &runs[k];
            let d = &decisions[k];
            let want = d.group.max(1);
            // count this block's members (consecutive same-segment
            // entries from the run start, capped by the group)
            let mut take = 0usize;
            while take < want
                && take < run.len
                && self.shards[si]
                    .fifo
                    .get(run.start + take)
                    .map_or(false, |r| r.seg == run.seg)
            {
                take += 1;
            }
            // per-shard routers keep local tag counters; namespace
            // them so ledger tags stay globally unique (identity at
            // shard 0)
            let gtag = global_tag(si, d.tag);
            let entries: Vec<Queued> = self.shards[si]
                .fifo
                .drain(run.start..run.start + take)
                .map(|mut req| {
                    // stage timing: everything since the last enqueue
                    // (admission or segment advance) was leader wait
                    req.leader_wait_s += now - req.enqueued_at;
                    req.block_tag = gtag;
                    req.routed_at = now;
                    req.enqueued_at = now;
                    req.block_size = take;
                    Queued { req, width: d.width }
                })
                .collect();
            blocks.push(entries);
        }
        blocks.reverse();

        let mut routed_heads = 0usize;
        for (k, ((decision, run), mut entries)) in
            decisions.iter().zip(runs).zip(blocks.drain(..)).enumerate()
        {
            debug_assert!(!entries.is_empty());
            routed_heads += entries.len();
            let block_size = entries.len();
            let head_seg = run.seg;

            // representative tuple for the partial-accuracy prior:
            // executed widths so far, this block's width for the
            // current segment, nearest-neighbour for the rest.
            let mut tuple = [decision.width; NUM_SEGMENTS];
            for s in 0..head_seg {
                tuple[s] = entries[0].req.widths_used[s];
            }

            self.ledger.open(
                global_tag(si, decision.tag),
                BlockState {
                    routed_at: now,
                    remaining: entries.len(),
                    size: entries.len(),
                    charged_j: 0.0,
                    width: decision.width,
                    seg: head_seg,
                    tuple,
                },
            );

            let server = self
                .alive_server(decision.server.min(self.devices.len() - 1));

            // WLAN transfer: charge the slowest member of the block
            let mut arrive = now;
            for q in &entries {
                let bytes = if head_seg == 0 {
                    // input image
                    (self.meta.img * self.meta.img * self.meta.in_ch * 4) as u64
                } else {
                    let (inp, _) = self.meta.seg_io_shapes(head_seg, 1);
                    (inp.iter().product::<usize>() * 4) as u64
                };
                let dt = match q.req.last_server {
                    Some(s) if s == server => self.link.local_s(),
                    _ => self.link.transfer_s(bytes, &mut self.rng),
                };
                arrive = arrive.max(now + dt);
            }
            for q in &mut entries {
                // stage timing: route → server arrival is network wait
                q.req.net_wait_s += arrive - now;
                q.req.arrived_at = arrive;
            }
            self.shards[si].stats.blocks += 1;
            if self.sink.is_some() {
                // clamp corrections attributed per decision by
                // diffing against the pre-repair plan (0 otherwise)
                let clamped = pre_clamp.as_ref().map_or(0, |before| {
                    let b = &before[k];
                    (b.server != decision.server) as u64
                        + (!width_eq(b.width, decision.width)) as u64
                        + (b.group != decision.group) as u64
                });
                // router-local tag (the `shard` field disambiguates):
                // locals stay far below 2^53, so the JSON f64 number
                // is exact — the namespaced global tag would not be
                self.emit(TraceEvent::Route {
                    t: now,
                    shard: si,
                    tag: decision.tag,
                    seg: head_seg,
                    server,
                    width: decision.width,
                    group: decision.group,
                    size: block_size,
                    clamped,
                    arrive_t: arrive,
                });
            }
            self.push_event(arrive, EvKind::BlockArrive { server, entries });
        }
        self.blocks_scratch = blocks;
        self.shards[si].stats.routed_heads += routed_heads as u64;
        if service > 0.0 && routed_heads > 0 {
            // the leader spent `service` per routed head; it can plan
            // again once that virtual work is done
            self.shards[si].busy_until = now + service * routed_heads as f64;
        }
    }

    /// Capture shard `si`'s planning work for one parallel round, or
    /// `None` when the shard has nothing routable (empty FIFO, or its
    /// leader is busy — in which case the wake-up event is scheduled
    /// exactly as the sequential loop would).
    fn gather_plan_input(
        &mut self,
        si: usize,
        now: f64,
        base: &TelemetrySnapshot,
        window: usize,
        service: f64,
    ) -> Option<PlanInput> {
        if self.shards[si].fifo.is_empty() {
            return None;
        }
        if service > 0.0 && self.shards[si].busy_until > now {
            if !self.shards[si].wake_scheduled {
                self.shards[si].wake_scheduled = true;
                let at = self.shards[si].busy_until;
                self.push_event(at, EvKind::LeaderFree { shard: si });
            }
            return None;
        }
        let depth = self.shards[si].fifo.len();
        if depth > self.shards[si].stats.max_depth {
            self.shards[si].stats.max_depth = depth;
        }
        let mut snap = base.clone();
        snap.fifo_len = depth;
        let runs = if window == 1 {
            let front = &self.shards[si].fifo[0];
            vec![HeadRun { start: 0, len: usize::MAX, seg: front.seg }]
        } else {
            head_runs(&self.shards[si].fifo, window, RUN_SCAN_CAP)
        };
        let heads: Vec<HeadView> = runs
            .iter()
            .map(|run| {
                let req = &self.shards[si].fifo[run.start];
                let age = now - req.arrival;
                HeadView {
                    fifo_index: run.start,
                    w_req: req.w_req,
                    seg: run.seg,
                    age_s: age,
                    slack_s: self
                        .cfg
                        .router
                        .slack_for(age, sla_multiplier(req.tenant)),
                }
            })
            .collect();
        Some(PlanInput { snap, runs, heads })
    }

    /// Parallel leader tier (`--plan-threads N`, N ≥ 2): plan all shards
    /// concurrently, apply sequentially. Each round gathers every
    /// routable shard's (snapshot, head runs/views), fans the
    /// `Router::plan` calls out over scoped threads — chunked so shard
    /// `si` always plans on `plan_rngs[si]`, making results independent
    /// of the thread count — then applies the plans in ascending shard
    /// order on the main thread, where all engine mutation (FIFO drains,
    /// ledger, WLAN draws on the main RNG, trace records, events)
    /// happens exactly as in the sequential loop. Rounds repeat until no
    /// shard has routable work, mirroring `route_shard`'s drain loop.
    ///
    /// Server telemetry cannot change while the leader tier routes
    /// (executions advance only through future `BlockArrive`/`BatchDone`
    /// events), so the per-round base snapshot every shard's plan sees
    /// is the same one the sequential loop would observe at that
    /// instant. Per-shard plan RNG streams are a function of (seed,
    /// shard) only, so any N ≥ 2 produces identical runs; `N = 1` never
    /// enters this path and stays bit-identical to the pre-parallel
    /// engine. Caveat: the PPO router is *shared* across shards
    /// (`SharedPpoRouter` — one rollout buffer, one tag counter), so
    /// concurrent plans would advance that shared state in
    /// thread-dependent order; PPO runs keep the default
    /// `--plan-threads 1` (memory-safe either way — the shared state is
    /// behind a mutex — but not reproducible). The per-shard-cloned
    /// algorithmic routers parallelize deterministically.
    fn route_all_parallel(&mut self) {
        let window = self.knobs.route_window.max(1);
        let service = self.cfg.shard.leader_service_s;
        let threads = self.cfg.shard.plan_threads.min(self.shards.len()).max(1);
        loop {
            let now = self.clock.now();
            let mut base = std::mem::take(&mut self.snap_scratch);
            self.fill_snapshot(&mut base);
            let inputs: Vec<Option<PlanInput>> = (0..self.shards.len())
                .map(|si| self.gather_plan_input(si, now, &base, window, service))
                .collect();
            self.snap_scratch = base;
            if inputs.iter().all(Option::is_none) {
                return;
            }

            let chunk = self.shards.len().div_ceil(threads);
            let shards = &mut self.shards;
            let plan_rngs = &mut self.plan_rngs;
            let plans: Vec<Option<RoutingPlan>> = std::thread::scope(|scope| {
                let mut handles = Vec::with_capacity(threads);
                for ((shard_chunk, rng_chunk), input_chunk) in shards
                    .chunks_mut(chunk)
                    .zip(plan_rngs.chunks_mut(chunk))
                    .zip(inputs.chunks(chunk))
                {
                    handles.push(scope.spawn(move || {
                        let mut out = Vec::with_capacity(input_chunk.len());
                        for ((sh, rng), input) in shard_chunk
                            .iter_mut()
                            .zip(rng_chunk.iter_mut())
                            .zip(input_chunk)
                        {
                            out.push(input.as_ref().map(|inp| {
                                sh.router.plan(&inp.snap, &inp.heads, rng)
                            }));
                        }
                        out
                    }));
                }
                handles
                    .into_iter()
                    .flat_map(|h| h.join().expect("plan worker panicked"))
                    .collect()
            });

            for (si, (input, plan)) in inputs.iter().zip(plans).enumerate() {
                if let (Some(inp), Some(plan)) = (input, plan) {
                    self.apply_shard_plan(si, now, &inp.runs, inp.heads.len(), plan);
                }
            }
        }
    }

    /// Run the scheduler on one server and execute its dispatches.
    fn pump_server(&mut self, server: usize) {
        if self.down[server] {
            return;
        }
        let now = self.clock.now();
        let dispatches = {
            let dev = &mut self.devices[server];
            self.scheds[server].step(now, dev)
        };
        for d in dispatches {
            if let Some(o) = self.obs.as_mut() {
                o.on_batch(server, d.batch.len());
            }
            // semantic cost of the batch: per-request FLOPs at the
            // instance's width and the request's true w_prev
            let flops: u64 = d
                .batch
                .iter()
                .map(|q| {
                    self.meta
                        .seg_flops(d.key.seg, d.width, q.req.w_prev, 1)
                })
                .sum();
            let mem = (self.meta.seg_mem_bytes(d.key.seg, d.batch.len()) as f64
                * d.width) as u64;
            let start = now + d.load_penalty_s;
            let (device_batch, finish) = self.devices[server].begin_batch(
                start,
                flops,
                mem,
                d.batch.len(),
                d.width,
            );
            self.push_event(
                finish,
                EvKind::BatchDone { server, device_batch, dispatch: d },
            );
        }
    }

    fn handle_batch_done(&mut self, server: usize, device_batch: u64, d: Dispatch) {
        let now = self.clock.now();
        self.devices[server].finish_batch(now, device_batch);
        self.scheds[server].complete(d.instance_id, now);
        self.metrics.width_histogram[self.width_index(d.width)] +=
            d.batch.len() as u64;

        let snap = self.snapshot();
        for q in d.batch {
            let mut req = q.req;
            let tag = req.block_tag;
            // per-request energy rides the ledger's member accounting
            // (`BlockLedger::member_done`): an intermediate member of a
            // block the local scheduler re-split across device batches
            // charges a provisional P̄·(t−routed)/size share at its own
            // completion instant, and the final member takes the
            // remainder of the block's device energy E_t = P̄·L — so the
            // member shares of every block sum to its recorded energy
            // *exactly*, whatever the split pattern (the trace `done`
            // records and the A/B harness pair on the per-request sum).
            match self.ledger.member_done(tag, snap.mean_power_w(), now) {
                MemberDone::Completed { block, latency_s, energy_j, share_j } => {
                    self.metrics.record_block(latency_s, energy_j);
                    req.energy_j += share_j;
                    // reward flows back to the shard that made the
                    // decision, under the router's own (local) tag. The
                    // engine minted every tag via global_tag(si <
                    // shards.len()), so an out-of-range shard index can
                    // only mean tag corruption — index directly and fail
                    // loudly rather than train an unrelated shard's
                    // router on a foreign reward.
                    let (fsi, ltag) = split_tag(tag);
                    let fb = BlockFeedback {
                        tag: ltag,
                        acc_prior_norm: self.prior.normalized(&block.tuple),
                        latency_s,
                        energy_j,
                        util_variance: snap.util_variance(),
                    };
                    self.shards[fsi].router.feedback(&fb);
                }
                MemberDone::Partial { share_j } => {
                    req.energy_j += share_j;
                }
                MemberDone::Orphan => {
                    // the block was abandoned while this member was in
                    // flight (device-dropout re-route): the ledger can no
                    // longer attribute, so fall back to the member's own
                    // routing fields — approximate, but orphaned blocks
                    // are excluded from block-energy metrics anyway
                    req.energy_j += snap.mean_power_w()
                        * (now - req.routed_at)
                        / req.block_size.max(1) as f64;
                }
            }

            // stage timing: server arrival → completion is device time
            // (queueing at the server included)
            req.device_s += now - req.arrived_at;

            if req.advance(d.width, now, server) {
                self.enqueue_leader(req);
            } else {
                let acc = self.prior.lookup(&req.width_tuple());
                let e2e = now - req.arrival;
                self.metrics.record_request_done(e2e, acc, req.tenant);
                if let Some(o) = self.obs.as_mut() {
                    o.on_done(
                        req.tenant,
                        req.admitted_at - req.arrival,
                        req.leader_wait_s,
                        req.net_wait_s,
                        req.device_s,
                        e2e,
                    );
                }
                if self.sink.is_some() {
                    // slack against the tenant's *effective* SLA
                    // (×1.0 exact for tenant 0)
                    let sla = self.cfg.router.sla_s * sla_multiplier(req.tenant);
                    self.emit(TraceEvent::Done {
                        t: now,
                        id: req.id,
                        e2e_s: e2e,
                        energy_j: req.energy_j,
                        slack_s: sla - e2e,
                        widths: req.widths_used.to_vec(),
                        tenant: req.tenant,
                    });
                }
            }
        }
        // freed instance may unblock queued batches
        self.pump_server(server);
        // requests that advanced need routing
        self.route_pending();
    }

    /// Re-admit requests whose routed block never executed (device
    /// dropout): abandon their old decision tags — close the ledger
    /// entries and let a learning router drop the staged transitions
    /// (no reward will ever arrive for them) — then re-route.
    fn readmit(&mut self, entries: Vec<Queued>) {
        for q in entries {
            let tag = q.req.block_tag;
            if self.ledger.abandon(tag).is_some() {
                // engine-minted tags always decode to a live shard; an
                // out-of-range index is corruption and must panic
                let (asi, ltag) = split_tag(tag);
                self.shards[asi].router.abandon(ltag);
            }
            self.enqueue_leader(q.req);
        }
        self.route_pending();
    }

    /// A server goes offline: settle its energy at the failure instant
    /// (a dead machine draws nothing afterwards), stop dispatching
    /// there, and hand its queued requests back to the leader for
    /// re-routing. In-flight batches are allowed to finish (their
    /// `BatchDone` events are already scheduled).
    fn handle_device_down(&mut self, server: usize) {
        let now = self.clock.now();
        self.devices[server].integrate_to(now);
        self.down[server] = true;
        let drained = self.scheds[server].drain_queue();
        self.readmit(drained);
    }

    /// Run the configured workload to completion; returns the outcome.
    pub fn run(self) -> RunOutcome {
        self.run_returning_router().0
    }

    /// Like [`Engine::run`] but hands the router back — used to train a
    /// PPO router across multiple episodes and then freeze it for
    /// evaluation.
    pub fn run_returning_router(mut self) -> (RunOutcome, R) {
        let mut workload = Workload::new(
            self.cfg.workload.clone(),
            &self.cfg.scheduler.widths,
            self.rng.split(0xA11),
        );
        // trace replay: the same construction path (including the RNG
        // split above) keeps the engine's RNG stream bit-identical to
        // the recording run; only the arrival source changes
        if let Some(events) = self.arrivals.take() {
            workload = workload.with_trace(events);
        }
        if let Some(first) = workload.next_event() {
            let req = Request::new(first.request_id, first.at, first.w_req)
                .with_tenant(first.tenant);
            self.push_event(first.at, EvKind::Arrival(req));
        }
        self.push_event(TELEMETRY_DT, EvKind::TelemetryTick);
        self.push_event(UNLOAD_DT, EvKind::UnloadTick);
        if self.gate.is_some() {
            self.push_event(ADMIT_DT, EvKind::AdmitTick);
        }
        if self.controller.is_some() {
            // the starting knob state anchors the trajectory — retune
            // events alone would leave the baseline implicit
            self.note_knobs(0.0);
        }
        if let Some(dp) = self.cfg.dropout {
            if dp.server < self.devices.len() {
                self.push_event(
                    dp.at_s.max(0.0),
                    EvKind::DeviceDown { server: dp.server },
                );
            }
        }

        while let Some((t, ev)) = self.events.pop() {
            if t > self.max_sim_time_s {
                break;
            }
            self.clock.advance_to(t);
            if let Some(o) = self.obs.as_mut() {
                o.on_event(ev.index());
            }
            match ev {
                EvKind::Arrival(req) => {
                    // the arrival is recorded *before* admission, so a
                    // shed request's arrival is still in the trace —
                    // replaying it re-offers the same sequence to the
                    // gate and sheds identically (byte-stable round
                    // trips under `--admission drr`)
                    if self.sink.is_some() {
                        self.emit(TraceEvent::Arrival {
                            t: self.clock.now(),
                            id: req.id,
                            w_req: req.w_req,
                            tenant: req.tenant,
                        });
                    }
                    self.metrics.record_arrival(req.tenant);
                    if self.gate.is_some() {
                        self.offer_to_gate(req);
                    } else {
                        self.enqueue_leader(req);
                    }
                    if let Some(next) = workload.next_event() {
                        let r = Request::new(next.request_id, next.at, next.w_req)
                            .with_tenant(next.tenant);
                        self.push_event(next.at, EvKind::Arrival(r));
                    }
                    self.route_pending();
                }
                EvKind::BlockArrive { server, entries } => {
                    if self.down[server] {
                        // the block raced the dropout: re-route its members
                        self.readmit(entries);
                    } else {
                        for q in entries {
                            self.scheds[server].enqueue(q);
                        }
                        self.pump_server(server);
                    }
                }
                EvKind::BatchDone { server, device_batch, dispatch } => {
                    self.handle_batch_done(server, device_batch, dispatch);
                }
                EvKind::TelemetryTick => {
                    let now = self.clock.now();
                    for (d, &down) in self.devices.iter_mut().zip(&self.down) {
                        // a dead server's energy is settled at the
                        // failure instant, not accrued forever
                        if !down {
                            d.integrate_to(now);
                        }
                    }
                    let snap = self.snapshot();
                    self.metrics.telemetry_log.record(&snap);
                    let depths = self.shard_depths_now();
                    self.metrics.telemetry_log.record_shard_depths(&depths);
                    if self.sink.is_some() {
                        self.emit(TraceEvent::Tick {
                            t: now,
                            fifo: snap.fifo_len,
                            done: snap.done_count,
                            util: snap.servers.iter().map(|s| s.util_pct).collect(),
                            power: snap.servers.iter().map(|s| s.power_w).collect(),
                        });
                    }
                    if self.obs.is_some() || self.controller.is_some() {
                        let servers = &snap.servers;
                        let m = &self.metrics;
                        let row = TickRow {
                            t: now,
                            shard_depths: depths,
                            server_util: servers.iter().map(|s| s.util_pct).collect(),
                            server_power: servers.iter().map(|s| s.power_w).collect(),
                            server_instances: servers.iter().map(|s| s.instances).collect(),
                            gate_pending: self.gate.as_ref().map_or(0, |g| g.pending_total()),
                            shed: m.shed,
                            done: m.done,
                            tenant_done: m.tenant_stats.iter().map(|ts| ts.done).collect(),
                        };
                        // the control plane: a pure function of (tick
                        // row, current knobs), clamped to the validated
                        // ranges before anything re-reads it
                        let proposed = self
                            .controller
                            .as_ref()
                            .map(|c| crate::ctrl::clamp(c.tune(&row, &self.knobs)));
                        if let Some(new_knobs) = proposed {
                            if new_knobs != self.knobs {
                                self.knobs = new_knobs;
                                if let Some(g) = self.gate.as_mut() {
                                    g.set_knobs(
                                        new_knobs.drr_quantum,
                                        new_knobs.drr_burst_cap,
                                        new_knobs.drr_queue_cap,
                                    );
                                }
                                self.note_knobs(now);
                            }
                        }
                        if let Some(o) = self.obs.as_mut() {
                            o.on_tick(row);
                        }
                    }
                    if !self.metrics.all_done() {
                        self.push_event(now + TELEMETRY_DT, EvKind::TelemetryTick);
                    }
                }
                EvKind::UnloadTick => {
                    let now = self.clock.now();
                    for i in 0..self.scheds.len() {
                        let dev = &mut self.devices[i];
                        self.scheds[i].unload_idle(now, dev);
                        // unloads may free VRAM another key was waiting for
                    }
                    for i in 0..self.scheds.len() {
                        self.pump_server(i);
                    }
                    if !self.metrics.all_done() {
                        self.push_event(now + UNLOAD_DT, EvKind::UnloadTick);
                    }
                }
                EvKind::DeviceDown { server } => {
                    self.handle_device_down(server);
                }
                EvKind::LeaderFree { shard } => {
                    self.shards[shard].wake_scheduled = false;
                    // the freed leader resumes its backlog; rebalance may
                    // also hand some of it to idle shards first
                    self.route_pending();
                }
                EvKind::AdmitTick => {
                    let now = self.clock.now();
                    self.drain_gate(now);
                    if !self.metrics.all_done() {
                        self.push_event(now + ADMIT_DT, EvKind::AdmitTick);
                    }
                }
            }
            if self.metrics.all_done() {
                // drain: all requests served
                break;
            }
        }
        for sh in &mut self.shards {
            sh.router.end_of_run();
        }

        let now = self.clock.now();
        for (d, &down) in self.devices.iter_mut().zip(&self.down) {
            if !down {
                d.integrate_to(now);
            }
        }
        let total_energy: f64 = self.devices.iter().map(|d| d.energy_j()).sum();
        let greedy_stats: Vec<GreedyStats> =
            self.scheds.iter().map(|s| s.stats()).collect();
        let label = self.shards[0].router.name().to_string();
        let shard_stats: Vec<ShardStats> =
            self.shards.iter().map(|s| s.stats.clone()).collect();
        // fold the gate's per-tenant admission counters into the
        // per-tenant stats so trace compare and obs export see them
        if let Some(g) = self.gate.as_ref() {
            for t in 0..self.metrics.tenant_stats.len() {
                let (_, deg, forf, cools) = g.tenant_counters(t as u16);
                let ts = self.metrics.tenant_mut(t as u16);
                ts.degraded = deg;
                ts.credit_forfeits = forf;
                ts.cooldowns = cools;
            }
        }
        let (degraded_total, credit_forfeits_total) = self
            .gate
            .as_ref()
            .map_or((0, 0), |g| (g.degraded, g.credit_forfeits()));
        let m = self.metrics;
        let width_histogram: Vec<(f64, u64)> = self
            .cfg
            .scheduler
            .widths
            .iter()
            .cloned()
            .zip(m.width_histogram.iter().cloned())
            .collect();
        let obs = self.obs.take().map(|mut o| {
            o.reg.set_counter("span_retunes", self.events.span_retunes());
            o.reg.set_counter("plan_clamps", m.plan_clamps);
            o.reg.set_counter("requests_shed", m.shed);
            o.reg.set_counter("requests_done", m.done);
            o.reg.set_counter("sla_misses", m.sla_misses);
            o.reg.set_gauge("sim_duration_s", now);
            o.reg.set_gauge("total_energy_j", total_energy);
            for (i, st) in shard_stats.iter().enumerate() {
                let lbl = |base: &str| format!("{base}{{shard=\"{i}\"}}");
                o.reg.set_counter(&lbl("shard_assigned"), st.assigned);
                o.reg.set_counter(&lbl("shard_routed_heads"), st.routed_heads);
                o.reg.set_counter(&lbl("shard_blocks"), st.blocks);
                o.reg.set_counter(&lbl("shard_plan_clamps"), st.plan_clamps);
                o.reg.set_counter(&lbl("shard_migrated_in"), st.migrated_in);
                o.reg.set_counter(&lbl("shard_migrated_out"), st.migrated_out);
                o.reg.set_gauge(&lbl("shard_max_depth"), st.max_depth as f64);
            }
            if let Some(g) = self.gate.as_ref() {
                o.reg.set_counter("drr_shed_total", g.shed);
                o.reg.set_counter("drr_degraded_total", g.degraded);
                o.reg.set_counter("drr_credit_forfeits_total", g.credit_forfeits());
                // cooldown counters appear only when the feature is
                // armed, keeping `--drr-cooldown 0` bundles unchanged
                let cooldowns_on = self.cfg.admission.cooldown_ticks > 0;
                if cooldowns_on {
                    o.reg.set_counter("drr_cooldowns_total", g.cooldowns_total());
                }
                for t in 0..m.tenant_stats.len() {
                    let (shed, deg, forf, cools) = g.tenant_counters(t as u16);
                    let lbl = |base: &str| format!("{base}{{tenant=\"{t}\"}}");
                    o.reg.set_counter(&lbl("drr_shed"), shed);
                    o.reg.set_counter(&lbl("drr_degraded"), deg);
                    o.reg.set_counter(&lbl("drr_credit_forfeits"), forf);
                    if cooldowns_on {
                        o.reg.set_counter(&lbl("drr_cooldowns"), cools);
                    }
                }
            }
            o
        });
        let outcome = RunOutcome {
            report: RunReport {
                label,
                accuracy_pct: m.mean_accuracy(),
                latency: m.block_latency,
                energy: m.block_energy,
                gpu_var: m.telemetry_log.util_variance.clone(),
                completed: m.done,
                duration_s: now,
            },
            e2e_latency: m.e2e_latency,
            telemetry: m.telemetry_log,
            greedy_stats,
            width_histogram,
            blocks_completed: m.blocks_completed,
            sim_duration_s: now,
            total_energy_j: total_energy,
            shard_stats,
            plan_clamps: m.plan_clamps,
            sla_misses: m.sla_misses,
            tenant_stats: m.tenant_stats,
            shed: m.shed,
            degraded: degraded_total,
            credit_forfeits: credit_forfeits_total,
            max_starvation_s: m.max_starvation_s,
            obs,
        };
        // shard 0's router is the one handed back: for single-leader runs
        // it is *the* router; for shared-policy PPO every replica is a
        // handle onto the same underlying router anyway
        let router = self
            .shards
            .into_iter()
            .next()
            .expect("engine always has at least one shard")
            .router;
        (outcome, router)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DropoutCfg;
    use crate::coordinator::router::{
        snap_width_up, Decision, EdfRouter, LeastLoadedRouter, RandomRouter,
        RoundRobinRouter, RoutingPlan,
    };

    fn small_cfg(requests: usize, rate: f64) -> Config {
        let mut cfg = Config::default();
        cfg.workload.total_requests = requests;
        cfg.workload.rate_hz = rate;
        cfg.workload.burst_factor = 1.0;
        cfg.workload.burst_period_s = 0.0;
        cfg
    }

    fn run_with(cfg: Config, router: Box<dyn Router>) -> RunOutcome {
        Engine::new(cfg, router).run()
    }

    #[test]
    fn completes_every_request_random_router() {
        let cfg = small_cfg(300, 200.0);
        let widths = cfg.scheduler.widths.clone();
        let out = run_with(cfg, Box::new(RandomRouter::new(widths, false, 4)));
        assert_eq!(out.report.completed, 300);
        assert_eq!(out.e2e_latency.count(), 300);
        assert!(out.blocks_completed > 0);
        assert!(out.report.latency.mean() > 0.0);
        assert!(out.report.energy.mean() > 0.0);
        assert!(out.total_energy_j > 0.0);
        // every request crossed 4 segments
        assert_eq!(out.width_execs(), 4 * 300);
    }

    #[test]
    fn width_histogram_keys_follow_the_scenario_width_set() {
        // |W| = 2 scenario: the histogram must carry exactly those keys
        let mut cfg = small_cfg(120, 150.0);
        cfg.scheduler.widths = vec![0.25, 1.0];
        let widths = cfg.scheduler.widths.clone();
        let out = run_with(cfg, Box::new(RandomRouter::new(widths, true, 4)));
        assert_eq!(out.report.completed, 120);
        let keys: Vec<f64> = out.width_histogram.iter().map(|&(w, _)| w).collect();
        assert_eq!(keys, vec![0.25, 1.0]);
        assert_eq!(out.width_execs(), 4 * 120);
        assert_eq!(
            out.width_count(0.25) + out.width_count(1.0),
            out.width_execs()
        );
        assert_eq!(out.width_count(0.5), 0); // not in this W
        let f = out.width_frac_at_most(0.25);
        assert!((0.0..=1.0).contains(&f));
    }

    #[test]
    fn windowed_routing_completes_and_conserves() {
        for window in [2usize, 4, 16] {
            let mut cfg = small_cfg(300, 250.0);
            cfg.router.route_window = window;
            let widths = cfg.scheduler.widths.clone();
            let out =
                run_with(cfg, Box::new(RandomRouter::new(widths, true, 4)));
            assert_eq!(out.report.completed, 300, "window={window}");
            assert_eq!(out.e2e_latency.count(), 300, "window={window}");
            assert_eq!(out.width_execs(), 4 * 300, "window={window}");
        }
    }

    #[test]
    fn windowed_routing_is_deterministic() {
        let mk = || {
            let mut cfg = small_cfg(200, 300.0);
            cfg.router.route_window = 4;
            let widths = cfg.scheduler.widths.clone();
            run_with(cfg, Box::new(RandomRouter::new(widths, true, 4)))
        };
        let a = mk();
        let b = mk();
        assert_eq!(a.width_histogram, b.width_histogram);
        assert_eq!(a.report.latency.mean().to_bits(), b.report.latency.mean().to_bits());
        assert_eq!(a.total_energy_j.to_bits(), b.total_energy_j.to_bits());
    }

    #[test]
    fn accuracy_within_prior_bounds() {
        let cfg = small_cfg(200, 200.0);
        let widths = cfg.scheduler.widths.clone();
        let out = run_with(cfg, Box::new(RandomRouter::new(widths, true, 4)));
        assert!(out.report.accuracy_pct >= 69.0 && out.report.accuracy_pct <= 77.0,
                "{}", out.report.accuracy_pct);
    }

    #[test]
    fn deterministic_given_seed() {
        let mk = || {
            let cfg = small_cfg(150, 300.0);
            let widths = cfg.scheduler.widths.clone();
            run_with(cfg, Box::new(RandomRouter::new(widths, true, 4)))
        };
        let a = mk();
        let b = mk();
        assert_eq!(a.report.completed, b.report.completed);
        assert!((a.report.latency.mean() - b.report.latency.mean()).abs() < 1e-12);
        assert!((a.total_energy_j - b.total_energy_j).abs() < 1e-9);
        assert_eq!(a.width_histogram, b.width_histogram);
    }

    #[test]
    fn round_robin_and_least_loaded_complete() {
        let cfg = small_cfg(200, 250.0);
        let widths = cfg.scheduler.widths.clone();
        let out_rr =
            run_with(cfg.clone(), Box::new(RoundRobinRouter::new(widths.clone(), 4)));
        assert_eq!(out_rr.report.completed, 200);
        let out_ll = run_with(cfg, Box::new(LeastLoadedRouter::new(widths, 16)));
        assert_eq!(out_ll.report.completed, 200);
    }

    #[test]
    fn slim_widths_are_cheaper() {
        // force all-slim vs all-wide via the width mix and compare energy
        let mut slim_cfg = small_cfg(300, 200.0);
        slim_cfg.workload.width_mix = vec![0.25];
        let widths = slim_cfg.scheduler.widths.clone();
        let slim = run_with(
            slim_cfg,
            Box::new(RandomRouter::new(widths.clone(), false, 4)),
        );

        let mut wide_cfg = small_cfg(300, 200.0);
        wide_cfg.workload.width_mix = vec![1.0];
        let wide = run_with(wide_cfg, Box::new(RandomRouter::new(widths, false, 4)));

        assert!(slim.report.latency.mean() < wide.report.latency.mean());
        assert!(slim.report.energy.mean() < wide.report.energy.mean());
        // and the accuracy ordering is the paper's Table I
        assert!(slim.report.accuracy_pct < wide.report.accuracy_pct);
        assert!((slim.report.accuracy_pct - 70.30).abs() < 0.2);
        assert!((wide.report.accuracy_pct - 76.43).abs() < 0.2);
    }

    #[test]
    fn telemetry_sampled_and_instances_loaded() {
        let cfg = small_cfg(150, 150.0);
        let widths = cfg.scheduler.widths.clone();
        let out = run_with(cfg, Box::new(RandomRouter::new(widths, false, 4)));
        assert!(out.telemetry.samples > 0);
        let loads: u64 = out.greedy_stats.iter().map(|s| s.loads).sum();
        assert!(loads > 0);
    }

    #[test]
    fn overload_increases_latency() {
        let widths = Config::default().scheduler.widths.clone();
        let calm = run_with(
            small_cfg(300, 100.0),
            Box::new(RandomRouter::new(widths.clone(), false, 4)),
        );
        let slammed = run_with(
            small_cfg(300, 3000.0),
            Box::new(RandomRouter::new(widths, false, 4)),
        );
        assert!(
            slammed.report.latency.mean() > calm.report.latency.mean(),
            "{} vs {}",
            slammed.report.latency.mean(),
            calm.report.latency.mean()
        );
    }

    /// Emits a server index one past the cluster on every head: every
    /// decision goes through the clamp path exactly once.
    struct OutOfRangeRouter {
        widths: Vec<f64>,
        next_tag: u64,
    }

    impl Router for OutOfRangeRouter {
        fn name(&self) -> &'static str {
            "out-of-range"
        }
        fn plan(
            &mut self,
            snap: &TelemetrySnapshot,
            heads: &[HeadView],
            _rng: &mut Rng,
        ) -> RoutingPlan {
            let decisions = heads
                .iter()
                .map(|head| {
                    let tag = self.next_tag;
                    self.next_tag += 1;
                    Decision {
                        server: snap.servers.len(), // one past the end
                        width: snap_width_up(&self.widths, head.w_req),
                        group: 4,
                        tag,
                    }
                })
                .collect();
            RoutingPlan::new(decisions)
        }
    }

    #[test]
    fn clamp_corrections_are_surfaced_not_dropped() {
        // modest load: every decision clamps onto the slowest server, so
        // the whole cluster collapses to one GTX 980 Ti
        let cfg = small_cfg(120, 60.0);
        let widths = cfg.scheduler.widths.clone();
        let out = Engine::new(cfg, OutOfRangeRouter { widths, next_tag: 0 })
            .run();
        assert_eq!(out.report.completed, 120);
        // every routed block had exactly one repaired field (the server)
        assert!(out.plan_clamps > 0, "clamp count vanished");
        let per_shard: u64 =
            out.shard_stats.iter().map(|s| s.plan_clamps).sum();
        assert_eq!(out.plan_clamps, per_shard);
        let blocks: u64 = out.shard_stats.iter().map(|s| s.blocks).sum();
        assert_eq!(out.plan_clamps, blocks);
    }

    #[test]
    fn well_behaved_routers_report_zero_clamps() {
        let cfg = small_cfg(150, 150.0);
        let widths = cfg.scheduler.widths.clone();
        let out = run_with(cfg, Box::new(RandomRouter::new(widths, true, 4)));
        assert_eq!(out.plan_clamps, 0);
        assert!(out.shard_stats.iter().all(|s| s.plan_clamps == 0));
    }

    #[test]
    fn sla_misses_follow_the_configured_threshold() {
        // an impossible SLA marks every completion late; a generous one
        // marks none — and the rate is their ratio to completions
        let mut strict = small_cfg(150, 200.0);
        strict.router.sla_s = 1e-9;
        let widths = strict.scheduler.widths.clone();
        let out = run_with(strict, Box::new(RandomRouter::new(widths.clone(), true, 4)));
        assert_eq!(out.sla_misses, 150);
        assert!((out.sla_miss_rate() - 1.0).abs() < 1e-12);

        let mut lax = small_cfg(150, 200.0);
        lax.router.sla_s = 1e9;
        let out = run_with(lax, Box::new(RandomRouter::new(widths, true, 4)));
        assert_eq!(out.sla_misses, 0);
        assert_eq!(out.sla_miss_rate(), 0.0);
    }

    #[test]
    fn trace_sink_captures_the_request_lifecycle() {
        use crate::trace::record::TraceRecorder;

        let cfg = small_cfg(80, 150.0);
        let widths = cfg.scheduler.widths.clone();
        let recorder = TraceRecorder::new(&cfg, "random");
        let mut engine =
            Engine::new(cfg, RandomRouter::new(widths, true, 4));
        engine.set_trace_sink(Box::new(recorder.clone()));
        let out = engine.run();
        assert_eq!(out.report.completed, 80);

        let events = recorder.events();
        let mut arrivals = 0usize;
        let mut assigns = 0usize;
        let mut routes = 0usize;
        let mut dones = 0usize;
        let mut ticks = 0usize;
        for ev in &events {
            match ev {
                TraceEvent::Arrival { .. } => arrivals += 1,
                TraceEvent::Assign { .. } => assigns += 1,
                TraceEvent::Route { size, clamped, .. } => {
                    routes += 1;
                    assert!(*size >= 1);
                    assert_eq!(*clamped, 0); // well-behaved router
                }
                TraceEvent::Done { widths, e2e_s, .. } => {
                    dones += 1;
                    assert_eq!(widths.len(), NUM_SEGMENTS);
                    assert!(*e2e_s > 0.0);
                }
                TraceEvent::Tick { .. } => ticks += 1,
                // no controller installed: the control plane must not
                // have touched this trace
                TraceEvent::Knobs { .. } => panic!("knobs event without a controller"),
            }
        }
        assert_eq!(arrivals, 80);
        assert_eq!(dones, 80);
        // every request is assigned once per segment traversal
        assert_eq!(assigns, 4 * 80);
        assert!(routes > 0);
        assert!(ticks > 0);
        // per-request energy accrual sums (approximately) to the block
        // energy mass: both integrate mean power over block latencies
        let traced_energy: f64 = events
            .iter()
            .filter_map(|ev| match ev {
                TraceEvent::Done { energy_j, .. } => Some(*energy_j),
                _ => None,
            })
            .sum();
        assert!(traced_energy > 0.0);
    }

    #[test]
    fn backlog_controller_is_deterministic_and_retunes_under_pressure() {
        use crate::config::ControllerKind;
        use crate::trace::record::TraceRecorder;

        // a single overloaded tenant behind a DRR gate builds hundreds
        // of gate-held requests, so the tick-time depth crosses the
        // hysteresis high water and the controller must enter relief
        let mk = || {
            let mut cfg = small_cfg(400, 3000.0);
            cfg.ctrl.controller = ControllerKind::Backlog;
            cfg.admission.kind = AdmissionKind::Drr;
            cfg.admission.quantum = 1.0;
            cfg.admission.queue_cap = 256;
            let widths = cfg.scheduler.widths.clone();
            let recorder = TraceRecorder::new(&cfg, "random");
            let mut engine = Engine::new(cfg, RandomRouter::new(widths, true, 4));
            engine.set_trace_sink(Box::new(recorder.clone()));
            let out = engine.run();
            (out, recorder.to_jsonl())
        };
        let (a, trace_a) = mk();
        let (b, trace_b) = mk();
        assert_eq!(a.report.completed + a.shed, 400);
        // controller runs are pure functions of the seed
        assert_eq!(trace_a, trace_b);
        let knob_lines = trace_a
            .lines()
            .filter(|l| l.contains("\"ev\":\"knobs\""))
            .count();
        assert!(
            knob_lines >= 2,
            "expected the initial state plus at least one retune, got {knob_lines}"
        );
        assert_eq!(
            a.report.latency.mean().to_bits(),
            b.report.latency.mean().to_bits()
        );
    }

    #[test]
    fn controller_none_emits_no_knob_state_anywhere() {
        use crate::trace::record::TraceRecorder;

        let mut cfg = small_cfg(120, 200.0);
        cfg.obs.enabled = true;
        let widths = cfg.scheduler.widths.clone();
        let recorder = TraceRecorder::new(&cfg, "random");
        let mut engine = Engine::new(cfg, RandomRouter::new(widths, true, 4));
        engine.set_trace_sink(Box::new(recorder.clone()));
        let out = engine.run();
        assert_eq!(out.report.completed, 120);
        assert!(
            !recorder.to_jsonl().contains("\"ev\":\"knobs\""),
            "controller-less traces must stay knob-free"
        );
        assert!(out.obs.expect("obs enabled").knob_log.is_empty());
    }

    #[test]
    fn per_request_energy_shares_sum_to_block_energy() {
        use crate::trace::record::TraceRecorder;

        // group 1 ⇒ every block has exactly one member completing at the
        // block's own completion instant, so the per-member share equals
        // the recorded block energy and the sums must agree exactly
        let cfg = small_cfg(100, 150.0);
        let widths = cfg.scheduler.widths.clone();
        let recorder = TraceRecorder::new(&cfg, "random");
        let mut engine =
            Engine::new(cfg, RandomRouter::new(widths, true, 1));
        engine.set_trace_sink(Box::new(recorder.clone()));
        let out = engine.run();
        assert_eq!(out.report.completed, 100);
        let traced: f64 = recorder
            .done_map()
            .values()
            .map(|d| d.energy_j)
            .sum();
        let block_mass =
            out.report.energy.mean() * out.report.energy.count() as f64;
        assert!(block_mass > 0.0);
        assert!(
            ((traced - block_mass) / block_mass).abs() < 1e-9,
            "per-request energy {traced} vs block mass {block_mass}"
        );
    }

    #[test]
    fn per_request_energy_shares_sum_exactly_for_resplit_blocks() {
        use crate::trace::record::TraceRecorder;

        // group 8 routed blocks over a B_max = 4 scheduler: the local
        // scheduler re-splits blocks across device batches, so members
        // of one block complete at different instants under different
        // power readings — the drift case the ledger's member
        // accounting pins to zero (final member takes the remainder).
        // The leader needs finite routing capacity for FIFO backlog (and
        // thus same-segment runs longer than B_max) to exist at all: an
        // infinitely fast leader routes every arrival alone.
        let mut cfg = small_cfg(240, 1000.0);
        cfg.scheduler.b_max = 4;
        cfg.shard.leader_service_s = 0.002;
        let widths = cfg.scheduler.widths.clone();
        let recorder = TraceRecorder::new(&cfg, "random");
        let mut engine = Engine::new(cfg, RandomRouter::new(widths, true, 8));
        engine.set_trace_sink(Box::new(recorder.clone()));
        let out = engine.run();
        assert_eq!(out.report.completed, 240);
        // blocks bigger than B_max were routed, so at least those were
        // genuinely re-split across device batches
        let oversized = recorder
            .events()
            .iter()
            .filter(|ev| matches!(ev, TraceEvent::Route { size, .. } if *size > 4))
            .count();
        assert!(oversized > 0, "no block exceeded B_max; nothing re-split");
        let traced: f64 =
            recorder.done_map().values().map(|d| d.energy_j).sum();
        let block_mass =
            out.report.energy.mean() * out.report.energy.count() as f64;
        assert!(block_mass > 0.0);
        assert!(
            ((traced - block_mass) / block_mass).abs() < 1e-9,
            "per-request energy {traced} vs block mass {block_mass}"
        );
    }

    #[test]
    fn no_sla_run_counts_no_misses_and_edf_still_drains() {
        // --sla 0: every head carries infinite slack; EDF must fall back
        // to deterministic FIFO order and the run must complete with a
        // zero miss count (not the old "everything missed" degeneracy)
        let mk = || {
            let mut cfg = small_cfg(200, 250.0);
            cfg.router.sla_s = 0.0;
            cfg.router.route_window = 4;
            let widths = cfg.scheduler.widths.clone();
            Engine::new(cfg, EdfRouter::new(widths, 16)).run()
        };
        let out = mk();
        assert_eq!(out.report.completed, 200);
        assert_eq!(out.sla_misses, 0, "no SLA means nothing can miss it");
        assert_eq!(out.sla_miss_rate(), 0.0);
        // deterministic across runs
        let again = mk();
        assert_eq!(
            out.report.latency.mean().to_bits(),
            again.report.latency.mean().to_bits()
        );
        assert_eq!(out.width_histogram, again.width_histogram);
    }

    #[test]
    fn tracing_does_not_perturb_the_run() {
        use crate::trace::record::TraceRecorder;

        let mk = |traced: bool| {
            let cfg = small_cfg(120, 250.0);
            let widths = cfg.scheduler.widths.clone();
            let recorder = TraceRecorder::new(&cfg, "random");
            let mut engine =
                Engine::new(cfg, RandomRouter::new(widths, true, 4));
            if traced {
                engine.set_trace_sink(Box::new(recorder.clone()));
            }
            engine.run()
        };
        let plain = mk(false);
        let traced = mk(true);
        assert_eq!(plain.width_histogram, traced.width_histogram);
        assert_eq!(
            plain.report.latency.mean().to_bits(),
            traced.report.latency.mean().to_bits()
        );
        assert_eq!(plain.total_energy_j.to_bits(), traced.total_energy_j.to_bits());
    }

    #[test]
    fn clamped_decisions_are_attributed_in_the_trace() {
        use crate::trace::record::TraceRecorder;

        let cfg = small_cfg(60, 60.0);
        let widths = cfg.scheduler.widths.clone();
        let recorder = TraceRecorder::new(&cfg, "out-of-range");
        let mut engine =
            Engine::new(cfg, OutOfRangeRouter { widths, next_tag: 0 });
        engine.set_trace_sink(Box::new(recorder.clone()));
        let out = engine.run();
        assert!(out.plan_clamps > 0);
        let traced_clamps: u64 = recorder
            .events()
            .iter()
            .filter_map(|ev| match ev {
                TraceEvent::Route { clamped, .. } => Some(*clamped),
                _ => None,
            })
            .sum();
        assert_eq!(traced_clamps, out.plan_clamps);
    }

    #[test]
    fn replayed_arrivals_drive_the_run_verbatim() {
        use crate::sim::WorkloadEvent;

        // note the configured budget (50) deliberately disagrees with
        // the replayed stream: set_arrivals reconciles the run budget to
        // the event count, so the run drains instead of idling against
        // the safety cap waiting for 47 arrivals that never come
        let cfg = small_cfg(50, 100.0);
        let widths = cfg.scheduler.widths.clone();
        let arrivals = vec![
            WorkloadEvent { at: 0.01, request_id: 0, w_req: 0.25, tenant: 0 },
            WorkloadEvent { at: 0.02, request_id: 1, w_req: 0.5, tenant: 0 },
            WorkloadEvent { at: 0.5, request_id: 2, w_req: 1.0, tenant: 0 },
        ];
        let mut engine =
            Engine::new(cfg, RandomRouter::new(widths, false, 4));
        let cap = engine.max_sim_time_s;
        engine.set_arrivals(arrivals);
        let out = engine.run();
        assert_eq!(out.report.completed, 3);
        assert_eq!(out.e2e_latency.count(), 3);
        assert!(out.sim_duration_s < cap, "replay idled into the safety cap");
    }

    /// Audit router for the migration round-trip test: each shard
    /// replica mints tags in a residue class disjoint from every other
    /// replica's (`tag ≡ hint (mod n)`), so a completion misdelivered to
    /// the wrong shard's router — a stale tag leak across a rebalance
    /// migration — is detectable from the feedback log alone.
    struct TagAuditRouter {
        widths: Vec<f64>,
        hint: u64,
        n: u64,
        issued: u64,
        feedback_log: std::sync::Arc<std::sync::Mutex<Vec<(u64, u64)>>>,
    }

    impl Router for TagAuditRouter {
        fn name(&self) -> &'static str {
            "tag-audit"
        }
        fn plan(
            &mut self,
            snap: &TelemetrySnapshot,
            heads: &[HeadView],
            _rng: &mut Rng,
        ) -> RoutingPlan {
            let n_srv = snap.servers.len().max(1);
            let decisions = heads
                .iter()
                .map(|head| {
                    let tag = self.hint + self.issued * self.n;
                    self.issued += 1;
                    Decision {
                        server: (tag as usize) % n_srv,
                        width: snap_width_up(&self.widths, head.w_req),
                        group: 4,
                        tag,
                    }
                })
                .collect();
            RoutingPlan::new(decisions)
        }
        fn feedback(&mut self, fb: &BlockFeedback) {
            self.feedback_log.lock().unwrap().push((self.hint, fb.tag));
        }
    }

    #[test]
    fn migrated_runs_route_and_complete_under_the_destination_shard() {
        use crate::trace::record::TraceRecorder;

        // the proven migration regime (tests/shard_equivalence.rs): the
        // sharded-hot scenario's bursty slim-skewed arrivals over four
        // slow finite-capacity leaders with a hair-trigger threshold —
        // backlog and imbalance are guaranteed, so head runs migrate
        // (an infinitely fast leader never accrues the backlog the
        // rebalancer acts on)
        let mut cfg = Config::default();
        crate::sim::scenarios::apply_named("sharded-hot", &mut cfg)
            .expect("registered scenario");
        cfg.workload.total_requests = 600;
        cfg.seed = 42;
        cfg.shard.leaders = 4;
        cfg.shard.leader_service_s = 0.003;
        cfg.shard.rebalance_threshold = 2;
        let widths = cfg.scheduler.widths.clone();
        let log = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
        let routers: Vec<TagAuditRouter> = (0..4)
            .map(|hint| TagAuditRouter {
                widths: widths.clone(),
                hint,
                n: 4,
                issued: 0,
                feedback_log: log.clone(),
            })
            .collect();
        let (devices, scheds) = default_parts(&cfg);
        let recorder = TraceRecorder::new(&cfg, "tag-audit");
        let mut engine = Engine::with_shard_parts(cfg, routers, devices, scheds);
        engine.set_trace_sink(Box::new(recorder.clone()));
        let out = engine.run();
        assert_eq!(out.report.completed, 600);

        // migrations actually happened, and conserved requests
        let migrated_in: u64 =
            out.shard_stats.iter().map(|s| s.migrated_in).sum();
        let migrated_out: u64 =
            out.shard_stats.iter().map(|s| s.migrated_out).sum();
        assert!(migrated_in > 0, "no migration occurred: {:?}", out.shard_stats);
        assert_eq!(migrated_in, migrated_out);

        // round trip: every completion's reward landed on the router
        // that issued its tag — tags are minted at routing time by the
        // destination shard, so a migrated run's feedback must decode
        // there (residue check: tag ≡ hint mod 4)
        let log = log.lock().unwrap();
        assert!(!log.is_empty());
        for &(hint, tag) in log.iter() {
            assert_eq!(
                tag % 4,
                hint,
                "feedback tag {tag} delivered to shard {hint}: stale \
                 cross-shard tag leak"
            );
        }

        // trace re-attribution: each migration re-emits an assign record
        // for the destination shard, so assign totals must account for
        // placements plus migrations
        let assigns = recorder
            .events()
            .iter()
            .filter(|ev| matches!(ev, TraceEvent::Assign { .. }))
            .count() as u64;
        let assigned: u64 = out.shard_stats.iter().map(|s| s.assigned).sum();
        assert_eq!(assigns, assigned + migrated_in);
    }

    #[test]
    fn telemetry_depths_count_gate_held_requests() {
        // trickle admission: a tiny quantum makes the gate itself the
        // queue. Depth telemetry must see that backlog even though no
        // shard FIFO ever grows — a depth signal that ignored the gate
        // would read a fully backpressured cluster as idle.
        let mut cfg = small_cfg(200, 300.0);
        cfg.workload.tenants = 4;
        cfg.admission.kind = AdmissionKind::Drr;
        cfg.admission.quantum = 0.05; // ~10 admits/s per tenant
        cfg.admission.burst_cap = 1.0;
        cfg.admission.queue_cap = 512; // hold, don't shed
        let widths = cfg.scheduler.widths.clone();
        let out = run_with(cfg, Box::new(RandomRouter::new(widths, true, 4)));
        assert_eq!(out.report.completed + out.shed, 200);
        let max_depth = out
            .telemetry
            .shard_depths
            .iter()
            .map(Summary::max)
            .fold(0.0, f64::max);
        assert!(
            max_depth > 50.0,
            "gate backlog invisible to depth telemetry: {max_depth}"
        );
    }

    #[test]
    fn drr_flash_crowd_sheds_degrades_and_stays_deterministic() {
        let mk = || {
            let mut cfg = Config::default();
            crate::sim::scenarios::apply_named("flash-crowd", &mut cfg)
                .expect("registered scenario");
            cfg.workload.total_requests = 400;
            // every request asks for the full width, so any slim
            // execution can only come from the gate's overload
            // degradation
            cfg.workload.width_mix = vec![1.0];
            cfg.seed = 7;
            let widths = cfg.scheduler.widths.clone();
            run_with(cfg, Box::new(EdfRouter::new(widths, 4)))
        };
        let a = mk();
        assert_eq!(a.report.completed + a.shed, 400);
        assert!(a.shed > 0, "the 10x spike must overflow the queue cap");
        assert_eq!(a.e2e_latency.count(), a.report.completed as usize);
        assert!(
            a.width_count(0.25) > 0,
            "hot-tenant requests were never degraded: {:?}",
            a.width_histogram
        );
        assert!(a.max_starvation_s > 0.0);

        // per-tenant accounting conserves the workload exactly
        let arrived: u64 = a.tenant_stats.iter().map(|s| s.arrivals).sum();
        let done: u64 = a.tenant_stats.iter().map(|s| s.done).sum();
        let shed: u64 = a.tenant_stats.iter().map(|s| s.shed).sum();
        assert_eq!(arrived, 400);
        assert_eq!(done, a.report.completed);
        assert_eq!(shed, a.shed);
        let jl = a.jain_latency();
        let jt = a.jain_throughput();
        assert!(jl > 0.0 && jl <= 1.0, "jain_latency = {jl}");
        assert!(jt > 0.0 && jt <= 1.0, "jain_throughput = {jt}");

        // bit-determinism per seed, gate and all
        let b = mk();
        assert_eq!(a.report.completed, b.report.completed);
        assert_eq!(a.shed, b.shed);
        assert_eq!(a.width_histogram, b.width_histogram);
        assert_eq!(
            a.report.latency.mean().to_bits(),
            b.report.latency.mean().to_bits()
        );
        assert_eq!(a.jain_latency().to_bits(), b.jain_latency().to_bits());
    }

    #[test]
    fn device_dropout_still_completes_every_request() {
        let mut cfg = small_cfg(250, 150.0);
        cfg.dropout = Some(DropoutCfg { server: 0, at_s: 0.3 });
        let widths = cfg.scheduler.widths.clone();
        let out = run_with(cfg, Box::new(RandomRouter::new(widths, true, 4)));
        assert_eq!(out.report.completed, 250);
        assert_eq!(out.e2e_latency.count(), 250);
    }

    #[test]
    fn dropout_shifts_load_off_the_dead_server() {
        // hammer server 0 via round-robin, kill it early: the survivors
        // must absorb everything and the run still drains.
        let mut cfg = small_cfg(300, 200.0);
        cfg.dropout = Some(DropoutCfg { server: 2, at_s: 0.2 });
        let widths = cfg.scheduler.widths.clone();
        let out = run_with(cfg, Box::new(RoundRobinRouter::new(widths, 4)));
        assert_eq!(out.report.completed, 300);
        // the dead server stops dispatching after the dropout instant, so
        // its share of loads is below an even split
        let loads: Vec<u64> = out.greedy_stats.iter().map(|s| s.loads).collect();
        let total: u64 = loads.iter().sum();
        assert!(total > 0);
        assert!(
            (loads[2] as f64) < total as f64 / 2.0,
            "dead server kept working: {loads:?}"
        );
    }
}
