//! Algorithm 1 — the per-server greedy segment-slim scheduler.
//!
//! The worker repeatedly forms a batch from the FIFO head's key and
//! assigns it to a free instance of the same segment with the smallest
//! width ≥ the requested width. If none exists it opportunistically
//! scales up (up to `N_new` new instances when the queue is past `Q_th`,
//! one otherwise), guarded by the VRAM budget `M_max` and the live
//! GPU-utilization block threshold `U_blk`. Idle instances are offloaded
//! after `t_idle` to release memory.
//!
//! The scheduler is device-agnostic: VRAM and utilization checks go
//! through [`DeviceGate`], implemented by the simulator's `SimDevice` and
//! by the real-serving wrapper around the PJRT executor.

use crate::config::SchedulerCfg;
use crate::model::ModelMeta;

use super::instance::InstancePool;
use super::queue::{KeyedFifo, Queued};
use super::request::BatchKey;

/// What the scheduler needs from the device it manages.
pub trait DeviceGate {
    /// Reserve VRAM; false when physically impossible.
    fn try_alloc(&mut self, bytes: u64) -> bool;
    /// Release a prior reservation.
    fn free(&mut self, bytes: u64);
    /// Live GPU utilization in percent (U_blk comparisons).
    fn util_pct(&self) -> f64;
    /// Bytes currently reserved (M_max budget comparisons).
    fn vram_used(&self) -> u64;
}

impl DeviceGate for crate::sim::SimDevice {
    fn try_alloc(&mut self, bytes: u64) -> bool {
        self.try_alloc_vram(bytes)
    }
    fn free(&mut self, bytes: u64) {
        self.free_vram(bytes)
    }
    fn util_pct(&self) -> f64 {
        crate::sim::SimDevice::util_pct(self)
    }
    fn vram_used(&self) -> u64 {
        crate::sim::SimDevice::vram_used(self)
    }
}

/// A batch handed to an instance for execution.
#[derive(Clone, Debug)]
pub struct Dispatch {
    pub instance_id: u64,
    /// Width the instance executes at (>= every request's granted width).
    pub width: f64,
    pub key: BatchKey,
    pub batch: Vec<Queued>,
    /// Extra latency charged when this dispatch had to cold-load its
    /// instance (weights transfer over PCIe).
    pub load_penalty_s: f64,
}

/// Counters for ablations/telemetry.
#[derive(Clone, Debug, Default)]
pub struct GreedyStats {
    pub loads: u64,
    pub unloads: u64,
    pub blocked_by_vram: u64,
    pub blocked_by_util: u64,
    pub requeues: u64,
    pub dispatches: u64,
}

/// Per-server greedy scheduler state.
#[derive(Clone, Debug)]
pub struct GreedyScheduler {
    pub cfg: SchedulerCfg,
    pub meta: ModelMeta,
    pub fifo: KeyedFifo,
    pub pool: InstancePool,
    pub stats: GreedyStats,
    /// PCIe-style weight-upload bandwidth for cold-load penalties.
    pub load_bw_bytes_per_s: f64,
}

impl GreedyScheduler {
    pub fn new(cfg: SchedulerCfg, meta: ModelMeta) -> Self {
        GreedyScheduler {
            cfg,
            meta,
            fifo: KeyedFifo::new(),
            pool: InstancePool::new(),
            stats: GreedyStats::default(),
            load_bw_bytes_per_s: 8.0e9,
        }
    }

    /// Enqueue a routed request at this server.
    pub fn enqueue(&mut self, q: Queued) {
        self.fifo.push_back(q);
    }

    /// VRAM an instance of (seg, width) pins here (semantic slimmed cost,
    /// sized for the batch limit).
    fn instance_bytes(&self, seg: usize, width: f64) -> u64 {
        self.meta.instance_vram_semantic(seg, width, self.cfg.b_max)
    }

    /// CANLOAD (Algorithm 1): VRAM budget then utilization threshold.
    fn can_load(&mut self, bytes: u64, gate: &mut dyn DeviceGate) -> CanLoad {
        if gate.vram_used() + bytes > self.cfg.m_max_bytes {
            self.stats.blocked_by_vram += 1;
            return CanLoad::VramBudget;
        }
        let util = gate.util_pct();
        if util >= self.cfg.u_blk_pct {
            self.stats.blocked_by_util += 1;
            return CanLoad::UtilBlocked;
        }
        if !gate.try_alloc(bytes) {
            self.stats.blocked_by_vram += 1;
            return CanLoad::VramPhysical;
        }
        CanLoad::Ok
    }

    /// Cold-load penalty: slimmed weights over the upload link.
    fn load_penalty(&self, seg: usize, width: f64) -> f64 {
        (self.meta.seg_weight_bytes(seg) as f64 * width * width)
            / self.load_bw_bytes_per_s
    }

    /// One scheduling sweep (Algorithm 1's LOOP body, run to quiescence):
    /// forms batches and assigns instances until the FIFO head cannot be
    /// served. Returns the dispatches for the engine to execute.
    pub fn step(&mut self, now: f64, gate: &mut dyn DeviceGate) -> Vec<Dispatch> {
        let mut out = Vec::new();
        loop {
            let Some(key) = self.fifo.head_key() else { break };
            let batch = self.fifo.pop_batch(self.cfg.b_max);
            debug_assert!(!batch.is_empty());

            let mut load_penalty = 0.0;
            let mut inst = self.pool.find_free_best_fit(key.seg, key.width());
            if inst.is_none() {
                // opportunistic scale-up for key k
                let bytes = self.instance_bytes(key.seg, key.width());
                let extra = if self.fifo.len() + batch.len() > self.cfg.q_th {
                    self.cfg.n_new
                } else {
                    1
                };
                for _ in 0..extra.max(1) {
                    match self.can_load(bytes, gate) {
                        CanLoad::Ok => {
                            let id =
                                self.pool.load(key.seg, key.width(), bytes, now);
                            self.stats.loads += 1;
                            if inst.is_none() {
                                inst = Some(id);
                                load_penalty =
                                    self.load_penalty(key.seg, key.width());
                            }
                        }
                        _ => break,
                    }
                }
            }

            match inst {
                None => {
                    // Algorithm 1 line 9: requeue to front, wait for a
                    // completion or unload to change the situation.
                    self.stats.requeues += 1;
                    self.fifo.requeue_front(batch);
                    break;
                }
                Some(id) => {
                    let (width, _) = self.pool.checkout(id).expect("free instance");
                    self.stats.dispatches += 1;
                    out.push(Dispatch {
                        instance_id: id,
                        width,
                        key,
                        batch,
                        load_penalty_s: load_penalty,
                    });
                }
            }
        }
        out
    }

    /// Batch completion: release the instance.
    pub fn complete(&mut self, instance_id: u64, now: f64) {
        self.pool.checkin(instance_id, now);
    }

    /// UNLOADERLOOP: offload instances idle past t_idle, releasing VRAM.
    pub fn unload_idle(&mut self, now: f64, gate: &mut dyn DeviceGate) -> usize {
        let freed = self.pool.unload_idle(now, self.cfg.t_idle_s);
        for (_, bytes) in &freed {
            gate.free(*bytes);
            self.stats.unloads += 1;
        }
        freed.len()
    }

    /// Local queue length (telemetry q_t^(i)).
    pub fn queue_len(&self) -> usize {
        self.fifo.len()
    }
}

#[derive(Debug, PartialEq)]
enum CanLoad {
    Ok,
    VramBudget,
    VramPhysical,
    UtilBlocked,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SchedulerCfg;
    use crate::coordinator::request::Request;
    use crate::sim::{profiles, SimDevice};

    fn sched(cfg: SchedulerCfg) -> GreedyScheduler {
        GreedyScheduler::new(cfg, ModelMeta::default())
    }

    fn queued(id: u64, seg: usize, width: f64) -> Queued {
        let mut req = Request::new(id, 0.0, width);
        req.seg = seg;
        req.w_prev = if seg == 0 { 1.0 } else { 0.5 };
        Queued { req, width }
    }

    #[test]
    fn dispatches_matching_batch_with_scale_up() {
        let mut s = sched(SchedulerCfg::default());
        let mut dev = SimDevice::new(profiles::rtx2080ti());
        s.enqueue(queued(0, 0, 0.5));
        s.enqueue(queued(1, 0, 0.5));
        let ds = s.step(0.0, &mut dev);
        assert_eq!(ds.len(), 1);
        assert_eq!(ds[0].batch.len(), 2);
        assert_eq!(ds[0].width, 0.5);
        assert!(ds[0].load_penalty_s > 0.0); // cold load
        assert_eq!(s.stats.loads, 1);
        assert!(dev.vram_used() > 0);
    }

    #[test]
    fn second_batch_reuses_warm_instance() {
        let mut s = sched(SchedulerCfg::default());
        let mut dev = SimDevice::new(profiles::rtx2080ti());
        s.enqueue(queued(0, 1, 0.25));
        let d1 = s.step(0.0, &mut dev);
        s.complete(d1[0].instance_id, 0.1);
        s.enqueue(queued(1, 1, 0.25));
        let d2 = s.step(0.2, &mut dev);
        assert_eq!(d2[0].instance_id, d1[0].instance_id);
        assert_eq!(d2[0].load_penalty_s, 0.0); // warm
        assert_eq!(s.stats.loads, 1);
    }

    #[test]
    fn busy_instance_causes_scale_up_then_requeue_at_vram_limit() {
        let mut cfg = SchedulerCfg::default();
        cfg.m_max_bytes = 0; // no budget at all
        let mut s = sched(cfg);
        let mut dev = SimDevice::new(profiles::rtx2080ti());
        s.enqueue(queued(0, 0, 0.5));
        let ds = s.step(0.0, &mut dev);
        assert!(ds.is_empty());
        assert_eq!(s.stats.requeues, 1);
        assert!(s.stats.blocked_by_vram >= 1);
        assert_eq!(s.queue_len(), 1); // request still queued
    }

    #[test]
    fn util_threshold_blocks_loading() {
        let mut cfg = SchedulerCfg::default();
        cfg.u_blk_pct = 10.0;
        let mut s = sched(cfg);
        let mut dev = SimDevice::new(profiles::rtx2080ti());
        // drive utilization above the threshold
        dev.begin_batch(0.0, 1_000_000_000, 1_000_000, 8, 1.0);
        assert!(dev.util_pct() > 10.0);
        s.enqueue(queued(0, 0, 0.5));
        let ds = s.step(0.0, &mut dev);
        assert!(ds.is_empty());
        assert!(s.stats.blocked_by_util >= 1);
    }

    #[test]
    fn unload_idle_releases_vram() {
        let mut cfg = SchedulerCfg::default();
        cfg.t_idle_s = 1.0;
        let mut s = sched(cfg);
        let mut dev = SimDevice::new(profiles::rtx2080ti());
        s.enqueue(queued(0, 2, 1.0));
        let ds = s.step(0.0, &mut dev);
        s.complete(ds[0].instance_id, 0.5);
        let used = dev.vram_used();
        assert!(used > 0);
        assert_eq!(s.unload_idle(0.6, &mut dev), 0); // not idle long enough
        assert_eq!(s.unload_idle(2.0, &mut dev), 1);
        assert_eq!(dev.vram_used(), 0);
        assert_eq!(s.stats.unloads, 1);
    }

    #[test]
    fn wider_idle_instance_serves_slimmer_request() {
        let mut s = sched(SchedulerCfg::default());
        let mut dev = SimDevice::new(profiles::rtx2080ti());
        // warm a full-width instance
        s.enqueue(queued(0, 3, 1.0));
        let d1 = s.step(0.0, &mut dev);
        s.complete(d1[0].instance_id, 0.1);
        // a 0.25-width request: best-fit prefers a fresh 0.25 load only if
        // no free wider instance... Algorithm 1 picks smallest width >= req,
        // and the warm 1.0 instance qualifies, so NO new load happens.
        s.enqueue(queued(1, 3, 0.25));
        let d2 = s.step(0.2, &mut dev);
        assert_eq!(d2[0].instance_id, d1[0].instance_id);
        assert_eq!(d2[0].width, 1.0); // executed at the instance's width
        assert_eq!(s.stats.loads, 1);
    }

    #[test]
    fn queue_pressure_loads_n_new_instances() {
        let mut cfg = SchedulerCfg::default();
        cfg.q_th = 4;
        cfg.n_new = 3;
        cfg.b_max = 2;
        let mut s = sched(cfg);
        let mut dev = SimDevice::new(profiles::rtx2080ti());
        for i in 0..10 {
            s.enqueue(queued(i, 0, 0.5));
        }
        let ds = s.step(0.0, &mut dev);
        // queue (10) > q_th: first miss loads up to n_new=3 instances and
        // the sweep keeps dispatching onto them
        assert!(s.stats.loads >= 3, "loads={}", s.stats.loads);
        assert!(ds.len() >= 3);
    }

    #[test]
    fn property_step_never_loses_requests() {
        crate::utilx::prop::check("greedy-conservation", 30, |rng| {
            let mut cfg = SchedulerCfg::default();
            cfg.b_max = rng.index(6) + 1;
            cfg.q_th = rng.index(10);
            cfg.n_new = rng.index(3) + 1;
            let mut s = sched(cfg);
            let mut dev = SimDevice::new(profiles::toy_gpu());
            let n = rng.index(40) + 1;
            for i in 0..n {
                let seg = rng.index(4);
                let w = [0.25, 0.5, 0.75, 1.0][rng.index(4)];
                s.enqueue(queued(i as u64, seg, w));
            }
            let ds = s.step(0.0, &mut dev);
            let dispatched: usize = ds.iter().map(|d| d.batch.len()).sum();
            let left = s.queue_len();
            if dispatched + left != n {
                return Err(format!(
                    "lost requests: {dispatched} dispatched + {left} queued != {n}"
                ));
            }
            // all dispatched instances exist & are busy
            for d in &ds {
                let inst = s.pool.get(d.instance_id).ok_or("missing instance")?;
                if !inst.busy {
                    return Err("dispatched to non-busy instance".into());
                }
                if inst.width < d.key.width() - 1e-9 {
                    return Err("instance narrower than requested".into());
                }
            }
            Ok(())
        });
    }
}
