//! Loaded model instances and the best-fit pool.
//!
//! An instance is one (segment, width) executable pinned in a device's
//! VRAM — in simulation a VRAM-ledger entry, on the real serving path a
//! compiled PJRT executable. Algorithm 1's FINDFREEBESTFIT picks the free
//! instance of the right segment with the *smallest* width ≥ the
//! requested width, so slim requests prefer slim instances but can
//! upgrade when only wider ones are idle.

use super::request::wkey;

/// One loaded (segment, width) executable.
#[derive(Clone, Debug)]
pub struct Instance {
    pub id: u64,
    pub seg: usize,
    pub width: f64,
    /// VRAM bytes charged while loaded.
    pub vram_bytes: u64,
    pub busy: bool,
    /// Last time the instance finished work (for t_idle offload).
    pub t_last: f64,
    /// Total batches served (telemetry / ablation).
    pub served: u64,
}

/// Per-server instance pool.
#[derive(Clone, Debug, Default)]
pub struct InstancePool {
    instances: Vec<Instance>,
    next_id: u64,
}

impl InstancePool {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a freshly loaded instance; returns its id.
    pub fn load(&mut self, seg: usize, width: f64, vram_bytes: u64, now: f64) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.instances.push(Instance {
            id,
            seg,
            width,
            vram_bytes,
            busy: false,
            t_last: now,
            served: 0,
        });
        id
    }

    /// FINDFREEBESTFIT: free instance with `seg` and minimal width ≥ w_req.
    pub fn find_free_best_fit(&self, seg: usize, w_req: f64) -> Option<u64> {
        self.instances
            .iter()
            .filter(|i| !i.busy && i.seg == seg && i.width >= w_req - 1e-9)
            .min_by_key(|i| (wkey(i.width), i.id))
            .map(|i| i.id)
    }

    /// Any instance (busy or not) matching (seg, width)? — used to decide
    /// whether a scale-up would duplicate an existing key.
    pub fn count_for(&self, seg: usize, width: f64) -> usize {
        self.instances
            .iter()
            .filter(|i| i.seg == seg && wkey(i.width) == wkey(width))
            .count()
    }

    pub fn get(&self, id: u64) -> Option<&Instance> {
        self.instances.iter().find(|i| i.id == id)
    }

    pub fn get_mut(&mut self, id: u64) -> Option<&mut Instance> {
        self.instances.iter_mut().find(|i| i.id == id)
    }

    /// Mark busy and return (width, vram) for dispatch accounting.
    pub fn checkout(&mut self, id: u64) -> Option<(f64, u64)> {
        let inst = self.get_mut(id)?;
        debug_assert!(!inst.busy);
        inst.busy = true;
        Some((inst.width, inst.vram_bytes))
    }

    /// Mark idle after a batch completes.
    pub fn checkin(&mut self, id: u64, now: f64) {
        if let Some(inst) = self.get_mut(id) {
            inst.busy = false;
            inst.t_last = now;
            inst.served += 1;
        }
    }

    /// Remove all non-busy instances idle since before `now - t_idle`;
    /// returns the freed (id, vram_bytes) pairs (UNLOADERLOOP).
    pub fn unload_idle(&mut self, now: f64, t_idle: f64) -> Vec<(u64, u64)> {
        let mut freed = Vec::new();
        self.instances.retain(|i| {
            let stale = !i.busy && now - i.t_last >= t_idle;
            if stale {
                freed.push((i.id, i.vram_bytes));
            }
            !stale
        });
        freed
    }

    pub fn len(&self) -> usize {
        self.instances.len()
    }

    pub fn is_empty(&self) -> bool {
        self.instances.is_empty()
    }

    pub fn busy_count(&self) -> usize {
        self.instances.iter().filter(|i| i.busy).count()
    }

    pub fn total_vram(&self) -> u64 {
        self.instances.iter().map(|i| i.vram_bytes).sum()
    }

    pub fn iter(&self) -> impl Iterator<Item = &Instance> {
        self.instances.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn best_fit_prefers_smallest_sufficient_width() {
        let mut pool = InstancePool::new();
        pool.load(1, 1.0, 100, 0.0);
        let id_half = pool.load(1, 0.5, 100, 0.0);
        pool.load(1, 0.75, 100, 0.0);
        pool.load(0, 0.5, 100, 0.0); // wrong segment
        assert_eq!(pool.find_free_best_fit(1, 0.5), Some(id_half));
        assert_eq!(pool.find_free_best_fit(1, 0.3), Some(id_half));
    }

    #[test]
    fn best_fit_skips_busy_and_too_narrow() {
        let mut pool = InstancePool::new();
        let id_half = pool.load(2, 0.5, 100, 0.0);
        let id_full = pool.load(2, 1.0, 100, 0.0);
        pool.checkout(id_half);
        assert_eq!(pool.find_free_best_fit(2, 0.5), Some(id_full));
        pool.checkout(id_full);
        assert_eq!(pool.find_free_best_fit(2, 0.5), None);
        // narrow instance can't serve a wide request
        pool.checkin(id_half, 1.0);
        assert_eq!(pool.find_free_best_fit(2, 0.75), None);
    }

    #[test]
    fn checkout_checkin_cycle() {
        let mut pool = InstancePool::new();
        let id = pool.load(0, 0.25, 555, 0.0);
        let (w, vram) = pool.checkout(id).unwrap();
        assert_eq!(w, 0.25);
        assert_eq!(vram, 555);
        assert_eq!(pool.busy_count(), 1);
        pool.checkin(id, 3.0);
        assert_eq!(pool.busy_count(), 0);
        let inst = pool.get(id).unwrap();
        assert_eq!(inst.t_last, 3.0);
        assert_eq!(inst.served, 1);
    }

    #[test]
    fn unload_idle_frees_only_stale_nonbusy() {
        let mut pool = InstancePool::new();
        let id_stale = pool.load(0, 0.5, 100, 0.0);
        let id_fresh = pool.load(0, 0.5, 200, 9.5);
        let id_busy = pool.load(0, 1.0, 300, 0.0);
        pool.checkout(id_busy);

        let freed = pool.unload_idle(10.0, 5.0);
        assert_eq!(freed, vec![(id_stale, 100)]);
        assert_eq!(pool.len(), 2);
        assert!(pool.get(id_fresh).is_some());
        assert!(pool.get(id_busy).is_some());
    }

    #[test]
    fn count_for_matches_key() {
        let mut pool = InstancePool::new();
        pool.load(1, 0.5, 1, 0.0);
        pool.load(1, 0.5, 1, 0.0);
        pool.load(1, 0.75, 1, 0.0);
        assert_eq!(pool.count_for(1, 0.5), 2);
        assert_eq!(pool.count_for(1, 0.75), 1);
        assert_eq!(pool.count_for(0, 0.5), 0);
    }

    #[test]
    fn total_vram_sums() {
        let mut pool = InstancePool::new();
        pool.load(0, 1.0, 100, 0.0);
        pool.load(1, 1.0, 250, 0.0);
        assert_eq!(pool.total_vram(), 350);
    }
}
