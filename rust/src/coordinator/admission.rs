//! Deficit-round-robin admission control.
//!
//! A `DrrGate` sits between arrival and shard routing: every arrival is
//! offered to its tenant's pending queue (finite — overflow sheds
//! deterministically), and an admission tick drains the queues
//! round-robin under a credit discipline. Each backlogged tenant
//! accrues `quantum` credits per tick up to a `burst_cap` ceiling
//! (`can_serve` / `charge`, one credit per admitted request), the scan
//! examines at most `scan_width` tenants per tick resuming where the
//! previous tick's cursor stopped, and at most `batch_max` requests are
//! admitted per tick across all tenants. The overload policy degrades a
//! tenant whose backlog exceeds `degrade_depth` to the slimmest width —
//! serve the flash crowd slim instead of queueing it to death.
//!
//! Everything here is a pure function of the offered arrival sequence
//! and the config — no RNG, no hash iteration — so an admitted stream
//! is byte-deterministic per seed, which is what lets `--admission drr`
//! traces round-trip record→replay→re-record byte-identically.

use std::collections::VecDeque;

use crate::config::AdmissionCfg;

use super::request::Request;

/// Credits one admission costs (`charge` subtracts it, `can_serve`
/// checks it).
const SERVE_COST: f64 = 1.0;

/// Outcome of offering an arrival to the gate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Offer {
    /// Parked in the tenant's pending queue; a later tick admits it.
    Queued,
    /// The tenant's pending queue is full — the request is shed
    /// (deterministic backpressure, never served).
    Shed,
}

#[derive(Clone, Debug, Default)]
struct TenantState {
    credit: f64,
    pending: VecDeque<Request>,
    /// Offers shed against this tenant's full queue.
    shed: u64,
    /// Admissions degraded to the slim width for this tenant.
    degraded: u64,
    /// Ticks where this tenant's positive deficit was forfeited
    /// (queue went empty while credit remained).
    forfeits: u64,
    /// Remaining cooldown ticks during which this tenant accrues no
    /// credit (Kaskade-style failure cooldown; armed by a shed when
    /// `cooldown_ticks > 0`).
    cooldown: u64,
    /// Cooldown windows entered by this tenant.
    cooldowns: u64,
}

/// The deficit-round-robin admission gate.
#[derive(Clone, Debug)]
pub struct DrrGate {
    cfg: AdmissionCfg,
    /// Per-tenant state, indexed by tenant id (grown on first offer).
    tenants: Vec<TenantState>,
    /// Round-robin scan cursor — the tenant the next tick starts at.
    cursor: usize,
    /// Requests currently parked across all tenants.
    pending_total: usize,
    /// Requests shed at offer time (queue-cap overflow).
    pub shed: u64,
    /// Requests admitted with their width degraded by the overload
    /// policy.
    pub degraded: u64,
}

impl DrrGate {
    pub fn new(cfg: AdmissionCfg) -> Self {
        DrrGate {
            cfg,
            tenants: Vec::new(),
            cursor: 0,
            pending_total: 0,
            shed: 0,
            degraded: 0,
        }
    }

    fn state_mut(&mut self, tenant: u16) -> &mut TenantState {
        let idx = tenant as usize;
        if idx >= self.tenants.len() {
            self.tenants.resize_with(idx + 1, TenantState::default);
        }
        &mut self.tenants[idx]
    }

    /// Offer an arrival: parked behind the tenant's backlog, or shed if
    /// the finite queue is full.
    pub fn offer(&mut self, req: Request) -> Offer {
        let cap = self.cfg.queue_cap;
        let cooldown_ticks = self.cfg.cooldown_ticks;
        let st = self.state_mut(req.tenant);
        if st.pending.len() >= cap {
            st.shed += 1;
            self.shed += 1;
            // Kaskade-style failure cooldown: a shed (re)arms the
            // window; the tenant re-accrues credit only after it
            // expires. Off (0) leaves the accrual path untouched.
            if cooldown_ticks > 0 {
                if st.cooldown == 0 {
                    st.cooldowns += 1;
                }
                st.cooldown = cooldown_ticks;
            }
            return Offer::Shed;
        }
        st.pending.push_back(req);
        self.pending_total += 1;
        Offer::Queued
    }

    /// Whether `tenant` has enough credit for one admission.
    pub fn can_serve(&self, tenant: u16) -> bool {
        self.tenants
            .get(tenant as usize)
            .is_some_and(|st| st.credit >= SERVE_COST)
    }

    /// Spend one admission's worth of `tenant`'s credit.
    pub fn charge(&mut self, tenant: u16) {
        self.state_mut(tenant).credit -= SERVE_COST;
    }

    /// One admission tick: accrue credits for backlogged tenants, then
    /// scan up to `scan_width` tenants from the cursor and admit up to
    /// `batch_max` requests total, round-robin. Admitted requests are
    /// appended to `out` (not cleared) in deterministic scan order;
    /// requests from tenants deeper than `degrade_depth` are degraded
    /// to `slim_width`.
    pub fn tick(&mut self, out: &mut Vec<Request>, slim_width: f64) {
        if self.pending_total == 0 {
            return;
        }
        for st in &mut self.tenants {
            let cooling = st.cooldown > 0;
            if cooling {
                st.cooldown -= 1;
            }
            if st.pending.is_empty() {
                // classic DRR: an empty queue forfeits its deficit, so
                // idle tenants can't hoard credit beyond the cap
                if st.credit > 0.0 {
                    st.forfeits += 1;
                }
                st.credit = 0.0;
            } else if !cooling {
                st.credit = (st.credit + self.cfg.quantum).min(self.cfg.burst_cap);
            }
        }
        let n = self.tenants.len();
        let mut admitted = 0usize;
        let mut next_cursor = self.cursor % n.max(1);
        for step in 0..n.min(self.cfg.scan_width) {
            if admitted >= self.cfg.batch_max {
                break;
            }
            let idx = (self.cursor + step) % n;
            next_cursor = (idx + 1) % n;
            let degrade = self.tenants[idx].pending.len() > self.cfg.degrade_depth
                && self.cfg.degrade_depth > 0;
            let st = &mut self.tenants[idx];
            while st.credit >= SERVE_COST && admitted < self.cfg.batch_max {
                let Some(mut req) = st.pending.pop_front() else {
                    break;
                };
                st.credit -= SERVE_COST;
                self.pending_total -= 1;
                admitted += 1;
                if degrade && req.w_req > slim_width {
                    req.w_req = slim_width;
                    st.degraded += 1;
                    self.degraded += 1;
                }
                out.push(req);
            }
        }
        self.cursor = next_cursor;
    }

    /// Requests parked across all tenants.
    pub fn pending_total(&self) -> usize {
        self.pending_total
    }

    /// Requests parked for one tenant.
    pub fn pending_for(&self, tenant: u16) -> usize {
        self.tenants
            .get(tenant as usize)
            .map_or(0, |st| st.pending.len())
    }

    /// Tenant ids the gate has seen (dense upper bound).
    pub fn tenant_count(&self) -> usize {
        self.tenants.len()
    }

    /// Per-tenant `(shed, degraded, credit_forfeits, cooldowns)`
    /// counters; unknown tenants report zeros.
    pub fn tenant_counters(&self, tenant: u16) -> (u64, u64, u64, u64) {
        self.tenants.get(tenant as usize).map_or((0, 0, 0, 0), |st| {
            (st.shed, st.degraded, st.forfeits, st.cooldowns)
        })
    }

    /// Total deficit forfeits across tenants.
    pub fn credit_forfeits(&self) -> u64 {
        self.tenants.iter().map(|st| st.forfeits).sum()
    }

    /// Total cooldown windows entered across tenants.
    pub fn cooldowns_total(&self) -> u64 {
        self.tenants.iter().map(|st| st.cooldowns).sum()
    }

    /// Control-plane hook: retune the gate's credit/queue knobs in
    /// place. Existing credits and queues are untouched — the new
    /// values take effect from the next offer/tick.
    pub fn set_knobs(&mut self, quantum: f64, burst_cap: f64, queue_cap: usize) {
        self.cfg.quantum = quantum;
        self.cfg.burst_cap = burst_cap;
        self.cfg.queue_cap = queue_cap;
    }

    pub fn is_empty(&self) -> bool {
        self.pending_total == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AdmissionKind;

    fn gate(quantum: f64, burst_cap: f64, queue_cap: usize) -> DrrGate {
        DrrGate::new(AdmissionCfg {
            kind: AdmissionKind::Drr,
            quantum,
            burst_cap,
            scan_width: 16,
            batch_max: 64,
            queue_cap,
            degrade_depth: 0,
            cooldown_ticks: 0,
        })
    }

    fn req(id: u64, tenant: u16) -> Request {
        Request::new(id, id as f64 * 0.01, 1.0).with_tenant(tenant)
    }

    #[test]
    fn credits_accrue_and_admit_round_robin() {
        let mut g = gate(1.0, 8.0, 64);
        for id in 0..6 {
            assert_eq!(g.offer(req(id, (id % 2) as u16)), Offer::Queued);
        }
        assert_eq!(g.pending_total(), 6);
        let mut out = Vec::new();
        g.tick(&mut out, 0.25);
        // quantum 1.0: each backlogged tenant admits exactly one per tick
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].tenant, 0);
        assert_eq!(out[1].tenant, 1);
        g.tick(&mut out, 0.25);
        g.tick(&mut out, 0.25);
        assert_eq!(out.len(), 6);
        assert!(g.is_empty());
    }

    #[test]
    fn burst_cap_bounds_idle_credit() {
        let mut g = gate(4.0, 6.0, 64);
        g.offer(req(0, 0));
        // many ticks against a single pending request: credit would
        // grow 4/tick unbounded without the cap
        let mut out = Vec::new();
        g.tick(&mut out, 0.25);
        assert_eq!(out.len(), 1);
        for id in 1..40 {
            g.offer(req(id, 0));
        }
        out.clear();
        g.tick(&mut out, 0.25);
        // one tick admits at most burst_cap (6) worth, not the backlog
        assert_eq!(out.len(), 6);
    }

    #[test]
    fn can_serve_and_charge_track_credit() {
        let mut g = gate(2.0, 8.0, 64);
        g.offer(req(0, 3));
        assert!(!g.can_serve(3));
        let mut out = Vec::new();
        g.tick(&mut out, 0.25); // accrues 2, admits 1 (cost 1)
        assert_eq!(out.len(), 1);
        assert!(g.can_serve(3)); // one credit left
        g.charge(3);
        assert!(!g.can_serve(3));
        // unknown tenants can never be served
        assert!(!g.can_serve(60_000));
    }

    #[test]
    fn finite_queue_sheds_deterministically() {
        let mut g = gate(1.0, 4.0, 3);
        for id in 0..5 {
            g.offer(req(id, 0));
        }
        assert_eq!(g.pending_for(0), 3);
        assert_eq!(g.shed, 2);
        // shed requests are gone: draining admits only the queued 3
        let mut out = Vec::new();
        for _ in 0..10 {
            g.tick(&mut out, 0.25);
        }
        assert_eq!(out.len(), 3);
        assert_eq!(out.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
    }

    #[test]
    fn overload_degrades_deep_tenants_to_the_slim_width() {
        let mut g = DrrGate::new(AdmissionCfg {
            kind: AdmissionKind::Drr,
            quantum: 2.0,
            burst_cap: 8.0,
            scan_width: 16,
            batch_max: 64,
            queue_cap: 64,
            degrade_depth: 4,
            cooldown_ticks: 0,
        });
        for id in 0..10 {
            g.offer(req(id, 0)); // deep: 10 > 4
        }
        g.offer(req(100, 1)); // shallow
        let mut out = Vec::new();
        g.tick(&mut out, 0.25);
        let hot: Vec<_> = out.iter().filter(|r| r.tenant == 0).collect();
        let cold: Vec<_> = out.iter().filter(|r| r.tenant == 1).collect();
        assert!(!hot.is_empty() && !cold.is_empty());
        assert!(hot.iter().all(|r| r.w_req == 0.25));
        assert!(cold.iter().all(|r| r.w_req == 1.0));
        assert_eq!(g.degraded, hot.len() as u64);
    }

    #[test]
    fn scan_width_and_batch_max_bound_one_tick() {
        let mut g = DrrGate::new(AdmissionCfg {
            kind: AdmissionKind::Drr,
            quantum: 8.0,
            burst_cap: 8.0,
            scan_width: 2,
            batch_max: 3,
            queue_cap: 64,
            degrade_depth: 0,
            cooldown_ticks: 0,
        });
        for t in 0..4u16 {
            for id in 0..8 {
                g.offer(req(t as u64 * 100 + id, t));
            }
        }
        let mut out = Vec::new();
        g.tick(&mut out, 0.25);
        // batch_max caps the tick at 3 even though 2 tenants × 8 credits
        // could admit more
        assert_eq!(out.len(), 3);
        assert!(out.iter().all(|r| r.tenant <= 1));
        out.clear();
        g.tick(&mut out, 0.25);
        g.tick(&mut out, 0.25);
        // the cursor resumed past the tenants earlier ticks served
        assert!(out.iter().any(|r| r.tenant >= 2), "{out:?}");
    }

    #[test]
    fn empty_queues_forfeit_their_deficit() {
        let mut g = gate(1.0, 8.0, 64);
        g.offer(req(0, 0));
        let mut out = Vec::new();
        for _ in 0..20 {
            g.tick(&mut out, 0.25); // tenant 0 drains, then idles
        }
        assert_eq!(out.len(), 1);
        // after idling, a newly-backlogged tenant starts from zero
        // credit + one quantum — not 20 ticks of hoarded credit
        for id in 1..10 {
            g.offer(req(id, 0));
        }
        out.clear();
        g.tick(&mut out, 0.25);
        assert_eq!(out.len(), 1);
    }

    #[test]
    fn per_tenant_counters_split_the_aggregates() {
        let mut g = DrrGate::new(AdmissionCfg {
            kind: AdmissionKind::Drr,
            quantum: 2.0,
            burst_cap: 8.0,
            scan_width: 16,
            batch_max: 64,
            queue_cap: 3,
            degrade_depth: 2,
            cooldown_ticks: 0,
        });
        // tenant 0: 6 offers into a 3-deep queue → 3 shed, deep → degraded
        for id in 0..6 {
            g.offer(req(id, 0));
        }
        g.offer(req(100, 1));
        let mut out = Vec::new();
        g.tick(&mut out, 0.25);
        let (shed0, deg0, _, _) = g.tenant_counters(0);
        let (shed1, deg1, _, _) = g.tenant_counters(1);
        assert_eq!(shed0, 3);
        assert_eq!(shed1, 0);
        assert!(deg0 > 0);
        assert_eq!(deg1, 0);
        assert_eq!(g.shed, shed0 + shed1);
        assert_eq!(g.degraded, deg0 + deg1);
        // drain tenant 0 fully; its leftover credit is forfeited on a
        // later backlogged tick (tenant 1 keeps the gate non-idle)
        while g.pending_for(0) > 0 {
            g.tick(&mut out, 0.25);
        }
        for id in 0..4 {
            g.offer(req(200 + id, 1));
        }
        g.tick(&mut out, 0.25);
        let (_, _, forfeits0, _) = g.tenant_counters(0);
        let (_, _, forfeits1, _) = g.tenant_counters(1);
        assert!(forfeits0 > 0, "positive idle credit must be forfeited");
        assert_eq!(g.credit_forfeits(), forfeits0 + forfeits1);
        // unknown tenants report zeros
        assert_eq!(g.tenant_counters(42), (0, 0, 0, 0));
    }

    #[test]
    fn cooldown_blocks_accrual_until_it_expires() {
        let mut g = DrrGate::new(AdmissionCfg {
            kind: AdmissionKind::Drr,
            quantum: 1.0,
            burst_cap: 8.0,
            scan_width: 16,
            batch_max: 64,
            queue_cap: 2,
            degrade_depth: 0,
            cooldown_ticks: 3,
        });
        for id in 0..3 {
            g.offer(req(id, 0)); // third overflows the 2-deep queue
        }
        assert_eq!(g.shed, 1);
        assert_eq!(g.tenant_counters(0), (1, 0, 0, 1));
        let mut out = Vec::new();
        // three cooling ticks: no credit accrues, nothing admitted
        for _ in 0..3 {
            g.tick(&mut out, 0.25);
            assert!(out.is_empty());
        }
        // cooldown expired: accrual resumes, the backlog drains
        g.tick(&mut out, 0.25);
        assert_eq!(out.len(), 1);
        // a second shed during an armed window re-arms without
        // counting a new cooldown entry
        g.offer(req(10, 0));
        g.offer(req(11, 0));
        g.offer(req(12, 0));
        let (_, _, _, cd) = g.tenant_counters(0);
        assert_eq!(cd, 2);
        g.offer(req(13, 0));
        let (_, _, _, cd) = g.tenant_counters(0);
        assert_eq!(cd, 2, "re-arm inside an active window is not a new entry");
        assert_eq!(g.cooldowns_total(), 2);
    }

    #[test]
    fn cooldown_off_is_bit_identical_to_the_plain_gate() {
        let run = |cooldown_ticks: u64| {
            // deliberately overloaded (quantum ≪ arrival rate, shallow
            // queues) so both runs shed and the cooldown path is hot
            let mut g = DrrGate::new(AdmissionCfg {
                kind: AdmissionKind::Drr,
                quantum: 0.25,
                burst_cap: 6.0,
                scan_width: 16,
                batch_max: 64,
                queue_cap: 2,
                degrade_depth: 0,
                cooldown_ticks,
            });
            let mut out = Vec::new();
            for id in 0..100 {
                g.offer(req(id, (id % 3) as u16));
                if id % 2 == 0 {
                    g.tick(&mut out, 0.25);
                }
            }
            while !g.is_empty() {
                g.tick(&mut out, 0.25);
            }
            (
                out.iter().map(|r| (r.id, r.tenant)).collect::<Vec<_>>(),
                g.shed,
                g.cooldowns_total(),
            )
        };
        let off = run(0);
        assert_eq!(off.2, 0, "cooldown off must never count a window");
        // armed, the same offered sequence admits differently
        let on = run(4);
        assert!(on.2 > 0);
        assert_ne!(off.0, on.0);
    }

    #[test]
    fn set_knobs_retunes_credit_and_queue_caps_live() {
        let mut g = gate(1.0, 2.0, 8);
        for id in 0..8 {
            g.offer(req(id, 0));
        }
        let mut out = Vec::new();
        g.tick(&mut out, 0.25);
        assert_eq!(out.len(), 1); // quantum 1 admits one
        g.set_knobs(4.0, 8.0, 2);
        out.clear();
        g.tick(&mut out, 0.25);
        assert_eq!(out.len(), 4, "new quantum takes effect next tick");
        // queue cap shrank to 2: with >2 already parked, new offers shed
        assert!(g.pending_for(0) > 2);
        assert_eq!(g.offer(req(50, 0)), Offer::Shed);
    }

    #[test]
    fn same_offer_sequence_is_bit_deterministic() {
        let run = || {
            let mut g = gate(1.5, 6.0, 8);
            let mut out = Vec::new();
            for id in 0..200 {
                g.offer(req(id, (id % 5) as u16));
                if id % 3 == 0 {
                    g.tick(&mut out, 0.25);
                }
            }
            while !g.is_empty() {
                g.tick(&mut out, 0.25);
            }
            (out.iter().map(|r| (r.id, r.tenant)).collect::<Vec<_>>(), g.shed)
        };
        assert_eq!(run(), run());
    }
}
