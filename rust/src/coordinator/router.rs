//! Global routing policies.
//!
//! The router is the top of the hierarchy: given the eq. 1 telemetry
//! snapshot and the FIFO head, it picks `(server, width, micro-batch
//! group)` — the factored action of eq. 2. The greedy executor then
//! realizes the decision locally. Implementations:
//!
//! * [`RandomRouter`] — the paper's Table III baseline (uniform random
//!   task distribution).
//! * [`RoundRobinRouter`] — classic algorithmic comparator.
//! * [`LeastLoadedRouter`] — greedy global comparator (min queue).
//! * `ppo::PpoRouter` (in the [`crate::ppo`] module) — the learned policy
//!   of Tables IV–V; it implements this same trait so every experiment
//!   driver is router-agnostic.

use crate::utilx::Rng;

use super::telemetry::TelemetrySnapshot;

/// A routing decision for the next block (eq. 2's factored action).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Decision {
    pub server: usize,
    pub width: f64,
    /// Micro-batch group size: how many head requests ride this decision.
    pub group: usize,
    /// Correlation tag echoed in feedback (rollout bookkeeping).
    pub tag: u64,
}

/// Post-hoc outcome of a routed block (reward ingredients, eq. 7).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BlockFeedback {
    pub tag: u64,
    /// Accuracy prior p̃_acc ∈ [0,1] of the block's width tuple.
    pub acc_prior_norm: f64,
    /// End-to-end block latency L_t (s).
    pub latency_s: f64,
    /// Block energy E_t = P̄_t · L_t (J).
    pub energy_j: f64,
    /// Var of normalized per-server utilizations at completion.
    pub util_variance: f64,
}

/// Routing policy interface (sim and real serving share it).
pub trait Router: Send {
    fn name(&self) -> &'static str;

    /// Choose (server, width, group) for the FIFO head.
    fn route(
        &mut self,
        snap: &TelemetrySnapshot,
        head_w_req: f64,
        head_seg: usize,
        rng: &mut Rng,
    ) -> Decision;

    /// Outcome of an earlier decision (ignored by stateless routers).
    fn feedback(&mut self, _fb: &BlockFeedback) {}

    /// A routed block was cancelled before executing (device dropout
    /// re-route): no feedback will ever arrive for `tag`. Learning
    /// routers drop the staged transition; stateless routers ignore it.
    fn abandon(&mut self, _tag: u64) {}

    /// Called when the run drains (learning routers flush updates).
    fn end_of_run(&mut self) {}
}

impl Router for Box<dyn Router> {
    fn name(&self) -> &'static str {
        (**self).name()
    }
    fn route(
        &mut self,
        snap: &TelemetrySnapshot,
        head_w_req: f64,
        head_seg: usize,
        rng: &mut Rng,
    ) -> Decision {
        (**self).route(snap, head_w_req, head_seg, rng)
    }
    fn feedback(&mut self, fb: &BlockFeedback) {
        (**self).feedback(fb)
    }
    fn abandon(&mut self, tag: u64) {
        (**self).abandon(tag)
    }
    fn end_of_run(&mut self) {
        (**self).end_of_run()
    }
}

fn snap_width_up(widths: &[f64], w_req: f64) -> f64 {
    widths
        .iter()
        .cloned()
        .filter(|w| *w >= w_req - 1e-9)
        .fold(f64::INFINITY, f64::min)
        .min(widths.iter().cloned().fold(0.0, f64::max))
}

/// Table III baseline: uniformly random server; width honors the request
/// (or is uniformly random when `randomize_width`); fixed group.
pub struct RandomRouter {
    pub widths: Vec<f64>,
    pub randomize_width: bool,
    pub group: usize,
    next_tag: u64,
}

impl RandomRouter {
    pub fn new(widths: Vec<f64>, randomize_width: bool, group: usize) -> Self {
        RandomRouter { widths, randomize_width, group, next_tag: 0 }
    }
}

impl Router for RandomRouter {
    fn name(&self) -> &'static str {
        "random"
    }

    fn route(
        &mut self,
        snap: &TelemetrySnapshot,
        head_w_req: f64,
        _head_seg: usize,
        rng: &mut Rng,
    ) -> Decision {
        let tag = self.next_tag;
        self.next_tag += 1;
        let width = if self.randomize_width {
            *rng.choice(&self.widths)
        } else {
            snap_width_up(&self.widths, head_w_req)
        };
        Decision {
            server: rng.index(snap.servers.len().max(1)),
            width,
            group: self.group,
            tag,
        }
    }
}

/// Strict round-robin over servers.
pub struct RoundRobinRouter {
    pub widths: Vec<f64>,
    pub group: usize,
    cursor: usize,
    next_tag: u64,
}

impl RoundRobinRouter {
    pub fn new(widths: Vec<f64>, group: usize) -> Self {
        RoundRobinRouter { widths, group, cursor: 0, next_tag: 0 }
    }
}

impl Router for RoundRobinRouter {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn route(
        &mut self,
        snap: &TelemetrySnapshot,
        head_w_req: f64,
        _head_seg: usize,
        _rng: &mut Rng,
    ) -> Decision {
        let n = snap.servers.len().max(1);
        let server = self.cursor % n;
        self.cursor = (self.cursor + 1) % n;
        let tag = self.next_tag;
        self.next_tag += 1;
        Decision {
            server,
            width: snap_width_up(&self.widths, head_w_req),
            group: self.group,
            tag,
        }
    }
}

/// Greedy global comparator: route to the server minimizing a load score
/// (queue length + utilization), widen groups under backlog.
pub struct LeastLoadedRouter {
    pub widths: Vec<f64>,
    pub max_group: usize,
    next_tag: u64,
}

impl LeastLoadedRouter {
    pub fn new(widths: Vec<f64>, max_group: usize) -> Self {
        LeastLoadedRouter { widths, max_group, next_tag: 0 }
    }
}

impl Router for LeastLoadedRouter {
    fn name(&self) -> &'static str {
        "least-loaded"
    }

    fn route(
        &mut self,
        snap: &TelemetrySnapshot,
        head_w_req: f64,
        _head_seg: usize,
        _rng: &mut Rng,
    ) -> Decision {
        let server = snap
            .servers
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| {
                let sa = a.queue_len as f64 + a.util_pct / 25.0;
                let sb = b.queue_len as f64 + b.util_pct / 25.0;
                sa.partial_cmp(&sb).unwrap()
            })
            .map(|(i, _)| i)
            .unwrap_or(0);
        let group = if snap.fifo_len > 8 { self.max_group } else { 1 };
        let tag = self.next_tag;
        self.next_tag += 1;
        Decision {
            server,
            width: snap_width_up(&self.widths, head_w_req),
            group,
            tag,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::telemetry::ServerTelemetry;

    fn snap(queues: &[usize], utils: &[f64]) -> TelemetrySnapshot {
        TelemetrySnapshot {
            fifo_len: 20,
            done_count: 0,
            total_requests: 100,
            servers: queues
                .iter()
                .zip(utils)
                .map(|(&q, &u)| ServerTelemetry {
                    queue_len: q,
                    power_w: 100.0,
                    util_pct: u,
                    mem_util: 0.1,
                    instances: 1,
                })
                .collect(),
        }
    }

    const W: [f64; 4] = [0.25, 0.5, 0.75, 1.0];

    #[test]
    fn random_router_covers_all_servers() {
        let mut r = RandomRouter::new(W.to_vec(), false, 4);
        let mut rng = Rng::new(1);
        let s = snap(&[0, 0, 0], &[0.0, 0.0, 0.0]);
        let mut seen = [false; 3];
        for _ in 0..100 {
            let d = r.route(&s, 0.5, 0, &mut rng);
            seen[d.server] = true;
            assert_eq!(d.width, 0.5); // honors request
            assert_eq!(d.group, 4);
        }
        assert_eq!(seen, [true; 3]);
    }

    #[test]
    fn random_router_randomizes_width_when_asked() {
        let mut r = RandomRouter::new(W.to_vec(), true, 1);
        let mut rng = Rng::new(2);
        let s = snap(&[0], &[0.0]);
        let mut widths = std::collections::BTreeSet::new();
        for _ in 0..100 {
            let d = r.route(&s, 0.25, 0, &mut rng);
            widths.insert((d.width * 100.0) as u32);
        }
        assert_eq!(widths.len(), 4);
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = RoundRobinRouter::new(W.to_vec(), 1);
        let mut rng = Rng::new(3);
        let s = snap(&[0, 0, 0], &[0.0, 0.0, 0.0]);
        let servers: Vec<usize> =
            (0..6).map(|_| r.route(&s, 1.0, 0, &mut rng).server).collect();
        assert_eq!(servers, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_picks_min_queue_and_widens_group() {
        let mut r = LeastLoadedRouter::new(W.to_vec(), 16);
        let mut rng = Rng::new(4);
        let s = snap(&[9, 2, 7], &[50.0, 50.0, 50.0]);
        let d = r.route(&s, 0.75, 1, &mut rng);
        assert_eq!(d.server, 1);
        assert_eq!(d.group, 16); // fifo_len 20 > 8
        // utilization tie-breaks queues
        let s2 = snap(&[3, 3], &[95.0, 10.0]);
        assert_eq!(r.route(&s2, 0.75, 1, &mut rng).server, 1);
    }

    #[test]
    fn snap_width_up_handles_overflow() {
        assert_eq!(snap_width_up(&W, 0.6), 0.75);
        assert_eq!(snap_width_up(&W, 1.0), 1.0);
        assert_eq!(snap_width_up(&W, 2.0), 1.0); // clamps to widest
    }

    #[test]
    fn tags_are_unique_and_increasing() {
        let mut r = RandomRouter::new(W.to_vec(), false, 1);
        let mut rng = Rng::new(5);
        let s = snap(&[0], &[0.0]);
        let t0 = r.route(&s, 1.0, 0, &mut rng).tag;
        let t1 = r.route(&s, 1.0, 0, &mut rng).tag;
        assert!(t1 > t0);
    }
}
