//! Global routing policies — the windowed *plan* API.
//!
//! The router is the top of the hierarchy: given the eq. 1 telemetry
//! snapshot and a **window of visible FIFO heads**, it picks `(server,
//! width, micro-batch group)` — the factored action of eq. 2 — for every
//! head in one call. The greedy executor then realizes each decision
//! locally. Implementations:
//!
//! * [`RandomRouter`] — the paper's Table III baseline (uniform random
//!   task distribution).
//! * [`RoundRobinRouter`] — classic algorithmic comparator.
//! * [`LeastLoadedRouter`] — greedy global comparator (min queue).
//! * `ppo::PpoRouter` (in the [`crate::ppo`] module) — the learned policy
//!   of Tables IV–V; its batched path evaluates every head of the window
//!   in a single matrix forward pass.
//!
//! ## Migration note (per-head `route` → windowed `plan`)
//!
//! Pre-redesign signature (one policy invocation per queued head):
//!
//! ```text
//! fn route(&mut self, snap: &TelemetrySnapshot, head_w_req: f64,
//!          head_seg: usize, rng: &mut Rng) -> Decision
//! ```
//!
//! New signature (one invocation per routing event, covering up to
//! `RouterCfg::route_window` compatible heads):
//!
//! ```text
//! fn plan(&mut self, snap: &TelemetrySnapshot, heads: &[HeadView],
//!         rng: &mut Rng) -> RoutingPlan
//! ```
//!
//! A [`HeadView`] carries what the old scalar pair did (requested width,
//! segment) plus queue position, age and deadline slack. A
//! [`RoutingPlan`] is a typed, validated set of per-head [`Decision`]s:
//! arity mismatches surface as a [`PlanError`] and out-of-range
//! servers/widths go through an explicit clamp path instead of silent
//! indexing. With `route_window = 1` (the default) the engine presents
//! exactly one head per event and every router reproduces the
//! pre-redesign decision stream bit-identically per seed
//! (`tests/plan_equivalence.rs`). Callers that routed a single synthetic
//! head (benches, the serve example) use [`Router::route_one`].

use std::fmt;

use crate::utilx::Rng;

use super::telemetry::TelemetrySnapshot;

/// A routing decision for the next block (eq. 2's factored action).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Decision {
    pub server: usize,
    pub width: f64,
    /// Micro-batch group size: how many head requests ride this decision.
    pub group: usize,
    /// Correlation tag echoed in feedback (rollout bookkeeping).
    pub tag: u64,
}

/// One visible FIFO head presented to [`Router::plan`]: the first request
/// of a run of consecutive same-segment entries.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HeadView {
    /// Position of this head in the global FIFO (0 = front).
    pub fifo_index: usize,
    /// Width the client asked for (minimum acceptable).
    pub w_req: f64,
    /// Segment the head currently needs.
    pub seg: usize,
    /// Time since the request arrived at the leader (s).
    pub age_s: f64,
    /// Remaining slack against the nominal SLA (`RouterCfg::sla_s`), in
    /// seconds; negative once the head is already late.
    pub slack_s: f64,
}

impl HeadView {
    /// Synthetic head for single-decision callers (benches, serving
    /// shims): front of the queue, zero age, and no deadline pressure
    /// (infinite slack — a deadline-aware router must never treat a
    /// synthetic head as due-now).
    pub fn new(w_req: f64, seg: usize) -> Self {
        HeadView {
            fifo_index: 0,
            w_req,
            seg,
            age_s: 0.0,
            slack_s: f64::INFINITY,
        }
    }
}

/// Why a [`RoutingPlan`] failed validation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PlanError {
    /// The plan does not carry exactly one decision per presented head.
    WrongArity { expected: usize, got: usize },
    /// A decision names a server outside `0..n_servers`.
    ServerOutOfRange { head: usize, server: usize, n_servers: usize },
    /// A decision's width is not in the scenario's width set W.
    WidthNotInSet { head: usize, width: f64 },
    /// A decision asks for an empty micro-batch group.
    ZeroGroup { head: usize },
}

impl fmt::Display for PlanError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            PlanError::WrongArity { expected, got } => {
                write!(f, "plan has {got} decisions for {expected} heads")
            }
            PlanError::ServerOutOfRange { head, server, n_servers } => {
                write!(f, "head {head}: server {server} out of range (cluster has {n_servers})")
            }
            PlanError::WidthNotInSet { head, width } => {
                write!(f, "head {head}: width {width} not in the scenario width set")
            }
            PlanError::ZeroGroup { head } => {
                write!(f, "head {head}: micro-batch group must be >= 1")
            }
        }
    }
}

/// A typed set of per-head decisions, index-aligned with the `heads`
/// slice handed to [`Router::plan`].
#[derive(Clone, Debug, PartialEq)]
pub struct RoutingPlan {
    decisions: Vec<Decision>,
}

impl RoutingPlan {
    pub fn new(decisions: Vec<Decision>) -> Self {
        RoutingPlan { decisions }
    }

    pub fn len(&self) -> usize {
        self.decisions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.decisions.is_empty()
    }

    pub fn decisions(&self) -> &[Decision] {
        &self.decisions
    }

    pub fn into_decisions(self) -> Vec<Decision> {
        self.decisions
    }

    /// Strict validation against the cluster shape: exactly one decision
    /// per head, servers in range, widths in the scenario set, non-empty
    /// groups. First violation wins.
    pub fn validate(
        &self,
        n_heads: usize,
        n_servers: usize,
        widths: &[f64],
    ) -> Result<(), PlanError> {
        if self.decisions.len() != n_heads {
            return Err(PlanError::WrongArity {
                expected: n_heads,
                got: self.decisions.len(),
            });
        }
        for (head, d) in self.decisions.iter().enumerate() {
            if d.server >= n_servers.max(1) {
                return Err(PlanError::ServerOutOfRange {
                    head,
                    server: d.server,
                    n_servers,
                });
            }
            if !widths.iter().any(|&w| width_eq(w, d.width)) {
                return Err(PlanError::WidthNotInSet { head, width: d.width });
            }
            if d.group == 0 {
                return Err(PlanError::ZeroGroup { head });
            }
        }
        Ok(())
    }

    /// Repair path for out-of-range decisions: servers clamp into range,
    /// widths snap to the nearest member of W, groups floor at 1. A plan
    /// that already validates is returned unchanged (bit-identical).
    /// Returns the repaired plan plus how many fields were clamped.
    pub fn clamp(mut self, n_servers: usize, widths: &[f64]) -> (RoutingPlan, usize) {
        let mut clamped = 0usize;
        for d in &mut self.decisions {
            if d.server >= n_servers.max(1) {
                d.server = n_servers.saturating_sub(1);
                clamped += 1;
            }
            if !widths.is_empty()
                && !widths.iter().any(|&w| width_eq(w, d.width))
            {
                let nearest = widths
                    .iter()
                    .cloned()
                    .min_by(|a, b| {
                        (a - d.width).abs().total_cmp(&(b - d.width).abs())
                    })
                    .unwrap();
                d.width = nearest;
                clamped += 1;
            }
            if d.group == 0 {
                d.group = 1;
                clamped += 1;
            }
        }
        (self, clamped)
    }
}

/// Post-hoc outcome of a routed block (reward ingredients, eq. 7).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BlockFeedback {
    pub tag: u64,
    /// Accuracy prior p̃_acc ∈ [0,1] of the block's width tuple.
    pub acc_prior_norm: f64,
    /// End-to-end block latency L_t (s).
    pub latency_s: f64,
    /// Block energy E_t = P̄_t · L_t (J).
    pub energy_j: f64,
    /// Var of normalized per-server utilizations at completion.
    pub util_variance: f64,
}

/// Routing policy interface (sim and real serving share it).
pub trait Router: Send {
    fn name(&self) -> &'static str;

    /// Choose (server, width, group) for every visible FIFO head. The
    /// returned plan must carry exactly one decision per head, in head
    /// order; the engine validates arity and clamps out-of-range fields.
    fn plan(
        &mut self,
        snap: &TelemetrySnapshot,
        heads: &[HeadView],
        rng: &mut Rng,
    ) -> RoutingPlan;

    /// Single-head convenience wrapper over [`Router::plan`] (benches,
    /// serving shims, tests).
    fn route_one(
        &mut self,
        snap: &TelemetrySnapshot,
        head: &HeadView,
        rng: &mut Rng,
    ) -> Decision {
        self.plan(snap, std::slice::from_ref(head), rng)
            .into_decisions()
            .into_iter()
            .next()
            .expect("router returned an empty plan for one head")
    }

    /// Outcome of an earlier decision (ignored by stateless routers).
    fn feedback(&mut self, _fb: &BlockFeedback) {}

    /// A routed block was cancelled before executing (device dropout
    /// re-route): no feedback will ever arrive for `tag`. Learning
    /// routers drop the staged transition; stateless routers ignore it.
    fn abandon(&mut self, _tag: u64) {}

    /// Called when the run drains (learning routers flush updates).
    fn end_of_run(&mut self) {}
}

impl Router for Box<dyn Router> {
    fn name(&self) -> &'static str {
        (**self).name()
    }
    fn plan(
        &mut self,
        snap: &TelemetrySnapshot,
        heads: &[HeadView],
        rng: &mut Rng,
    ) -> RoutingPlan {
        (**self).plan(snap, heads, rng)
    }
    fn feedback(&mut self, fb: &BlockFeedback) {
        (**self).feedback(fb)
    }
    fn abandon(&mut self, tag: u64) {
        (**self).abandon(tag)
    }
    fn end_of_run(&mut self) {
        (**self).end_of_run()
    }
}

/// Width-set membership tolerance, shared by plan validation/clamping
/// and the run-outcome histogram so they can never drift apart.
pub(crate) fn width_eq(a: f64, b: f64) -> bool {
    (a - b).abs() < 1e-9
}

pub(crate) fn snap_width_up(widths: &[f64], w_req: f64) -> f64 {
    widths
        .iter()
        .cloned()
        .filter(|w| *w >= w_req - 1e-9)
        .fold(f64::INFINITY, f64::min)
        .min(widths.iter().cloned().fold(0.0, f64::max))
}

/// Table III baseline: uniformly random server; width honors the request
/// (or is uniformly random when `randomize_width`); fixed group.
#[derive(Clone)]
pub struct RandomRouter {
    pub widths: Vec<f64>,
    pub randomize_width: bool,
    pub group: usize,
    next_tag: u64,
}

impl RandomRouter {
    pub fn new(widths: Vec<f64>, randomize_width: bool, group: usize) -> Self {
        RandomRouter { widths, randomize_width, group, next_tag: 0 }
    }
}

impl Router for RandomRouter {
    fn name(&self) -> &'static str {
        "random"
    }

    fn plan(
        &mut self,
        snap: &TelemetrySnapshot,
        heads: &[HeadView],
        rng: &mut Rng,
    ) -> RoutingPlan {
        let decisions = heads
            .iter()
            .map(|head| {
                let tag = self.next_tag;
                self.next_tag += 1;
                // draw order (width, then server) matches the per-head
                // route() this replaced — seeds reproduce bit-identically
                let width = if self.randomize_width {
                    *rng.choice(&self.widths)
                } else {
                    snap_width_up(&self.widths, head.w_req)
                };
                Decision {
                    server: rng.index(snap.servers.len().max(1)),
                    width,
                    group: self.group,
                    tag,
                }
            })
            .collect();
        RoutingPlan::new(decisions)
    }
}

/// Strict round-robin over servers.
#[derive(Clone)]
pub struct RoundRobinRouter {
    pub widths: Vec<f64>,
    pub group: usize,
    cursor: usize,
    next_tag: u64,
}

impl RoundRobinRouter {
    pub fn new(widths: Vec<f64>, group: usize) -> Self {
        RoundRobinRouter { widths, group, cursor: 0, next_tag: 0 }
    }
}

impl Router for RoundRobinRouter {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn plan(
        &mut self,
        snap: &TelemetrySnapshot,
        heads: &[HeadView],
        _rng: &mut Rng,
    ) -> RoutingPlan {
        let n = snap.servers.len().max(1);
        let decisions = heads
            .iter()
            .map(|head| {
                let server = self.cursor % n;
                self.cursor = (self.cursor + 1) % n;
                let tag = self.next_tag;
                self.next_tag += 1;
                Decision {
                    server,
                    width: snap_width_up(&self.widths, head.w_req),
                    group: self.group,
                    tag,
                }
            })
            .collect();
        RoutingPlan::new(decisions)
    }
}

/// Load score shared by the telemetry-driven comparators (LeastLoaded,
/// Edf): queue length plus scaled utilization. One definition, so a
/// recalibration can never make the comparators drift apart silently.
fn load_score(s: &super::telemetry::ServerTelemetry) -> f64 {
    s.queue_len as f64 + s.util_pct / 25.0
}

/// Index of the minimum of a live load image — NaN-safe via `total_cmp`
/// (a poisoned telemetry sample must not panic the leader; NaN sorts
/// last and simply never wins).
fn pick_min(scores: &[f64]) -> usize {
    scores
        .iter()
        .enumerate()
        .min_by(|(_, a), (_, b)| a.total_cmp(b))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Greedy global comparator: route to the server minimizing a load score
/// (queue length + utilization), widen groups under backlog.
#[derive(Clone)]
pub struct LeastLoadedRouter {
    pub widths: Vec<f64>,
    pub max_group: usize,
    next_tag: u64,
}

impl LeastLoadedRouter {
    pub fn new(widths: Vec<f64>, max_group: usize) -> Self {
        LeastLoadedRouter { widths, max_group, next_tag: 0 }
    }
}

impl Router for LeastLoadedRouter {
    fn name(&self) -> &'static str {
        "least-loaded"
    }

    fn plan(
        &mut self,
        snap: &TelemetrySnapshot,
        heads: &[HeadView],
        _rng: &mut Rng,
    ) -> RoutingPlan {
        // NaN-safe ordering throughout (total_cmp via `pick_min`): a
        // poisoned telemetry sample must not panic the leader mid-run.
        let group = if snap.fifo_len > 8 { self.max_group } else { 1 };
        if let [head] = heads {
            // per-head hot path (route_window = 1): allocation-free scan,
            // the pre-plan body verbatim
            let server = snap
                .servers
                .iter()
                .enumerate()
                .min_by(|(_, a), (_, b)| {
                    load_score(a).total_cmp(&load_score(b))
                })
                .map(|(i, _)| i)
                .unwrap_or(0);
            let tag = self.next_tag;
            self.next_tag += 1;
            return RoutingPlan::new(vec![Decision {
                server,
                width: snap_width_up(&self.widths, head.w_req),
                group,
                tag,
            }]);
        }
        // Windowed path — live load image: assigning a block raises its
        // target's score, so a wide window spreads over the cluster
        // instead of herding every head onto the server that was least
        // loaded at snapshot time.
        let mut scores: Vec<f64> = snap.servers.iter().map(load_score).collect();
        let decisions = heads
            .iter()
            .map(|head| {
                let server = pick_min(&scores);
                if let Some(sc) = scores.get_mut(server) {
                    *sc += group as f64;
                }
                let tag = self.next_tag;
                self.next_tag += 1;
                Decision {
                    server,
                    width: snap_width_up(&self.widths, head.w_req),
                    group,
                    tag,
                }
            })
            .collect();
        RoutingPlan::new(decisions)
    }
}

/// Deadline-aware comparator: Earliest-Deadline-First over the visible
/// window. Heads are processed in ascending `HeadView::slack_s` order
/// (the latest head first), each taking the currently least-loaded
/// server under a live per-plan load image — so under deadline pressure
/// the most-overdue work gets the emptiest machine instead of whatever
/// the FIFO order handed it. Widths honor the request; the micro-batch
/// group widens for heads that are already late (negative slack) or when
/// the leader backlog is deep, to clear overdue runs in one dispatch.
#[derive(Clone)]
pub struct EdfRouter {
    pub widths: Vec<f64>,
    pub max_group: usize,
    next_tag: u64,
}

impl EdfRouter {
    pub fn new(widths: Vec<f64>, max_group: usize) -> Self {
        EdfRouter { widths, max_group, next_tag: 0 }
    }
}

impl Router for EdfRouter {
    fn name(&self) -> &'static str {
        "edf"
    }

    fn plan(
        &mut self,
        snap: &TelemetrySnapshot,
        heads: &[HeadView],
        _rng: &mut Rng,
    ) -> RoutingPlan {
        let n = heads.len();
        // least slack first; total_cmp keeps a poisoned slack (NaN) from
        // panicking the leader — NaN sorts last and ties keep head order
        // (sort_by is stable), so the ordering is deterministic
        let mut order: Vec<usize> = (0..n).collect();
        if heads.iter().any(|h| h.slack_s.is_finite()) {
            order.sort_by(|&a, &b| heads[a].slack_s.total_cmp(&heads[b].slack_s));
        }
        // else: no head carries a usable deadline (SLA unset — every
        // slack is +∞ — or telemetry poisoned every slack to NaN).
        // Sorting such a window orders on garbage: total_cmp ranks +∞
        // below NaN, so a single poisoned head would reshuffle the
        // window. Fall back to plain FIFO order explicitly — without
        // deadlines EDF *is* FIFO with load-aware placement — and let
        // the load image below do the spreading.
        let mut scores: Vec<f64> = snap.servers.iter().map(load_score).collect();
        let mut decisions: Vec<Option<Decision>> = vec![None; n];
        for &k in &order {
            let head = &heads[k];
            let server = pick_min(&scores);
            let late = head.slack_s <= 0.0;
            let group = if late || snap.fifo_len > 8 { self.max_group } else { 1 };
            if let Some(sc) = scores.get_mut(server) {
                *sc += group as f64;
            }
            let tag = self.next_tag;
            self.next_tag += 1;
            decisions[k] = Some(Decision {
                server,
                width: snap_width_up(&self.widths, head.w_req),
                group,
                tag,
            });
        }
        RoutingPlan::new(
            decisions
                .into_iter()
                .map(|d| d.expect("every head planned exactly once"))
                .collect(),
        )
    }
}

/// The four algorithmic routers behind one cloneable, nameable type —
/// what the CLI, the trace replay path and the counterfactual A/B
/// harness build from a `--router` spelling. Construction parameters
/// (width randomization, group caps) match the long-standing `repro
/// simulate` arms exactly, so a trace recorded through the CLI replays
/// bit-identically through this type. PPO keeps its own type (it carries
/// training state and a checkpoint lifecycle).
#[derive(Clone)]
pub enum AlgoRouter {
    Random(RandomRouter),
    RoundRobin(RoundRobinRouter),
    LeastLoaded(LeastLoadedRouter),
    Edf(EdfRouter),
}

impl AlgoRouter {
    /// Build the named router over the scenario's width set; None for
    /// unknown spellings (see [`AlgoRouter::names`]).
    pub fn by_name(name: &str, widths: &[f64]) -> Option<AlgoRouter> {
        Some(match name {
            "random" => {
                AlgoRouter::Random(RandomRouter::new(widths.to_vec(), true, 8))
            }
            "round-robin" => {
                AlgoRouter::RoundRobin(RoundRobinRouter::new(widths.to_vec(), 8))
            }
            "least-loaded" => {
                AlgoRouter::LeastLoaded(LeastLoadedRouter::new(widths.to_vec(), 16))
            }
            "edf" => AlgoRouter::Edf(EdfRouter::new(widths.to_vec(), 16)),
            _ => return None,
        })
    }

    /// Every spelling [`AlgoRouter::by_name`] accepts.
    pub fn names() -> Vec<&'static str> {
        vec!["random", "round-robin", "least-loaded", "edf"]
    }

    /// Canonical spelling for `name` when it names an algorithmic
    /// router (the `&'static str` the enum would report).
    pub fn canonical(name: &str) -> Option<&'static str> {
        Self::names().into_iter().find(|&n| n == name)
    }

    fn inner(&mut self) -> &mut dyn Router {
        match self {
            AlgoRouter::Random(r) => r,
            AlgoRouter::RoundRobin(r) => r,
            AlgoRouter::LeastLoaded(r) => r,
            AlgoRouter::Edf(r) => r,
        }
    }
}

impl Router for AlgoRouter {
    fn name(&self) -> &'static str {
        match self {
            AlgoRouter::Random(r) => r.name(),
            AlgoRouter::RoundRobin(r) => r.name(),
            AlgoRouter::LeastLoaded(r) => r.name(),
            AlgoRouter::Edf(r) => r.name(),
        }
    }

    fn plan(
        &mut self,
        snap: &TelemetrySnapshot,
        heads: &[HeadView],
        rng: &mut Rng,
    ) -> RoutingPlan {
        self.inner().plan(snap, heads, rng)
    }

    fn feedback(&mut self, fb: &BlockFeedback) {
        self.inner().feedback(fb)
    }

    fn abandon(&mut self, tag: u64) {
        self.inner().abandon(tag)
    }

    fn end_of_run(&mut self) {
        self.inner().end_of_run()
    }
}

/// A parsed router spelling — what `--routers` lists and the
/// counterfactual A/B harness accept. Two families:
///
/// * an algorithmic router name (`random`, `round-robin`,
///   `least-loaded`, `edf`) — constructed via [`AlgoRouter::by_name`];
/// * `ppo:<path>` — a frozen PPO policy restored from a checkpoint
///   file. Construction lives with the PPO module
///   (`ppo::PpoRouter::from_checkpoint`), since the policy carries a
///   weight lifecycle the algorithmic routers don't; this type only
///   owns the spelling, so the coordinator stays free of PPO imports.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RouterSpec {
    /// A named algorithmic router (canonical spelling).
    Algo(&'static str),
    /// A PPO policy checkpoint at this path (`ppo:<path>`).
    PpoCheckpoint(String),
}

impl RouterSpec {
    /// Parse one `--routers` entry. `None` for unknown spellings and
    /// for a bare `ppo:` with no path.
    pub fn parse(s: &str) -> Option<RouterSpec> {
        if let Some(path) = s.strip_prefix("ppo:") {
            if path.is_empty() {
                return None;
            }
            return Some(RouterSpec::PpoCheckpoint(path.to_string()));
        }
        AlgoRouter::canonical(s).map(RouterSpec::Algo)
    }

    /// The spelling this spec round-trips to (report labels, trace
    /// headers).
    pub fn label(&self) -> String {
        match self {
            RouterSpec::Algo(name) => (*name).to_string(),
            RouterSpec::PpoCheckpoint(path) => format!("ppo:{path}"),
        }
    }

    /// Human-readable list of accepted spellings (error messages).
    pub fn spellings() -> String {
        format!("{}, ppo:<checkpoint.json>", AlgoRouter::names().join(", "))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::telemetry::ServerTelemetry;

    fn snap(queues: &[usize], utils: &[f64]) -> TelemetrySnapshot {
        TelemetrySnapshot {
            fifo_len: 20,
            done_count: 0,
            total_requests: 100,
            servers: queues
                .iter()
                .zip(utils)
                .map(|(&q, &u)| ServerTelemetry {
                    queue_len: q,
                    power_w: 100.0,
                    util_pct: u,
                    mem_util: 0.1,
                    instances: 1,
                })
                .collect(),
        }
    }

    const W: [f64; 4] = [0.25, 0.5, 0.75, 1.0];

    #[test]
    fn random_router_covers_all_servers() {
        let mut r = RandomRouter::new(W.to_vec(), false, 4);
        let mut rng = Rng::new(1);
        let s = snap(&[0, 0, 0], &[0.0, 0.0, 0.0]);
        let mut seen = [false; 3];
        for _ in 0..100 {
            let d = r.route_one(&s, &HeadView::new(0.5, 0), &mut rng);
            seen[d.server] = true;
            assert_eq!(d.width, 0.5); // honors request
            assert_eq!(d.group, 4);
        }
        assert_eq!(seen, [true; 3]);
    }

    #[test]
    fn random_router_randomizes_width_when_asked() {
        let mut r = RandomRouter::new(W.to_vec(), true, 1);
        let mut rng = Rng::new(2);
        let s = snap(&[0], &[0.0]);
        let mut widths = std::collections::BTreeSet::new();
        for _ in 0..100 {
            let d = r.route_one(&s, &HeadView::new(0.25, 0), &mut rng);
            widths.insert((d.width * 100.0) as u32);
        }
        assert_eq!(widths.len(), 4);
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = RoundRobinRouter::new(W.to_vec(), 1);
        let mut rng = Rng::new(3);
        let s = snap(&[0, 0, 0], &[0.0, 0.0, 0.0]);
        let servers: Vec<usize> = (0..6)
            .map(|_| r.route_one(&s, &HeadView::new(1.0, 0), &mut rng).server)
            .collect();
        assert_eq!(servers, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_picks_min_queue_and_widens_group() {
        let mut r = LeastLoadedRouter::new(W.to_vec(), 16);
        let mut rng = Rng::new(4);
        let s = snap(&[9, 2, 7], &[50.0, 50.0, 50.0]);
        let d = r.route_one(&s, &HeadView::new(0.75, 1), &mut rng);
        assert_eq!(d.server, 1);
        assert_eq!(d.group, 16); // fifo_len 20 > 8
        // utilization tie-breaks queues
        let s2 = snap(&[3, 3], &[95.0, 10.0]);
        assert_eq!(r.route_one(&s2, &HeadView::new(0.75, 1), &mut rng).server, 1);
    }

    #[test]
    fn least_loaded_spreads_a_wide_window() {
        // six equal-cost blocks over three idle servers must not herd
        // onto the single snapshot-time minimum
        let mut r = LeastLoadedRouter::new(W.to_vec(), 4);
        let mut rng = Rng::new(9);
        let s = snap(&[0, 0, 0], &[0.0, 0.0, 0.0]); // fifo_len 20 > 8
        let plan = r.plan(&s, &heads(6), &mut rng);
        let mut per_server = [0usize; 3];
        for d in plan.decisions() {
            per_server[d.server] += 1;
        }
        assert_eq!(per_server, [2, 2, 2], "window herded: {per_server:?}");
    }

    #[test]
    fn least_loaded_survives_nan_telemetry() {
        // a poisoned sample (NaN util) must not panic the leader; the
        // NaN-scored server simply never wins the min
        let mut r = LeastLoadedRouter::new(W.to_vec(), 16);
        let mut rng = Rng::new(5);
        let mut s = snap(&[9, 2, 7], &[50.0, 50.0, 50.0]);
        s.servers[1].util_pct = f64::NAN;
        let d = r.route_one(&s, &HeadView::new(0.5, 0), &mut rng);
        assert!(d.server < 3);
        assert_ne!(d.server, 1, "NaN-scored server must sort last");
    }

    #[test]
    fn edf_gives_the_latest_head_the_emptiest_server() {
        let mut r = EdfRouter::new(W.to_vec(), 8);
        let mut rng = Rng::new(11);
        let s = snap(&[6, 0, 3], &[50.0, 10.0, 30.0]); // server 1 emptiest
        let hs = vec![
            HeadView { fifo_index: 0, w_req: 0.5, seg: 0, age_s: 0.1, slack_s: 0.9 },
            HeadView { fifo_index: 1, w_req: 0.5, seg: 1, age_s: 1.5, slack_s: -0.5 },
            HeadView { fifo_index: 2, w_req: 0.5, seg: 2, age_s: 0.4, slack_s: 0.6 },
        ];
        let plan = r.plan(&s, &hs, &mut rng);
        assert_eq!(plan.len(), 3);
        let ds = plan.decisions();
        // head 1 is overdue: it planned first and took server 1, with the
        // widened late-head group
        assert_eq!(ds[1].server, 1);
        assert_eq!(ds[1].group, 8);
        // decisions stay index-aligned with the heads slice
        assert!(plan.validate(3, 3, &W).is_ok());
    }

    #[test]
    fn edf_on_time_heads_fall_back_to_load_order() {
        let mut r = EdfRouter::new(W.to_vec(), 8);
        let mut rng = Rng::new(12);
        let mut s = snap(&[0, 0, 0], &[0.0, 0.0, 0.0]);
        s.fifo_len = 2; // calm leader: groups stay 1
        let hs: Vec<HeadView> = (0..3)
            .map(|i| HeadView {
                fifo_index: i,
                w_req: 0.25,
                seg: 0,
                age_s: 0.01 * i as f64,
                slack_s: 1.0 - 0.01 * i as f64,
            })
            .collect();
        let plan = r.plan(&s, &hs, &mut rng);
        // three equal-cost on-time heads spread over three idle servers
        let mut seen: Vec<usize> =
            plan.decisions().iter().map(|d| d.server).collect();
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1, 2]);
        assert!(plan.decisions().iter().all(|d| d.group == 1));
        assert!(plan.decisions().iter().all(|d| d.width == 0.25));
    }

    #[test]
    fn edf_without_sla_falls_back_to_fifo_order() {
        // SLA unset: every head carries infinite slack. EDF must process
        // the window in FIFO order (an explicit fallback, not a sort
        // over uniform garbage) and never apply the late-head widening.
        let mut r = EdfRouter::new(W.to_vec(), 8);
        let mut rng = Rng::new(14);
        let mut s = snap(&[0, 0, 0], &[0.0, 0.0, 0.0]);
        s.fifo_len = 2; // calm leader: group widening stays off
        let hs: Vec<HeadView> = (0..3)
            .map(|i| HeadView {
                fifo_index: i,
                w_req: 0.5,
                seg: i,
                age_s: 0.02 * i as f64,
                slack_s: f64::INFINITY,
            })
            .collect();
        let plan = r.plan(&s, &hs, &mut rng);
        // FIFO processing over idle equal servers: head k takes server k
        let servers: Vec<usize> =
            plan.decisions().iter().map(|d| d.server).collect();
        assert_eq!(servers, vec![0, 1, 2]);
        assert!(plan.decisions().iter().all(|d| d.group == 1));

        // one poisoned NaN among the infinities must not reshuffle the
        // deterministic FIFO fallback
        let mut r2 = EdfRouter::new(W.to_vec(), 8);
        let mut hs2 = hs.clone();
        hs2[0].slack_s = f64::NAN;
        let plan2 = r2.plan(&s, &hs2, &mut rng);
        let servers2: Vec<usize> =
            plan2.decisions().iter().map(|d| d.server).collect();
        assert_eq!(servers2, vec![0, 1, 2]);
    }

    #[test]
    fn router_spec_parses_names_and_checkpoints() {
        for name in AlgoRouter::names() {
            assert_eq!(RouterSpec::parse(name), Some(RouterSpec::Algo(name)));
            assert_eq!(RouterSpec::parse(name).unwrap().label(), name);
        }
        assert_eq!(
            RouterSpec::parse("ppo:ckpt.json"),
            Some(RouterSpec::PpoCheckpoint("ckpt.json".to_string()))
        );
        assert_eq!(
            RouterSpec::parse("ppo:ckpt.json").unwrap().label(),
            "ppo:ckpt.json"
        );
        assert_eq!(RouterSpec::parse("ppo:"), None); // path required
        assert_eq!(RouterSpec::parse("ppo"), None); // bare ppo is ambiguous
        assert_eq!(RouterSpec::parse("marsbase"), None);
        assert!(RouterSpec::spellings().contains("edf"));
        assert!(RouterSpec::spellings().contains("ppo:<checkpoint.json>"));
    }

    #[test]
    fn edf_survives_nan_slack() {
        let mut r = EdfRouter::new(W.to_vec(), 4);
        let mut rng = Rng::new(13);
        let s = snap(&[1, 2], &[10.0, 20.0]);
        let hs = vec![
            HeadView { fifo_index: 0, w_req: 0.5, seg: 0, age_s: 0.0, slack_s: f64::NAN },
            HeadView { fifo_index: 1, w_req: 0.5, seg: 1, age_s: 0.0, slack_s: 0.2 },
        ];
        let plan = r.plan(&s, &hs, &mut rng);
        assert_eq!(plan.len(), 2);
        assert!(plan.validate(2, 2, &W).is_ok());
    }

    #[test]
    fn algo_router_by_name_matches_the_direct_constructions() {
        // every spelling resolves, reports the inner name, and plans the
        // same decision stream as the directly built router
        let s = snap(&[3, 1, 2], &[10.0, 20.0, 30.0]);
        let hs = heads(4);
        for name in AlgoRouter::names() {
            let mut r = AlgoRouter::by_name(name, &W).unwrap();
            assert_eq!(r.name(), name);
            let mut rng = Rng::new(21);
            let plan = r.plan(&s, &hs, &mut rng);
            assert!(plan.validate(hs.len(), 3, &W).is_ok(), "{name}");
        }
        assert!(AlgoRouter::by_name("marsbase", &W).is_none());

        let mut rng_a = Rng::new(33);
        let mut rng_b = rng_a.clone();
        let mut via_enum = AlgoRouter::by_name("random", &W).unwrap();
        let mut direct = RandomRouter::new(W.to_vec(), true, 8);
        assert_eq!(
            via_enum.plan(&s, &hs, &mut rng_a).into_decisions(),
            direct.plan(&s, &hs, &mut rng_b).into_decisions()
        );
    }

    #[test]
    fn snap_width_up_handles_overflow() {
        assert_eq!(snap_width_up(&W, 0.6), 0.75);
        assert_eq!(snap_width_up(&W, 1.0), 1.0);
        assert_eq!(snap_width_up(&W, 2.0), 1.0); // clamps to widest
    }

    #[test]
    fn tags_are_unique_and_increasing() {
        let mut r = RandomRouter::new(W.to_vec(), false, 1);
        let mut rng = Rng::new(5);
        let s = snap(&[0], &[0.0]);
        let t0 = r.route_one(&s, &HeadView::new(1.0, 0), &mut rng).tag;
        let t1 = r.route_one(&s, &HeadView::new(1.0, 0), &mut rng).tag;
        assert!(t1 > t0);
    }

    fn heads(n: usize) -> Vec<HeadView> {
        (0..n)
            .map(|i| HeadView {
                fifo_index: i,
                w_req: W[i % 4],
                seg: i % 4,
                age_s: 0.01 * i as f64,
                slack_s: 1.0 - 0.01 * i as f64,
            })
            .collect()
    }

    #[test]
    fn every_router_plans_one_decision_per_head() {
        let s = snap(&[3, 1, 2], &[10.0, 20.0, 30.0]);
        let hs = heads(5);
        let mut rng = Rng::new(6);
        let mut routers: Vec<Box<dyn Router>> = vec![
            Box::new(RandomRouter::new(W.to_vec(), true, 4)),
            Box::new(RoundRobinRouter::new(W.to_vec(), 4)),
            Box::new(LeastLoadedRouter::new(W.to_vec(), 16)),
            Box::new(EdfRouter::new(W.to_vec(), 16)),
        ];
        for r in &mut routers {
            let plan = r.plan(&s, &hs, &mut rng);
            assert_eq!(plan.len(), hs.len(), "{}", r.name());
            assert!(plan.validate(hs.len(), 3, &W).is_ok(), "{}", r.name());
        }
    }

    #[test]
    fn multi_head_plan_matches_repeated_single_head_plans() {
        // for stateful-but-snapshot-driven routers the windowed plan is
        // the same decision sequence the per-head loop would produce
        let s = snap(&[3, 1, 2], &[10.0, 20.0, 30.0]);
        let hs = heads(6);

        let mut rng_a = Rng::new(7);
        let mut rng_b = rng_a.clone();
        let mut a = RandomRouter::new(W.to_vec(), true, 4);
        let mut b = RandomRouter::new(W.to_vec(), true, 4);
        let windowed = a.plan(&s, &hs, &mut rng_a).into_decisions();
        let per_head: Vec<Decision> =
            hs.iter().map(|h| b.route_one(&s, h, &mut rng_b)).collect();
        assert_eq!(windowed, per_head);

        let mut rng = Rng::new(8);
        let mut a = RoundRobinRouter::new(W.to_vec(), 4);
        let mut b = RoundRobinRouter::new(W.to_vec(), 4);
        let windowed = a.plan(&s, &hs, &mut rng).into_decisions();
        let per_head: Vec<Decision> =
            hs.iter().map(|h| b.route_one(&s, h, &mut rng)).collect();
        assert_eq!(windowed, per_head);
    }

    #[test]
    fn validate_rejects_bad_plans() {
        let d = Decision { server: 0, width: 0.5, group: 1, tag: 0 };
        let plan = RoutingPlan::new(vec![d]);
        assert_eq!(
            plan.validate(2, 3, &W),
            Err(PlanError::WrongArity { expected: 2, got: 1 })
        );
        let plan = RoutingPlan::new(vec![Decision { server: 9, ..d }]);
        assert_eq!(
            plan.validate(1, 3, &W),
            Err(PlanError::ServerOutOfRange { head: 0, server: 9, n_servers: 3 })
        );
        let plan = RoutingPlan::new(vec![Decision { width: 0.33, ..d }]);
        assert!(matches!(
            plan.validate(1, 3, &W),
            Err(PlanError::WidthNotInSet { head: 0, .. })
        ));
        let plan = RoutingPlan::new(vec![Decision { group: 0, ..d }]);
        assert_eq!(plan.validate(1, 3, &W), Err(PlanError::ZeroGroup { head: 0 }));
        let plan = RoutingPlan::new(vec![d]);
        assert!(plan.validate(1, 3, &W).is_ok());
    }

    #[test]
    fn clamp_repairs_out_of_range_fields_and_keeps_valid_plans() {
        let good = Decision { server: 1, width: 0.75, group: 4, tag: 1 };
        let bad = Decision { server: 7, width: 0.6, group: 0, tag: 2 };
        let (plan, clamped) =
            RoutingPlan::new(vec![good, bad]).clamp(3, &W);
        assert_eq!(clamped, 3);
        let ds = plan.into_decisions();
        assert_eq!(ds[0], good); // untouched
        assert_eq!(ds[1].server, 2);
        assert_eq!(ds[1].width, 0.5); // nearest member of W
        assert_eq!(ds[1].group, 1);
        assert!(RoutingPlan::new(ds).validate(2, 3, &W).is_ok());
    }
}
