//! Request and batch-key types.
//!
//! A request is one image working its way through the four SlimResNet
//! segments. At any moment it sits in some queue waiting for its *current*
//! segment to execute; Algorithm 1 keys it by `(s, w_req, w_prev)` —
//! segment index, requested width, and the width the previous segment
//! actually ran at (which determines the input-side FLOPs).

use crate::model::NUM_SEGMENTS;

/// Quantize a width ratio for use in hashable keys (0.25 -> 25).
pub fn wkey(w: f64) -> u16 {
    (w * 100.0).round() as u16
}

/// Batch compatibility key: requests sharing this key can be batched onto
/// one instance (paper: k = (s, w_req, w_prev)).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BatchKey {
    pub seg: usize,
    pub w: u16,
    pub w_prev: u16,
}

impl BatchKey {
    pub fn new(seg: usize, w: f64, w_prev: f64) -> Self {
        BatchKey { seg, w: wkey(w), w_prev: wkey(w_prev) }
    }

    pub fn width(&self) -> f64 {
        self.w as f64 / 100.0
    }

    pub fn width_prev(&self) -> f64 {
        self.w_prev as f64 / 100.0
    }
}

/// One inference request (an image traversing all four segments).
///
/// `Copy`: every field is plain-old-data, so the hot path moves requests
/// between FIFOs, blocks, and events by bitwise copy instead of clone
/// calls — there is deliberately no heap state in here (§Perf).
#[derive(Clone, Copy, Debug)]
pub struct Request {
    pub id: u64,
    /// Owning tenant (0 in single-tenant workloads). Determines the
    /// effective SLA via `sim::workload::sla_multiplier` and the DRR
    /// admission queue the request waits in.
    pub tenant: u16,
    /// Wall arrival time at the leader.
    pub arrival: f64,
    /// Width the client asked for (minimum acceptable).
    pub w_req: f64,
    /// Segment the request currently needs (0..4).
    pub seg: usize,
    /// Width the previous segment executed at (1.0 before seg 0).
    pub w_prev: f64,
    /// Width actually used per segment (filled as segments complete).
    pub widths_used: [f64; NUM_SEGMENTS],
    /// When the request entered the current segment's local queue.
    pub enqueued_at: f64,
    /// When the router dispatched the current block (for block latency).
    pub routed_at: f64,
    /// Server that executed the previous segment (for link-cost modeling).
    pub last_server: Option<usize>,
    /// Tag of the routed block this request currently belongs to.
    pub block_tag: u64,
    /// Members of the routed block this request currently rides in
    /// (stamped at routing; 1 before the first routing decision) — the
    /// divisor that turns block energy into a per-member share.
    pub block_size: usize,
    /// Energy (J) attributed to this request so far: each completed
    /// segment charges its 1/`block_size` share of mean cluster power ×
    /// time-since-routing — the per-member slice of the block energy the
    /// paper's E_t = P̄·L measures. When a block executes as one batch
    /// (the common case) the shares sum exactly to the recorded block
    /// energy; a block split across device batches charges each member
    /// at its *own* completion instant, so the per-request view is a
    /// faithful attribution rather than an exact decomposition of the
    /// block aggregate. The trace `done` records this sum and the A/B
    /// harness pairs on it.
    pub energy_j: f64,
    /// When the DRR gate released the request (== `arrival` when no
    /// gate is configured), so gate wait = `admitted_at - arrival`.
    pub admitted_at: f64,
    /// Sim time this request's current block arrived at its server
    /// (stamped at routing from the WLAN transfer model; device stage
    /// time for a segment is completion − `arrived_at`).
    pub arrived_at: f64,
    /// Accumulated leader-queue wait across segments (admission/advance
    /// → routing decision), for the obs stage decomposition.
    pub leader_wait_s: f64,
    /// Accumulated WLAN transfer wait across segments (routing → server
    /// arrival).
    pub net_wait_s: f64,
    /// Accumulated on-server time across segments (server arrival →
    /// batch completion, queueing included).
    pub device_s: f64,
}

impl Request {
    pub fn new(id: u64, arrival: f64, w_req: f64) -> Self {
        Request {
            id,
            tenant: 0,
            arrival,
            w_req,
            seg: 0,
            w_prev: 1.0,
            widths_used: [0.0; NUM_SEGMENTS],
            enqueued_at: arrival,
            routed_at: arrival,
            last_server: None,
            block_tag: 0,
            block_size: 1,
            energy_j: 0.0,
            admitted_at: arrival,
            arrived_at: arrival,
            leader_wait_s: 0.0,
            net_wait_s: 0.0,
            device_s: 0.0,
        }
    }

    /// Stamp the owning tenant (builder-style; `new` defaults to 0 so
    /// hand-built test requests stay terse).
    pub fn with_tenant(mut self, tenant: u16) -> Self {
        self.tenant = tenant;
        self
    }

    /// Key of the segment execution this request currently waits for,
    /// given the width the router granted.
    pub fn key_with(&self, width: f64) -> BatchKey {
        BatchKey::new(self.seg, width, self.w_prev)
    }

    /// Record completion of the current segment and advance. Returns true
    /// while more segments remain.
    pub fn advance(&mut self, executed_width: f64, now: f64, server: usize) -> bool {
        self.widths_used[self.seg] = executed_width;
        self.w_prev = executed_width;
        self.last_server = Some(server);
        self.seg += 1;
        self.enqueued_at = now;
        self.seg < NUM_SEGMENTS
    }

    /// Whether every segment has executed.
    pub fn is_complete(&self) -> bool {
        self.seg >= NUM_SEGMENTS
    }

    /// The 4-width tuple (only meaningful once complete).
    pub fn width_tuple(&self) -> [f64; NUM_SEGMENTS] {
        self.widths_used
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wkey_quantizes_the_width_set() {
        assert_eq!(wkey(0.25), 25);
        assert_eq!(wkey(0.50), 50);
        assert_eq!(wkey(0.75), 75);
        assert_eq!(wkey(1.00), 100);
    }

    #[test]
    fn batch_key_roundtrip() {
        let k = BatchKey::new(2, 0.75, 0.5);
        assert_eq!(k.seg, 2);
        assert_eq!(k.width(), 0.75);
        assert_eq!(k.width_prev(), 0.5);
    }

    #[test]
    fn keys_equal_iff_same_triple() {
        assert_eq!(BatchKey::new(1, 0.5, 1.0), BatchKey::new(1, 0.5, 1.0));
        assert_ne!(BatchKey::new(1, 0.5, 1.0), BatchKey::new(1, 0.5, 0.5));
        assert_ne!(BatchKey::new(1, 0.5, 1.0), BatchKey::new(2, 0.5, 1.0));
        assert_ne!(BatchKey::new(1, 0.5, 1.0), BatchKey::new(1, 0.75, 1.0));
    }

    #[test]
    fn request_lifecycle_through_all_segments() {
        let mut r = Request::new(7, 1.0, 0.5);
        assert_eq!(r.seg, 0);
        assert_eq!(r.w_prev, 1.0);
        assert!(!r.is_complete());

        assert!(r.advance(0.5, 1.1, 0));
        assert_eq!(r.seg, 1);
        assert_eq!(r.w_prev, 0.5);
        assert_eq!(r.last_server, Some(0));

        assert!(r.advance(0.75, 1.2, 2));
        assert!(r.advance(0.25, 1.3, 1));
        assert!(!r.advance(1.0, 1.4, 0)); // last segment
        assert!(r.is_complete());
        assert_eq!(r.width_tuple(), [0.5, 0.75, 0.25, 1.0]);
    }

    #[test]
    fn key_with_uses_current_state() {
        let mut r = Request::new(1, 0.0, 0.25);
        assert_eq!(r.key_with(0.5), BatchKey::new(0, 0.5, 1.0));
        r.advance(0.5, 0.1, 0);
        assert_eq!(r.key_with(0.25), BatchKey::new(1, 0.25, 0.5));
    }
}
