//! Reusable discrete-event substrate behind the cluster engine.
//!
//! The engine used to be a 600-line monolith owning its own event heap,
//! block bookkeeping and metric accumulators, hard-wired to `SimDevice`
//! and `GreedyScheduler`. This module factors those substrates out:
//!
//! * [`EventQueue`] — the deterministic event queue (earliest timestamp
//!   first, FIFO sequence tie-break), calendar-queue internals for O(1)
//!   amortized push/pop; [`HeapEventQueue`] is the original `BinaryHeap`
//!   reference it is property-tested against. The tie-break is what makes
//!   every run reproducible per seed; `tests/determinism.rs` guards it.
//! * [`DeviceModel`] / [`LocalScheduler`] — the traits the engine drives
//!   devices and per-server schedulers through, so alternative device
//!   models (real executors, other simulators) and scheduling policies
//!   slot in without touching the event loop.
//! * [`BlockLedger`] — in-flight routed-block accounting.
//! * [`RunMetrics`] — the per-run measurement bundle (Tables III–V rows).
//!
//! With these pieces an [`super::Engine`] instance is cheap to construct
//! and `Send`, which is what lets `ppo::parallel` run one seeded engine
//! per worker thread.

use std::collections::{BinaryHeap, HashMap};

use crate::metrics::Summary;
use crate::model::NUM_SEGMENTS;
use crate::sim::SimDevice;

use super::greedy::{DeviceGate, Dispatch, GreedyScheduler, GreedyStats};
use super::queue::Queued;
use super::telemetry::TelemetryLog;

// ---------------------------------------------------------------------
// Deterministic event queues (calendar default, heap reference)
// ---------------------------------------------------------------------

struct Slot<E> {
    t: f64,
    seq: u64,
    ev: E,
}

impl<E> PartialEq for Slot<E> {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.seq == other.seq
    }
}
impl<E> Eq for Slot<E> {}
impl<E> PartialOrd for Slot<E> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Slot<E> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // reversed: BinaryHeap is a max-heap, we need earliest-first;
        // equal timestamps pop in push order (lowest sequence first).
        other
            .t
            .total_cmp(&self.t)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// The (timestamp, sequence) total order both queue implementations pop
/// in: earliest `t` first (`f64::total_cmp`), push order on ties.
#[inline]
fn slot_key_cmp(a_t: f64, a_seq: u64, b_t: f64, b_seq: u64) -> std::cmp::Ordering {
    a_t.total_cmp(&b_t).then_with(|| a_seq.cmp(&b_seq))
}

/// Reference min-heap implementation of the event queue — the original
/// `BinaryHeap` core. Kept as the executable specification the calendar
/// [`EventQueue`] is property-tested against (identical pop sequences
/// under arbitrary push/pop interleavings) and as the baseline of the
/// `micro_hotpath` `wheel_vs_heap_speedup_x` metric.
pub struct HeapEventQueue<E> {
    heap: BinaryHeap<Slot<E>>,
    seq: u64,
}

impl<E> Default for HeapEventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> HeapEventQueue<E> {
    pub fn new() -> Self {
        HeapEventQueue { heap: BinaryHeap::new(), seq: 0 }
    }

    /// Schedule `ev` at absolute virtual time `t`.
    pub fn push(&mut self, t: f64, ev: E) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Slot { t, seq, ev });
    }

    /// Earliest event (ties in push order), or None when drained.
    pub fn pop(&mut self) -> Option<(f64, E)> {
        self.heap.pop().map(|s| (s.t, s.ev))
    }

    /// Timestamp of the next event without popping it.
    pub fn next_t(&self) -> Option<f64> {
        self.heap.peek().map(|s| s.t)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

/// Deterministic event queue with calendar (two-level ladder) internals:
/// earliest timestamp first, FIFO sequence tie-break — the exact
/// (t, seq) total order of [`HeapEventQueue`], bit-for-bit.
///
/// Layout: `cur` holds the imminent batch sorted descending by (t, seq)
/// so `pop` is a O(1) `Vec::pop` from the tail; `future` holds everything
/// at or beyond `horizon`, unsorted, so the common push (an event
/// scheduled past the imminent window) is a O(1) append. When `cur`
/// drains, one `advance` sweep moves the next `span` of virtual time out
/// of `future`, sorts that small batch, and adapts `span` toward a
/// target batch size — so sorting cost stays O(log B) per event for a
/// small B regardless of how many events are outstanding, where the heap
/// paid O(log N) per operation on the whole population. Pushes that land
/// inside the imminent window (same-instant follow-ups, short transfer
/// delays) binary-insert into `cur`, which the adaptation keeps small.
pub struct EventQueue<E> {
    /// Imminent events, sorted descending by (t, seq); pop from the end.
    cur: Vec<Slot<E>>,
    /// Events with `t >= horizon`, unsorted.
    future: Vec<Slot<E>>,
    /// Every slot in `cur` sorts at or before (≤) every slot in
    /// `future`: `cur` times are ≤ `horizon`, `future` times ≥ `horizon`,
    /// and the seq tie-break orders the boundary (a `future` slot at
    /// exactly `horizon` was pushed after any equal-time `cur` slot).
    horizon: f64,
    /// Virtual-time width of the next imminent batch (adaptive).
    span: f64,
    seq: u64,
    /// Times the span adaptation fired (either direction) — exported by
    /// the obs layer as `span_retunes`.
    retunes: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

/// `span` adaptation targets a batch in [`SPAN_MIN_BATCH`, `SPAN_MAX_BATCH`].
const SPAN_MIN_BATCH: usize = 16;
const SPAN_MAX_BATCH: usize = 128;
const SPAN_INIT: f64 = 0.05;

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue {
            cur: Vec::new(),
            future: Vec::new(),
            horizon: f64::NEG_INFINITY,
            span: SPAN_INIT,
            seq: 0,
            retunes: 0,
        }
    }

    /// Schedule `ev` at absolute virtual time `t`.
    pub fn push(&mut self, t: f64, ev: E) {
        let seq = self.seq;
        self.seq += 1;
        let slot = Slot { t, seq, ev };
        if t.total_cmp(&self.horizon) == std::cmp::Ordering::Less {
            // lands inside the imminent window: keep `cur` sorted
            // (descending), so the first index whose key sorts before the
            // new slot is the insertion point. The new slot has the
            // largest seq so far, so among equal timestamps it sits
            // closer to the front — popped last, preserving push order.
            let at = self.cur.partition_point(|s| {
                slot_key_cmp(s.t, s.seq, t, seq) == std::cmp::Ordering::Greater
            });
            self.cur.insert(at, slot);
        } else {
            self.future.push(slot);
        }
    }

    /// Refill `cur` from `future`: take the slots within `span` of the
    /// earliest outstanding timestamp, sort that batch, and adapt `span`
    /// toward the target batch size. Caller guarantees `future` is
    /// non-empty; afterwards `cur` holds at least the earliest slot.
    fn advance(&mut self) {
        debug_assert!(self.cur.is_empty() && !self.future.is_empty());
        // seed from the first slot, not +∞: under `total_cmp` a NaN
        // timestamp sorts above +∞, so an ∞-seeded scan over all-NaN
        // slots would find no minimum and move nothing
        let mut min_t = self.future[0].t;
        for s in &self.future[1..] {
            if s.t.total_cmp(&min_t) == std::cmp::Ordering::Less {
                min_t = s.t;
            }
        }
        // `<=` cutoff: even when `min_t + span` rounds back to `min_t`
        // (huge timestamps, tiny span) the earliest slot still moves, so
        // advance always makes progress.
        let cutoff = min_t + self.span;
        let mut i = 0;
        while i < self.future.len() {
            if self.future[i].t.total_cmp(&cutoff) != std::cmp::Ordering::Greater {
                self.cur.push(self.future.swap_remove(i));
            } else {
                i += 1;
            }
        }
        self.cur.sort_unstable_by(|a, b| slot_key_cmp(b.t, b.seq, a.t, a.seq));
        self.horizon = cutoff;
        let moved = self.cur.len();
        if moved > SPAN_MAX_BATCH {
            self.span *= 0.5;
            self.retunes += 1;
        } else if moved < SPAN_MIN_BATCH {
            self.span *= 2.0;
            self.retunes += 1;
        }
        self.span = self.span.clamp(1e-9, 1e9);
    }

    /// How many times the span adaptation fired so far.
    pub fn span_retunes(&self) -> u64 {
        self.retunes
    }

    /// Earliest event (ties in push order), or None when drained.
    pub fn pop(&mut self) -> Option<(f64, E)> {
        if self.cur.is_empty() {
            if self.future.is_empty() {
                return None;
            }
            self.advance();
        }
        self.cur.pop().map(|s| (s.t, s.ev))
    }

    /// Timestamp of the next event without popping it.
    pub fn next_t(&self) -> Option<f64> {
        if let Some(s) = self.cur.last() {
            return Some(s.t);
        }
        self.future
            .iter()
            .map(|s| s.t)
            .min_by(|a, b| a.total_cmp(b))
    }

    pub fn len(&self) -> usize {
        self.cur.len() + self.future.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cur.is_empty() && self.future.is_empty()
    }
}

// ---------------------------------------------------------------------
// Device + scheduler attachment traits
// ---------------------------------------------------------------------

/// What the engine needs from a device beyond the scheduler-facing
/// [`DeviceGate`]: batch lifecycle, power/energy accounting, telemetry.
pub trait DeviceModel: DeviceGate + Send {
    /// Start a batch at `now`; returns (batch id, finish time).
    fn begin_batch(
        &mut self,
        now: f64,
        flops: u64,
        mem_bytes: u64,
        batch: usize,
        width: f64,
    ) -> (u64, f64);
    /// Complete a batch by id at `now`.
    fn finish_batch(&mut self, now: f64, id: u64);
    /// Integrate energy up to `now` at the current utilization.
    fn integrate_to(&mut self, now: f64);
    /// Instantaneous power draw (W).
    fn power_w(&self) -> f64;
    /// Memory utilization fraction in [0,1].
    fn mem_util(&self) -> f64;
    /// Total joules consumed so far.
    fn energy_j(&self) -> f64;
}

impl DeviceModel for SimDevice {
    fn begin_batch(
        &mut self,
        now: f64,
        flops: u64,
        mem_bytes: u64,
        batch: usize,
        width: f64,
    ) -> (u64, f64) {
        SimDevice::begin_batch(self, now, flops, mem_bytes, batch, width)
    }
    fn finish_batch(&mut self, now: f64, id: u64) {
        SimDevice::finish_batch(self, now, id)
    }
    fn integrate_to(&mut self, now: f64) {
        SimDevice::integrate_to(self, now)
    }
    fn power_w(&self) -> f64 {
        SimDevice::power_w(self)
    }
    fn mem_util(&self) -> f64 {
        SimDevice::mem_util(self)
    }
    fn energy_j(&self) -> f64 {
        SimDevice::energy_j(self)
    }
}

/// The per-server scheduling policy the engine drives (Algorithm 1 by
/// default, but anything honoring the enqueue/step/complete contract).
pub trait LocalScheduler: Send {
    /// Accept a routed request into the local queue.
    fn enqueue(&mut self, q: Queued);
    /// One scheduling sweep; returns the dispatches to execute.
    fn step(&mut self, now: f64, gate: &mut dyn DeviceGate) -> Vec<Dispatch>;
    /// Batch completion: release the instance.
    fn complete(&mut self, instance_id: u64, now: f64);
    /// Offload instances idle past t_idle; returns how many were freed.
    fn unload_idle(&mut self, now: f64, gate: &mut dyn DeviceGate) -> usize;
    /// Local queue length (telemetry q_t^(i)).
    fn queue_len(&self) -> usize;
    /// Loaded instance count (telemetry).
    fn instances_loaded(&self) -> usize;
    /// Counter snapshot for the run report.
    fn stats(&self) -> GreedyStats;
    /// Hand every queued entry back (device dropout re-routing).
    fn drain_queue(&mut self) -> Vec<Queued>;
}

impl LocalScheduler for GreedyScheduler {
    fn enqueue(&mut self, q: Queued) {
        GreedyScheduler::enqueue(self, q)
    }
    fn step(&mut self, now: f64, gate: &mut dyn DeviceGate) -> Vec<Dispatch> {
        GreedyScheduler::step(self, now, gate)
    }
    fn complete(&mut self, instance_id: u64, now: f64) {
        GreedyScheduler::complete(self, instance_id, now)
    }
    fn unload_idle(&mut self, now: f64, gate: &mut dyn DeviceGate) -> usize {
        GreedyScheduler::unload_idle(self, now, gate)
    }
    fn queue_len(&self) -> usize {
        GreedyScheduler::queue_len(self)
    }
    fn instances_loaded(&self) -> usize {
        self.pool.len()
    }
    fn stats(&self) -> GreedyStats {
        self.stats.clone()
    }
    fn drain_queue(&mut self) -> Vec<Queued> {
        self.fifo.drain_all()
    }
}

// ---------------------------------------------------------------------
// Block ledger
// ---------------------------------------------------------------------

/// In-flight routed block (for block-level latency/energy and reward).
#[derive(Clone, Debug)]
pub struct BlockState {
    pub routed_at: f64,
    pub remaining: usize,
    /// Total members routed in this block (fixed at open; `remaining`
    /// counts down from it).
    pub size: usize,
    /// Energy already attributed to completed members (J) — see
    /// [`BlockLedger::member_done`].
    pub charged_j: f64,
    pub width: f64,
    pub seg: usize,
    /// Representative width tuple (first request's history + this width).
    pub tuple: [f64; NUM_SEGMENTS],
}

/// What [`BlockLedger::member_done`] resolved one member completion to.
#[derive(Clone, Debug)]
pub enum MemberDone {
    /// An intermediate member: its provisional 1/size share of the
    /// block energy, integrated at this member's own completion instant.
    Partial { share_j: f64 },
    /// The final member: the block just completed. `energy_j` is the
    /// block's device energy `P̄·L` at the completion instant;
    /// `share_j = energy_j − (shares already charged)`, so the member
    /// shares of a block sum to `energy_j` *exactly* — the invariant the
    /// per-request energy column of the trace rests on. (The remainder
    /// can dip below zero in the corner where cluster mean power falls
    /// sharply between a split block's completions — exactness of the
    /// sum is the contract; per-member shares are an attribution, not a
    /// physical meter.)
    Completed { block: BlockState, latency_s: f64, energy_j: f64, share_j: f64 },
    /// Unknown tag: the block was abandoned (device-dropout re-route)
    /// while this member was already in flight.
    Orphan,
}

/// Tracks every routed block until all its members complete.
#[derive(Clone, Debug, Default)]
pub struct BlockLedger {
    blocks: HashMap<u64, BlockState>,
}

impl BlockLedger {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a freshly routed block under its decision tag.
    pub fn open(&mut self, tag: u64, state: BlockState) {
        self.blocks.insert(tag, state);
    }

    /// One member of `tag` finished; returns the block state when the
    /// whole block just completed. Unknown tags (e.g. blocks orphaned by
    /// a device dropout re-route) are ignored.
    pub fn note_done(&mut self, tag: u64) -> Option<BlockState> {
        let finished = match self.blocks.get_mut(&tag) {
            Some(b) => {
                b.remaining -= 1;
                b.remaining == 0
            }
            None => false,
        };
        if finished {
            self.blocks.remove(&tag)
        } else {
            None
        }
    }

    /// [`BlockLedger::note_done`] with exact per-member energy
    /// accounting. A block's device energy is `P̄·L` measured when its
    /// *last* member completes; members that finish earlier (the block
    /// was re-split across device batches by the local scheduler) are
    /// charged a provisional `P̄(t_i)·(t_i − routed)/size` share at their
    /// own completion instant, accumulated in `charged_j`, and the final
    /// member takes the remainder — so the sum of member shares equals
    /// the block energy to the last bit, whatever the split pattern.
    /// (When the whole block completes in one batch every share reduces
    /// to the historical `E/size` attribution.)
    pub fn member_done(&mut self, tag: u64, power_w: f64, now: f64) -> MemberDone {
        match self.blocks.get_mut(&tag) {
            None => return MemberDone::Orphan,
            Some(b) => {
                b.remaining -= 1;
                if b.remaining > 0 {
                    let share_j =
                        power_w * (now - b.routed_at) / b.size.max(1) as f64;
                    b.charged_j += share_j;
                    return MemberDone::Partial { share_j };
                }
            }
        }
        // last member: settle the block
        let block = self.blocks.remove(&tag).expect("entry present");
        let latency_s = now - block.routed_at;
        let energy_j = power_w * latency_s;
        let share_j = energy_j - block.charged_j;
        MemberDone::Completed { block, latency_s, energy_j, share_j }
    }

    /// Cancel a block outright (its members were re-routed under new
    /// tags); returns the state if it was still open.
    pub fn abandon(&mut self, tag: u64) -> Option<BlockState> {
        self.blocks.remove(&tag)
    }

    /// Blocks still in flight.
    pub fn open_blocks(&self) -> usize {
        self.blocks.len()
    }
}

// ---------------------------------------------------------------------
// Run metrics
// ---------------------------------------------------------------------

/// Per-tenant accounting row (grown on demand as tenants appear).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TenantStat {
    /// Arrivals owned by this tenant (admitted or shed).
    pub arrivals: u64,
    /// Requests that completed all segments.
    pub done: u64,
    /// Requests shed by admission backpressure.
    pub shed: u64,
    /// Sum of end-to-end latencies over `done` (for the mean).
    pub latency_sum: f64,
    /// Completions that blew the tenant's effective SLA
    /// (`sla_s × sla_multiplier(tenant)`).
    pub sla_misses: u64,
    /// Requests the DRR gate admitted at the degraded (slim) width.
    pub degraded: u64,
    /// Ticks where this tenant's DRR credit was forfeited (positive
    /// credit zeroed because its queue went empty).
    pub credit_forfeits: u64,
    /// Failure-cooldown windows this tenant entered (a shed with
    /// `--drr-cooldown` armed pauses its credit accrual).
    pub cooldowns: u64,
}

impl TenantStat {
    /// Mean end-to-end latency over this tenant's completions.
    pub fn mean_latency_s(&self) -> f64 {
        if self.done > 0 {
            self.latency_sum / self.done as f64
        } else {
            0.0
        }
    }

    /// SLA miss rate over this tenant's completions.
    pub fn sla_miss_rate(&self) -> f64 {
        if self.done > 0 {
            self.sla_misses as f64 / self.done as f64
        } else {
            0.0
        }
    }
}

/// Jain's fairness index J = (Σx)² / (n·Σx²) over the positive entries
/// of `xs`: 1.0 when everyone gets the same, →1/n when one tenant takes
/// everything. Empty (or all-zero) input reports 1.0 — a run with
/// nothing to divide is vacuously fair, and it keeps single-tenant runs
/// at exactly 1.0.
pub fn jain_index(xs: &[f64]) -> f64 {
    let (mut sum, mut sq, mut n) = (0.0, 0.0, 0u32);
    for &x in xs {
        if x > 0.0 {
            sum += x;
            sq += x * x;
            n += 1;
        }
    }
    if n == 0 {
        return 1.0;
    }
    (sum * sum) / (n as f64 * sq)
}

/// Everything a run measures while events fire (the Tables III–V rows
/// plus the per-width execution histogram).
#[derive(Clone, Debug)]
pub struct RunMetrics {
    pub done: u64,
    pub total: usize,
    pub block_latency: Summary,
    pub block_energy: Summary,
    pub e2e_latency: Summary,
    pub acc_sum: f64,
    pub telemetry_log: TelemetryLog,
    /// Executed-width histogram over all segment executions, one counter
    /// per member of the scenario's width set W (W order) — sized at
    /// construction so |W| ≠ 4 scenarios report correctly.
    pub width_histogram: Vec<u64>,
    pub blocks_completed: u64,
    /// Plan fields repaired by the explicit `RoutingPlan::clamp` path —
    /// surfaced in `RunOutcome` so silently-corrected routers are
    /// visible instead of vanishing into the repair.
    pub plan_clamps: u64,
    /// The soft per-request SLA (s) completions are judged against
    /// (`RouterCfg::sla_s`, fixed at construction).
    pub sla_s: f64,
    /// Completions whose end-to-end latency exceeded `sla_s`.
    pub sla_misses: u64,
    /// Per-tenant accounting, indexed by tenant id (grown on demand;
    /// single-tenant runs hold exactly one row).
    pub tenant_stats: Vec<TenantStat>,
    /// Requests shed by admission backpressure (never served; a shed
    /// request counts toward run completion so overloaded runs still
    /// terminate).
    pub shed: u64,
    /// Worst admission-queue wait observed (s): the oldest age at which
    /// a request finally cleared the gate.
    pub max_starvation_s: f64,
}

impl RunMetrics {
    pub fn new(n_servers: usize, total: usize, n_widths: usize, sla_s: f64) -> Self {
        RunMetrics {
            done: 0,
            total,
            block_latency: Summary::default(),
            block_energy: Summary::default(),
            e2e_latency: Summary::default(),
            acc_sum: 0.0,
            telemetry_log: TelemetryLog::new(n_servers),
            width_histogram: vec![0; n_widths],
            blocks_completed: 0,
            plan_clamps: 0,
            sla_s,
            sla_misses: 0,
            tenant_stats: Vec::new(),
            shed: 0,
            max_starvation_s: 0.0,
        }
    }

    pub(crate) fn tenant_mut(&mut self, tenant: u16) -> &mut TenantStat {
        let idx = tenant as usize;
        if idx >= self.tenant_stats.len() {
            self.tenant_stats.resize(idx + 1, TenantStat::default());
        }
        &mut self.tenant_stats[idx]
    }

    /// A request arrived (before admission — shed requests count too).
    pub fn record_arrival(&mut self, tenant: u16) {
        self.tenant_mut(tenant).arrivals += 1;
    }

    /// Admission backpressure shed a request outright.
    pub fn record_shed(&mut self, tenant: u16) {
        self.shed += 1;
        self.tenant_mut(tenant).shed += 1;
    }

    /// A request cleared the admission gate after waiting `age_s`.
    pub fn record_starvation(&mut self, age_s: f64) {
        if age_s > self.max_starvation_s {
            self.max_starvation_s = age_s;
        }
    }

    /// A routed block fully completed.
    pub fn record_block(&mut self, latency_s: f64, energy_j: f64) {
        self.block_latency.record(latency_s);
        self.block_energy.record(energy_j);
        self.blocks_completed += 1;
    }

    /// A request crossed its final segment. A non-positive `sla_s`
    /// means no SLA is configured — nothing can miss it (previously a
    /// zero threshold marked *every* completion late). The tenant's
    /// effective SLA is `sla_s × sla_multiplier(tenant)` (×1.0 exact
    /// for tenant 0, so single-tenant miss counts are unchanged).
    pub fn record_request_done(&mut self, e2e_latency_s: f64, acc_pct: f64, tenant: u16) {
        self.done += 1;
        self.e2e_latency.record(e2e_latency_s);
        self.acc_sum += acc_pct;
        let sla = self.sla_s * crate::sim::workload::sla_multiplier(tenant);
        let missed = self.sla_s > 0.0 && e2e_latency_s > sla;
        if missed {
            self.sla_misses += 1;
        }
        let ts = self.tenant_mut(tenant);
        ts.done += 1;
        ts.latency_sum += e2e_latency_s;
        if missed {
            ts.sla_misses += 1;
        }
    }

    /// Shed requests count toward termination: an overloaded run where
    /// admission drops part of the offered load still finishes once
    /// everything has either completed or been shed.
    pub fn all_done(&self) -> bool {
        self.done + self.shed >= self.total as u64
    }

    /// Mean width-tuple accuracy over completed requests.
    pub fn mean_accuracy(&self) -> f64 {
        if self.done > 0 {
            self.acc_sum / self.done as f64
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::utilx::Rng;

    #[test]
    fn event_queue_pops_earliest_first() {
        let mut q: EventQueue<&'static str> = EventQueue::new();
        q.push(2.0, "late");
        q.push(0.5, "early");
        q.push(1.0, "mid");
        assert_eq!(q.next_t(), Some(0.5));
        assert_eq!(q.pop(), Some((0.5, "early")));
        assert_eq!(q.pop(), Some((1.0, "mid")));
        assert_eq!(q.pop(), Some((2.0, "late")));
        assert_eq!(q.pop(), None);
        assert!(q.is_empty());
    }

    #[test]
    fn equal_timestamps_pop_in_push_order() {
        // the determinism guarantee the PPO training loop relies on
        let mut q: EventQueue<usize> = EventQueue::new();
        for i in 0..64 {
            q.push(1.0, i);
        }
        let order: Vec<usize> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn interleaved_times_and_ties() {
        let mut q: EventQueue<u32> = EventQueue::new();
        q.push(1.0, 10);
        q.push(0.0, 0);
        q.push(1.0, 11);
        q.push(0.0, 1);
        let order: Vec<u32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec![0, 1, 10, 11]);
    }

    #[test]
    fn calendar_and_heap_queues_pop_identically_under_random_ops() {
        // the pin that lets the calendar queue replace the heap wholesale:
        // under arbitrary interleavings of pushes and pops — coarse
        // timestamps to force ties, occasional past-horizon pushes, full
        // drains mid-stream — both implementations yield the same
        // (t, payload) sequence bit for bit.
        crate::utilx::prop::check("calendar-matches-heap", 60, |rng: &mut Rng| {
            let mut cal: EventQueue<u64> = EventQueue::new();
            let mut heap: HeapEventQueue<u64> = HeapEventQueue::new();
            let mut next_id = 0u64;
            let mut clock = 0.0f64;
            let ops = 200 + rng.index(800);
            for _ in 0..ops {
                if cal.is_empty() || rng.chance(0.6) {
                    // quantized offsets produce frequent exact ties; a
                    // small chance of a push behind the clock exercises
                    // the inside-horizon insert path
                    let dt = rng.below(16) as f64 * 0.25;
                    let t = if rng.chance(0.1) { (clock - dt).max(0.0) } else { clock + dt };
                    cal.push(t, next_id);
                    heap.push(t, next_id);
                    next_id += 1;
                } else {
                    if cal.next_t() != heap.next_t() {
                        return Err(format!(
                            "next_t diverged: calendar {:?} vs heap {:?}",
                            cal.next_t(),
                            heap.next_t()
                        ));
                    }
                    let a = cal.pop();
                    let b = heap.pop();
                    if a != b {
                        return Err(format!("pop diverged: calendar {a:?} vs heap {b:?}"));
                    }
                    if let Some((t, _)) = a {
                        clock = t;
                    }
                }
                if cal.len() != heap.len() {
                    return Err(format!("len diverged: {} vs {}", cal.len(), heap.len()));
                }
            }
            loop {
                let a = cal.pop();
                let b = heap.pop();
                if a != b {
                    return Err(format!("drain diverged: calendar {a:?} vs heap {b:?}"));
                }
                if a.is_none() {
                    return Ok(());
                }
            }
        });
        // NaN timestamps never arise in the engine, but total_cmp gives
        // them a defined order — both queues must agree there too
        let mut cal: EventQueue<u32> = EventQueue::new();
        let mut heap: HeapEventQueue<u32> = HeapEventQueue::new();
        for (t, id) in [(1.0, 0u32), (f64::NAN, 1), (0.5, 2), (f64::NAN, 3)] {
            cal.push(t, id);
            heap.push(t, id);
        }
        for _ in 0..4 {
            let (ca, he) = (cal.pop().unwrap(), heap.pop().unwrap());
            assert_eq!(ca.0.to_bits(), he.0.to_bits());
            assert_eq!(ca.1, he.1);
        }
    }

    fn block3() -> BlockState {
        BlockState {
            routed_at: 1.0,
            remaining: 3,
            size: 3,
            charged_j: 0.0,
            width: 0.5,
            seg: 2,
            tuple: [0.5; NUM_SEGMENTS],
        }
    }

    #[test]
    fn block_ledger_counts_down() {
        let mut l = BlockLedger::new();
        l.open(7, block3());
        assert_eq!(l.open_blocks(), 1);
        assert!(l.note_done(7).is_none());
        assert!(l.note_done(7).is_none());
        let done = l.note_done(7).expect("third member closes the block");
        assert_eq!(done.seg, 2);
        assert!((done.routed_at - 1.0).abs() < 1e-12);
        assert_eq!(l.open_blocks(), 0);
        // unknown / already-closed tags are ignored
        assert!(l.note_done(7).is_none());
        assert!(l.note_done(99).is_none());
    }

    #[test]
    fn member_shares_sum_exactly_to_block_energy_across_splits() {
        // a 3-member block whose members complete at three different
        // instants under three different power readings — the re-split
        // case the old per-request attribution drifted on
        let mut l = BlockLedger::new();
        l.open(9, block3());
        let mut charged = 0.0;
        let share1 = match l.member_done(9, 100.0, 2.0) {
            MemberDone::Partial { share_j } => share_j,
            other => panic!("first member must be partial: {other:?}"),
        };
        assert!((share1 - 100.0 * 1.0 / 3.0).abs() < 1e-12);
        charged += share1;
        let share2 = match l.member_done(9, 80.0, 3.0) {
            MemberDone::Partial { share_j } => share_j,
            other => panic!("second member must be partial: {other:?}"),
        };
        assert!((share2 - 80.0 * 2.0 / 3.0).abs() < 1e-12);
        charged += share2;
        let (block, latency_s, energy_j, share_j) =
            match l.member_done(9, 120.0, 5.0) {
                MemberDone::Completed { block, latency_s, energy_j, share_j } => {
                    (block, latency_s, energy_j, share_j)
                }
                other => panic!("third member closes the block: {other:?}"),
            };
        assert!((latency_s - 4.0).abs() < 1e-12);
        assert!((energy_j - 120.0 * 4.0).abs() < 1e-12);
        charged += share_j;
        // the invariant: member shares sum to the block's device energy
        assert!((charged - energy_j).abs() < 1e-9, "{charged} vs {energy_j}");
        assert_eq!(block.size, 3);
        assert_eq!(l.open_blocks(), 0);
        // orphaned tags resolve as such (no charge)
        assert!(matches!(l.member_done(9, 100.0, 6.0), MemberDone::Orphan));
    }

    #[test]
    fn single_batch_blocks_split_energy_evenly() {
        // all members complete at one instant: every share is E/size
        let mut l = BlockLedger::new();
        l.open(4, block3());
        let e = 90.0 * 2.0; // P̄ = 90 W, L = 2 s
        for k in 0..3 {
            match l.member_done(4, 90.0, 3.0) {
                MemberDone::Partial { share_j } => {
                    assert!((share_j - e / 3.0).abs() < 1e-12, "member {k}");
                }
                MemberDone::Completed { share_j, energy_j, .. } => {
                    assert_eq!(k, 2);
                    assert!((share_j - e / 3.0).abs() < 1e-9);
                    assert!((energy_j - e).abs() < 1e-12);
                }
                MemberDone::Orphan => panic!("member {k} orphaned"),
            }
        }
    }

    #[test]
    fn run_metrics_accumulate() {
        let mut m = RunMetrics::new(3, 2, 4, 0.6);
        assert_eq!(m.width_histogram.len(), 4);
        assert!(!m.all_done());
        m.record_block(0.2, 30.0);
        m.record_request_done(0.5, 74.0, 0);
        m.record_request_done(0.7, 70.0, 0);
        assert!(m.all_done());
        assert_eq!(m.blocks_completed, 1);
        assert!((m.mean_accuracy() - 72.0).abs() < 1e-12);
        assert_eq!(m.e2e_latency.count(), 2);
        // the 0.7 s completion blew the 0.6 s SLA; the 0.5 s one held it
        assert_eq!(m.sla_misses, 1);
        // single-tenant runs hold exactly one tenant row mirroring the
        // aggregate view
        assert_eq!(m.tenant_stats.len(), 1);
        assert_eq!(m.tenant_stats[0].done, 2);
        assert_eq!(m.tenant_stats[0].sla_misses, 1);
        assert!((m.tenant_stats[0].mean_latency_s() - 0.6).abs() < 1e-12);
    }

    #[test]
    fn shed_requests_count_toward_termination() {
        let mut m = RunMetrics::new(1, 3, 4, 0.0);
        m.record_arrival(0);
        m.record_arrival(1);
        m.record_arrival(1);
        m.record_request_done(0.5, 70.0, 0);
        m.record_shed(1);
        assert!(!m.all_done());
        m.record_shed(1);
        assert!(m.all_done());
        assert_eq!(m.shed, 2);
        assert_eq!(m.tenant_stats[1].shed, 2);
        assert_eq!(m.tenant_stats[1].arrivals, 2);
        m.record_starvation(0.4);
        m.record_starvation(0.2);
        assert_eq!(m.max_starvation_s, 0.4);
    }

    #[test]
    fn per_tenant_sla_uses_the_multiplier() {
        // tenant 1's tier is ×1.5: a 0.7 s completion misses tenant 0's
        // 0.6 s SLA but holds tenant 1's 0.9 s one
        let mut m = RunMetrics::new(1, 2, 4, 0.6);
        m.record_request_done(0.7, 70.0, 0);
        m.record_request_done(0.7, 70.0, 1);
        assert_eq!(m.sla_misses, 1);
        assert_eq!(m.tenant_stats[0].sla_misses, 1);
        assert_eq!(m.tenant_stats[1].sla_misses, 0);
    }

    #[test]
    fn jain_index_brackets() {
        assert_eq!(jain_index(&[]), 1.0);
        assert_eq!(jain_index(&[3.0]), 1.0);
        assert_eq!(jain_index(&[2.0, 2.0, 2.0]), 1.0);
        // one tenant hogging everything → 1/n
        let j = jain_index(&[10.0, 1e-12, 1e-12]);
        assert!(j < 0.4, "j={j}");
        // zeros are excluded (tenants that served nothing don't poison
        // the index)
        assert_eq!(jain_index(&[5.0, 0.0, 5.0]), 1.0);
        let mixed = jain_index(&[1.0, 2.0, 3.0]);
        assert!(mixed > 0.5 && mixed < 1.0, "mixed={mixed}");
    }

    #[test]
    fn engine_is_send() {
        // the property ppo::parallel's scoped worker threads require
        fn assert_send<T: Send>() {}
        assert_send::<super::super::Engine<super::super::router::RandomRouter>>();
        assert_send::<EventQueue<u64>>();
    }
}
